//! Deterministic-BC test-time inference (paper Sec. 2.6 method 1 + Sec. 5
//! hardware claims): train the MLP, fold the binary weights + BN into the
//! bit-packed multiplication-free engine, and compare it against f32
//! inference on accuracy, weight memory, and latency.
//!
//!     cargo run --release --example binary_inference -- --epochs 15

use binaryconnect::bench_harness::{bench, fmt_time, Table};
use binaryconnect::binary::packed::dense_f32;
use binaryconnect::binary::{load_packed, pack_mlp, save_packed};
use binaryconnect::coordinator::{mnist_opts, prepare, train, DataOpts};
use binaryconnect::data::Corpus;
use binaryconnect::runtime::{Executor, Mode, ReferenceExecutor};
use binaryconnect::util::error::{Error, Result};
use binaryconnect::util::Args;

fn main() -> Result<()> {
    let args = Args::parse().map_err(Error::msg)?;
    let epochs = args.usize("epochs", 15);

    let model = ReferenceExecutor::builtin("mlp")?;
    let info = model.info().clone();

    let (data, _) = prepare(
        Corpus::Mnist,
        &DataOpts { n_train: 3000, n_test: 800, ..Default::default() },
    )?;

    eprintln!("training det-BC MLP for {epochs} epochs ...");
    let result = train(&model, &data, &mnist_opts(Mode::Det, epochs, 11))?;
    eprintln!(
        "trained: val err {:.4}, reference-eval test err {:.4}",
        result.best_val_err, result.test_err
    );

    // ---- fold into the packed engine and round-trip through disk
    let packed = pack_mlp(&info, &result.state)?;
    let path = std::env::temp_dir().join("bc_mlp_packed.bin");
    save_packed(&packed, &path)?;
    let packed = load_packed(&path)?;
    eprintln!("packed model saved + reloaded from {}", path.display());

    let packed_err = packed.test_error(&data.test, 256);
    println!(
        "\naccuracy:   reference (binary weights) {:.4}  |  packed engine {:.4}  (must match closely)",
        result.test_err, packed_err
    );

    // ---- memory claim (paper: >= 16x vs 16-bit floats; 32x vs f32)
    let packed_b = packed.weight_memory_bytes();
    let f32_b = packed.f32_weight_memory_bytes();
    println!(
        "memory:     f32 {:>8} B   packed {:>8} B   ratio {:.1}x (paper claims >= 16x vs f16 = {:.1}x)",
        f32_b,
        packed_b,
        f32_b as f64 / packed_b as f64,
        f32_b as f64 / 2.0 / packed_b as f64
    );

    // ---- latency: packed sign-gated accumulate vs naive f32 GEMM over the
    //      same trained layers (batch 64)
    let b = 64usize;
    let x: Vec<f32> = data.test.x[..b * data.test.dim].to_vec();
    let weights_f32: Vec<(Vec<f32>, usize, usize)> = {
        let mut out = vec![];
        for (i, p) in info.params.iter().enumerate() {
            if p.kind == "weight" {
                out.push((result.state.param_vec(i)?, p.shape[0], p.shape[1]));
            }
        }
        out
    };

    let r_packed = bench("packed", 3, 20, || {
        std::hint::black_box(packed.forward(&x, b));
    });
    let r_f32 = bench("f32", 3, 20, || {
        let mut cur = x.clone();
        for (w, k, n) in &weights_f32 {
            let mut next = vec![0f32; b * n];
            dense_f32(&cur, w, b, *k, *n, &mut next);
            for v in next.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
            cur = next;
        }
        std::hint::black_box(cur);
    });

    let mut t = Table::new(&["engine", "mean / batch-64", "images/s", "weight bytes"]);
    t.row(&[
        "f32 GEMM (no multiplier savings)".into(),
        fmt_time(r_f32.mean_s),
        format!("{:.0}", b as f64 / r_f32.mean_s),
        format!("{f32_b}"),
    ]);
    t.row(&[
        "packed sign-accumulate (mult-free)".into(),
        fmt_time(r_packed.mean_s),
        format!("{:.0}", b as f64 / r_packed.mean_s),
        format!("{packed_b}"),
    ]);
    println!();
    t.print();
    println!(
        "\nNote: on CPU the win is memory ({}x) — the paper's mult-free claim targets\n\
         ASIC/FPGA datapaths where removing multipliers also removes area/energy;\n\
         see `bcrun hw` and benches/hw_claims.rs for the op-count model.",
        f32_b / packed_b
    );
    Ok(())
}
