//! SVHN (paper Sec. 3.3): same protocol as CIFAR-10 with a half-width CNN
//! (the `cnn_small` artifact) and fewer epochs — the paper uses 200 instead
//! of 500 because SVHN is large.
//!
//!     cargo run --release --example svhn_cnn -- --epochs 8 --n-train 2000

use anyhow::Result;

use binaryconnect::bench_harness::Table;
use binaryconnect::coordinator::{cnn_opts, prepare, train, DataOpts};
use binaryconnect::data::Corpus;
use binaryconnect::runtime::{Manifest, Mode, Runtime};
use binaryconnect::util::Args;

fn main() -> Result<()> {
    let args = Args::parse().map_err(anyhow::Error::msg)?;
    let epochs = args.usize("epochs", 8);

    let manifest = Manifest::load(std::path::Path::new(&args.str("artifacts", "artifacts")))?;
    let rt = Runtime::cpu()?;
    let model = rt.load_model(manifest.model("cnn_small")?)?;

    let (data, real) = prepare(
        Corpus::Svhn,
        &DataOpts {
            data_dir: args.opt_str("data-dir").map(Into::into),
            n_train: args.usize("n-train", 2000),
            n_test: args.usize("n-test", 500),
            ..Default::default()
        },
    )?;
    eprintln!(
        "SVHN protocol: {} train / {} val / {} test ({}), half-width CNN, {} epochs",
        data.train.len(),
        data.val.len(),
        data.test.len(),
        if real { "real" } else { "synthetic" },
        epochs
    );

    let mut table = Table::new(&["Method", "Test error", "best epoch"]);
    for (label, mode) in [
        ("No regularizer", Mode::None),
        ("BinaryConnect (det.)", Mode::Det),
        ("BinaryConnect (stoch.)", Mode::Stoch),
    ] {
        let r = train(&model, &data, &cnn_opts(mode, epochs, 5))?;
        table.row(&[
            label.to_string(),
            format!("{:.2} %", r.test_err * 100.0),
            r.best_epoch.to_string(),
        ]);
    }
    println!("\nTable 2 (SVHN column) — measured on this testbed:");
    table.print();
    println!("paper (full scale): none 2.44, det 2.30, stoch 2.15");
    Ok(())
}
