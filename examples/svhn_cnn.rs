//! SVHN (paper Sec. 3.3): same protocol as CIFAR-10 with a narrower model
//! and fewer epochs — the paper uses 200 instead of 500 because SVHN is
//! large. On the reference backend the half-width CNN is stood in for by
//! the `svhn_mlp` dense model.
//!
//!     cargo run --release --example svhn_cnn -- --epochs 8 --n-train 2000

use binaryconnect::bench_harness::Table;
use binaryconnect::coordinator::{cnn_opts, prepare, train, DataOpts};
use binaryconnect::data::Corpus;
use binaryconnect::runtime::{Mode, ReferenceExecutor};
use binaryconnect::util::error::{Error, Result};
use binaryconnect::util::Args;

fn main() -> Result<()> {
    let args = Args::parse().map_err(Error::msg)?;
    let epochs = args.usize("epochs", 8);

    let model = ReferenceExecutor::builtin(&args.str("model", "svhn_mlp"))?;

    let (data, real) = prepare(
        Corpus::Svhn,
        &DataOpts {
            data_dir: args.opt_str("data-dir").map(Into::into),
            n_train: args.usize("n-train", 2000),
            n_test: args.usize("n-test", 500),
            ..Default::default()
        },
    )?;
    eprintln!(
        "SVHN protocol: {} train / {} val / {} test ({}), {} epochs",
        data.train.len(),
        data.val.len(),
        data.test.len(),
        if real { "real" } else { "synthetic" },
        epochs
    );

    let mut table = Table::new(&["Method", "Test error", "best epoch"]);
    for (label, mode) in [
        ("No regularizer", Mode::None),
        ("BinaryConnect (det.)", Mode::Det),
        ("BinaryConnect (stoch.)", Mode::Stoch),
    ] {
        let r = train(&model, &data, &cnn_opts(mode, epochs, 5))?;
        table.row(&[
            label.to_string(),
            format!("{:.2} %", r.test_err * 100.0),
            r.best_epoch.to_string(),
        ]);
    }
    println!("\nTable 2 (SVHN column) — measured on this testbed:");
    table.print();
    println!("paper (full scale): none 2.44, det 2.30, stoch 2.15");
    Ok(())
}
