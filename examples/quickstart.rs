//! Quickstart: the end-to-end driver proving all three layers compose.
//!
//! Trains the BinaryConnect MLP (deterministic binarization, Algorithm 1)
//! on a small synthetic MNIST for a few hundred steps through the full
//! stack — Rust coordinator -> PJRT -> AOT HLO containing the Pallas
//! kernels — and logs the loss curve. Run with:
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! The run recorded in EXPERIMENTS.md par."End-to-end validation" is this
//! binary's output.

use anyhow::Result;

use binaryconnect::coordinator::{mnist_opts, prepare, train, DataOpts};
use binaryconnect::data::Corpus;
use binaryconnect::runtime::{Manifest, Mode, Runtime};

fn main() -> Result<()> {
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let info = manifest.model("mlp")?;
    println!(
        "model: mlp — {} param tensors, {} scalars, batch {}",
        info.params.len(),
        info.n_scalars,
        info.batch
    );

    // ~3000 synthetic MNIST digits -> 25 train batches/epoch
    let (data, real) = prepare(
        Corpus::Mnist,
        &DataOpts { n_train: 3000, n_test: 600, ..Default::default() },
    )?;
    println!(
        "data: {} ({} train / {} val / {} test, {})",
        data.train.name,
        data.train.len(),
        data.val.len(),
        data.test.len(),
        if real { "real" } else { "synthetic" }
    );

    let rt = Runtime::cpu()?;
    let model = rt.load_model(info)?;

    let mut opts = mnist_opts(Mode::Det, 16, 42);
    opts.verbose = true; // per-epoch progress to stderr
    let result = train(&model, &data, &opts)?;

    println!("\nloss curve (train squared hinge, per epoch):");
    for r in &result.curves {
        let bar = "*".repeat((r.train_loss.min(60.0) * 1.0) as usize / 2);
        println!("  epoch {:>2}  loss {:>8.3}  val err {:>6.3}  {}", r.epoch, r.train_loss, r.val_err, bar);
    }
    println!(
        "\n{} steps in {:.1}s ({:.1} steps/s)",
        result.steps,
        result.total_seconds,
        result.steps as f64 / result.total_seconds
    );
    println!(
        "best val err {:.4} @ epoch {} -> test err {:.4} (binary weights at test time)",
        result.best_val_err, result.best_epoch, result.test_err
    );

    // the BinaryConnect invariant: real weights clipped to ±H
    for (lit, p) in result.state.params.iter().zip(&model.info.params) {
        if p.kind == "weight" {
            let v = lit.to_vec::<f32>()?;
            let maxabs = v.iter().fold(0f32, |a, &b| a.max(b.abs()));
            assert!(maxabs <= p.glorot as f32 + 1e-6, "{} escaped clip box", p.name);
        }
    }
    println!("all binary weight tensors inside their ±H clip boxes — OK");
    Ok(())
}
