//! Quickstart: the end-to-end driver proving the layers compose.
//!
//! Trains the BinaryConnect MLP (deterministic binarization, Algorithm 1)
//! on a small synthetic MNIST for a few hundred steps through the full
//! stack — data pipeline -> Executor backend -> model selection — and logs
//! the loss curve. Runs on the pure-Rust reference backend, so a plain
//!
//!     cargo run --release --example quickstart
//!
//! works from a clean checkout with no artifacts.

use binaryconnect::coordinator::{mnist_opts, prepare, train, DataOpts};
use binaryconnect::data::Corpus;
use binaryconnect::runtime::{Executor, Mode, ReferenceExecutor};
use binaryconnect::util::error::Result;

fn main() -> Result<()> {
    let model = ReferenceExecutor::builtin("mlp")?;
    let info = model.info().clone();
    println!(
        "model: {} — {} param tensors, {} scalars, batch {}",
        info.name,
        info.params.len(),
        info.n_scalars,
        info.batch
    );

    // ~3000 synthetic MNIST digits -> 23 train batches/epoch
    let (data, real) = prepare(
        Corpus::Mnist,
        &DataOpts { n_train: 3000, n_test: 600, ..Default::default() },
    )?;
    println!(
        "data: {} ({} train / {} val / {} test, {})",
        data.train.name,
        data.train.len(),
        data.val.len(),
        data.test.len(),
        if real { "real" } else { "synthetic" }
    );

    let mut opts = mnist_opts(Mode::Det, 16, 42);
    opts.verbose = true; // per-epoch progress to stderr
    let result = train(&model, &data, &opts)?;

    println!("\nloss curve (train squared hinge, per epoch):");
    for r in &result.curves {
        let bar = "*".repeat((r.train_loss.min(60.0) * 1.0) as usize / 2);
        println!(
            "  epoch {:>2}  loss {:>8.3}  val err {:>6.3}  {}",
            r.epoch, r.train_loss, r.val_err, bar
        );
    }
    println!(
        "\n{} steps in {:.1}s ({:.1} steps/s)",
        result.steps,
        result.total_seconds,
        result.steps as f64 / result.total_seconds
    );
    println!(
        "best val err {:.4} @ epoch {} -> test err {:.4} (binary weights at test time)",
        result.best_val_err, result.best_epoch, result.test_err
    );

    // the BinaryConnect invariant: real weights clipped to ±H
    for (t, p) in result.state.params.iter().zip(&info.params) {
        if p.kind == "weight" {
            let maxabs = t.iter().fold(0f32, |a, &b| a.max(b.abs()));
            assert!(maxabs <= p.glorot as f32 + 1e-6, "{} escaped clip box", p.name);
        }
    }
    println!("all binary weight tensors inside their ±H clip boxes — OK");
    Ok(())
}
