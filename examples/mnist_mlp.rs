//! Permutation-invariant MNIST, Table 2's first column.
//!
//! Runs the paper's four regimes — no regularizer, deterministic BC,
//! stochastic BC, 50% dropout — each repeated over several seeds, and
//! prints the Table-2-style rows (test error mean ± std at the
//! best-validation epoch, SGD without momentum, exponentially decaying
//! LR). Flags: --epochs N --trials N --n-train N --n-test N --data-dir D
//!
//!     cargo run --release --example mnist_mlp -- --epochs 30 --trials 3

use binaryconnect::bench_harness::Table;
use binaryconnect::coordinator::{dropout_opts, mnist_opts, prepare, trials, DataOpts};
use binaryconnect::data::Corpus;
use binaryconnect::runtime::{Mode, ReferenceExecutor};
use binaryconnect::util::error::{Error, Result};
use binaryconnect::util::Args;

fn main() -> Result<()> {
    let args = Args::parse().map_err(Error::msg)?;
    let epochs = args.usize("epochs", 25);
    let n_trials = args.usize("trials", 3);

    let model = ReferenceExecutor::builtin("mlp")?;

    let (data, real) = prepare(
        Corpus::Mnist,
        &DataOpts {
            data_dir: args.opt_str("data-dir").map(Into::into),
            n_train: args.usize("n-train", 6000),
            n_test: args.usize("n-test", 1500),
            ..Default::default()
        },
    )?;
    eprintln!(
        "MNIST protocol: {} train / {} val / {} test ({}), {} epochs x {} trials",
        data.train.len(),
        data.val.len(),
        data.test.len(),
        if real { "real" } else { "synthetic" },
        epochs,
        n_trials,
    );

    let regimes: Vec<(&str, binaryconnect::coordinator::TrainOpts)> = vec![
        ("No regularizer", mnist_opts(Mode::None, epochs, 1)),
        ("BinaryConnect (det.)", mnist_opts(Mode::Det, epochs, 1)),
        ("BinaryConnect (stoch.)", mnist_opts(Mode::Stoch, epochs, 1)),
        ("50% Dropout", dropout_opts(&mnist_opts(Mode::None, epochs, 1))),
    ];

    let mut table = Table::new(&["Method", "Test error (mean ± std)", "best-val epochs"]);
    for (name, opts) in regimes {
        eprintln!("running {name} ...");
        let s = trials(&model, &data, &opts, n_trials)?;
        let epochs_str = s
            .results
            .iter()
            .map(|r| r.best_epoch.to_string())
            .collect::<Vec<_>>()
            .join(",");
        table.row(&[
            name.to_string(),
            format!("{:.2} ± {:.2} %", s.mean * 100.0, s.std * 100.0),
            epochs_str,
        ]);
    }
    println!("\nTable 2 (MNIST column) — measured on this testbed:");
    table.print();
    println!("paper (full scale): none 1.30±0.04, det 1.29±0.08, stoch 1.18±0.04, dropout 1.01±0.04");
    Ok(())
}
