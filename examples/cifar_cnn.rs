//! CIFAR-10 (paper Sec. 3.2 / Figure 3 protocol).
//!
//! Trains with ADAM + BN + GCN/ZCA preprocessing in each regime and
//! writes per-epoch training-cost / validation-error curves (Figure 3's
//! series) to CSV. On the reference backend the Eq.-5 CNN is stood in
//! for by the `cifar_mlp` dense model; the regularizer comparison — the
//! point of the figure — is architecture-agnostic.
//!
//!     cargo run --release --example cifar_cnn -- --epochs 12 --n-train 2000

use binaryconnect::coordinator::{cnn_opts, prepare, train, DataOpts};
use binaryconnect::data::Corpus;
use binaryconnect::runtime::{Mode, ReferenceExecutor};
use binaryconnect::stats::Csv;
use binaryconnect::util::error::{Error, Result};
use binaryconnect::util::Args;

fn main() -> Result<()> {
    let args = Args::parse().map_err(Error::msg)?;
    let epochs = args.usize("epochs", 10);
    let out = args.str("out", "cifar_curves");

    let model = ReferenceExecutor::builtin(&args.str("model", "cifar_mlp"))?;

    let (data, real) = prepare(
        Corpus::Cifar10,
        &DataOpts {
            data_dir: args.opt_str("data-dir").map(Into::into),
            n_train: args.usize("n-train", 2000),
            n_test: args.usize("n-test", 500),
            zca: !args.bool("no-zca", false),
            ..Default::default()
        },
    )?;
    eprintln!(
        "CIFAR-10 protocol: {} train / {} val / {} test ({}), GCN+ZCA, ADAM, {} epochs",
        data.train.len(),
        data.val.len(),
        data.test.len(),
        if real { "real" } else { "synthetic" },
        epochs
    );

    for (label, mode) in [("none", Mode::None), ("det", Mode::Det), ("stoch", Mode::Stoch)] {
        let mut opts = cnn_opts(mode, epochs, 3);
        opts.verbose = true;
        eprintln!("--- regime: {label} ---");
        let r = train(&model, &data, &opts)?;
        let mut csv = Csv::new(&["epoch", "train_cost", "val_err"]);
        for rec in &r.curves {
            csv.rowf(&[rec.epoch as f64, rec.train_loss, rec.val_err]);
        }
        let path = format!("{out}_{label}.csv");
        csv.save(std::path::Path::new(&path))?;
        println!(
            "{label:>6}: best val {:.4} @ epoch {} -> test {:.4}  ({} -> {})",
            r.best_val_err,
            r.best_epoch,
            r.test_err,
            r.curves.first().map(|c| format!("{:.2}", c.train_loss)).unwrap_or_default(),
            r.curves.last().map(|c| format!("{:.2}", c.train_loss)).unwrap_or_default(),
        );
        println!("wrote {path}");
    }
    println!("\nFigure 3's qualitative shape: BC regimes keep a higher training cost and");
    println!("(at paper scale) a lower validation error than the unregularized baseline.");
    Ok(())
}
