"""AOT export tests: lowering to HLO text and manifest integrity."""

import json
import os

import jax
import numpy as np
import pytest

from compile import hyper as H
from compile.aot import build_configs, lower_model, to_hlo_text
from compile.models import MLPConfig
from compile.train import make_train_step


def test_build_configs_cover_default_set():
    cfgs = build_configs(1)
    assert set(cfgs) == {"mlp", "mlp_ng", "cnn", "cnn_small"}
    assert cfgs["mlp"].use_pallas and not cfgs["mlp_ng"].use_pallas
    # SVHN net is half the CIFAR net (paper Sec. 3.3)
    assert cfgs["cnn_small"].base * 2 == cfgs["cnn"].base
    assert cfgs["cnn_small"].fc * 2 == cfgs["cnn"].fc


def test_scale_flag_multiplies_width():
    c1 = build_configs(1)["mlp"]
    c8 = build_configs(8)["mlp"]
    assert c8.hidden == 8 * c1.hidden
    # paper scale: 3 x 1024 hidden units
    assert c8.hidden == 1024


def test_hlo_text_is_parseable_hlo(tmp_path):
    cfg = MLPConfig(name="t", hidden=8, batch=4, in_dim=6, depth=1, use_pallas=False)
    sds = jax.ShapeDtypeStruct
    f32 = jax.numpy.float32
    spec = cfg.spec()
    pshapes = [sds(d.shape, f32) for d in spec]
    lowered = jax.jit(make_train_step(cfg)).lower(
        *(pshapes * 3),
        sds(cfg.input_shape, f32),
        sds((4, 10), f32),
        sds((H.LEN,), f32),
    )
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:50]
    assert "ENTRY" in text
    # tuple return: 3n params/slots + loss + nerr
    assert text.count("parameter(") >= 3 * len(spec) + 3


def test_lower_model_writes_artifacts_and_manifest_entry(tmp_path):
    cfg = MLPConfig(name="tiny", hidden=8, batch=4, in_dim=6, depth=1, use_pallas=False)
    entry = lower_model(cfg, str(tmp_path))
    for k in ("init", "train", "eval"):
        path = tmp_path / entry["artifacts"][k]
        assert path.exists(), k
        assert path.read_text().startswith("HloModule")
    assert entry["batch"] == 4
    assert entry["n_param_tensors"] == len(cfg.spec())
    names = [p["name"] for p in entry["params"]]
    assert names[0] == "l0.W" and names[-1] == "out.b"
    kinds = {p["kind"] for p in entry["params"]}
    assert kinds == {"weight", "affine", "bn_stat"}
    # glorot coefficients recorded for weights only
    for p in entry["params"]:
        if p["kind"] == "weight":
            assert p["glorot"] > 0
        else:
            assert p["glorot"] == 0


def test_generated_manifest_consistency():
    # validate the real artifacts dir when present (built by `make artifacts`)
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        manifest = json.load(f)
    assert manifest["hyper"]["len"] == H.LEN
    for name, m in manifest["models"].items():
        n_scalars = sum(int(np.prod(p["shape"])) for p in m["params"])
        assert n_scalars == m["n_scalars"], name
        d = os.path.dirname(path)
        for art in m["artifacts"].values():
            assert os.path.exists(os.path.join(d, art)), art
