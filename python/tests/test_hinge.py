"""Kernel-vs-oracle tests for the squared hinge (L2-SVM) loss."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import hinge_loss
from compile.kernels import ref


def _case(seed, b, c):
    rs = np.random.RandomState(seed)
    z = rs.standard_normal((b, c)).astype(np.float32) * 2
    labels = rs.randint(0, c, size=b)
    y = -np.ones((b, c), np.float32)
    y[np.arange(b), labels] = 1.0
    return z, y


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 300), c=st.integers(2, 20), seed=st.integers(0, 2**16))
def test_hinge_matches_ref(b, c, seed):
    z, y = _case(seed, b, c)
    out = hinge_loss(jnp.asarray(z), jnp.asarray(y))
    assert out.shape == (b,)
    assert_allclose(np.asarray(out), np.asarray(ref.hinge_loss_ref(z, y)), rtol=1e-5, atol=1e-5)


def test_hinge_grad_matches_ref():
    z, y = _case(5, 64, 10)
    zj, yj = jnp.asarray(z), jnp.asarray(y)

    g = jax.grad(lambda z_: jnp.mean(hinge_loss(z_, yj)))(zj)
    gref = ref.hinge_grad_ref(z, y, np.full(64, 1.0 / 64, np.float32))
    assert_allclose(np.asarray(g), np.asarray(gref), rtol=1e-5, atol=1e-6)


def test_hinge_zero_when_margin_satisfied():
    # z exactly on the correct side with margin >= 1 -> zero loss.
    y = np.array([[1.0, -1.0]], np.float32)
    z = np.array([[2.0, -3.0]], np.float32)
    out = np.asarray(hinge_loss(jnp.asarray(z), jnp.asarray(y)))
    assert_allclose(out, [0.0])


def test_hinge_known_value():
    y = np.array([[1.0, -1.0]], np.float32)
    z = np.array([[0.0, 0.0]], np.float32)
    # both classes violate by exactly 1 -> 1^2 + 1^2 = 2
    out = np.asarray(hinge_loss(jnp.asarray(z), jnp.asarray(y)))
    assert_allclose(out, [2.0])
