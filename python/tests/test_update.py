"""Kernel-vs-oracle tests for the fused clip-update kernels (Sec. 2.4)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import sgd_update, nesterov_update, adam_update
from compile.kernels import ref

SHAPES = st.sampled_from([(5,), (100,), (8192,), (8200,), (17, 9), (64, 64)])
LR = st.floats(1e-4, 0.5)


def _tensors(seed, shape, n):
    rs = np.random.RandomState(seed)
    return [rs.standard_normal(shape).astype(np.float32) for _ in range(n)]


@settings(max_examples=20, deadline=None)
@given(shape=SHAPES, lr=LR, clip=st.booleans(), seed=st.integers(0, 2**16))
def test_sgd_update_matches_ref(shape, lr, clip, seed):
    w, g = _tensors(seed, shape, 2)
    out = sgd_update(jnp.asarray(w), jnp.asarray(g), lr, 1.0 if clip else 0.0)
    expect = ref.sgd_update_ref(w, g, np.float32(lr), clip)
    assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(shape=SHAPES, lr=LR, clip=st.booleans(), seed=st.integers(0, 2**16))
def test_nesterov_update_matches_ref(shape, lr, clip, seed):
    w, g, m = _tensors(seed, shape, 3)
    mu = 0.9
    w2, m2 = nesterov_update(
        jnp.asarray(w), jnp.asarray(g), jnp.asarray(m), lr, 1.0 if clip else 0.0, mu
    )
    ew, em = ref.nesterov_update_ref(w, g, m, np.float32(lr), clip, np.float32(mu))
    assert_allclose(np.asarray(w2), np.asarray(ew), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(m2), np.asarray(em), rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(shape=SHAPES, lr=LR, clip=st.booleans(), t=st.integers(1, 500), seed=st.integers(0, 2**16))
def test_adam_update_matches_ref(shape, lr, clip, t, seed):
    w, g, m, v = _tensors(seed, shape, 4)
    v = np.abs(v)  # second-moment slot is non-negative in real runs
    b1, b2, eps = 0.9, 0.999, 1e-8
    corr1 = 1.0 - b1**t
    corr2 = 1.0 - b2**t
    w2, m2, v2 = adam_update(
        jnp.asarray(w), jnp.asarray(g), jnp.asarray(m), jnp.asarray(v),
        lr, 1.0 if clip else 0.0, b1, b2, eps, corr1, corr2,
    )
    ew, em, ev = ref.adam_update_ref(
        w, g, m, v, np.float32(lr), clip, np.float32(b1), np.float32(b2), np.float32(eps), t
    )
    # corr1/corr2 reach the kernel as pre-rounded f32 scalars while the
    # oracle keeps python-float precision in beta**t — allow that ulp gap
    assert_allclose(np.asarray(w2), np.asarray(ew), rtol=2e-3, atol=2e-5)
    assert_allclose(np.asarray(m2), np.asarray(em), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(v2), np.asarray(ev), rtol=1e-5, atol=1e-6)


def test_clip_keeps_weights_in_unit_box():
    w = jnp.asarray(np.linspace(-2, 2, 101).astype(np.float32))
    g = jnp.asarray(np.ones(101, np.float32) * -10.0)  # pushes w up hard
    out = np.asarray(sgd_update(w, g, 1.0, 1.0))
    assert out.min() >= -1.0 and out.max() <= 1.0


def test_no_clip_lets_weights_escape():
    w = jnp.zeros((4,), jnp.float32)
    g = jnp.asarray(np.full(4, -10.0, np.float32))
    out = np.asarray(sgd_update(w, g, 1.0, 0.0))
    assert_allclose(out, np.full(4, 10.0, np.float32))
