"""Tests for Layer-2 building blocks."""

import numpy as np
import jax
import jax.numpy as jnp
from numpy.testing import assert_allclose

from compile import layers as L


def test_glorot_coeff():
    assert_allclose(L.glorot_coeff(784, 1024), np.sqrt(6.0 / 1808.0))


def test_glorot_init_bounds_and_spread():
    key = jax.random.PRNGKey(0)
    w = np.asarray(L.glorot_init(key, (200, 300), 200, 300))
    c = L.glorot_coeff(200, 300)
    assert w.min() >= -c and w.max() <= c
    # uniform(-c, c) variance = c^2/3
    assert_allclose(w.var(), c * c / 3.0, rtol=0.1)


def test_dense_binary_det_uses_sign_weights():
    x = jnp.asarray([[1.0, 2.0]], jnp.float32)
    w = jnp.asarray([[0.3], [-0.2]], jnp.float32)
    out = L.dense_binary(x, w, jax.random.PRNGKey(0), jnp.int32(1))
    assert_allclose(np.asarray(out), [[1.0 - 2.0]], rtol=1e-6)


def test_dense_binary_pallas_vs_native():
    rs = np.random.RandomState(0)
    x = jnp.asarray(rs.standard_normal((32, 65)).astype(np.float32))
    w = jnp.asarray(rs.standard_normal((65, 17)).astype(np.float32))
    key = jax.random.PRNGKey(3)
    a = L.dense_binary(x, w, key, jnp.int32(1), use_pallas=True)
    b = L.dense_binary(x, w, key, jnp.int32(1), use_pallas=False)
    assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_conv_binary_matches_manual_sign_conv():
    rs = np.random.RandomState(1)
    x = jnp.asarray(rs.standard_normal((2, 8, 8, 3)).astype(np.float32))
    w = jnp.asarray(rs.standard_normal((3, 3, 3, 5)).astype(np.float32))
    out = L.conv_binary(x, w, jax.random.PRNGKey(0), jnp.int32(1))
    wb = jnp.where(w >= 0, 1.0, -1.0)
    expect = jax.lax.conv_general_dilated(
        x, wb, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-5)
    assert out.shape == (2, 8, 8, 5)


def test_batchnorm_train_normalizes():
    rs = np.random.RandomState(2)
    x = jnp.asarray(rs.standard_normal((256, 16)).astype(np.float32) * 3 + 5)
    gamma = jnp.ones(16)
    beta = jnp.zeros(16)
    y, nm, nv = L.batchnorm_train(x, gamma, beta, jnp.zeros(16), jnp.ones(16), 0.9)
    yn = np.asarray(y)
    assert_allclose(yn.mean(axis=0), np.zeros(16), atol=1e-4)
    assert_allclose(yn.var(axis=0), np.ones(16), rtol=1e-2)
    # running stats move toward batch stats
    assert_allclose(np.asarray(nm), 0.1 * np.asarray(x).mean(axis=0), rtol=1e-4)


def test_batchnorm_conv_reduces_spatial():
    rs = np.random.RandomState(3)
    x = jnp.asarray(rs.standard_normal((4, 6, 6, 8)).astype(np.float32) * 2 + 1)
    y, _, _ = L.batchnorm_train(x, jnp.ones(8), jnp.zeros(8), jnp.zeros(8), jnp.ones(8), 0.9)
    yn = np.asarray(y).reshape(-1, 8)
    assert_allclose(yn.mean(axis=0), np.zeros(8), atol=1e-4)


def test_batchnorm_eval_uses_running_stats():
    x = jnp.asarray([[2.0, 4.0]], jnp.float32)
    y = L.batchnorm_eval(x, jnp.ones(2), jnp.zeros(2), jnp.asarray([1.0, 2.0]), jnp.ones(2))
    assert_allclose(np.asarray(y), [[1.0, 2.0]], rtol=1e-3)


def test_maxpool2():
    x = jnp.arange(16.0).reshape(1, 4, 4, 1)
    y = np.asarray(L.maxpool2(x)).reshape(2, 2)
    assert_allclose(y, [[5.0, 7.0], [13.0, 15.0]])


def test_dropout_zero_rate_identity():
    x = jnp.asarray(np.random.RandomState(0).standard_normal((64, 32)).astype(np.float32))
    y = L.dropout(x, jax.random.PRNGKey(0), jnp.float32(0.0))
    assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-6)


def test_dropout_preserves_expectation():
    x = jnp.ones((400, 500), jnp.float32)
    y = np.asarray(L.dropout(x, jax.random.PRNGKey(1), jnp.float32(0.5)))
    assert abs(y.mean() - 1.0) < 0.02
    # roughly half the units dropped
    drop_frac = (y == 0).mean()
    assert abs(drop_frac - 0.5) < 0.02
