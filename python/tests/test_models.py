"""Model spec / init / apply consistency tests."""

import numpy as np
import jax
import jax.numpy as jnp
from numpy.testing import assert_allclose

from compile import hyper as H
from compile.models import MLPConfig, CNNConfig, init_params, n_scalars


def _hv(**kw):
    hv = np.zeros(H.LEN, np.float32)
    hv[H.BN_MOMENTUM] = 0.9
    hv[H.STEP] = 1
    for k, val in kw.items():
        hv[H.NAMES[k]] = val
    return jnp.asarray(hv)


MLP = MLPConfig(hidden=32, batch=8, in_dim=20, use_pallas=False)
CNN = CNNConfig(base=4, fc=16, batch=4, in_hw=16)


def test_mlp_spec_shapes():
    spec = MLP.spec()
    # 3 hidden layers x (W + 4 BN) + out W + out b
    assert len(spec) == 3 * 5 + 2
    assert spec[0].shape == (20, 32)
    assert spec[0].kind == "weight"
    assert spec[-2].shape == (32, 10)
    assert spec[-1].shape == (10,)
    names = [d.name for d in spec]
    assert len(set(names)) == len(names)


def test_cnn_spec_shapes():
    spec = CNN.spec()
    # 6 conv x 5 + 2 fc x 5 + out W + b
    assert len(spec) == 6 * 5 + 2 * 5 + 2
    assert spec[0].shape == (3, 3, 3, 4)
    # after 3 maxpools: 16 -> 2; flat = 2*2*16 = 64
    fc0 = [d for d in spec if d.name == "fc0.W"][0]
    assert fc0.shape == (64, 16)


def test_init_params_match_spec():
    params = init_params(MLP, jax.random.PRNGKey(0))
    spec = MLP.spec()
    assert len(params) == len(spec)
    for p, d in zip(params, spec):
        assert p.shape == d.shape
    # BN gamma starts at 1, stats at (0, 1)
    gamma = params[1]
    assert_allclose(np.asarray(gamma), np.ones(32, np.float32))


def test_n_scalars_counts():
    total = sum(int(np.prod(d.shape)) for d in MLP.spec())
    assert n_scalars(MLP) == total


def test_mlp_apply_shapes_and_determinism():
    params = init_params(MLP, jax.random.PRNGKey(1))
    x = jnp.asarray(np.random.RandomState(0).standard_normal((8, 20)).astype(np.float32))
    key = jax.random.PRNGKey(7)
    logits, updates = MLP.apply(params, x, key, _hv(mode=1), train=True)
    assert logits.shape == (8, 10)
    # one (rmean, rvar) update per hidden layer
    assert len(updates) == 6
    logits2, _ = MLP.apply(params, x, key, _hv(mode=1), train=True)
    assert_allclose(np.asarray(logits), np.asarray(logits2), rtol=1e-6)


def test_mlp_eval_no_updates():
    params = init_params(MLP, jax.random.PRNGKey(1))
    x = jnp.zeros((8, 20), jnp.float32)
    logits, updates = MLP.apply(params, x, jax.random.PRNGKey(0), _hv(mode=0), train=False)
    assert updates == {}
    assert logits.shape == (8, 10)


def test_cnn_apply_shapes():
    params = init_params(CNN, jax.random.PRNGKey(2))
    x = jnp.asarray(np.random.RandomState(1).standard_normal((4, 16, 16, 3)).astype(np.float32))
    logits, updates = CNN.apply(params, x, jax.random.PRNGKey(0), _hv(mode=1), train=True)
    assert logits.shape == (4, 10)
    assert len(updates) == 16  # 8 BN layers x 2 stats


def test_mode_changes_output():
    params = init_params(MLP, jax.random.PRNGKey(3))
    x = jnp.asarray(np.random.RandomState(2).standard_normal((8, 20)).astype(np.float32))
    key = jax.random.PRNGKey(0)
    l0, _ = MLP.apply(params, x, key, _hv(mode=0), train=False)
    l1, _ = MLP.apply(params, x, key, _hv(mode=1), train=False)
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


def test_stochastic_mode_varies_with_seed():
    params = init_params(MLP, jax.random.PRNGKey(4))
    x = jnp.ones((8, 20), jnp.float32)
    la, _ = MLP.apply(params, x, jax.random.PRNGKey(1), _hv(mode=2), train=False)
    lb, _ = MLP.apply(params, x, jax.random.PRNGKey(2), _hv(mode=2), train=False)
    assert not np.allclose(np.asarray(la), np.asarray(lb))
