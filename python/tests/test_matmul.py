"""Kernel-vs-oracle tests for the blocked Pallas matmuls."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import pmatmul, bgemm_det
from compile.kernels import ref

DIMS = st.integers(1, 300)


def _mats(seed, m, k, n):
    rs = np.random.RandomState(seed)
    x = rs.standard_normal((m, k)).astype(np.float32)
    w = rs.standard_normal((k, n)).astype(np.float32)
    return x, w


@settings(max_examples=20, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**16))
def test_pmatmul_matches_ref(m, k, n, seed):
    x, w = _mats(seed, m, k, n)
    out = pmatmul(jnp.asarray(x), jnp.asarray(w))
    assert_allclose(np.asarray(out), np.asarray(ref.matmul_ref(x, w)), rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**16))
def test_bgemm_det_matches_ref(m, k, n, seed):
    x, w = _mats(seed, m, k, n)
    out = bgemm_det(jnp.asarray(x), jnp.asarray(w))
    assert_allclose(np.asarray(out), np.asarray(ref.bgemm_det_ref(x, w)), rtol=2e-4, atol=2e-4)


def test_pmatmul_exact_block_multiples():
    x, w = _mats(7, 256, 128, 384)
    out = pmatmul(jnp.asarray(x), jnp.asarray(w))
    assert_allclose(np.asarray(out), x @ w, rtol=2e-4, atol=2e-4)


def test_pmatmul_gradients_match_dot():
    x, w = _mats(11, 30, 20, 10)

    def f_pallas(x_, w_):
        return jnp.sum(pmatmul(x_, w_) ** 2)

    def f_ref(x_, w_):
        return jnp.sum(jnp.dot(x_, w_) ** 2)

    gx_p, gw_p = jax.grad(f_pallas, argnums=(0, 1))(jnp.asarray(x), jnp.asarray(w))
    gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(jnp.asarray(x), jnp.asarray(w))
    assert_allclose(np.asarray(gx_p), np.asarray(gx_r), rtol=1e-3, atol=1e-3)
    assert_allclose(np.asarray(gw_p), np.asarray(gw_r), rtol=1e-3, atol=1e-3)


def test_bgemm_binarizes_weights_not_activations():
    # x stays real; only w is signed.
    x = np.array([[0.5, -0.25]], np.float32)
    w = np.array([[0.3], [-0.7]], np.float32)
    out = bgemm_det(jnp.asarray(x), jnp.asarray(w))
    # 0.5*1 + (-0.25)*(-1) = 0.75
    assert_allclose(np.asarray(out), [[0.75]], rtol=1e-6)


def test_pmatmul_shape_errors():
    import pytest

    with pytest.raises(ValueError):
        pmatmul(jnp.ones((2, 3)), jnp.ones((4, 5)))
    with pytest.raises(ValueError):
        pmatmul(jnp.ones((2, 3, 4)), jnp.ones((4, 5)))
