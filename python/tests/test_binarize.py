"""Kernel-vs-oracle tests for the binarization kernels (paper Eqs. 1-3)."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import binarize, binarize_det, binarize_stoch, hard_sigmoid
from compile.kernels import ref

SHAPES = st.sampled_from(
    [(1,), (7,), (128,), (8192,), (8193,), (3, 5), (64, 64), (2, 3, 4), (1, 1, 1, 1)]
)


def _arr(rs, shape, scale=2.0):
    return (rs.standard_normal(shape) * scale).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(shape=SHAPES, seed=st.integers(0, 2**16))
def test_binarize_det_matches_ref(shape, seed):
    w = _arr(np.random.RandomState(seed), shape)
    out = binarize_det(jnp.asarray(w))
    assert_allclose(np.asarray(out), np.asarray(ref.binarize_det_ref(w)))


@settings(max_examples=25, deadline=None)
@given(shape=SHAPES, seed=st.integers(0, 2**16))
def test_binarize_stoch_matches_ref(shape, seed):
    rs = np.random.RandomState(seed)
    w = _arr(rs, shape)
    u = rs.uniform(size=shape).astype(np.float32)
    out = binarize_stoch(jnp.asarray(w), jnp.asarray(u))
    assert_allclose(np.asarray(out), np.asarray(ref.binarize_stoch_ref(w, u)))


@settings(max_examples=25, deadline=None)
@given(shape=SHAPES, seed=st.integers(0, 2**16))
def test_hard_sigmoid_matches_ref(shape, seed):
    x = _arr(np.random.RandomState(seed), shape, scale=3.0)
    out = hard_sigmoid(jnp.asarray(x))
    assert_allclose(np.asarray(out), np.asarray(ref.hard_sigmoid_ref(x)), rtol=1e-6)


def test_binarize_det_outputs_only_pm1():
    w = jnp.asarray(np.random.RandomState(0).standard_normal((50, 50)).astype(np.float32))
    out = np.asarray(binarize_det(w))
    assert set(np.unique(out)) <= {-1.0, 1.0}


def test_binarize_det_tie_goes_positive():
    out = np.asarray(binarize_det(jnp.zeros((4,), jnp.float32)))
    assert_allclose(out, np.ones(4, np.float32))


def test_binarize_stoch_expectation_is_hard_sigmoid():
    # E[w_b] = 2*sigma(w) - 1: the paper's "preserves the expected value"
    # property (Sec. 2.3), checked by Monte Carlo.
    w = jnp.asarray([-2.0, -0.5, 0.0, 0.5, 2.0], jnp.float32)
    n = 20000
    key = jax.random.PRNGKey(0)
    u = jax.random.uniform(key, (n, 5))
    wb = binarize_stoch(jnp.broadcast_to(w, (n, 5)), u)
    mean = np.asarray(wb).mean(axis=0)
    expect = 2.0 * np.asarray(ref.hard_sigmoid_ref(w)) - 1.0
    assert_allclose(mean, expect, atol=0.03)


def test_binarize_mode_switch():
    rs = np.random.RandomState(3)
    w = jnp.asarray(_arr(rs, (33, 17)))
    key = jax.random.PRNGKey(5)
    u = jax.random.uniform(key, w.shape, w.dtype)  # what the stoch branch draws
    out0 = binarize(w, key, jnp.int32(0), 1.0)
    out1 = binarize(w, key, jnp.int32(1), 1.0)
    out2 = binarize(w, key, jnp.int32(2), 1.0)
    assert_allclose(np.asarray(out0), np.asarray(w))
    assert_allclose(np.asarray(out1), np.asarray(ref.binarize_det_ref(w)))
    assert_allclose(np.asarray(out2), np.asarray(ref.binarize_stoch_ref(w, u)))


def test_binarize_straight_through_gradient():
    # dC/dw must equal dC/dw_b exactly (identity STE), for every mode.
    rs = np.random.RandomState(4)
    w = jnp.asarray(_arr(rs, (8, 8)))
    key = jax.random.PRNGKey(6)
    c = jnp.asarray(_arr(rs, (8, 8)))

    for mode in (0, 1, 2):
        g = jax.grad(lambda w_: jnp.sum(binarize(w_, key, jnp.int32(mode), 0.5) * c))(w)
        assert_allclose(np.asarray(g), np.asarray(c), rtol=1e-6)


def test_binarize_jit_lowers():
    # The op must survive jit + lowering (the AOT path depends on it).
    w = jnp.ones((16, 16), jnp.float32)
    f = jax.jit(binarize)
    out = f(w, jax.random.PRNGKey(0), jnp.int32(1), 1.0)
    assert_allclose(np.asarray(out), np.ones((16, 16), np.float32))


def test_binarize_det_scale_h():
    w = jnp.asarray([[0.02, -0.01]], jnp.float32)
    out = np.asarray(binarize_det(w, 0.25))
    assert_allclose(out, [[0.25, -0.25]])


def test_binarize_stoch_scale_h_probability():
    # p = hard_sigmoid(w / H): at w = H/2, p = 0.75 regardless of H.
    h = 0.125
    w = jnp.full((20000,), h / 2, jnp.float32)
    u = jax.random.uniform(jax.random.PRNGKey(0), (20000,))
    wb = np.asarray(binarize_stoch(w, u, h))
    assert set(np.unique(wb)) <= {-np.float32(h), np.float32(h)}
    frac_pos = (wb > 0).mean()
    assert abs(frac_pos - 0.75) < 0.02, frac_pos
