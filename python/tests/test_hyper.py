"""Hyper-vector layout invariants (mirrored by rust/src/runtime/hyper.rs)."""

from compile import hyper as H


def test_indices_unique_and_in_range():
    vals = list(H.NAMES.values())
    assert len(set(vals)) == len(vals)
    assert all(0 <= v < H.LEN for v in vals)


def test_canonical_positions_frozen():
    # the Rust mirror hard-codes these; breaking them silently corrupts runs
    assert H.LR == 0
    assert H.MODE == 1
    assert H.OPT == 2
    assert H.MOMENTUM == 3
    assert H.BETA2 == 4
    assert H.EPS == 5
    assert H.DROPOUT == 6
    assert H.BN_MOMENTUM == 7
    assert H.LR_SCALE == 8
    assert H.STEP == 9
    assert H.SEED == 10
    assert H.IN_DROPOUT == 11
    assert H.LEN == 16


def test_names_map_matches_constants():
    for name, idx in H.NAMES.items():
        assert getattr(H, name.upper()) == idx
