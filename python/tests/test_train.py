"""End-to-end train-step tests: one jitted function implements Algorithm 1."""

import numpy as np
import jax
import jax.numpy as jnp
from numpy.testing import assert_allclose

from compile import hyper as H
from compile.models import MLPConfig, init_params
from compile.train import make_train_step, make_eval_step, make_init

CFG = MLPConfig(hidden=32, batch=16, in_dim=12, depth=2, use_pallas=False)
N = len(CFG.spec())


def _hv(**kw):
    hv = np.zeros(H.LEN, np.float32)
    hv[H.LR] = 0.05
    hv[H.MOMENTUM] = 0.9
    hv[H.BETA2] = 0.999
    hv[H.EPS] = 1e-8
    hv[H.BN_MOMENTUM] = 0.9
    hv[H.STEP] = 1
    for k, val in kw.items():
        hv[H.NAMES[k]] = val
    return jnp.asarray(hv)


def _batch(seed=0):
    rs = np.random.RandomState(seed)
    x = rs.standard_normal((16, 12)).astype(np.float32)
    labels = rs.randint(0, 10, 16)
    y = -np.ones((16, 10), np.float32)
    y[np.arange(16), labels] = 1.0
    return jnp.asarray(x), jnp.asarray(y)


def _state(seed=0):
    params = init_params(CFG, jax.random.PRNGKey(seed))
    zeros = [jnp.zeros_like(p) for p in params]
    return params, zeros, [jnp.zeros_like(p) for p in params]


def test_init_artifact_matches_init_params():
    init = jax.jit(make_init(CFG))
    out = init(_hv(seed=5))
    assert len(out) == 3 * N
    params = init_params(CFG, jax.random.fold_in(jax.random.PRNGKey(0), jnp.uint32(5)))
    for a, b in zip(out[:N], params):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    for s in out[N:]:
        assert float(jnp.abs(s).max()) == 0.0


def test_train_step_output_arity_and_metrics():
    step = jax.jit(make_train_step(CFG))
    params, m, v = _state()
    x, y = _batch()
    out = step(*params, *m, *v, x, y, _hv(mode=1, opt=0))
    assert len(out) == 3 * N + 2
    loss, nerr = float(out[-2]), float(out[-1])
    assert loss > 0.0
    assert 0 <= nerr <= 16


def test_sgd_loss_decreases_over_steps():
    step = jax.jit(make_train_step(CFG))
    params, m, v = _state()
    x, y = _batch()
    losses = []
    state = list(params) + list(m) + list(v)
    for t in range(1, 31):
        out = step(*state, x, y, _hv(mode=1, opt=0, step=t, seed=t, lr=0.05))
        state = list(out[: 3 * N])
        losses.append(float(out[-2]))
    assert losses[-1] < losses[0] * 0.7, losses


def test_adam_loss_decreases_over_steps():
    step = jax.jit(make_train_step(CFG))
    params, m, v = _state()
    x, y = _batch()
    state = list(params) + list(m) + list(v)
    losses = []
    for t in range(1, 31):
        out = step(*state, x, y, _hv(mode=2, opt=2, step=t, seed=t, lr=0.01, lr_scale=1))
        state = list(out[: 3 * N])
        losses.append(float(out[-2]))
    assert losses[-1] < losses[0] * 0.7, losses


def test_binary_weights_stay_clipped():
    step = jax.jit(make_train_step(CFG))
    params, m, v = _state()
    x, y = _batch()
    state = list(params) + list(m) + list(v)
    for t in range(1, 11):
        out = step(*state, x, y, _hv(mode=1, opt=0, step=t, lr=1.0))  # huge LR
        state = list(out[: 3 * N])
    spec = CFG.spec()
    for i, d in enumerate(spec):
        if d.kind == "weight":
            # clip box is ±H with H the layer's Glorot coefficient
            w = np.asarray(state[i])
            assert np.abs(w).max() <= d.glorot + 1e-6, d.name


def test_no_reg_mode_does_not_clip():
    # Start the first weight matrix just inside its clip box; a single
    # unclipped SGD step must be able to cross the ±H boundary in mode 0
    # but not in mode 1.
    step = jax.jit(make_train_step(CFG))
    params, m, v = _state()
    params = list(params)
    h = CFG.spec()[0].glorot
    params[0] = jnp.full_like(params[0], h * 0.999)
    x, y = _batch()
    out0 = step(*params, *m, *v, x, y, _hv(mode=0, opt=0, lr=0.5))
    out1 = step(*params, *m, *v, x, y, _hv(mode=1, opt=0, lr=0.5))
    w0 = np.asarray(out0[0])
    w1 = np.asarray(out1[0])
    assert np.abs(w0).max() > h  # real-valued weights free to grow without BC
    assert np.abs(w1).max() <= h + 1e-6  # BC clips (Sec. 2.4)


def test_bn_stats_update_only_in_train():
    step = jax.jit(make_train_step(CFG))
    params, m, v = _state()
    x, y = _batch()
    out = step(*params, *m, *v, x, y, _hv(mode=1, opt=0))
    spec = CFG.spec()
    moved = [
        i
        for i, d in enumerate(spec)
        if d.kind == "bn_stat"
        and not np.allclose(np.asarray(out[i]), np.asarray(params[i]))
    ]
    assert len(moved) == 4  # rmean+rvar per hidden layer


def test_eval_step_per_example_outputs():
    evals = jax.jit(make_eval_step(CFG))
    params, _, _ = _state()
    x, y = _batch()
    lossv, errv = evals(*params, x, y, _hv(mode=1))
    assert lossv.shape == (16,)
    assert errv.shape == (16,)
    assert set(np.unique(np.asarray(errv))) <= {0.0, 1.0}


def test_eval_real_vs_binary_weights_differ():
    evals = jax.jit(make_eval_step(CFG))
    params, _, _ = _state()
    x, y = _batch()
    l0, _ = evals(*params, x, y, _hv(mode=0))
    l1, _ = evals(*params, x, y, _hv(mode=1))
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


def test_optimizers_diverge_from_each_other():
    step = jax.jit(make_train_step(CFG))
    params, m, v = _state()
    x, y = _batch()
    hv_sgd = _hv(mode=1, opt=0)
    hv_adam = _hv(mode=1, opt=2)
    o1 = step(*params, *m, *v, x, y, hv_sgd)
    o2 = step(*params, *m, *v, x, y, hv_adam)
    w1, w2 = np.asarray(o1[0]), np.asarray(o2[0])
    assert not np.allclose(w1, w2)


def test_lr_scaling_changes_update():
    step = jax.jit(make_train_step(CFG))
    params, m, v = _state()
    x, y = _batch()
    o1 = step(*params, *m, *v, x, y, _hv(mode=1, opt=0, lr_scale=0))
    o2 = step(*params, *m, *v, x, y, _hv(mode=1, opt=0, lr_scale=1))
    assert not np.allclose(np.asarray(o1[0]), np.asarray(o2[0]))
    # Scaled SGD takes strictly larger steps (lr / coeff^2 > lr): the mean
    # |delta| must grow, up to the ±H clip.
    w0 = np.asarray(params[0])
    d1 = np.abs(np.asarray(o1[0]) - w0).mean()
    d2 = np.abs(np.asarray(o2[0]) - w0).mean()
    assert d2 > d1 * 2.0, f"scaled delta {d2} vs unscaled {d1}"
