"""The AOT-exported step functions: init / train_step / eval_step.

Every function is a *pure* function of its tensor arguments — no Python
state — so each lowers to one HLO artifact that the Rust coordinator can
execute forever.  Wire format (flat, in spec order):

    init(hyper)                                  -> (P..., M..., V...)
    train_step(P..., M..., V..., x, y, hyper)    -> (P'..., M'..., V'..., loss, nerr)
    eval_step(P..., x, y, hyper)                 -> (loss_vec[B], err_vec[B])

P is the full param list (weights, BN affine, BN stats); M/V are optimizer
slots (zeros where unused, so every optimizer shares one signature).
Algorithm 1 maps onto train_step as: binarize -> forward -> backward (both
on w_b, via the straight-through ``binarize``) -> update + clip on the
real-valued weights (the fused Layer-1 update kernels).
"""

import jax
import jax.numpy as jnp

from . import hyper as H
from .kernels import hinge_loss, sgd_update, nesterov_update, adam_update


def _key_from(hv):
    seed = hv[H.SEED].astype(jnp.uint32)
    return jax.random.fold_in(jax.random.PRNGKey(0), seed)


def _metrics(logits, y):
    pred = jnp.argmax(logits, axis=1)
    target = jnp.argmax(y, axis=1)
    errv = (pred != target).astype(jnp.float32)
    lossv = hinge_loss(logits, y)
    return lossv, errv


def make_train_step(config):
    spec = config.spec()
    n = len(spec)
    tr_idx = [i for i, d in enumerate(spec) if d.kind != "bn_stat"]
    is_weight = [spec[i].kind == "weight" for i in tr_idx]
    coeff = [spec[i].glorot for i in tr_idx]

    def _updates(opt_scale_pow, update_one):
        """Build one optimizer branch: map update_one over trainables."""

        def branch(tr, grads, m, v, lr, mode, lr_scale, hv):
            new_tr, new_m, new_v = [], [], []
            for j in range(len(tr)):
                if is_weight[j]:
                    # Sec. 2.5 trick, as in the authors' released code
                    # (W_LR_scale="Glorot"): the weight LR is scaled UP by
                    # the inverse Glorot coefficient (inverse square for
                    # SGD/Nesterov) — clipped [-H, H] weights need steps
                    # large enough to flip signs within a run.
                    c = coeff[j] ** opt_scale_pow
                    lr_j = jnp.where(lr_scale > 0.0, lr / c, lr)
                    clip_j = jnp.where(mode > 0.0, 1.0, 0.0)
                    h_j = coeff[j]
                else:
                    lr_j = lr
                    clip_j = jnp.float32(0.0)
                    h_j = 1.0
                w2, m2, v2 = update_one(tr[j], grads[j], m[j], v[j], lr_j, clip_j, h_j, hv)
                new_tr.append(w2)
                new_m.append(m2)
                new_v.append(v2)
            return new_tr, new_m, new_v

        return branch

    def _sgd_one(w, g, m, v, lr, clip, h, hv):
        return sgd_update(w, g, lr, clip, h), m, v

    def _nesterov_one(w, g, m, v, lr, clip, h, hv):
        w2, m2 = nesterov_update(w, g, m, lr, clip, hv[H.MOMENTUM], h)
        return w2, m2, v

    def _adam_one(w, g, m, v, lr, clip, h, hv):
        t = hv[H.STEP]
        corr1 = 1.0 - jnp.power(hv[H.MOMENTUM], t)
        corr2 = 1.0 - jnp.power(hv[H.BETA2], t)
        return adam_update(
            w, g, m, v, lr, clip, hv[H.MOMENTUM], hv[H.BETA2], hv[H.EPS], corr1, corr2, h
        )

    def train_step(*args):
        assert len(args) == 3 * n + 3, f"expected {3 * n + 3} args, got {len(args)}"
        params = list(args[:n])
        mslots = list(args[n : 2 * n])
        vslots = list(args[2 * n : 3 * n])
        x, y, hv = args[3 * n], args[3 * n + 1], args[3 * n + 2]
        key = _key_from(hv)

        def loss_fn(tr):
            full = list(params)
            for j, gi in enumerate(tr_idx):
                full[gi] = tr[j]
            logits, bn_updates = config.apply(full, x, key, hv, train=True)
            lossv, errv = _metrics(logits, y)
            return jnp.mean(lossv), (jnp.sum(errv), bn_updates)

        tr = [params[gi] for gi in tr_idx]
        (loss, (nerr, bn_updates)), grads = jax.value_and_grad(loss_fn, has_aux=True)(tr)

        lr = hv[H.LR]
        mode = hv[H.MODE]
        lr_scale = hv[H.LR_SCALE]
        opt = hv[H.OPT].astype(jnp.int32)
        tr_m = [mslots[gi] for gi in tr_idx]
        tr_v = [vslots[gi] for gi in tr_idx]
        new_tr, new_m, new_v = jax.lax.switch(
            opt,
            [
                _updates(2, _sgd_one),       # SGD scales LR by 1/coeff^2
                _updates(2, _nesterov_one),  # so does Nesterov momentum
                _updates(1, _adam_one),      # ADAM scales by 1/coeff
            ],
            tr, grads, tr_m, tr_v, lr, mode, lr_scale, hv,
        )

        out_p, out_m, out_v = list(params), list(mslots), list(vslots)
        for j, gi in enumerate(tr_idx):
            out_p[gi] = new_tr[j]
            out_m[gi] = new_m[j]
            out_v[gi] = new_v[j]
        for gi, stat in bn_updates.items():
            out_p[gi] = stat
        return tuple(out_p + out_m + out_v + [loss, nerr])

    return train_step


def make_eval_step(config):
    spec = config.spec()
    n = len(spec)

    def eval_step(*args):
        assert len(args) == n + 3
        params = list(args[:n])
        x, y, hv = args[n], args[n + 1], args[n + 2]
        key = _key_from(hv)
        logits, _ = config.apply(params, x, key, hv, train=False)
        lossv, errv = _metrics(logits, y)
        return lossv, errv

    return eval_step


def make_init(config):
    from .models import init_params

    n = len(config.spec())

    def init(hv):
        key = _key_from(hv)
        params = init_params(config, key)
        zeros = [jnp.zeros_like(p) for p in params]
        return tuple(params + zeros + [jnp.zeros_like(z) for z in zeros])

    return init
