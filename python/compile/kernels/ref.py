"""Pure-jnp oracles for every Layer-1 kernel.

These are the correctness ground truth: no Pallas, no tiling, no padding —
just the textbook formulas.  ``python/tests/`` asserts kernel == oracle
over hypothesis-generated shapes/values, and the Rust test-suite's expected
values are derived from these as well.
"""

import jax.numpy as jnp


def hard_sigmoid_ref(x):
    """Eq. 3."""
    return jnp.clip((x + 1.0) * 0.5, 0.0, 1.0)


def binarize_det_ref(w, h=1.0):
    """Eq. 1 at scale H, ties to +H."""
    return jnp.where(w >= 0.0, h, -h).astype(w.dtype)


def binarize_stoch_ref(w, u, h=1.0):
    """Eq. 2 at scale H with externally supplied uniforms."""
    return jnp.where(u < hard_sigmoid_ref(w / h), h, -h).astype(w.dtype)


def matmul_ref(x, w):
    return jnp.dot(x, w)


def bgemm_det_ref(x, w):
    return jnp.dot(x, binarize_det_ref(w))


def sgd_update_ref(w, g, lr, clip, h=1.0):
    wn = w - lr * g
    return jnp.clip(wn, -h, h) if clip else wn


def nesterov_update_ref(w, g, m, lr, clip, mu, h=1.0):
    m_new = mu * m - lr * g
    wn = w + mu * m_new - lr * g
    if clip:
        wn = jnp.clip(wn, -h, h)
    return wn, m_new


def adam_update_ref(w, g, m, v, lr, clip, beta1, beta2, eps, t, h=1.0):
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    m_hat = m_new / (1.0 - beta1**t)
    v_hat = v_new / (1.0 - beta2**t)
    wn = w - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    if clip:
        wn = jnp.clip(wn, -h, h)
    return wn, m_new, v_new


def hinge_loss_ref(z, y):
    margin = jnp.maximum(0.0, 1.0 - y * z)
    return jnp.sum(margin * margin, axis=1)


def hinge_grad_ref(z, y, g):
    margin = jnp.maximum(0.0, 1.0 - y * z)
    return -2.0 * margin * y * g[:, None]
