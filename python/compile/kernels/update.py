"""Fused optimizer-update kernels: clip(w - step, -H, H) in one VMEM pass.

The paper clips the real-valued weights right after every update
(Sec. 2.4) so they cannot drift where the binarization no longer sees
them.  The clip box is [-H, H] with H the layer's binarization scale (the
Glorot coefficient, matching the authors' released code; the paper text's
[-1, 1] is the H = 1 special case).  Each kernel fuses the optimizer
arithmetic with that clip so the weight tensor is read and written exactly
once per step.

All kernels take ``clip`` as a traced 0/1 flag (broadcast scalar): binary
weights clip, biases / BN affine parameters do not.  The learning rate
arrives pre-scaled by the inverse Glorot coefficient (Sec. 2.5 trick).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8192


def _clip_or_not(wn, w_clip, h):
    return jnp.where(w_clip > 0.0, jnp.clip(wn, -h, h), wn)


def _sgd_kernel(w_ref, g_ref, s_ref, o_ref):
    # s = [lr, clip, h]
    lr = s_ref[0]
    wn = w_ref[...] - lr * g_ref[...]
    o_ref[...] = _clip_or_not(wn, s_ref[1], s_ref[2])


def _nesterov_kernel(w_ref, g_ref, m_ref, s_ref, ow_ref, om_ref):
    # s = [lr, clip, h, mu]
    # Nesterov momentum (Sutskever formulation):
    #   m' = mu * m - lr * g ;  w' = w + mu * m' - lr * g
    lr = s_ref[0]
    mu = s_ref[3]
    g = g_ref[...]
    m_new = mu * m_ref[...] - lr * g
    wn = w_ref[...] + mu * m_new - lr * g
    om_ref[...] = m_new
    ow_ref[...] = _clip_or_not(wn, s_ref[1], s_ref[2])


def _adam_kernel(w_ref, g_ref, m_ref, v_ref, s_ref, ow_ref, om_ref, ov_ref):
    # s = [lr, clip, h, beta1, beta2, eps, corr1, corr2]
    # corr1/corr2 = 1 - beta^t bias corrections, computed once per step at
    # L2 so the kernel stays elementwise.
    lr, b1, b2, eps = s_ref[0], s_ref[3], s_ref[4], s_ref[5]
    corr1, corr2 = s_ref[6], s_ref[7]
    g = g_ref[...]
    m_new = b1 * m_ref[...] + (1.0 - b1) * g
    v_new = b2 * v_ref[...] + (1.0 - b2) * g * g
    m_hat = m_new / corr1
    v_hat = v_new / corr2
    wn = w_ref[...] - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    om_ref[...] = m_new
    ov_ref[...] = v_new
    ow_ref[...] = _clip_or_not(wn, s_ref[1], s_ref[2])


def _ew_multi(kernel, tensors, scalars, n_out):
    """Elementwise kernel over same-shape tensors + a small scalar vector.

    The scalar vector rides along unblocked (pl.BlockSpec with a constant
    index map) so every grid step sees the full hyper row.
    """
    shape = tensors[0].shape
    dtype = tensors[0].dtype
    n = 1
    for d in shape:
        n *= d
    flat = [t.reshape((n,)) for t in tensors]
    npad = (-n) % BLOCK
    if npad:
        flat = [jnp.pad(t, (0, npad)) for t in flat]
    total = n + npad
    s = jnp.asarray(scalars, dtype=dtype)
    ns = s.shape[0]
    grid = (total // BLOCK,)
    in_specs = [pl.BlockSpec((BLOCK,), lambda i: (i,)) for _ in flat]
    in_specs.append(pl.BlockSpec((ns,), lambda i: (0,)))
    out_shape = [jax.ShapeDtypeStruct((total,), dtype) for _ in range(n_out)]
    out_specs = [pl.BlockSpec((BLOCK,), lambda i: (i,)) for _ in range(n_out)]
    if n_out == 1:
        out_shape, out_specs = out_shape[0], out_specs[0]
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=True,
    )(*flat, s)
    if n_out == 1:
        outs = (outs,)
    return tuple(o[:n].reshape(shape) for o in outs)


def sgd_update(w, g, lr, clip, h=1.0):
    """w' = maybe_clip(w - lr * g, ±h).  Returns w'."""
    (w2,) = _ew_multi(_sgd_kernel, [w, g], [lr, clip, h], 1)
    return w2


def nesterov_update(w, g, m, lr, clip, mu, h=1.0):
    """Nesterov momentum step.  Returns (w', m')."""
    return _ew_multi(_nesterov_kernel, [w, g, m], [lr, clip, h, mu], 2)


def adam_update(w, g, m, v, lr, clip, beta1, beta2, eps, corr1, corr2, h=1.0):
    """ADAM step with bias correction.  Returns (w', m', v')."""
    return _ew_multi(
        _adam_kernel,
        [w, g, m, v],
        [lr, clip, h, beta1, beta2, eps, corr1, corr2],
        3,
    )
