"""Blocked Pallas matmul kernels.

``pmatmul``  -- general A @ B with a classic (m, n, k) grid, f32 accumulate
               in the revisited output block.  Wrapped in a custom_vjp so it
               is usable under ``jax.grad`` (Pallas kernels do not
               auto-differentiate); the backward pass reuses the same kernel
               on transposed operands, so fwd AND bwd matmuls both run the
               Pallas hot path, exactly as Algorithm 1 prescribes for w_b.

``bgemm_det`` -- the fused inference hot-spot: binarize a weight tile in
               VMEM (Eq. 1) and immediately feed the MXU-shaped block
               matmul.  Fusing means HBM traffic is the *real* weight
               stream once, never the expanded w_b (DESIGN.md par.8).

Block sizes default to MXU-friendly 128x128x128 and are padded as needed;
zero-padding is safe for products because padded lanes of the *left*
operand are zero (padded weight lanes binarize to +1 but multiply zeros or
are sliced off).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# (bm, bk, bn) — MXU systolic array is 128x128; K tile chosen to keep the
# three resident blocks ~192 KiB, deep inside VMEM even with double
# buffering.
_DEFAULT_BLOCKS = (128, 128, 128)
_blocks = _DEFAULT_BLOCKS


def set_default_blocks(bm, bk, bn):
    """Tune the global block shape (perf pass knob; see EXPERIMENTS.md)."""
    global _blocks
    _blocks = (int(bm), int(bk), int(bn))


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...])


def _bgemm_det_kernel(x_ref, w_ref, o_ref, *, nk):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[...]
    wb = jnp.where(w >= 0.0, 1.0, -1.0).astype(w.dtype)
    o_ref[...] += jnp.dot(x_ref[...], wb)


def _pad2(a, r, c):
    pr = (-a.shape[0]) % r
    pc = (-a.shape[1]) % c
    if pr or pc:
        a = jnp.pad(a, ((0, pr), (0, pc)))
    return a


def _blocked_call(kernel, x, w):
    """Shared driver: pad to block multiples, run (m, n, k) grid, slice."""
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"expected 2-D operands, got {x.shape} @ {w.shape}")
    if x.shape[1] != w.shape[0]:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    m, k = x.shape
    _, n = w.shape
    bm, bk, bn = _blocks
    bm = min(bm, max(8, m))  # do not tile far beyond the actual extent
    bk = min(bk, max(8, k))
    bn = min(bn, max(8, n))
    xp = _pad2(x, bm, bk)
    wp = _pad2(w, bk, bn)
    mp, kp = xp.shape
    _, np_ = wp.shape
    nk = kp // bk
    grid = (mp // bm, np_ // bn, nk)
    out = pl.pallas_call(
        functools.partial(kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


@jax.custom_vjp
def pmatmul(x, w):
    """A @ B through the blocked Pallas kernel, differentiable."""
    return _blocked_call(_matmul_kernel, x, w)


def _pmatmul_fwd(x, w):
    return pmatmul(x, w), (x, w)


def _pmatmul_bwd(res, g):
    x, w = res
    # dX = G @ W^T and dW = X^T @ G, both through the same Pallas kernel so
    # the backward propagation also runs on binarized weights when the
    # caller passed w = w_b (Algorithm 1, step 2).
    dx = pmatmul(g, w.T)
    dw = pmatmul(x.T, g)
    return dx, dw


pmatmul.defvjp(_pmatmul_fwd, _pmatmul_bwd)


def bgemm_det(x, w):
    """Fused x @ sign(w): the deterministic-BinaryConnect inference GEMM.

    Not differentiable by design -- the training path composes
    ``binarize`` (STE) with ``pmatmul`` instead so the mode stays
    switchable inside one HLO.
    """
    return _blocked_call(_bgemm_det_kernel, x, w)
