"""Squared hinge (L2-SVM) loss kernel.

The paper minimizes the square hinge loss of an L2-SVM output layer on all
three benchmarks (Sec. 3.1).  Targets are +/-1 one-vs-rest rows; the
per-example loss is

    L_i = sum_j max(0, 1 - y_ij * z_ij)^2

Returned per example (not reduced) so the Rust coordinator can mask padded
tail batches during evaluation and still report exact error counts.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BB = 128  # batch rows per block; class dim rides whole (<= a few dozen)


def _hinge_kernel(z_ref, y_ref, o_ref):
    margin = jnp.maximum(0.0, 1.0 - y_ref[...] * z_ref[...])
    o_ref[...] = jnp.sum(margin * margin, axis=1)


@jax.custom_vjp
def hinge_loss(z, y):
    """Per-example squared hinge loss, shape (B,). Differentiable in z."""
    b, c = z.shape
    bb = min(_BB, b)
    pad = (-b) % bb
    zp = jnp.pad(z, ((0, pad), (0, 0)))
    yp = jnp.pad(y, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _hinge_kernel,
        grid=((b + pad) // bb,),
        in_specs=[
            pl.BlockSpec((bb, c), lambda i: (i, 0)),
            pl.BlockSpec((bb, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b + pad,), z.dtype),
        interpret=True,
    )(zp, yp)
    return out[:b]


def _hinge_fwd(z, y):
    return hinge_loss(z, y), (z, y)


def _hinge_bwd(res, g):
    z, y = res
    margin = jnp.maximum(0.0, 1.0 - y * z)
    dz = -2.0 * margin * y * g[:, None]
    return dz, None


hinge_loss.defvjp(_hinge_fwd, _hinge_bwd)
