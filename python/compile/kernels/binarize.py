"""Binarization kernels (paper Eqs. 1-3) and the straight-through estimator.

The elementwise kernels are tiled over a 1-D grid of VMEM-sized blocks.
Arbitrary-rank inputs are flattened, padded to a block multiple, processed,
and reshaped back; padding is sliced off so sign(0)=+1 on pad lanes never
leaks into results.

``binarize`` is the user-facing op: a ``jax.custom_vjp`` whose forward is a
``lax.switch`` over {identity, deterministic, stochastic} and whose backward
passes the cotangent straight through to the real-valued weights
(Algorithm 1: the gradient w.r.t. w_b is applied to w).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Elementwise block: one VMEM tile's worth of f32 lanes.  8192 * 4 B = 32 KiB
# per operand block, far under the ~16 MiB VMEM budget, and big enough that
# the grid loop is not overhead-dominated.
BLOCK = 8192


def _hard_sigmoid(x):
    # Eq. 3: clip((x+1)/2, 0, 1).  Piece-wise linear "hard" sigmoid.
    return jnp.clip((x + 1.0) * 0.5, 0.0, 1.0)


def _hard_sigmoid_kernel(x_ref, o_ref):
    o_ref[...] = _hard_sigmoid(x_ref[...])


def _binarize_det_kernel(w_ref, h_ref, o_ref):
    w = w_ref[...]
    h = h_ref[0]
    # Eq. 1 at scale H: +H if w >= 0 else -H (ties to +H).
    o_ref[...] = jnp.where(w >= 0.0, h, -h).astype(w.dtype)


def _binarize_stoch_kernel(w_ref, u_ref, h_ref, o_ref):
    w = w_ref[...]
    u = u_ref[...]
    h = h_ref[0]
    # Eq. 2 at scale H: +H with probability hard_sigmoid(w / H), else -H.
    # The paper's text uses H = 1, but the authors' released code sets H to
    # the layer's Glorot coefficient ("H = Glorot"): real weights live in
    # [-H, H], so w/H spans the full probability range from initialization
    # on.  With H = 1 and Glorot-scale inits, p ~= 0.5 everywhere and the
    # propagated signal is pure noise (we verified the resulting
    # constant-output collapse empirically — see DESIGN.md par.6).
    o_ref[...] = jnp.where(u < _hard_sigmoid(w / h), h, -h).astype(w.dtype)


def _elementwise_call(kernel, out_dtype, args, scalars=None):
    """Run an elementwise Pallas kernel over same-shape args, any rank.

    ``scalars`` (optional small 1-D vector) rides along unblocked so every
    grid step sees the full row.
    """
    shape = args[0].shape
    n = 1
    for d in shape:
        n *= d
    flat = [a.reshape((n,)) for a in args]
    npad = (-n) % BLOCK
    if npad:
        flat = [jnp.pad(a, (0, npad)) for a in flat]
    total = n + npad
    grid = (total // BLOCK,)
    in_specs = [pl.BlockSpec((BLOCK,), lambda i: (i,)) for _ in flat]
    if scalars is not None:
        s = jnp.asarray(scalars, dtype=out_dtype)
        in_specs.append(pl.BlockSpec((s.shape[0],), lambda i: (0,)))
        flat = flat + [s]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((total,), out_dtype),
        interpret=True,
    )(*flat)
    return out[:n].reshape(shape)


def hard_sigmoid(x):
    """Eq. 3 as a Pallas kernel; matches ``ref.hard_sigmoid_ref``."""
    return _elementwise_call(_hard_sigmoid_kernel, x.dtype, [x])


def binarize_det(w, h=1.0):
    """Deterministic binarization to ±H, Eq. 1.  sign with sign(0) = +1."""
    return _elementwise_call(_binarize_det_kernel, w.dtype, [w], [h])


def binarize_stoch(w, u, h=1.0):
    """Stochastic binarization to ±H with p = hard_sigmoid(w/H), Eq. 2.

    ``u`` must be uniforms on [0, 1) of the same shape as ``w``; the caller
    owns RNG (the train step derives them from the per-step seed so that the
    whole step is a pure function of its inputs).
    """
    return _elementwise_call(_binarize_stoch_kernel, w.dtype, [w, u], [h])


@jax.custom_vjp
def binarize(w, key, mode, h):
    """Mode-switched binarization with the straight-through estimator.

    mode 0 -> identity (the "no regularizer" baseline uses real weights)
    mode 1 -> deterministic (Eq. 1), values ±H
    mode 2 -> stochastic (Eq. 2), values ±H

    ``mode`` is a traced int32 scalar so a single lowered HLO serves every
    row of Table 2; the switch costs one branch per weight tensor.  ``h``
    is the layer's binarization scale (the Glorot coefficient, per the
    authors' released code — see `_binarize_stoch_kernel`).

    The stochastic uniforms are drawn from ``key`` INSIDE the switch
    branch, so the deterministic and no-regularizer modes never pay the
    counter-RNG cost (perf pass, EXPERIMENTS.md par.Perf iteration 2).
    """
    return jax.lax.switch(
        mode,
        [
            lambda w, key, h: w,
            lambda w, key, h: binarize_det(w, h),
            lambda w, key, h: binarize_stoch(
                w, jax.random.uniform(key, w.shape, w.dtype), h
            ),
        ],
        w,
        key,
        h,
    )


def _binarize_fwd(w, key, mode, h):
    return binarize(w, key, mode, h), ()


def _binarize_bwd(_res, g):
    # Straight-through: dC/dw := dC/dw_b (Algorithm 1, step 3).  No gradient
    # flows to the noise, the mode selector or the scale.
    return (g, None, None, None)


binarize.defvjp(_binarize_fwd, _binarize_bwd)
