"""Layer-1 Pallas kernels for BinaryConnect.

Every kernel here is the paper's compute hot-spot expressed as a Pallas
kernel (interpret=True so CPU PJRT can execute the lowered HLO; see
DESIGN.md par.8 for the TPU mapping).  Pure-jnp oracles live in ``ref.py``
and pytest checks kernel == oracle over hypothesis-generated shapes.

Public surface:

* ``hard_sigmoid``            -- Eq. 3
* ``binarize_det``            -- Eq. 1 (sign, tie -> +1)
* ``binarize_stoch``          -- Eq. 2 (needs external uniforms)
* ``binarize``                -- mode-switched (none/det/stoch) with the
                                 straight-through estimator as custom_vjp
* ``pmatmul``                 -- blocked Pallas matmul with custom_vjp
* ``bgemm_det``               -- fused binarize+matmul (inference hot path)
* ``sgd_update`` / ``nesterov_update`` / ``adam_update``
                              -- fused clip(w - eta*g, -1, 1) update kernels
* ``hinge_loss``              -- squared hinge (L2-SVM) per-example loss
"""

from .binarize import (
    hard_sigmoid,
    binarize_det,
    binarize_stoch,
    binarize,
)
from .matmul import pmatmul, bgemm_det, set_default_blocks
from .update import sgd_update, nesterov_update, adam_update
from .hinge import hinge_loss

__all__ = [
    "hard_sigmoid",
    "binarize_det",
    "binarize_stoch",
    "binarize",
    "pmatmul",
    "bgemm_det",
    "set_default_blocks",
    "sgd_update",
    "nesterov_update",
    "adam_update",
    "hinge_loss",
]
