"""Layer-2 building blocks: binary dense/conv, batch norm, pooling, dropout.

Everything here is a pure function of explicit parameters — no module state
— so the whole train step lowers to a single HLO artifact.  Weight
binarization goes through the Layer-1 ``binarize`` op (straight-through
estimator); the dense path can route its GEMM through the Pallas
``pmatmul`` kernel or native ``jnp.dot`` (build-time choice, benchmarked as
an ablation).
"""

import math

import jax
import jax.numpy as jnp

from .kernels import binarize, pmatmul

BN_EPS = 1e-4


def glorot_coeff(fan_in, fan_out):
    """Glorot/Xavier uniform limit sqrt(6/(fan_in+fan_out)).

    The paper's Sec. 2.5 trick scales each weight tensor's learning rate by
    this coefficient (ADAM) or its square (SGD / Nesterov momentum).
    """
    return math.sqrt(6.0 / (fan_in + fan_out))


def glorot_init(key, shape, fan_in, fan_out, dtype=jnp.float32):
    c = glorot_coeff(fan_in, fan_out)
    return jax.random.uniform(key, shape, dtype, minval=-c, maxval=c)


def dense_binary(x, w, key, mode, h=1.0, use_pallas=True):
    """x @ binarize(w): the paper's multiplication-free dense propagation.

    ``h`` is the layer's binarization scale (Glorot coefficient — see
    kernels/binarize.py).
    """
    wb = binarize(w, key, mode, h)
    if use_pallas:
        return pmatmul(x, wb)
    return jnp.dot(x, wb)


def conv_binary(x, w, key, mode, h=1.0):
    """NHWC 'SAME' 3x3 convolution on binarized weights (HWIO layout).

    The convolution itself uses lax.conv_general_dilated — under CPU PJRT
    that is the only tractable conv — while the binarization (the paper's
    contribution) still runs the Layer-1 Pallas kernel and its STE.
    """
    wb = binarize(w, key, mode, h)
    return jax.lax.conv_general_dilated(
        x,
        wb,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def batchnorm_train(x, gamma, beta, rmean, rvar, momentum):
    """Batch norm (train): normalize by batch stats, update running stats.

    Dense inputs (B, F) reduce over axis 0; conv inputs (B, H, W, C) reduce
    over (0, 1, 2).  Returns (y, new_rmean, new_rvar).
    """
    axes = tuple(range(x.ndim - 1))
    mean = jnp.mean(x, axis=axes)
    var = jnp.var(x, axis=axes)
    y = (x - mean) * jax.lax.rsqrt(var + BN_EPS) * gamma + beta
    new_rmean = momentum * rmean + (1.0 - momentum) * mean
    new_rvar = momentum * rvar + (1.0 - momentum) * var
    return y, new_rmean, new_rvar


def batchnorm_eval(x, gamma, beta, rmean, rvar):
    return (x - rmean) * jax.lax.rsqrt(rvar + BN_EPS) * gamma + beta


def relu(x):
    return jnp.maximum(x, 0.0)


def maxpool2(x):
    """2x2 max-pool, stride 2, NHWC."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )


def dropout(x, key, p):
    """Inverted dropout with traced rate ``p`` (p = 0 keeps everything).

    Guarded by lax.cond so the p = 0 regimes (everything except the
    Dropout baseline row) skip the mask RNG entirely at runtime — the same
    HLO still serves every row of Table 2.
    """

    def apply(x):
        u = jax.random.uniform(key, x.shape, x.dtype)
        keep = (u >= p).astype(x.dtype)
        return x * keep / jnp.maximum(1.0 - p, 1e-6)

    return jax.lax.cond(p > 0.0, apply, lambda x: x, x)
