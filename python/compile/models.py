"""Model definitions: the paper's MLP (Sec. 3.1) and VGG-ish CNN (Sec. 3.2).

A model is a ``ParamDef`` spec (ordered, named, kinded) plus pure
``init`` / ``apply`` functions operating on a flat list of arrays in spec
order.  The flat list IS the wire format: the Rust coordinator holds the
same ordered list of buffers and never needs to understand the pytree.

Param kinds drive the optimizer (see train.py):

* ``weight``  — binarized during propagation, clipped to [-1, 1] after the
                update, learning rate scaled by the Glorot coefficient.
* ``affine``  — BN gamma/beta and the output bias: trained, never
                binarized, never clipped, unscaled LR.
* ``bn_stat`` — BN running mean/var: not trained; overwritten by the BN
                update inside the train step.
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from . import hyper as H
from . import layers as L


@dataclass(frozen=True)
class ParamDef:
    name: str
    shape: tuple
    kind: str            # "weight" | "affine" | "bn_stat"
    glorot: float = 0.0  # LR-scaling coefficient for kind == "weight"
    init: str = "zeros"  # "glorot" | "zeros" | "ones"


def _bn_defs(name, c):
    return [
        ParamDef(f"{name}.gamma", (c,), "affine", init="ones"),
        ParamDef(f"{name}.beta", (c,), "affine", init="zeros"),
        ParamDef(f"{name}.rmean", (c,), "bn_stat", init="zeros"),
        ParamDef(f"{name}.rvar", (c,), "bn_stat", init="ones"),
    ]


@dataclass(frozen=True)
class MLPConfig:
    """Permutation-invariant MNIST MLP: depth x hidden ReLU units, BN after
    every hidden layer, L2-SVM output (square hinge loss)."""

    name: str = "mlp"
    in_dim: int = 784
    hidden: int = 1024
    depth: int = 3
    classes: int = 10
    batch: int = 200
    use_pallas: bool = True

    @property
    def input_shape(self):
        return (self.batch, self.in_dim)

    def spec(self):
        defs = []
        d = self.in_dim
        for i in range(self.depth):
            c = L.glorot_coeff(d, self.hidden)
            defs.append(ParamDef(f"l{i}.W", (d, self.hidden), "weight", c, "glorot"))
            defs += _bn_defs(f"l{i}.bn", self.hidden)
            d = self.hidden
        c = L.glorot_coeff(d, self.classes)
        defs.append(ParamDef("out.W", (d, self.classes), "weight", c, "glorot"))
        defs.append(ParamDef("out.b", (self.classes,), "affine", init="zeros"))
        return defs

    def apply(self, params, x, key, hv, train):
        """Returns (logits, {param_index: new_bn_stat}) in train mode."""
        mode = hv[H.MODE].astype(jnp.int32)
        bn_mom = hv[H.BN_MOMENTUM]
        spec = self.spec()
        updates = {}
        i = 0
        k = 0

        if train:
            x = L.dropout(x, jax.random.fold_in(key, 1000 + k), hv[H.IN_DROPOUT])
        for layer in range(self.depth):
            w = params[i]
            z = L.dense_binary(
                x, w, jax.random.fold_in(key, k), mode, spec[i].glorot, self.use_pallas
            )
            gamma, beta, rmean, rvar = params[i + 1 : i + 5]
            if train:
                z, nm, nv = L.batchnorm_train(z, gamma, beta, rmean, rvar, bn_mom)
                updates[i + 3] = nm
                updates[i + 4] = nv
            else:
                z = L.batchnorm_eval(z, gamma, beta, rmean, rvar)
            x = L.relu(z)
            if train:
                x = L.dropout(x, jax.random.fold_in(key, 2000 + k), hv[H.DROPOUT])
            i += 5
            k += 1
        w, b = params[i], params[i + 1]
        logits = (
            L.dense_binary(x, w, jax.random.fold_in(key, k), mode, spec[i].glorot, self.use_pallas)
            + b
        )
        return logits, updates


@dataclass(frozen=True)
class CNNConfig:
    """Paper Eq. 5 architecture, width-scalable:

    (2 x base C3) - MP2 - (2 x 2base C3) - MP2 - (2 x 4base C3) - MP2
      - (2 x fc FC) - classes SVM

    base=128, fc=1024 is the paper's CIFAR-10 net; SVHN uses half.  The
    default build scales base down so CPU-PJRT runs stay tractable —
    EXPERIMENTS.md records which scale each table row used.
    """

    name: str = "cnn"
    base: int = 128
    fc: int = 1024
    in_hw: int = 32
    in_c: int = 3
    classes: int = 10
    batch: int = 50
    use_pallas: bool = True

    @property
    def input_shape(self):
        return (self.batch, self.in_hw, self.in_hw, self.in_c)

    def _conv_plan(self):
        b = self.base
        chans = [b, b, 2 * b, 2 * b, 4 * b, 4 * b]
        pool_after = {1, 3, 5}  # MP2 after the 2nd, 4th, 6th conv
        return chans, pool_after

    def spec(self):
        defs = []
        chans, _ = self._conv_plan()
        cin = self.in_c
        for i, cout in enumerate(chans):
            fan_in = 9 * cin
            fan_out = 9 * cout
            c = L.glorot_coeff(fan_in, fan_out)
            defs.append(ParamDef(f"conv{i}.W", (3, 3, cin, cout), "weight", c, "glorot"))
            defs += _bn_defs(f"conv{i}.bn", cout)
            cin = cout
        hw = self.in_hw // 8
        flat = hw * hw * chans[-1]
        d = flat
        for i in range(2):
            c = L.glorot_coeff(d, self.fc)
            defs.append(ParamDef(f"fc{i}.W", (d, self.fc), "weight", c, "glorot"))
            defs += _bn_defs(f"fc{i}.bn", self.fc)
            d = self.fc
        c = L.glorot_coeff(d, self.classes)
        defs.append(ParamDef("out.W", (d, self.classes), "weight", c, "glorot"))
        defs.append(ParamDef("out.b", (self.classes,), "affine", init="zeros"))
        return defs

    def apply(self, params, x, key, hv, train):
        mode = hv[H.MODE].astype(jnp.int32)
        bn_mom = hv[H.BN_MOMENTUM]
        spec = self.spec()
        chans, pool_after = self._conv_plan()
        updates = {}
        i = 0
        k = 0
        for layer in range(len(chans)):
            w = params[i]
            z = L.conv_binary(x, w, jax.random.fold_in(key, k), mode, spec[i].glorot)
            gamma, beta, rmean, rvar = params[i + 1 : i + 5]
            if train:
                z, nm, nv = L.batchnorm_train(z, gamma, beta, rmean, rvar, bn_mom)
                updates[i + 3] = nm
                updates[i + 4] = nv
            else:
                z = L.batchnorm_eval(z, gamma, beta, rmean, rvar)
            x = L.relu(z)
            if layer in pool_after:
                x = L.maxpool2(x)
            i += 5
            k += 1
        x = x.reshape((x.shape[0], -1))
        if train:
            x = L.dropout(x, jax.random.fold_in(key, 3000), hv[H.DROPOUT])
        for layer in range(2):
            w = params[i]
            z = L.dense_binary(
                x, w, jax.random.fold_in(key, k), mode, spec[i].glorot, self.use_pallas
            )
            gamma, beta, rmean, rvar = params[i + 1 : i + 5]
            if train:
                z, nm, nv = L.batchnorm_train(z, gamma, beta, rmean, rvar, bn_mom)
                updates[i + 3] = nm
                updates[i + 4] = nv
            else:
                z = L.batchnorm_eval(z, gamma, beta, rmean, rvar)
            x = L.relu(z)
            if train:
                x = L.dropout(x, jax.random.fold_in(key, 4000 + k), hv[H.DROPOUT])
            i += 5
            k += 1
        w, b = params[i], params[i + 1]
        logits = (
            L.dense_binary(x, w, jax.random.fold_in(key, k), mode, spec[i].glorot, self.use_pallas)
            + b
        )
        return logits, updates


def init_params(config, key):
    """Initialize the flat param list per spec (Glorot uniform weights)."""
    out = []
    for i, d in enumerate(config.spec()):
        if d.init == "glorot":
            fan_in = 1
            for s in d.shape[:-1]:
                fan_in *= s
            fan_out = d.shape[-1]
            if len(d.shape) == 4:  # conv HWIO: receptive field counts in both
                fan_out *= d.shape[0] * d.shape[1]
            out.append(L.glorot_init(jax.random.fold_in(key, i), d.shape, fan_in, fan_out))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, jnp.float32))
        else:
            out.append(jnp.zeros(d.shape, jnp.float32))
    return out


def n_scalars(config):
    total = 0
    for d in config.spec():
        n = 1
        for s in d.shape:
            n *= s
        total += n
    return total
