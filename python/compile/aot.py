"""AOT entry point: lower init/train/eval per model config to HLO text.

Run once via ``make artifacts``; Python never executes at runtime.  HLO
*text* (not ``.serialize()``) is the interchange format: the image's
xla_extension 0.5.1 rejects jax>=0.5 serialized protos (64-bit instruction
ids); the text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md.

Emits, per model config:

    artifacts/<name>_init.hlo.txt
    artifacts/<name>_train.hlo.txt
    artifacts/<name>_eval.hlo.txt

plus one ``artifacts/manifest.json`` describing shapes, param specs and
hyper-vector layout for the Rust loader (rust/src/runtime/manifest.rs).

Usage:
    python -m compile.aot --out-dir ../artifacts [--scale N] [--models mlp,cnn,...]

``--scale`` multiplies model widths toward paper scale (scale=8 is the
paper's exact MLP/CNN; the default 1 keeps CPU-PJRT training tractable).
"""

import argparse
import json
import os

import jax

from jax._src.lib import xla_client as xc

from . import hyper as H
from .models import MLPConfig, CNNConfig, n_scalars
from .train import make_train_step, make_eval_step, make_init


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_configs(scale: int):
    """The default artifact set.  scale=1 is CPU-tractable; scale=8 is the
    paper's full-width MLP (3x1024) and CIFAR-10 CNN (128C3 base)."""
    return {
        # permutation-invariant MNIST MLP (Sec. 3.1); paper: hidden=1024, batch=200
        "mlp": MLPConfig(name="mlp", hidden=128 * scale, batch=100, use_pallas=True),
        # same MLP with the GEMM on native dot instead of the Pallas kernel
        # (build-time ablation benchmarked in EXPERIMENTS.md par.Perf)
        "mlp_ng": MLPConfig(name="mlp_ng", hidden=128 * scale, batch=100, use_pallas=False),
        # CIFAR-10 CNN (Sec. 3.2, Eq. 5); paper: base=128, fc=1024, batch=50
        "cnn": CNNConfig(name="cnn", base=16 * scale, fc=128 * scale, batch=50),
        # SVHN CNN — half the units of the CIFAR-10 net (Sec. 3.3); doubles
        # as Table 1's "small CNN"
        "cnn_small": CNNConfig(name="cnn_small", base=8 * scale, fc=64 * scale, batch=50),
    }


def lower_model(config, out_dir):
    spec = config.spec()
    n = len(spec)
    f32 = jax.numpy.float32
    sds = jax.ShapeDtypeStruct
    pshapes = [sds(d.shape, f32) for d in spec]
    x = sds(config.input_shape, f32)
    y = sds((config.batch, config.classes), f32)
    hv = sds((H.LEN,), f32)

    files = {}

    init = make_init(config)
    lowered = jax.jit(init).lower(hv)
    files["init"] = f"{config.name}_init.hlo.txt"
    with open(os.path.join(out_dir, files["init"]), "w") as f:
        f.write(to_hlo_text(lowered))

    train = make_train_step(config)
    lowered = jax.jit(train).lower(*(pshapes * 3), x, y, hv)
    files["train"] = f"{config.name}_train.hlo.txt"
    with open(os.path.join(out_dir, files["train"]), "w") as f:
        f.write(to_hlo_text(lowered))

    evals = make_eval_step(config)
    lowered = jax.jit(evals).lower(*pshapes, x, y, hv)
    files["eval"] = f"{config.name}_eval.hlo.txt"
    with open(os.path.join(out_dir, files["eval"]), "w") as f:
        f.write(to_hlo_text(lowered))

    return {
        "batch": config.batch,
        "classes": config.classes,
        "input_shape": list(config.input_shape),
        "n_param_tensors": n,
        "n_scalars": n_scalars(config),
        "use_pallas": bool(getattr(config, "use_pallas", True)),
        "params": [
            {
                "name": d.name,
                "shape": list(d.shape),
                "kind": d.kind,
                "glorot": d.glorot,
            }
            for d in spec
        ],
        "artifacts": files,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--scale", type=int, default=1)
    ap.add_argument("--models", default="mlp,mlp_ng,cnn,cnn_small")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    configs = build_configs(args.scale)
    wanted = [m.strip() for m in args.models.split(",") if m.strip()]

    manifest = {
        "format": 1,
        "scale": args.scale,
        "hyper": {"len": H.LEN, **H.NAMES},
        "models": {},
    }
    for name in wanted:
        cfg = configs[name]
        print(f"lowering {name} ...", flush=True)
        manifest["models"][name] = lower_model(cfg, args.out_dir)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out_dir}/manifest.json ({len(wanted)} models)")


if __name__ == "__main__":
    main()
