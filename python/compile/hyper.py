"""The hyper vector: one f32[16] row carrying every per-step knob.

Packing all scalar configuration into a single tensor keeps the Rust-side
calling convention trivial (params..., m..., v..., x, y, hyper) and lets
ONE lowered HLO artifact serve every cell of Table 1 and Table 2: the
binarization mode, the optimizer and the LR-scaling trick are all runtime
switches (lax.switch) rather than build-time variants.

Integers ride as exact small floats (f32 is exact through 2^24, far above
any step count or seed we use).  The same layout is mirrored in
rust/src/runtime/hyper.rs — keep the two in sync.
"""

LR = 0            # base learning rate (already decayed by the coordinator)
MODE = 1          # weight binarization: 0 none, 1 deterministic, 2 stochastic
OPT = 2           # optimizer: 0 SGD, 1 Nesterov momentum, 2 ADAM
MOMENTUM = 3      # Nesterov mu / ADAM beta1
BETA2 = 4         # ADAM beta2
EPS = 5           # ADAM epsilon
DROPOUT = 6       # hidden-layer dropout rate (baseline regularizer row)
BN_MOMENTUM = 7   # running-stat momentum for batch norm
LR_SCALE = 8      # Sec. 2.5 trick: 0 off, 1 scale LR by Glorot coefficients
STEP = 9          # 1-based global step (ADAM bias correction)
SEED = 10         # per-step RNG seed (stochastic binarization, dropout)
IN_DROPOUT = 11   # input-layer dropout rate
LEN = 16

NAMES = {
    "lr": LR,
    "mode": MODE,
    "opt": OPT,
    "momentum": MOMENTUM,
    "beta2": BETA2,
    "eps": EPS,
    "dropout": DROPOUT,
    "bn_momentum": BN_MOMENTUM,
    "lr_scale": LR_SCALE,
    "step": STEP,
    "seed": SEED,
    "in_dropout": IN_DROPOUT,
}
