//! Integration: the binary convolution subsystem end to end.
//!
//! Four contracts, over real sockets and real files:
//!
//!   * solo == coalesced bit-exactness holds for a *served conv model*
//!     through the HTTP layer — the conv front rides the same
//!     lane-batched packed sign-GEMM as the dense stack, and im2col
//!     keeps every image's patch rows in its own row block, so batch
//!     composition cannot change any row's result;
//!   * `/healthz` advertises the conv input shape `(h, w, c)` so
//!     clients (loadgen) can shape image payloads;
//!   * train -> pack -> save (BCPACK03) -> load -> serve round-trips
//!     bit-exactly: served logits equal the in-process packed forward;
//!   * checkpoint/resume stays bit-exact for conv models — the same
//!     train(N) == train(k) + resume + train(N-k) contract the MLP
//!     suite pins, now through conv layers' STE/BN/pool state, down to
//!     byte-identical exported artifacts.

use std::sync::{Arc, Barrier};
use std::time::Duration;

use binaryconnect::binary::{
    load_packed, pack_mlp, save_packed, BitMatrix, PackedConvLayer, PackedLayer, PackedMlp,
};
use binaryconnect::coordinator::{train, LrSchedule, ResumeFrom, TrainOpts};
use binaryconnect::data::{Dataset, SplitData};
use binaryconnect::runtime::reference::conv_net_info;
use binaryconnect::runtime::{Mode, Opt, ReferenceExecutor, TrainState};
use binaryconnect::serve::loadgen::{predict_body, HttpClient};
use binaryconnect::serve::{self, ServeConfig};
use binaryconnect::util::{Json, Rng};

/// Hand-built conv model: 3x3 conv (2 -> 3 channels, pooled) on 4x4
/// input, then dense 12 -> 4. in_dim = 32.
fn toy_conv_mlp(seed: u64) -> PackedMlp {
    let mut rng = Rng::new(seed);
    let (h, w, cin, cout) = (4usize, 4usize, 2usize, 3usize);
    let pk = 9 * cin;
    let wts: Vec<f32> = (0..pk * cout).map(|_| rng.normal()).collect();
    let conv = PackedConvLayer {
        bits: BitMatrix::pack(&wts, pk, cout),
        scale: (0..cout).map(|_| 0.5 + rng.uniform_f64() as f32).collect(),
        shift: (0..cout).map(|_| 0.1 * rng.normal()).collect(),
        kh: 3,
        kw: 3,
        cin,
        cout,
        h_in: h,
        w_in: w,
        pool: true,
    };
    let dw: Vec<f32> = (0..12 * 4).map(|_| rng.normal()).collect();
    let dense = PackedLayer {
        bits: BitMatrix::pack(&dw, 12, 4),
        scale: vec![1.0; 4],
        shift: vec![0.01, -0.02, 0.0, 0.02],
        relu: false,
    };
    PackedMlp { conv: vec![conv], layers: vec![dense], in_dim: h * w * cin, classes: 4 }
}

/// The trainable spec every trained-path test shares: 6x6x2 input, two
/// conv stages (3 then 4 channels, pool after the second -> 3x3x4 flat),
/// one 16-wide fc, 4 classes, batch 8.
fn tiny_cnn_info() -> binaryconnect::runtime::ModelInfo {
    conv_net_info("tiny_cnn", 6, 2, &[3, 4], &[16], 4, 8)
}

/// Class-structured synthetic 6x6x2 images matching [`tiny_cnn_info`].
fn data(seed: u64) -> SplitData {
    let mut rng = Rng::new(seed);
    let mut mk = |n: usize| {
        let mut ds = Dataset::new("tiny-conv", (6, 6, 2), 4);
        let mut row = vec![0f32; 72];
        for i in 0..n {
            let label = (i % 4) as u8;
            for (j, v) in row.iter_mut().enumerate() {
                let noise = (rng.next_u64() % 2048) as f32 / 1024.0 - 1.0;
                *v = noise + if j % 4 == label as usize { 1.0 } else { 0.0 };
            }
            ds.push(&row, label);
        }
        ds
    };
    SplitData::from_train_test(mk(96), mk(32), 24)
}

fn opts(epochs: usize) -> TrainOpts {
    TrainOpts {
        epochs,
        schedule: LrSchedule::Exponential { start: 0.01, end: 0.002, epochs },
        mode: Mode::Det,
        opt: Opt::Adam,
        seed: 7,
        verbose: false,
        ..Default::default()
    }
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("bc_conv_subsys_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn rows(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    // pixel-like [0,1) features: what a real normalized image feeds in
    (0..n).map(|_| (0..dim).map(|_| rng.uniform_f64() as f32).collect()).collect()
}

fn predict(client: &mut HttpClient, row: &[f32]) -> (u16, String) {
    let mut body = String::new();
    predict_body(&mut body, row);
    client.request("POST", "/predict", Some(&body)).unwrap()
}

/// Parse a 200 /predict body into (pred, logit bit patterns).
fn decode(body: &str) -> (usize, Vec<u64>) {
    let j = Json::parse(body).unwrap();
    let pred = j.get("pred").unwrap().as_usize().unwrap();
    let logits: Vec<u64> = j
        .get("logits")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| (v.as_f64().unwrap() as f32).to_bits() as u64)
        .collect();
    (pred, logits)
}

fn state_bits(s: &TrainState) -> Vec<Vec<Vec<u32>>> {
    [&s.params, &s.m, &s.v]
        .iter()
        .map(|g| g.iter().map(|t| t.iter().map(|x| x.to_bits()).collect()).collect())
        .collect()
}

#[test]
fn conv_solo_and_coalesced_predictions_are_bit_identical_over_http() {
    let n = 16;
    let xs = rows(n, 32, 500);

    // pass 1: a server that cannot coalesce (max_batch 1), sequential
    let mut server = serve::start(
        toy_conv_mlp(42),
        ServeConfig { max_batch: 1, max_wait: Duration::ZERO, ..Default::default() },
    )
    .unwrap();
    let host = server.addr().to_string();
    let mut client = HttpClient::connect(&host).unwrap();
    let solo: Vec<(usize, Vec<u64>)> = xs
        .iter()
        .map(|x| {
            let (status, body) = predict(&mut client, x);
            assert_eq!(status, 200, "{body}");
            decode(&body)
        })
        .collect();
    drop(client);
    server.stop();

    // pass 2: a coalescing server hit by n concurrent clients
    let mut server = serve::start(
        toy_conv_mlp(42),
        ServeConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(20),
            workers: n,
            conn_backlog: 2 * n,
            ..Default::default()
        },
    )
    .unwrap();
    let host = server.addr().to_string();
    let barrier = Arc::new(Barrier::new(n));
    let joins: Vec<_> = xs
        .iter()
        .map(|x| {
            let host = host.clone();
            let x = x.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut client = HttpClient::connect(&host).unwrap();
                barrier.wait();
                let (status, body) = predict(&mut client, &x);
                assert_eq!(status, 200, "{body}");
                decode(&body)
            })
        })
        .collect();
    let coalesced: Vec<(usize, Vec<u64>)> =
        joins.into_iter().map(|j| j.join().unwrap()).collect();
    let snap = server.metrics().snapshot(0);
    server.stop();

    for (i, (s, c)) in solo.iter().zip(&coalesced).enumerate() {
        assert_eq!(s, c, "row {i}: conv solo and coalesced responses differ at the bit level");
    }
    assert_eq!(snap.get("rows").unwrap().as_usize(), Some(n));
    assert_eq!(snap.get("predictions").unwrap().as_usize(), Some(n));
}

#[test]
fn trained_conv_model_round_trips_to_a_server_that_reports_its_shape() {
    // train the tiny conv net briefly, fold its BN/H into a packed model
    let info = tiny_cnn_info();
    let ex = ReferenceExecutor::new(info.clone()).unwrap();
    let run = train(&ex, &data(11), &opts(2)).unwrap();
    let mlp = pack_mlp(&info, &run.state).unwrap();

    // through the BCPACK03 file: save, load, serve the loaded copy
    let dir = tmpdir("export");
    let path = dir.join("tiny_cnn.bcpack");
    save_packed(&mlp, &path).unwrap();
    let loaded = load_packed(&path).unwrap();
    let mut server = serve::start(loaded, ServeConfig::default()).unwrap();
    let host = server.addr().to_string();
    let mut client = HttpClient::connect(&host).unwrap();

    // healthz advertises the image input shape for payload generators
    let (status, body) = client.request("GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("in_dim").unwrap().as_usize(), Some(72));
    assert_eq!(j.get("conv_layers").unwrap().as_usize(), Some(2));
    let shape: Vec<usize> = j
        .get("input_shape")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_usize().unwrap())
        .collect();
    assert_eq!(shape, vec![6, 6, 2]);

    // served logits equal the in-process packed forward, bit for bit
    // (f32 -> shortest-repr decimal -> f64 -> f32 is lossless)
    for x in rows(4, 72, 600) {
        let local = mlp.forward(&x, 1);
        let (status, body) = predict(&mut client, &x);
        assert_eq!(status, 200, "{body}");
        let (pred, logits) = decode(&body);
        assert!(pred < 4);
        let want: Vec<u64> = local.iter().map(|v| v.to_bits() as u64).collect();
        assert_eq!(logits, want, "served logits diverge from the packed forward");
    }
    server.stop();
}

#[test]
fn conv_checkpoint_resume_is_bit_exact_down_to_the_exported_artifact() {
    let info = tiny_cnn_info();
    let d = data(3);
    let epochs = 4;

    let ex = ReferenceExecutor::new(info.clone()).unwrap();
    let full = train(&ex, &d, &opts(epochs)).unwrap();

    // same run, checkpointing every epoch and keeping every file
    let dir = tmpdir("resume");
    let mut o = opts(epochs);
    o.checkpoint.dir = Some(dir.clone());
    o.checkpoint.keep = 0;
    let ex2 = ReferenceExecutor::new(info.clone()).unwrap();
    let ckpt_run = train(&ex2, &d, &o).unwrap();
    assert_eq!(
        state_bits(&full.state),
        state_bits(&ckpt_run.state),
        "checkpointing changed the conv run"
    );

    // resume the k=2 checkpoint in a fresh executor and finish
    let mut o2 = opts(epochs);
    o2.checkpoint.resume = Some(ResumeFrom::Path(dir.join("ckpt-000002.bcckpt")));
    let ex3 = ReferenceExecutor::new(info.clone()).unwrap();
    let resumed = train(&ex3, &d, &o2).unwrap();
    assert!(!resumed.interrupted);
    assert_eq!(state_bits(&full.state), state_bits(&resumed.state), "conv resume diverged");
    assert_eq!(full.steps, resumed.steps);
    assert_eq!(full.test_err.to_bits(), resumed.test_err.to_bits());

    // the strongest form: both runs export byte-identical artifacts
    let p_full = dir.join("full.bcpack");
    let p_resumed = dir.join("resumed.bcpack");
    save_packed(&pack_mlp(&info, &full.state).unwrap(), &p_full).unwrap();
    save_packed(&pack_mlp(&info, &resumed.state).unwrap(), &p_resumed).unwrap();
    assert_eq!(
        std::fs::read(&p_full).unwrap(),
        std::fs::read(&p_resumed).unwrap(),
        "exported conv artifacts differ after resume"
    );
}
