//! The checkpoint/resume contract, end to end over the real trainer:
//!
//!   train(N)  ==  train(k) + resume + train(N-k)      (bit-exactly)
//!
//! for every optimizer (SGD / Nesterov / ADAM), both binarization modes
//! (det / stoch), and both executor kernel paths (fast / baseline) —
//! params, optimizer slots, curves, best-model trackers and step counters
//! all included. Plus the resume guard rails: configuration mismatches
//! refuse to resume, retention prunes, and resuming a finished run is a
//! no-op.

use std::path::PathBuf;

use binaryconnect::coordinator::{train, LrSchedule, ResumeFrom, RunResult, TrainOpts};
use binaryconnect::data::{Dataset, SplitData};
use binaryconnect::runtime::{reference::mlp_info, Mode, Opt, ReferenceExecutor, TrainState};
use binaryconnect::util::Rng;

const DIM: usize = 12;
const CLASSES: usize = 4;

fn exec() -> ReferenceExecutor {
    ReferenceExecutor::new(mlp_info("micro", DIM, 10, 2, CLASSES, 8)).unwrap()
}

/// Tiny separable synthetic dataset matching the micro MLP's shape.
fn data(seed: u64) -> SplitData {
    let mut rng = Rng::new(seed);
    let mut mk = |n: usize| {
        let mut ds = Dataset::new("micro", (DIM, 1, 1), CLASSES);
        let mut row = vec![0f32; DIM];
        for i in 0..n {
            let label = (i % CLASSES) as u8;
            for (j, v) in row.iter_mut().enumerate() {
                let noise = (rng.next_u64() % 2048) as f32 / 1024.0 - 1.0;
                *v = noise + if j % CLASSES == label as usize { 1.5 } else { 0.0 };
            }
            ds.push(&row, label);
        }
        ds
    };
    SplitData::from_train_test(mk(160), mk(40), 32)
}

fn opts(mode: Mode, opt: Opt, epochs: usize) -> TrainOpts {
    TrainOpts {
        epochs,
        schedule: LrSchedule::Exponential { start: 0.01, end: 0.002, epochs },
        mode,
        opt,
        seed: 7,
        verbose: false,
        ..Default::default()
    }
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("bc_ckpt_train_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn state_bits(s: &TrainState) -> Vec<Vec<Vec<u32>>> {
    [&s.params, &s.m, &s.v]
        .iter()
        .map(|g| g.iter().map(|t| t.iter().map(|x| x.to_bits()).collect()).collect())
        .collect()
}

/// Everything except wall-clock seconds must match bit-for-bit.
fn assert_runs_identical(full: &RunResult, resumed: &RunResult, what: &str) {
    assert_eq!(state_bits(&full.state), state_bits(&resumed.state), "{what}: state");
    assert_eq!(full.steps, resumed.steps, "{what}: steps");
    assert_eq!(full.best_epoch, resumed.best_epoch, "{what}: best epoch");
    assert_eq!(
        full.best_val_err.to_bits(),
        resumed.best_val_err.to_bits(),
        "{what}: best val err"
    );
    assert_eq!(full.test_err.to_bits(), resumed.test_err.to_bits(), "{what}: test err");
    assert_eq!(full.curves.len(), resumed.curves.len(), "{what}: curve length");
    for (a, b) in full.curves.iter().zip(&resumed.curves) {
        assert_eq!(a.epoch, b.epoch, "{what}: curve epoch");
        assert_eq!(a.lr.to_bits(), b.lr.to_bits(), "{what}: curve lr");
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{what}: train loss");
        assert_eq!(a.train_err.to_bits(), b.train_err.to_bits(), "{what}: train err");
        assert_eq!(a.val_err.to_bits(), b.val_err.to_bits(), "{what}: val err");
        // a.seconds / b.seconds are wall clock; deliberately not compared
    }
}

/// The contract itself: 4 uninterrupted epochs vs. 2 epochs + resume from
/// the on-disk checkpoint (in a fresh executor) + 2 more.
fn assert_resume_bit_exact(mode: Mode, opt: Opt, fast: bool, tag: &str) {
    let d = data(3);
    let epochs = 4;

    let mut ex = exec();
    ex.set_fast(fast);
    let full = train(&ex, &d, &opts(mode, opt, epochs)).unwrap();

    // same run, checkpointing every epoch and keeping every file
    let dir = tmpdir(tag);
    let mut o = opts(mode, opt, epochs);
    o.checkpoint.dir = Some(dir.clone());
    o.checkpoint.keep = 0;
    let mut ex2 = exec();
    ex2.set_fast(fast);
    let ckpt_run = train(&ex2, &d, &o).unwrap();
    assert_runs_identical(&full, &ckpt_run, &format!("{tag}: checkpointing changed the run"));

    // resume the k=2 checkpoint in a fresh executor and finish
    let mut o2 = opts(mode, opt, epochs);
    o2.checkpoint.resume = Some(ResumeFrom::Path(dir.join("ckpt-000002.bcckpt")));
    let mut ex3 = exec();
    ex3.set_fast(fast);
    let resumed = train(&ex3, &d, &o2).unwrap();
    assert_runs_identical(&full, &resumed, &format!("{tag}: resume diverged"));
    assert!(!resumed.interrupted);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_bit_exact_sgd_det() {
    assert_resume_bit_exact(Mode::Det, Opt::Sgd, true, "sgd_det");
}

#[test]
fn resume_bit_exact_sgd_stoch() {
    assert_resume_bit_exact(Mode::Stoch, Opt::Sgd, true, "sgd_stoch");
}

#[test]
fn resume_bit_exact_nesterov_det() {
    assert_resume_bit_exact(Mode::Det, Opt::Nesterov, true, "nesterov_det");
}

#[test]
fn resume_bit_exact_nesterov_stoch() {
    assert_resume_bit_exact(Mode::Stoch, Opt::Nesterov, true, "nesterov_stoch");
}

#[test]
fn resume_bit_exact_adam_det() {
    assert_resume_bit_exact(Mode::Det, Opt::Adam, true, "adam_det");
}

#[test]
fn resume_bit_exact_adam_stoch() {
    assert_resume_bit_exact(Mode::Stoch, Opt::Adam, true, "adam_stoch");
}

#[test]
fn resume_bit_exact_baseline_path() {
    // the dense seed-era kernel path honors the same contract
    assert_resume_bit_exact(Mode::Det, Opt::Adam, false, "baseline_adam_det");
}

#[test]
fn resume_latest_picks_newest_and_empty_dir_starts_fresh() {
    let d = data(5);
    let ex = exec();
    let full = train(&ex, &d, &opts(Mode::Det, Opt::Sgd, 3)).unwrap();

    // resume latest over an empty dir == fresh start
    let dir = tmpdir("latest");
    let mut o = opts(Mode::Det, Opt::Sgd, 3);
    o.checkpoint.dir = Some(dir.clone());
    o.checkpoint.resume = Some(ResumeFrom::Latest);
    let fresh = train(&ex, &d, &o).unwrap();
    assert_runs_identical(&full, &fresh, "fresh start under --resume latest");

    // now the dir has checkpoints: run again with a shorter budget
    // already done (3 epochs saved); resuming latest is a no-op run
    let resumed = train(&ex, &d, &o).unwrap();
    assert_runs_identical(&full, &resumed, "resume of a finished run");
    assert_eq!(resumed.curves.len(), 3, "no extra epochs after completion");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_latest_without_dir_is_an_error() {
    let d = data(6);
    let ex = exec();
    let mut o = opts(Mode::Det, Opt::Sgd, 2);
    o.checkpoint.resume = Some(ResumeFrom::Latest);
    let err = train(&ex, &d, &o).unwrap_err().to_string();
    assert!(err.contains("checkpoint dir"), "{err}");
}

#[test]
fn resume_from_missing_path_is_an_error() {
    let d = data(6);
    let ex = exec();
    let mut o = opts(Mode::Det, Opt::Sgd, 2);
    o.checkpoint.resume = Some(ResumeFrom::Path(PathBuf::from("/nonexistent/x.bcckpt")));
    assert!(train(&ex, &d, &o).is_err());
}

#[test]
fn resume_refuses_configuration_mismatches() {
    let d = data(8);
    let ex = exec();
    let dir = tmpdir("compat");
    let mut o = opts(Mode::Det, Opt::Adam, 3);
    o.checkpoint.dir = Some(dir.clone());
    o.checkpoint.keep = 0;
    train(&ex, &d, &o).unwrap();
    let ck = dir.join("ckpt-000002.bcckpt");

    // different optimizer
    let mut o2 = opts(Mode::Det, Opt::Sgd, 3);
    o2.checkpoint.resume = Some(ResumeFrom::Path(ck.clone()));
    let err = train(&ex, &d, &o2).unwrap_err().to_string();
    assert!(err.contains("optimizer"), "{err}");

    // different binarization mode
    let mut o2 = opts(Mode::Stoch, Opt::Adam, 3);
    o2.checkpoint.resume = Some(ResumeFrom::Path(ck.clone()));
    let err = train(&ex, &d, &o2).unwrap_err().to_string();
    assert!(err.contains("mode"), "{err}");

    // different seed
    let mut o2 = opts(Mode::Det, Opt::Adam, 3);
    o2.seed = 8;
    o2.checkpoint.resume = Some(ResumeFrom::Path(ck.clone()));
    let err = train(&ex, &d, &o2).unwrap_err().to_string();
    assert!(err.contains("seed"), "{err}");

    // different epoch target
    let mut o2 = opts(Mode::Det, Opt::Adam, 5);
    o2.checkpoint.resume = Some(ResumeFrom::Path(ck.clone()));
    let err = train(&ex, &d, &o2).unwrap_err().to_string();
    assert!(err.contains("epochs"), "{err}");

    // different silent hyperparameter (dropout) -> fingerprint mismatch
    let mut o2 = opts(Mode::Det, Opt::Adam, 3);
    o2.dropout = 0.25;
    o2.checkpoint.resume = Some(ResumeFrom::Path(ck.clone()));
    let err = train(&ex, &d, &o2).unwrap_err().to_string();
    assert!(err.contains("fingerprint"), "{err}");

    // different model shape -> state validation failure
    let other = ReferenceExecutor::new(mlp_info("micro", DIM, 6, 2, CLASSES, 8)).unwrap();
    let mut o2 = opts(Mode::Det, Opt::Adam, 3);
    o2.checkpoint.resume = Some(ResumeFrom::Path(ck.clone()));
    assert!(train(&other, &d, &o2).is_err());

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn trainer_retention_keeps_newest_files() {
    let d = data(9);
    let ex = exec();
    let dir = tmpdir("retain");
    let mut o = opts(Mode::Det, Opt::Sgd, 5);
    o.checkpoint.dir = Some(dir.clone());
    o.checkpoint.keep = 2;
    train(&ex, &d, &o).unwrap();
    let names: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    let mut names = names;
    names.sort();
    assert_eq!(names, vec!["ckpt-000004.bcckpt", "ckpt-000005.bcckpt"], "{names:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checkpoint_cadence_skips_intermediate_epochs() {
    let d = data(10);
    let ex = exec();
    let dir = tmpdir("cadence");
    let mut o = opts(Mode::Det, Opt::Sgd, 5);
    o.checkpoint.dir = Some(dir.clone());
    o.checkpoint.every_epochs = 2;
    o.checkpoint.keep = 0;
    train(&ex, &d, &o).unwrap();
    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    names.sort();
    // cadence epochs 2 and 4, plus the always-saved final epoch 5
    assert_eq!(
        names,
        vec!["ckpt-000002.bcckpt", "ckpt-000004.bcckpt", "ckpt-000005.bcckpt"],
        "{names:?}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stop_latch_checkpoints_and_resumes_bit_exactly() {
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    let d = data(11);
    let ex = exec();
    let full = train(&ex, &d, &opts(Mode::Det, Opt::Nesterov, 4)).unwrap();

    // pre-set latch: the run stops (and checkpoints) after epoch 1
    let dir = tmpdir("stop");
    let mut o = opts(Mode::Det, Opt::Nesterov, 4);
    o.checkpoint.dir = Some(dir.clone());
    o.stop = Some(Arc::new(AtomicBool::new(true)));
    let stopped = train(&ex, &d, &o).unwrap();
    assert!(stopped.interrupted);
    assert_eq!(stopped.curves.len(), 1);
    assert!(dir.join("ckpt-000001.bcckpt").exists());

    // resume latest and run to completion: identical to uninterrupted
    let mut o2 = opts(Mode::Det, Opt::Nesterov, 4);
    o2.checkpoint.dir = Some(dir.clone());
    o2.checkpoint.resume = Some(ResumeFrom::Latest);
    let resumed = train(&ex, &d, &o2).unwrap();
    assert!(!resumed.interrupted);
    assert_runs_identical(&full, &resumed, "stop-latch resume");

    let _ = std::fs::remove_dir_all(&dir);
}
