//! Integration: the packed multiplication-free engine must agree with the
//! reference backend's deterministic-BC evaluation on identical trained
//! parameters — i.e. paper Sec. 2.6 method 1 has ONE semantics across
//! both engines.

use binaryconnect::binary::{load_packed, pack_mlp, save_packed};
use binaryconnect::coordinator::{mnist_opts, train};
use binaryconnect::data::{synth::synth_mnist, SplitData};
use binaryconnect::pipeline::{gather_batch, Plan};
use binaryconnect::preprocess::Standardizer;
use binaryconnect::runtime::{Executor, Hyper, Mode, ReferenceExecutor};

fn mlp() -> ReferenceExecutor {
    ReferenceExecutor::builtin("mlp").unwrap()
}

#[test]
fn packed_engine_matches_reference_det_eval() {
    let model = mlp();
    // short real training so BN stats / weights are non-trivial
    let mut train_ds = synth_mnist(1000, 31);
    let mut test_ds = synth_mnist(300, 32);
    let st = Standardizer::fit(&train_ds);
    st.apply(&mut train_ds);
    st.apply(&mut test_ds);
    let data = SplitData::from_train_test(train_ds, test_ds, 150);
    let opts = mnist_opts(Mode::Det, 6, 77);
    let r = train(&model, &data, &opts).unwrap();

    let packed = pack_mlp(model.info(), &r.state).unwrap();

    // disk round trip must be lossless
    let path = std::env::temp_dir().join(format!("bc_it_pack_{}.bcpack", std::process::id()));
    save_packed(&packed, &path).unwrap();
    let packed = load_packed(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    // compare per-example decisions on full batches
    let batch = model.info().batch;
    let idx: Vec<usize> = (0..batch).collect();
    let b = gather_batch(&data.test, &idx, batch, 0);
    let hyper = Hyper { mode: Mode::Det, ..Default::default() };
    let (_, errv) = model.eval_batch(&r.state, &b.x, &b.y, &hyper).unwrap();

    let preds = packed.classify(&b.x, batch);
    let mut disagreements = 0;
    for i in 0..batch {
        let label = data.test.labels[i] as usize;
        let ref_correct = errv[i] == 0.0;
        let packed_correct = preds[i] == label;
        if ref_correct != packed_correct {
            disagreements += 1;
        }
    }
    // identical math up to f32 summation order; allow a whisker of ties
    assert!(
        disagreements <= batch.div_ceil(50),
        "{disagreements}/{batch} decision disagreements between engines"
    );

    // aggregate error must match closely too
    let packed_err = packed.test_error(&data.test, 64);
    assert!(
        (packed_err - r.test_err).abs() < 0.05,
        "packed {packed_err} vs reference {}",
        r.test_err
    );
}

#[test]
fn packed_memory_is_about_32x_smaller() {
    let model = mlp();
    let state = model.init_state(&Hyper::default()).unwrap();
    let packed = pack_mlp(model.info(), &state).unwrap();
    let ratio = packed.f32_weight_memory_bytes() as f64 / packed.weight_memory_bytes() as f64;
    assert!(ratio > 28.0, "only {ratio}x");
}

#[test]
fn eval_plan_batches_are_deterministic() {
    // evaluation must not depend on the order batches are built in
    let ds = synth_mnist(130, 5);
    let plans = binaryconnect::pipeline::batch_indices(ds.len(), 50, Plan::Sequential);
    assert_eq!(plans.len(), 3);
    assert_eq!(plans[2].len(), 30);
}
