//! Chaos integration: the trainer under deterministic fault injection.
//!
//! The headline property (ISSUE 9 acceptance): with step panics, torn
//! checkpoint writes and NaN gradients firing on seeded schedules, a
//! crash/resume loop still converges to the *bit-identical* final state
//! of an unfaulted run, and every recovery counter (caught panics,
//! diverged steps, rollbacks, torn saves) matches the injector's own
//! counts exactly.

use std::path::PathBuf;
use std::sync::{Arc, Once};

use binaryconnect::coordinator::{train, LrSchedule, ResumeFrom, TrainOpts};
use binaryconnect::data::{Dataset, SplitData};
use binaryconnect::runtime::{
    reference::mlp_info, Executor, Hyper, Mode, Opt, ReferenceExecutor, TrainState,
};
use binaryconnect::util::{checkpoint, FaultPlan, Rng};

const DIM: usize = 12;
const CLASSES: usize = 4;

/// Injected panics are expected noise; a chaos run would otherwise spew
/// backtraces. Forward every *other* panic to the default hook so a real
/// bug still prints.
fn quiet_injected_panics() {
    static QUIET: Once = Once::new();
    QUIET.call_once(|| {
        let default = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let payload = info.payload();
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_default();
            if !msg.starts_with("fault injection:") {
                default(info);
            }
        }));
    });
}

fn exec_with(faults: Option<Arc<FaultPlan>>) -> ReferenceExecutor {
    let mut ex = ReferenceExecutor::new(mlp_info("micro", DIM, 10, 2, CLASSES, 8)).unwrap();
    ex.set_faults(faults);
    ex
}

/// Tiny separable synthetic dataset: 64 train rows -> 8 steps/epoch, so
/// a crash/resume loop with a per-step panic probability converges fast.
fn data(seed: u64) -> SplitData {
    let mut rng = Rng::new(seed);
    let mut mk = |n: usize| {
        let mut ds = Dataset::new("micro", (DIM, 1, 1), CLASSES);
        let mut row = vec![0f32; DIM];
        for i in 0..n {
            let label = (i % CLASSES) as u8;
            for (j, v) in row.iter_mut().enumerate() {
                let noise = (rng.next_u64() % 2048) as f32 / 1024.0 - 1.0;
                *v = noise + if j % CLASSES == label as usize { 1.5 } else { 0.0 };
            }
            ds.push(&row, label);
        }
        ds
    };
    SplitData::from_train_test(mk(72), mk(24), 8)
}

fn opts(epochs: usize) -> TrainOpts {
    TrainOpts {
        epochs,
        schedule: LrSchedule::Exponential { start: 0.01, end: 0.002, epochs },
        mode: Mode::Det,
        opt: Opt::Adam,
        seed: 7,
        verbose: false,
        ..Default::default()
    }
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("bc_chaos_train_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn state_bits(s: &TrainState) -> Vec<Vec<Vec<u32>>> {
    [&s.params, &s.m, &s.v]
        .iter()
        .map(|g| g.iter().map(|t| t.iter().map(|x| x.to_bits()).collect()).collect())
        .collect()
}

/// Deterministic crash recovery: a guaranteed (p=1) step panic kills the
/// run right after the epoch-1 checkpoint; resuming `latest` finishes the
/// run bit-identically to a never-crashed one.
#[test]
fn crash_after_checkpoint_resumes_bit_exactly() {
    quiet_injected_panics();
    let d = data(21);
    let clean = train(&exec_with(None), &d, &opts(3)).unwrap();

    let dir = tmpdir("crash");
    // phase 1: train epoch 0, checkpoint, then crash at epoch 1 step 1
    {
        use std::sync::atomic::AtomicBool;
        let mut o = opts(3);
        o.checkpoint.dir = Some(dir.clone());
        o.stop = Some(Arc::new(AtomicBool::new(true))); // stop after epoch 1
        let r = train(&exec_with(None), &d, &o).unwrap();
        assert!(r.interrupted);
        assert!(dir.join("ckpt-000001.bcckpt").exists());
    }
    let plan = Arc::new(FaultPlan::parse("panic_step@1", 0).unwrap());
    {
        let mut o = opts(3);
        o.checkpoint.dir = Some(dir.clone());
        o.checkpoint.resume = Some(ResumeFrom::Latest);
        o.faults = Some(plan.clone());
        let ex = exec_with(Some(plan.clone()));
        let crashed =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| train(&ex, &d, &o)));
        assert!(crashed.is_err(), "p=1 step panic must fire");
    }
    assert_eq!(plan.injected_step_panics(), 1);

    // phase 2: resume without faults and finish
    let mut o = opts(3);
    o.checkpoint.dir = Some(dir.clone());
    o.checkpoint.resume = Some(ResumeFrom::Latest);
    let resumed = train(&exec_with(None), &d, &o).unwrap();

    assert_eq!(state_bits(&clean.state), state_bits(&resumed.state));
    assert_eq!(clean.steps, resumed.steps);
    assert_eq!(clean.best_val_err.to_bits(), resumed.best_val_err.to_bits());
    assert_eq!(clean.test_err.to_bits(), resumed.test_err.to_bits());
    let _ = std::fs::remove_dir_all(&dir);
}

/// The crash/resume *loop*: a seeded per-step panic probability keeps
/// killing the process-equivalent (catch_unwind) mid-epoch; resuming
/// `latest` each time must still land on the unfaulted run's bits, with
/// the caught-panic count exactly equal to the injector's fired count.
#[test]
fn seeded_crash_resume_loop_lands_on_clean_bits() {
    quiet_injected_panics();
    let d = data(22);
    let clean = train(&exec_with(None), &d, &opts(2)).unwrap();

    let dir = tmpdir("crashloop");
    let plan = Arc::new(FaultPlan::parse("panic_step@0.1,seed=9", 0).unwrap());
    let mut caught = 0u64;
    let mut finished = None;
    for _attempt in 0..100 {
        let mut o = opts(2);
        o.checkpoint.dir = Some(dir.clone());
        o.checkpoint.resume = Some(ResumeFrom::Latest);
        o.faults = Some(plan.clone());
        let ex = exec_with(Some(plan.clone()));
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| train(&ex, &d, &o))) {
            Ok(r) => {
                finished = Some(r.unwrap());
                break;
            }
            Err(_) => caught += 1,
        }
    }
    let r = finished.expect("run never completed within 100 crash/resume attempts");
    // injector and harness count the same events
    assert_eq!(caught, plan.injected_step_panics());
    assert_eq!(state_bits(&clean.state), state_bits(&r.state), "after {caught} crashes");
    assert_eq!(clean.steps, r.steps);
    assert_eq!(clean.curves.len(), r.curves.len());
    for (a, b) in clean.curves.iter().zip(&r.curves) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert_eq!(a.val_err.to_bits(), b.val_err.to_bits());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Divergence sentinel with skip: a p=1 NaN-gradient injection poisons
/// every step; skipping leaves the state bit-identical to init, and the
/// diverged-step accounting matches the injector exactly.
#[test]
fn nan_grad_with_skip_preserves_state_and_counts_exactly() {
    let d = data(23);
    let plan = Arc::new(FaultPlan::parse("nan_grad@1", 0).unwrap());
    let ex = exec_with(Some(plan.clone()));
    let mut o = opts(1);
    o.faults = Some(plan.clone());
    assert!(o.skip_diverged, "skip is the default policy");

    let init = ex
        .init_state(&Hyper { seed: (o.seed & 0xFF_FFFF) as u32, ..Default::default() })
        .unwrap();
    let r = train(&ex, &d, &o).unwrap();

    assert_eq!(state_bits(&init), state_bits(&r.state), "skipped updates must not land");
    assert_eq!(r.diverged_steps, r.steps as u64, "every step was poisoned");
    assert_eq!(r.diverged_steps, plan.injected_nan_grads());
    assert_eq!(r.rollbacks, 0, "rollback is off by default");
}

/// Without skip, the poisoned update lands: NaN reaches the weights.
#[test]
fn nan_grad_without_skip_poisons_the_weights() {
    let d = data(24);
    let plan = Arc::new(FaultPlan::parse("nan_grad@1", 0).unwrap());
    let ex = exec_with(Some(plan.clone()));
    let mut o = opts(1);
    o.faults = Some(plan.clone());
    o.skip_diverged = false;
    let r = train(&ex, &d, &o).unwrap();
    assert!(r.diverged_steps > 0);
    assert!(
        r.state.params[0].iter().any(|v| !v.is_finite()),
        "un-skipped NaN update must reach the weights"
    );
}

/// Rollback escalation: with every step diverging, each replay re-trips
/// the `max_diverged_steps` threshold until the rollback cap turns the
/// death spiral into a clear error — after exactly cap+1 attempts of
/// threshold+1 poisoned steps each.
#[test]
fn rollback_exhaustion_is_a_clear_error() {
    let d = data(25);
    let plan = Arc::new(FaultPlan::parse("nan_grad@1", 0).unwrap());
    let ex = exec_with(Some(plan.clone()));
    let mut o = opts(2);
    o.faults = Some(plan.clone());
    o.max_diverged_steps = 2;
    let err = train(&ex, &d, &o).unwrap_err().to_string();
    assert!(err.contains("rollback"), "{err}");
    // 8 rollbacks + the initial attempt, each aborted after 3 bad steps
    assert_eq!(plan.injected_nan_grads(), 9 * 3);
}

/// Torn-write injection: every checkpoint save lands truncated, load-time
/// CRC validation rejects them all, and `--resume latest` degrades to a
/// clean fresh start instead of trusting a corrupt file.
#[test]
fn torn_checkpoints_are_rejected_and_resume_starts_fresh() {
    let d = data(26);
    let clean = train(&exec_with(None), &d, &opts(2)).unwrap();

    let dir = tmpdir("torn");
    let plan = Arc::new(FaultPlan::parse("torn_checkpoint@1", 0).unwrap());
    let mut o = opts(2);
    o.checkpoint.dir = Some(dir.clone());
    o.faults = Some(plan.clone());
    let r = train(&exec_with(Some(plan.clone())), &d, &o).unwrap();
    // the run itself is unaffected — only the on-disk artifacts are torn
    assert_eq!(state_bits(&clean.state), state_bits(&r.state));
    assert_eq!(plan.injected_torn_checkpoints(), 2, "one torn save per epoch");
    assert_eq!(checkpoint::list(&dir).len(), 2);
    assert!(checkpoint::latest_good(&dir).is_none(), "every file must fail validation");

    // resume over the all-torn dir: graceful fresh start, same result
    let mut o2 = opts(2);
    o2.checkpoint.dir = Some(dir.clone());
    o2.checkpoint.resume = Some(ResumeFrom::Latest);
    let resumed = train(&exec_with(None), &d, &o2).unwrap();
    assert_eq!(state_bits(&clean.state), state_bits(&resumed.state));
    let _ = std::fs::remove_dir_all(&dir);
}
