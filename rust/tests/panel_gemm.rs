//! Panel-packing GEMM property suite (PR 6).
//!
//! Contracts pinned here, on top of `tests/simd_kernels.rs`:
//! * pack/unpack roundtrip: `pack_lhs`/`pack_rhs` followed by their
//!   unpackers reconstruct the logical matrix exactly, for dense and
//!   strided (transposed) sources, on ragged panel edges.
//! * panel GEMM ≡ naive oracle: every supported ISA rung of the trio
//!   (`A·B`, `Aᵀ·B`, `A·Bᵀ`) agrees with the seed's triple loop within
//!   the 1e-5 L1-mass reordering bound — ragged shapes, batch 1, and
//!   ±0.0 inputs included.
//! * `gemm*_into` (caller-owned [`PanelBuf`]) is bit-identical to the
//!   thread-local-buffer entry points, and the buffer is reusable across
//!   orientations and shapes.
//! * pooled ≡ serial bit-exactness survives the panel refactor.
//! * the `gemm*_strip` baselines (pre-panel kernels, kept for
//!   `perf_gemm`'s speedup ladder) still agree with the oracle.
//! * packed sign-GEMM: the panelized batched forward is **bit-exact**
//!   against the strip baseline (`matmul_scaled_into_strip`), batch 1
//!   and chunk-edge batches included.

use binaryconnect::binary::packed::BitMatrix;
use binaryconnect::kernel::pack::{
    lhs_len, pack_lhs, pack_rhs, rhs_len, unpack_lhs, unpack_rhs, PanelBuf,
};
use binaryconnect::kernel::simd::{Isa, ALL_ISAS};
use binaryconnect::kernel::{self};
use binaryconnect::prop::check;
use binaryconnect::util::Rng;

/// Every rung this host can actually execute (always includes scalar).
fn arms() -> Vec<Isa> {
    ALL_ISAS.into_iter().filter(|i| i.supported()).collect()
}

/// A dimension biased onto microkernel tile edges (multiples of the
/// widest mr/nr geometry ± 1).
fn edge_dim(r: &mut Rng, tile: usize, max: usize) -> usize {
    match r.below(4) {
        0 => tile * (1 + r.below(3)),
        1 => (tile * (1 + r.below(3))).saturating_sub(1).max(1),
        2 => tile * (1 + r.below(3)) + 1,
        _ => 1 + r.below(max),
    }
}

/// Values with zeros (both signs) mixed in — the pack-padding and
/// sign-bit edges.
fn signed_vals(r: &mut Rng, len: usize) -> Vec<f32> {
    (0..len)
        .map(|_| match r.below(8) {
            0 => 0.0f32,
            1 => -0.0f32,
            _ => r.normal(),
        })
        .collect()
}

/// |got - want| <= 1e-5 * (1 + l1) per element, l1 the L1 mass of the
/// element's products (the f32 reordering bound).
fn close_l1(name: &str, got: &[f32], want: &[f32], l1: &[f32]) -> Result<(), String> {
    for (i, ((&g, &w), &m)) in got.iter().zip(want).zip(l1).enumerate() {
        if (g - w).abs() > 1e-5 * (1.0 + m.abs()) {
            return Err(format!("{name}[{i}]: {g} vs {w} (l1 {m})"));
        }
    }
    Ok(())
}

fn bits_equal(name: &str, got: &[f32], want: &[f32]) -> Result<(), String> {
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        if g.to_bits() != w.to_bits() {
            return Err(format!("{name} not bit-exact at {i}: {g:?} vs {w:?}"));
        }
    }
    Ok(())
}

#[test]
fn prop_pack_roundtrip_dense_and_strided() {
    check(
        "pack/unpack roundtrip (dense + transposed sources)",
        |r| {
            let m = edge_dim(r, 4, 40);
            let k = 1 + r.below(30);
            let n = edge_dim(r, 16, 50);
            let a = signed_vals(r, m * k);
            let b = signed_vals(r, k * n);
            (m, k, n, a, b)
        },
        |(m, k, n, a, b)| {
            let (m, k, n) = (*m, *k, *n);
            for mr in [4usize] {
                let mut pa = vec![f32::NAN; lhs_len(m, k, mr)];
                pack_lhs(a, k, 1, m, k, mr, 0, m.div_ceil(mr), &mut pa);
                if unpack_lhs(&pa, m, k, mr) != *a {
                    return Err(format!("lhs roundtrip m={m} k={k} mr={mr}"));
                }
            }
            for nr in [8usize, 16] {
                let mut pb = vec![f32::NAN; rhs_len(k, n, nr)];
                pack_rhs(b, n, 1, k, n, nr, 0, n.div_ceil(nr), &mut pb);
                if unpack_rhs(&pb, k, n, nr) != *b {
                    return Err(format!("rhs roundtrip k={k} n={n} nr={nr}"));
                }
            }
            // strided (Aᵀ as LHS): packing a's columns equals packing the
            // explicit transpose's rows
            let mut at = vec![0f32; k * m];
            for i in 0..m {
                for kk in 0..k {
                    at[kk * m + i] = a[i * k + kk];
                }
            }
            let mr = 4;
            let mut via_stride = vec![f32::NAN; lhs_len(k, m, mr)];
            pack_lhs(a, 1, k, k, m, mr, 0, k.div_ceil(mr), &mut via_stride);
            let mut via_dense = vec![f32::NAN; lhs_len(k, m, mr)];
            pack_lhs(&at, m, 1, k, m, mr, 0, k.div_ceil(mr), &mut via_dense);
            if via_stride != via_dense {
                return Err(format!("strided lhs pack m={m} k={k}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_panel_trio_matches_naive_every_arm() {
    check(
        "panel GEMM trio == naive oracle on every supported arm",
        |r| {
            let m = if r.below(5) == 0 { 1 } else { edge_dim(r, 4, 40) }; // batch 1
            let k = edge_dim(r, 16, 120);
            let n = edge_dim(r, 16, 90);
            let a = signed_vals(r, m * k);
            let b = signed_vals(r, k * n);
            let bt = signed_vals(r, m * n); // m x n operand for Aᵀ·B
            (m, k, n, a, b, bt)
        },
        |(m, k, n, a, b, bt)| {
            let (m, k, n) = (*m, *k, *n);
            let absa: Vec<f32> = a.iter().map(|v| v.abs()).collect();
            let absb: Vec<f32> = b.iter().map(|v| v.abs()).collect();
            let absbt: Vec<f32> = bt.iter().map(|v| v.abs()).collect();

            // C = A·B
            let mut want = vec![0f32; m * n];
            kernel::gemm_naive(a, b, m, k, n, &mut want);
            let mut l1 = vec![0f32; m * n];
            kernel::gemm_naive(&absa, &absb, m, k, n, &mut l1);
            for &isa in &arms() {
                let mut got = vec![f32::NAN; m * n];
                kernel::gemm_with(isa, a, b, m, k, n, &mut got);
                close_l1(&format!("gemm/{}", isa.name()), &got, &want, &l1)?;
            }

            // C = Aᵀ·B: A is m x k, B is m x n, C is k x n
            let mut want = vec![0f32; k * n];
            kernel::gemm_at_b_naive(a, bt, m, k, n, &mut want);
            let mut l1 = vec![0f32; k * n];
            kernel::gemm_at_b_naive(&absa, &absbt, m, k, n, &mut l1);
            for &isa in &arms() {
                let mut got = vec![f32::NAN; k * n];
                kernel::gemm_at_b_with(isa, a, bt, m, k, n, &mut got);
                close_l1(&format!("at_b/{}", isa.name()), &got, &want, &l1)?;
            }

            // C = A·Bᵀ: A is m x n (bt), B is k x n (b), C is m x k
            let mut want = vec![0f32; m * k];
            kernel::gemm_a_bt_naive(bt, b, m, n, k, &mut want);
            let mut l1 = vec![0f32; m * k];
            kernel::gemm_a_bt_naive(&absbt, &absb, m, n, k, &mut l1);
            for &isa in &arms() {
                let mut got = vec![f32::NAN; m * k];
                kernel::gemm_a_bt_with(isa, bt, b, m, n, k, &mut got);
                close_l1(&format!("a_bt/{}", isa.name()), &got, &want, &l1)?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_into_serial_and_pooled_agree_bit_exact() {
    check(
        "gemm == gemm_serial == gemm_into (bit-exact), buffer reused",
        |r| {
            let m = edge_dim(r, 4, 50);
            let k = edge_dim(r, 16, 100);
            let n = edge_dim(r, 16, 80);
            let a = signed_vals(r, m * k);
            let b = signed_vals(r, k * n);
            let bt = signed_vals(r, m * n);
            (m, k, n, a, b, bt)
        },
        |(m, k, n, a, b, bt)| {
            let (m, k, n) = (*m, *k, *n);
            let mut buf = PanelBuf::new();

            let mut pooled = vec![0f32; m * n];
            kernel::gemm(a, b, m, k, n, &mut pooled);
            let mut serial = vec![f32::NAN; m * n];
            kernel::gemm_serial(a, b, m, k, n, &mut serial);
            bits_equal("gemm_serial", &serial, &pooled)?;
            let mut into = vec![f32::NAN; m * n];
            kernel::gemm_into(a, b, m, k, n, &mut into, &mut buf);
            bits_equal("gemm_into", &into, &pooled)?;

            // same buffer carries the other two orientations and shapes
            let mut pooled = vec![0f32; k * n];
            kernel::gemm_at_b(a, bt, m, k, n, &mut pooled);
            let mut into = vec![f32::NAN; k * n];
            kernel::gemm_at_b_into(a, bt, m, k, n, &mut into, &mut buf);
            bits_equal("gemm_at_b_into", &into, &pooled)?;

            let mut pooled = vec![0f32; m * k];
            kernel::gemm_a_bt(bt, b, m, n, k, &mut pooled);
            let mut into = vec![f32::NAN; m * k];
            kernel::gemm_a_bt_into(bt, b, m, n, k, &mut into, &mut buf);
            bits_equal("gemm_a_bt_into", &into, &pooled)?;
            Ok(())
        },
    );
}

#[test]
fn prop_strip_baselines_match_naive() {
    check(
        "gemm*_strip (perf baseline) == naive oracle",
        |r| {
            let m = 1 + r.below(30);
            let k = edge_dim(r, 16, 90);
            let n = edge_dim(r, 16, 70);
            let a = signed_vals(r, m * k);
            let b = signed_vals(r, k * n);
            let bt = signed_vals(r, m * n);
            (m, k, n, a, b, bt)
        },
        |(m, k, n, a, b, bt)| {
            let (m, k, n) = (*m, *k, *n);
            let absa: Vec<f32> = a.iter().map(|v| v.abs()).collect();
            let absb: Vec<f32> = b.iter().map(|v| v.abs()).collect();
            let absbt: Vec<f32> = bt.iter().map(|v| v.abs()).collect();

            let mut want = vec![0f32; m * n];
            kernel::gemm_naive(a, b, m, k, n, &mut want);
            let mut l1 = vec![0f32; m * n];
            kernel::gemm_naive(&absa, &absb, m, k, n, &mut l1);
            let mut got = vec![f32::NAN; m * n];
            kernel::gemm_strip(a, b, m, k, n, &mut got);
            close_l1("gemm_strip", &got, &want, &l1)?;

            let mut want = vec![0f32; k * n];
            kernel::gemm_at_b_naive(a, bt, m, k, n, &mut want);
            let mut l1 = vec![0f32; k * n];
            kernel::gemm_at_b_naive(&absa, &absbt, m, k, n, &mut l1);
            let mut got = vec![f32::NAN; k * n];
            kernel::gemm_at_b_strip(a, bt, m, k, n, &mut got);
            close_l1("gemm_at_b_strip", &got, &want, &l1)?;

            let mut want = vec![0f32; m * k];
            kernel::gemm_a_bt_naive(bt, b, m, n, k, &mut want);
            let mut l1 = vec![0f32; m * k];
            kernel::gemm_a_bt_naive(&absbt, &absb, m, n, k, &mut l1);
            let mut got = vec![f32::NAN; m * k];
            kernel::gemm_a_bt_strip(bt, b, m, n, k, &mut got);
            close_l1("gemm_a_bt_strip", &got, &want, &l1)?;
            Ok(())
        },
    );
}

#[test]
fn prop_packed_panel_forward_bit_exact_vs_strip() {
    check(
        "packed panel forward == strip baseline (bit-exact)",
        |r| {
            // b straddles the sel-chunk widths (64/128) incl. batch 1;
            // k straddles the 64-bit words and the 4-word blocks; n
            // straddles the 8-column panels.
            let b = match r.below(4) {
                0 => 1,
                1 => 64 + r.below(3),
                2 => 127 + r.below(3),
                _ => 1 + r.below(140),
            };
            let k = match r.below(3) {
                0 => 64 * (1 + r.below(5)),
                1 => 256 + r.below(3),
                _ => 1 + r.below(300),
            };
            let n = match r.below(3) {
                0 => 8 * (1 + r.below(4)),
                1 => 8 * (1 + r.below(4)) + 1,
                _ => 1 + r.below(24),
            };
            let w = signed_vals(r, k * n);
            let x = signed_vals(r, b * k);
            (b, k, n, w, x)
        },
        |(b, k, n, w, x)| {
            let (b, k, n) = (*b, *k, *n);
            let bm = BitMatrix::pack(w, k, n);
            let scale = 0.37f32;
            let mut xt = vec![0f32; k * b];
            let mut totals = vec![0f32; b];
            let mut want = vec![f32::NAN; b * n];
            bm.matmul_scaled_into_strip(x, b, scale, &mut want, &mut xt, &mut totals);
            let mut got = vec![f32::NAN; b * n];
            bm.matmul_scaled_into(x, b, scale, &mut got, &mut xt, &mut totals);
            bits_equal("panel forward", &got, &want)
        },
    );
}

#[test]
fn degenerate_shapes_overwrite_stale_output() {
    // k == 0 products must still overwrite C with zeros, through every
    // entry family (the workspace reuses output buffers across steps)
    let mut buf = PanelBuf::new();
    let mut c = vec![f32::NAN; 6];
    kernel::gemm(&[], &[], 2, 0, 3, &mut c);
    assert!(c.iter().all(|v| *v == 0.0), "gemm k=0: {c:?}");
    let mut c = vec![f32::NAN; 6];
    kernel::gemm_into(&[], &[], 2, 0, 3, &mut c, &mut buf);
    assert!(c.iter().all(|v| *v == 0.0), "gemm_into k=0: {c:?}");
    let mut c = vec![f32::NAN; 6];
    kernel::gemm_at_b(&[], &[], 0, 2, 3, &mut c);
    assert!(c.iter().all(|v| *v == 0.0), "gemm_at_b m=0: {c:?}");
    let mut c = vec![f32::NAN; 6];
    kernel::gemm_a_bt(&[], &[], 2, 0, 3, &mut c);
    assert!(c.iter().all(|v| *v == 0.0), "gemm_a_bt n=0: {c:?}");
    // m == 0 / n == 0: no output to write, must not panic
    let full = [0f32; 12];
    kernel::gemm(&[], &full, 0, 4, 3, &mut []);
    kernel::gemm(&full, &[], 3, 4, 0, &mut []);
}
