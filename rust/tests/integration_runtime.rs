//! Integration tests over the [`Executor`] runtime, driven end-to-end on
//! the pure-Rust reference backend — no artifacts or PJRT needed, so they
//! always run (the PJRT path shares the trait and the same contracts).

use binaryconnect::runtime::{Executor, Hyper, Mode, Opt, ReferenceExecutor};

fn load(name: &str) -> ReferenceExecutor {
    ReferenceExecutor::builtin(name).expect("builtin model loads")
}

fn batch_for(model: &dyn Executor, seed: u64) -> (Vec<f32>, Vec<f32>) {
    use binaryconnect::util::Rng;
    let mut rng = Rng::new(seed);
    let n: usize = model.info().input_shape.iter().product();
    let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let b = model.info().batch;
    let c = model.info().classes;
    let mut y = vec![-1.0f32; b * c];
    for i in 0..b {
        y[i * c + rng.below(c)] = 1.0;
    }
    (x, y)
}

#[test]
fn init_shapes_match_spec() {
    let model = load("mlp");
    let state = model.init_state(&Hyper::default()).unwrap();
    assert_eq!(state.params.len(), model.info().params.len());
    assert_eq!(state.m.len(), model.info().params.len());
    for (t, info) in state.params.iter().zip(&model.info().params) {
        assert_eq!(t.len(), info.numel(), "shape mismatch for {}", info.name);
    }
    // slots start at zero
    for s in state.m.iter().chain(state.v.iter()) {
        assert!(s.iter().all(|&v| v == 0.0));
    }
}

#[test]
fn init_is_seed_deterministic() {
    let model = load("mlp");
    let a = model.init_state(&Hyper { seed: 9, ..Default::default() }).unwrap();
    let b = model.init_state(&Hyper { seed: 9, ..Default::default() }).unwrap();
    let c = model.init_state(&Hyper { seed: 10, ..Default::default() }).unwrap();
    assert_eq!(a.params[0], b.params[0]);
    assert_ne!(a.params[0], c.params[0]);
}

#[test]
fn weights_init_within_glorot_bounds() {
    let model = load("mlp");
    let state = model.init_state(&Hyper::default()).unwrap();
    for (t, info) in state.params.iter().zip(&model.info().params) {
        if info.kind == "weight" {
            let c = info.glorot as f32;
            let maxabs = t.iter().fold(0f32, |a, &b| a.max(b.abs()));
            assert!(maxabs <= c + 1e-6, "{}: {maxabs} > {c}", info.name);
            assert!(maxabs > c * 0.5, "{}: suspiciously small init", info.name);
        }
    }
}

#[test]
fn train_step_reduces_loss_and_clips() {
    let model = load("mlp");
    let mut state = model.init_state(&Hyper::default()).unwrap();
    let (x, y) = batch_for(&model, 7);
    let mut losses = vec![];
    for step in 1..=25 {
        let h = Hyper {
            lr: 0.005,
            mode: Mode::Det,
            opt: Opt::Sgd,
            step,
            seed: step,
            ..Default::default()
        };
        let m = model.train_step(&mut state, &x, &y, &h).unwrap();
        assert!(m.loss.is_finite());
        losses.push(m.loss);
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.7),
        "loss did not drop: {losses:?}"
    );
    // binary-kind weights stay clipped inside their Glorot box
    for (t, info) in state.params.iter().zip(&model.info().params) {
        if info.kind == "weight" {
            let lim = info.glorot as f32 + 1e-6;
            let maxabs = t.iter().fold(0f32, |a, &b| a.max(b.abs()));
            assert!(maxabs <= lim, "{} escaped the clip box: {maxabs}", info.name);
        }
    }
}

#[test]
fn stochastic_mode_trains_too() {
    let model = load("mlp");
    let mut state = model.init_state(&Hyper::default()).unwrap();
    let (x, y) = batch_for(&model, 8);
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for step in 1..=25 {
        let h = Hyper {
            lr: 0.005,
            mode: Mode::Stoch,
            opt: Opt::Sgd,
            step,
            seed: 1000 + step,
            ..Default::default()
        };
        let m = model.train_step(&mut state, &x, &y, &h).unwrap();
        if step == 1 {
            first = m.loss;
        }
        last = m.loss;
    }
    assert!(last < first * 0.8, "stoch loss {first} -> {last}");
}

#[test]
fn adam_and_nesterov_produce_finite_updates() {
    let model = load("mlp");
    for opt in [Opt::Adam, Opt::Nesterov] {
        let mut state = model.init_state(&Hyper::default()).unwrap();
        let (x, y) = batch_for(&model, 9);
        for step in 1..=5 {
            let h = Hyper { lr: 0.001, opt, step, seed: step, ..Default::default() };
            let m = model.train_step(&mut state, &x, &y, &h).unwrap();
            assert!(m.loss.is_finite(), "{opt:?} diverged");
        }
        // slots moved
        assert!(
            state.m[0].iter().any(|&v| v != 0.0),
            "{opt:?} left m slots at zero"
        );
    }
}

#[test]
fn eval_batch_returns_per_example_vectors() {
    let model = load("mlp");
    let state = model.init_state(&Hyper::default()).unwrap();
    let (x, y) = batch_for(&model, 10);
    let h = Hyper { mode: Mode::Det, ..Default::default() };
    let (lossv, errv) = model.eval_batch(&state, &x, &y, &h).unwrap();
    assert_eq!(lossv.len(), model.info().batch);
    assert_eq!(errv.len(), model.info().batch);
    assert!(errv.iter().all(|&e| e == 0.0 || e == 1.0));
    assert!(lossv.iter().all(|&l| l.is_finite() && l >= 0.0));
}

#[test]
fn eval_is_deterministic_given_mode_det() {
    let model = load("mlp");
    let state = model.init_state(&Hyper::default()).unwrap();
    let (x, y) = batch_for(&model, 11);
    let h = Hyper { mode: Mode::Det, seed: 1, ..Default::default() };
    let (l1, _) = model.eval_batch(&state, &x, &y, &h).unwrap();
    let h2 = Hyper { mode: Mode::Det, seed: 2, ..Default::default() }; // seed must not matter
    let (l2, _) = model.eval_batch(&state, &x, &y, &h2).unwrap();
    assert_eq!(l1, l2);
}

#[test]
fn train_step_is_seed_deterministic() {
    // two identical states + identical hypers must evolve identically,
    // even in stochastic mode (the RNG derives from Hyper::seed).
    let model = load("mlp_small");
    let mut a = model.init_state(&Hyper { seed: 4, ..Default::default() }).unwrap();
    let mut b = a.snapshot();
    let (x, y) = batch_for(&model, 12);
    let h = Hyper { lr: 0.01, mode: Mode::Stoch, step: 1, seed: 77, ..Default::default() };
    let ma = model.train_step(&mut a, &x, &y, &h).unwrap();
    let mb = model.train_step(&mut b, &x, &y, &h).unwrap();
    assert_eq!(ma.loss, mb.loss);
    assert_eq!(a.params[0], b.params[0]);
}

#[test]
fn bad_input_sizes_error_cleanly() {
    let model = load("mlp");
    let mut state = model.init_state(&Hyper::default()).unwrap();
    let (x, y) = batch_for(&model, 14);
    let h = Hyper::default();
    assert!(model.train_step(&mut state, &x[..10], &y, &h).is_err());
    assert!(model.train_step(&mut state, &x, &y[..5], &h).is_err());
    assert!(model.eval_batch(&state, &x[..10], &y, &h).is_err());
}

#[test]
fn snapshot_is_deep_copy() {
    let model = load("mlp");
    let mut state = model.init_state(&Hyper::default()).unwrap();
    let snap = state.snapshot();
    let before = snap.params[0].clone();
    let (x, y) = batch_for(&model, 15);
    let h = Hyper { lr: 0.01, step: 1, ..Default::default() };
    model.train_step(&mut state, &x, &y, &h).unwrap();
    assert_ne!(before, state.params[0], "training should move params");
    assert_eq!(before, snap.params[0], "snapshot must not alias live state");
}

#[test]
fn conv_builtin_requires_pjrt_backend() {
    let err = ReferenceExecutor::builtin("cnn").unwrap_err().to_string();
    assert!(err.contains("pjrt"), "unhelpful error: {err}");
    let err = ReferenceExecutor::builtin("not_a_model").unwrap_err().to_string();
    assert!(err.contains("mlp"), "error should list available models: {err}");
}
