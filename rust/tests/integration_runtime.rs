//! Integration tests over the PJRT runtime + built artifacts.
//!
//! These require `make artifacts` to have run; they skip (pass trivially)
//! when the artifacts directory is absent so `cargo test` stays green on a
//! fresh checkout.

use binaryconnect::runtime::{Hyper, Manifest, Mode, Model, Opt, Runtime};

fn load(name: &str) -> Option<Model> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return None;
    }
    let m = Manifest::load(dir).expect("manifest parses");
    let rt = Runtime::cpu().expect("pjrt cpu client");
    Some(rt.load_model(m.model(name).expect("model in manifest")).expect("compiles"))
}

fn batch_for(model: &Model, seed: u64) -> (Vec<f32>, Vec<f32>) {
    use binaryconnect::util::Rng;
    let mut rng = Rng::new(seed);
    let n: usize = model.info.input_shape.iter().product();
    let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let b = model.info.batch;
    let c = model.info.classes;
    let mut y = vec![-1.0f32; b * c];
    for i in 0..b {
        y[i * c + rng.below(c)] = 1.0;
    }
    (x, y)
}

#[test]
fn init_shapes_match_manifest() {
    let Some(model) = load("mlp") else { return };
    let state = model.init_state(&Hyper::default()).unwrap();
    assert_eq!(state.params.len(), model.info.params.len());
    assert_eq!(state.m.len(), model.info.params.len());
    for (lit, info) in state.params.iter().zip(&model.info.params) {
        let n = lit.to_vec::<f32>().unwrap().len();
        assert_eq!(n, info.numel(), "shape mismatch for {}", info.name);
    }
    // slots start at zero
    for s in state.m.iter().chain(state.v.iter()) {
        assert!(s.to_vec::<f32>().unwrap().iter().all(|&v| v == 0.0));
    }
}

#[test]
fn init_is_seed_deterministic() {
    let Some(model) = load("mlp") else { return };
    let a = model.init_state(&Hyper { seed: 9, ..Default::default() }).unwrap();
    let b = model.init_state(&Hyper { seed: 9, ..Default::default() }).unwrap();
    let c = model.init_state(&Hyper { seed: 10, ..Default::default() }).unwrap();
    assert_eq!(a.params[0].to_vec::<f32>().unwrap(), b.params[0].to_vec::<f32>().unwrap());
    assert_ne!(a.params[0].to_vec::<f32>().unwrap(), c.params[0].to_vec::<f32>().unwrap());
}

#[test]
fn weights_init_within_glorot_bounds() {
    let Some(model) = load("mlp") else { return };
    let state = model.init_state(&Hyper::default()).unwrap();
    for (lit, info) in state.params.iter().zip(&model.info.params) {
        if info.kind == "weight" {
            let v = lit.to_vec::<f32>().unwrap();
            let c = info.glorot as f32;
            let maxabs = v.iter().fold(0f32, |a, &b| a.max(b.abs()));
            assert!(maxabs <= c + 1e-6, "{}: {maxabs} > {c}", info.name);
            assert!(maxabs > c * 0.5, "{}: suspiciously small init", info.name);
        }
    }
}

#[test]
fn train_step_reduces_loss_and_clips() {
    let Some(model) = load("mlp") else { return };
    let mut state = model.init_state(&Hyper::default()).unwrap();
    let (x, y) = batch_for(&model, 7);
    let mut losses = vec![];
    for step in 1..=25 {
        let h = Hyper {
            lr: 0.005,
            mode: Mode::Det,
            opt: Opt::Sgd,
            step,
            seed: step,
            ..Default::default()
        };
        let m = model.train_step(&mut state, &x, &y, &h).unwrap();
        assert!(m.loss.is_finite());
        losses.push(m.loss);
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.5),
        "loss did not drop: {losses:?}"
    );
    // binary-kind weights stay clipped
    for (lit, info) in state.params.iter().zip(&model.info.params) {
        if info.kind == "weight" {
            let v = lit.to_vec::<f32>().unwrap();
            let maxabs = v.iter().fold(0f32, |a, &b| a.max(b.abs()));
            assert!(maxabs <= 1.0, "{} escaped the clip box: {maxabs}", info.name);
        }
    }
}

#[test]
fn stochastic_mode_trains_too() {
    let Some(model) = load("mlp") else { return };
    let mut state = model.init_state(&Hyper::default()).unwrap();
    let (x, y) = batch_for(&model, 8);
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for step in 1..=25 {
        let h = Hyper {
            lr: 0.005,
            mode: Mode::Stoch,
            opt: Opt::Sgd,
            step,
            seed: 1000 + step,
            ..Default::default()
        };
        let m = model.train_step(&mut state, &x, &y, &h).unwrap();
        if step == 1 {
            first = m.loss;
        }
        last = m.loss;
    }
    assert!(last < first * 0.7, "stoch loss {first} -> {last}");
}

#[test]
fn adam_and_nesterov_produce_finite_updates() {
    let Some(model) = load("mlp") else { return };
    for opt in [Opt::Adam, Opt::Nesterov] {
        let mut state = model.init_state(&Hyper::default()).unwrap();
        let (x, y) = batch_for(&model, 9);
        for step in 1..=5 {
            let h = Hyper { lr: 0.001, opt, step, seed: step, ..Default::default() };
            let m = model.train_step(&mut state, &x, &y, &h).unwrap();
            assert!(m.loss.is_finite(), "{opt:?} diverged");
        }
        // slots moved
        let m0 = state.m[0].to_vec::<f32>().unwrap();
        assert!(m0.iter().any(|&v| v != 0.0), "{opt:?} left m slots at zero");
    }
}

#[test]
fn eval_batch_returns_per_example_vectors() {
    let Some(model) = load("mlp") else { return };
    let state = model.init_state(&Hyper::default()).unwrap();
    let (x, y) = batch_for(&model, 10);
    let h = Hyper { mode: Mode::Det, ..Default::default() };
    let (lossv, errv) = model.eval_batch(&state, &x, &y, &h).unwrap();
    assert_eq!(lossv.len(), model.info.batch);
    assert_eq!(errv.len(), model.info.batch);
    assert!(errv.iter().all(|&e| e == 0.0 || e == 1.0));
    assert!(lossv.iter().all(|&l| l.is_finite() && l >= 0.0));
}

#[test]
fn eval_is_deterministic_given_mode_det() {
    let Some(model) = load("mlp") else { return };
    let state = model.init_state(&Hyper::default()).unwrap();
    let (x, y) = batch_for(&model, 11);
    let h = Hyper { mode: Mode::Det, seed: 1, ..Default::default() };
    let (l1, _) = model.eval_batch(&state, &x, &y, &h).unwrap();
    let h2 = Hyper { mode: Mode::Det, seed: 2, ..Default::default() }; // seed must not matter
    let (l2, _) = model.eval_batch(&state, &x, &y, &h2).unwrap();
    assert_eq!(l1, l2);
}

#[test]
fn pallas_and_native_gemm_models_agree() {
    // mlp (Pallas matmul) and mlp_ng (native dot) share init seeds, so one
    // eval on identical params must produce near-identical numbers — this
    // is the L1-kernel-vs-XLA cross-check at full-model scale.
    let Some(pallas) = load("mlp") else { return };
    let Some(native) = load("mlp_ng") else { return };
    let sp = pallas.init_state(&Hyper { seed: 3, ..Default::default() }).unwrap();
    let sn = native.init_state(&Hyper { seed: 3, ..Default::default() }).unwrap();
    assert_eq!(
        sp.params[0].to_vec::<f32>().unwrap(),
        sn.params[0].to_vec::<f32>().unwrap(),
        "same init expected"
    );
    let (x, y) = batch_for(&pallas, 12);
    let h = Hyper { mode: Mode::Det, ..Default::default() };
    let (lp, ep) = pallas.eval_batch(&sp, &x, &y, &h).unwrap();
    let (ln, en) = native.eval_batch(&sn, &x, &y, &h).unwrap();
    assert_eq!(ep, en, "hard decisions must agree");
    for (a, b) in lp.iter().zip(&ln) {
        assert!((a - b).abs() < 1e-2 * (1.0 + b.abs()), "{a} vs {b}");
    }
}

#[test]
fn cnn_small_round_trip() {
    let Some(model) = load("cnn_small") else { return };
    let mut state = model.init_state(&Hyper::default()).unwrap();
    let (x, y) = batch_for(&model, 13);
    let h = Hyper { lr: 0.001, opt: Opt::Adam, mode: Mode::Det, step: 1, ..Default::default() };
    let m = model.train_step(&mut state, &x, &y, &h).unwrap();
    assert!(m.loss.is_finite());
    let (lossv, _) = model.eval_batch(&state, &x, &y, &h).unwrap();
    assert_eq!(lossv.len(), model.info.batch);
}

#[test]
fn bad_input_sizes_error_cleanly() {
    let Some(model) = load("mlp") else { return };
    let mut state = model.init_state(&Hyper::default()).unwrap();
    let (x, y) = batch_for(&model, 14);
    let h = Hyper::default();
    assert!(model.train_step(&mut state, &x[..10], &y, &h).is_err());
    assert!(model.train_step(&mut state, &x, &y[..5], &h).is_err());
}

#[test]
fn snapshot_is_deep_copy() {
    let Some(model) = load("mlp") else { return };
    let mut state = model.init_state(&Hyper::default()).unwrap();
    let snap = state.snapshot().unwrap();
    let before = snap.params[0].to_vec::<f32>().unwrap();
    let (x, y) = batch_for(&model, 15);
    let h = Hyper { lr: 0.01, step: 1, ..Default::default() };
    model.train_step(&mut state, &x, &y, &h).unwrap();
    let after_live = state.params[0].to_vec::<f32>().unwrap();
    let after_snap = snap.params[0].to_vec::<f32>().unwrap();
    assert_ne!(before, after_live, "training should move params");
    assert_eq!(before, after_snap, "snapshot must not alias live state");
}
