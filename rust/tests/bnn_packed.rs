//! Property suite for the XNOR–popcount BNN engine (`binary/bnn.rs`).
//!
//! The engine's correctness rests on four claims, each pinned here:
//!
//! 1. **Integer exactness.** With ±1 activations, the float reference
//!    (sign-by-sign multiply-accumulate) produces exact small integers
//!    at every partial sum, so `k - 2*popcount(xor)` must match it
//!    *bit-for-bit* after the folded affine — not approximately.
//! 2. **Batch invariance.** A row's output never depends on the batch
//!    it was computed in (solo ≡ coalesced, the serving contract).
//! 3. **Ragged shapes.** `k % 64 != 0` and `n % 64 != 0` exercise the
//!    padding words; padding bits must stay zero and never leak into
//!    counts or packed outputs.
//! 4. **ISA equivalence.** Every `sign_xnor_dot` rung returns the same
//!    integer, so the `_isa`-pinned paths are bit-identical.

use binaryconnect::binary::bnn::{
    pack_rows_into, words_per_row, xnor_layer_bits, xnor_layer_bits_isa, xnor_layer_f32,
    xnor_layer_f32_isa, xnor_reference_preact,
};
use binaryconnect::binary::packed::{BitMatrix, PackedLayer, PackedMlp};
use binaryconnect::kernel::simd::{Isa, ALL_ISAS};
use binaryconnect::util::Rng;

fn rand_mat(r: usize, c: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..r * c).map(|_| rng.normal()).collect()
}

/// Random ±1 rows — exactly the value domain hidden activations live in.
fn sign_rows(b: usize, k: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..b * k).map(|_| if rng.normal() >= 0.0 { 1.0f32 } else { -1.0 }).collect()
}

/// A layer with mixed-sign scales (BN gammas can be negative) and
/// non-trivial shifts.
fn mk_layer(k: usize, n: usize, seed: u64, relu: bool) -> PackedLayer {
    let mut rng = Rng::new(seed);
    let w = rand_mat(k, n, seed + 1);
    PackedLayer {
        bits: BitMatrix::pack(&w, k, n),
        scale: (0..n).map(|_| 0.4 * rng.normal()).collect(),
        shift: (0..n).map(|_| 0.2 * rng.normal()).collect(),
        relu,
    }
}

/// Word-edge shapes: k and n both cross (or undershoot) 64-bit words.
const SHAPES: [(usize, usize); 5] = [(64, 64), (70, 33), (128, 10), (1, 5), (63, 127)];

#[test]
fn xnor_f32_layer_is_bit_identical_to_float_reference() {
    for (si, &(k, n)) in SHAPES.iter().enumerate() {
        for b in [1usize, 4] {
            let layer = mk_layer(k, n, 1000 + si as u64, false);
            let a = sign_rows(b, k, 2000 + si as u64);
            let mut abits = vec![0u64; b * words_per_row(k)];
            pack_rows_into(&a, b, k, &mut abits);
            let mut y = vec![0f32; b * n];
            xnor_layer_f32(&layer, &abits, b, &mut y);
            let mut yref = vec![0f32; b * n];
            xnor_reference_preact(&layer, &a, b, &mut yref);
            let yb: Vec<u32> = y.iter().map(|v| v.to_bits()).collect();
            let rb: Vec<u32> = yref.iter().map(|v| v.to_bits()).collect();
            assert_eq!(yb, rb, "xnor vs float reference differ (k={k} n={n} b={b})");
        }
    }
}

#[test]
fn xnor_bits_layer_matches_reference_signs_and_zeroes_padding() {
    for (si, &(k, n)) in SHAPES.iter().enumerate() {
        for b in [1usize, 3] {
            let layer = mk_layer(k, n, 3000 + si as u64, true);
            let a = sign_rows(b, k, 4000 + si as u64);
            let mut abits = vec![0u64; b * words_per_row(k)];
            pack_rows_into(&a, b, k, &mut abits);
            let wpo = words_per_row(n);
            // pre-poison the output buffer: every word must be fully
            // (re)written, padding bits included
            let mut obits = vec![u64::MAX; b * wpo];
            xnor_layer_bits(&layer, &abits, b, &mut obits);
            let mut yref = vec![0f32; b * n];
            xnor_reference_preact(&layer, &a, b, &mut yref);
            for bi in 0..b {
                for j in 0..n {
                    let bit = (obits[bi * wpo + j / 64] >> (j % 64)) & 1;
                    let want = u64::from(yref[bi * n + j] >= 0.0);
                    assert_eq!(bit, want, "unit ({bi},{j}) sign (k={k} n={n})");
                }
                if n % 64 != 0 {
                    let pad = obits[bi * wpo + wpo - 1] >> (n % 64);
                    assert_eq!(pad, 0, "padding bits must be zero (row {bi}, n={n})");
                }
            }
        }
    }
}

#[test]
fn pack_rows_treats_negative_zero_as_plus_one() {
    // sign(0) = +1 per Eq. 1 of the paper; -0.0 >= 0.0 in IEEE, so both
    // zeros land on the +1 side — same convention as the weight packer.
    let x = [-0.0f32, 0.0, -1.0, 1.0, f32::MIN_POSITIVE, -f32::MIN_POSITIVE];
    let mut bits = vec![0u64; 1];
    pack_rows_into(&x, 1, x.len(), &mut bits);
    assert_eq!(bits[0], 0b011011, "bits: +0,-0,+1 set; -1 and -eps clear");

    // and a layer fed ±0.0-swapped activations must not notice
    let (k, n) = (70usize, 33usize);
    let layer = mk_layer(k, n, 7000, false);
    let mut a = sign_rows(1, k, 7001);
    let mut a2 = a.clone();
    a[0] = 0.0;
    a2[0] = -0.0;
    let mut b1 = vec![0u64; words_per_row(k)];
    let mut b2 = vec![0u64; words_per_row(k)];
    pack_rows_into(&a, 1, k, &mut b1);
    pack_rows_into(&a2, 1, k, &mut b2);
    assert_eq!(b1, b2, "+0.0 and -0.0 must pack identically");
    let mut y1 = vec![0f32; n];
    let mut y2 = vec![0f32; n];
    xnor_layer_f32(&layer, &b1, 1, &mut y1);
    xnor_layer_f32(&layer, &b2, 1, &mut y2);
    assert_eq!(y1, y2);
}

#[test]
fn every_isa_rung_is_bit_identical() {
    let (k, n) = (257usize, 66usize); // ragged words on both sides
    let b = 5;
    let layer = mk_layer(k, n, 5000, true);
    let a = sign_rows(b, k, 5001);
    let mut abits = vec![0u64; b * words_per_row(k)];
    pack_rows_into(&a, b, k, &mut abits);
    let wpo = words_per_row(n);
    let mut bits_ref = vec![0u64; b * wpo];
    xnor_layer_bits_isa(Isa::Scalar, &layer, &abits, b, &mut bits_ref);
    let mut y_ref = vec![0f32; b * n];
    xnor_layer_f32_isa(Isa::Scalar, &layer, &abits, b, &mut y_ref);
    for &isa in ALL_ISAS {
        if !isa.supported() {
            continue;
        }
        let mut bits = vec![0u64; b * wpo];
        xnor_layer_bits_isa(isa, &layer, &abits, b, &mut bits);
        assert_eq!(bits, bits_ref, "{}: bit layer diverged from scalar", isa.name());
        let mut y = vec![0f32; b * n];
        xnor_layer_f32_isa(isa, &layer, &abits, b, &mut y);
        let yb: Vec<u32> = y.iter().map(|v| v.to_bits()).collect();
        let rb: Vec<u32> = y_ref.iter().map(|v| v.to_bits()).collect();
        assert_eq!(yb, rb, "{}: f32 layer diverged from scalar", isa.name());
    }
}

/// Word-edge 3-layer net: 12 -> 70 -> 33 -> 4, BN on the hidden layers.
fn toy_mlp(seed: u64) -> PackedMlp {
    let w1 = rand_mat(12, 70, seed);
    let w2 = rand_mat(70, 33, seed + 1);
    let w3 = rand_mat(33, 4, seed + 2);
    let mut rng = Rng::new(seed + 3);
    type Bn = Option<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)>;
    let bn = |n: usize, r: &mut Rng| -> Bn {
        Some((
            (0..n).map(|_| 1.0 + 0.1 * r.normal()).collect(),
            (0..n).map(|_| 0.1 * r.normal()).collect(),
            (0..n).map(|_| 0.2 * r.normal()).collect(),
            (0..n).map(|_| (1.0 + 0.1 * r.normal()).abs()).collect(),
        ))
    };
    PackedMlp::build(
        vec![(w1, 12, 70), (w2, 70, 33), (w3, 33, 4)],
        vec![bn(70, &mut rng), bn(33, &mut rng), None],
        Some(vec![0.05, -0.05, 0.0, 0.02]),
    )
}

#[test]
fn forward_bnn_rows_bit_identical_across_batch_sizes() {
    // the serving exactness contract, bnn edition: solo == coalesced
    let mlp = toy_mlp(90);
    let b = 8;
    let x = rand_mat(b, mlp.in_dim, 91);
    let mut ws = mlp.bnn_workspace(b);
    let full = mlp.forward_bnn_into(&x, b, &mut ws).to_vec();
    for bi in 0..b {
        let row = &x[bi * mlp.in_dim..(bi + 1) * mlp.in_dim];
        let solo = mlp.forward_bnn_into(row, 1, &mut ws).to_vec();
        assert_eq!(
            solo,
            full[bi * mlp.classes..(bi + 1) * mlp.classes].to_vec(),
            "row {bi}: solo != coalesced in bnn mode"
        );
    }
    // ragged split 3 + 5
    let cut = 3 * mlp.in_dim;
    let head = mlp.forward_bnn_into(&x[..cut], 3, &mut ws).to_vec();
    let tail = mlp.forward_bnn_into(&x[cut..], 5, &mut ws).to_vec();
    let mut joined = head;
    joined.extend(tail);
    assert_eq!(joined, full, "3+5 split != coalesced batch of 8 in bnn mode");
}

#[test]
fn forward_bnn_isa_pins_are_bit_identical() {
    let mlp = toy_mlp(95);
    let b = 6;
    let x = rand_mat(b, mlp.in_dim, 96);
    let mut ws = mlp.bnn_workspace(b);
    let scalar = mlp.forward_bnn_into_isa(Isa::Scalar, &x, b, &mut ws).to_vec();
    for &isa in ALL_ISAS {
        if !isa.supported() {
            continue;
        }
        let got = mlp.forward_bnn_into_isa(isa, &x, b, &mut ws).to_vec();
        let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
        let sb: Vec<u32> = scalar.iter().map(|v| v.to_bits()).collect();
        assert_eq!(gb, sb, "{}: bnn forward diverged from scalar", isa.name());
    }
}

#[test]
fn forward_bnn_equals_manual_layer_composition() {
    // the wired pipeline (escape hatch -> pack -> xnor bits -> xnor f32)
    // recomposed by hand from the public pieces must give the same bits
    let mlp = toy_mlp(120);
    let b = 4;
    let x = rand_mat(b, mlp.in_dim, 121);
    let mut ws = mlp.bnn_workspace(b);
    let got = mlp.forward_bnn_into(&x, b, &mut ws).to_vec();

    let l0 = &mlp.layers[0];
    let n0 = l0.bits.n;
    let mut h0 = vec![0f32; b * n0];
    let mut xt = vec![0f32; b * mlp.in_dim];
    let mut totals = vec![0f32; b];
    l0.bits.matmul_scaled_into_batched(&x, b, 1.0, &mut h0, &mut xt, &mut totals);
    for row in h0.chunks_exact_mut(n0) {
        for ((v, &s), &t) in row.iter_mut().zip(&l0.scale).zip(&l0.shift) {
            *v = *v * s + t; // affine only — sign replaces ReLU in bnn mode
        }
    }
    let mut bits = vec![0u64; b * words_per_row(n0)];
    pack_rows_into(&h0, b, n0, &mut bits);
    let l1 = &mlp.layers[1];
    let mut bits2 = vec![0u64; b * words_per_row(l1.bits.n)];
    xnor_layer_bits(l1, &bits, b, &mut bits2);
    let l2 = &mlp.layers[2];
    let mut want = vec![0f32; b * mlp.classes];
    xnor_layer_f32(l2, &bits2, b, &mut want);
    assert_eq!(got, want, "forward_bnn_into != manual composition");
}

#[test]
fn bnn_logits_are_finite_and_shaped() {
    // sanity, not exactness: bnn and packed-f32 are different functions
    // by design (sign vs relu hidden nonlinearity), so there is no
    // cross-mode equality to pin — only shape and finiteness.
    let mlp = toy_mlp(130);
    let b = 16;
    let x = rand_mat(b, mlp.in_dim, 131);
    let mut bws = mlp.bnn_workspace(b);
    let logits = mlp.forward_bnn_into(&x, b, &mut bws);
    assert_eq!(logits.len(), b * mlp.classes);
    assert!(logits.iter().all(|v| v.is_finite()), "bnn logits must be finite");
}
