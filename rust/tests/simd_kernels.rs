//! SIMD rungs ≡ scalar twins, property-tested across every ISA the host
//! can run (the scalar arm is pinned end-to-end by the CI lane that sets
//! `BCRUN_SIMD=scalar` for the whole suite).
//!
//! Contracts (see `kernel/simd` module docs):
//! * f32 GEMM trio: every rung agrees with the scalar kernels within a
//!   1e-5-scale bound (FMA/wide accumulators reorder the f32 sums, so the
//!   bound scales with the L1 mass of each output element).
//! * batched packed sign-GEMM (forward + STE transpose-apply): **bit
//!   exact** across rungs — SIMD lanes are batch columns, so per-column
//!   reduction order is identical by construction.
//! * batch-1 packed forward (`sign_dot`): the XOR sign-flip kernel agrees
//!   with the scalar selected-sum within the 1e-5-scale bound.
//!
//! Shapes are biased onto the lane/word boundaries (multiples of 8 and 64
//! ± 1), batch 1, and ±0.0 inputs — exactly where tail handling breaks.

use binaryconnect::binary::packed::BitMatrix;
use binaryconnect::kernel;
use binaryconnect::kernel::simd::{self, Isa, ALL_ISAS};
use binaryconnect::prop::check;
use binaryconnect::util::Rng;

/// Every rung this host can actually execute (always includes scalar).
fn arms() -> Vec<Isa> {
    ALL_ISAS.into_iter().filter(|i| i.supported()).collect()
}

/// A dimension biased onto SIMD lane / bit-word edges.
fn edge_dim(r: &mut Rng, word: usize, max: usize) -> usize {
    match r.below(4) {
        0 => word * (1 + r.below(3)),
        1 => (word * (1 + r.below(3))).saturating_sub(1).max(1),
        2 => word * (1 + r.below(3)) + 1,
        _ => 1 + r.below(max),
    }
}

/// Values with zeros (both signs) mixed in, the packed/zero-skip edges.
fn signed_vals(r: &mut Rng, len: usize) -> Vec<f32> {
    (0..len)
        .map(|_| match r.below(8) {
            0 => 0.0f32,
            1 => -0.0f32,
            _ => r.normal(),
        })
        .collect()
}

/// |got - want| <= 1e-5 * (1 + l1) per element, l1 the L1 mass of the
/// element's products (the numerically meaningful reordering bound).
fn close_l1(name: &str, got: &[f32], want: &[f32], l1: &[f32]) -> Result<(), String> {
    for (i, ((&g, &w), &m)) in got.iter().zip(want).zip(l1).enumerate() {
        if (g - w).abs() > 1e-5 * (1.0 + m.abs()) {
            return Err(format!("{name}[{i}]: {g} vs {w} (l1 {m})"));
        }
    }
    Ok(())
}

#[test]
fn every_env_arm_is_reachable_and_resolves() {
    let arms = arms();
    assert!(arms.contains(&Isa::Scalar));
    #[cfg(target_arch = "x86_64")]
    assert!(arms.contains(&Isa::Sse2), "SSE2 is baseline on x86_64");
    // whatever BCRUN_SIMD says for this test process, it resolves to a
    // rung this host can run, and that is what the dispatcher selected
    let resolved = simd::resolve_env().expect("BCRUN_SIMD must be valid in the test env");
    assert!(resolved.supported());
    assert_eq!(simd::active(), resolved);
    assert!(arms.contains(&simd::active()));
}

#[test]
fn prop_gemm_trio_simd_matches_scalar_within_1e5() {
    check(
        "gemm trio: SIMD == scalar (1e-5 scale)",
        |r| {
            let m = 1 + r.below(12); // includes batch 1
            let k = edge_dim(r, 8, 150);
            let n = edge_dim(r, 8, 120);
            let a = signed_vals(r, m * k);
            let b = signed_vals(r, k * n);
            (m, k, n, a, b)
        },
        |(m, k, n, a, b)| {
            let (m, k, n) = (*m, *k, *n);
            let absa: Vec<f32> = a.iter().map(|v| v.abs()).collect();
            let absb: Vec<f32> = b.iter().map(|v| v.abs()).collect();
            // C = A·B
            let mut want = vec![0f32; m * n];
            kernel::gemm_with(Isa::Scalar, a, b, m, k, n, &mut want);
            let mut l1 = vec![0f32; m * n];
            kernel::gemm_with(Isa::Scalar, &absa, &absb, m, k, n, &mut l1);
            for &isa in arms().iter().filter(|i| **i != Isa::Scalar) {
                let mut got = vec![0f32; m * n];
                kernel::gemm_with(isa, a, b, m, k, n, &mut got);
                close_l1(&format!("gemm/{}", isa.name()), &got, &want, &l1)?;
            }
            // C = A^T·B (B reinterpreted as m x n)
            let b2 = &b[..(m * n).min(b.len())];
            if b2.len() == m * n {
                let absb2: Vec<f32> = b2.iter().map(|v| v.abs()).collect();
                let mut want = vec![0f32; k * n];
                kernel::gemm_at_b_with(Isa::Scalar, a, b2, m, k, n, &mut want);
                let mut l1 = vec![0f32; k * n];
                kernel::gemm_at_b_with(Isa::Scalar, &absa, &absb2, m, k, n, &mut l1);
                for &isa in arms().iter().filter(|i| **i != Isa::Scalar) {
                    let mut got = vec![0f32; k * n];
                    kernel::gemm_at_b_with(isa, a, b2, m, k, n, &mut got);
                    close_l1(&format!("at_b/{}", isa.name()), &got, &want, &l1)?;
                }
            }
            // C = A·B^T (A reinterpreted as m x n via a2, B as k x n)
            let a2: Vec<f32> = (0..m * n).map(|i| a[i % a.len()]).collect();
            let absa2: Vec<f32> = a2.iter().map(|v| v.abs()).collect();
            let mut want = vec![0f32; m * k];
            kernel::gemm_a_bt_with(Isa::Scalar, &a2, b, m, n, k, &mut want);
            let mut l1 = vec![0f32; m * k];
            kernel::gemm_a_bt_with(Isa::Scalar, &absa2, &absb, m, n, k, &mut l1);
            for &isa in arms().iter().filter(|i| **i != Isa::Scalar) {
                let mut got = vec![0f32; m * k];
                kernel::gemm_a_bt_with(isa, &a2, b, m, n, k, &mut got);
                close_l1(&format!("a_bt/{}", isa.name()), &got, &want, &l1)?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batched_sign_gemm_bit_exact_across_arms() {
    check(
        "packed forward: SIMD bit-exact vs scalar",
        |r| {
            // b straddles the per-rung batch chunks (64 on avx2, 128 on
            // scalar/sse2) and the 8-lane groups; k straddles the words.
            let b = 2 + r.below(140);
            let k = edge_dim(r, 64, 200);
            let n = 1 + r.below(16);
            let w = signed_vals(r, k * n);
            let x = signed_vals(r, b * k);
            (b, k, n, w, x)
        },
        |(b, k, n, w, x)| {
            let (b, k, n) = (*b, *k, *n);
            let bm = BitMatrix::pack(w, k, n);
            let scale = 0.37f32;
            let mut xt = vec![0f32; k * b];
            let mut totals = vec![0f32; b];
            let mut want = vec![0f32; b * n];
            bm.matmul_scaled_into_isa(Isa::Scalar, x, b, scale, &mut want, &mut xt, &mut totals);
            for &isa in arms().iter().filter(|i| **i != Isa::Scalar) {
                let mut got = vec![0f32; b * n];
                bm.matmul_scaled_into_isa(isa, x, b, scale, &mut got, &mut xt, &mut totals);
                let name = isa.name();
                for (i, (g, wv)) in got.iter().zip(&want).enumerate() {
                    if g.to_bits() != wv.to_bits() {
                        return Err(format!("{name} not bit-exact at {i}: {g:?} vs {wv:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tmatmul_bit_exact_across_arms() {
    check(
        "packed STE backward: SIMD bit-exact vs scalar",
        |r| {
            let b = 1 + r.below(70);
            let k = edge_dim(r, 64, 200);
            let n = 1 + r.below(16);
            let w = signed_vals(r, k * n);
            let dz = signed_vals(r, b * n);
            (b, k, n, w, dz)
        },
        |(b, k, n, w, dz)| {
            let (b, k, n) = (*b, *k, *n);
            let bm = BitMatrix::pack(w, k, n);
            let scale = 0.53f32;
            let mut dzt = vec![0f32; n * b];
            let mut acc = vec![0f32; k * b];
            let mut totals = vec![0f32; b];
            let mut want = vec![0f32; b * k];
            bm.tmatmul_scaled_into_isa(
                Isa::Scalar,
                dz,
                b,
                scale,
                &mut want,
                &mut dzt,
                &mut acc,
                &mut totals,
            );
            for &isa in arms().iter().filter(|i| **i != Isa::Scalar) {
                let mut got = vec![0f32; b * k];
                bm.tmatmul_scaled_into_isa(
                    isa,
                    dz,
                    b,
                    scale,
                    &mut got,
                    &mut dzt,
                    &mut acc,
                    &mut totals,
                );
                let name = isa.name();
                for (i, (g, wv)) in got.iter().zip(&want).enumerate() {
                    if g.to_bits() != wv.to_bits() {
                        return Err(format!("{name} not bit-exact at {i}: {g:?} vs {wv:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batch1_sign_dot_matches_scalar_within_1e5() {
    check(
        "packed batch-1 forward: SIMD == scalar (1e-5 scale)",
        |r| {
            let k = edge_dim(r, 64, 300);
            let n = 1 + r.below(12);
            // bias some columns fully positive so the scalar u64::MAX
            // fast path is exercised against the XOR kernel
            let all_pos = r.below(3) == 0;
            let w: Vec<f32> = (0..k * n)
                .map(|_| if all_pos { r.normal().abs() } else { r.normal() })
                .collect();
            let x = signed_vals(r, k);
            (k, n, w, x)
        },
        |(k, n, w, x)| {
            let (k, n) = (*k, *n);
            let bm = BitMatrix::pack(w, k, n);
            let scale = 0.7f32;
            let mut xt = vec![0f32; k];
            let mut totals = vec![0f32; 1];
            let mut want = vec![0f32; n];
            bm.matmul_scaled_into_isa(Isa::Scalar, x, 1, scale, &mut want, &mut xt, &mut totals);
            let l1: f32 = x.iter().map(|v| v.abs()).sum();
            for &isa in arms().iter().filter(|i| **i != Isa::Scalar) {
                let mut got = vec![0f32; n];
                bm.matmul_scaled_into_isa(isa, x, 1, scale, &mut got, &mut xt, &mut totals);
                let name = isa.name();
                for (j, (g, wv)) in got.iter().zip(&want).enumerate() {
                    if (g - wv).abs() > 1e-5 * (1.0 + scale * l1) {
                        return Err(format!("{name} col {j}: {g} vs {wv} (l1 {l1})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fixed_edge_shapes_stay_bit_exact() {
    // deterministic spot checks on the exact word/lane/chunk boundaries
    // (k = 63/64/65 bit-words; b on the 64- and 128-wide chunk edges and
    // 8-lane tails) — belt and braces on top of the biased property gens
    let mut rng = Rng::new(0xED6E);
    for &(b, k) in &[
        (2usize, 1usize),
        (7, 63),
        (8, 64),
        (9, 65),
        (63, 64),
        (64, 64),
        (65, 129),
        (100, 70),
        (127, 65),
        (128, 64),
        (129, 70),
    ] {
        let n = 5;
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..b * k).map(|_| rng.normal()).collect();
        let bm = BitMatrix::pack(&w, k, n);
        let mut xt = vec![0f32; k * b];
        let mut totals = vec![0f32; b];
        let mut want = vec![0f32; b * n];
        bm.matmul_scaled_into_isa(Isa::Scalar, &x, b, 1.0, &mut want, &mut xt, &mut totals);
        for &isa in arms().iter().filter(|i| **i != Isa::Scalar) {
            let mut got = vec![0f32; b * n];
            bm.matmul_scaled_into_isa(isa, &x, b, 1.0, &mut got, &mut xt, &mut totals);
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{} b={b} k={k}",
                isa.name()
            );
        }
    }
}
