//! Integration: the serving layer end to end, over real sockets.
//!
//! The headline property (ISSUE 5 acceptance): a request served solo and
//! the same request served inside a coalesced batch return bit-identical
//! predictions *through the HTTP layer* — JSON encode/decode included.
//! This holds because every batched forward takes the lane-batched packed
//! kernel (order per output element is batch-size invariant) and because
//! f32 -> shortest-repr decimal -> f64 -> f32 is lossless.

use std::sync::{Arc, Barrier};
use std::time::Duration;

use binaryconnect::binary::packed::PackedMlp;
use binaryconnect::binary::ForwardMode;
use binaryconnect::serve::loadgen::{predict_body, HttpClient};
use binaryconnect::serve::{self, ServeConfig};
use binaryconnect::util::{Json, Rng};

fn toy_mlp(seed: u64) -> PackedMlp {
    let mut rng = Rng::new(seed);
    let mut mat = |k: usize, n: usize| -> (Vec<f32>, usize, usize) {
        ((0..k * n).map(|_| rng.normal()).collect(), k, n)
    };
    let (w1, w2, w3) = (mat(12, 70), mat(70, 33), mat(33, 4));
    let mut bn = |n: usize| -> Option<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        Some((
            (0..n).map(|_| 1.0 + 0.05 * rng.normal()).collect(),
            (0..n).map(|_| 0.05 * rng.normal()).collect(),
            (0..n).map(|_| 0.1 * rng.normal()).collect(),
            (0..n).map(|_| (1.0 + 0.1 * rng.normal()).abs()).collect(),
        ))
    };
    let (bn1, bn2) = (bn(70), bn(33));
    PackedMlp::build(
        vec![w1, w2, w3],
        vec![bn1, bn2, None],
        Some(vec![0.02, -0.02, 0.0, 0.01]),
    )
}

fn rows(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| (0..dim).map(|_| rng.normal()).collect()).collect()
}

fn predict(client: &mut HttpClient, row: &[f32]) -> (u16, String) {
    let mut body = String::new();
    predict_body(&mut body, row);
    client.request("POST", "/predict", Some(&body)).unwrap()
}

/// Parse a 200 /predict body into (pred, logit bit patterns).
fn decode(body: &str) -> (usize, Vec<u64>) {
    let j = Json::parse(body).unwrap();
    let pred = j.get("pred").unwrap().as_usize().unwrap();
    let logits: Vec<u64> = j
        .get("logits")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap().to_bits())
        .collect();
    (pred, logits)
}

#[test]
fn solo_and_coalesced_predictions_are_bit_identical_over_http() {
    let n = 24;
    let xs = rows(n, 12, 500);

    // pass 1: a server that cannot coalesce (max_batch 1), sequential
    let mut server = serve::start(
        toy_mlp(77),
        ServeConfig { max_batch: 1, max_wait: Duration::ZERO, ..Default::default() },
    )
    .unwrap();
    let host = server.addr().to_string();
    let mut client = HttpClient::connect(&host).unwrap();
    let solo: Vec<(usize, Vec<u64>)> = xs
        .iter()
        .map(|x| {
            let (status, body) = predict(&mut client, x);
            assert_eq!(status, 200, "{body}");
            decode(&body)
        })
        .collect();
    drop(client);
    server.stop();

    // pass 2: a coalescing server hit by n concurrent clients
    let mut server = serve::start(
        toy_mlp(77),
        ServeConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(20),
            workers: n,
            conn_backlog: 2 * n,
            ..Default::default()
        },
    )
    .unwrap();
    let host = server.addr().to_string();
    let barrier = Arc::new(Barrier::new(n));
    let joins: Vec<_> = xs
        .iter()
        .map(|x| {
            let host = host.clone();
            let x = x.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut client = HttpClient::connect(&host).unwrap();
                barrier.wait();
                let (status, body) = predict(&mut client, &x);
                assert_eq!(status, 200, "{body}");
                let j = Json::parse(&body).unwrap();
                let batch = j.get("batch").unwrap().as_usize().unwrap();
                (decode(&body), batch)
            })
        })
        .collect();
    let mut coalesced = Vec::with_capacity(n);
    let mut batch_sizes = Vec::with_capacity(n);
    for j in joins {
        let (d, b) = j.join().unwrap();
        coalesced.push(d);
        batch_sizes.push(b);
    }
    let snap = server.metrics().snapshot(0);
    server.stop();

    for (i, (s, c)) in solo.iter().zip(&coalesced).enumerate() {
        assert_eq!(s, c, "row {i}: solo and coalesced responses differ at the bit level");
    }
    // all rows were served, in strictly fewer forwards than rows would
    // take uncoalesced is not guaranteed by timing — but every reply
    // reports a plausible batch size and the server accounted every row
    assert!(batch_sizes.iter().all(|&b| (1..=32).contains(&b)));
    assert_eq!(snap.get("rows").unwrap().as_usize(), Some(n));
    assert_eq!(snap.get("predictions").unwrap().as_usize(), Some(n));
}

#[test]
fn bnn_solo_and_coalesced_predictions_are_bit_identical_over_http() {
    // ISSUE 7 acceptance: the XNOR-popcount engine honors the same
    // solo == coalesced exactness contract as the packed-f32 path —
    // integer dots are batch-invariant, the per-unit affine is a fixed
    // f32 op sequence per row, and the first-layer escape hatch rides
    // the already-contracted lane-batched kernel.
    let n = 16;
    let xs = rows(n, 12, 900);

    // pass 1: bnn server that cannot coalesce, sequential requests
    let mut server = serve::start(
        toy_mlp(77),
        ServeConfig {
            max_batch: 1,
            max_wait: Duration::ZERO,
            mode: ForwardMode::Bnn,
            ..Default::default()
        },
    )
    .unwrap();
    let host = server.addr().to_string();

    // mode is visible on the health endpoint before any traffic
    let mut client = HttpClient::connect(&host).unwrap();
    let (status, body) = client.request("GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("mode").unwrap().as_str(), Some("bnn"));

    let solo: Vec<(usize, Vec<u64>)> = xs
        .iter()
        .map(|x| {
            let (status, body) = predict(&mut client, x);
            assert_eq!(status, 200, "{body}");
            decode(&body)
        })
        .collect();
    drop(client);
    server.stop();

    // pass 2: coalescing bnn server hit by n concurrent clients
    let mut server = serve::start(
        toy_mlp(77),
        ServeConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(20),
            workers: n,
            conn_backlog: 2 * n,
            mode: ForwardMode::Bnn,
            ..Default::default()
        },
    )
    .unwrap();
    let host = server.addr().to_string();
    let barrier = Arc::new(Barrier::new(n));
    let joins: Vec<_> = xs
        .iter()
        .map(|x| {
            let host = host.clone();
            let x = x.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut client = HttpClient::connect(&host).unwrap();
                barrier.wait();
                let (status, body) = predict(&mut client, &x);
                assert_eq!(status, 200, "{body}");
                decode(&body)
            })
        })
        .collect();
    let coalesced: Vec<(usize, Vec<u64>)> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let snap = server.metrics().snapshot(0);
    server.stop();

    for (i, (s, c)) in solo.iter().zip(&coalesced).enumerate() {
        assert_eq!(s, c, "row {i}: bnn solo and coalesced responses differ at the bit level");
    }
    assert_eq!(snap.get("rows").unwrap().as_usize(), Some(n));
    assert_eq!(snap.get("predictions").unwrap().as_usize(), Some(n));
}

#[test]
fn healthz_stats_errors_and_shutdown_endpoint() {
    let mut server = serve::start(
        toy_mlp(88),
        ServeConfig { max_batch: 8, max_wait: Duration::from_micros(100), ..Default::default() },
    )
    .unwrap();
    let host = server.addr().to_string();
    let mut client = HttpClient::connect(&host).unwrap();

    // healthz reports the model facts
    let (status, body) = client.request("GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(j.get("in_dim").unwrap().as_usize(), Some(12));
    assert_eq!(j.get("classes").unwrap().as_usize(), Some(4));

    // a good prediction
    let x = rows(1, 12, 600).remove(0);
    let (status, body) = predict(&mut client, &x);
    assert_eq!(status, 200, "{body}");
    let (pred, logits) = decode(&body);
    assert!(pred < 4);
    assert_eq!(logits.len(), 4);

    // client errors: wrong shape, bad json, bad route, bad method
    let (status, _) = client
        .request("POST", "/predict", Some(r#"{"x":[1,2,3]}"#))
        .unwrap();
    assert_eq!(status, 400);
    let (status, _) = client.request("POST", "/predict", Some("not json")).unwrap();
    assert_eq!(status, 400);
    let (status, _) = client.request("GET", "/nope", None).unwrap();
    assert_eq!(status, 404);
    let (status, _) = client.request("GET", "/predict", None).unwrap();
    assert_eq!(status, 404);

    // stats reflect the traffic so far
    let (status, body) = client.request("GET", "/stats", None).unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("predictions").unwrap().as_usize(), Some(1));
    assert_eq!(j.get("bad_requests").unwrap().as_usize(), Some(2));
    assert_eq!(j.get("not_found").unwrap().as_usize(), Some(2));
    assert!(j.get("latency_p99_us").unwrap().as_f64().unwrap() >= 0.0);

    // graceful shutdown over HTTP: the server acknowledges, drains and
    // stop() returns; afterwards new connections are refused eventually
    let (status, body) = client.request("POST", "/shutdown", None).unwrap();
    assert_eq!(status, 200, "{body}");
    assert!(server.is_shutdown());
    server.stop();
    // the listener is gone: a fresh connect + request must fail
    let refused = match HttpClient::connect(&host) {
        Err(_) => true,
        Ok(mut c) => c.request("GET", "/healthz", None).is_err(),
    };
    assert!(refused, "server still answering after drained shutdown");
}

#[test]
fn deadline_header_drives_shedding_and_stats_surface_supervision() {
    let mut server = serve::start(
        toy_mlp(88),
        ServeConfig { max_batch: 8, max_wait: Duration::from_micros(100), ..Default::default() },
    )
    .unwrap();
    let host = server.addr().to_string();
    let mut client = HttpClient::connect(&host).unwrap();
    let x = rows(1, 12, 650).remove(0);
    let mut body = String::new();
    predict_body(&mut body, &x);

    // an already-expired deadline is never served and never hangs: 503
    // at admission (the estimated wait alone exceeds a zero budget) or
    // 504 from the batcher if it slipped through
    let (status, text) = client
        .request_with_headers(
            "POST",
            "/predict",
            Some(&body),
            &[("X-Deadline-Ms", "0".to_string())],
        )
        .unwrap();
    assert!(status == 503 || status == 504, "expected shed, got {status}: {text}");
    // shed responses carry a Retry-After hint for well-behaved clients
    assert_eq!(client.last_retry_after(), Some(1));

    // a generous deadline serves normally
    let (status, text) = client
        .request_with_headers(
            "POST",
            "/predict",
            Some(&body),
            &[("X-Deadline-Ms", "10000".to_string())],
        )
        .unwrap();
    assert_eq!(status, 200, "{text}");
    assert_eq!(client.last_retry_after(), None);

    // a garbage header value is a client error, not a panic or a hang
    let (status, _) = client
        .request_with_headers(
            "POST",
            "/predict",
            Some(&body),
            &[("X-Deadline-Ms", "soon".to_string())],
        )
        .unwrap();
    assert_eq!(status, 400);
    // a parse-level 400 closes the connection by design
    let mut client = HttpClient::connect(&host).unwrap();

    // the supervision counters exist on /stats from the first scrape
    let (status, body) = client.request("GET", "/stats", None).unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("worker_restarts").unwrap().as_usize(), Some(0));
    assert_eq!(j.get("batcher_restarts").unwrap().as_usize(), Some(0));
    assert!(j.get("deadline_sheds_504").unwrap().as_usize().unwrap() <= 1);
    assert!(j.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0);

    // /healthz mirrors them, plus the configured default deadline
    let (status, body) = client.request("GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("worker_restarts").unwrap().as_usize(), Some(0));
    assert_eq!(j.get("default_deadline_ms").unwrap().as_usize(), Some(0));
    assert!(j.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
    server.stop();
}

#[test]
fn overload_answers_503_and_recovers() {
    // queue_cap 2 with a long batching window (max_batch 8 keeps the
    // batcher waiting for more rows): two rows park in the queue, the
    // third submit must be shed with 503
    let mut server = serve::start(
        toy_mlp(99),
        ServeConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(400),
            queue_cap: 2,
            workers: 4,
            ..Default::default()
        },
    )
    .unwrap();
    let host = server.addr().to_string();
    let xs = rows(3, 12, 700);

    // park two requests inside the batching window
    let blocked: Vec<_> = xs[..2]
        .iter()
        .map(|x| {
            let host = host.clone();
            let x = x.clone();
            std::thread::spawn(move || {
                let mut c = HttpClient::connect(&host).unwrap();
                predict(&mut c, &x).0
            })
        })
        .collect();
    // wait until both rows are parked in the queue (observable via
    // /stats) before overflowing it; on a pathologically slow run they
    // may already have been answered, which degrades the assertion to
    // "200 or 503, never a hang or another 5xx"
    let mut c = HttpClient::connect(&host).unwrap();
    for _ in 0..200 {
        let (_, body) = c.request("GET", "/stats", None).unwrap();
        let j = Json::parse(&body).unwrap();
        let depth = j.get("queue_depth").unwrap().as_usize().unwrap();
        let preds = j.get("predictions").unwrap().as_usize().unwrap();
        if depth >= 2 || preds >= 2 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let (status, body) = predict(&mut c, &xs[2]);
    assert!(
        status == 503 || status == 200,
        "expected shed (503) or served (200), got {status}: {body}"
    );
    for j in blocked {
        assert_eq!(j.join().unwrap(), 200);
    }
    // after the window clears, the same request succeeds: overload is
    // transient by contract
    let (status, _) = predict(&mut c, &xs[2]);
    assert_eq!(status, 200);
    server.stop();
}

#[test]
fn many_sequential_requests_on_one_connection_reuse_it() {
    // keep-alive: 50 round trips over a single connection
    let mut server = serve::start(toy_mlp(111), ServeConfig::default()).unwrap();
    let host = server.addr().to_string();
    let mut client = HttpClient::connect(&host).unwrap();
    let xs = rows(50, 12, 800);
    for x in &xs {
        let (status, _) = predict(&mut client, x);
        assert_eq!(status, 200);
    }
    let snap = server.metrics().snapshot(0);
    server.stop();
    assert_eq!(snap.get("predictions").unwrap().as_usize(), Some(50));
}
