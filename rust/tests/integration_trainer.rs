//! End-to-end coordinator tests: full training runs over the real stack
//! (synthetic data -> pipeline -> Executor train/eval -> model selection),
//! on the pure-Rust reference backend — no artifacts needed.

use binaryconnect::coordinator::{train, trials, LrSchedule, TrainOpts};
use binaryconnect::data::{synth::synth_mnist, SplitData};
use binaryconnect::preprocess::Standardizer;
use binaryconnect::runtime::{Executor, Mode, Opt, ReferenceExecutor};

fn mlp() -> ReferenceExecutor {
    ReferenceExecutor::builtin("mlp").unwrap()
}

fn small_data(n_train: usize, n_test: usize, seed: u64) -> SplitData {
    let mut train = synth_mnist(n_train, seed);
    let mut test = synth_mnist(n_test, seed + 1);
    let st = Standardizer::fit(&train);
    st.apply(&mut train);
    st.apply(&mut test);
    SplitData::from_train_test(train, test, n_train / 6)
}

fn opts(mode: Mode, epochs: usize) -> TrainOpts {
    TrainOpts {
        epochs,
        schedule: LrSchedule::Exponential { start: 0.002, end: 0.0004, epochs },
        mode,
        opt: Opt::Adam,
        seed: 42,
        verbose: false,
        ..Default::default()
    }
}

#[test]
fn det_bc_learns_synthetic_mnist() {
    let model = mlp();
    let data = small_data(1200, 300, 5);
    let r = train(&model, &data, &opts(Mode::Det, 10)).unwrap();
    assert_eq!(r.curves.len(), 10);
    assert!(r.best_val_err < 0.5, "val err {}", r.best_val_err);
    assert!(r.test_err < 0.6, "test err {}", r.test_err);
    // training cost decreased
    let first = r.curves.first().unwrap().train_loss;
    let last = r.curves.last().unwrap().train_loss;
    assert!(last < first, "loss {first} -> {last}");
    assert_eq!(r.steps, 10 * (1000 / model.info().batch));
}

#[test]
fn bc_raises_training_cost_vs_baseline() {
    // Fig. 3's qualitative claim: BC behaves like a regularizer — the
    // training cost stays higher than the unregularized baseline.
    let model = mlp();
    let data = small_data(1200, 300, 6);
    let base = train(&model, &data, &opts(Mode::None, 6)).unwrap();
    let bc = train(&model, &data, &opts(Mode::Det, 6)).unwrap();
    let b_loss = base.curves.last().unwrap().train_loss;
    let c_loss = bc.curves.last().unwrap().train_loss;
    assert!(
        b_loss < c_loss,
        "expected baseline train cost {b_loss} < BC {c_loss}"
    );
}

#[test]
fn early_stopping_respects_patience() {
    let model = mlp();
    let data = small_data(600, 100, 7);
    let mut o = opts(Mode::Det, 60);
    o.patience = 2;
    let r = train(&model, &data, &o).unwrap();
    if r.curves.len() < 60 {
        // stopped early: best epoch is at least `patience` before the end
        assert!(r.curves.len() - 1 - r.best_epoch >= 2);
    }
}

#[test]
fn trials_aggregate_mean_std() {
    let model = mlp();
    let data = small_data(600, 150, 8);
    let s = trials(&model, &data, &opts(Mode::Det, 4), 3).unwrap();
    assert_eq!(s.test_errs.len(), 3);
    let lo = s.test_errs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = s.test_errs.iter().cloned().fold(0.0, f64::max);
    assert!(s.mean >= lo && s.mean <= hi);
    assert!(s.std >= 0.0);
}

#[test]
fn curves_record_decaying_lr() {
    let model = mlp();
    let data = small_data(600, 100, 9);
    let r = train(&model, &data, &opts(Mode::Det, 5)).unwrap();
    for (e, rec) in r.curves.iter().enumerate() {
        assert_eq!(rec.epoch, e);
        if e > 0 {
            assert!(rec.lr < r.curves[e - 1].lr, "lr must decay");
        }
    }
}

#[test]
fn test_err_reported_at_best_val_epoch() {
    let model = mlp();
    let data = small_data(900, 200, 10);
    let r = train(&model, &data, &opts(Mode::Det, 8)).unwrap();
    let best = r
        .curves
        .iter()
        .map(|c| c.val_err)
        .fold(f64::INFINITY, f64::min);
    assert_eq!(r.best_val_err, best);
    assert!(r.test_err.is_finite());
}

#[test]
fn dropout_regime_runs_end_to_end() {
    let model = mlp();
    let data = small_data(600, 100, 11);
    let mut o = opts(Mode::None, 3);
    o.dropout = 0.5;
    o.in_dropout = 0.2;
    let r = train(&model, &data, &o).unwrap();
    assert_eq!(r.curves.len(), 3);
    assert!(r.curves.iter().all(|c| c.train_loss.is_finite()));
}
