//! Chaos integration: the serving layer under deterministic fault
//! injection, over real sockets.
//!
//! The headline property (ISSUE 8 acceptance): with workers and the
//! batcher panicking on seeded schedules, every accepted request is
//! answered with 200/500/503/504 — never a silently dropped connection —
//! the process never exits, the `/stats` restart counters equal the
//! injected panic counts *exactly* (the injector and the supervisor
//! count the same events), and the answers that do come back stay
//! bit-identical to an unfaulted reference in both serving modes.

use std::sync::{Arc, Once};
use std::time::Duration;

use binaryconnect::binary::packed::PackedMlp;
use binaryconnect::binary::ForwardMode;
use binaryconnect::serve::loadgen::{self, predict_body, HttpClient, LoadgenOpts};
use binaryconnect::serve::{self, ServeConfig};
use binaryconnect::util::{FaultPlan, Json, Rng};

/// Injected panics are expected noise; a chaos run would otherwise spew
/// hundreds of backtraces. Forward every *other* panic to the default
/// hook so a real bug still prints.
fn quiet_injected_panics() {
    static QUIET: Once = Once::new();
    QUIET.call_once(|| {
        let default = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let payload = info.payload();
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_default();
            if !msg.starts_with("fault injection:") {
                default(info);
            }
        }));
    });
}

fn toy_mlp(seed: u64) -> PackedMlp {
    let mut rng = Rng::new(seed);
    let mut mat = |k: usize, n: usize| -> (Vec<f32>, usize, usize) {
        ((0..k * n).map(|_| rng.normal()).collect(), k, n)
    };
    let (w1, w2, w3) = (mat(12, 70), mat(70, 33), mat(33, 4));
    let mut bn = |n: usize| -> Option<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        Some((
            (0..n).map(|_| 1.0 + 0.05 * rng.normal()).collect(),
            (0..n).map(|_| 0.05 * rng.normal()).collect(),
            (0..n).map(|_| 0.1 * rng.normal()).collect(),
            (0..n).map(|_| (1.0 + 0.1 * rng.normal()).abs()).collect(),
        ))
    };
    let (bn1, bn2) = (bn(70), bn(33));
    PackedMlp::build(
        vec![w1, w2, w3],
        vec![bn1, bn2, None],
        Some(vec![0.02, -0.02, 0.0, 0.01]),
    )
}

fn row(dim: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..dim).map(|_| rng.normal()).collect()
}

/// Server logits from a 200 body as f32 bit patterns (the wire format is
/// shortest-repr f32, so f64-parse + cast back is lossless).
fn logits_bits(body: &str) -> Vec<u32> {
    let j = Json::parse(body).unwrap();
    j.get("logits")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| (v.as_f64().unwrap() as f32).to_bits())
        .collect()
}

fn stats(host: &str) -> Json {
    let mut c = HttpClient::connect(host).unwrap();
    let (status, body) = c.request("GET", "/stats", None).unwrap();
    assert_eq!(status, 200);
    Json::parse(&body).unwrap()
}

#[test]
fn every_worker_panic_is_answered_with_500_and_counted() {
    quiet_injected_panics();
    // p=1: every /predict panics its worker mid-request
    let plan = Arc::new(FaultPlan::parse("panic_worker@1", 7).unwrap());
    let mut server = serve::start(
        toy_mlp(77),
        ServeConfig {
            workers: 2,
            faults: Some(Arc::clone(&plan)),
            ..Default::default()
        },
    )
    .unwrap();
    let host = server.addr().to_string();
    let x = row(12, 600);
    let mut body = String::new();
    predict_body(&mut body, &x);
    for i in 0..5 {
        // the supervisor answers on the panicked connection then closes
        // it, so each request takes a fresh connection
        let mut c = HttpClient::connect(&host).unwrap();
        let (status, text) = c.request("POST", "/predict", Some(&body)).unwrap();
        assert_eq!(status, 500, "request {i}: {text}");
        assert!(text.contains("panicked"), "request {i}: {text}");
    }
    // non-inject routes are unaffected: the pool survived 5 panics
    let mut c = HttpClient::connect(&host).unwrap();
    let (status, _) = c.request("GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    let snap = stats(&host);
    assert_eq!(snap.get("worker_restarts").unwrap().as_usize(), Some(5));
    assert_eq!(plan.injected_worker_panics(), 5);
    server.stop();
}

#[test]
fn batcher_panics_fail_held_rows_and_the_batcher_respawns() {
    quiet_injected_panics();
    // p=1: every non-empty batch panics the batcher before the forward
    let plan = Arc::new(FaultPlan::parse("panic_batcher@1", 11).unwrap());
    let mut server = serve::start(
        toy_mlp(77),
        ServeConfig {
            max_batch: 4,
            max_wait: Duration::from_micros(100),
            faults: Some(Arc::clone(&plan)),
            ..Default::default()
        },
    )
    .unwrap();
    let host = server.addr().to_string();
    let x = row(12, 601);
    let mut body = String::new();
    predict_body(&mut body, &x);
    let mut client = HttpClient::connect(&host).unwrap();
    for i in 0..4 {
        // held rows are failed (500), never dropped: the request always
        // gets an answer, on the same keep-alive connection
        let (status, text) = client.request("POST", "/predict", Some(&body)).unwrap();
        assert_eq!(status, 500, "request {i}: {text}");
        assert!(text.contains("batcher aborted"), "request {i}: {text}");
    }
    let snap = stats(&host);
    let restarts = snap.get("batcher_restarts").unwrap().as_usize().unwrap();
    assert_eq!(restarts as u64, plan.injected_batcher_panics());
    assert!(restarts >= 4, "4 one-row batches must mean >= 4 respawns, got {restarts}");
    // the respawned batcher (fresh workspace) still drains a clean stop
    let (status, _) = client.request("GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    server.stop();
}

/// The full chaos property for one serving mode: probabilistic worker +
/// batcher panics and slow batches; a retrying closed loop must land
/// every request (zero lost), restart counters must equal injected
/// counts exactly, and surviving answers must be bit-identical to the
/// unfaulted reference network.
fn chaos_mode(mode: ForwardMode, loadgen_seed: u64) {
    quiet_injected_panics();
    let plan = Arc::new(
        FaultPlan::parse("panic_worker@0.05,panic_batcher@0.04,slow_batch=1ms@0.1", 2024).unwrap(),
    );
    let mut server = serve::start(
        toy_mlp(77),
        ServeConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(300),
            workers: 8,
            queue_cap: 256,
            mode,
            default_deadline: Some(Duration::from_secs(5)),
            faults: Some(Arc::clone(&plan)),
            ..Default::default()
        },
    )
    .unwrap();
    let host = server.addr().to_string();

    // closed loop under chaos: every ticket must eventually land
    let n = 250;
    let rep = loadgen::run(&LoadgenOpts {
        host: host.clone(),
        concurrency: 6,
        requests: n,
        seed: loadgen_seed,
        retries: 40,
    })
    .unwrap();
    assert_eq!(rep.sent, n);
    assert_eq!(rep.ok, n, "lost requests: {} non-2xx, {} errors", rep.failed_status, rep.errors);
    assert_eq!(rep.failed_status, 0);
    assert_eq!(rep.errors, 0);

    // within-mode exactness survives the chaos: a fixed row answered
    // through panics/respawns matches the direct in-process forward
    let x = row(12, 4242);
    let reference = toy_mlp(77);
    let want: Vec<u32> = match mode {
        ForwardMode::PackedF32 => reference.forward(&x, 1).iter().map(|v| v.to_bits()).collect(),
        ForwardMode::Bnn => {
            let mut ws = reference.bnn_workspace(1);
            reference.forward_bnn_into(&x, 1, &mut ws).iter().map(|v| v.to_bits()).collect()
        }
    };
    let mut body = String::new();
    predict_body(&mut body, &x);
    let mut checked = 0;
    for _ in 0..400 {
        if checked >= 20 {
            break;
        }
        let Ok(mut c) = HttpClient::connect(&host) else { continue };
        match c.request("POST", "/predict", Some(&body)) {
            Ok((200, text)) => {
                assert_eq!(logits_bits(&text), want, "chaos answer diverged from reference");
                checked += 1;
            }
            // chaos outcomes (500 abort, 503/504 shed) and torn
            // connections are retried; anything else is a bug
            Ok((status, text)) => {
                assert!(matches!(status, 500 | 503 | 504), "unexpected {status}: {text}");
            }
            Err(_) => {}
        }
    }
    assert!(checked >= 20, "only {checked} clean answers in 400 attempts");

    // accounting is exact: the supervisor recovered precisely the panics
    // the injector fired — nothing double-counted, nothing missed (all
    // traffic is done; /stats itself never injects)
    let snap = stats(&host);
    assert_eq!(
        snap.get("worker_restarts").unwrap().as_usize().map(|v| v as u64),
        Some(plan.injected_worker_panics()),
    );
    assert_eq!(
        snap.get("batcher_restarts").unwrap().as_usize().map(|v| v as u64),
        Some(plan.injected_batcher_panics()),
    );
    assert!(plan.injected_worker_panics() > 0, "chaos run injected no worker panics");
    assert!(plan.injected_batcher_panics() > 0, "chaos run injected no batcher panics");
    // graceful drain still works after a chaotic life
    server.stop();
}

#[test]
fn chaos_packed_mode_loses_nothing_and_stays_exact() {
    chaos_mode(ForwardMode::PackedF32, 31);
}

#[test]
fn chaos_bnn_mode_loses_nothing_and_stays_exact() {
    chaos_mode(ForwardMode::Bnn, 32);
}
