//! Property-based tests on coordinator invariants, using the in-repo
//! `prop` harness (no proptest crate offline). These cover routing/
//! batching/state invariants that must hold for ANY input, not just the
//! happy path.

use binaryconnect::binary::packed::{dense_f32, BitMatrix};
use binaryconnect::coordinator::LrSchedule;
use binaryconnect::data::Dataset;
use binaryconnect::kernel;
use binaryconnect::pipeline::{batch_indices, encode_targets, gather_batch, n_batches, Plan};
use binaryconnect::prop::{check, log_size};
use binaryconnect::runtime::reference::mlp_info;
use binaryconnect::runtime::{Executor, Hyper, Mode, Opt, ReferenceExecutor};
use binaryconnect::stats::{mean_std, Histogram};
use binaryconnect::util::Rng;

#[test]
fn prop_shuffled_batches_partition_dataset() {
    check(
        "shuffled batches partition",
        |r| {
            let n = log_size(r, 3000);
            let b = log_size(r, 64);
            (n, b, r.next_u64())
        },
        |&(n, b, seed)| {
            let plans = batch_indices(n, b, Plan::Shuffled { seed });
            if plans.len() != n / b {
                return Err(format!("{} batches, expected {}", plans.len(), n / b));
            }
            let mut seen = vec![false; n];
            for p in &plans {
                if p.len() != b {
                    return Err("ragged training batch".into());
                }
                for &i in p {
                    if i >= n {
                        return Err(format!("index {i} out of range {n}"));
                    }
                    if seen[i] {
                        return Err(format!("index {i} repeated"));
                    }
                    seen[i] = true;
                }
            }
            if seen.iter().filter(|&&s| s).count() != (n / b) * b {
                return Err("coverage mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sequential_batches_cover_everything_in_order() {
    check(
        "sequential covers all",
        |r| (log_size(r, 2000), log_size(r, 64)),
        |&(n, b)| {
            let plans = batch_indices(n, b, Plan::Sequential);
            if plans.len() != n_batches(n, b, Plan::Sequential) {
                return Err("n_batches mismatch".into());
            }
            let flat: Vec<usize> = plans.into_iter().flatten().collect();
            if flat != (0..n).collect::<Vec<_>>() {
                return Err("not the identity order".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_targets_one_hot_pm1() {
    check(
        "targets are +/-1 one-hot",
        |r| {
            let n = log_size(r, 200);
            let c = 2 + r.below(19);
            let labels: Vec<u8> = (0..n).map(|_| r.below(c) as u8).collect();
            (labels, c)
        },
        |(labels, c)| {
            let mut y = vec![];
            encode_targets(labels, *c, &mut y);
            for (i, row) in y.chunks(*c).enumerate() {
                let pos: Vec<usize> =
                    row.iter().enumerate().filter(|(_, &v)| v == 1.0).map(|(j, _)| j).collect();
                if pos.len() != 1 || pos[0] != labels[i] as usize {
                    return Err(format!("row {i} not one-hot at label"));
                }
                if row.iter().any(|&v| v != 1.0 && v != -1.0) {
                    return Err("values outside {-1,+1}".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gather_batch_pads_with_last_row() {
    check(
        "gather pads correctly",
        |r| {
            let dim = 1 + r.below(20);
            let n = 2 + r.below(50);
            let batch = 1 + r.below(32);
            let take = 1 + r.below(batch.min(n));
            (dim, n, batch, take, r.next_u64())
        },
        |&(dim, n, batch, take, seed)| {
            let mut rng = Rng::new(seed);
            let mut ds = Dataset::new("p", (1, dim, 1), 4);
            for _ in 0..n {
                let row: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
                ds.push(&row, rng.below(4) as u8);
            }
            let idx: Vec<usize> = (0..take).map(|_| rng.below(n)).collect();
            let b = gather_batch(&ds, &idx, batch, 0);
            if b.n_valid != take || b.x.len() != batch * dim {
                return Err("size bookkeeping wrong".into());
            }
            // all padding rows equal the last real row
            let last = &b.x[(take - 1) * dim..take * dim];
            for p in take..batch {
                if &b.x[p * dim..(p + 1) * dim] != last {
                    return Err(format!("padding row {p} differs"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_packed_matmul_equals_sign_gemm() {
    check(
        "packed == sign gemm",
        |r| {
            let b = 1 + r.below(4);
            let k = 1 + r.below(300);
            let n = 1 + r.below(24);
            let w: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
            let x: Vec<f32> = (0..b * k).map(|_| r.normal()).collect();
            (b, k, n, w, x)
        },
        |(b, k, n, w, x)| {
            let (b, k, n) = (*b, *k, *n);
            let bm = BitMatrix::pack(w, k, n);
            let mut y = vec![0f32; b * n];
            bm.matmul(x, b, &mut y);
            let ws: Vec<f32> = w.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
            let mut yref = vec![0f32; b * n];
            dense_f32(x, &ws, b, k, n, &mut yref);
            for (i, (a, r)) in y.iter().zip(&yref).enumerate() {
                if (a - r).abs() > 2e-3 * (1.0 + r.abs()) {
                    return Err(format!("mismatch at {i}: {a} vs {r}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_lr_schedule_bounded_and_monotone() {
    check(
        "exp schedule bounded + monotone",
        |r| {
            let start = 10f32.powf(-(r.uniform() * 3.0)); // 1 .. 1e-3
            let end = start * 10f32.powf(-(1.0 + r.uniform() * 2.0));
            let epochs = 2 + r.below(200);
            (start, end, epochs)
        },
        |&(start, end, epochs)| {
            let s = LrSchedule::Exponential { start, end, epochs };
            let mut prev = f32::INFINITY;
            for e in 0..epochs {
                let lr = s.at(e);
                if !(lr <= start * 1.0001 && lr >= end * 0.9999) {
                    return Err(format!("lr {lr} escapes [{end}, {start}] at {e}"));
                }
                if lr > prev {
                    return Err(format!("lr increased at epoch {e}"));
                }
                prev = lr;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_histogram_conserves_mass() {
    check(
        "histogram mass conserved",
        |r| {
            let n = log_size(r, 5000);
            let vals: Vec<f32> = (0..n).map(|_| r.normal() * 2.0).collect();
            let bins = 1 + r.below(100);
            (vals, bins)
        },
        |(vals, bins)| {
            let h = Histogram::build(vals, -1.0, 1.0, *bins);
            if h.total() as usize != vals.len() {
                return Err(format!("{} != {}", h.total(), vals.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mean_std_translation_invariance() {
    check(
        "std is translation invariant",
        |r| {
            let v: Vec<f64> = (0..2 + r.below(100)).map(|_| r.normal() as f64).collect();
            let shift = r.normal() as f64 * 10.0;
            (v, shift)
        },
        |(v, shift)| {
            let (m1, s1) = mean_std(v);
            let shifted: Vec<f64> = v.iter().map(|x| x + shift).collect();
            let (m2, s2) = mean_std(&shifted);
            if (m2 - m1 - shift).abs() > 1e-9 {
                return Err("mean did not translate".into());
            }
            if (s2 - s1).abs() > 1e-9 {
                return Err("std changed under translation".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_matmul_single_batched_and_naive_agree() {
    // BitMatrix::matmul has two code paths (b == 1 selected-sum walk,
    // b > 1 transposed stripe adds); both must equal a naive f32 sign-GEMM
    // for any k, including k not a multiple of 64.
    check(
        "single == batched == naive sign gemm",
        |r| {
            let b = 2 + r.below(4); // batched path needs b > 1
            // bias k toward word boundaries: 64m-1, 64m, 64m+1 and odd sizes
            let k = match r.below(4) {
                0 => 64 * (1 + r.below(3)),
                1 => 64 * (1 + r.below(3)) - 1,
                2 => 64 * (1 + r.below(3)) + 1,
                _ => 1 + r.below(200),
            };
            let n = 1 + r.below(20);
            let w: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
            let x: Vec<f32> = (0..b * k).map(|_| r.normal()).collect();
            (b, k, n, w, x)
        },
        |(b, k, n, w, x)| {
            let (b, k, n) = (*b, *k, *n);
            let bm = BitMatrix::pack(w, k, n);
            // batched path
            let mut y_batched = vec![0f32; b * n];
            bm.matmul(x, b, &mut y_batched);
            // single path, row by row
            let mut y_single = vec![0f32; b * n];
            for t in 0..b {
                bm.matmul(&x[t * k..(t + 1) * k], 1, &mut y_single[t * n..(t + 1) * n]);
            }
            // naive f32 sign-GEMM
            let ws: Vec<f32> = w.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
            let mut y_naive = vec![0f32; b * n];
            dense_f32(x, &ws, b, k, n, &mut y_naive);
            for i in 0..b * n {
                let (s, bt, nv) = (y_single[i], y_batched[i], y_naive[i]);
                if (s - nv).abs() > 2e-3 * (1.0 + nv.abs()) {
                    return Err(format!("single vs naive at {i}: {s} vs {nv}"));
                }
                if (bt - nv).abs() > 2e-3 * (1.0 + nv.abs()) {
                    return Err(format!("batched vs naive at {i}: {bt} vs {nv}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pack_sign_roundtrip_with_signed_zero() {
    // Eq. 1 defines sign(0) = +1; packing must map BOTH +0.0 and -0.0 to
    // the +1 bit, and round-trip every other sign exactly.
    check(
        "pack -> sign round trip incl. ±0.0",
        |r| {
            let k = 1 + r.below(150);
            let n = 1 + r.below(12);
            let w: Vec<f32> = (0..k * n)
                .map(|_| match r.below(5) {
                    0 => 0.0f32,
                    1 => -0.0f32,
                    _ => r.normal(),
                })
                .collect();
            (k, n, w)
        },
        |(k, n, w)| {
            let bm = BitMatrix::pack(w, *k, *n);
            for row in 0..*k {
                for col in 0..*n {
                    let v = w[row * n + col];
                    let got = bm.sign(row, col);
                    if v == 0.0 {
                        // covers both +0.0 and -0.0 (they compare equal);
                        // Eq. 1 demands sign(±0.0) = +1
                        if got != 1.0 {
                            return Err(format!(
                                "sign({v:?}) at ({row},{col}) must be +1, got {got}"
                            ));
                        }
                    } else {
                        let want = if v > 0.0 { 1.0 } else { -1.0 };
                        if got != want {
                            return Err(format!(
                                "sign mismatch at ({row},{col}): w = {v:?}, got {got}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pooled_gemm_bit_identical_to_serial() {
    // the thread pool splits output rows, never reductions: for EVERY
    // shape (straddling the 256-wide k/n tiles and odd sizes) the pooled
    // kernels must equal their single-threaded twins bit-for-bit.
    check(
        "pooled gemm == serial gemm (exact)",
        |r| {
            let m = 1 + r.below(40);
            let k = 1 + r.below(300);
            let n = 1 + r.below(300);
            // sparse A exercises the zero-skip branches
            let a: Vec<f32> = (0..m * k)
                .map(|_| if r.uniform() < 0.4 { 0.0 } else { r.normal() })
                .collect();
            let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
            (m, k, n, a, b)
        },
        |(m, k, n, a, b)| {
            let (m, k, n) = (*m, *k, *n);
            let mut pooled = vec![0f32; m * n];
            kernel::gemm(a, b, m, k, n, &mut pooled);
            let mut serial = vec![0f32; m * n];
            kernel::gemm_serial(a, b, m, k, n, &mut serial);
            if pooled != serial {
                return Err("gemm: pooled != serial".into());
            }
            // A^T·B: reinterpret a as (m x k), b' as (m x n') — reuse b
            // truncated to m rows when possible, else skip (shapes must
            // share the leading dim)
            let nn = n.min(300);
            let b2: Vec<f32> = (0..m * nn).map(|i| b[i % b.len()]).collect();
            let mut pooled = vec![0f32; k * nn];
            kernel::gemm_at_b(a, &b2, m, k, nn, &mut pooled);
            let mut serial = vec![0f32; k * nn];
            kernel::gemm_at_b_serial(a, &b2, m, k, nn, &mut serial);
            if pooled != serial {
                return Err("gemm_at_b: pooled != serial".into());
            }
            // A·B^T: A is (m x n'), B is (k' x n')
            let a2: Vec<f32> = (0..m * nn).map(|i| a[i % a.len()]).collect();
            let mut pooled = vec![0f32; m * k];
            kernel::gemm_a_bt(&a2, &b2_as_kn(&b2, k, m, nn), m, nn, k, &mut pooled);
            let mut serial = vec![0f32; m * k];
            kernel::gemm_a_bt_serial(&a2, &b2_as_kn(&b2, k, m, nn), m, nn, k, &mut serial);
            if pooled != serial {
                return Err("gemm_a_bt: pooled != serial".into());
            }
            Ok(())
        },
    );
}

/// Build a (k x n) matrix by cycling a source buffer (shape adapter for
/// the property above).
fn b2_as_kn(src: &[f32], k: usize, _m: usize, n: usize) -> Vec<f32> {
    (0..k * n).map(|i| src[i % src.len()]).collect()
}

#[test]
fn prop_packed_train_step_matches_dense_baseline() {
    // The packed sign-GEMM training path (fast) and the seed's dense
    // binarized f32 path (baseline) are one algorithm up to f32 summation
    // order: loss and updated params agree within 1e-4 for det mode, k
    // NOT a multiple of 64, batch 1 and 64 (plus stoch spot checks —
    // the packed stochastic pack consumes the same RNG stream).
    for (in_dim, hidden, batch, mode) in [
        (70usize, 33usize, 1usize, Mode::Det),
        (70, 33, 64, Mode::Det),
        (130, 96, 64, Mode::Det),
        (70, 33, 64, Mode::Stoch),
    ] {
        let fast =
            ReferenceExecutor::new(mlp_info("p", in_dim, hidden, 2, 5, batch)).unwrap();
        let mut base =
            ReferenceExecutor::new(mlp_info("p", in_dim, hidden, 2, 5, batch)).unwrap();
        base.set_fast(false);
        let mut sf = fast.init_state(&Hyper { seed: 7, ..Default::default() }).unwrap();
        let mut sb = sf.snapshot();
        let mut rng = Rng::new(1234);
        let x: Vec<f32> = (0..batch * in_dim).map(|_| rng.normal()).collect();
        let mut y = vec![-1.0f32; batch * 5];
        for t in 0..batch {
            y[t * 5 + rng.below(5)] = 1.0;
        }
        for step in 1..=3u32 {
            let h = Hyper {
                lr: 0.02,
                mode,
                opt: Opt::Sgd,
                step,
                seed: 40 + step,
                ..Default::default()
            };
            let mf = fast.train_step(&mut sf, &x, &y, &h).unwrap();
            let mb = base.train_step(&mut sb, &x, &y, &h).unwrap();
            assert!(
                (mf.loss - mb.loss).abs() < 1e-4 * (1.0 + mb.loss.abs()),
                "{mode:?} k={in_dim} b={batch} step {step}: loss {} vs {}",
                mf.loss,
                mb.loss
            );
        }
        for (pi, (pf, pb)) in sf.params.iter().zip(&sb.params).enumerate() {
            for (j, (a, b)) in pf.iter().zip(pb).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4 * (1.0 + b.abs()),
                    "{mode:?} k={in_dim} b={batch}: param {pi}[{j}] {a} vs {b}"
                );
            }
        }
        // eval agrees too (same trained state through both engines)
        let hy = Hyper { mode, seed: 3, ..Default::default() };
        let (lf, _) = fast.eval_batch(&sf, &x, &y, &hy).unwrap();
        let (lb, _) = base.eval_batch(&sf, &x, &y, &hy).unwrap();
        for (a, b) in lf.iter().zip(&lb) {
            assert!(
                (a - b).abs() < 2e-4 * (1.0 + b.abs()),
                "{mode:?} eval loss {a} vs {b}"
            );
        }
    }
}

#[test]
fn prop_bitmatrix_sign_agrees_with_source() {
    check(
        "bit-pack preserves signs",
        |r| {
            let k = 1 + r.below(200);
            let n = 1 + r.below(16);
            let w: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
            (k, n, w)
        },
        |(k, n, w)| {
            let bm = BitMatrix::pack(w, *k, *n);
            for row in 0..*k {
                for col in 0..*n {
                    let want = if w[row * n + col] >= 0.0 { 1.0 } else { -1.0 };
                    if bm.sign(row, col) != want {
                        return Err(format!("sign mismatch at ({row},{col})"));
                    }
                }
            }
            Ok(())
        },
    );
}
