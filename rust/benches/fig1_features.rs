//! Figure 1: first-layer features of the MNIST MLP by regularizer.
//!
//! Trains under each regime (reference backend) and writes a PGM tile
//! sheet of the first-layer features (per-tile contrast normalized, like
//! the paper's plot). The paper's qualitative claim: each regularizer
//! leaves a visibly different feature texture.
//!
//! Run: cargo bench --bench fig1_features [-- --epochs N]
//! Writes fig1_none.pgm, fig1_det.pgm, fig1_stoch.pgm, fig1_dropout.pgm.

use binaryconnect::coordinator::{dropout_opts, mnist_opts, prepare, train, DataOpts};
use binaryconnect::data::Corpus;
use binaryconnect::runtime::{Executor, Mode, ReferenceExecutor};
use binaryconnect::stats::{feature_tiles, write_pgm};
use binaryconnect::util::error::{Error, Result};
use binaryconnect::util::Args;
use binaryconnect::{anyhow, ensure};

fn main() -> Result<()> {
    let args = Args::parse().map_err(Error::msg)?;
    let epochs = args.usize("epochs", 10);

    let model = ReferenceExecutor::builtin(&args.str("model", "mlp"))?;
    let info = model.info().clone();
    let (data, _) = prepare(
        Corpus::Mnist,
        &DataOpts { n_train: args.usize("n-train", 3000), n_test: 500, ..Default::default() },
    )?;

    let in_dim = info.params[0].shape[0];
    let units = info.params[0].shape[1];
    let side = (in_dim as f64).sqrt() as usize;
    ensure!(side * side == in_dim, "input not square");
    let n_tiles = units.min(100);

    let regimes = [
        ("none", mnist_opts(Mode::None, epochs, 17)),
        ("det", mnist_opts(Mode::Det, epochs, 17)),
        ("stoch", mnist_opts(Mode::Stoch, epochs, 17)),
        ("dropout", dropout_opts(&mnist_opts(Mode::None, epochs, 17))),
    ];
    println!("Figure 1 — first-layer feature sheets ({n_tiles} tiles each):");
    for (label, opts) in regimes {
        eprintln!("[fig1] {label} ...");
        let r = train(&model, &data, &opts)?;
        let w0 = r.state.param_vec(0)?;
        let (img, w, h) = feature_tiles(&w0, in_dim, units, side, n_tiles, 10);
        let path = format!("fig1_{label}.pgm");
        write_pgm(std::path::Path::new(&path), &img, w, h)
            .map_err(|e| anyhow!("write {path}: {e}"))?;
        // quantify texture difference: fraction of near-saturated pixels
        let sat = img.iter().filter(|&&p| p < 30 || p > 225).count() as f64
            / img.len() as f64;
        println!(
            "  {label:<8} -> {path}  ({w}x{h}, {:.1}% saturated pixels, test err {:.3})",
            sat * 100.0,
            r.test_err
        );
    }
    println!("view with any PGM viewer; the four sheets show distinct feature textures.");
    Ok(())
}
