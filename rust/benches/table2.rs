//! Table 2: test error by regularizer x dataset.
//!
//! Paper (full scale):
//!     method      MNIST        CIFAR-10  SVHN
//!     none        1.30±0.04    10.64     2.44
//!     BC det      1.29±0.08     9.90     2.30
//!     BC stoch    1.18±0.04     8.27     2.15
//!     dropout     1.01±0.04     —        —
//!
//! Shape to reproduce: BC is never worse than no-regularizer, stoch <= det,
//! and on MNIST dropout is the strongest regularizer. On the reference
//! backend the CIFAR/SVHN CNNs are stood in for by dense models (see
//! DESIGN.md); pass --epochs/--n-train to go larger.
//!
//! Run: cargo bench --bench table2 [-- --epochs N --trials N]

use binaryconnect::bench_harness::Table;
use binaryconnect::coordinator::{
    dropout_opts, mnist_opts, prepare, trials, DataOpts, TrainOpts,
};
use binaryconnect::data::Corpus;
use binaryconnect::runtime::{Mode, ReferenceExecutor};
use binaryconnect::util::error::{Error, Result};
use binaryconnect::util::Args;

fn main() -> Result<()> {
    let args = Args::parse().map_err(Error::msg)?;
    let mnist_epochs = args.usize("epochs", 25);
    let cnn_epochs = args.usize("cnn-epochs", 14);
    let n_trials = args.usize("trials", 2);
    let data_dir = args.opt_str("data-dir").map(std::path::PathBuf::from);

    let methods: [(&str, Mode, bool); 4] = [
        ("No regularizer", Mode::None, false),
        ("BinaryConnect (det.)", Mode::Det, false),
        ("BinaryConnect (stoch.)", Mode::Stoch, false),
        ("50% Dropout", Mode::None, true),
    ];

    let mut cells: Vec<Vec<String>> =
        methods.iter().map(|(name, _, _)| vec![name.to_string()]).collect();

    // ---------- MNIST (MLP, SGD, multi-trial mean ± std) ----------
    {
        let model = ReferenceExecutor::builtin("mlp")?;
        let (data, _) = prepare(
            Corpus::Mnist,
            &DataOpts {
                data_dir: data_dir.clone(),
                n_train: args.usize("n-train", 4000),
                n_test: args.usize("n-test", 1000),
                ..Default::default()
            },
        )?;
        for (mi, (name, mode, dropout)) in methods.iter().enumerate() {
            let base = mnist_opts(*mode, mnist_epochs, 31);
            let o: TrainOpts = if *dropout { dropout_opts(&base) } else { base };
            eprintln!("[table2/mnist] {name} ...");
            let s = trials(&model, &data, &o, n_trials)?;
            cells[mi].push(format!("{:.2} ± {:.2}%", s.mean * 100.0, s.std * 100.0));
        }
    }

    // ---------- CIFAR-10 and SVHN (dense stand-ins, ADAM, single run;
    //            dropout row blank as in the paper) ----------
    for (corpus, model_name, n_tr) in [
        (Corpus::Cifar10, "cifar_mlp", 800usize),
        (Corpus::Svhn, "svhn_mlp", 800),
    ] {
        let model = ReferenceExecutor::builtin(model_name)?;
        let (data, _) = prepare(
            corpus,
            &DataOpts {
                data_dir: data_dir.clone(),
                n_train: args.usize("cnn-n-train", n_tr),
                n_test: args.usize("cnn-n-test", 400),
                ..Default::default()
            },
        )?;
        for (mi, (name, mode, dropout)) in methods.iter().enumerate() {
            if *dropout {
                cells[mi].push("—".into());
                continue;
            }
            eprintln!("[table2/{:?}] {name} ...", corpus);
            let mut o = binaryconnect::coordinator::cnn_opts(*mode, cnn_epochs, 37);
            if *mode == Mode::Stoch {
                // Sec.-2.6 method 1 (det weights) keeps BN calibrated in
                // the short-training regime; the stoch cells remain
                // step-budget-limited (footnote).
                o.eval_override = Some(Mode::Det);
            }
            let r = binaryconnect::coordinator::train(&model, &data, &o)?;
            let mark = if *mode == Mode::Stoch { "*" } else { "" };
            cells[mi].push(format!("{:.2}%{mark}", r.test_err * 100.0));
        }
    }

    let mut table = Table::new(&["Method", "MNIST", "CIFAR-10", "SVHN"]);
    for row in &cells {
        table.row(row);
    }
    println!("\nTable 2 — measured on this testbed (scaled datasets/widths/epochs):");
    table.print();
    println!(
        "paper:  none 1.30±0.04 / 10.64 / 2.44 ; det 1.29±0.08 / 9.90 / 2.30 ;\n        stoch 1.18±0.04 / 8.27 / 2.15 ; dropout 1.01±0.04 / — / —"
    );
    println!(
        "* stoch cells are step-budget-limited on this testbed: polarization\n\
         needs ~1e5+ steps (paper: 500 epochs = ~450k steps; this run: ~{}\n\
         steps). The MNIST column, where the step budget suffices, reproduces\n\
         the paper's stoch <= det ordering.",
        cnn_epochs * 800 / 50
    );
    Ok(())
}
