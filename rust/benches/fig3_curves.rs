//! Figure 3: training curves on CIFAR-10 by regularizer.
//!
//! Paper's qualitative claims: both BinaryConnect versions (dotted lines:
//! training cost; solid: validation error) (a) keep the training cost
//! HIGHER and train slower than the unregularized net, and (b) reach a
//! LOWER validation error — the signature of a Dropout-like regularizer.
//!
//! On the reference backend the paper's CNN is stood in for by the
//! `cifar_mlp` dense model (the regularizer comparison is architecture-
//! agnostic); build with `--features pjrt` and pass `--model cnn_small`
//! under the PJRT backend for the convolutional version.
//!
//! Run: cargo bench --bench fig3_curves [-- --epochs N --n-train N]
//! Writes fig3_<regime>.csv and prints the claim checks.

use binaryconnect::coordinator::{cnn_opts, prepare, train, DataOpts};
use binaryconnect::data::Corpus;
use binaryconnect::runtime::{Mode, ReferenceExecutor};
use binaryconnect::stats::Csv;
use binaryconnect::util::error::{Error, Result};
use binaryconnect::util::Args;

fn main() -> Result<()> {
    let args = Args::parse().map_err(Error::msg)?;
    let epochs = args.usize("epochs", 8);

    let model = ReferenceExecutor::builtin(&args.str("model", "cifar_mlp"))?;
    let (data, real) = prepare(
        Corpus::Cifar10,
        &DataOpts {
            n_train: args.usize("n-train", 1500),
            n_test: args.usize("n-test", 300),
            data_dir: args.opt_str("data-dir").map(Into::into),
            ..Default::default()
        },
    )?;
    eprintln!(
        "[fig3] cifar_mlp on CIFAR-10 ({}), {} epochs",
        if real { "real" } else { "synthetic" },
        epochs
    );

    let mut curves = vec![];
    for (label, mode) in [("none", Mode::None), ("det", Mode::Det), ("stoch", Mode::Stoch)] {
        eprintln!("[fig3] regime {label} ...");
        let r = train(&model, &data, &cnn_opts(mode, epochs, 23))?;
        let mut csv = Csv::new(&["epoch", "train_cost", "val_err"]);
        for rec in &r.curves {
            csv.rowf(&[rec.epoch as f64, rec.train_loss, rec.val_err]);
        }
        let path = format!("fig3_{label}.csv");
        csv.save(std::path::Path::new(&path))?;
        println!("wrote {path}");
        curves.push((label, r));
    }

    println!("\nFigure 3 series (train cost | val err):");
    println!("epoch | {:>18} | {:>18} | {:>18}", "none", "det", "stoch");
    for e in 0..epochs {
        let cell = |i: usize| {
            let c = &curves[i].1.curves;
            c.get(e)
                .map(|r| format!("{:>8.3} {:>8.4}", r.train_loss, r.val_err))
                .unwrap_or_default()
        };
        println!("{e:>5} | {} | {} | {}", cell(0), cell(1), cell(2));
    }

    let last = |i: usize| curves[i].1.curves.last().unwrap().train_loss;
    let best = |i: usize| curves[i].1.best_val_err;
    println!("\nclaim checks (paper Fig. 3):");
    println!(
        "  training cost: none {:.3} < det {:.3} / stoch {:.3}  -> {}",
        last(0),
        last(1),
        last(2),
        if last(0) < last(1) && last(0) < last(2) { "MATCHES" } else { "differs at this scale" }
    );
    println!(
        "  best val err : none {:.4} vs det {:.4} / stoch {:.4} -> {}",
        best(0),
        best(1),
        best(2),
        if best(1) <= best(0) || best(2) <= best(0) {
            "BC regularizes (MATCHES)"
        } else {
            "no BC win at this scale (paper needs full scale/epochs)"
        }
    );
    Ok(())
}
