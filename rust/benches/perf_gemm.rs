//! Performance microbenches for the hot paths (EXPERIMENTS.md par.Perf):
//!
//!   * packed sign-accumulate GEMM vs naive f32 GEMM (inference hot path)
//!   * PJRT train-step latency: Pallas-GEMM artifact vs native-dot artifact
//!     (the L1 ablation), plus the literal round-trip overhead
//!
//! Run: cargo bench --bench perf_gemm [-- --iters N]

use binaryconnect::bench_harness::{bench, fmt_time, Table};
use binaryconnect::binary::packed::{dense_f32, BitMatrix};
use binaryconnect::runtime::{Hyper, Manifest, Mode, Opt, Runtime};
use binaryconnect::util::{Args, Rng};

fn main() -> anyhow::Result<()> {
    let args = Args::parse().map_err(anyhow::Error::msg)?;
    let iters = args.usize("iters", 15);

    // ---------- packed vs f32 GEMM ----------
    println!("packed sign-GEMM vs f32 GEMM (batch 64):");
    let mut t = Table::new(&["k x n", "f32", "packed", "ratio", "weight mem ratio"]);
    let mut rng = Rng::new(5);
    for (k, n) in [(256, 256), (784, 1024), (1024, 1024)] {
        let b = 64;
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..b * k).map(|_| rng.normal()).collect();
        let bm = BitMatrix::pack(&w, k, n);
        let mut y = vec![0f32; b * n];
        let rf = bench("f32", 2, iters, || {
            dense_f32(&x, &w, b, k, n, &mut y);
            std::hint::black_box(&y);
        });
        let rp = bench("packed", 2, iters, || {
            bm.matmul(&x, b, &mut y);
            std::hint::black_box(&y);
        });
        t.row(&[
            format!("{k}x{n}"),
            fmt_time(rf.mean_s),
            fmt_time(rp.mean_s),
            format!("{:.2}x", rf.mean_s / rp.mean_s),
            format!("{}x", (k * n * 4) / bm.memory_bytes()),
        ]);
    }
    t.print();

    // ---------- PJRT step latency: pallas vs native ----------
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("\n(no artifacts; skipping PJRT step benches)");
        return Ok(());
    }
    let manifest = Manifest::load(dir)?;
    let rt = Runtime::cpu()?;
    println!("\nPJRT train/eval step latency (mlp = Pallas GEMM, mlp_ng = native dot):");
    let mut t2 = Table::new(&["model", "train step", "eval step", "steps/s (train)"]);
    for name in ["mlp", "mlp_ng", "cnn_small"] {
        let model = rt.load_model(manifest.model(name)?)?;
        let mut state = model.init_state(&Hyper::default())?;
        let nx: usize = model.info.input_shape.iter().product();
        let mut r = Rng::new(9);
        let x: Vec<f32> = (0..nx).map(|_| r.normal()).collect();
        let bc = model.info.batch * model.info.classes;
        let mut y = vec![-1.0f32; bc];
        for i in 0..model.info.batch {
            y[i * model.info.classes + r.below(model.info.classes)] = 1.0;
        }
        let mut step = 0u32;
        let h0 = Hyper { lr: 0.001, mode: Mode::Det, opt: Opt::Adam, ..Default::default() };
        let rtr = bench("train", 3, iters, || {
            step += 1;
            let h = Hyper { step, seed: step, ..h0.clone() };
            model.train_step(&mut state, &x, &y, &h).unwrap();
        });
        let rev = bench("eval", 3, iters, || {
            model.eval_batch(&state, &x, &y, &h0).unwrap();
        });
        t2.row(&[
            name.to_string(),
            fmt_time(rtr.mean_s),
            fmt_time(rev.mean_s),
            format!("{:.1}", 1.0 / rtr.mean_s),
        ]);
    }
    t2.print();
    println!("\n(mlp vs mlp_ng isolates the Pallas-kernel cost inside the lowered HLO)");

    // ---------- step-latency breakdown: where does the time go? ----------
    let model = rt.load_model(manifest.model("mlp")?)?;
    let state = model.init_state(&Hyper::default())?;
    let nx: usize = model.info.input_shape.iter().product();
    let mut r = Rng::new(11);
    let x: Vec<f32> = (0..nx).map(|_| r.normal()).collect();
    let dims: Vec<i64> = model.info.input_shape.iter().map(|&d| d as i64).collect();
    let r_lit = bench("literal build", 3, 50, || {
        let xl = xla::Literal::vec1(&x).reshape(&dims).unwrap();
        std::hint::black_box(xl);
    });
    let r_snap = bench("state snapshot (host copy of all params+slots)", 1, 10, || {
        std::hint::black_box(state.snapshot().unwrap());
    });
    println!("\nstep-overhead components (mlp):");
    println!("  input-literal build : {} per step", fmt_time(r_lit.mean_s));
    println!("  full-state host copy: {} (only on snapshot, not per step)", fmt_time(r_snap.mean_s));
    Ok(())
}
