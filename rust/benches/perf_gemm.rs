//! Performance microbenches for the hot paths:
//!
//!   * packed sign-accumulate GEMM vs naive f32 GEMM (inference hot path)
//!   * reference-backend train/eval step latency per builtin MLP model
//!
//! Run: cargo bench --bench perf_gemm [-- --iters N]

use binaryconnect::bench_harness::{bench, fmt_time, Table};
use binaryconnect::binary::packed::{dense_f32, BitMatrix};
use binaryconnect::runtime::{Executor, Hyper, Mode, Opt, ReferenceExecutor};
use binaryconnect::util::error::{Error, Result};
use binaryconnect::util::{Args, Rng};

fn main() -> Result<()> {
    let args = Args::parse().map_err(Error::msg)?;
    let iters = args.usize("iters", 15);

    // ---------- packed vs f32 GEMM ----------
    println!("packed sign-GEMM vs f32 GEMM (batch 64):");
    let mut t = Table::new(&["k x n", "f32", "packed", "ratio", "weight mem ratio"]);
    let mut rng = Rng::new(5);
    for (k, n) in [(256, 256), (784, 1024), (1024, 1024)] {
        let b = 64;
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..b * k).map(|_| rng.normal()).collect();
        let bm = BitMatrix::pack(&w, k, n);
        let mut y = vec![0f32; b * n];
        let rf = bench("f32", 2, iters, || {
            dense_f32(&x, &w, b, k, n, &mut y);
            std::hint::black_box(&y);
        });
        let mut y = vec![0f32; b * n];
        let rp = bench("packed", 2, iters, || {
            bm.matmul(&x, b, &mut y);
            std::hint::black_box(&y);
        });
        t.row(&[
            format!("{k}x{n}"),
            fmt_time(rf.mean_s),
            fmt_time(rp.mean_s),
            format!("{:.2}x", rf.mean_s / rp.mean_s),
            format!("{}x", (k * n * 4) / bm.memory_bytes()),
        ]);
    }
    t.print();

    // ---------- reference-backend step latency ----------
    println!("\nreference-backend train/eval step latency (builtin MLPs):");
    let mut t2 = Table::new(&["model", "train step", "eval step", "steps/s (train)"]);
    for name in ["mlp_small", "mlp", "cifar_mlp"] {
        let model = ReferenceExecutor::builtin(name)?;
        let mut state = model.init_state(&Hyper::default())?;
        let nx: usize = model.info().input_shape.iter().product();
        let mut r = Rng::new(9);
        let x: Vec<f32> = (0..nx).map(|_| r.normal()).collect();
        let bc = model.info().batch * model.info().classes;
        let mut y = vec![-1.0f32; bc];
        for i in 0..model.info().batch {
            y[i * model.info().classes + r.below(model.info().classes)] = 1.0;
        }
        let mut step = 0u32;
        let h0 = Hyper { lr: 0.001, mode: Mode::Det, opt: Opt::Adam, ..Default::default() };
        let rtr = bench("train", 3, iters, || {
            step += 1;
            let h = Hyper { step, seed: step, ..h0.clone() };
            model.train_step(&mut state, &x, &y, &h).unwrap();
        });
        let rev = bench("eval", 3, iters, || {
            model.eval_batch(&state, &x, &y, &h0).unwrap();
        });
        t2.row(&[
            name.to_string(),
            fmt_time(rtr.mean_s),
            fmt_time(rev.mean_s),
            format!("{:.1}", 1.0 / rtr.mean_s),
        ]);
    }
    t2.print();
    println!("\n(per-step cost is dominated by the three dense GEMMs; see hw_claims");
    println!(" for the multiplier-count model these latencies put in context)");
    Ok(())
}
