//! Performance microbenches for the hot paths:
//!
//!   * f32 GEMM kernels: seed-era naive vs blocked (1 thread) vs blocked +
//!     pool (the kernel-layer speedup, isolated)
//!   * packed sign-accumulate GEMM vs naive f32 GEMM (inference hot path)
//!   * train/eval step: seed-era baseline path vs the packed/workspace
//!     fast path, per builtin MLP and at the paper's 3x1024 MLP scale —
//!     the headline "train-step speedup vs current main" number
//!   * the SIMD dispatch ladder: the same f32 GEMM, packed sign-GEMM and
//!     fast train step pinned to each ISA rung the host supports
//!     (`gemm_avx2`, `packed_avx2`, `train_fast_avx2`, ... series), with
//!     `*_speedup_vs_scalar` metrics — the dispatch layer's win isolated
//!     from blocking/threading
//!   * the panel-vs-strip ladder: the PR-6 pack-once register-tiled
//!     kernels (`gemm_panel_{isa}`, `packed_panel_{isa}` series) against
//!     the retained strip baselines, with `panel_speedup_vs_strip`
//!     metrics on the mlp1024 train GEMM shape and the packed b=100
//!     batch shape
//!   * the BNN ladder (`bnn_*` series): the XNOR-popcount hidden layer
//!     against the packed-f32 layer on the mlp1024 1024x1024 shape at
//!     b=64, plus end-to-end `forward_bnn_into` vs `forward_into` on
//!     784 -> 3x1024 -> 10 — headline `bnn_speedup_vs_packed` rides the
//!     avx2 rung when the host has it
//!   * the conv ladder (`conv_naive_{isa}` vs `conv_im2col_{isa}`
//!     series): binary convolution as naive direct convolution against
//!     the im2col lowering onto the packed sign-GEMM, per ISA rung, with
//!     the headline `conv_im2col_speedup_vs_naive` metric riding avx2
//!   * checkpointing: `ckpt_save` (the atomic fsync'd save of a
//!     paper-scale mlp1024 TrainState, tracked as `ckpt_save_ms`) and the
//!     per-epoch train-loop tax `train_overhead_with_ckpt` (10-step mlp
//!     epoch with vs without a boundary save)
//!
//! Run: cargo bench --bench perf_gemm [-- --iters N] [--json BENCH_perf.json]
//!
//! `--json` writes machine-readable results (name, mean_s, iters, shape,
//! plus the machine block: cores, pool threads, detected/selected ISA) so
//! the perf trajectory is tracked from PR to PR (BENCH_perf.json at the
//! repo root holds the last committed run; regenerate it with the command
//! above from `rust/`).

use binaryconnect::bench_harness::{bench, fmt_time, JsonReport, Table};
use binaryconnect::binary::bnn::{pack_rows_into, words_per_row, xnor_layer_bits};
use binaryconnect::binary::packed::{BitMatrix, PackedLayer};
use binaryconnect::binary::PackedMlp;
use binaryconnect::conv::{im2col, oracle as conv_oracle};
use binaryconnect::kernel;
use binaryconnect::kernel::simd::{self, Isa, ALL_ISAS};
use binaryconnect::runtime::reference::mlp_info;
use binaryconnect::runtime::{Executor, Hyper, Mode, Opt, ReferenceExecutor};
use binaryconnect::util::checkpoint::{self, Checkpoint, CurvePoint};
use binaryconnect::util::error::{Error, Result};
use binaryconnect::util::{pool, Args, Rng};

fn main() -> Result<()> {
    let args = Args::parse().map_err(Error::msg)?;
    args.check_known(&["iters", "json"]).map_err(Error::msg)?;
    let iters = args.usize("iters", 15);
    let mut report = JsonReport::new();
    println!(
        "threads: {} | simd: {} (detected {})",
        pool::global().n_threads,
        simd::active().name(),
        simd::detect().name()
    );
    report.metric("threads", pool::global().n_threads as f64);

    // ---------- f32 GEMM kernels: naive vs blocked vs blocked+pool ----------
    println!("\nf32 GEMM kernel (C = A·B, batch 100):");
    let mut t = Table::new(&["k x n", "naive (seed)", "blocked 1T", "blocked+pool", "speedup"]);
    let mut rng = Rng::new(5);
    for (k, n) in [(256, 256), (1024, 1024)] {
        let m = 100;
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let mut c = vec![0f32; m * n];
        let shape = format!("{m}x{k}x{n}");
        let rn = bench("gemm_naive", 2, iters, || {
            kernel::gemm_naive(&a, &b, m, k, n, &mut c);
            std::hint::black_box(&c);
        });
        let rs = bench("gemm_serial", 2, iters, || {
            kernel::gemm_serial(&a, &b, m, k, n, &mut c);
            std::hint::black_box(&c);
        });
        let rp = bench("gemm_pool", 2, iters, || {
            kernel::gemm(&a, &b, m, k, n, &mut c);
            std::hint::black_box(&c);
        });
        report.add(&rn, &shape);
        report.add(&rs, &shape);
        report.add(&rp, &shape);
        t.row(&[
            format!("{k}x{n}"),
            fmt_time(rn.mean_s),
            fmt_time(rs.mean_s),
            fmt_time(rp.mean_s),
            format!("{:.2}x", rn.mean_s / rp.mean_s),
        ]);
    }
    t.print();

    // ---------- packed sign-GEMM vs f32 GEMM ----------
    println!("\npacked sign-GEMM vs f32 GEMM (batch 64):");
    let mut t = Table::new(&["k x n", "f32 naive", "packed", "ratio", "weight mem ratio"]);
    for (k, n) in [(256, 256), (784, 1024), (1024, 1024)] {
        let b = 64;
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..b * k).map(|_| rng.normal()).collect();
        let bm = BitMatrix::pack(&w, k, n);
        let shape = format!("{k}x{n} b={b}");
        let mut y = vec![0f32; b * n];
        let rf = bench("f32_naive", 2, iters, || {
            kernel::gemm_naive(&x, &w, b, k, n, &mut y);
            std::hint::black_box(&y);
        });
        let mut y = vec![0f32; b * n];
        let mut xt = vec![0f32; k * b];
        let mut totals = vec![0f32; b];
        let rp = bench("packed", 2, iters, || {
            bm.matmul_scaled_into(&x, b, 1.0, &mut y, &mut xt, &mut totals);
            std::hint::black_box(&y);
        });
        report.add(&rf, &shape);
        report.add(&rp, &shape);
        t.row(&[
            format!("{k}x{n}"),
            fmt_time(rf.mean_s),
            fmt_time(rp.mean_s),
            format!("{:.2}x", rf.mean_s / rp.mean_s),
            format!("{}x", (k * n * 4) / bm.memory_bytes()),
        ]);
    }
    t.print();

    // ---------- train/eval step: baseline (seed path) vs fast ----------
    println!("\ntrain/eval step: seed-era baseline vs packed+workspace fast path (det/ADAM):");
    let mut t2 = Table::new(&[
        "model",
        "train base",
        "train fast",
        "speedup",
        "eval fast",
        "steps/s (fast)",
    ]);
    // mlp1024 is the paper's MNIST scale: 784 -> 3x1024 -> 10, batch 100.
    let customs = [
        ("mlp", None),
        ("cifar_mlp", None),
        ("mlp1024", Some(mlp_info("mlp1024", 784, 1024, 3, 10, 100))),
    ];
    for (name, custom) in customs {
        let fast = match &custom {
            Some(info) => ReferenceExecutor::new(info.clone())?,
            None => ReferenceExecutor::builtin(name)?,
        };
        let mut base = match custom {
            Some(info) => ReferenceExecutor::new(info)?,
            None => ReferenceExecutor::builtin(name)?,
        };
        base.set_fast(false);
        let mut state_f = fast.init_state(&Hyper::default())?;
        let mut state_b = fast.init_state(&Hyper::default())?;
        let nx: usize = fast.info().input_shape.iter().product();
        let mut r = Rng::new(9);
        let x: Vec<f32> = (0..nx).map(|_| r.normal()).collect();
        let bc = fast.info().batch * fast.info().classes;
        let mut y = vec![-1.0f32; bc];
        for i in 0..fast.info().batch {
            y[i * fast.info().classes + r.below(fast.info().classes)] = 1.0;
        }
        let h0 = Hyper { lr: 0.001, mode: Mode::Det, opt: Opt::Adam, ..Default::default() };
        let mut step = 0u32;
        let rb = bench("train_baseline", 2, iters, || {
            step += 1;
            let h = Hyper { step, seed: step, ..h0.clone() };
            base.train_step(&mut state_b, &x, &y, &h).unwrap();
        });
        let mut step = 0u32;
        let rf = bench("train_fast", 2, iters, || {
            step += 1;
            let h = Hyper { step, seed: step, ..h0.clone() };
            fast.train_step(&mut state_f, &x, &y, &h).unwrap();
        });
        let re = bench("eval_fast", 2, iters, || {
            fast.eval_batch(&state_f, &x, &y, &h0).unwrap();
        });
        let speedup = rb.mean_s / rf.mean_s;
        report.add(&rb, name);
        report.add(&rf, name);
        report.add(&re, name);
        report.metric(&format!("train_step_speedup_{name}"), speedup);
        t2.row(&[
            name.to_string(),
            fmt_time(rb.mean_s),
            fmt_time(rf.mean_s),
            format!("{speedup:.2}x"),
            fmt_time(re.mean_s),
            format!("{:.1}", 1.0 / rf.mean_s),
        ]);
    }
    t2.print();
    println!("\n(speedup = seed-era dense/naive/allocating step vs packed sign-GEMM +");
    println!(" blocked multithreaded kernels + zero-alloc workspace; see EXPERIMENTS.md)");

    // ---------- SIMD dispatch ladder: per-ISA series ----------
    let selected = simd::active();
    println!(
        "\nSIMD dispatch ladder (detected {}, selected {}):",
        simd::detect().name(),
        selected.name()
    );
    let mut t3 = Table::new(&[
        "isa",
        "gemm 1024 (1T)",
        "packed b=64",
        "packed b=100",
        "train mlp1024",
        "gemm x",
        "packed x",
        "packed100 x",
        "train x",
    ]);
    let (m, k, n) = (100usize, 1024usize, 1024usize);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let bmat: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
    let mut c = vec![0f32; m * n];
    let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
    // b=64: the AVX2 register-resident chunk exactly; b=100 (the mlp1024
    // training batch) additionally exercises the ragged 36-wide tail
    // chunk, so the tracked metric matches the real training shape.
    let bb = 64usize;
    let b100 = 100usize;
    let x: Vec<f32> = (0..b100 * k).map(|_| rng.normal()).collect();
    let bm = BitMatrix::pack(&w, k, n);
    let mut y = vec![0f32; b100 * n];
    let mut xt = vec![0f32; k * b100];
    let mut totals = vec![0f32; b100];
    let ladder = ReferenceExecutor::new(mlp_info("mlp1024", 784, 1024, 3, 10, 100))?;
    // every rung restarts training from this exact state, so the ladder
    // compares ISAs on identical work (sparsity/sign profiles drift as
    // training progresses — sharing one evolving state would confound the
    // speedup metric with that drift)
    let lstate0 = ladder.init_state(&Hyper::default())?;
    let nx: usize = ladder.info().input_shape.iter().product();
    let mut r2 = Rng::new(77);
    let lx: Vec<f32> = (0..nx).map(|_| r2.normal()).collect();
    let classes = ladder.info().classes;
    let mut ly = vec![-1.0f32; ladder.info().batch * classes];
    for i in 0..ladder.info().batch {
        ly[i * classes + r2.below(classes)] = 1.0;
    }
    let h0 = Hyper { lr: 0.001, mode: Mode::Det, opt: Opt::Adam, ..Default::default() };
    let mut scalar_base: Option<(f64, f64, f64, f64)> = None;
    // worst rung first: the scalar arm establishes the speedup baseline
    for &isa in ALL_ISAS.iter().rev() {
        if !isa.supported() {
            continue;
        }
        simd::set_active(isa).map_err(Error::msg)?;
        let mut lstate = lstate0.snapshot();
        let mut lstep = 0u32;
        let rg = bench(&format!("gemm_{}", isa.name()), 2, iters, || {
            kernel::gemm_serial(&a, &bmat, m, k, n, &mut c);
            std::hint::black_box(&c);
        });
        let rp = bench(&format!("packed_{}", isa.name()), 2, iters, || {
            let xs = &x[..bb * k];
            bm.matmul_scaled_into(xs, bb, 1.0, &mut y[..bb * n], &mut xt, &mut totals);
            std::hint::black_box(&y);
        });
        let rp100 = bench(&format!("packed_b100_{}", isa.name()), 2, iters, || {
            bm.matmul_scaled_into(&x, b100, 1.0, &mut y, &mut xt, &mut totals);
            std::hint::black_box(&y);
        });
        let rt = bench(&format!("train_fast_{}", isa.name()), 2, iters, || {
            lstep += 1;
            let h = Hyper { step: lstep, seed: lstep, ..h0.clone() };
            ladder.train_step(&mut lstate, &lx, &ly, &h).unwrap();
        });
        report.add(&rg, &format!("{k}x{n} b={m} 1T"));
        report.add(&rp, &format!("{k}x{n} b={bb}"));
        report.add(&rp100, &format!("{k}x{n} b={b100}"));
        report.add(&rt, "mlp1024");
        if isa == Isa::Scalar {
            scalar_base = Some((rg.mean_s, rp.mean_s, rp100.mean_s, rt.mean_s));
        }
        let (g0, p0, p1, t0) = scalar_base.unwrap();
        t3.row(&[
            isa.name().to_string(),
            fmt_time(rg.mean_s),
            fmt_time(rp.mean_s),
            fmt_time(rp100.mean_s),
            fmt_time(rt.mean_s),
            format!("{:.2}x", g0 / rg.mean_s),
            format!("{:.2}x", p0 / rp.mean_s),
            format!("{:.2}x", p1 / rp100.mean_s),
            format!("{:.2}x", t0 / rt.mean_s),
        ]);
        if isa != Isa::Scalar {
            let name = isa.name();
            report.metric(&format!("gemm_{name}_speedup_vs_scalar"), g0 / rg.mean_s);
            report.metric(&format!("packed_{name}_speedup_vs_scalar"), p0 / rp.mean_s);
            report.metric(&format!("packed_b100_{name}_speedup_vs_scalar"), p1 / rp100.mean_s);
            report.metric(&format!("train_fast_{name}_speedup_vs_scalar"), t0 / rt.mean_s);
        }
    }
    simd::set_active(selected).map_err(Error::msg)?;
    t3.print();
    println!("(gemm series is single-threaded to isolate the ISA; packed/train ride the pool.");
    println!(" acceptance: gemm_avx2 >= 2x scalar, packed SIMD >= 1.5x scalar)");

    // ---------- panel vs strip: the PR-6 microkernel ladder ----------
    // Same shapes the dispatch ladder tracks: the mlp1024 train forward
    // GEMM (100 x 1024 x 1024, single-threaded to isolate the kernel)
    // and the packed batch-100 forward. Strip = the pre-panel 4-row
    // kernels, kept exactly for this baseline.
    println!("\npanel vs strip kernels (pack-once register tiles vs 4-row strips, 1T):");
    let mut t4 = Table::new(&[
        "isa",
        "gemm strip",
        "gemm panel",
        "panel x",
        "packed strip",
        "packed panel",
        "panel x",
    ]);
    for &isa in ALL_ISAS.iter().rev() {
        if !isa.supported() {
            continue;
        }
        simd::set_active(isa).map_err(Error::msg)?;
        let name = isa.name();
        let gshape = format!("{k}x{n} b={m} 1T");
        let pshape = format!("{k}x{n} b={b100}");
        let rgs = bench(&format!("gemm_strip_{name}"), 2, iters, || {
            kernel::gemm_strip(&a, &bmat, m, k, n, &mut c);
            std::hint::black_box(&c);
        });
        let rgp = bench(&format!("gemm_panel_{name}"), 2, iters, || {
            kernel::gemm_serial(&a, &bmat, m, k, n, &mut c);
            std::hint::black_box(&c);
        });
        let rps = bench(&format!("packed_strip_{name}"), 2, iters, || {
            bm.matmul_scaled_into_strip(&x, b100, 1.0, &mut y, &mut xt, &mut totals);
            std::hint::black_box(&y);
        });
        let rpp = bench(&format!("packed_panel_{name}"), 2, iters, || {
            bm.matmul_scaled_into(&x, b100, 1.0, &mut y, &mut xt, &mut totals);
            std::hint::black_box(&y);
        });
        report.add(&rgs, &gshape);
        report.add(&rgp, &gshape);
        report.add(&rps, &pshape);
        report.add(&rpp, &pshape);
        let gx = rgs.mean_s / rgp.mean_s;
        let px = rps.mean_s / rpp.mean_s;
        report.metric(&format!("gemm_panel_speedup_vs_strip_{name}"), gx);
        report.metric(&format!("packed_panel_speedup_vs_strip_{name}"), px);
        if isa == selected {
            // the headline acceptance metric rides the dispatched rung
            report.metric("panel_speedup_vs_strip", gx);
            report.metric("packed_panel_speedup_vs_strip", px);
        }
        t4.row(&[
            name.to_string(),
            fmt_time(rgs.mean_s),
            fmt_time(rgp.mean_s),
            format!("{gx:.2}x"),
            fmt_time(rps.mean_s),
            fmt_time(rpp.mean_s),
            format!("{px:.2}x"),
        ]);
    }
    simd::set_active(selected).map_err(Error::msg)?;
    t4.print();
    println!("(acceptance: panel >= 1.0x strip everywhere, >= 1.2x on the avx2 gemm)");

    // ---------- BNN ladder: xnor-popcount vs packed-f32 ----------
    // Layer level: one 1024x1024 hidden layer at b=64 (the mlp1024 shape
    // the acceptance metric names), packed-f32 lane-batched forward vs
    // the XNOR bit layer on pre-packed activation bits. End to end:
    // forward_into vs forward_bnn_into on 784 -> 3x1024 -> 10 (the BNN
    // pass pays the f32 escape-hatch first layer + the output layer, so
    // its ratio is lower than the pure hidden-layer win).
    println!("\nBNN xnor-popcount vs packed-f32 (layer 1024x1024 b=64, fwd 784->3x1024->10):");
    let mut t5 = Table::new(&[
        "isa",
        "f32 layer",
        "xnor layer",
        "layer x",
        "fwd packed",
        "fwd bnn",
        "fwd x",
    ]);
    let bscale: Vec<f32> = (0..n).map(|_| 1.0 + 0.01 * rng.normal()).collect();
    let bshift: Vec<f32> = (0..n).map(|_| 0.1 * rng.normal()).collect();
    let blayer = PackedLayer { bits: bm.clone(), scale: bscale, shift: bshift, relu: true };
    let wpr = words_per_row(k);
    let mut abits = vec![0u64; bb * wpr];
    pack_rows_into(&x[..bb * k], bb, k, &mut abits);
    let mut obits = vec![0u64; bb * words_per_row(n)];
    let mut mk_w = |k: usize, n: usize| -> (Vec<f32>, usize, usize) {
        ((0..k * n).map(|_| rng.normal()).collect(), k, n)
    };
    let mk_bn = |n: usize| Some((vec![1.0; n], vec![0.0; n], vec![0.1; n], vec![1.0; n]));
    let fwd_mlp = PackedMlp::build(
        vec![mk_w(784, 1024), mk_w(1024, 1024), mk_w(1024, 1024), mk_w(1024, 10)],
        vec![mk_bn(1024), mk_bn(1024), mk_bn(1024), None],
        Some(vec![0.0; 10]),
    );
    let fwd_x: Vec<f32> = (0..bb * 784).map(|_| rng.normal()).collect();
    let mut pws = fwd_mlp.workspace(bb);
    let mut bws = fwd_mlp.bnn_workspace(bb);
    let headline_isa = if Isa::Avx2.supported() { Isa::Avx2 } else { selected };
    for &isa in ALL_ISAS.iter().rev() {
        if !isa.supported() {
            continue;
        }
        simd::set_active(isa).map_err(Error::msg)?;
        let name = isa.name();
        let lshape = format!("{k}x{n} b={bb}");
        let rlf = bench(&format!("bnn_packedf32_layer_{name}"), 2, iters, || {
            blayer.forward_batched_into(&x[..bb * k], bb, &mut y[..bb * n], &mut xt, &mut totals);
            std::hint::black_box(&y);
        });
        let rlx = bench(&format!("bnn_xnor_layer_{name}"), 2, iters, || {
            xnor_layer_bits(&blayer, &abits, bb, &mut obits);
            std::hint::black_box(&obits);
        });
        let rfp = bench(&format!("bnn_fwd_packed_{name}"), 2, iters, || {
            let out = fwd_mlp.forward_into(&fwd_x, bb, &mut pws);
            std::hint::black_box(out);
        });
        let rfb = bench(&format!("bnn_fwd_{name}"), 2, iters, || {
            let out = fwd_mlp.forward_bnn_into(&fwd_x, bb, &mut bws);
            std::hint::black_box(out);
        });
        report.add(&rlf, &lshape);
        report.add(&rlx, &lshape);
        report.add(&rfp, &format!("mlp1024 b={bb}"));
        report.add(&rfb, &format!("mlp1024 b={bb}"));
        let lx = rlf.mean_s / rlx.mean_s;
        let fx = rfp.mean_s / rfb.mean_s;
        report.metric(&format!("bnn_layer_speedup_vs_packed_{name}"), lx);
        report.metric(&format!("bnn_forward_speedup_vs_packed_{name}"), fx);
        if isa == headline_isa {
            report.metric("bnn_speedup_vs_packed", lx);
            report.metric("bnn_forward_speedup_vs_packed", fx);
        }
        t5.row(&[
            name.to_string(),
            fmt_time(rlf.mean_s),
            fmt_time(rlx.mean_s),
            format!("{lx:.2}x"),
            fmt_time(rfp.mean_s),
            fmt_time(rfb.mean_s),
            format!("{fx:.2}x"),
        ]);
    }
    simd::set_active(selected).map_err(Error::msg)?;
    t5.print();
    println!("(acceptance: bnn_speedup_vs_packed >= 2x on the avx2 rung, 1024x1024 b=64)");

    // ---------- conv ladder: naive direct conv vs im2col + packed sign-GEMM ----------
    // The binary-conv lowering's win, isolated per ISA rung: the same
    // sign-weight SAME convolution computed by the seven-loop direct
    // oracle (what you ship without the lowering) versus im2col into the
    // packed sign-GEMM (what conv/ actually runs). 3x3 kernel, 16x16
    // spatial, 32 -> 32 channels at b=8 — the mid-stack C3 shape. The
    // naive side is scalar by construction; running it on every rung
    // keeps the per-ISA speedup honest about dispatch overhead.
    println!("\nconv: naive direct vs im2col + packed sign-GEMM (3x3, 16x16, 32->32, b=8):");
    let mut t7 = Table::new(&["isa", "naive direct", "im2col+packed", "speedup"]);
    let (cb, ch, cw, cin, cout) = (8usize, 16usize, 16usize, 32usize, 32usize);
    let (ckh, ckw) = (3usize, 3usize);
    let pk = ckh * ckw * cin;
    let rows = cb * ch * cw;
    let cwt: Vec<f32> = (0..pk * cout).map(|_| rng.normal()).collect();
    // the naive side convolves with the materialized ±1 signs — the
    // same function the packed side computes straight from the bits
    let csigns: Vec<f32> = cwt.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
    let cbits = BitMatrix::pack(&cwt, pk, cout);
    let cx: Vec<f32> = (0..cb * ch * cw * cin).map(|_| rng.normal()).collect();
    let mut cy = vec![0f32; rows * cout];
    let mut cpatches = vec![0f32; rows * pk];
    let mut cxt = vec![0f32; rows * pk];
    let mut ctot = vec![0f32; rows];
    let conv_shape = format!("{ckh}x{ckw} {ch}x{cw} {cin}->{cout} b={cb}");
    for &isa in ALL_ISAS.iter().rev() {
        if !isa.supported() {
            continue;
        }
        simd::set_active(isa).map_err(Error::msg)?;
        let name = isa.name();
        let rcn = bench(&format!("conv_naive_{name}"), 2, iters, || {
            conv_oracle::conv2d_forward(&cx, cb, ch, cw, cin, &csigns, ckh, ckw, cout, &mut cy);
            std::hint::black_box(&cy);
        });
        let rci = bench(&format!("conv_im2col_{name}"), 2, iters, || {
            im2col::im2col_into(&cx, cb, ch, cw, cin, ckh, ckw, &mut cpatches);
            cbits.matmul_scaled_into(&cpatches, rows, 1.0, &mut cy, &mut cxt, &mut ctot);
            std::hint::black_box(&cy);
        });
        report.add(&rcn, &conv_shape);
        report.add(&rci, &conv_shape);
        let cxup = rcn.mean_s / rci.mean_s;
        report.metric(&format!("conv_im2col_speedup_vs_naive_{name}"), cxup);
        if isa == headline_isa {
            report.metric("conv_im2col_speedup_vs_naive", cxup);
        }
        t7.row(&[
            name.to_string(),
            fmt_time(rcn.mean_s),
            fmt_time(rci.mean_s),
            format!("{cxup:.2}x"),
        ]);
    }
    simd::set_active(selected).map_err(Error::msg)?;
    t7.print();
    println!("(acceptance: conv_im2col_speedup_vs_naive >= 2x on the avx2 rung)");

    // ---------- checkpoint: crash-safe save cost + train-loop overhead ----------
    // `ckpt_save_ms` times the full atomic cycle (serialize -> same-dir
    // temp -> fsync -> rename -> retention prune) on a paper-scale
    // mlp1024 TrainState. `train_overhead_with_ckpt` is the per-epoch tax
    // a default `--checkpoint-every-epochs 1` run pays: a 10-step builtin
    // mlp epoch with one boundary save vs the same epoch without.
    println!("\ncheckpoint: atomic save cost and per-epoch train overhead:");
    let ckdir = std::env::temp_dir().join(format!("bc_bench_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&ckdir).map_err(Error::msg)?;
    let ck_big = Checkpoint {
        model: "mlp1024".to_string(),
        mode: Mode::Det as u8,
        opt: Opt::Adam as u8,
        seed: 7,
        total_epochs: 100,
        hyper_fp: 0xDEAD_BEEF,
        epoch_next: 50,
        step: 50 * 450,
        rng: Rng::new(42).state(),
        best_val: 0.011,
        best_epoch: 48,
        test_at_best: 0.012,
        stale: 2,
        diverged_steps: 0,
        curves: (0..50)
            .map(|e| CurvePoint {
                epoch: e,
                lr: 0.01,
                train_loss: 0.1,
                train_err: 0.05,
                val_err: 0.02,
                seconds: 1.0,
            })
            .collect(),
        state: lstate0.snapshot(),
    };
    let rc = bench("ckpt_save", 2, iters, || {
        let p = checkpoint::save_into_dir(&ckdir, &ck_big, 2, None).unwrap();
        std::hint::black_box(&p);
    });
    report.add(&rc, "mlp1024 full TrainState");
    report.metric("ckpt_save_ms", rc.mean_s * 1e3);

    let mexec = ReferenceExecutor::builtin("mlp")?;
    let mut mstate = mexec.init_state(&Hyper::default())?;
    let mnx: usize = mexec.info().input_shape.iter().product();
    let mut r3 = Rng::new(31);
    let mx: Vec<f32> = (0..mnx).map(|_| r3.normal()).collect();
    let mclasses = mexec.info().classes;
    let mut my = vec![-1.0f32; mexec.info().batch * mclasses];
    for i in 0..mexec.info().batch {
        my[i * mclasses + r3.below(mclasses)] = 1.0;
    }
    let mut ck_small = Checkpoint {
        model: mexec.info().name.clone(),
        epoch_next: 1,
        step: 10,
        curves: vec![CurvePoint {
            epoch: 0,
            lr: 0.01,
            train_loss: 0.1,
            train_err: 0.05,
            val_err: 0.02,
            seconds: 1.0,
        }],
        state: mstate.snapshot(),
        ..ck_big.clone()
    };
    const EPOCH_STEPS: usize = 10;
    let mh0 = Hyper { lr: 0.001, mode: Mode::Det, opt: Opt::Adam, ..Default::default() };
    let mut mstep = 0u32;
    let rplain = bench("train_epoch_plain", 1, iters, || {
        for _ in 0..EPOCH_STEPS {
            mstep += 1;
            let h = Hyper { step: mstep, seed: mstep, ..mh0.clone() };
            mexec.train_step(&mut mstate, &mx, &my, &h).unwrap();
        }
    });
    let rckpt = bench("train_epoch_ckpt", 1, iters, || {
        for _ in 0..EPOCH_STEPS {
            mstep += 1;
            let h = Hyper { step: mstep, seed: mstep, ..mh0.clone() };
            mexec.train_step(&mut mstate, &mx, &my, &h).unwrap();
        }
        // a real boundary save snapshots the live state, then goes to disk
        ck_small.state = mstate.snapshot();
        let p = checkpoint::save_into_dir(&ckdir, &ck_small, 2, None).unwrap();
        std::hint::black_box(&p);
    });
    let overhead = rckpt.mean_s / rplain.mean_s;
    report.add(&rplain, "mlp 10 steps");
    report.add(&rckpt, "mlp 10 steps + save");
    report.metric("train_overhead_with_ckpt", overhead);
    let mut t6 = Table::new(&["what", "mean", "note"]);
    t6.row(&[
        "ckpt save (mlp1024)".to_string(),
        fmt_time(rc.mean_s),
        format!("{:.2} ms", rc.mean_s * 1e3),
    ]);
    t6.row(&["10-step mlp epoch".to_string(), fmt_time(rplain.mean_s), String::new()]);
    t6.row(&[
        "10-step epoch + save".to_string(),
        fmt_time(rckpt.mean_s),
        format!("{overhead:.3}x"),
    ]);
    t6.print();
    println!("(acceptance: train_overhead_with_ckpt stays small; save cost is one fsync'd");
    println!(" rename, amortized over a real epoch's hundreds of steps)");
    let _ = std::fs::remove_dir_all(&ckdir);

    if let Some(path) = args.opt_str("json") {
        report.save("perf_gemm", std::path::Path::new(&path))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}
