//! Table 1: test error on CIFAR-10 by optimization method x learning-rate
//! scaling (deterministic BinaryConnect).
//!
//! Paper values (full scale, 500 epochs):
//!     SGD       15.65 / 11.45   Nesterov  —(diverged row blank) / 11.30
//!     ADAM      12.81 / 10.47
//! Shape to reproduce: LR scaling improves every optimizer; ADAM+scaling
//! is best. On the reference backend the CNN is stood in for by the
//! `cifar_mlp` dense model (the optimizer x scaling comparison is
//! architecture-agnostic).
//!
//! Run: cargo bench --bench table1 [-- --epochs N --n-train N]

use binaryconnect::bench_harness::Table;
use binaryconnect::coordinator::{cnn_opts, prepare, train, DataOpts};
use binaryconnect::data::Corpus;
use binaryconnect::runtime::{Mode, Opt, ReferenceExecutor};
use binaryconnect::util::error::{Error, Result};
use binaryconnect::util::Args;

fn main() -> Result<()> {
    let args = Args::parse().map_err(Error::msg)?;
    let epochs = args.usize("epochs", 6);
    let n_train = args.usize("n-train", 1200);

    let model = ReferenceExecutor::builtin(&args.str("model", "cifar_mlp"))?;
    let (data, real) = prepare(
        Corpus::Cifar10,
        &DataOpts {
            n_train,
            n_test: args.usize("n-test", 400),
            data_dir: args.opt_str("data-dir").map(Into::into),
            ..Default::default()
        },
    )?;
    eprintln!(
        "[table1] cifar_mlp, det-BC, {} train / {} test ({}), {epochs} epochs",
        data.train.len() + data.val.len(),
        data.test.len(),
        if real { "real" } else { "synthetic" }
    );

    // per-optimizer base LRs (the paper tunes per cell; these come from a
    // coarse sweep on the synthetic stand-in)
    let base_lr = |opt: Opt, scaled: bool| -> f32 {
        match (opt, scaled) {
            (Opt::Sgd, true) => 0.003,
            (Opt::Sgd, false) => 0.01,
            (Opt::Nesterov, true) => 0.001,
            (Opt::Nesterov, false) => 0.003,
            (Opt::Adam, true) => 0.002,
            (Opt::Adam, false) => 0.003,
        }
    };

    let mut table = Table::new(&["Optimization", "No LR scaling", "LR scaling"]);
    let mut rows = vec![];
    for opt in [Opt::Sgd, Opt::Nesterov, Opt::Adam] {
        let mut cells = vec![opt.label().to_string()];
        for scaled in [false, true] {
            let mut o = cnn_opts(Mode::Det, epochs, 21);
            o.opt = opt;
            o.lr_scale = scaled;
            let lr = base_lr(opt, scaled);
            o.schedule = binaryconnect::coordinator::LrSchedule::Exponential {
                start: lr,
                end: lr * 0.1,
                epochs,
            };
            eprintln!("[table1] {} scaling={} ...", opt.label(), scaled);
            let r = train(&model, &data, &o)?;
            cells.push(format!("{:.2}%", r.test_err * 100.0));
            rows.push((opt.label(), scaled, r.test_err));
        }
        table.row(&cells);
    }
    println!("\nTable 1 — measured on this testbed (det-BC, synthetic CIFAR scale):");
    table.print();
    println!("paper:  SGD 15.65/11.45  Nesterov —/11.30  ADAM 12.81/10.47");

    // the claim to check: scaling helps for each optimizer
    for opt in ["SGD", "Nesterov", "ADAM"] {
        let un = rows.iter().find(|r| r.0 == opt && !r.1).unwrap().2;
        let sc = rows.iter().find(|r| r.0 == opt && r.1).unwrap().2;
        println!(
            "  {opt}: scaling {}",
            if sc <= un { "helps or ties (matches paper)" } else { "did not help at this scale" }
        );
    }
    Ok(())
}
