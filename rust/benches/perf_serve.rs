//! Serving-layer bench: solo vs coalesced throughput and the latency
//! distribution as a function of the batching window, end to end through
//! real sockets (server + closed-loop load generator in one process).
//!
//! Series (JSON names):
//!   * `serve_solo_c1`   — max_batch 1, one connection: the true batch-1
//!     round-trip latency floor.
//!   * `serve_solo_c16`  — max_batch 1 at concurrency 16: what the
//!     server does under load *without* coalescing (every row pays a
//!     full batch-1 forward; the queue serializes them).
//!   * `serve_batch64_w{0,200,1000}us_c16` — dynamic micro-batching at
//!     concurrency 16 with increasing windows: throughput rides the
//!     lane-batched packed kernel, latency buys it with the window.
//!   * `serve_bnn_batch64_w200us_c16` / `serve_bnn_solo_c16` — the same
//!     workload through the XNOR-popcount engine (`--bnn`).
//!
//! Derived metrics: `serve_rps_<series>`, `serve_mean_batch_<series>`,
//! the headline `serve_coalesce_speedup_c16` =
//! rps(batch64_w200us_c16) / rps(solo_c16), and
//! `serve_bnn_speedup_vs_packed` = rps(bnn batch64 w200us) /
//! rps(packed-f32 batch64 w200us).
//! Acceptance (ISSUE 5): coalesced >= 3x solo at concurrency >= 16 on
//! the auto ISA.
//!
//! Run: cargo bench --bench perf_serve -- [--requests N] [--concurrency N]
//!      [--json BENCH_serve.json]

use std::time::Duration;

use binaryconnect::bench_harness::{fmt_time, BenchResult, JsonReport, Table};
use binaryconnect::binary::packed::PackedMlp;
use binaryconnect::binary::ForwardMode;
use binaryconnect::kernel::simd;
use binaryconnect::serve::{self, loadgen, ServeConfig};
use binaryconnect::util::error::{Error, Result};
use binaryconnect::util::{pool, Args, Rng};

/// The paper's MNIST-scale MLP shape (784 -> 3x1024 -> 10) with random
/// signs/affines — serving cost depends on shape, not trained values.
fn bench_mlp() -> PackedMlp {
    let mut rng = Rng::new(4242);
    let dims = [784usize, 1024, 1024, 1024, 10];
    let mut weights = vec![];
    let mut bns = vec![];
    for (w, pair) in dims.windows(2).enumerate() {
        let (k, n) = (pair[0], pair[1]);
        weights.push(((0..k * n).map(|_| rng.normal()).collect::<Vec<f32>>(), k, n));
        if w < 3 {
            bns.push(Some((
                vec![1.0f32; n],
                vec![0.0f32; n],
                (0..n).map(|_| 0.05 * rng.normal()).collect::<Vec<f32>>(),
                vec![1.0f32; n],
            )));
        } else {
            bns.push(None);
        }
    }
    PackedMlp::build(weights, bns, Some(vec![0.0; 10]))
}

struct SeriesResult {
    name: String,
    rps: f64,
    mean_batch: f64,
    lat: binaryconnect::util::LatencyStats,
    requests: usize,
}

#[allow(clippy::too_many_arguments)]
fn run_series(
    name: &str,
    mlp: PackedMlp,
    max_batch: usize,
    max_wait: Duration,
    concurrency: usize,
    requests: usize,
    mode: ForwardMode,
) -> Result<SeriesResult> {
    // workers = concurrency + 2: headroom so every loadgen connection is
    // served concurrently even with an extra probe/monitor connection —
    // otherwise one starved connection would pollute the latency tail
    let mut server = serve::start(
        mlp,
        ServeConfig {
            max_batch,
            max_wait,
            workers: (concurrency + 2).clamp(3, 64),
            conn_backlog: 2 * concurrency.max(1),
            queue_cap: 4096,
            mode,
            ..Default::default()
        },
    )?;
    let rep = loadgen::run(&loadgen::LoadgenOpts {
        host: server.addr().to_string(),
        concurrency,
        requests,
        seed: 7,
        retries: 0, // a perf run must measure the server, not retry politeness
    })?;
    server.stop();
    if rep.failed_status > 0 || rep.errors > 0 {
        return Err(Error::msg(format!(
            "{name}: {} non-2xx, {} transport errors",
            rep.failed_status, rep.errors
        )));
    }
    Ok(SeriesResult {
        name: name.to_string(),
        rps: rep.throughput_rps(),
        mean_batch: rep.server_mean_batch,
        lat: rep.latency,
        requests: rep.ok,
    })
}

fn main() -> Result<()> {
    let args = Args::parse().map_err(Error::msg)?;
    args.check_known(&["requests", "concurrency", "json"]).map_err(Error::msg)?;
    let requests = args.usize("requests", 2000);
    let concurrency = args.usize("concurrency", 16);
    let mut report = JsonReport::new();
    println!(
        "threads: {} | simd: {} (detected {}) | {} requests per series, concurrency {}",
        pool::global().n_threads,
        simd::active().name(),
        simd::detect().name(),
        requests,
        concurrency
    );
    report.metric("loadgen_concurrency", concurrency as f64);

    let window = |us: u64| Duration::from_micros(us);
    let (f32m, bnn) = (ForwardMode::PackedF32, ForwardMode::Bnn);
    let series: Vec<(String, usize, Duration, usize, ForwardMode)> = vec![
        ("serve_solo_c1".into(), 1, window(0), 1, f32m),
        ("serve_solo_c16".into(), 1, window(0), concurrency, f32m),
        (format!("serve_batch64_w0us_c{concurrency}"), 64, window(0), concurrency, f32m),
        (format!("serve_batch64_w200us_c{concurrency}"), 64, window(200), concurrency, f32m),
        (format!("serve_batch64_w1000us_c{concurrency}"), 64, window(1000), concurrency, f32m),
        ("serve_bnn_solo_c16".into(), 1, window(0), concurrency, bnn),
        (format!("serve_bnn_batch64_w200us_c{concurrency}"), 64, window(200), concurrency, bnn),
    ];

    let mut table = Table::new(&[
        "series",
        "req/s",
        "mean batch",
        "p50",
        "p95",
        "p99",
        "max",
    ]);
    let mut solo_c16_rps = 0.0;
    let mut coalesced_rps = 0.0;
    let mut bnn_coalesced_rps = 0.0;
    for (name, max_batch, wait, conc, mode) in &series {
        let r = run_series(name, bench_mlp(), *max_batch, *wait, *conc, requests, *mode)?;
        table.row(&[
            r.name.clone(),
            format!("{:.0}", r.rps),
            format!("{:.2}", r.mean_batch),
            fmt_time(r.lat.percentile(50.0)),
            fmt_time(r.lat.percentile(95.0)),
            fmt_time(r.lat.percentile(99.0)),
            fmt_time(r.lat.max()),
        ]);
        // latency distribution as a BenchResult row (mean/p50/p99/min)
        let bres = BenchResult {
            name: r.name.clone(),
            iters: r.requests,
            mean_s: r.lat.mean(),
            p50_s: r.lat.percentile(50.0),
            p99_s: r.lat.percentile(99.0),
            min_s: r.lat.min(),
        };
        report.add(&bres, &format!("784x3x1024x10 c={conc} w={}us", wait.as_micros()));
        report.metric(&format!("serve_rps_{}", r.name), r.rps);
        report.metric(&format!("serve_mean_batch_{}", r.name), r.mean_batch);
        if r.name == "serve_solo_c16" {
            solo_c16_rps = r.rps;
        }
        if r.name == format!("serve_batch64_w200us_c{concurrency}") {
            coalesced_rps = r.rps;
        }
        if r.name == format!("serve_bnn_batch64_w200us_c{concurrency}") {
            bnn_coalesced_rps = r.rps;
        }
    }
    table.print();

    if solo_c16_rps > 0.0 {
        let speedup = coalesced_rps / solo_c16_rps;
        report.metric("serve_coalesce_speedup_c16", speedup);
        println!(
            "\ncoalesce speedup (batch64/w200us vs solo, c={concurrency}): {speedup:.2}x \
             (acceptance: >= 3x at concurrency >= 16 on the auto ISA)"
        );
    }
    if coalesced_rps > 0.0 && bnn_coalesced_rps > 0.0 {
        let speedup = bnn_coalesced_rps / coalesced_rps;
        report.metric("serve_bnn_speedup_vs_packed", speedup);
        println!(
            "bnn engine speedup (bnn vs packed-f32, batch64/w200us, c={concurrency}): \
             {speedup:.2}x (end-to-end: HTTP + batching overhead dilute the kernel win)"
        );
    }
    println!(
        "(closed-loop load; solo series forward one row per request through the same \
         lane-batched kernel the coalesced series uses, so responses are bit-identical \
         across series — only throughput/latency differ)"
    );

    if let Some(path) = args.opt_str("json") {
        report.save("perf_serve", std::path::Path::new(&path))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}
