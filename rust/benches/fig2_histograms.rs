//! Figure 2: histogram of the first-layer real-valued weights (plotted as
//! w/H in [-1, 1]) after training with deterministic vs stochastic
//! BinaryConnect.
//!
//! Paper observation: weights polarize toward the clip boundaries ±1
//! ("trying to become deterministic"); det-BC also keeps a spike of
//! undecided weights near 0 ("hesitating between -1 and 1").
//!
//! Run: cargo bench --bench fig2_histograms [-- --epochs N]
//! Writes fig2_det.csv / fig2_stoch.csv and prints ASCII histograms plus
//! the polarization statistic.

use binaryconnect::coordinator::{mnist_opts, prepare, train, DataOpts};
use binaryconnect::data::Corpus;
use binaryconnect::runtime::{Executor, Mode, ReferenceExecutor};
use binaryconnect::stats::Histogram;
use binaryconnect::util::error::{Error, Result};
use binaryconnect::util::Args;

fn main() -> Result<()> {
    let args = Args::parse().map_err(Error::msg)?;
    let epochs = args.usize("epochs", 15);

    let model = ReferenceExecutor::builtin(&args.str("model", "mlp"))?;
    let info = model.info().clone();
    let (data, _) = prepare(
        Corpus::Mnist,
        &DataOpts { n_train: args.usize("n-train", 3000), n_test: 500, ..Default::default() },
    )?;

    let h_scale = info.params[0].glorot.max(1e-12) as f32;
    let mut polarization = vec![];
    for (label, mode) in [("det", Mode::Det), ("stoch", Mode::Stoch)] {
        eprintln!("[fig2] training {label} for {epochs} epochs ...");
        let r = train(&model, &data, &mnist_opts(mode, epochs, 13))?;
        let w0: Vec<f32> =
            r.state.param_vec(0)?.iter().map(|v| v / h_scale).collect();
        let hist = Histogram::build(&w0, -1.0, 1.0, 40);
        let path = format!("fig2_{label}.csv");
        std::fs::write(&path, hist.to_csv())?;
        let frac = hist.mass_beyond(0.9);
        polarization.push((label, frac));
        println!("\nFigure 2 ({label} BinaryConnect), first-layer w/H after {epochs} epochs:");
        print!("{}", hist.to_ascii(60));
        println!("mass at |w/H| >= 0.9: {:.1}%   (wrote {path})", frac * 100.0);
    }
    println!(
        "\npaper's qualitative claim: training polarizes the real weights toward ±1;\n\
         measured polarization — det {:.1}%, stoch {:.1}% (initialization would give ~5%).",
        polarization[0].1 * 100.0,
        polarization[1].1 * 100.0
    );
    Ok(())
}
