//! Hardware claims (paper Sec. 1 and Sec. 5), from the op-count model:
//!
//!   * BinaryConnect removes the multiplications from forward + backward
//!     propagation — about 2/3 of all training multiplications -> the
//!     paper's "speed-up by a factor of 3 at training time" on
//!     multiplier-bound hardware.
//!   * Test-time deterministic BC: no multiplications in the weight inner
//!     loops and >= 16x less weight memory (vs 16-bit floats; 32x vs f32).
//!
//! Model specs come from the builtin registry (including the full-scale
//! CNN specs, which the cost model can price without executing them).
//!
//! Run: cargo bench --bench hw_claims

use binaryconnect::bench_harness::Table;
use binaryconnect::hw;
use binaryconnect::runtime::reference::builtin_info;
use binaryconnect::util::error::Result;

fn spatial_of(name: &str) -> u64 {
    if !name.starts_with("conv") {
        return 1;
    }
    let idx: usize = name
        .trim_start_matches("conv")
        .split('.')
        .next()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let hw = 32usize >> (idx / 2).min(3); // SAME conv + MP2 pairs: 32,32,16,16,8,8
    (hw * hw) as u64
}

fn main() -> Result<()> {
    let names = ["mlp", "cnn", "cnn_small"];

    let mut table = Table::new(&[
        "model",
        "mults/step (real)",
        "mults/step (BC)",
        "removed",
        "speedup (mult-bound)",
    ]);
    for name in names {
        let info = builtin_info(name).expect("builtin spec");
        let real = hw::step_cost(&info.params, info.batch as u64, false, spatial_of);
        let bc = hw::step_cost(&info.params, info.batch as u64, true, spatial_of);
        let removed = hw::mult_reduction(&real, &bc);
        table.row(&[
            name.to_string(),
            format!("{:.3e}", real.total_mults() as f64),
            format!("{:.3e}", bc.total_mults() as f64),
            format!("{:.1}%", removed * 100.0),
            format!("{:.2}x", 1.0 / (1.0 - removed)),
        ]);
    }
    println!("\ntraining-time multiplication model (paper claims ~2/3 removed, ~3x):");
    table.print();

    let mut mem = Table::new(&["model", "f32 weights", "f16 weights", "packed (1-bit)", "vs f16"]);
    for name in names {
        let info = builtin_info(name).expect("builtin spec");
        let m = hw::weight_memory(&info.params);
        mem.row(&[
            name.to_string(),
            format!("{} KiB", m.f32_bytes / 1024),
            format!("{} KiB", m.f16_bytes / 1024),
            format!("{} KiB", m.packed_bytes / 1024),
            format!("{}x", m.f16_bytes / m.packed_bytes.max(1)),
        ]);
    }
    println!("\ntest-time weight memory (paper claims >= 16x vs 16-bit):");
    mem.print();

    println!("\nphase breakdown for the MLP (per step, batch included):");
    let info = builtin_info("mlp").expect("builtin spec");
    let real = hw::step_cost(&info.params, info.batch as u64, false, spatial_of);
    let bc = hw::step_cost(&info.params, info.batch as u64, true, spatial_of);
    let mut ph = Table::new(&["phase", "real mults", "BC mults", "adds (both)"]);
    ph.row(&[
        "1. forward".into(),
        format!("{:.3e}", real.forward.mults as f64),
        format!("{:.3e}", bc.forward.mults as f64),
        format!("{:.3e}", real.forward.adds as f64),
    ]);
    ph.row(&[
        "2. backward".into(),
        format!("{:.3e}", real.backward.mults as f64),
        format!("{:.3e}", bc.backward.mults as f64),
        format!("{:.3e}", real.backward.adds as f64),
    ]);
    ph.row(&[
        "3. update".into(),
        format!("{:.3e}", real.update.mults as f64),
        format!("{:.3e}", bc.update.mults as f64),
        format!("{:.3e}", real.update.adds as f64),
    ]);
    ph.print();
    println!("(phases 1-2 go multiplication-free under BC; phase 3 keeps its real MACs)");
    Ok(())
}
