//! Minimal property-based testing harness (the offline registry has no
//! proptest/quickcheck).
//!
//! `check` runs a property over N generated cases from a seeded RNG and, on
//! failure, reports the failing case's Debug form plus the seed that
//! reproduces it. No shrinking — generators are kept small-biased instead
//! (sizes are drawn log-uniformly so tiny cases appear often).

use crate::util::Rng;

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // Seed can be pinned via env for reproducing CI failures.
        let seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xB17E5);
        Self { cases: 64, seed }
    }
}

/// Run `prop` over `cfg.cases` values produced by `gen`.
/// Panics with a reproducible report on the first failure.
pub fn check_with<T: std::fmt::Debug>(
    cfg: &Config,
    name: &str,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let value = gen(&mut rng);
        if let Err(msg) = prop(&value) {
            panic!(
                "property '{name}' failed on case {case} (PROP_SEED={case_seed}):\n  \
                 input: {value:?}\n  error: {msg}"
            );
        }
    }
}

/// `check` with the default config.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    gen: impl FnMut(&mut Rng) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    check_with(&Config::default(), name, gen, prop);
}

/// Log-uniform size in [1, max] — biases toward small cases.
pub fn log_size(rng: &mut Rng, max: usize) -> usize {
    let lmax = (max as f64).ln();
    ((rng.uniform_f64() * lmax).exp() as usize).clamp(1, max)
}

/// A vector of standard-normal f32s with log-uniform length.
pub fn normal_vec(rng: &mut Rng, max_len: usize) -> Vec<f32> {
    let n = log_size(rng, max_len);
    (0..n).map(|_| rng.normal()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut seen = 0;
        check(
            "counts",
            |r| r.below(100),
            |_| {
                seen += 1;
                Ok(())
            },
        );
        assert_eq!(seen, Config::default().cases);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports() {
        check("fails", |r| r.below(10), |&v| if v < 10 { Err("boom".into()) } else { Ok(()) });
    }

    #[test]
    fn log_size_in_bounds_and_biased_small() {
        let mut rng = Rng::new(1);
        let mut small = 0;
        for _ in 0..1000 {
            let s = log_size(&mut rng, 1000);
            assert!((1..=1000).contains(&s));
            if s <= 31 {
                small += 1;
            }
        }
        // log-uniform: P(size <= sqrt-ish range) ~ 1/2
        assert!(small > 300, "small sizes too rare: {small}");
    }

    #[test]
    fn normal_vec_length_bounds() {
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let v = normal_vec(&mut rng, 50);
            assert!(!v.is_empty() && v.len() <= 50);
        }
    }
}
