//! Dataset substrate: containers, synthetic generators, real-file loaders.
//!
//! `load_or_synth` implements the substitution policy from DESIGN.md par.7:
//! real MNIST / CIFAR-10 / SVHN files are used when present under the data
//! directory, otherwise the procedural generators produce shape-identical
//! class-structured stand-ins.

pub mod dataset;
pub mod glyph;
pub mod loaders;
pub mod synth;

pub use dataset::{Dataset, SplitData};

use std::path::Path;

/// Which benchmark a run targets; carries the paper's protocol constants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Corpus {
    Mnist,
    Cifar10,
    Svhn,
}

impl Corpus {
    pub fn parse(s: &str) -> Option<Corpus> {
        match s.to_ascii_lowercase().as_str() {
            "mnist" => Some(Corpus::Mnist),
            "cifar10" | "cifar-10" | "cifar" => Some(Corpus::Cifar10),
            "svhn" => Some(Corpus::Svhn),
            _ => None,
        }
    }

    /// Validation-set size as a fraction of the paper's (train, val) split:
    /// MNIST holds out the last 10000 of 60000, CIFAR-10 the last 5000 of
    /// 50000, SVHN we mirror CIFAR-10's 10%.
    pub fn val_fraction(self) -> f64 {
        match self {
            Corpus::Mnist => 10_000.0 / 60_000.0,
            Corpus::Cifar10 => 5_000.0 / 50_000.0,
            Corpus::Svhn => 0.1,
        }
    }
}

/// Load a (train, test) pair: real files when available, synthetic
/// otherwise. `n_train`/`n_test` bound the sizes (0 = full real size or a
/// CPU-scale default for synthetic).
pub fn load_or_synth(
    corpus: Corpus,
    data_dir: Option<&Path>,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> (Dataset, Dataset, bool) {
    if let Some(dir) = data_dir {
        let loaded = match corpus {
            Corpus::Mnist => loaders::load_mnist(dir, true)
                .and_then(|tr| loaders::load_mnist(dir, false).map(|te| (tr, te))),
            Corpus::Cifar10 => loaders::load_cifar10(dir, true)
                .and_then(|tr| loaders::load_cifar10(dir, false).map(|te| (tr, te))),
            Corpus::Svhn => loaders::load_svhn(dir, true)
                .and_then(|tr| loaders::load_svhn(dir, false).map(|te| (tr, te))),
        };
        if let Ok((mut tr, mut te)) = loaded {
            if n_train > 0 && n_train < tr.len() {
                tr = tr.slice(0, n_train);
            }
            if n_test > 0 && n_test < te.len() {
                te = te.slice(0, n_test);
            }
            return (tr, te, true);
        }
    }
    let (def_train, def_test) = (8_000, 2_000);
    let ntr = if n_train > 0 { n_train } else { def_train };
    let nte = if n_test > 0 { n_test } else { def_test };
    let (tr, te) = match corpus {
        Corpus::Mnist => (synth::synth_mnist(ntr, seed), synth::synth_mnist(nte, seed + 1)),
        Corpus::Cifar10 => (synth::synth_cifar(ntr, seed), synth::synth_cifar(nte, seed + 1)),
        Corpus::Svhn => (synth::synth_svhn(ntr, seed), synth::synth_svhn(nte, seed + 1)),
    };
    (tr, te, false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_parse() {
        assert_eq!(Corpus::parse("MNIST"), Some(Corpus::Mnist));
        assert_eq!(Corpus::parse("cifar-10"), Some(Corpus::Cifar10));
        assert_eq!(Corpus::parse("nope"), None);
    }

    #[test]
    fn synth_fallback_sizes() {
        let (tr, te, real) = load_or_synth(Corpus::Mnist, None, 100, 40, 7);
        assert!(!real);
        assert_eq!(tr.len(), 100);
        assert_eq!(te.len(), 40);
    }

    #[test]
    fn train_and_test_sets_differ() {
        let (tr, te, _) = load_or_synth(Corpus::Cifar10, None, 50, 50, 7);
        assert_ne!(tr.x, te.x);
    }
}
