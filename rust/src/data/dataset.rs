//! Core dataset container used by the pipeline, preprocessing and trainer.

/// An in-memory labelled image dataset, row-major f32 features.
#[derive(Clone)]
pub struct Dataset {
    pub name: String,
    /// n * dim feature matrix, row-major.
    pub x: Vec<f32>,
    /// n labels in 0..n_classes.
    pub labels: Vec<u8>,
    /// flattened feature dimension (h * w * c).
    pub dim: usize,
    /// (height, width, channels) of one example.
    pub shape: (usize, usize, usize),
    pub n_classes: usize,
}

impl Dataset {
    pub fn new(
        name: impl Into<String>,
        shape: (usize, usize, usize),
        n_classes: usize,
    ) -> Self {
        let dim = shape.0 * shape.1 * shape.2;
        Self { name: name.into(), x: vec![], labels: vec![], dim, shape, n_classes }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    pub fn push(&mut self, row: &[f32], label: u8) {
        debug_assert_eq!(row.len(), self.dim);
        debug_assert!((label as usize) < self.n_classes);
        self.x.extend_from_slice(row);
        self.labels.push(label);
    }

    /// Split off the LAST `n_tail` examples (the paper uses the last 10k /
    /// 5k training samples as the validation set — Sec. 3.1 / 3.2).
    pub fn split_tail(&self, n_tail: usize) -> (Dataset, Dataset) {
        assert!(n_tail <= self.len(), "tail split larger than dataset");
        let n_head = self.len() - n_tail;
        let head = self.slice(0, n_head);
        let tail = self.slice(n_head, self.len());
        (head, tail)
    }

    /// Contiguous [lo, hi) sub-dataset (copies).
    pub fn slice(&self, lo: usize, hi: usize) -> Dataset {
        assert!(lo <= hi && hi <= self.len());
        Dataset {
            name: self.name.clone(),
            x: self.x[lo * self.dim..hi * self.dim].to_vec(),
            labels: self.labels[lo..hi].to_vec(),
            dim: self.dim,
            shape: self.shape,
            n_classes: self.n_classes,
        }
    }

    /// Per-class example counts (sanity checks, class-balance tests).
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_classes];
        for &l in &self.labels {
            counts[l as usize] += 1;
        }
        counts
    }
}

/// Train / validation / test triple, the unit the coordinator consumes.
#[derive(Clone)]
pub struct SplitData {
    pub train: Dataset,
    pub val: Dataset,
    pub test: Dataset,
}

impl SplitData {
    /// Paper protocol: carve validation off the tail of the training set.
    pub fn from_train_test(train: Dataset, test: Dataset, n_val: usize) -> Self {
        let (train, val) = train.split_tail(n_val);
        Self { train, val, test }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let mut d = Dataset::new("t", (1, 2, 1), 3);
        for i in 0..10u8 {
            d.push(&[i as f32, -(i as f32)], i % 3);
        }
        d
    }

    #[test]
    fn push_and_row() {
        let d = tiny();
        assert_eq!(d.len(), 10);
        assert_eq!(d.dim, 2);
        assert_eq!(d.row(3), &[3.0, -3.0]);
    }

    #[test]
    fn split_tail_keeps_order() {
        let d = tiny();
        let (head, tail) = d.split_tail(4);
        assert_eq!(head.len(), 6);
        assert_eq!(tail.len(), 4);
        assert_eq!(tail.row(0), &[6.0, -6.0]);
        assert_eq!(head.labels, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn class_counts_sum() {
        let d = tiny();
        let c = d.class_counts();
        assert_eq!(c.iter().sum::<usize>(), 10);
        assert_eq!(c, vec![4, 3, 3]);
    }

    #[test]
    #[should_panic]
    fn split_tail_too_large_panics() {
        tiny().split_tail(11);
    }
}
