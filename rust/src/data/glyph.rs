//! Procedural digit glyph rendering: the shared substrate behind the
//! synthetic MNIST and SVHN generators (DESIGN.md par.7 substitutions).
//!
//! Each digit 0-9 is a set of polyline strokes in a unit box; rendering
//! applies a random affine jitter (rotation, scale, shear, translation),
//! draws anti-aliased strokes with randomized thickness, then adds pixel
//! noise. The result is a class-structured image distribution that a
//! permutation-invariant MLP must genuinely learn — which is what the
//! paper's regularization comparison needs.

use crate::util::Rng;

/// Stroke endpoints in [0,1]^2 glyph space, (x0, y0, x1, y1).
type Seg = (f32, f32, f32, f32);

/// Polyline skeletons per digit (x grows right, y grows DOWN).
pub fn digit_segments(d: u8) -> Vec<Seg> {
    // 7-segment-style frame with diagonals where it helps separability.
    const L: f32 = 0.30; // left
    const R: f32 = 0.70; // right
    const T: f32 = 0.18; // top
    const M: f32 = 0.50; // middle
    const B: f32 = 0.82; // bottom
    match d {
        0 => vec![(L, T, R, T), (R, T, R, B), (R, B, L, B), (L, B, L, T), (L, T, R, B)],
        1 => vec![(0.5, T, 0.5, B), (0.38, T + 0.10, 0.5, T)],
        2 => vec![(L, T, R, T), (R, T, R, M), (R, M, L, B), (L, B, R, B)],
        3 => vec![(L, T, R, T), (R, T, R, B), (L, M, R, M), (L, B, R, B)],
        4 => vec![(L, T, L, M), (L, M, R, M), (R, T, R, B)],
        5 => vec![(R, T, L, T), (L, T, L, M), (L, M, R, M), (R, M, R, B), (R, B, L, B)],
        6 => vec![(R, T, L, T), (L, T, L, B), (L, B, R, B), (R, B, R, M), (R, M, L, M)],
        7 => vec![(L, T, R, T), (R, T, 0.45, B)],
        8 => vec![(L, T, R, T), (R, T, R, B), (R, B, L, B), (L, B, L, T), (L, M, R, M)],
        9 => vec![(R, M, L, M), (L, M, L, T), (L, T, R, T), (R, T, R, B), (R, B, L, B)],
        _ => panic!("digit out of range: {d}"),
    }
}

/// Affine jitter parameters drawn per sample.
pub struct Jitter {
    pub rot: f32,
    pub scale_x: f32,
    pub scale_y: f32,
    pub shear: f32,
    pub dx: f32,
    pub dy: f32,
    pub thickness: f32,
    pub intensity: f32,
}

impl Jitter {
    pub fn sample(rng: &mut Rng) -> Self {
        Self {
            rot: rng.range(-0.26, 0.26), // ~±15 degrees
            scale_x: rng.range(0.80, 1.15),
            scale_y: rng.range(0.80, 1.15),
            shear: rng.range(-0.15, 0.15),
            dx: rng.range(-0.07, 0.07),
            dy: rng.range(-0.07, 0.07),
            thickness: rng.range(0.045, 0.085),
            intensity: rng.range(0.75, 1.0),
        }
    }

    /// Map a glyph-space point through the jitter, still in unit coords.
    fn apply(&self, x: f32, y: f32) -> (f32, f32) {
        let (cx, cy) = (x - 0.5, y - 0.5);
        let xs = cx * self.scale_x + cy * self.shear;
        let ys = cy * self.scale_y;
        let (s, c) = self.rot.sin_cos();
        let xr = xs * c - ys * s;
        let yr = xs * s + ys * c;
        (xr + 0.5 + self.dx, yr + 0.5 + self.dy)
    }
}

fn dist_to_seg(px: f32, py: f32, seg: &Seg) -> f32 {
    let (x0, y0, x1, y1) = *seg;
    let (dx, dy) = (x1 - x0, y1 - y0);
    let len2 = dx * dx + dy * dy;
    let t = if len2 > 0.0 {
        (((px - x0) * dx + (py - y0) * dy) / len2).clamp(0.0, 1.0)
    } else {
        0.0
    };
    let (qx, qy) = (x0 + t * dx, y0 + t * dy);
    ((px - qx).powi(2) + (py - qy).powi(2)).sqrt()
}

/// Render digit `d` into an `hw x hw` grayscale buffer in [0,1].
pub fn render_digit(d: u8, hw: usize, rng: &mut Rng, noise: f32) -> Vec<f32> {
    let jit = Jitter::sample(rng);
    let segs: Vec<Seg> = digit_segments(d)
        .iter()
        .map(|&(x0, y0, x1, y1)| {
            let (a, b) = jit.apply(x0, y0);
            let (c, e) = jit.apply(x1, y1);
            (a, b, c, e)
        })
        .collect();
    let mut img = vec![0f32; hw * hw];
    let t = jit.thickness;
    for py in 0..hw {
        for px in 0..hw {
            let ux = (px as f32 + 0.5) / hw as f32;
            let uy = (py as f32 + 0.5) / hw as f32;
            let mut dmin = f32::INFINITY;
            for s in &segs {
                dmin = dmin.min(dist_to_seg(ux, uy, s));
            }
            // soft-edged stroke: 1 inside, linear falloff over one pixel
            let edge = 1.0 / hw as f32;
            let v = ((t - dmin) / edge + 0.5).clamp(0.0, 1.0) * jit.intensity;
            img[py * hw + px] = v;
        }
    }
    if noise > 0.0 {
        for v in img.iter_mut() {
            *v = (*v + noise * rng.normal()).clamp(0.0, 1.0);
        }
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_digits_have_segments() {
        for d in 0..10u8 {
            assert!(!digit_segments(d).is_empty());
        }
    }

    #[test]
    fn render_is_in_unit_range_and_nonempty() {
        let mut rng = Rng::new(1);
        for d in 0..10u8 {
            let img = render_digit(d, 28, &mut rng, 0.05);
            assert_eq!(img.len(), 784);
            let mx = img.iter().cloned().fold(0.0f32, f32::max);
            let mn = img.iter().cloned().fold(1.0f32, f32::min);
            assert!(mx <= 1.0 && mn >= 0.0);
            assert!(mx > 0.5, "digit {d} rendered too faint: {mx}");
            let ink: f32 = img.iter().sum();
            assert!(ink > 10.0, "digit {d} has almost no ink: {ink}");
        }
    }

    #[test]
    fn different_digits_look_different() {
        // Render without jitter-heavy noise and compare mean absolute
        // difference between class prototypes.
        let mut imgs = vec![];
        for d in 0..10u8 {
            let mut acc = vec![0f32; 784];
            for seed in 0..8u64 {
                let mut rng = Rng::new(seed * 10 + d as u64);
                let img = render_digit(d, 28, &mut rng, 0.0);
                for (a, b) in acc.iter_mut().zip(img) {
                    *a += b / 8.0;
                }
            }
            imgs.push(acc);
        }
        for a in 0..10 {
            for b in (a + 1)..10 {
                let mad: f32 = imgs[a]
                    .iter()
                    .zip(&imgs[b])
                    .map(|(x, y)| (x - y).abs())
                    .sum::<f32>()
                    / 784.0;
                assert!(mad > 0.02, "digits {a} and {b} are too similar: {mad}");
            }
        }
    }

    #[test]
    fn same_seed_same_image() {
        let a = render_digit(5, 28, &mut Rng::new(7), 0.05);
        let b = render_digit(5, 28, &mut Rng::new(7), 0.05);
        assert_eq!(a, b);
    }
}
