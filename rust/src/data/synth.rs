//! Synthetic dataset generators standing in for MNIST / CIFAR-10 / SVHN.
//!
//! The paper's datasets are not redistributable inside this environment, so
//! we synthesize class-structured image distributions of identical shape
//! and protocol (DESIGN.md par.7). What matters for reproducing the paper's
//! *claims* is that the task (a) is learnable from raw pixels, (b) has
//! enough intra-class variation to overfit on — otherwise regularizers
//! cannot be compared. Real files, when present under `--data-dir`, take
//! priority (see `loaders.rs`).

use super::dataset::Dataset;
use super::glyph::render_digit;
use crate::util::Rng;

/// MNIST stand-in: 28x28 grayscale jittered digit glyphs.
pub fn synth_mnist(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x4D4E4953_54000000); // "MNIST"
    let mut ds = Dataset::new("synth-mnist", (28, 28, 1), 10);
    for i in 0..n {
        let label = (i % 10) as u8; // balanced classes
        let mut r = rng.fork(i as u64);
        let img = render_digit(label, 28, &mut r, 0.06);
        ds.push(&img, label);
    }
    ds
}

/// SVHN stand-in: 32x32 RGB digit over colored, cluttered background.
pub fn synth_svhn(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x5356484E_00000000); // "SVHN"
    let mut ds = Dataset::new("synth-svhn", (32, 32, 3), 10);
    let mut row = vec![0f32; 32 * 32 * 3];
    for i in 0..n {
        let label = (i % 10) as u8;
        let mut r = rng.fork(i as u64);
        let glyph = render_digit(label, 32, &mut r, 0.0);
        // background: smooth color gradient + speckle, like house facades
        let bg = [r.range(0.1, 0.9), r.range(0.1, 0.9), r.range(0.1, 0.9)];
        let fg = [r.range(0.0, 1.0), r.range(0.0, 1.0), r.range(0.0, 1.0)];
        let gx = r.range(-0.3, 0.3);
        let gy = r.range(-0.3, 0.3);
        for y in 0..32 {
            for x in 0..32 {
                let g = glyph[y * 32 + x];
                let grad = gx * (x as f32 / 32.0 - 0.5) + gy * (y as f32 / 32.0 - 0.5);
                for c in 0..3 {
                    let base = (bg[c] + grad + 0.05 * r.normal()).clamp(0.0, 1.0);
                    let v = base * (1.0 - g) + fg[c] * g;
                    row[(y * 32 + x) * 3 + c] = v.clamp(0.0, 1.0);
                }
            }
        }
        ds.push(&row, label);
    }
    ds
}

/// Per-class visual signature for the CIFAR-10 stand-in.
struct ClassSig {
    hue: [f32; 3],
    hue2: [f32; 3],
    freq: f32,
    angle: f32,
    shape: u8, // 0 disk, 1 square, 2 triangle, 3 ring, 4 cross
}

fn class_sig(c: u8) -> ClassSig {
    // deterministic per-class parameters, spread across visual space
    let mut r = Rng::new(0xC1FA_u64 * 31 + c as u64);
    let hue = [r.range(0.1, 0.9), r.range(0.1, 0.9), r.range(0.1, 0.9)];
    let hue2 = [1.0 - hue[0], 1.0 - hue[1], (hue[2] + 0.5) % 1.0];
    ClassSig {
        hue,
        hue2,
        freq: 1.0 + (c % 5) as f32,
        angle: (c as f32) * 0.314,
        shape: c % 5,
    }
}

fn shape_mask(shape: u8, ux: f32, uy: f32, cx: f32, cy: f32, rad: f32) -> f32 {
    let dx = ux - cx;
    let dy = uy - cy;
    match shape {
        0 => ((rad - (dx * dx + dy * dy).sqrt()) * 24.0).clamp(0.0, 1.0),
        1 => {
            let d = dx.abs().max(dy.abs());
            ((rad - d) * 24.0).clamp(0.0, 1.0)
        }
        2 => {
            // downward triangle
            let inside = dy > -rad && dx.abs() < (rad - dy) * 0.6;
            if inside { 1.0 } else { 0.0 }
        }
        3 => {
            let d = (dx * dx + dy * dy).sqrt();
            (1.0 - ((d - rad * 0.8).abs() / (rad * 0.25)).min(1.0)).max(0.0)
        }
        _ => {
            let in_h = dy.abs() < rad * 0.25 && dx.abs() < rad;
            let in_v = dx.abs() < rad * 0.25 && dy.abs() < rad;
            if in_h || in_v { 1.0 } else { 0.0 }
        }
    }
}

/// CIFAR-10 stand-in: 32x32 RGB class-conditional texture + shape.
pub fn synth_cifar(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xC1FA_5210_0000_0000);
    let mut ds = Dataset::new("synth-cifar", (32, 32, 3), 10);
    let sigs: Vec<ClassSig> = (0..10).map(|c| class_sig(c as u8)).collect();
    let mut row = vec![0f32; 32 * 32 * 3];
    for i in 0..n {
        let label = (i % 10) as u8;
        let sig = &sigs[label as usize];
        let mut r = rng.fork(i as u64);
        let cx = r.range(0.35, 0.65);
        let cy = r.range(0.35, 0.65);
        let rad = r.range(0.18, 0.30);
        let phase = r.range(0.0, std::f32::consts::TAU);
        let angle = sig.angle + r.range(-0.2, 0.2);
        let (sa, ca) = angle.sin_cos();
        let bright = r.range(0.7, 1.1);
        for y in 0..32 {
            for x in 0..32 {
                let ux = x as f32 / 32.0;
                let uy = y as f32 / 32.0;
                // oriented sinusoid texture at a class-specific frequency
                let t = ((ux * ca + uy * sa) * sig.freq * std::f32::consts::TAU + phase).sin();
                let tex = 0.5 + 0.35 * t;
                let m = shape_mask(sig.shape, ux, uy, cx, cy, rad);
                for c in 0..3 {
                    let base = sig.hue[c] * tex;
                    let v = (base * (1.0 - m) + sig.hue2[c] * m) * bright
                        + 0.04 * r.normal();
                    row[(y * 32 + x) * 3 + c] = v.clamp(0.0, 1.0);
                }
            }
        }
        ds.push(&row, label);
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_shape_and_balance() {
        let ds = synth_mnist(100, 1);
        assert_eq!(ds.len(), 100);
        assert_eq!(ds.dim, 784);
        assert_eq!(ds.class_counts(), vec![10; 10]);
    }

    #[test]
    fn cifar_svhn_shapes() {
        let c = synth_cifar(20, 2);
        assert_eq!(c.dim, 3072);
        assert_eq!(c.shape, (32, 32, 3));
        let s = synth_svhn(20, 3);
        assert_eq!(s.dim, 3072);
    }

    #[test]
    fn values_in_unit_range() {
        for ds in [synth_mnist(30, 4), synth_cifar(30, 5), synth_svhn(30, 6)] {
            for &v in &ds.x {
                assert!((0.0..=1.0).contains(&v), "{} out of range in {}", v, ds.name);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = synth_cifar(10, 42);
        let b = synth_cifar(10, 42);
        assert_eq!(a.x, b.x);
        let c = synth_cifar(10, 43);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn classes_are_visually_distinct() {
        // nearest-class-prototype classification on raw pixels must beat
        // chance by a wide margin, else the task carries no class signal.
        let ds = synth_cifar(500, 7);
        let mut protos = vec![vec![0f32; ds.dim]; 10];
        let counts = ds.class_counts();
        for i in 0..ds.len() {
            let l = ds.labels[i] as usize;
            for (p, v) in protos[l].iter_mut().zip(ds.row(i)) {
                *p += v / counts[l] as f32;
            }
        }
        let test = synth_cifar(200, 8);
        let mut correct = 0;
        for i in 0..test.len() {
            let r = test.row(i);
            let mut best = (f32::INFINITY, 0usize);
            for (c, p) in protos.iter().enumerate() {
                let d: f32 = p.iter().zip(r).map(|(a, b)| (a - b) * (a - b)).sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 == test.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f32 / test.len() as f32;
        assert!(acc > 0.5, "prototype accuracy too low: {acc}");
    }

    #[test]
    fn classes_have_intra_class_variation() {
        // regularization comparisons need variation inside a class
        let ds = synth_mnist(40, 9);
        let a = ds.row(0); // label 0
        let b = ds.row(10); // label 0 again
        let diff: f32 = a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1.0, "no intra-class variation: {diff}");
    }
}
