//! Real-dataset loaders: MNIST IDX and CIFAR-10 binary formats.
//!
//! If the user drops the original files under a data directory the
//! experiments run on the real corpora; otherwise callers fall back to the
//! synthetic generators. Expected layout (uncompressed):
//!
//!   <dir>/mnist/train-images-idx3-ubyte   + train-labels-idx1-ubyte
//!   <dir>/mnist/t10k-images-idx3-ubyte    + t10k-labels-idx1-ubyte
//!   <dir>/cifar-10-batches-bin/data_batch_{1..5}.bin + test_batch.bin
//!
//! SVHN ships as MATLAB .mat only; convert to CIFAR-style binary records
//! (1 label byte + 3072 CHW bytes) as svhn_train.bin / svhn_test.bin.

use std::fs;
use std::io::Read;
use std::path::Path;

use crate::util::crc32;
use crate::util::error::{Context, Result};
use crate::{bail, ensure};

use super::dataset::Dataset;

/// Optional per-directory checksum manifest: lines of `CRC32HEX FILENAME`
/// (whitespace-separated, `#` starts a comment). When a data file has an
/// entry in its directory's manifest, its CRC32 must match — a silently
/// bit-rotted cached dataset would otherwise train on garbage. Files
/// without an entry, and directories without a manifest, load unverified,
/// so verification is strictly opt-in and nothing breaks when absent.
pub const CHECKSUM_MANIFEST: &str = "checksums.txt";

/// Read `path` fully, verifying its CRC32 against the directory's
/// [`CHECKSUM_MANIFEST`] entry when one exists.
pub fn read_verified(path: &Path) -> Result<Vec<u8>> {
    let mut buf = vec![];
    fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?
        .read_to_end(&mut buf)?;
    let (Some(name), Some(dir)) = (path.file_name().and_then(|n| n.to_str()), path.parent())
    else {
        return Ok(buf);
    };
    let manifest = dir.join(CHECKSUM_MANIFEST);
    let Ok(listing) = fs::read_to_string(&manifest) else {
        return Ok(buf); // no manifest for this directory
    };
    for line in listing.lines() {
        let line = line.split('#').next().unwrap_or("").trim();
        let Some((hex, file)) = line.split_once(char::is_whitespace) else {
            continue; // blank, or no filename to match against
        };
        if file.trim() != name {
            continue;
        }
        let want = u32::from_str_radix(hex, 16).map_err(|_| {
            crate::anyhow!(
                "{}: bad CRC32 hex '{hex}' for entry '{name}'",
                manifest.display()
            )
        })?;
        let got = crc32(&buf);
        ensure!(
            got == want,
            "{}: checksum mismatch: manifest says {want:#010x}, file has {got:#010x} \
             (re-download or update {})",
            path.display(),
            manifest.display()
        );
        return Ok(buf);
    }
    Ok(buf)
}

fn read_u32_be(b: &[u8], off: usize) -> u32 {
    u32::from_be_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

/// Parse an IDX image file (magic 0x00000803) into row-major [0,1] floats.
pub fn load_idx_images(path: &Path) -> Result<(Vec<f32>, usize, usize, usize)> {
    let buf = read_verified(path)?;
    if buf.len() < 16 {
        bail!("{}: truncated IDX header", path.display());
    }
    let magic = read_u32_be(&buf, 0);
    if magic != 0x0000_0803 {
        bail!("{}: bad IDX image magic {magic:#x}", path.display());
    }
    let n = read_u32_be(&buf, 4) as usize;
    let h = read_u32_be(&buf, 8) as usize;
    let w = read_u32_be(&buf, 12) as usize;
    let want = 16 + n * h * w;
    if buf.len() != want {
        bail!("{}: expected {want} bytes, got {}", path.display(), buf.len());
    }
    let x = buf[16..].iter().map(|&b| b as f32 / 255.0).collect();
    Ok((x, n, h, w))
}

/// Parse an IDX label file (magic 0x00000801).
pub fn load_idx_labels(path: &Path) -> Result<Vec<u8>> {
    let buf = read_verified(path)?;
    if buf.len() < 8 {
        bail!("{}: truncated IDX header", path.display());
    }
    let magic = read_u32_be(&buf, 0);
    if magic != 0x0000_0801 {
        bail!("{}: bad IDX label magic {magic:#x}", path.display());
    }
    let n = read_u32_be(&buf, 4) as usize;
    if buf.len() != 8 + n {
        bail!("{}: label count mismatch", path.display());
    }
    Ok(buf[8..].to_vec())
}

/// Load MNIST train or test split from `<dir>/mnist/`.
pub fn load_mnist(dir: &Path, train: bool) -> Result<Dataset> {
    let (img, lbl) = if train {
        ("train-images-idx3-ubyte", "train-labels-idx1-ubyte")
    } else {
        ("t10k-images-idx3-ubyte", "t10k-labels-idx1-ubyte")
    };
    let base = dir.join("mnist");
    let (x, n, h, w) = load_idx_images(&base.join(img))?;
    let labels = load_idx_labels(&base.join(lbl))?;
    if labels.len() != n {
        bail!("mnist: {n} images but {} labels", labels.len());
    }
    let mut ds = Dataset::new("mnist", (h, w, 1), 10);
    ds.x = x;
    ds.labels = labels;
    Ok(ds)
}

/// Parse CIFAR-10-style binary records (1 label + c*h*w CHW bytes) and
/// convert to the HWC layout the models expect.
pub fn load_cifar_records(path: &Path, h: usize, w: usize, c: usize) -> Result<Dataset> {
    let buf = read_verified(path)?;
    let rec = 1 + h * w * c;
    if buf.len() % rec != 0 {
        bail!("{}: size {} not a multiple of record {rec}", path.display(), buf.len());
    }
    let n = buf.len() / rec;
    let mut ds = Dataset::new("cifar-bin", (h, w, c), 10);
    let mut row = vec![0f32; h * w * c];
    for i in 0..n {
        let r = &buf[i * rec..(i + 1) * rec];
        let label = r[0];
        if label > 9 {
            bail!("{}: label {label} out of range at record {i}", path.display());
        }
        // CHW -> HWC
        for ch in 0..c {
            for y in 0..h {
                for x in 0..w {
                    row[(y * w + x) * c + ch] = r[1 + ch * h * w + y * w + x] as f32 / 255.0;
                }
            }
        }
        ds.push(&row, label);
    }
    Ok(ds)
}

/// Load CIFAR-10 from `<dir>/cifar-10-batches-bin/`.
pub fn load_cifar10(dir: &Path, train: bool) -> Result<Dataset> {
    let base = dir.join("cifar-10-batches-bin");
    let mut out = Dataset::new("cifar10", (32, 32, 3), 10);
    let files: Vec<String> = if train {
        (1..=5).map(|i| format!("data_batch_{i}.bin")).collect()
    } else {
        vec!["test_batch.bin".to_string()]
    };
    for f in files {
        let part = load_cifar_records(&base.join(&f), 32, 32, 3)?;
        out.x.extend_from_slice(&part.x);
        out.labels.extend_from_slice(&part.labels);
    }
    out.name = "cifar10".into();
    Ok(out)
}

/// Load SVHN from CIFAR-style converted binaries, if present.
pub fn load_svhn(dir: &Path, train: bool) -> Result<Dataset> {
    let f = if train { "svhn_train.bin" } else { "svhn_test.bin" };
    let mut ds = load_cifar_records(&dir.join("svhn").join(f), 32, 32, 3)?;
    ds.name = "svhn".into();
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("bc_loader_test_{}", std::process::id()));
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn write_idx_images(path: &Path, n: usize, h: usize, w: usize) {
        let mut buf = vec![];
        buf.extend_from_slice(&0x0000_0803u32.to_be_bytes());
        buf.extend_from_slice(&(n as u32).to_be_bytes());
        buf.extend_from_slice(&(h as u32).to_be_bytes());
        buf.extend_from_slice(&(w as u32).to_be_bytes());
        for i in 0..n * h * w {
            buf.push((i % 256) as u8);
        }
        fs::File::create(path).unwrap().write_all(&buf).unwrap();
    }

    fn write_idx_labels(path: &Path, labels: &[u8]) {
        let mut buf = vec![];
        buf.extend_from_slice(&0x0000_0801u32.to_be_bytes());
        buf.extend_from_slice(&(labels.len() as u32).to_be_bytes());
        buf.extend_from_slice(labels);
        fs::File::create(path).unwrap().write_all(&buf).unwrap();
    }

    #[test]
    fn idx_roundtrip() {
        let d = tmpdir();
        let img = d.join("img");
        let lbl = d.join("lbl");
        write_idx_images(&img, 3, 4, 5);
        write_idx_labels(&lbl, &[0, 1, 2]);
        let (x, n, h, w) = load_idx_images(&img).unwrap();
        assert_eq!((n, h, w), (3, 4, 5));
        assert_eq!(x.len(), 60);
        assert!((x[1] - 1.0 / 255.0).abs() < 1e-6);
        assert_eq!(load_idx_labels(&lbl).unwrap(), vec![0, 1, 2]);
    }

    #[test]
    fn idx_bad_magic_rejected() {
        let d = tmpdir();
        let p = d.join("bad");
        fs::File::create(&p).unwrap().write_all(&[0u8; 32]).unwrap();
        assert!(load_idx_images(&p).is_err());
        assert!(load_idx_labels(&p).is_err());
    }

    #[test]
    fn cifar_records_chw_to_hwc() {
        let d = tmpdir();
        let p = d.join("batch.bin");
        // 1 record: label 7, image where channel 0 = 10, ch1 = 20, ch2 = 30
        let h = 2;
        let w = 2;
        let mut buf = vec![7u8];
        for ch in 0..3u8 {
            for _ in 0..h * w {
                buf.push((ch + 1) * 10);
            }
        }
        fs::File::create(&p).unwrap().write_all(&buf).unwrap();
        let ds = load_cifar_records(&p, h, w, 3).unwrap();
        assert_eq!(ds.len(), 1);
        assert_eq!(ds.labels[0], 7);
        let r = ds.row(0);
        // HWC: first pixel has channels (10, 20, 30)/255
        assert!((r[0] - 10.0 / 255.0).abs() < 1e-6);
        assert!((r[1] - 20.0 / 255.0).abs() < 1e-6);
        assert!((r[2] - 30.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn cifar_bad_label_rejected() {
        let d = tmpdir();
        let p = d.join("badlabel.bin");
        let mut buf = vec![10u8]; // invalid class
        buf.extend(vec![0u8; 12]);
        fs::File::create(&p).unwrap().write_all(&buf).unwrap();
        assert!(load_cifar_records(&p, 2, 2, 3).is_err());
    }

    #[test]
    fn missing_files_error_cleanly() {
        let d = tmpdir();
        assert!(load_mnist(&d, true).is_err());
        assert!(load_cifar10(&d, false).is_err());
        assert!(load_svhn(&d, true).is_err());
    }

    #[test]
    fn committed_cifar_fixture_loads_verified_and_pins_hwc() {
        // tiny committed fixture (rust/tests/fixtures/cifar_tiny): 3
        // records of 4x4x3 CHW bytes + label, with a checksums.txt
        // naming the file — so this exercises the *verified* read path
        // against real on-disk data, not test-synthesized bytes. Skip
        // (don't fail) when a stripped checkout omits fixtures.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/cifar_tiny");
        let path = dir.join("tiny_batch.bin");
        if !path.exists() {
            eprintln!("skipping: fixture {} absent", path.display());
            return;
        }
        assert!(dir.join(CHECKSUM_MANIFEST).exists(), "fixture manifest missing");
        let ds = load_cifar_records(&path, 4, 4, 3).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.labels, vec![0, 1, 2]);
        assert_eq!(ds.shape, (4, 4, 3));
        // pin CHW->HWC against the generator formula the fixture was
        // built with: byte = (rec*83 + ch*47 + y*13 + x*5 + 7) % 256
        let r0 = ds.row(0);
        for (ch, want) in [7u8, 54, 101].into_iter().enumerate() {
            assert!((r0[ch] - want as f32 / 255.0).abs() < 1e-6, "r0 ch{ch}");
        }
        let r2 = ds.row(2);
        let px = (4 + 2) * 3; // pixel (y=1, x=2)
        for (ch, want) in [196u8, 243, 34].into_iter().enumerate() {
            assert!((r2[px + ch] - want as f32 / 255.0).abs() < 1e-6, "r2 ch{ch}");
        }
        // bit-rot the fixture in a scratch copy: the manifest must trip
        let d = tmpdir().join("fixture_corrupt");
        fs::create_dir_all(&d).unwrap();
        let mut bytes = fs::read(&path).unwrap();
        bytes[5] ^= 0xFF;
        fs::write(d.join("tiny_batch.bin"), &bytes).unwrap();
        fs::copy(dir.join(CHECKSUM_MANIFEST), d.join(CHECKSUM_MANIFEST)).unwrap();
        let err =
            load_cifar_records(&d.join("tiny_batch.bin"), 4, 4, 3).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
    }

    #[test]
    fn checksum_manifest_verifies_and_rejects() {
        // own subdir: the manifest applies per-directory and must not
        // leak into the other tests sharing tmpdir()
        let d = tmpdir().join("cksum");
        fs::create_dir_all(&d).unwrap();
        let img = d.join("img");
        write_idx_images(&img, 2, 3, 3);
        let bytes = fs::read(&img).unwrap();
        let crc = crate::util::crc32(&bytes);

        // matching entry (plus comments and unrelated entries) -> loads
        fs::write(
            d.join(CHECKSUM_MANIFEST),
            format!(
                "# dataset cache checksums\n{crc:08x}  img\ndeadbeef  other-file # unrelated\n"
            ),
        )
        .unwrap();
        assert!(load_idx_images(&img).is_ok());

        // mismatching entry -> clear error naming both CRCs
        fs::write(d.join(CHECKSUM_MANIFEST), format!("{:08x}  img\n", crc ^ 1)).unwrap();
        let err = load_idx_images(&img).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");

        // malformed hex for the matching file -> error, not silence
        fs::write(d.join(CHECKSUM_MANIFEST), "zzzz  img\n").unwrap();
        let err = load_idx_images(&img).unwrap_err().to_string();
        assert!(err.contains("bad CRC32 hex"), "{err}");

        // no entry for this file -> unverified load succeeds
        fs::write(d.join(CHECKSUM_MANIFEST), "deadbeef  something-else\n").unwrap();
        assert!(load_idx_images(&img).is_ok());

        // no manifest at all -> unverified load succeeds
        fs::remove_file(d.join(CHECKSUM_MANIFEST)).unwrap();
        assert!(load_idx_images(&img).is_ok());
    }
}
