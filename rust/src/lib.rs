//! # BinaryConnect — training DNNs with binary weights during propagations
//!
//! A production-shaped reproduction of Courbariaux, Bengio & David,
//! *BinaryConnect* (NIPS 2015), as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (`python/compile/kernels/`) — Pallas kernels for the
//!   binarization ops, the blocked GEMM, the fused clip-updates and the
//!   squared-hinge loss.
//! * **Layer 2** (`python/compile/`) — the paper's MLP and VGG-ish CNN,
//!   three optimizers, and Algorithm 1 as one jitted `train_step`, lowered
//!   once to HLO text (`make artifacts`).
//! * **Layer 3** (this crate) — the coordinator: datasets, preprocessing,
//!   minibatch pipeline, a backend-pluggable [`runtime::Executor`] with a
//!   pure-Rust reference backend (and, behind the `pjrt` cargo feature,
//!   the PJRT runtime executing the AOT artifacts), the experiment driver
//!   reproducing every table/figure, a bit-packed multiplication-free
//!   inference engine, and the hardware cost model behind the paper's
//!   efficiency claims.
//!
//! The default build is fully self-contained: no Python, no artifacts, no
//! external crates — `cargo test` and every bench/example run end-to-end
//! on the reference backend with synthetic data.
//!
//! See DESIGN.md (repo root) for the module inventory and the
//! backend/feature matrix.

pub mod bench_harness;
pub mod binary;
pub mod coordinator;
pub mod data;
pub mod hw;
pub mod pipeline;
pub mod preprocess;
pub mod prop;
pub mod runtime;
pub mod stats;
pub mod util;
