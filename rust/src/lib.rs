//! # BinaryConnect — training DNNs with binary weights during propagations
//!
//! A production-shaped reproduction of Courbariaux, Bengio & David,
//! *BinaryConnect* (NIPS 2015), as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (`python/compile/kernels/`) — Pallas kernels for the
//!   binarization ops, the blocked GEMM, the fused clip-updates and the
//!   squared-hinge loss.
//! * **Layer 2** (`python/compile/`) — the paper's MLP and VGG-ish CNN,
//!   three optimizers, and Algorithm 1 as one jitted `train_step`, lowered
//!   once to HLO text (`make artifacts`).
//! * **Layer 3** (this crate) — the coordinator: datasets, preprocessing,
//!   minibatch pipeline, a backend-pluggable [`runtime::Executor`] with a
//!   pure-Rust reference backend (and, behind the `pjrt` cargo feature,
//!   the PJRT runtime executing the AOT artifacts), the [`kernel`]
//!   hot-path layer (panel-packed multithreaded f32 GEMM + the packed
//!   sign-GEMM training path over the [`util::pool`] fork-join pool, with
//!   runtime-dispatched register-tiled microkernels — AVX2/SSE2 on
//!   x86-64, NEON on aarch64 — under [`kernel::simd`]),
//!   the experiment driver reproducing every table/figure, a bit-packed
//!   multiplication-free inference engine, the [`serve`] online layer
//!   (HTTP server with dynamic micro-batching over the packed engine,
//!   plus a closed-loop load generator), and the hardware cost model
//!   behind the paper's efficiency claims.
//!
//! The default build is fully self-contained: no Python, no artifacts, no
//! external crates — `cargo test` and every bench/example run end-to-end
//! on the reference backend with synthetic data.
//!
//! See DESIGN.md (repo root) for the module inventory and the
//! backend/feature matrix.

pub mod bench_harness;
pub mod binary;
pub mod conv;
pub mod coordinator;
pub mod data;
pub mod hw;
pub mod kernel;
pub mod pipeline;
pub mod preprocess;
pub mod prop;
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod util;

/// Thread-local allocation counter backing the zero-allocation
/// steady-state `train_step` test (see `runtime/reference.rs`). Compiled
/// into the lib test binary only; integration tests and release builds use
/// the system allocator untouched.
#[cfg(test)]
pub(crate) mod test_alloc {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::cell::Cell;

    thread_local! {
        static ALLOCS: Cell<u64> = const { Cell::new(0) };
    }

    /// Allocations (malloc + realloc) made by the *calling thread* since
    /// process start. Thread-local so concurrently running tests cannot
    /// pollute each other's counts.
    pub fn thread_allocs() -> u64 {
        ALLOCS.with(|c| c.get())
    }

    struct CountingAlloc;

    // SAFETY: delegates verbatim to `System`; only bumps a thread-local
    // counter (a const-initialized, Drop-free TLS cell — no reentrant
    // allocation).
    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
            System.alloc(layout)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
            System.realloc(ptr, layout, new_size)
        }
    }

    #[global_allocator]
    static COUNTER: CountingAlloc = CountingAlloc;
}
