//! # BinaryConnect — training DNNs with binary weights during propagations
//!
//! A production-shaped reproduction of Courbariaux, Bengio & David,
//! *BinaryConnect* (NIPS 2015), as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (`python/compile/kernels/`) — Pallas kernels for the
//!   binarization ops, the blocked GEMM, the fused clip-updates and the
//!   squared-hinge loss.
//! * **Layer 2** (`python/compile/`) — the paper's MLP and VGG-ish CNN,
//!   three optimizers, and Algorithm 1 as one jitted `train_step`, lowered
//!   once to HLO text (`make artifacts`).
//! * **Layer 3** (this crate) — the coordinator: datasets, preprocessing,
//!   minibatch pipeline, the PJRT runtime executing the AOT artifacts, the
//!   experiment driver reproducing every table/figure, a bit-packed
//!   multiplication-free inference engine, and the hardware cost model
//!   behind the paper's efficiency claims.
//!
//! Python never runs on the training/request path; after `make artifacts`
//! the Rust binary is self-contained.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for measured
//! reproductions of Tables 1-2 and Figures 1-3.

pub mod bench_harness;
pub mod binary;
pub mod coordinator;
pub mod data;
pub mod hw;
pub mod pipeline;
pub mod preprocess;
pub mod prop;
pub mod runtime;
pub mod stats;
pub mod util;
