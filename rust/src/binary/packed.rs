//! Bit-packed sign matrices and the multiplication-free dense kernels.
//!
//! Since the kernel-layer refactor this module also powers the *training*
//! hot path: `ReferenceExecutor` packs the binarized weights into a
//! reusable [`BitMatrix`] every step (`pack_det_into` / `pack_stoch_into`,
//! no allocation) and computes the forward `z = H * sign_gemm(a, Wb)` and
//! the STE backward `dX = dZ * Wb^T` (`tmatmul_scaled_into`) with
//! accumulations only — the paper's Sec. 1/5 claim realized inside
//! training, not just inference. Column/row blocks ride the
//! `util::pool` fork-join pool; every output element is produced by
//! exactly one thread, so results are thread-count independent.
//!
//! The accumulation loops go through the runtime-dispatched
//! [`crate::kernel::simd`] microkernel table: in the batched kernels the
//! SIMD lanes map one-to-one onto batch columns (each decoded weight bit
//! adds a contiguous activation stripe 8-at-a-time on AVX2, with the
//! steady-state 64-column chunk held in registers), so every rung is
//! **bit-exact** with the scalar path. The batched forward is
//! *panelized* like the f32 GEMM trio: [`COL_PANEL`] output columns
//! share each [`PK_WORDS`]-word sweep of the packed bits, reusing the
//! hot window of per-bit activation stripes across the panel — a pure
//! re-tiling that leaves every per-element add order (and therefore
//! every bit of output) unchanged; the pre-panel loop survives as
//! [`BitMatrix::matmul_scaled_into_strip`], the `panel_speedup_vs_strip`
//! baseline. The batch-1 forward instead lets each 64-bit sign word
//! drive sign-flips of eight activation lanes at a time (XOR with a mask
//! expanded from the bits) — same math, different association,
//! property-tested against scalar within a 1e-5-scale bound. The `*_isa`
//! variants pin an explicit rung for tests and benches.

use crate::conv::{im2col, pool as cpool};
use crate::data::Dataset;
use crate::kernel::simd::{self, Isa, Kernels};
use crate::util::pool::{global as pool_global, par_rows, SendPtr};
use crate::util::Rng;

/// Output columns processed together by the panelized batched forward:
/// one word-block of the packed weights is decoded against all columns
/// of the panel while its activation stripes are cache-hot.
const COL_PANEL: usize = 8;
/// Packed words (64 input rows each) per panel sweep step. Amortizes the
/// per-call accumulator-strip load/store (eight ymm registers on AVX2)
/// over 256 input rows while keeping the live stripe window L1/L2-sized.
const PK_WORDS: usize = 4;

/// Sign bits of a (k x n) weight matrix, packed along k, one bit-column
/// per output unit: bit=1 means weight +1, bit=0 means -1.
#[derive(Clone)]
pub struct BitMatrix {
    pub k: usize,
    pub n: usize,
    words_per_col: usize,
    /// column-major: col j occupies words[j*wpc .. (j+1)*wpc].
    words: Vec<u64>,
}

impl BitMatrix {
    /// All-(-1) matrix of the given shape; fill via `pack_*_into`.
    pub fn zeroed(k: usize, n: usize) -> BitMatrix {
        let wpc = k.div_ceil(64);
        BitMatrix { k, n, words_per_col: wpc, words: vec![0u64; wpc * n] }
    }

    /// Pack sign(w) from a row-major (k x n) f32 matrix (sign(0) = +1,
    /// matching Eq. 1).
    pub fn pack(w: &[f32], k: usize, n: usize) -> BitMatrix {
        let mut bm = BitMatrix::zeroed(k, n);
        bm.pack_det_into(w, k, n);
        bm
    }

    /// Re-pack sign(w) in place (Eq. 1, sign(0) = +1). Allocation-free
    /// when the shape is unchanged — the training loop calls this every
    /// step on a workspace-owned matrix.
    pub fn pack_det_into(&mut self, w: &[f32], k: usize, n: usize) {
        assert_eq!(w.len(), k * n);
        self.reshape(k, n);
        let wpc = self.words_per_col;
        self.words.fill(0);
        for (row, wrow) in w.chunks_exact(n).enumerate() {
            let (wi, bit) = (row / 64, row % 64);
            let mask = 1u64 << bit;
            for (col, &v) in wrow.iter().enumerate() {
                if v >= 0.0 {
                    self.words[col * wpc + wi] |= mask;
                }
            }
        }
    }

    /// Re-pack a stochastic binarization in place: bit = 1 with
    /// p = hard_sigmoid(w/H) (Eq. 2). Draws one uniform per weight in
    /// row-major order — the exact RNG stream the dense baseline's
    /// `binarize` consumed, so packed and dense training agree.
    pub fn pack_stoch_into(&mut self, w: &[f32], k: usize, n: usize, h: f32, rng: &mut Rng) {
        assert_eq!(w.len(), k * n);
        self.reshape(k, n);
        let wpc = self.words_per_col;
        self.words.fill(0);
        for (row, wrow) in w.chunks_exact(n).enumerate() {
            let (wi, bit) = (row / 64, row % 64);
            let mask = 1u64 << bit;
            for (col, &v) in wrow.iter().enumerate() {
                let p = ((v / h + 1.0) * 0.5).clamp(0.0, 1.0);
                if rng.uniform() < p {
                    self.words[col * wpc + wi] |= mask;
                }
            }
        }
    }

    /// Resize backing storage iff the shape changed (steady state: no-op).
    fn reshape(&mut self, k: usize, n: usize) {
        let wpc = k.div_ceil(64);
        if self.k != k || self.n != n || self.words.len() != wpc * n {
            self.k = k;
            self.n = n;
            self.words_per_col = wpc;
            self.words = vec![0u64; wpc * n];
        }
    }

    /// Rebuild from serialized words (see export.rs).
    pub fn from_words(k: usize, n: usize, words: Vec<u64>) -> BitMatrix {
        let wpc = k.div_ceil(64);
        assert_eq!(words.len(), wpc * n, "word count mismatch");
        BitMatrix { k, n, words_per_col: wpc, words }
    }

    #[inline]
    pub fn col(&self, j: usize) -> &[u64] {
        &self.words[j * self.words_per_col..(j + 1) * self.words_per_col]
    }

    pub fn sign(&self, row: usize, col: usize) -> f32 {
        let w = self.col(col)[row / 64];
        if (w >> (row % 64)) & 1 == 1 { 1.0 } else { -1.0 }
    }

    /// Packed 64-bit words per column: `ceil(k / 64)`. Bits at row
    /// indices `>= k` are padding and are always zero (every packer
    /// clears the buffer first and only sets bits below `k`) — the
    /// invariant the BNN XNOR kernels rely on to count over whole words.
    pub fn words_per_col(&self) -> usize {
        self.words_per_col
    }

    /// True allocated footprint of the packed matrix — `wpc * n` words,
    /// i.e. *including* the zero padding bits that round each column up
    /// to whole 64-bit words (a k=1000 column still occupies 16 words).
    /// `/stats` and the bench reports quote this number, not the
    /// theoretical `k*n/8`.
    pub fn memory_bytes(&self) -> usize {
        self.words.len() * 8
    }

    /// y[b, n] = x[b, k] @ sign(W): multiplication-free inner loop.
    ///
    /// Back-compat wrapper that allocates its own scratch; the hot
    /// training path uses [`BitMatrix::matmul_scaled_into`] with
    /// workspace-owned scratch instead.
    ///
    /// Two regimes (EXPERIMENTS.md par.Perf):
    /// * b == 1: walk each column's set bits and add the selected inputs.
    /// * b > 1: transpose x to k-major once, then every decoded bit adds a
    ///   CONTIGUOUS stripe of b floats — the bit-decode cost is amortized
    ///   across the whole batch and the adds auto-vectorize.
    pub fn matmul(&self, x: &[f32], b: usize, y: &mut [f32]) {
        let mut xt = vec![0f32; if b == 1 { 0 } else { self.k * b }];
        let mut totals = vec![0f32; b];
        self.matmul_scaled_into(x, b, 1.0, y, &mut xt, &mut totals);
    }

    /// y[b, n] = scale * (x[b, k] @ sign(W)), allocation-free given
    /// scratch: `xt` >= k*b (transpose buffer, unused when b == 1) and
    /// `totals` >= b. Columns are computed in parallel over the pool;
    /// each column's reduction order is fixed, so results do not depend
    /// on the thread count.
    pub fn matmul_scaled_into(
        &self,
        x: &[f32],
        b: usize,
        scale: f32,
        y: &mut [f32],
        xt: &mut [f32],
        totals: &mut [f32],
    ) {
        self.matmul_scaled_kern(simd::kernels(), x, b, scale, y, xt, totals);
    }

    /// [`BitMatrix::matmul_scaled_into`] pinned to an explicit ISA rung
    /// (test/bench hook — no process-global dispatch mutation).
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_scaled_into_isa(
        &self,
        isa: Isa,
        x: &[f32],
        b: usize,
        scale: f32,
        y: &mut [f32],
        xt: &mut [f32],
        totals: &mut [f32],
    ) {
        self.matmul_scaled_kern(simd::kernels_for(isa), x, b, scale, y, xt, totals);
    }

    /// [`BitMatrix::matmul_scaled_into`] pinned to the *lane-batched*
    /// kernel even when `b == 1` (where `matmul_scaled_into` would take
    /// the faster single-row sign-flip path instead).
    ///
    /// In the lane-batched kernel every output element accumulates its
    /// column in packed-bit order, independently of the batch size, the
    /// chunk width and the ISA rung (SIMD lanes are batch columns). A
    /// given input row therefore produces **bit-identical** outputs
    /// whether it is computed alone or inside any coalesced batch — the
    /// serving layer's solo ≡ coalesced exactness contract. Scratch
    /// requirements match `matmul_scaled_into` (`xt` >= k*b, `totals`
    /// >= b).
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_scaled_into_batched(
        &self,
        x: &[f32],
        b: usize,
        scale: f32,
        y: &mut [f32],
        xt: &mut [f32],
        totals: &mut [f32],
    ) {
        assert_eq!(x.len(), b * self.k);
        assert_eq!(y.len(), b * self.n);
        self.matmul_batched_scaled(simd::kernels(), x, b, scale, y, xt, totals);
    }

    /// [`BitMatrix::matmul_scaled_into_batched`] pinned to an explicit
    /// ISA rung (test/bench hook — no process-global dispatch mutation).
    /// The BNN forward's escape-hatch layer routes through this so its
    /// `_isa` variants pin every kernel in the pass, not just the XNOR
    /// ones.
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_scaled_into_batched_isa(
        &self,
        isa: Isa,
        x: &[f32],
        b: usize,
        scale: f32,
        y: &mut [f32],
        xt: &mut [f32],
        totals: &mut [f32],
    ) {
        assert_eq!(x.len(), b * self.k);
        assert_eq!(y.len(), b * self.n);
        self.matmul_batched_scaled(simd::kernels_for(isa), x, b, scale, y, xt, totals);
    }

    #[allow(clippy::too_many_arguments)]
    fn matmul_scaled_kern(
        &self,
        kern: &'static Kernels,
        x: &[f32],
        b: usize,
        scale: f32,
        y: &mut [f32],
        xt: &mut [f32],
        totals: &mut [f32],
    ) {
        assert_eq!(x.len(), b * self.k);
        assert_eq!(y.len(), b * self.n);
        if b == 1 {
            self.matmul_single_scaled(kern, x, scale, y);
        } else {
            self.matmul_batched_scaled(kern, x, b, scale, y, xt, totals);
        }
    }

    /// Columns per pool block (single block when the job is small).
    fn col_grain(&self, b: usize) -> usize {
        if self.k * self.n * b < (1 << 16) {
            return self.n.max(1);
        }
        self.n.div_ceil(pool_global().n_threads * 4).max(1)
    }

    /// Batch-1 forward. The scalar rung walks each column's set bits
    /// (selected-sum plus the `2·sel − total` identity); the SIMD rungs
    /// sign-flip eight input lanes per decoded byte of the weight word
    /// (XOR with a mask expanded from the bits) and sum directly.
    fn matmul_single_scaled(
        &self,
        kern: &'static Kernels,
        xrow: &[f32],
        scale: f32,
        y: &mut [f32],
    ) {
        let wpc = self.words_per_col;
        // only the scalar rung's 2·sel − total identity consumes the input
        // sum; the SIMD sign-flip kernels ignore it, so skip the O(k) pass
        let total: f32 = if kern.isa == Isa::Scalar { xrow.iter().sum() } else { 0.0 };
        let words = &self.words;
        let yp = SendPtr(y.as_mut_ptr());
        par_rows(self.n, self.col_grain(1), &|jlo, jhi| {
            // SAFETY: disjoint column ranges of y.
            let ys = unsafe { yp.slice(jlo, jhi - jlo) };
            for (dj, yv) in ys.iter_mut().enumerate() {
                let j = jlo + dj;
                let col = &words[j * wpc..(j + 1) * wpc];
                *yv = scale * (kern.sign_dot)(col, xrow, total);
            }
        });
    }

    /// Shared prologue of the batched kernels: transpose x to k-major
    /// (k x b) stripes — one pass, reused by every column — and compute
    /// the per-row totals (the "- sum_k x_k" term), still
    /// multiplication-free.
    fn batched_prologue<'s>(
        &self,
        x: &[f32],
        b: usize,
        xt: &'s mut [f32],
        totals: &'s mut [f32],
    ) -> (&'s [f32], &'s [f32]) {
        let k = self.k;
        assert!(xt.len() >= k * b, "xt scratch too small");
        assert!(totals.len() >= b, "totals scratch too small");
        let xt = &mut xt[..k * b];
        for (bi, xrow) in x.chunks_exact(k).enumerate() {
            for (ki, &v) in xrow.iter().enumerate() {
                xt[ki * b + bi] = v;
            }
        }
        let totals = &mut totals[..b];
        for (t, xrow) in totals.iter_mut().zip(x.chunks_exact(k)) {
            *t = xrow.iter().sum();
        }
        (xt, totals)
    }

    /// The panelized batched forward: [`COL_PANEL`] output columns share
    /// each [`PK_WORDS`]-word sweep of the packed bits, so the activation
    /// stripes of those 256 input rows are read once per panel while hot
    /// instead of once per column. Bit-exact with the pre-panel strip
    /// kernel on every ISA: `sign_accum` *accumulates* into the carried
    /// strip and word blocks ascend, so each output element sees the
    /// identical per-lane add sequence — which also preserves the serving
    /// layer's solo ≡ coalesced contract (per-column order never depends
    /// on b, the chunk split, or the panel).
    #[allow(clippy::too_many_arguments)]
    fn matmul_batched_scaled(
        &self,
        kern: &'static Kernels,
        x: &[f32],
        b: usize,
        scale: f32,
        y: &mut [f32],
        xt: &mut [f32],
        totals: &mut [f32],
    ) {
        let n = self.n;
        let wpc = self.words_per_col;
        let (xt, totals) = self.batched_prologue(x, b, xt, totals);
        let words = &self.words;
        let yp = SendPtr(y.as_mut_ptr());
        // per-ISA batch chunk: 64 keeps a whole strip in eight ymm
        // registers on AVX2; scalar/SSE2/NEON use 128 to halve the
        // per-column bit-decode passes. Chunking cannot change results —
        // SIMD lanes are batch columns, so every rung accumulates each
        // column in the same order: bit-exact across ISAs, chunk widths
        // and panel splits.
        let chunk = kern.sel_chunk.clamp(1, simd::SEL_CHUNK_MAX);
        par_rows(n, self.col_grain(b), &|jlo, jhi| {
            // one selected-sum strip per panel column, on the stack
            // (keeps the training step allocation-free)
            let mut sel = [0f32; COL_PANEL * simd::SEL_CHUNK_MAX];
            let mut jp = jlo;
            while jp < jhi {
                let jpe = (jp + COL_PANEL).min(jhi);
                let cols = jpe - jp;
                let mut c0 = 0usize;
                while c0 < b {
                    let ce = (c0 + chunk).min(b);
                    let cw = ce - c0;
                    let strips = &mut sel[..cols * cw];
                    strips.fill(0.0);
                    let mut w0 = 0usize;
                    while w0 < wpc {
                        let w1 = (w0 + PK_WORDS).min(wpc);
                        for (pi, strip) in strips.chunks_exact_mut(cw).enumerate() {
                            let j = jp + pi;
                            let col = &words[j * wpc + w0..j * wpc + w1];
                            // the sub-column's bits address xt rows
                            // relative to w0*64, so offset the stripe base
                            (kern.sign_accum)(col, &xt[w0 * 64 * b..], b, c0, strip);
                        }
                        w0 = w1;
                    }
                    for (pi, strip) in strips.chunks_exact(cw).enumerate() {
                        let j = jp + pi;
                        for (bi, &s) in (c0..ce).zip(strip.iter()) {
                            // SAFETY: element (bi, j) is written by exactly
                            // one thread (columns are partitioned).
                            unsafe { yp.write(bi * n + j, scale * (2.0 * s - totals[bi])) };
                        }
                    }
                    c0 = ce;
                }
                jp = jpe;
            }
        });
    }

    /// [`BitMatrix::matmul_scaled_into`] through the pre-panel kernels
    /// (one full-column bit sweep per column-chunk). Perf baseline for
    /// `perf_gemm`'s `packed_panel_*` series; bit-exact with the panel
    /// path for b > 1 and identical to `matmul_scaled_into` at b == 1.
    pub fn matmul_scaled_into_strip(
        &self,
        x: &[f32],
        b: usize,
        scale: f32,
        y: &mut [f32],
        xt: &mut [f32],
        totals: &mut [f32],
    ) {
        assert_eq!(x.len(), b * self.k);
        assert_eq!(y.len(), b * self.n);
        let kern = simd::kernels();
        if b == 1 {
            self.matmul_single_scaled(kern, x, scale, y);
        } else {
            self.matmul_batched_strip(kern, x, b, scale, y, xt, totals);
        }
    }

    /// The pre-panel batched loop, preserved verbatim as the
    /// `panel_speedup_vs_strip` baseline and a bit-exactness oracle for
    /// the panel path.
    #[allow(clippy::too_many_arguments)]
    fn matmul_batched_strip(
        &self,
        kern: &'static Kernels,
        x: &[f32],
        b: usize,
        scale: f32,
        y: &mut [f32],
        xt: &mut [f32],
        totals: &mut [f32],
    ) {
        let n = self.n;
        let wpc = self.words_per_col;
        let (xt, totals) = self.batched_prologue(x, b, xt, totals);
        let words = &self.words;
        let yp = SendPtr(y.as_mut_ptr());
        let chunk = kern.sel_chunk.clamp(1, simd::SEL_CHUNK_MAX);
        par_rows(n, self.col_grain(b), &|jlo, jhi| {
            let mut sel = [0f32; simd::SEL_CHUNK_MAX];
            for j in jlo..jhi {
                let col = &words[j * wpc..(j + 1) * wpc];
                let mut c0 = 0usize;
                while c0 < b {
                    let ce = (c0 + chunk).min(b);
                    let sel = &mut sel[..ce - c0];
                    sel.fill(0.0);
                    (kern.sign_accum)(col, xt, b, c0, sel);
                    for (bi, &s) in (c0..ce).zip(sel.iter()) {
                        // SAFETY: element (bi, j) is written by exactly one
                        // thread (columns are partitioned).
                        unsafe { yp.write(bi * n + j, scale * (2.0 * s - totals[bi])) };
                    }
                    c0 = ce;
                }
            }
        });
    }

    /// dx[b, k] = scale * (dz[b, n] @ sign(W)^T) — the transpose-apply
    /// (STE backward dX = dZ·Wb^T), accumulations only. Scratch: `dzt` >=
    /// n*b (transpose of dz), `acc` >= k*b (per-input selected sums),
    /// `totals` >= b. Parallel over 64-aligned input-row blocks so each
    /// thread owns whole bit-words; thread-count independent.
    #[allow(clippy::too_many_arguments)]
    pub fn tmatmul_scaled_into(
        &self,
        dz: &[f32],
        b: usize,
        scale: f32,
        dx: &mut [f32],
        dzt: &mut [f32],
        acc: &mut [f32],
        totals: &mut [f32],
    ) {
        self.tmatmul_scaled_kern(simd::kernels(), dz, b, scale, dx, dzt, acc, totals);
    }

    /// [`BitMatrix::tmatmul_scaled_into`] pinned to an explicit ISA rung
    /// (test/bench hook — no process-global dispatch mutation).
    #[allow(clippy::too_many_arguments)]
    pub fn tmatmul_scaled_into_isa(
        &self,
        isa: Isa,
        dz: &[f32],
        b: usize,
        scale: f32,
        dx: &mut [f32],
        dzt: &mut [f32],
        acc: &mut [f32],
        totals: &mut [f32],
    ) {
        self.tmatmul_scaled_kern(simd::kernels_for(isa), dz, b, scale, dx, dzt, acc, totals);
    }

    #[allow(clippy::too_many_arguments)]
    fn tmatmul_scaled_kern(
        &self,
        kern: &'static Kernels,
        dz: &[f32],
        b: usize,
        scale: f32,
        dx: &mut [f32],
        dzt: &mut [f32],
        acc: &mut [f32],
        totals: &mut [f32],
    ) {
        let k = self.k;
        let n = self.n;
        let wpc = self.words_per_col;
        assert_eq!(dz.len(), b * n);
        assert_eq!(dx.len(), b * k);
        assert!(dzt.len() >= n * b, "dzt scratch too small");
        assert!(acc.len() >= k * b, "acc scratch too small");
        assert!(totals.len() >= b, "totals scratch too small");
        // transpose dz to n-major (n x b) stripes
        let dzt = &mut dzt[..n * b];
        for (bi, row) in dz.chunks_exact(n).enumerate() {
            for (j, &v) in row.iter().enumerate() {
                dzt[j * b + bi] = v;
            }
        }
        let totals = &mut totals[..b];
        for (t, row) in totals.iter_mut().zip(dz.chunks_exact(n)) {
            *t = row.iter().sum();
        }
        // acc[i*b + t] = sum over columns j with bit(i, j) set of dz[t, j]
        let acc = &mut acc[..k * b];
        let dzt: &[f32] = dzt;
        let words = &self.words;
        let accp = SendPtr(acc.as_mut_ptr());
        let grain = {
            let t = pool_global().n_threads;
            let g = if k * n * b < (1 << 16) { k } else { k.div_ceil(t * 2) };
            g.div_ceil(64).max(1) * 64
        };
        // word-block tile: keep the acc sub-block being scattered into
        // ~L1-sized (64/b words ≈ 16 KiB of acc rows) while streaming all
        // n columns over it. For each acc row the adds still arrive in
        // j-ascending order (a row's word lives in exactly one block), so
        // the tiling never changes a single bit.
        let twb = (64 / b.max(1)).max(1);
        par_rows(k, grain, &|ilo, ihi| {
            // SAFETY: disjoint input-row ranges of acc; 64-aligned blocks
            // mean each bit-word belongs to exactly one range (bits at or
            // beyond k are never set by pack).
            let arows = unsafe { accp.slice(ilo * b, (ihi - ilo) * b) };
            arows.fill(0.0);
            let w0 = ilo / 64;
            let w1 = ihi.div_ceil(64);
            let mut wb = w0;
            while wb < w1 {
                let wbe = (wb + twb).min(w1);
                for j in 0..n {
                    let col = &words[j * wpc..(j + 1) * wpc];
                    let stripe = &dzt[j * b..(j + 1) * b];
                    for wi in wb..wbe {
                        let mut m = col[wi];
                        if m == 0 {
                            continue;
                        }
                        let base = wi * 64;
                        while m != 0 {
                            let t = m.trailing_zeros() as usize;
                            let i = base + t;
                            let arow = &mut arows[(i - ilo) * b..(i - ilo + 1) * b];
                            // lanes are batch columns: bit-exact on every ISA
                            (kern.add)(arow, stripe);
                            m &= m - 1;
                        }
                    }
                }
                wb = wbe;
            }
        });
        // dx[t, i] = scale * (2 * acc[i, t] - totals[t])
        let acc: &[f32] = acc;
        let totals: &[f32] = totals;
        let dxp = SendPtr(dx.as_mut_ptr());
        par_rows(b, 1, &|blo, bhi| {
            for t in blo..bhi {
                // SAFETY: disjoint batch rows of dx.
                let row = unsafe { dxp.slice(t * k, k) };
                let tot = totals[t];
                for (i, v) in row.iter_mut().enumerate() {
                    *v = scale * (2.0 * acc[i * b + t] - tot);
                }
            }
        });
    }
}

/// One packed dense layer with folded batch-norm affine and ReLU.
#[derive(Clone)]
pub struct PackedLayer {
    pub bits: BitMatrix,
    /// per-unit scale (gamma / sqrt(var + eps)); 1.0 when no BN.
    pub scale: Vec<f32>,
    /// per-unit shift (beta - mu * scale, plus bias if any).
    pub shift: Vec<f32>,
    pub relu: bool,
}

impl PackedLayer {
    pub fn forward(&self, x: &[f32], b: usize, y: &mut [f32]) {
        self.bits.matmul(x, b, y);
        self.affine(b, y);
    }

    /// [`PackedLayer::forward`] through the lane-batched kernel for every
    /// batch size (see [`BitMatrix::matmul_scaled_into_batched`]) with
    /// caller scratch — allocation-free, and each row's output is
    /// bit-identical whether served solo or inside a coalesced batch.
    pub fn forward_batched_into(
        &self,
        x: &[f32],
        b: usize,
        y: &mut [f32],
        xt: &mut [f32],
        totals: &mut [f32],
    ) {
        self.bits.matmul_scaled_into_batched(x, b, 1.0, y, xt, totals);
        self.affine(b, y);
    }

    /// Folded BN affine + ReLU applied in place over the matmul output.
    fn affine(&self, b: usize, y: &mut [f32]) {
        let n = self.bits.n;
        assert_eq!(self.scale.len(), n, "scale length must match layer width");
        assert_eq!(self.shift.len(), n, "shift length must match layer width");
        for bi in 0..b {
            let row = &mut y[bi * n..(bi + 1) * n];
            for ((v, &s), &t) in row.iter_mut().zip(&self.scale).zip(&self.shift) {
                *v = *v * s + t;
                if self.relu && *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
    }
}

/// One packed SAME-padding conv layer, lowered onto the sign-GEMM via
/// im2col: the `[kh, kw, cin, cout]` filter bank flattens row-major into
/// a `(kh*kw*cin) x cout` [`BitMatrix`], and the forward is a plain
/// batched sign-GEMM over `b*h*w` patch rows. The ±H weight scale and
/// the eval-mode BN affine are folded into `scale`/`shift` (exactly like
/// [`PackedLayer`]); ReLU always applies (conv layers are never the
/// output), and `pool` appends a MaxPool2x2.
#[derive(Clone)]
pub struct PackedConvLayer {
    /// `(kh*kw*cin) x cout` sign bits of the flattened filter bank.
    pub bits: BitMatrix,
    /// per-channel `H * gamma / sqrt(rvar + eps)`.
    pub scale: Vec<f32>,
    /// per-channel `beta - rmean * gamma / sqrt(rvar + eps)`.
    pub shift: Vec<f32>,
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
    /// input spatial dims (SAME padding keeps them through the conv).
    pub h_in: usize,
    pub w_in: usize,
    /// MaxPool2x2 after the affine+ReLU (halves both spatial dims).
    pub pool: bool,
}

impl PackedConvLayer {
    /// im2col patch width = GEMM reduction dim.
    pub fn patch_k(&self) -> usize {
        self.kh * self.kw * self.cin
    }

    /// Flat input activation size per image.
    pub fn in_dim(&self) -> usize {
        self.h_in * self.w_in * self.cin
    }

    /// Spatial dims after the optional pool.
    pub fn out_hw(&self) -> (usize, usize) {
        if self.pool {
            (self.h_in / 2, self.w_in / 2)
        } else {
            (self.h_in, self.w_in)
        }
    }

    /// Flat output activation size per image.
    pub fn out_dim(&self) -> usize {
        let (h, w) = self.out_hw();
        h * w * self.cout
    }

    /// Folded BN affine + ReLU in place over the `rows x cout` sign-GEMM
    /// output (same per-element ops as [`PackedLayer::affine`], so conv
    /// channels inherit its exactness story).
    fn affine(&self, rows: usize, y: &mut [f32]) {
        let n = self.cout;
        assert_eq!(self.scale.len(), n, "scale length must match cout");
        assert_eq!(self.shift.len(), n, "shift length must match cout");
        for bi in 0..rows {
            let row = &mut y[bi * n..(bi + 1) * n];
            for ((v, &s), &t) in row.iter_mut().zip(&self.scale).zip(&self.shift) {
                *v = *v * s + t;
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
    }
}

/// A fully packed classifier (the paper's deterministic-BC test-time
/// network): an optional conv front (`conv`, empty for MLPs) feeding the
/// dense stack. The last conv layer's flat output is the first dense
/// layer's input — im2col keeps activations `(b, h, w, c)` row-major, so
/// flatten is a no-op.
pub struct PackedMlp {
    pub conv: Vec<PackedConvLayer>,
    pub layers: Vec<PackedLayer>,
    pub in_dim: usize,
    pub classes: usize,
}

/// Reusable scratch for [`PackedMlp::forward_into`]: ping-pong activation
/// buffers plus the transpose/totals scratch of the batched sign-GEMM,
/// sized once for a maximum batch. A warmed workspace makes every
/// subsequent forward allocation-free (counting-allocator tested) — the
/// contract the serving batcher and `test_error` hot loops rely on.
pub struct PackedWorkspace {
    max_batch: usize,
    ping: Vec<f32>,
    pong: Vec<f32>,
    xt: Vec<f32>,
    totals: Vec<f32>,
    /// im2col patch matrix, sized for the largest conv stage (empty for
    /// pure MLPs — the three conv buffers cost dense models nothing).
    patches: Vec<f32>,
    /// pre-pool conv output, sized for the largest *pooled* conv stage.
    prepool: Vec<f32>,
    /// argmax scratch of the pool (serving discards it; sized with
    /// `prepool`).
    pool_idx: Vec<u32>,
}

impl PackedWorkspace {
    /// Batch capacity this workspace was sized for.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Allocated activation-scratch footprint in bytes (ping + pong +
    /// transpose + totals buffers, plus the conv patch/pool scratch for
    /// conv models). The packed-f32 counterpart of
    /// [`crate::binary::BnnWorkspace::memory_bytes`]; surfaced per mode
    /// by `/stats` and the bench reports.
    pub fn memory_bytes(&self) -> usize {
        (self.ping.len()
            + self.pong.len()
            + self.xt.len()
            + self.totals.len()
            + self.patches.len()
            + self.prepool.len()
            + self.pool_idx.len())
            * 4
    }
}

/// Index of the row maximum via `total_cmp` (last max wins, like the
/// `partial_cmp` it replaces, but deterministic and panic-free on NaN —
/// the serving layer feeds this with network-supplied inputs).
pub fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

pub const BN_EPS: f32 = 1e-4;

impl PackedMlp {
    /// Fold (W, BN) stacks into packed layers.
    /// `weights[i]` is row-major (k x n); `bn[i]` is Some((gamma, beta,
    /// mean, var)) for hidden layers, None for the output layer whose
    /// `bias` applies instead.  `bias` belongs to the LAST layer only: a
    /// BN-less hidden layer gets identity scale and zero shift, never the
    /// output bias (whose length would not even match the layer width).
    pub fn build(
        weights: Vec<(Vec<f32>, usize, usize)>,
        bn: Vec<Option<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)>>,
        bias: Option<Vec<f32>>,
    ) -> PackedMlp {
        assert_eq!(weights.len(), bn.len());
        let in_dim = weights[0].1;
        let n_layers = weights.len();
        let mut layers = vec![];
        for (i, ((w, k, n), bn_i)) in weights.into_iter().zip(bn).enumerate() {
            let bits = BitMatrix::pack(&w, k, n);
            let last = i == n_layers - 1;
            let (scale, shift) = match bn_i {
                Some((gamma, beta, mean, var)) => {
                    let scale: Vec<f32> = gamma
                        .iter()
                        .zip(&var)
                        .map(|(&g, &v)| g / (v + BN_EPS).sqrt())
                        .collect();
                    let shift: Vec<f32> = beta
                        .iter()
                        .zip(&mean)
                        .zip(&scale)
                        .map(|((&b, &m), &s)| b - m * s)
                        .collect();
                    (scale, shift)
                }
                None => {
                    let shift = if last {
                        bias.clone().unwrap_or_else(|| vec![0.0; n])
                    } else {
                        vec![0.0; n]
                    };
                    assert_eq!(shift.len(), n, "bias length must match the output width");
                    (vec![1.0; n], shift)
                }
            };
            layers.push(PackedLayer { bits, scale, shift, relu: !last });
        }
        let classes = layers.last().unwrap().bits.n;
        PackedMlp { conv: vec![], layers, in_dim, classes }
    }

    /// Forward a batch, returning logits (b x classes).
    ///
    /// Back-compat wrapper that allocates per call (and takes the
    /// single-row kernel at b == 1); the serving/eval hot paths use
    /// [`PackedMlp::forward_into`] with a reused [`PackedWorkspace`].
    pub fn forward(&self, x: &[f32], b: usize) -> Vec<f32> {
        assert_eq!(x.len(), b * self.in_dim);
        if !self.conv.is_empty() {
            let mut ws = self.workspace(b);
            return self.forward_into(x, b, &mut ws).to_vec();
        }
        let mut cur = x.to_vec();
        for layer in &self.layers {
            let mut next = vec![0f32; b * layer.bits.n];
            layer.forward(&cur, b, &mut next);
            cur = next;
        }
        cur
    }

    /// Widest activation row the net produces (input and the conv
    /// stages' flat post-pool outputs included) — the per-row workspace
    /// buffer size.
    pub fn max_width(&self) -> usize {
        let conv_w = self.conv.iter().map(|c| c.out_dim()).fold(0, usize::max);
        self.layers.iter().map(|l| l.bits.n).fold(self.in_dim.max(conv_w), usize::max)
    }

    /// Buffer lengths a `max_batch`-row [`PackedWorkspace`] needs:
    /// (ping/pong, xt, totals, patches, prepool). Conv stages run the
    /// sign-GEMM over `b*h*w` patch rows, so the patch matrix and the
    /// GEMM transpose scratch scale with the spatial extent, and
    /// `totals` with the row count. Shared with
    /// [`PackedMlp::activation_memory_bytes`](crate::binary::ForwardMode)
    /// so the reported figure cannot drift from the allocation.
    pub(crate) fn workspace_lens(&self, max_batch: usize) -> (usize, usize, usize, usize, usize) {
        let w = self.max_width();
        let mut patches = 0usize;
        let mut prepool = 0usize;
        let mut xt = max_batch * w;
        let mut totals = max_batch;
        for c in &self.conv {
            let rows = max_batch * c.h_in * c.w_in;
            patches = patches.max(rows * c.patch_k());
            xt = xt.max(rows * c.patch_k());
            totals = totals.max(rows);
            if c.pool {
                prepool = prepool.max(rows * c.cout);
            }
        }
        (max_batch * w, xt, totals, patches, prepool)
    }

    /// Build a [`PackedWorkspace`] able to forward batches up to
    /// `max_batch` rows with zero per-call allocations.
    pub fn workspace(&self, max_batch: usize) -> PackedWorkspace {
        assert!(max_batch >= 1, "workspace batch capacity must be >= 1");
        let (pp, xt, totals, patches, prepool) = self.workspace_lens(max_batch);
        PackedWorkspace {
            max_batch,
            ping: vec![0f32; pp],
            pong: vec![0f32; pp],
            xt: vec![0f32; xt],
            totals: vec![0f32; totals],
            patches: vec![0f32; patches],
            prepool: vec![0f32; prepool],
            pool_idx: vec![0u32; prepool / 4],
        }
    }

    /// Forward a batch into workspace-owned buffers, returning the logits
    /// slice (b x classes). Allocation-free, and — because every layer
    /// goes through [`BitMatrix::matmul_scaled_into_batched`] — each
    /// row's logits are **bit-identical** for any batch size the row is
    /// computed in: the serving layer's solo ≡ coalesced contract. Conv
    /// stages keep that contract too: im2col rows, the batched GEMM, the
    /// per-channel affine and the pool all touch image `bi`'s data only
    /// from row block `bi`.
    pub fn forward_into<'ws>(
        &self,
        x: &[f32],
        b: usize,
        ws: &'ws mut PackedWorkspace,
    ) -> &'ws [f32] {
        assert_eq!(x.len(), b * self.in_dim);
        assert!(
            b <= ws.max_batch,
            "batch {b} exceeds the workspace capacity {}",
            ws.max_batch
        );
        ws.ping[..x.len()].copy_from_slice(x);
        let mut in_ping = true;
        for c in &self.conv {
            let (h, w) = (c.h_in, c.w_in);
            let rows = b * h * w;
            let pk = c.patch_k();
            let (src, dst) = if in_ping {
                (&ws.ping, &mut ws.pong)
            } else {
                (&ws.pong, &mut ws.ping)
            };
            im2col::im2col_into(
                &src[..b * c.in_dim()],
                b,
                h,
                w,
                c.cin,
                c.kh,
                c.kw,
                &mut ws.patches[..rows * pk],
            );
            if c.pool {
                let z = &mut ws.prepool[..rows * c.cout];
                c.bits.matmul_scaled_into_batched(
                    &ws.patches[..rows * pk],
                    rows,
                    1.0,
                    z,
                    &mut ws.xt,
                    &mut ws.totals,
                );
                c.affine(rows, z);
                cpool::maxpool2x2_into(
                    z,
                    b,
                    h,
                    w,
                    c.cout,
                    &mut dst[..b * c.out_dim()],
                    &mut ws.pool_idx[..b * c.out_dim()],
                );
            } else {
                let z = &mut dst[..rows * c.cout];
                c.bits.matmul_scaled_into_batched(
                    &ws.patches[..rows * pk],
                    rows,
                    1.0,
                    z,
                    &mut ws.xt,
                    &mut ws.totals,
                );
                c.affine(rows, z);
            }
            in_ping = !in_ping;
        }
        for layer in &self.layers {
            let (k, n) = (layer.bits.k, layer.bits.n);
            let (src, dst) = if in_ping {
                (&ws.ping, &mut ws.pong)
            } else {
                (&ws.pong, &mut ws.ping)
            };
            layer.forward_batched_into(
                &src[..b * k],
                b,
                &mut dst[..b * n],
                &mut ws.xt,
                &mut ws.totals,
            );
            in_ping = !in_ping;
        }
        let out = if in_ping { &ws.ping } else { &ws.pong };
        &out[..b * self.classes]
    }

    /// argmax classification.
    pub fn classify(&self, x: &[f32], b: usize) -> Vec<usize> {
        let logits = self.forward(x, b);
        (0..b)
            .map(|bi| argmax(&logits[bi * self.classes..(bi + 1) * self.classes]))
            .collect()
    }

    /// Test error over a dataset (batched; one reused workspace, so the
    /// whole evaluation allocates only once).
    pub fn test_error(&self, ds: &Dataset, batch: usize) -> f64 {
        let batch = batch.max(1);
        let mut ws = self.workspace(batch);
        let mut wrong = 0usize;
        let mut i = 0;
        while i < ds.len() {
            let hi = (i + batch).min(ds.len());
            let b = hi - i;
            let x = &ds.x[i * ds.dim..hi * ds.dim];
            let logits = self.forward_into(x, b, &mut ws);
            for (bi, &l) in ds.labels[i..hi].iter().enumerate() {
                let row = &logits[bi * self.classes..(bi + 1) * self.classes];
                if argmax(row) != l as usize {
                    wrong += 1;
                }
            }
            i = hi;
        }
        wrong as f64 / ds.len() as f64
    }

    /// Packed weight memory (the paper's ">= 16x reduction" claim: f32
    /// weights / this = 32x). Sums [`BitMatrix::memory_bytes`], so
    /// per-column word padding is included — this is the allocated
    /// footprint, not the theoretical bit count.
    pub fn weight_memory_bytes(&self) -> usize {
        let conv: usize = self.conv.iter().map(|c| c.bits.memory_bytes()).sum();
        conv + self.layers.iter().map(|l| l.bits.memory_bytes()).sum::<usize>()
    }

    pub fn f32_weight_memory_bytes(&self) -> usize {
        let conv: usize = self.conv.iter().map(|c| c.bits.k * c.bits.n * 4).sum();
        conv + self.layers.iter().map(|l| l.bits.k * l.bits.n * 4).sum::<usize>()
    }
}

/// Dense f32 GEMM (y = x @ w) for correctness cross-checks and the
/// packed-vs-float benchmark. Back-compat re-export: the one kernel now
/// lives in [`crate::kernel::gemm_naive`] (the blocked/parallel variants
/// are `kernel::gemm*`), deduped from the copy that used to live here.
pub fn dense_f32(x: &[f32], w: &[f32], b: usize, k: usize, n: usize, y: &mut [f32]) {
    crate::kernel::gemm_naive(x, w, b, k, n, y);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_mat(k: usize, n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..k * n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn pack_roundtrip_signs() {
        let w = vec![0.5, -0.2, 0.0, -1.5, 2.0, -0.1];
        let bm = BitMatrix::pack(&w, 3, 2);
        assert_eq!(bm.sign(0, 0), 1.0);
        assert_eq!(bm.sign(0, 1), -1.0);
        assert_eq!(bm.sign(1, 0), 1.0); // sign(0) = +1
        assert_eq!(bm.sign(1, 1), -1.0);
        assert_eq!(bm.sign(2, 0), 1.0);
        assert_eq!(bm.sign(2, 1), -1.0);
    }

    #[test]
    fn packed_matmul_matches_sign_gemm() {
        for (b, k, n, seed) in [(1, 5, 3, 1u64), (4, 64, 8, 2), (3, 130, 17, 3), (2, 200, 50, 4)] {
            let w = rand_mat(k, n, seed);
            let x = rand_mat(b, k, seed + 100);
            let bm = BitMatrix::pack(&w, k, n);
            let mut y = vec![0f32; b * n];
            bm.matmul(&x, b, &mut y);
            // reference: x @ sign(w)
            let ws: Vec<f32> = w.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
            let mut yref = vec![0f32; b * n];
            dense_f32(&x, &ws, b, k, n, &mut yref);
            for (a, r) in y.iter().zip(&yref) {
                assert!((a - r).abs() < 1e-3 * (1.0 + r.abs()), "{a} vs {r}");
            }
        }
    }

    #[test]
    fn scaled_matmul_matches_unit_scale_times_h() {
        let (b, k, n) = (5, 130, 9);
        let w = rand_mat(k, n, 41);
        let x = rand_mat(b, k, 42);
        let bm = BitMatrix::pack(&w, k, n);
        let mut base = vec![0f32; b * n];
        bm.matmul(&x, b, &mut base);
        let h = 0.37f32;
        let mut scaled = vec![0f32; b * n];
        let mut xt = vec![0f32; k * b];
        let mut totals = vec![0f32; b];
        bm.matmul_scaled_into(&x, b, h, &mut scaled, &mut xt, &mut totals);
        for (s, r) in scaled.iter().zip(&base) {
            assert!((s - h * r).abs() < 1e-4 * (1.0 + r.abs()), "{s} vs {}", h * r);
        }
    }

    #[test]
    fn pack_into_reuses_and_repacks() {
        let (k, n) = (70, 6);
        let w1 = rand_mat(k, n, 50);
        let w2 = rand_mat(k, n, 51);
        let mut bm = BitMatrix::zeroed(k, n);
        bm.pack_det_into(&w1, k, n);
        let fresh1 = BitMatrix::pack(&w1, k, n);
        for row in 0..k {
            for col in 0..n {
                assert_eq!(bm.sign(row, col), fresh1.sign(row, col));
            }
        }
        // repack with different signs: stale bits must be cleared
        bm.pack_det_into(&w2, k, n);
        let fresh2 = BitMatrix::pack(&w2, k, n);
        for row in 0..k {
            for col in 0..n {
                assert_eq!(bm.sign(row, col), fresh2.sign(row, col));
            }
        }
    }

    #[test]
    fn stochastic_pack_matches_dense_binarize_stream() {
        // same seed -> pack_stoch_into bit b equals (binarize draw < p),
        // i.e. the sign the dense baseline would have used.
        let (k, n) = (67, 5);
        let h = 0.25f32;
        let w = rand_mat(k, n, 60);
        let mut bm = BitMatrix::zeroed(k, n);
        let mut rng = Rng::new(99);
        bm.pack_stoch_into(&w, k, n, h, &mut rng);
        let mut rng2 = Rng::new(99);
        for row in 0..k {
            for col in 0..n {
                let v = w[row * n + col];
                let p = ((v / h + 1.0) * 0.5).clamp(0.0, 1.0);
                let want = if rng2.uniform() < p { 1.0 } else { -1.0 };
                assert_eq!(bm.sign(row, col), want, "at ({row},{col})");
            }
        }
    }

    #[test]
    fn panel_forward_bit_exact_vs_strip() {
        // the panelized batched forward is a pure re-tiling of the strip
        // loop: identical per-element add order, so identical bits —
        // across ragged column counts (panel edges), word-boundary k, and
        // batch sizes straddling the sel_chunk width
        for (b, k, n, seed) in [
            (2usize, 70, 7, 300u64), // n < COL_PANEL: one ragged panel
            (5, 64, 8, 301),         // exact word and panel boundaries
            (64, 130, 19, 302),      // two panels + ragged tail
            (129, 257, 33, 303),     // b > sel_chunk on every ISA
        ] {
            let w = rand_mat(k, n, seed);
            let x = rand_mat(b, k, seed + 10);
            let bm = BitMatrix::pack(&w, k, n);
            let mut xt = vec![0f32; k * b];
            let mut totals = vec![0f32; b];
            let mut y_panel = vec![0f32; b * n];
            bm.matmul_scaled_into_batched(&x, b, 0.7, &mut y_panel, &mut xt, &mut totals);
            let mut y_strip = vec![0f32; b * n];
            bm.matmul_scaled_into_strip(&x, b, 0.7, &mut y_strip, &mut xt, &mut totals);
            let pb: Vec<u32> = y_panel.iter().map(|v| v.to_bits()).collect();
            let sb: Vec<u32> = y_strip.iter().map(|v| v.to_bits()).collect();
            assert_eq!(pb, sb, "panel vs strip must be bit-identical (b={b} k={k} n={n})");
        }
    }

    #[test]
    fn tmatmul_matches_dense_transpose_gemm() {
        for (b, k, n, seed) in [(1usize, 70, 9, 70u64), (4, 130, 17, 71), (64, 100, 33, 72)] {
            let w = rand_mat(k, n, seed);
            let dz = rand_mat(b, n, seed + 10);
            let bm = BitMatrix::pack(&w, k, n);
            let h = 0.5f32;
            let mut dx = vec![0f32; b * k];
            let mut dzt = vec![0f32; n * b];
            let mut acc = vec![0f32; k * b];
            let mut totals = vec![0f32; b];
            bm.tmatmul_scaled_into(&dz, b, h, &mut dx, &mut dzt, &mut acc, &mut totals);
            // reference: dz @ (h * sign(w))^T via explicit transpose
            let mut wt = vec![0f32; n * k];
            for i in 0..k {
                for j in 0..n {
                    wt[j * k + i] = if w[i * n + j] >= 0.0 { h } else { -h };
                }
            }
            let mut want = vec![0f32; b * k];
            dense_f32(&dz, &wt, b, n, k, &mut want);
            for (idx, (a, r)) in dx.iter().zip(&want).enumerate() {
                assert!((a - r).abs() < 1e-3 * (1.0 + r.abs()), "[{idx}] {a} vs {r}");
            }
        }
    }

    #[test]
    fn memory_is_32x_smaller() {
        let k = 1024;
        let n = 1024;
        let bm = BitMatrix::pack(&rand_mat(k, n, 5), k, n);
        assert_eq!(bm.memory_bytes(), k / 64 * n * 8);
        let f32_bytes = k * n * 4;
        assert_eq!(f32_bytes / bm.memory_bytes(), 32);
    }

    #[test]
    fn packed_layer_bn_fold() {
        // One unit, known numbers: z = x1 + x2 (both weights +1),
        // BN(gamma=2, beta=1, mean=3, var=1-eps) -> y = 2*(z-3)+1
        let w = vec![1.0, 1.0];
        let layer = PackedLayer {
            bits: BitMatrix::pack(&w, 2, 1),
            scale: vec![2.0 / (1.0f32 + BN_EPS).sqrt()],
            shift: vec![1.0 - 3.0 * 2.0 / (1.0f32 + BN_EPS).sqrt()],
            relu: false,
        };
        let mut y = vec![0f32];
        layer.forward(&[2.0, 2.0], 1, &mut y);
        assert!((y[0] - (2.0 * (4.0 - 3.0) + 1.0)).abs() < 1e-3, "{}", y[0]);
    }

    #[test]
    fn relu_applies_only_on_hidden() {
        let w = vec![1.0, -1.0]; // 1x2: unit0 = +x, unit1 = -x
        let mlp = PackedMlp::build(vec![(w, 1, 2)], vec![None], Some(vec![0.0, 0.0]));
        let out = mlp.forward(&[3.0], 1);
        assert_eq!(out, vec![3.0, -3.0]); // output layer: no relu
    }

    #[test]
    fn classify_matches_forward_argmax() {
        let mut rng = Rng::new(9);
        let w1 = rand_mat(6, 8, 10);
        let w2 = rand_mat(8, 3, 11);
        let bn = (vec![1.0; 8], vec![0.0; 8], vec![0.0; 8], vec![1.0; 8]);
        let mlp = PackedMlp::build(
            vec![(w1, 6, 8), (w2, 8, 3)],
            vec![Some(bn), None],
            Some(vec![0.1, -0.1, 0.0]),
        );
        let x: Vec<f32> = (0..12).map(|_| rng.normal()).collect();
        let preds = mlp.classify(&x, 2);
        let logits = mlp.forward(&x, 2);
        for bi in 0..2 {
            let row = &logits[bi * 3..(bi + 1) * 3];
            let am = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            assert_eq!(preds[bi], am);
        }
    }

    #[test]
    fn bn_less_hidden_layer_does_not_inherit_output_bias() {
        // regression: a BN-less hidden layer used to clone the output bias
        // into its shift, silently truncated by zip when lengths differed.
        let w1 = rand_mat(4, 6, 20); // hidden, 6 units, no BN
        let w2 = rand_mat(6, 2, 21); // output, 2 units
        let mlp = PackedMlp::build(
            vec![(w1, 4, 6), (w2, 6, 2)],
            vec![None, None],
            Some(vec![0.5, -0.5]),
        );
        assert_eq!(mlp.layers[0].shift, vec![0.0; 6], "hidden shift must stay zero");
        assert_eq!(mlp.layers[0].scale, vec![1.0; 6]);
        assert_eq!(mlp.layers[1].shift, vec![0.5, -0.5], "output keeps its bias");
        // and the forward pass works on well-formed shapes
        let out = mlp.forward(&[1.0, -1.0, 0.5, 0.25], 1);
        assert_eq!(out.len(), 2);
    }

    #[test]
    #[should_panic(expected = "scale length")]
    fn forward_rejects_mismatched_affine_lengths() {
        let layer = PackedLayer {
            bits: BitMatrix::pack(&[1.0, -1.0], 1, 2),
            scale: vec![1.0], // wrong length: 1 instead of 2
            shift: vec![0.0, 0.0],
            relu: false,
        };
        let mut y = vec![0f32; 2];
        layer.forward(&[1.0], 1, &mut y);
    }

    /// 3-layer net with non-trivial affines covering word-edge shapes
    /// (k = 70 crosses a 64-bit word boundary).
    fn toy_mlp(seed: u64) -> PackedMlp {
        let w1 = rand_mat(12, 70, seed);
        let w2 = rand_mat(70, 33, seed + 1);
        let w3 = rand_mat(33, 4, seed + 2);
        let mut rng = Rng::new(seed + 3);
        type Bn = Option<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)>;
        let bn = |n: usize, r: &mut Rng| -> Bn {
            Some((
                (0..n).map(|_| 1.0 + 0.1 * r.normal()).collect(),
                (0..n).map(|_| 0.1 * r.normal()).collect(),
                (0..n).map(|_| 0.2 * r.normal()).collect(),
                (0..n).map(|_| (1.0 + 0.1 * r.normal()).abs()).collect(),
            ))
        };
        PackedMlp::build(
            vec![(w1, 12, 70), (w2, 70, 33), (w3, 33, 4)],
            vec![bn(70, &mut rng), bn(33, &mut rng), None],
            Some(vec![0.05, -0.05, 0.0, 0.02]),
        )
    }

    #[test]
    fn forward_into_matches_forward_on_batched_shapes() {
        // same kernels, same order for b > 1: bit-identical
        let mlp = toy_mlp(80);
        let x = rand_mat(6, mlp.in_dim, 81);
        let mut ws = mlp.workspace(6);
        let got = mlp.forward_into(&x, 6, &mut ws).to_vec();
        let want = mlp.forward(&x, 6);
        assert_eq!(got, want, "forward_into must be bit-identical to forward for b > 1");
    }

    #[test]
    fn forward_into_rows_bit_identical_across_batch_sizes() {
        // the serving exactness contract: a row's logits do not depend on
        // which coalesced batch it was computed in — including batch 1
        let mlp = toy_mlp(90);
        let b = 8;
        let x = rand_mat(b, mlp.in_dim, 91);
        let mut ws = mlp.workspace(b);
        let full = mlp.forward_into(&x, b, &mut ws).to_vec();
        // solo, one row at a time
        for bi in 0..b {
            let row = &x[bi * mlp.in_dim..(bi + 1) * mlp.in_dim];
            let solo = mlp.forward_into(row, 1, &mut ws).to_vec();
            assert_eq!(
                solo,
                full[bi * mlp.classes..(bi + 1) * mlp.classes].to_vec(),
                "row {bi}: solo != coalesced"
            );
        }
        // ragged split 3 + 5
        let cut = 3 * mlp.in_dim;
        let head = mlp.forward_into(&x[..cut], 3, &mut ws).to_vec();
        let tail = mlp.forward_into(&x[cut..], 5, &mut ws).to_vec();
        let mut joined = head;
        joined.extend(tail);
        assert_eq!(joined, full, "3+5 split != coalesced batch of 8");
    }

    #[test]
    fn forward_into_batch1_close_to_single_row_kernel() {
        // the b == 1 fast path (sign_dot) re-associates; the lane-batched
        // route must agree within the usual f32 bound
        let mlp = toy_mlp(95);
        let x = rand_mat(1, mlp.in_dim, 96);
        let mut ws = mlp.workspace(1);
        let batched = mlp.forward_into(&x, 1, &mut ws).to_vec();
        let single = mlp.forward(&x, 1);
        for (a, r) in batched.iter().zip(&single) {
            assert!((a - r).abs() < 1e-4 * (1.0 + r.abs()), "{a} vs {r}");
        }
    }

    #[test]
    fn forward_into_steady_state_is_allocation_free() {
        let mlp = toy_mlp(100);
        let b = 16;
        let mut ws = mlp.workspace(b);
        let x = rand_mat(b, mlp.in_dim, 101);
        // warm: first call faults pages and initializes pool/dispatch
        let _ = mlp.forward_into(&x, b, &mut ws);
        let before = crate::test_alloc::thread_allocs();
        for _ in 0..3 {
            let out = mlp.forward_into(&x, b, &mut ws);
            std::hint::black_box(out);
        }
        let after = crate::test_alloc::thread_allocs();
        assert_eq!(after, before, "forward_into allocated in steady state");
    }

    #[test]
    fn argmax_is_deterministic_and_nan_safe() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[2.0, 2.0]), 1, "last max wins on ties");
        // NaN inputs must not panic (network-fed logits); result is the
        // total_cmp maximum, which orders NaN above every finite value
        assert_eq!(argmax(&[1.0, f32::NAN, 3.0]), 1);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn test_error_on_trivially_separable_data() {
        // dataset where class = sign of the single feature; a hand-made
        // 1->2 packed net classifies it perfectly.
        let mut ds = Dataset::new("sep", (1, 1, 1), 2);
        for i in 0..50 {
            let v = if i % 2 == 0 { 1.0 } else { -1.0 };
            ds.push(&[v], if v > 0.0 { 1 } else { 0 });
        }
        // unit0 = -x (class 0 score), unit1 = +x (class 1 score)
        let mlp = PackedMlp::build(vec![(vec![-1.0, 1.0], 1, 2)], vec![None], None);
        assert_eq!(mlp.test_error(&ds, 16), 0.0);
    }

    fn rand_conv_layer(
        kh: usize,
        kw: usize,
        cin: usize,
        cout: usize,
        h_in: usize,
        w_in: usize,
        pool: bool,
        seed: u64,
    ) -> PackedConvLayer {
        let w = rand_mat(kh * kw * cin, cout, seed);
        let mut rng = Rng::new(seed + 1);
        PackedConvLayer {
            bits: BitMatrix::pack(&w, kh * kw * cin, cout),
            scale: (0..cout).map(|_| 0.3 + 0.1 * rng.normal().abs()).collect(),
            shift: (0..cout).map(|_| 0.05 * rng.normal()).collect(),
            kh,
            kw,
            cin,
            cout,
            h_in,
            w_in,
            pool,
        }
    }

    /// Conv front (3x3x2->3 unpooled, then 3x3x3->4 pooled on 6x6) into
    /// the dense stack — ragged widths everywhere, patch_k % 64 != 0.
    fn toy_conv(seed: u64) -> PackedMlp {
        let conv = vec![
            rand_conv_layer(3, 3, 2, 3, 6, 6, false, seed),
            rand_conv_layer(3, 3, 3, 4, 6, 6, true, seed + 10),
        ];
        let flat = conv.last().unwrap().out_dim(); // 3*3*4 = 36
        let w1 = rand_mat(flat, 5, seed + 20);
        let w2 = rand_mat(5, 3, seed + 21);
        let layers = vec![
            PackedLayer {
                bits: BitMatrix::pack(&w1, flat, 5),
                scale: vec![0.5; 5],
                shift: vec![0.01; 5],
                relu: true,
            },
            PackedLayer {
                bits: BitMatrix::pack(&w2, 5, 3),
                scale: vec![1.0; 3],
                shift: vec![0.1, -0.1, 0.0],
                relu: false,
            },
        ];
        PackedMlp { conv, layers, in_dim: 6 * 6 * 2, classes: 3 }
    }

    #[test]
    fn conv_front_matches_the_f32_sign_oracle() {
        // one conv stage in isolation (empty dense stack): the im2col
        // sign-GEMM + folded affine + pool must match the naive direct
        // conv over the same ±1 weights within the usual f32 bound.
        for &pool in &[false, true] {
            let (b, h, w, cin, cout) = (3usize, 4usize, 6usize, 2usize, 5usize);
            let layer = rand_conv_layer(3, 3, cin, cout, h, w, pool, 700 + pool as u64);
            let out_dim = layer.out_dim();
            // reconstruct the ±1 filter bank the packed bits encode
            let mut signs = vec![0f32; 9 * cin * cout];
            for r in 0..9 * cin {
                for c in 0..cout {
                    signs[r * cout + c] = layer.bits.sign(r, c);
                }
            }
            let x = rand_mat(b, h * w * cin, 777);
            let mut want_full = vec![0f32; b * h * w * cout];
            crate::conv::oracle::conv2d_forward(&x, b, h, w, cin, &signs, 3, 3, cout, &mut want_full);
            for (i, v) in want_full.iter_mut().enumerate() {
                let c = i % cout;
                *v = (*v * layer.scale[c] + layer.shift[c]).max(0.0);
            }
            let want = if pool {
                let mut pooled = vec![0f32; b * h * w * cout / 4];
                let mut idx = vec![0u32; pooled.len()];
                cpool::maxpool2x2_into(&want_full, b, h, w, cout, &mut pooled, &mut idx);
                pooled
            } else {
                want_full
            };
            let mlp = PackedMlp {
                conv: vec![layer],
                layers: vec![],
                in_dim: h * w * cin,
                classes: out_dim,
            };
            let mut ws = mlp.workspace(b);
            let got = mlp.forward_into(&x, b, &mut ws);
            assert_eq!(got.len(), want.len());
            for (i, (a, r)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (a - r).abs() < 1e-4 * (1.0 + r.abs()),
                    "pool={pool} [{i}]: {a} vs {r}"
                );
            }
        }
    }

    #[test]
    fn conv_forward_into_rows_bit_identical_across_batch_sizes() {
        // solo ≡ coalesced through the whole conv+dense stack: im2col
        // rows, the batched sign-GEMM, the affine and the pool all keep
        // image bi's data inside row block bi.
        let mlp = toy_conv(800);
        let b = 5;
        let x = rand_mat(b, mlp.in_dim, 801);
        let mut ws = mlp.workspace(b);
        let full = mlp.forward_into(&x, b, &mut ws).to_vec();
        for bi in 0..b {
            let row = &x[bi * mlp.in_dim..(bi + 1) * mlp.in_dim];
            let solo = mlp.forward_into(row, 1, &mut ws).to_vec();
            assert_eq!(
                solo,
                full[bi * mlp.classes..(bi + 1) * mlp.classes].to_vec(),
                "row {bi}: solo != coalesced"
            );
        }
        let cut = 2 * mlp.in_dim;
        let head = mlp.forward_into(&x[..cut], 2, &mut ws).to_vec();
        let tail = mlp.forward_into(&x[cut..], 3, &mut ws).to_vec();
        let mut joined = head;
        joined.extend(tail);
        assert_eq!(joined, full, "2+3 split != coalesced batch of 5");
    }

    #[test]
    fn conv_forward_allocating_wrapper_matches_forward_into() {
        let mlp = toy_conv(810);
        let b = 4;
        let x = rand_mat(b, mlp.in_dim, 811);
        let mut ws = mlp.workspace(b);
        let want = mlp.forward_into(&x, b, &mut ws).to_vec();
        assert_eq!(mlp.forward(&x, b), want);
        assert_eq!(mlp.classify(&x, b).len(), b);
    }

    #[test]
    fn conv_forward_into_steady_state_is_allocation_free() {
        let mlp = toy_conv(820);
        let b = 6;
        let mut ws = mlp.workspace(b);
        let x = rand_mat(b, mlp.in_dim, 821);
        let _ = mlp.forward_into(&x, b, &mut ws);
        let before = crate::test_alloc::thread_allocs();
        for _ in 0..3 {
            let out = mlp.forward_into(&x, b, &mut ws);
            std::hint::black_box(out);
        }
        let after = crate::test_alloc::thread_allocs();
        assert_eq!(after, before, "conv forward_into allocated in steady state");
    }

    #[test]
    fn conv_workspace_sizes_scratch_for_the_spatial_extent() {
        // the conv GEMM runs over b*h*w rows: patches/xt/totals must be
        // spatially sized, and the memory report must count them.
        let mlp = toy_conv(830);
        let ws = mlp.workspace(2);
        let rows = 2 * 6 * 6;
        assert!(ws.xt.len() >= rows * 9 * 3, "xt must cover the largest conv GEMM");
        assert!(ws.totals.len() >= rows);
        assert_eq!(ws.patches.len(), rows * 9 * 3);
        assert_eq!(ws.prepool.len(), rows * 4);
        assert_eq!(ws.pool_idx.len(), rows); // rows*4/4
        let dense_only = PackedMlp { conv: vec![], layers: mlp.layers.clone(), in_dim: 36, classes: 3 };
        assert!(mlp.workspace(2).memory_bytes() > dense_only.workspace(2).memory_bytes());
        // pure MLPs pay nothing for the conv buffers
        assert_eq!(dense_only.workspace(2).patches.len(), 0);
        assert_eq!(dense_only.workspace(2).prepool.len(), 0);
    }
}
