//! XNOR–popcount inference mode: binarized activations over the packed
//! weights (the "BNN" successor of BinaryConnect — Courbariaux/Hubara et
//! al. 2016 — as a serving-side engine).
//!
//! The packed-f32 forward streams full-precision activations through the
//! sign-GEMM: every decoded weight bit still moves a stripe of f32
//! lanes. This module binarizes the activations too, so a whole
//! 64-element slice of the dot product collapses into one `XOR +
//! popcount` over `u64` words:
//!
//! ```text
//! dot(a, w) = k - 2 * popcount(bits(a) XOR bits(w))     (a, w ∈ {±1}^k)
//! ```
//!
//! with bit = 1 ⟺ value ≥ 0 — the same sign convention as
//! [`BitMatrix::pack_det_into`] (so −0.0 packs as +1), and the same
//! column word layout, so an activation row XORs directly against a
//! weight column. Both packers zero their padding bits, which makes the
//! whole-word count exact for any ragged `k`. The per-unit scale/shift
//! (folded BN or bias) is applied once to the integer dot at the end.
//!
//! Layer semantics — deliberately different from packed-f32 mode: the
//! hidden nonlinearity is `sign(·)` (that *is* the binarization;
//! `sign∘ReLU` would be the constant +1 and collapse the network), so a
//! hidden unit emits `bit = (scale*dot + shift >= 0)` and the output
//! layer emits f32 logits `scale*dot + shift`. A BNN-mode model is
//! therefore a different function than the same weights in packed-f32
//! mode; the exactness contracts below are *within* the mode.
//!
//! The first layer is an **f32 escape hatch**: real inputs are not ±1,
//! so layer 0 runs the existing lane-batched sign-GEMM plus its affine
//! (no ReLU), and only its output signs enter the bit domain.
//!
//! ## Exactness
//!
//! * Every per-unit dot is an exact integer (`k < 2^24`), and integer
//!   addition is associative — so `sign_xnor_dot` is **bit-exact across
//!   every ISA rung** and across any loop order.
//! * Solo ≡ coalesced: an XNOR layer computes row `bi` from its own bit
//!   row only, independent of `b`; layer 0 rides
//!   [`BitMatrix::matmul_scaled_into_batched`], which carries the same
//!   contract. So a request served alone is bit-identical to the same
//!   request inside any coalesced batch — pinned end-to-end by
//!   `tests/bnn_packed.rs` and the serve integration tests.
//!
//! Parallelism: output units are partitioned over the pool in
//! **64-aligned column ranges** (same trick as the transpose-apply), so
//! every output bit-word has exactly one writer and results are
//! thread-count independent.

use crate::kernel::simd::{self, Isa, Kernels};
use crate::util::pool::{global as pool_global, par_rows, SendPtr};

use super::packed::{BitMatrix, PackedLayer, PackedMlp};

/// Which forward engine a `PackedMlp` serves with.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ForwardMode {
    /// Bit-packed weights, f32 activations (`PackedMlp::forward_into`).
    PackedF32,
    /// Bit-packed weights *and* activations
    /// (`PackedMlp::forward_bnn_into`): XNOR–popcount hidden layers
    /// behind the first-layer f32 escape hatch.
    Bnn,
}

impl ForwardMode {
    /// The spelling used by `/stats`, the startup log and the bench
    /// series names.
    pub fn label(self) -> &'static str {
        match self {
            ForwardMode::PackedF32 => "packed-f32",
            ForwardMode::Bnn => "bnn",
        }
    }
}

/// Packed words per activation row of width `k`: `ceil(k / 64)` — the
/// same rounding as [`BitMatrix::words_per_col`], so a packed row and a
/// packed weight column are word-for-word alignable.
pub fn words_per_row(k: usize) -> usize {
    k.div_ceil(64)
}

/// Pack the signs of `b` f32 rows of width `k` (row-major, as produced
/// by the forward buffers) into bit rows: row `bi` occupies
/// `out[bi*wpr .. (bi+1)*wpr]`, bit `i` is set ⟺ `x[bi*k + i] >= 0.0`
/// (so −0.0 packs as +1, matching the weight packer). Padding bits are
/// cleared — the invariant that keeps whole-word XNOR counts exact.
pub fn pack_rows_into(x: &[f32], b: usize, k: usize, out: &mut [u64]) {
    let wpr = words_per_row(k);
    assert_eq!(x.len(), b * k, "pack_rows_into: input length mismatch");
    assert!(out.len() >= b * wpr, "pack_rows_into: bit buffer too small");
    let out = &mut out[..b * wpr];
    out.fill(0);
    for (row, orow) in x.chunks_exact(k).zip(out.chunks_exact_mut(wpr)) {
        for (i, &v) in row.iter().enumerate() {
            if v >= 0.0 {
                orow[i / 64] |= 1u64 << (i % 64);
            }
        }
    }
}

/// 64-aligned output-column grain (single block for small jobs), so each
/// pool range owns whole output bit-words.
fn col_grain_64(k: usize, n: usize, b: usize) -> usize {
    let t = pool_global().n_threads;
    let g = if k * n * b < (1 << 16) { n } else { n.div_ceil(t * 2) };
    g.div_ceil(64).max(1) * 64
}

/// Hidden XNOR layer: bit input (b rows × `words_per_row(k)`) → bit
/// output (b rows × `words_per_row(n)`), unit `j` of row `bi` set ⟺
/// `scale[j] * (k - 2*popcount(arow XOR col_j)) + shift[j] >= 0`.
pub fn xnor_layer_bits(layer: &PackedLayer, abits: &[u64], b: usize, out: &mut [u64]) {
    xnor_layer_bits_kern(simd::kernels(), layer, abits, b, out)
}

/// [`xnor_layer_bits`] pinned to an explicit ISA rung (test/bench hook —
/// no process-global dispatch mutation).
pub fn xnor_layer_bits_isa(
    isa: Isa,
    layer: &PackedLayer,
    abits: &[u64],
    b: usize,
    out: &mut [u64],
) {
    xnor_layer_bits_kern(simd::kernels_for(isa), layer, abits, b, out)
}

fn xnor_layer_bits_kern(
    kern: &'static Kernels,
    layer: &PackedLayer,
    abits: &[u64],
    b: usize,
    out: &mut [u64],
) {
    let bits = &layer.bits;
    let (k, n) = (bits.k, bits.n);
    let wpr = bits.words_per_col();
    let wpo = words_per_row(n);
    assert!(abits.len() >= b * wpr, "xnor_layer_bits: input bit buffer too small");
    assert!(out.len() >= b * wpo, "xnor_layer_bits: output bit buffer too small");
    assert_eq!(layer.scale.len(), n, "scale length must match layer width");
    assert_eq!(layer.shift.len(), n, "shift length must match layer width");
    let kf = k as f32;
    let scale = &layer.scale[..n];
    let shift = &layer.shift[..n];
    let op = SendPtr(out.as_mut_ptr());
    par_rows(n, col_grain_64(k, n, b), &|jlo, jhi| {
        // jlo is 64-aligned (the grain is a multiple of 64), so this
        // range owns output words [jlo/64, ceil(jhi/64)) outright.
        let w0 = jlo / 64;
        let w1 = jhi.div_ceil(64);
        for bi in 0..b {
            let arow = &abits[bi * wpr..(bi + 1) * wpr];
            for w in w0..w1 {
                let mut word = 0u64;
                let je = ((w + 1) * 64).min(jhi);
                for j in (w * 64)..je {
                    let cnt = (kern.sign_xnor_dot)(arow, bits.col(j));
                    let u = scale[j] * (kf - 2.0 * cnt as f32) + shift[j];
                    if u >= 0.0 {
                        word |= 1u64 << (j - w * 64);
                    }
                }
                // SAFETY: 64-aligned column partition — word (bi, w) is
                // written by exactly one thread, and fully (padding
                // bits of a ragged final word come out zero).
                unsafe { op.write(bi * wpo + w, word) };
            }
        }
    });
}

/// Output XNOR layer: bit input → f32 logits
/// `y[bi, j] = scale[j] * (k - 2*popcount(arow XOR col_j)) + shift[j]`.
pub fn xnor_layer_f32(layer: &PackedLayer, abits: &[u64], b: usize, y: &mut [f32]) {
    xnor_layer_f32_kern(simd::kernels(), layer, abits, b, y)
}

/// [`xnor_layer_f32`] pinned to an explicit ISA rung.
pub fn xnor_layer_f32_isa(isa: Isa, layer: &PackedLayer, abits: &[u64], b: usize, y: &mut [f32]) {
    xnor_layer_f32_kern(simd::kernels_for(isa), layer, abits, b, y)
}

fn xnor_layer_f32_kern(
    kern: &'static Kernels,
    layer: &PackedLayer,
    abits: &[u64],
    b: usize,
    y: &mut [f32],
) {
    let bits = &layer.bits;
    let (k, n) = (bits.k, bits.n);
    let wpr = bits.words_per_col();
    assert!(abits.len() >= b * wpr, "xnor_layer_f32: input bit buffer too small");
    assert_eq!(y.len(), b * n, "xnor_layer_f32: output length mismatch");
    assert_eq!(layer.scale.len(), n, "scale length must match layer width");
    assert_eq!(layer.shift.len(), n, "shift length must match layer width");
    let kf = k as f32;
    let scale = &layer.scale[..n];
    let shift = &layer.shift[..n];
    let yp = SendPtr(y.as_mut_ptr());
    par_rows(n, col_grain_64(k, n, b), &|jlo, jhi| {
        for bi in 0..b {
            let arow = &abits[bi * wpr..(bi + 1) * wpr];
            for j in jlo..jhi {
                let cnt = (kern.sign_xnor_dot)(arow, bits.col(j));
                let u = scale[j] * (kf - 2.0 * cnt as f32) + shift[j];
                // SAFETY: element (bi, j) is written by exactly one
                // thread (columns are partitioned).
                unsafe { yp.write(bi * n + j, u) };
            }
        }
    });
}

/// Folded affine without ReLU — the escape-hatch layer's epilogue. In
/// BNN mode the hidden nonlinearity is `sign(·)` (applied by the bit
/// packer), never ReLU, so only `y*scale + shift` runs here; for a
/// single-layer net this is exactly the output affine.
fn affine_presign(layer: &PackedLayer, y: &mut [f32]) {
    let n = layer.bits.n;
    assert_eq!(layer.scale.len(), n, "scale length must match layer width");
    assert_eq!(layer.shift.len(), n, "shift length must match layer width");
    for row in y.chunks_exact_mut(n) {
        for ((v, &s), &t) in row.iter_mut().zip(&layer.scale).zip(&layer.shift) {
            *v = *v * s + t;
        }
    }
}

/// Reusable scratch for [`PackedMlp::forward_bnn_into`]: one f32 buffer
/// (layer-0 output, then — once those signs are packed — the final
/// logits), the layer-0 sign-GEMM scratch, and ping-pong *bit* buffers
/// for the hidden activations (64 rows of sign per word — the ~64×
/// input-bandwidth cut over [`super::PackedWorkspace`]'s f32 ping-pong).
/// A warmed workspace makes every subsequent forward allocation-free.
pub struct BnnWorkspace {
    max_batch: usize,
    fbuf: Vec<f32>,
    xt: Vec<f32>,
    totals: Vec<f32>,
    bping: Vec<u64>,
    bpong: Vec<u64>,
}

impl BnnWorkspace {
    /// Batch capacity this workspace was sized for.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Allocated activation-scratch footprint in bytes (f32 buffers plus
    /// both bit buffers). The BNN counterpart of
    /// [`super::PackedWorkspace::memory_bytes`].
    pub fn memory_bytes(&self) -> usize {
        (self.fbuf.len() + self.xt.len() + self.totals.len()) * 4
            + (self.bping.len() + self.bpong.len()) * 8
    }
}

impl PackedMlp {
    /// Widest hidden-activation row in packed words — the ping-pong bit
    /// buffers' per-row size (0 for a single-layer net, which never
    /// enters the bit domain).
    fn max_hidden_words(&self) -> usize {
        let m = self.layers.len();
        self.layers[..m - 1].iter().map(|l| words_per_row(l.bits.n)).max().unwrap_or(0)
    }

    /// Build a [`BnnWorkspace`] able to forward batches up to
    /// `max_batch` rows with zero per-call allocations.
    pub fn bnn_workspace(&self, max_batch: usize) -> BnnWorkspace {
        assert!(max_batch >= 1, "workspace batch capacity must be >= 1");
        assert!(
            self.conv.is_empty(),
            "BNN mode does not support conv models (use packed-f32)"
        );
        let w = self.max_width();
        let hw = self.max_hidden_words();
        BnnWorkspace {
            max_batch,
            fbuf: vec![0f32; max_batch * w],
            xt: vec![0f32; max_batch * self.in_dim],
            totals: vec![0f32; max_batch],
            bping: vec![0u64; max_batch * hw],
            bpong: vec![0u64; max_batch * hw],
        }
    }

    /// Allocated activation-scratch bytes a `max_batch`-row workspace
    /// costs in the given mode, without building one. Matches the
    /// corresponding workspace's `memory_bytes()` exactly (unit-tested);
    /// `/stats` and the bench reports quote this per-mode figure.
    pub fn activation_memory_bytes(&self, max_batch: usize, mode: ForwardMode) -> usize {
        let w = self.max_width();
        match mode {
            ForwardMode::PackedF32 => {
                // same sizing logic as `workspace()` (ping + pong + xt +
                // totals + the conv patch/pool scratch; pool_idx is u32,
                // so prepool/4 entries cost prepool bytes)
                let (pp, xt, totals, patches, prepool) = self.workspace_lens(max_batch);
                (2 * pp + xt + totals + patches + prepool + prepool / 4) * 4
            }
            ForwardMode::Bnn => {
                (w * max_batch + self.in_dim * max_batch + max_batch) * 4
                    + 2 * self.max_hidden_words() * max_batch * 8
            }
        }
    }

    /// BNN forward: layer 0 through the f32 escape hatch (lane-batched
    /// sign-GEMM + affine, no ReLU), signs bit-packed, every further
    /// layer XNOR–popcount; returns the logits slice (b × classes).
    /// Allocation-free with a warmed workspace, and each row's logits
    /// are bit-identical for any batch size the row is computed in — the
    /// serving layer's solo ≡ coalesced contract, same as
    /// [`PackedMlp::forward_into`].
    pub fn forward_bnn_into<'ws>(
        &self,
        x: &[f32],
        b: usize,
        ws: &'ws mut BnnWorkspace,
    ) -> &'ws [f32] {
        self.forward_bnn_kern(simd::kernels(), x, b, ws)
    }

    /// [`PackedMlp::forward_bnn_into`] pinned to an explicit ISA rung
    /// (test/bench hook — no process-global dispatch mutation).
    pub fn forward_bnn_into_isa<'ws>(
        &self,
        isa: Isa,
        x: &[f32],
        b: usize,
        ws: &'ws mut BnnWorkspace,
    ) -> &'ws [f32] {
        self.forward_bnn_kern(simd::kernels_for(isa), x, b, ws)
    }

    fn forward_bnn_kern<'ws>(
        &self,
        kern: &'static Kernels,
        x: &[f32],
        b: usize,
        ws: &'ws mut BnnWorkspace,
    ) -> &'ws [f32] {
        assert_eq!(x.len(), b * self.in_dim);
        assert!(
            self.conv.is_empty(),
            "BNN mode does not support conv models (use packed-f32)"
        );
        assert!(
            b <= ws.max_batch,
            "batch {b} exceeds the workspace capacity {}",
            ws.max_batch
        );
        let m = self.layers.len();
        let l0 = &self.layers[0];
        let n0 = l0.bits.n;
        {
            let y = &mut ws.fbuf[..b * n0];
            l0.bits.matmul_scaled_into_batched_isa(
                kern.isa,
                x,
                b,
                1.0,
                y,
                &mut ws.xt,
                &mut ws.totals,
            );
            affine_presign(l0, y);
        }
        if m == 1 {
            return &ws.fbuf[..b * self.classes];
        }
        pack_rows_into(&ws.fbuf[..b * n0], b, n0, &mut ws.bping);
        let mut in_ping = true;
        for (li, layer) in self.layers.iter().enumerate().skip(1) {
            let (src, dst) = if in_ping {
                (&ws.bping, &mut ws.bpong)
            } else {
                (&ws.bpong, &mut ws.bping)
            };
            if li == m - 1 {
                // fbuf is free again: its layer-0 contents were consumed
                // by pack_rows_into before the first XNOR layer ran
                let n = layer.bits.n;
                xnor_layer_f32_kern(kern, layer, src, b, &mut ws.fbuf[..b * n]);
            } else {
                xnor_layer_bits_kern(kern, layer, src, b, dst);
                in_ping = !in_ping;
            }
        }
        &ws.fbuf[..b * self.classes]
    }
}

/// Float reference for one XNOR layer's pre-activation, used by the
/// property tests: with ±1 operands every partial sum is an exact small
/// integer, so this is bit-identical to the integer path's
/// `scale * (k - 2*cnt) + shift` — the oracle that pins the kernels.
#[doc(hidden)]
pub fn xnor_reference_preact(layer: &PackedLayer, asigns: &[f32], b: usize, y: &mut [f32]) {
    let bits = &layer.bits;
    let (k, n) = (bits.k, bits.n);
    assert_eq!(asigns.len(), b * k);
    assert_eq!(y.len(), b * n);
    for bi in 0..b {
        let arow = &asigns[bi * k..(bi + 1) * k];
        for j in 0..n {
            let mut dot = 0f32;
            for (i, &a) in arow.iter().enumerate() {
                dot += a * bits.sign(i, j);
            }
            y[bi * n + j] = layer.scale[j] * dot + layer.shift[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_mat(r: usize, c: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..r * c).map(|_| rng.normal()).collect()
    }

    /// Word-edge shapes: k = 70 and n = 33 both cross 64-bit boundaries.
    fn toy(seed: u64) -> PackedMlp {
        let w1 = rand_mat(12, 70, seed);
        let w2 = rand_mat(70, 33, seed + 1);
        let w3 = rand_mat(33, 4, seed + 2);
        let mut rng = Rng::new(seed + 3);
        type Bn = Option<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)>;
        let bn = |n: usize, r: &mut Rng| -> Bn {
            Some((
                (0..n).map(|_| 1.0 + 0.1 * r.normal()).collect(),
                (0..n).map(|_| 0.1 * r.normal()).collect(),
                (0..n).map(|_| 0.2 * r.normal()).collect(),
                (0..n).map(|_| (1.0 + 0.1 * r.normal()).abs()).collect(),
            ))
        };
        PackedMlp::build(
            vec![(w1, 12, 70), (w2, 70, 33), (w3, 33, 4)],
            vec![bn(70, &mut rng), bn(33, &mut rng), None],
            Some(vec![0.05, -0.05, 0.0, 0.02]),
        )
    }

    #[test]
    fn pack_rows_sets_signs_and_clears_padding() {
        // k = 70: the second word of each row carries 6 live bits + 58
        // padding bits that must stay zero; ±0.0 both pack as +1.
        let k = 70;
        let mut x = rand_mat(3, k, 7);
        x[0] = 0.0;
        x[1] = -0.0;
        let mut out = vec![u64::MAX; 3 * words_per_row(k)];
        pack_rows_into(&x, 3, k, &mut out);
        for bi in 0..3 {
            let row = &out[bi * 2..(bi + 1) * 2];
            for i in 0..k {
                let bit = (row[i / 64] >> (i % 64)) & 1;
                let want = u64::from(x[bi * k + i] >= 0.0);
                assert_eq!(bit, want, "row {bi} bit {i}");
            }
            assert_eq!(row[1] >> 6, 0, "row {bi}: padding bits must be zero");
        }
        assert_eq!(out[0] & 3, 3, "+0.0 and -0.0 must both pack as +1");
    }

    #[test]
    fn forward_bnn_into_steady_state_is_allocation_free() {
        let mlp = toy(200);
        let b = 16;
        let mut ws = mlp.bnn_workspace(b);
        let x = rand_mat(b, mlp.in_dim, 201);
        // warm: first call faults pages and initializes pool/dispatch
        let _ = mlp.forward_bnn_into(&x, b, &mut ws);
        let before = crate::test_alloc::thread_allocs();
        for _ in 0..3 {
            let out = mlp.forward_bnn_into(&x, b, &mut ws);
            std::hint::black_box(out);
        }
        let after = crate::test_alloc::thread_allocs();
        assert_eq!(after, before, "forward_bnn_into allocated in steady state");
    }

    #[test]
    fn activation_memory_bytes_matches_the_workspaces() {
        let mlp = toy(210);
        for b in [1usize, 7, 64] {
            assert_eq!(
                mlp.activation_memory_bytes(b, ForwardMode::PackedF32),
                mlp.workspace(b).memory_bytes(),
                "packed-f32 formula drifted from the workspace (b={b})"
            );
            assert_eq!(
                mlp.activation_memory_bytes(b, ForwardMode::Bnn),
                mlp.bnn_workspace(b).memory_bytes(),
                "bnn formula drifted from the workspace (b={b})"
            );
        }
    }

    /// A conv-front model for the guard/memory tests below.
    fn toy_conv() -> PackedMlp {
        use super::super::packed::PackedConvLayer;
        let wc = rand_mat(18, 3, 230);
        let wd = rand_mat(12, 2, 231);
        PackedMlp {
            conv: vec![PackedConvLayer {
                bits: BitMatrix::pack(&wc, 18, 3),
                scale: vec![0.5; 3],
                shift: vec![0.0; 3],
                kh: 3,
                kw: 3,
                cin: 2,
                cout: 3,
                h_in: 4,
                w_in: 4,
                pool: true,
            }],
            layers: vec![PackedLayer {
                bits: BitMatrix::pack(&wd, 12, 2),
                scale: vec![1.0; 2],
                shift: vec![0.0; 2],
                relu: false,
            }],
            in_dim: 32,
            classes: 2,
        }
    }

    #[test]
    fn packed_f32_memory_formula_covers_conv_scratch() {
        // the conv workspace carries patch/pool scratch the dense formula
        // never saw; the reported figure must track the real allocation
        let mlp = toy_conv();
        for b in [1usize, 3] {
            assert_eq!(
                mlp.activation_memory_bytes(b, ForwardMode::PackedF32),
                mlp.workspace(b).memory_bytes(),
                "packed-f32 formula drifted from the conv workspace (b={b})"
            );
        }
    }

    #[test]
    #[should_panic(expected = "BNN mode does not support conv models")]
    fn bnn_workspace_rejects_conv_models() {
        let _ = toy_conv().bnn_workspace(2);
    }

    #[test]
    fn single_layer_net_is_pure_escape_hatch() {
        // no hidden layers: bnn mode == the f32 layer + bias, and the
        // bit buffers are zero-sized
        let mlp = PackedMlp::build(
            vec![(rand_mat(6, 3, 220), 6, 3)],
            vec![None],
            Some(vec![0.1, 0.0, -0.1]),
        );
        let mut ws = mlp.bnn_workspace(4);
        assert_eq!(ws.bping.len(), 0);
        let x = rand_mat(4, 6, 221);
        let got = mlp.forward_bnn_into(&x, 4, &mut ws).to_vec();
        let mut pws = mlp.workspace(4);
        let want = mlp.forward_into(&x, 4, &mut pws).to_vec();
        // the output layer has relu=false, so both modes are the same
        // function here — and both ride the lane-batched kernel
        assert_eq!(got, want, "single-layer bnn must equal packed-f32");
    }

    #[test]
    fn mode_labels_are_stable() {
        // serialized into /stats and bench series names — do not rename
        assert_eq!(ForwardMode::PackedF32.label(), "packed-f32");
        assert_eq!(ForwardMode::Bnn.label(), "bnn");
    }
}
