//! Export a trained model TrainState into the packed inference engine,
//! and (de)serialize packed models to disk.
//!
//! The layer layout follows the manifest's parameter naming convention
//! (python/compile/models.py): repeated [W, bn.gamma, bn.beta, bn.rmean,
//! bn.rvar] blocks — conv blocks first for CNNs, identified by their
//! 4-d `[kh, kw, cin, cout]` weight shape — then the output [W, b] pair.

use std::io::Read;
use std::path::Path;

use crate::bail;
use crate::util::crc32;
use crate::util::error::{Context, Result};

use crate::runtime::{ModelInfo, TrainState};

use super::packed::{BitMatrix, PackedConvLayer, PackedLayer, PackedMlp, BN_EPS};

/// Fold a trained state into the multiplication-free packed engine
/// (deterministic BinaryConnect test-time network, paper Sec. 2.6
/// method 1). The ±H scale is folded into the BN affine so the packed
/// engine can keep computing with ±1 bits; conv filter banks flatten
/// row-major into `(kh*kw*cin) x cout` sign matrices for the im2col
/// lowering.
pub fn pack_mlp(info: &ModelInfo, state: &TrainState) -> Result<PackedMlp> {
    let dims = crate::conv::spatial_dims(info)?;
    let mut conv: Vec<PackedConvLayer> = vec![];
    let mut i = 0usize;
    for d in &dims {
        if d.param != i {
            bail!("conv block {} is not at the expected parameter offset {i}", d.name);
        }
        let p = &info.params[i];
        let w = state.param_vec(i)?;
        let h = p.glorot as f32;
        let pk = d.kh * d.kw * d.cin;
        let bits = BitMatrix::pack(&w, pk, d.cout);
        // conv stacks are always BN-normalized: W + 4 BN tensors
        let gamma = state.param_vec(i + 1)?;
        let beta = state.param_vec(i + 2)?;
        let rmean = state.param_vec(i + 3)?;
        let rvar = state.param_vec(i + 4)?;
        let mut scale = vec![0f32; d.cout];
        let mut shift = vec![0f32; d.cout];
        for c in 0..d.cout {
            let s = gamma[c] / (rvar[c] + BN_EPS).sqrt();
            scale[c] = s * h;
            shift[c] = beta[c] - rmean[c] * s;
        }
        conv.push(PackedConvLayer {
            bits,
            scale,
            shift,
            kh: d.kh,
            kw: d.kw,
            cin: d.cin,
            cout: d.cout,
            h_in: d.h_in,
            w_in: d.w_in,
            pool: d.pool,
        });
        i += 5;
    }
    let mut layers: Vec<PackedLayer> = vec![];
    let n = info.params.len();
    while i < n {
        let p = &info.params[i];
        if !p.name.ends_with(".W") {
            bail!("unexpected param {} at index {i}", p.name);
        }
        if p.shape.len() != 2 {
            bail!(
                "pack_mlp only supports dense and conv layers, {} has shape {:?}",
                p.name,
                p.shape
            );
        }
        let (k, units) = (p.shape[0], p.shape[1]);
        let w = state.param_vec(i)?;
        let h = p.glorot as f32;
        let bits = BitMatrix::pack(&w, k, units);
        let is_output = i + 1 < n && info.params[i + 1].name.ends_with(".b");
        if is_output {
            let bias = state.param_vec(i + 1)?;
            // logits = (x @ wb) where wb = ±H  ->  scale = H
            layers.push(PackedLayer {
                bits,
                scale: vec![h; units],
                shift: bias,
                relu: false,
            });
            i += 2;
        } else {
            // W + 4 BN tensors; z_real = H * (x @ ±1-bits)
            let gamma = state.param_vec(i + 1)?;
            let beta = state.param_vec(i + 2)?;
            let rmean = state.param_vec(i + 3)?;
            let rvar = state.param_vec(i + 4)?;
            let mut scale = vec![0f32; units];
            let mut shift = vec![0f32; units];
            for u in 0..units {
                let s = gamma[u] / (rvar[u] + BN_EPS).sqrt();
                scale[u] = s * h;
                shift[u] = beta[u] - rmean[u] * s;
            }
            layers.push(PackedLayer { bits, scale, shift, relu: true });
            i += 5;
        }
    }
    let in_dim = match conv.first() {
        Some(c0) => c0.in_dim(),
        None => info.params[0].shape[0],
    };
    let classes = layers.last().context("no dense output layer")?.bits.n;
    Ok(PackedMlp { conv, layers, in_dim, classes })
}

const MAGIC: &[u8; 8] = b"BCPACK03";
/// Superseded formats. Refusing them with a targeted message beats a
/// generic "not a BCPACK file" for anyone holding a stale artifact:
/// BCPACK01 lacked the checksum, BCPACK02 the layer-kind tags.
const LEGACY_MAGICS: [&[u8; 8]; 2] = [b"BCPACK01", b"BCPACK02"];

/// Per-layer kind tags (one `u8` ahead of each layer record).
const KIND_DENSE: u8 = 0;
const KIND_CONV: u8 = 1;

/// Sanity caps for deserialization: `.bcpack` is now the serving
/// deployment artifact, so `load_packed` must reject corrupt headers
/// (e.g. a flipped byte turning a layer count into billions) with an
/// error *before* attempting the implied multi-gigabyte allocation.
const MAX_LAYERS: usize = 256;
const MAX_DIM: usize = 1 << 22;
/// Cap on one layer's packed-words allocation: k and n can each be
/// individually plausible while their product implies terabytes, so the
/// byte size is bounded too (1 GiB of packed words ≈ 8.6e9 weights —
/// far beyond anything this engine serves).
const MAX_LAYER_WORD_BYTES: usize = 1 << 30;

fn push_affine_and_words(buf: &mut Vec<u8>, scale: &[f32], shift: &[f32], bits: &BitMatrix) {
    for v in scale.iter().chain(shift) {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    for j in 0..bits.n {
        for w in bits.col(j) {
            buf.extend_from_slice(&w.to_le_bytes());
        }
    }
}

/// Serialize: MAGIC, n_layers (conv + dense), then per layer a kind tag
/// (`1` = conv: kh/kw/cin/cout/h_in/w_in + pool flag; `0` = dense: k/n +
/// relu flag) followed by scale/shift f32s + packed words, then a
/// little-endian CRC32 of everything before it.
///
/// The write is crash-safe: bytes go to a same-directory temp file which
/// is fsync'd and atomically renamed over `path`, so a crash (or an
/// injected panic) mid-export leaves either the old artifact or the new
/// one — never a torn file. The CRC trailer catches the remaining case
/// of a torn *medium* (partial page flush, bit rot), which
/// [`load_packed`] verifies before parsing.
pub fn save_packed(mlp: &PackedMlp, path: &Path) -> Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&((mlp.conv.len() + mlp.layers.len()) as u32).to_le_bytes());
    for c in &mlp.conv {
        buf.push(KIND_CONV);
        for dim in [c.kh, c.kw, c.cin, c.cout, c.h_in, c.w_in] {
            buf.extend_from_slice(&(dim as u32).to_le_bytes());
        }
        buf.push(c.pool as u8);
        push_affine_and_words(&mut buf, &c.scale, &c.shift, &c.bits);
    }
    for l in &mlp.layers {
        buf.push(KIND_DENSE);
        buf.extend_from_slice(&(l.bits.k as u32).to_le_bytes());
        buf.extend_from_slice(&(l.bits.n as u32).to_le_bytes());
        buf.push(l.relu as u8);
        push_affine_and_words(&mut buf, &l.scale, &l.shift, &l.bits);
    }
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());

    // temp file in the *same directory* so the rename cannot cross a
    // filesystem boundary (rename is only atomic within one fs)
    let dir = path.parent().filter(|d| !d.as_os_str().is_empty());
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .with_context(|| format!("{}: not a writable file path", path.display()))?;
    let tmp_name = format!(".{name}.tmp.{}", std::process::id());
    let tmp = match dir {
        Some(d) => d.join(&tmp_name),
        None => Path::new(&tmp_name).to_path_buf(),
    };
    let write = (|| -> Result<()> {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&buf)?;
        f.sync_all()?; // data durable before the rename publishes it
        Ok(())
    })();
    if let Err(e) = write {
        let _ = std::fs::remove_file(&tmp);
        return Err(e).with_context(|| format!("write {}", tmp.display()));
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e.into());
    }
    // best effort: make the rename itself durable (the artifact is
    // already consistent either way)
    #[cfg(unix)]
    if let Some(d) = dir {
        if let Ok(dirf) = std::fs::File::open(d) {
            let _ = dirf.sync_all();
        }
    }
    Ok(())
}

/// Bound on a whole `.bcpack` file; MAX_LAYERS layers each at the
/// per-layer word cap would far exceed any real artifact, so 2 GiB is a
/// generous ceiling that still refuses to slurp an obviously-wrong file.
const MAX_FILE_BYTES: u64 = 1 << 31;

pub fn load_packed(path: &Path) -> Result<PackedMlp> {
    let meta =
        std::fs::metadata(path).with_context(|| format!("open {}", path.display()))?;
    if meta.len() > MAX_FILE_BYTES {
        bail!("{}: {} bytes exceeds the {MAX_FILE_BYTES} byte cap", path.display(), meta.len());
    }
    let bytes =
        std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
    // magic(8) + n_layers(4) + crc(4) is the smallest well-formed file
    if bytes.len() < 16 {
        bail!("{}: {} bytes is too short to be a BCPACK file", path.display(), bytes.len());
    }
    for legacy in LEGACY_MAGICS {
        if bytes[..8] == legacy[..] {
            bail!(
                "{}: legacy {} artifact; re-export it with this build",
                path.display(),
                String::from_utf8_lossy(&legacy[..])
            );
        }
    }
    if bytes[..8] != MAGIC[..] {
        bail!("{}: not a BCPACK file", path.display());
    }
    let (body, trailer) = bytes.split_at(bytes.len() - 4);
    let stored = u32::from_le_bytes([trailer[0], trailer[1], trailer[2], trailer[3]]);
    let computed = crc32(body);
    if stored != computed {
        bail!(
            "{}: checksum mismatch (torn write or corruption): \
             stored {stored:#010x}, computed {computed:#010x}",
            path.display()
        );
    }
    let mut f: &[u8] = &body[8..];
    let mut b4 = [0u8; 4];
    f.read_exact(&mut b4)?;
    let n_layers = u32::from_le_bytes(b4) as usize;
    if n_layers == 0 || n_layers > MAX_LAYERS {
        bail!("{}: implausible layer count {n_layers} (cap {MAX_LAYERS})", path.display());
    }
    let mut conv: Vec<PackedConvLayer> = vec![];
    let mut layers: Vec<PackedLayer> = vec![];
    // flat activation width flowing between layers, for chain validation
    let mut width: Option<usize> = None;
    for li in 0..n_layers {
        let mut b1 = [0u8; 1];
        f.read_exact(&mut b1)?;
        let kind = b1[0];
        let (k, n) = match kind {
            KIND_DENSE => {
                f.read_exact(&mut b4)?;
                let k = u32::from_le_bytes(b4) as usize;
                f.read_exact(&mut b4)?;
                let n = u32::from_le_bytes(b4) as usize;
                (k, n)
            }
            KIND_CONV => {
                if !layers.is_empty() {
                    bail!("{}: conv layer {li} appears after a dense layer", path.display());
                }
                let mut dims = [0usize; 6];
                for d in dims.iter_mut() {
                    f.read_exact(&mut b4)?;
                    *d = u32::from_le_bytes(b4) as usize;
                }
                let [kh, kw, cin, cout, h_in, w_in] = dims;
                if kh % 2 == 0 || kw % 2 == 0 {
                    bail!("{}: conv layer {li} kernel {kh}x{kw} is not odd", path.display());
                }
                if h_in == 0 || w_in == 0 || h_in > MAX_DIM || w_in > MAX_DIM {
                    bail!(
                        "{}: implausible conv input {h_in}x{w_in} for layer {li}",
                        path.display()
                    );
                }
                let Some(pk) = kh.checked_mul(kw).and_then(|v| v.checked_mul(cin)) else {
                    bail!("{}: implausible conv kernel for layer {li}", path.display());
                };
                f.read_exact(&mut b1)?;
                let pool = b1[0] != 0;
                if pool && (h_in % 2 != 0 || w_in % 2 != 0) {
                    bail!(
                        "{}: conv layer {li} pools odd spatial dims {h_in}x{w_in}",
                        path.display()
                    );
                }
                // spatial size caps: the workspace scales with b*h*w*pk
                if h_in.checked_mul(w_in).and_then(|s| s.checked_mul(pk)).is_none() {
                    bail!("{}: implausible conv extent for layer {li}", path.display());
                }
                conv.push(PackedConvLayer {
                    bits: BitMatrix::zeroed(1, 1), // placeholder until words are read
                    scale: vec![],
                    shift: vec![],
                    kh,
                    kw,
                    cin,
                    cout,
                    h_in,
                    w_in,
                    pool,
                });
                (pk, cout)
            }
            other => bail!("{}: unknown layer kind {other} for layer {li}", path.display()),
        };
        if k == 0 || n == 0 || k > MAX_DIM || n > MAX_DIM {
            bail!("{}: implausible shape {k}x{n} for layer {li}", path.display());
        }
        let wpc = k.div_ceil(64);
        let word_bytes = wpc
            .checked_mul(n)
            .and_then(|w| w.checked_mul(8))
            .filter(|&bytes| bytes <= MAX_LAYER_WORD_BYTES);
        let Some(word_bytes) = word_bytes else {
            bail!(
                "{}: implausible packed size {k}x{n} for layer {li} \
                 (exceeds {MAX_LAYER_WORD_BYTES} bytes)",
                path.display()
            );
        };
        // chain the flat activation width through conv and dense alike
        let in_flat = match kind {
            KIND_CONV => conv.last().unwrap().in_dim(),
            _ => k,
        };
        if let Some(prev) = width {
            if prev != in_flat {
                bail!(
                    "{}: layer {li} input dim {in_flat} does not chain with previous width {prev}",
                    path.display()
                );
            }
        }
        let relu = match kind {
            KIND_DENSE => {
                f.read_exact(&mut b1)?;
                b1[0] != 0
            }
            _ => true,
        };
        let mut read_f32s = |count: usize| -> Result<Vec<f32>> {
            let mut buf = vec![0u8; count * 4];
            f.read_exact(&mut buf)?;
            Ok(buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
        };
        let scale = read_f32s(n)?;
        let shift = read_f32s(n)?;
        let mut words = vec![0u8; word_bytes];
        f.read_exact(&mut words)?;
        let words: Vec<u64> = words
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect();
        if kind == KIND_CONV {
            let c = conv.last_mut().unwrap();
            c.bits = BitMatrix::from_words(k, n, words);
            c.scale = scale;
            c.shift = shift;
            width = Some(c.out_dim());
        } else {
            layers.push(PackedLayer { bits: BitMatrix::from_words(k, n, words), scale, shift, relu });
            width = Some(n);
        }
    }
    let mut b1 = [0u8; 1];
    if f.read(&mut b1)? != 0 {
        bail!("{}: trailing bytes after the last layer", path.display());
    }
    let Some(last) = layers.last() else {
        bail!("{}: no dense output layer", path.display());
    };
    let classes = last.bits.n;
    let in_dim = match conv.first() {
        Some(c0) => c0.in_dim(),
        None => layers.first().unwrap().bits.k,
    };
    Ok(PackedMlp { conv, layers, in_dim, classes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Append the valid CRC32 trailer to a hand-crafted body so tests can
    /// reach the header-validation logic *behind* the checksum gate.
    fn with_crc(mut body: Vec<u8>) -> Vec<u8> {
        let crc = crc32(&body);
        body.extend_from_slice(&crc.to_le_bytes());
        body
    }

    fn toy_packed() -> PackedMlp {
        let mut rng = Rng::new(3);
        let w1: Vec<f32> = (0..20 * 8).map(|_| rng.normal()).collect();
        let w2: Vec<f32> = (0..8 * 3).map(|_| rng.normal()).collect();
        PackedMlp {
            conv: vec![],
            layers: vec![
                PackedLayer {
                    bits: BitMatrix::pack(&w1, 20, 8),
                    scale: (0..8).map(|i| 0.5 + i as f32 * 0.1).collect(),
                    shift: (0..8).map(|i| i as f32 * 0.01).collect(),
                    relu: true,
                },
                PackedLayer {
                    bits: BitMatrix::pack(&w2, 8, 3),
                    scale: vec![1.0; 3],
                    shift: vec![0.1, -0.1, 0.0],
                    relu: false,
                },
            ],
            in_dim: 20,
            classes: 3,
        }
    }

    /// Conv-front toy: 3x3x2->3 (pooled) on 4x4, then dense 12 -> 3.
    fn toy_conv_packed() -> PackedMlp {
        let mut rng = Rng::new(5);
        let wc: Vec<f32> = (0..9 * 2 * 3).map(|_| rng.normal()).collect();
        let wd: Vec<f32> = (0..12 * 3).map(|_| rng.normal()).collect();
        PackedMlp {
            conv: vec![PackedConvLayer {
                bits: BitMatrix::pack(&wc, 18, 3),
                scale: (0..3).map(|i| 0.4 + i as f32 * 0.1).collect(),
                shift: (0..3).map(|i| i as f32 * 0.02 - 0.01).collect(),
                kh: 3,
                kw: 3,
                cin: 2,
                cout: 3,
                h_in: 4,
                w_in: 4,
                pool: true,
            }],
            layers: vec![PackedLayer {
                bits: BitMatrix::pack(&wd, 12, 3),
                scale: vec![0.7; 3],
                shift: vec![0.1, -0.1, 0.0],
                relu: false,
            }],
            in_dim: 4 * 4 * 2,
            classes: 3,
        }
    }

    #[test]
    fn save_load_roundtrip_preserves_outputs() {
        let mlp = toy_packed();
        let path = std::env::temp_dir().join(format!("bc_pack_{}.bin", std::process::id()));
        save_packed(&mlp, &path).unwrap();
        let loaded = load_packed(&path).unwrap();
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..5 * 20).map(|_| rng.normal()).collect();
        assert_eq!(mlp.forward(&x, 5), loaded.forward(&x, 5));
        assert_eq!(mlp.weight_memory_bytes(), loaded.weight_memory_bytes());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn conv_roundtrip_is_bit_exact_and_preserves_outputs() {
        let mlp = toy_conv_packed();
        let path = std::env::temp_dir().join(format!("bc_convpack_{}.bin", std::process::id()));
        save_packed(&mlp, &path).unwrap();
        let loaded = load_packed(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(loaded.in_dim, mlp.in_dim);
        assert_eq!(loaded.classes, mlp.classes);
        assert_eq!(loaded.conv.len(), 1);
        let (a, b) = (&loaded.conv[0], &mlp.conv[0]);
        assert_eq!(
            (a.kh, a.kw, a.cin, a.cout, a.h_in, a.w_in, a.pool),
            (b.kh, b.kw, b.cin, b.cout, b.h_in, b.w_in, b.pool)
        );
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&a.scale), bits(&b.scale));
        assert_eq!(bits(&a.shift), bits(&b.shift));
        for j in 0..a.bits.n {
            assert_eq!(a.bits.col(j), b.bits.col(j), "conv packed words of column {j}");
        }
        let mut rng = Rng::new(6);
        let x: Vec<f32> = (0..3 * mlp.in_dim).map(|_| rng.normal()).collect();
        assert_eq!(mlp.forward(&x, 3), loaded.forward(&x, 3));
    }

    #[test]
    fn load_rejects_bad_magic() {
        let path = std::env::temp_dir().join(format!("bc_badmagic_{}.bin", std::process::id()));
        std::fs::write(&path, b"NOTPACKED_PADDING").unwrap();
        assert!(load_packed(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn legacy_formats_get_a_targeted_reexport_error() {
        for magic in [b"BCPACK01", b"BCPACK02"] {
            let path = std::env::temp_dir()
                .join(format!("bc_legacy_{}_{}.bin", magic[7] as char, std::process::id()));
            let mut b = Vec::new();
            b.extend_from_slice(magic);
            b.extend_from_slice(&1u32.to_le_bytes());
            b.extend_from_slice(&[0u8; 32]);
            std::fs::write(&path, &b).unwrap();
            let err = load_packed(&path).unwrap_err().to_string();
            assert!(err.contains("legacy") && err.contains("re-export"), "{err}");
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn save_is_atomic_and_leaves_no_temp_litter() {
        let mlp = toy_packed();
        let dir = std::env::temp_dir().join(format!("bc_atomic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.bcpack");
        // overwrite an existing artifact: the reader must only ever see
        // the old or the new file, and no `.tmp` residue may remain
        save_packed(&mlp, &path).unwrap();
        save_packed(&mlp, &path).unwrap();
        assert!(load_packed(&path).is_ok());
        let leftovers: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "temp litter: {leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn flipped_crc_trailer_is_detected() {
        let mlp = toy_packed();
        let path = std::env::temp_dir().join(format!("bc_flipcrc_{}.bin", std::process::id()));
        save_packed(&mlp, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = load_packed(&path).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_write_in_the_body_is_detected_by_the_checksum() {
        // a torn medium can corrupt bytes *without* changing the length,
        // which no truncation check can catch — the CRC must
        let mlp = toy_packed();
        let path = std::env::temp_dir().join(format!("bc_torn_{}.bin", std::process::id()));
        save_packed(&mlp, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // zero a 16-byte run in the middle of the packed words
        let mid = bytes.len() / 2;
        let mut torn = bytes.clone();
        for b in &mut torn[mid..(mid + 16).min(bytes.len() - 4)] {
            *b = 0;
        }
        if torn != bytes {
            std::fs::write(&path, &torn).unwrap();
            let err = load_packed(&path).unwrap_err().to_string();
            assert!(err.contains("checksum mismatch"), "{err}");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        // the serving deployment artifact: every word, scale, shift and
        // relu flag must survive the disk round trip exactly
        let mlp = toy_packed();
        let path = std::env::temp_dir().join(format!("bc_bitexact_{}.bin", std::process::id()));
        save_packed(&mlp, &path).unwrap();
        let loaded = load_packed(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(loaded.in_dim, mlp.in_dim);
        assert_eq!(loaded.classes, mlp.classes);
        assert!(loaded.conv.is_empty());
        assert_eq!(loaded.layers.len(), mlp.layers.len());
        for (a, b) in loaded.layers.iter().zip(&mlp.layers) {
            assert_eq!(a.relu, b.relu);
            assert_eq!((a.bits.k, a.bits.n), (b.bits.k, b.bits.n));
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
            assert_eq!(bits(&a.scale), bits(&b.scale), "scale bits");
            assert_eq!(bits(&a.shift), bits(&b.shift), "shift bits");
            for j in 0..a.bits.n {
                assert_eq!(a.bits.col(j), b.bits.col(j), "packed words of column {j}");
            }
        }
    }

    #[test]
    fn every_truncation_errors_instead_of_panicking() {
        for (tag, mlp) in [("dense", toy_packed()), ("conv", toy_conv_packed())] {
            let path = std::env::temp_dir()
                .join(format!("bc_trunc_{tag}_{}.bin", std::process::id()));
            save_packed(&mlp, &path).unwrap();
            let bytes = std::fs::read(&path).unwrap();
            assert!(load_packed(&path).is_ok(), "untruncated {tag} file must load");
            for cut in 0..bytes.len() {
                std::fs::write(&path, &bytes[..cut]).unwrap();
                assert!(
                    load_packed(&path).is_err(),
                    "{tag}: truncation at byte {cut} must error"
                );
            }
            // trailing junk is corruption too, not silently ignored
            let mut padded = bytes.clone();
            padded.extend_from_slice(b"junk");
            std::fs::write(&path, &padded).unwrap();
            assert!(load_packed(&path).is_err(), "{tag}: trailing bytes must error");
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn corrupt_headers_error_instead_of_panicking_or_allocating_wildly() {
        let mlp = toy_packed();
        let path = std::env::temp_dir().join(format!("bc_corrupt_{}.bin", std::process::id()));
        save_packed(&mlp, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // flip each header-region byte to 0xFF: now that the file carries
        // a CRC trailer, *every* flip must be rejected, not just the ones
        // the header validation happens to notice
        for at in 0..bytes.len().min(64) {
            let mut mutated = bytes.clone();
            mutated[at] ^= 0xFF;
            std::fs::write(&path, &mutated).unwrap();
            assert!(load_packed(&path).is_err(), "flip at byte {at} must error");
        }
        // a header claiming ~4 billion units must be rejected up front
        // (not answered with a multi-gigabyte allocation attempt); a
        // valid CRC gets these bodies past the checksum gate
        let mut huge = Vec::new();
        huge.extend_from_slice(b"BCPACK03");
        huge.extend_from_slice(&1u32.to_le_bytes());
        huge.push(0); // dense kind tag
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        huge.push(0);
        std::fs::write(&path, with_crc(huge)).unwrap();
        let err = load_packed(&path).unwrap_err().to_string();
        assert!(err.contains("implausible"), "{err}");
        // dims individually under MAX_DIM whose product implies terabytes
        // must be rejected by the packed-size cap before any body read
        let mut wide = Vec::new();
        wide.extend_from_slice(b"BCPACK03");
        wide.extend_from_slice(&1u32.to_le_bytes());
        wide.push(0);
        wide.extend_from_slice(&(1u32 << 22).to_le_bytes());
        wide.extend_from_slice(&(1u32 << 22).to_le_bytes());
        wide.push(0);
        std::fs::write(&path, with_crc(wide)).unwrap();
        let err = load_packed(&path).unwrap_err().to_string();
        assert!(err.contains("implausible packed size"), "{err}");
        // an unknown layer-kind tag must be rejected, not misparsed
        let mut badkind = Vec::new();
        badkind.extend_from_slice(b"BCPACK03");
        badkind.extend_from_slice(&1u32.to_le_bytes());
        badkind.push(7);
        std::fs::write(&path, with_crc(badkind)).unwrap();
        let err = load_packed(&path).unwrap_err().to_string();
        assert!(err.contains("unknown layer kind"), "{err}");
        // zero layers is invalid too
        let mut zero = Vec::new();
        zero.extend_from_slice(b"BCPACK03");
        zero.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, with_crc(zero)).unwrap();
        assert!(load_packed(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mismatched_layer_chain_is_rejected() {
        // hand-craft a 2-layer file whose dims do not chain (layer0 is
        // 4x8, layer1 claims k=5): a corrupt serving artifact must not
        // load into a net that would panic at forward time
        let path = std::env::temp_dir().join(format!("bc_chain_{}.bin", std::process::id()));
        let mut b = Vec::new();
        b.extend_from_slice(b"BCPACK03");
        b.extend_from_slice(&2u32.to_le_bytes());
        // layer 0: dense k=4, n=8, relu, 8 scales + 8 shifts, 1 word/col
        b.push(0);
        b.extend_from_slice(&4u32.to_le_bytes());
        b.extend_from_slice(&8u32.to_le_bytes());
        b.push(1);
        for _ in 0..16 {
            b.extend_from_slice(&1.0f32.to_le_bytes());
        }
        for _ in 0..8 {
            b.extend_from_slice(&0u64.to_le_bytes());
        }
        // layer 1: k=5 (should be 8), n=2
        b.push(0);
        b.extend_from_slice(&5u32.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes());
        b.push(0);
        for _ in 0..4 {
            b.extend_from_slice(&1.0f32.to_le_bytes());
        }
        for _ in 0..2 {
            b.extend_from_slice(&0u64.to_le_bytes());
        }
        std::fs::write(&path, with_crc(b)).unwrap();
        let err = load_packed(&path).unwrap_err().to_string();
        assert!(err.contains("chain"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn conv_only_file_is_rejected_for_missing_dense_output() {
        // the packed classifier needs a dense output stage: a conv-only
        // artifact (e.g. a truncation that still checksums after a
        // re-save) must load-fail with a targeted error
        let mut mlp = toy_conv_packed();
        mlp.layers.clear();
        let path = std::env::temp_dir().join(format!("bc_convonly_{}.bin", std::process::id()));
        save_packed(&mlp, &path).unwrap();
        let err = load_packed(&path).unwrap_err().to_string();
        assert!(err.contains("no dense output layer"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn conv_record_after_a_dense_record_is_rejected() {
        // hand-craft: dense 12->3, then a conv record — an impossible
        // topology for the serving engine (flatten is one-way)
        let path = std::env::temp_dir().join(format!("bc_order_{}.bin", std::process::id()));
        let mut b = Vec::new();
        b.extend_from_slice(b"BCPACK03");
        b.extend_from_slice(&2u32.to_le_bytes());
        b.push(0); // dense k=12 n=3
        b.extend_from_slice(&12u32.to_le_bytes());
        b.extend_from_slice(&3u32.to_le_bytes());
        b.push(1);
        for _ in 0..6 {
            b.extend_from_slice(&1.0f32.to_le_bytes());
        }
        for _ in 0..3 {
            b.extend_from_slice(&0u64.to_le_bytes());
        }
        b.push(1); // conv after dense
        for dim in [3u32, 3, 3, 4, 4, 4] {
            b.extend_from_slice(&dim.to_le_bytes());
        }
        b.push(0);
        std::fs::write(&path, with_crc(b)).unwrap();
        let err = load_packed(&path).unwrap_err().to_string();
        assert!(err.contains("after a dense layer"), "{err}");
        let _ = std::fs::remove_file(&path);
    }
}
