//! Export a trained MLP TrainState into the packed inference engine, and
//! (de)serialize packed models to disk.
//!
//! The layer layout follows the manifest's parameter naming convention
//! (python/compile/models.py): repeated [W, bn.gamma, bn.beta, bn.rmean,
//! bn.rvar] blocks, then the output [W, b] pair.

use std::io::{Read, Write};
use std::path::Path;

use crate::bail;
use crate::util::error::{Context, Result};

use crate::runtime::{ModelInfo, TrainState};

use super::packed::{BitMatrix, PackedLayer, PackedMlp, BN_EPS};

/// Fold a trained MLP state into the multiplication-free packed engine
/// (deterministic BinaryConnect test-time network, paper Sec. 2.6
/// method 1). The ±H scale is folded into the BN affine so the packed
/// engine can keep computing with ±1 bits.
pub fn pack_mlp(info: &ModelInfo, state: &TrainState) -> Result<PackedMlp> {
    let mut layers: Vec<PackedLayer> = vec![];
    let mut i = 0usize;
    let n = info.params.len();
    while i < n {
        let p = &info.params[i];
        if !p.name.ends_with(".W") {
            bail!("unexpected param {} at index {i}", p.name);
        }
        if p.shape.len() != 2 {
            bail!("pack_mlp only supports dense layers, {} has shape {:?}", p.name, p.shape);
        }
        let (k, units) = (p.shape[0], p.shape[1]);
        let w = state.param_vec(i)?;
        let h = p.glorot as f32;
        let bits = BitMatrix::pack(&w, k, units);
        let is_output = i + 1 < n && info.params[i + 1].name.ends_with(".b");
        if is_output {
            let bias = state.param_vec(i + 1)?;
            // logits = (x @ wb) where wb = ±H  ->  scale = H
            layers.push(PackedLayer {
                bits,
                scale: vec![h; units],
                shift: bias,
                relu: false,
            });
            i += 2;
        } else {
            // W + 4 BN tensors; z_real = H * (x @ ±1-bits)
            let gamma = state.param_vec(i + 1)?;
            let beta = state.param_vec(i + 2)?;
            let rmean = state.param_vec(i + 3)?;
            let rvar = state.param_vec(i + 4)?;
            let mut scale = vec![0f32; units];
            let mut shift = vec![0f32; units];
            for u in 0..units {
                let s = gamma[u] / (rvar[u] + BN_EPS).sqrt();
                scale[u] = s * h;
                shift[u] = beta[u] - rmean[u] * s;
            }
            layers.push(PackedLayer { bits, scale, shift, relu: true });
            i += 5;
        }
    }
    let in_dim = info.params[0].shape[0];
    let classes = layers.last().context("empty model")?.bits.n;
    Ok(PackedMlp { layers, in_dim, classes })
}

const MAGIC: &[u8; 8] = b"BCPACK01";

/// Serialize: MAGIC, n_layers, then per layer k,n,relu + scale/shift f32s
/// + packed words.
pub fn save_packed(mlp: &PackedMlp, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(mlp.layers.len() as u32).to_le_bytes())?;
    for l in &mlp.layers {
        f.write_all(&(l.bits.k as u32).to_le_bytes())?;
        f.write_all(&(l.bits.n as u32).to_le_bytes())?;
        f.write_all(&[l.relu as u8])?;
        for v in l.scale.iter().chain(&l.shift) {
            f.write_all(&v.to_le_bytes())?;
        }
        for j in 0..l.bits.n {
            for w in l.bits.col(j) {
                f.write_all(&w.to_le_bytes())?;
            }
        }
    }
    Ok(())
}

pub fn load_packed(path: &Path) -> Result<PackedMlp> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not a BCPACK file", path.display());
    }
    let mut b4 = [0u8; 4];
    f.read_exact(&mut b4)?;
    let n_layers = u32::from_le_bytes(b4) as usize;
    let mut layers = vec![];
    for _ in 0..n_layers {
        f.read_exact(&mut b4)?;
        let k = u32::from_le_bytes(b4) as usize;
        f.read_exact(&mut b4)?;
        let n = u32::from_le_bytes(b4) as usize;
        let mut b1 = [0u8; 1];
        f.read_exact(&mut b1)?;
        let relu = b1[0] != 0;
        let mut read_f32s = |count: usize| -> Result<Vec<f32>> {
            let mut buf = vec![0u8; count * 4];
            f.read_exact(&mut buf)?;
            Ok(buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
        };
        let scale = read_f32s(n)?;
        let shift = read_f32s(n)?;
        let wpc = k.div_ceil(64);
        let mut words = vec![0u8; wpc * n * 8];
        f.read_exact(&mut words)?;
        let words: Vec<u64> = words
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect();
        layers.push(PackedLayer { bits: BitMatrix::from_words(k, n, words), scale, shift, relu });
    }
    let in_dim = layers.first().context("empty file")?.bits.k;
    let classes = layers.last().unwrap().bits.n;
    Ok(PackedMlp { layers, in_dim, classes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn toy_packed() -> PackedMlp {
        let mut rng = Rng::new(3);
        let w1: Vec<f32> = (0..20 * 8).map(|_| rng.normal()).collect();
        let w2: Vec<f32> = (0..8 * 3).map(|_| rng.normal()).collect();
        PackedMlp {
            layers: vec![
                PackedLayer {
                    bits: BitMatrix::pack(&w1, 20, 8),
                    scale: (0..8).map(|i| 0.5 + i as f32 * 0.1).collect(),
                    shift: (0..8).map(|i| i as f32 * 0.01).collect(),
                    relu: true,
                },
                PackedLayer {
                    bits: BitMatrix::pack(&w2, 8, 3),
                    scale: vec![1.0; 3],
                    shift: vec![0.1, -0.1, 0.0],
                    relu: false,
                },
            ],
            in_dim: 20,
            classes: 3,
        }
    }

    #[test]
    fn save_load_roundtrip_preserves_outputs() {
        let mlp = toy_packed();
        let path = std::env::temp_dir().join(format!("bc_pack_{}.bin", std::process::id()));
        save_packed(&mlp, &path).unwrap();
        let loaded = load_packed(&path).unwrap();
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..5 * 20).map(|_| rng.normal()).collect();
        assert_eq!(mlp.forward(&x, 5), loaded.forward(&x, 5));
        assert_eq!(mlp.weight_memory_bytes(), loaded.weight_memory_bytes());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_rejects_bad_magic() {
        let path = std::env::temp_dir().join(format!("bc_badmagic_{}.bin", std::process::id()));
        std::fs::write(&path, b"NOTPACKED").unwrap();
        assert!(load_packed(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
