//! Export a trained MLP TrainState into the packed inference engine, and
//! (de)serialize packed models to disk.
//!
//! The layer layout follows the manifest's parameter naming convention
//! (python/compile/models.py): repeated [W, bn.gamma, bn.beta, bn.rmean,
//! bn.rvar] blocks, then the output [W, b] pair.

use std::io::Read;
use std::path::Path;

use crate::bail;
use crate::util::crc32;
use crate::util::error::{Context, Result};

use crate::runtime::{ModelInfo, TrainState};

use super::packed::{BitMatrix, PackedLayer, PackedMlp, BN_EPS};

/// Fold a trained MLP state into the multiplication-free packed engine
/// (deterministic BinaryConnect test-time network, paper Sec. 2.6
/// method 1). The ±H scale is folded into the BN affine so the packed
/// engine can keep computing with ±1 bits.
pub fn pack_mlp(info: &ModelInfo, state: &TrainState) -> Result<PackedMlp> {
    let mut layers: Vec<PackedLayer> = vec![];
    let mut i = 0usize;
    let n = info.params.len();
    while i < n {
        let p = &info.params[i];
        if !p.name.ends_with(".W") {
            bail!("unexpected param {} at index {i}", p.name);
        }
        if p.shape.len() != 2 {
            bail!("pack_mlp only supports dense layers, {} has shape {:?}", p.name, p.shape);
        }
        let (k, units) = (p.shape[0], p.shape[1]);
        let w = state.param_vec(i)?;
        let h = p.glorot as f32;
        let bits = BitMatrix::pack(&w, k, units);
        let is_output = i + 1 < n && info.params[i + 1].name.ends_with(".b");
        if is_output {
            let bias = state.param_vec(i + 1)?;
            // logits = (x @ wb) where wb = ±H  ->  scale = H
            layers.push(PackedLayer {
                bits,
                scale: vec![h; units],
                shift: bias,
                relu: false,
            });
            i += 2;
        } else {
            // W + 4 BN tensors; z_real = H * (x @ ±1-bits)
            let gamma = state.param_vec(i + 1)?;
            let beta = state.param_vec(i + 2)?;
            let rmean = state.param_vec(i + 3)?;
            let rvar = state.param_vec(i + 4)?;
            let mut scale = vec![0f32; units];
            let mut shift = vec![0f32; units];
            for u in 0..units {
                let s = gamma[u] / (rvar[u] + BN_EPS).sqrt();
                scale[u] = s * h;
                shift[u] = beta[u] - rmean[u] * s;
            }
            layers.push(PackedLayer { bits, scale, shift, relu: true });
            i += 5;
        }
    }
    let in_dim = info.params[0].shape[0];
    let classes = layers.last().context("empty model")?.bits.n;
    Ok(PackedMlp { layers, in_dim, classes })
}

const MAGIC: &[u8; 8] = b"BCPACK02";
/// The pre-checksum format. Refusing it with a targeted message beats a
/// generic "not a BCPACK file" for anyone holding a stale artifact.
const LEGACY_MAGIC: &[u8; 8] = b"BCPACK01";

/// Sanity caps for deserialization: `.bcpack` is now the serving
/// deployment artifact, so `load_packed` must reject corrupt headers
/// (e.g. a flipped byte turning a layer count into billions) with an
/// error *before* attempting the implied multi-gigabyte allocation.
const MAX_LAYERS: usize = 256;
const MAX_DIM: usize = 1 << 22;
/// Cap on one layer's packed-words allocation: k and n can each be
/// individually plausible while their product implies terabytes, so the
/// byte size is bounded too (1 GiB of packed words ≈ 8.6e9 weights —
/// far beyond anything this engine serves).
const MAX_LAYER_WORD_BYTES: usize = 1 << 30;

/// Serialize: MAGIC, n_layers, then per layer k,n,relu + scale/shift f32s
/// + packed words, then a little-endian CRC32 of everything before it.
///
/// The write is crash-safe: bytes go to a same-directory temp file which
/// is fsync'd and atomically renamed over `path`, so a crash (or an
/// injected panic) mid-export leaves either the old artifact or the new
/// one — never a torn file. The CRC trailer catches the remaining case
/// of a torn *medium* (partial page flush, bit rot), which
/// [`load_packed`] verifies before parsing.
pub fn save_packed(mlp: &PackedMlp, path: &Path) -> Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(mlp.layers.len() as u32).to_le_bytes());
    for l in &mlp.layers {
        buf.extend_from_slice(&(l.bits.k as u32).to_le_bytes());
        buf.extend_from_slice(&(l.bits.n as u32).to_le_bytes());
        buf.push(l.relu as u8);
        for v in l.scale.iter().chain(&l.shift) {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        for j in 0..l.bits.n {
            for w in l.bits.col(j) {
                buf.extend_from_slice(&w.to_le_bytes());
            }
        }
    }
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());

    // temp file in the *same directory* so the rename cannot cross a
    // filesystem boundary (rename is only atomic within one fs)
    let dir = path.parent().filter(|d| !d.as_os_str().is_empty());
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .with_context(|| format!("{}: not a writable file path", path.display()))?;
    let tmp_name = format!(".{name}.tmp.{}", std::process::id());
    let tmp = match dir {
        Some(d) => d.join(&tmp_name),
        None => Path::new(&tmp_name).to_path_buf(),
    };
    let write = (|| -> Result<()> {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&buf)?;
        f.sync_all()?; // data durable before the rename publishes it
        Ok(())
    })();
    if let Err(e) = write {
        let _ = std::fs::remove_file(&tmp);
        return Err(e).with_context(|| format!("write {}", tmp.display()));
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e.into());
    }
    // best effort: make the rename itself durable (the artifact is
    // already consistent either way)
    #[cfg(unix)]
    if let Some(d) = dir {
        if let Ok(dirf) = std::fs::File::open(d) {
            let _ = dirf.sync_all();
        }
    }
    Ok(())
}

/// Bound on a whole `.bcpack` file; MAX_LAYERS layers each at the
/// per-layer word cap would far exceed any real artifact, so 2 GiB is a
/// generous ceiling that still refuses to slurp an obviously-wrong file.
const MAX_FILE_BYTES: u64 = 1 << 31;

pub fn load_packed(path: &Path) -> Result<PackedMlp> {
    let meta =
        std::fs::metadata(path).with_context(|| format!("open {}", path.display()))?;
    if meta.len() > MAX_FILE_BYTES {
        bail!("{}: {} bytes exceeds the {MAX_FILE_BYTES} byte cap", path.display(), meta.len());
    }
    let bytes =
        std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
    // magic(8) + n_layers(4) + crc(4) is the smallest well-formed file
    if bytes.len() < 16 {
        bail!("{}: {} bytes is too short to be a BCPACK file", path.display(), bytes.len());
    }
    if bytes[..8] == LEGACY_MAGIC[..] {
        bail!(
            "{}: legacy BCPACK01 artifact (no checksum); re-export it with this build",
            path.display()
        );
    }
    if bytes[..8] != MAGIC[..] {
        bail!("{}: not a BCPACK file", path.display());
    }
    let (body, trailer) = bytes.split_at(bytes.len() - 4);
    let stored = u32::from_le_bytes([trailer[0], trailer[1], trailer[2], trailer[3]]);
    let computed = crc32(body);
    if stored != computed {
        bail!(
            "{}: checksum mismatch (torn write or corruption): \
             stored {stored:#010x}, computed {computed:#010x}",
            path.display()
        );
    }
    let mut f: &[u8] = &body[8..];
    let mut b4 = [0u8; 4];
    f.read_exact(&mut b4)?;
    let n_layers = u32::from_le_bytes(b4) as usize;
    if n_layers == 0 || n_layers > MAX_LAYERS {
        bail!("{}: implausible layer count {n_layers} (cap {MAX_LAYERS})", path.display());
    }
    let mut layers: Vec<PackedLayer> = vec![];
    for li in 0..n_layers {
        f.read_exact(&mut b4)?;
        let k = u32::from_le_bytes(b4) as usize;
        f.read_exact(&mut b4)?;
        let n = u32::from_le_bytes(b4) as usize;
        if k == 0 || n == 0 || k > MAX_DIM || n > MAX_DIM {
            bail!("{}: implausible shape {k}x{n} for layer {li}", path.display());
        }
        let wpc = k.div_ceil(64);
        let word_bytes = wpc
            .checked_mul(n)
            .and_then(|w| w.checked_mul(8))
            .filter(|&bytes| bytes <= MAX_LAYER_WORD_BYTES);
        let Some(word_bytes) = word_bytes else {
            bail!(
                "{}: implausible packed size {k}x{n} for layer {li} \
                 (exceeds {MAX_LAYER_WORD_BYTES} bytes)",
                path.display()
            );
        };
        if let Some(prev) = layers.last() {
            if prev.bits.n != k {
                bail!(
                    "{}: layer {li} input dim {k} does not chain with previous width {}",
                    path.display(),
                    prev.bits.n
                );
            }
        }
        let mut b1 = [0u8; 1];
        f.read_exact(&mut b1)?;
        let relu = b1[0] != 0;
        let mut read_f32s = |count: usize| -> Result<Vec<f32>> {
            let mut buf = vec![0u8; count * 4];
            f.read_exact(&mut buf)?;
            Ok(buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
        };
        let scale = read_f32s(n)?;
        let shift = read_f32s(n)?;
        let mut words = vec![0u8; word_bytes];
        f.read_exact(&mut words)?;
        let words: Vec<u64> = words
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect();
        layers.push(PackedLayer { bits: BitMatrix::from_words(k, n, words), scale, shift, relu });
    }
    let mut b1 = [0u8; 1];
    if f.read(&mut b1)? != 0 {
        bail!("{}: trailing bytes after the last layer", path.display());
    }
    let in_dim = layers.first().context("empty file")?.bits.k;
    let classes = layers.last().unwrap().bits.n;
    Ok(PackedMlp { layers, in_dim, classes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Append the valid CRC32 trailer to a hand-crafted body so tests can
    /// reach the header-validation logic *behind* the checksum gate.
    fn with_crc(mut body: Vec<u8>) -> Vec<u8> {
        let crc = crc32(&body);
        body.extend_from_slice(&crc.to_le_bytes());
        body
    }

    fn toy_packed() -> PackedMlp {
        let mut rng = Rng::new(3);
        let w1: Vec<f32> = (0..20 * 8).map(|_| rng.normal()).collect();
        let w2: Vec<f32> = (0..8 * 3).map(|_| rng.normal()).collect();
        PackedMlp {
            layers: vec![
                PackedLayer {
                    bits: BitMatrix::pack(&w1, 20, 8),
                    scale: (0..8).map(|i| 0.5 + i as f32 * 0.1).collect(),
                    shift: (0..8).map(|i| i as f32 * 0.01).collect(),
                    relu: true,
                },
                PackedLayer {
                    bits: BitMatrix::pack(&w2, 8, 3),
                    scale: vec![1.0; 3],
                    shift: vec![0.1, -0.1, 0.0],
                    relu: false,
                },
            ],
            in_dim: 20,
            classes: 3,
        }
    }

    #[test]
    fn save_load_roundtrip_preserves_outputs() {
        let mlp = toy_packed();
        let path = std::env::temp_dir().join(format!("bc_pack_{}.bin", std::process::id()));
        save_packed(&mlp, &path).unwrap();
        let loaded = load_packed(&path).unwrap();
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..5 * 20).map(|_| rng.normal()).collect();
        assert_eq!(mlp.forward(&x, 5), loaded.forward(&x, 5));
        assert_eq!(mlp.weight_memory_bytes(), loaded.weight_memory_bytes());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_rejects_bad_magic() {
        let path = std::env::temp_dir().join(format!("bc_badmagic_{}.bin", std::process::id()));
        std::fs::write(&path, b"NOTPACKED_PADDING").unwrap();
        assert!(load_packed(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn legacy_format_gets_a_targeted_reexport_error() {
        let path = std::env::temp_dir().join(format!("bc_legacy_{}.bin", std::process::id()));
        let mut b = Vec::new();
        b.extend_from_slice(b"BCPACK01");
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&[0u8; 32]);
        std::fs::write(&path, &b).unwrap();
        let err = load_packed(&path).unwrap_err().to_string();
        assert!(err.contains("legacy") && err.contains("re-export"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn save_is_atomic_and_leaves_no_temp_litter() {
        let mlp = toy_packed();
        let dir = std::env::temp_dir().join(format!("bc_atomic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.bcpack");
        // overwrite an existing artifact: the reader must only ever see
        // the old or the new file, and no `.tmp` residue may remain
        save_packed(&mlp, &path).unwrap();
        save_packed(&mlp, &path).unwrap();
        assert!(load_packed(&path).is_ok());
        let leftovers: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "temp litter: {leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn flipped_crc_trailer_is_detected() {
        let mlp = toy_packed();
        let path = std::env::temp_dir().join(format!("bc_flipcrc_{}.bin", std::process::id()));
        save_packed(&mlp, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = load_packed(&path).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_write_in_the_body_is_detected_by_the_checksum() {
        // a torn medium can corrupt bytes *without* changing the length,
        // which no truncation check can catch — the CRC must
        let mlp = toy_packed();
        let path = std::env::temp_dir().join(format!("bc_torn_{}.bin", std::process::id()));
        save_packed(&mlp, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // zero a 16-byte run in the middle of the packed words
        let mid = bytes.len() / 2;
        let mut torn = bytes.clone();
        for b in &mut torn[mid..(mid + 16).min(bytes.len() - 4)] {
            *b = 0;
        }
        if torn != bytes {
            std::fs::write(&path, &torn).unwrap();
            let err = load_packed(&path).unwrap_err().to_string();
            assert!(err.contains("checksum mismatch"), "{err}");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        // the serving deployment artifact: every word, scale, shift and
        // relu flag must survive the disk round trip exactly
        let mlp = toy_packed();
        let path = std::env::temp_dir().join(format!("bc_bitexact_{}.bin", std::process::id()));
        save_packed(&mlp, &path).unwrap();
        let loaded = load_packed(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(loaded.in_dim, mlp.in_dim);
        assert_eq!(loaded.classes, mlp.classes);
        assert_eq!(loaded.layers.len(), mlp.layers.len());
        for (a, b) in loaded.layers.iter().zip(&mlp.layers) {
            assert_eq!(a.relu, b.relu);
            assert_eq!((a.bits.k, a.bits.n), (b.bits.k, b.bits.n));
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
            assert_eq!(bits(&a.scale), bits(&b.scale), "scale bits");
            assert_eq!(bits(&a.shift), bits(&b.shift), "shift bits");
            for j in 0..a.bits.n {
                assert_eq!(a.bits.col(j), b.bits.col(j), "packed words of column {j}");
            }
        }
    }

    #[test]
    fn every_truncation_errors_instead_of_panicking() {
        let mlp = toy_packed();
        let path = std::env::temp_dir().join(format!("bc_trunc_{}.bin", std::process::id()));
        save_packed(&mlp, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(load_packed(&path).is_ok(), "untruncated file must load");
        for cut in 0..bytes.len() {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            assert!(load_packed(&path).is_err(), "truncation at byte {cut} must error");
        }
        // trailing junk is corruption too, not silently ignored
        let mut padded = bytes.clone();
        padded.extend_from_slice(b"junk");
        std::fs::write(&path, &padded).unwrap();
        assert!(load_packed(&path).is_err(), "trailing bytes must error");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_headers_error_instead_of_panicking_or_allocating_wildly() {
        let mlp = toy_packed();
        let path = std::env::temp_dir().join(format!("bc_corrupt_{}.bin", std::process::id()));
        save_packed(&mlp, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // flip each header-region byte to 0xFF: now that the file carries
        // a CRC trailer, *every* flip must be rejected, not just the ones
        // the header validation happens to notice
        for at in 0..bytes.len().min(64) {
            let mut mutated = bytes.clone();
            mutated[at] ^= 0xFF;
            std::fs::write(&path, &mutated).unwrap();
            assert!(load_packed(&path).is_err(), "flip at byte {at} must error");
        }
        // a header claiming ~4 billion units must be rejected up front
        // (not answered with a multi-gigabyte allocation attempt); a
        // valid CRC gets these bodies past the checksum gate
        let mut huge = Vec::new();
        huge.extend_from_slice(b"BCPACK02");
        huge.extend_from_slice(&1u32.to_le_bytes());
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        huge.push(0);
        std::fs::write(&path, with_crc(huge)).unwrap();
        let err = load_packed(&path).unwrap_err().to_string();
        assert!(err.contains("implausible"), "{err}");
        // dims individually under MAX_DIM whose product implies terabytes
        // must be rejected by the packed-size cap before any body read
        let mut wide = Vec::new();
        wide.extend_from_slice(b"BCPACK02");
        wide.extend_from_slice(&1u32.to_le_bytes());
        wide.extend_from_slice(&(1u32 << 22).to_le_bytes());
        wide.extend_from_slice(&(1u32 << 22).to_le_bytes());
        wide.push(0);
        std::fs::write(&path, with_crc(wide)).unwrap();
        let err = load_packed(&path).unwrap_err().to_string();
        assert!(err.contains("implausible packed size"), "{err}");
        // zero layers is invalid too
        let mut zero = Vec::new();
        zero.extend_from_slice(b"BCPACK02");
        zero.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, with_crc(zero)).unwrap();
        assert!(load_packed(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mismatched_layer_chain_is_rejected() {
        // hand-craft a 2-layer file whose dims do not chain (layer0 is
        // 4x8, layer1 claims k=5): a corrupt serving artifact must not
        // load into a net that would panic at forward time
        let path = std::env::temp_dir().join(format!("bc_chain_{}.bin", std::process::id()));
        let mut b = Vec::new();
        b.extend_from_slice(b"BCPACK02");
        b.extend_from_slice(&2u32.to_le_bytes());
        // layer 0: k=4, n=8, relu, 8 scales + 8 shifts, 1 word per col
        b.extend_from_slice(&4u32.to_le_bytes());
        b.extend_from_slice(&8u32.to_le_bytes());
        b.push(1);
        for _ in 0..16 {
            b.extend_from_slice(&1.0f32.to_le_bytes());
        }
        for _ in 0..8 {
            b.extend_from_slice(&0u64.to_le_bytes());
        }
        // layer 1: k=5 (should be 8), n=2
        b.extend_from_slice(&5u32.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes());
        b.push(0);
        for _ in 0..4 {
            b.extend_from_slice(&1.0f32.to_le_bytes());
        }
        for _ in 0..2 {
            b.extend_from_slice(&0u64.to_le_bytes());
        }
        std::fs::write(&path, with_crc(b)).unwrap();
        let err = load_packed(&path).unwrap_err().to_string();
        assert!(err.contains("chain"), "{err}");
        let _ = std::fs::remove_file(&path);
    }
}
