//! Bit-packed, multiplication-free inference engine (paper Sec. 2.6 / 5).
//!
//! With deterministic BinaryConnect, test-time weights are exactly
//! sign(w): 1 bit each. This module packs them 64-per-word (a 32x memory
//! reduction versus f32, beating the paper's ">= 16x" claim) and computes
//! dense layers with **zero multiplications in the weight inner loop** —
//! the sum over k of ±x_k is two accumulations via the identity
//!
//! ```text
//! sum_k s_k x_k  =  2 * sum_{k: s_k=+1} x_k  -  sum_k x_k
//! ```
//!
//! so each output needs only the selected-sum (adds gated by weight bits)
//! and one precomputed row total. This is the honest CPU analogue of the
//! adder-only datapath the paper proposes for ASICs.
//!
//! BN folding: at inference, y = gamma*(z-mu)/sqrt(var+eps)+beta is an
//! affine per-unit transform, folded into (scale, shift) applied once per
//! accumulation — multiplications survive only there, O(units) not
//! O(units * fan_in).
//!
//! The [`bnn`] submodule goes one step further for serving: it binarizes
//! the *activations* too, turning hidden layers into XNOR–popcount over
//! packed words (`dot = k - 2*popcount(a XOR w)`) behind a first-layer
//! f32 escape hatch — `PackedMlp::forward_bnn_into`, selected at the
//! server by [`ForwardMode`].

pub mod bnn;
pub mod export;
pub mod packed;

pub use bnn::{
    pack_rows_into, words_per_row, xnor_layer_bits, xnor_layer_f32, BnnWorkspace, ForwardMode,
};
pub use export::{load_packed, pack_mlp, save_packed};
pub use packed::{argmax, BitMatrix, PackedConvLayer, PackedLayer, PackedMlp, PackedWorkspace};
