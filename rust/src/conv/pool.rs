//! MaxPool2x2 with an argmax-index cache.
//!
//! Activations are `(b, h, w, c)` row-major. The forward records, per
//! pooled output element, the flat index of the winning input element,
//! so the backward is a pure scatter (each input cell wins at most one
//! window — windows are disjoint — so scatter order cannot matter).
//! Ties break toward the first candidate in `(dy, dx)` scan order,
//! which keeps the choice deterministic across batch sizes and ISAs.

/// Pool `y` (shape `(b, h, w, c)`, `h`/`w` even) into `out`
/// (`(b, h/2, w/2, c)`), recording winner indices (flat into `y`) in
/// `idx`. `out.len() == idx.len() == b*h*w*c/4`.
pub fn maxpool2x2_into(y: &[f32], b: usize, h: usize, w: usize, c: usize, out: &mut [f32], idx: &mut [u32]) {
    debug_assert!(h % 2 == 0 && w % 2 == 0);
    debug_assert_eq!(y.len(), b * h * w * c);
    debug_assert_eq!(out.len(), b * h * w * c / 4);
    debug_assert_eq!(idx.len(), out.len());
    debug_assert!(y.len() <= u32::MAX as usize);
    let (oh, ow) = (h / 2, w / 2);
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let o = ((bi * oh + oy) * ow + ox) * c;
                for ci in 0..c {
                    let mut best_i = ((bi * h + 2 * oy) * w + 2 * ox) * c + ci;
                    let mut best = y[best_i];
                    for dy in 0..2usize {
                        for dx in 0..2usize {
                            if dy == 0 && dx == 0 {
                                continue;
                            }
                            let i = ((bi * h + 2 * oy + dy) * w + 2 * ox + dx) * c + ci;
                            let v = y[i];
                            if v > best {
                                best = v;
                                best_i = i;
                            }
                        }
                    }
                    out[o + ci] = best;
                    idx[o + ci] = best_i as u32;
                }
            }
        }
    }
}

/// Scatter pooled gradients back through the argmax cache: `dy` has the
/// pooled shape, `dx` the pre-pool shape. `dx` is overwritten.
pub fn maxpool2x2_backward_into(dy: &[f32], idx: &[u32], dx: &mut [f32]) {
    debug_assert_eq!(dy.len(), idx.len());
    dx.fill(0.0);
    for (g, &i) in dy.iter().zip(idx.iter()) {
        dx[i as usize] += g;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_picks_window_max_per_channel() {
        // one image, 2x2 spatial (a single window), 2 channels
        let y = [
            1.0, -9.0, // (0,0)
            4.0, -1.0, // (0,1)
            3.0, -2.0, // (1,0)
            2.0, -3.0, // (1,1)
        ];
        let mut out = [0.0f32; 2];
        let mut idx = [0u32; 2];
        maxpool2x2_into(&y, 1, 2, 2, 2, &mut out, &mut idx);
        assert_eq!(out, [4.0, -1.0]);
        assert_eq!(idx, [2, 3]); // both maxima live at pixel (0,1)
    }

    #[test]
    fn ties_break_to_the_first_candidate() {
        let y = [5.0f32, 5.0, 5.0, 5.0];
        let mut out = [0.0f32; 1];
        let mut idx = [9u32; 1];
        maxpool2x2_into(&y, 1, 2, 2, 1, &mut out, &mut idx);
        assert_eq!((out[0], idx[0]), (5.0, 0));
    }

    #[test]
    fn backward_routes_gradient_to_the_winner_only() {
        let y = [
            0.0f32, 2.0, //
            1.0, 0.5, //
        ];
        let mut out = [0.0f32; 1];
        let mut idx = [0u32; 1];
        maxpool2x2_into(&y, 1, 2, 2, 1, &mut out, &mut idx);
        let mut dx = [7.0f32; 4];
        maxpool2x2_backward_into(&[3.5], &idx, &mut dx);
        assert_eq!(dx, [0.0, 3.5, 0.0, 0.0]);
    }
}
