//! Binary convolution subsystem: SAME-padding 2-D convolution lowered
//! onto the packed sign-GEMM (`binary::packed::BitMatrix`).
//!
//! The paper's conv nets (Sec. 3.2: the VGG-ish "C3" stack behind the
//! CIFAR-10/SVHN results) are executed by rewriting every convolution as
//! a matrix product over gathered patches:
//!
//! * [`im2col::im2col_into`] gathers, for each output pixel, the
//!   `kh*kw*cin` input window (zeros outside the image — SAME padding)
//!   into one row of a patch matrix `P` of shape
//!   `(b*h*w) x (kh*kw*cin)`. Activations are HWC, filters are the
//!   spec's row-major `[kh, kw, cin, cout]`, so a flattened filter bank
//!   *is* a `(kh*kw*cin) x cout` weight matrix and the conv forward is
//!   literally `Z = P @ W` — the same shape the MLP path feeds to
//!   [`crate::binary::packed::BitMatrix::matmul_scaled_into`]. The
//!   binarized weights therefore never materialize as f32 here either:
//!   the bit-packers (`pack_det_into` / `pack_stoch_into`) run per conv
//!   filter bank exactly as they do per dense layer.
//! * The STE backward is the transpose pair: `dP = dZ · Wb^T` through
//!   the packed transpose kernel, scattered back to `dX` by
//!   [`im2col::col2im_into`] (the exact adjoint of the gather), and
//!   `dW = P^T · dZ` through the dense `gemm_at_b` kernel (real-valued
//!   gradients, like the MLP path).
//! * [`pool::maxpool2x2_into`] / [`pool::maxpool2x2_backward_into`]
//!   implement the paper's MP2 stages with an argmax-index cache so the
//!   backward is a pure scatter.
//! * [`oracle`] holds a naive direct-convolution f32 implementation
//!   (seven loops, no lowering) — the correctness oracle the property
//!   tests pin the packed path against.
//!
//! ## Workspace ownership
//!
//! Nothing in this module allocates on the hot path: every function
//! writes into caller-owned buffers. The callers
//! (`runtime/reference.rs`'s `Workspace`, `binary/packed.rs`'s
//! `PackedWorkspace`) size those buffers once, grow-only, so the
//! zero-alloc warmed-step contract of the MLP path extends to conv
//! (counting-allocator-tested in both places).
//!
//! ## Batch invariance
//!
//! An im2col row for output pixel `(bi, oy, ox)` reads only image `bi`,
//! and the packed GEMM accumulates each output element strictly along
//! its own patch row in packed-word order — the same argument that made
//! `matmul_scaled_into_batched` solo≡coalesced. A request served alone
//! therefore produces bit-identical logits to the same request inside
//! any coalesced batch; the serve integration tests pin this end-to-end
//! for a conv model.
//!
//! ## Spatial schedule
//!
//! The paper's C3 stacking is `(2 x C3) - MP2` repeated: a max-pool
//! follows every *second* conv layer. [`spatial_dims`] encodes that
//! convention once, derived purely from the model spec (4-d weight
//! tensors in param order + the input shape), and is the single source
//! of truth for the runtime plan, the packed exporter, the hw cost
//! model and `bcrun hw`.

pub mod im2col;
pub mod oracle;
pub mod pool;

use crate::runtime::manifest::ModelInfo;
use crate::util::error::Result;
use crate::{bail, ensure};

/// Resolved geometry of one conv stage of a model spec.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConvDims {
    /// The weight param's name (`conv3.W`).
    pub name: String,
    /// Index of the weight tensor in the spec's param list.
    pub param: usize,
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
    /// Input spatial size. SAME padding: the conv output is `h_in x
    /// w_in` too.
    pub h_in: usize,
    pub w_in: usize,
    /// A MaxPool2x2 follows this conv (C3 convention: after every
    /// second conv layer).
    pub pool: bool,
    /// Spatial size flowing into the next stage (halved when `pool`).
    pub h_next: usize,
    pub w_next: usize,
}

impl ConvDims {
    /// Patch width `kh*kw*cin` — the K dimension of the lowered GEMM.
    pub fn patch_k(&self) -> usize {
        self.kh * self.kw * self.cin
    }

    /// Output positions per example (`h_in * w_in`; SAME padding).
    pub fn spatial(&self) -> usize {
        self.h_in * self.w_in
    }

    /// Flattened activation dim leaving this stage (post-pool).
    pub fn out_dim(&self) -> usize {
        self.h_next * self.w_next * self.cout
    }
}

/// Infer every conv stage's spatial geometry from a model spec: 4-d
/// `[kh, kw, cin, cout]` weight tensors in param order, starting from
/// `input_shape = [b, h, w, c]`, SAME padding, MaxPool2x2 after every
/// second conv (the paper's C3 stacking). Returns an empty vec for
/// pure dense specs. This is the shared shape-inference used by the
/// runtime plan, `binary/export.rs`, `hw::step_cost` callers and
/// `bcrun hw` — the one place the convention lives.
pub fn spatial_dims(info: &ModelInfo) -> Result<Vec<ConvDims>> {
    let mut dims: Vec<ConvDims> = vec![];
    let conv_params: Vec<(usize, &crate::runtime::manifest::ParamInfo)> = info
        .params
        .iter()
        .enumerate()
        .filter(|(_, p)| p.name.ends_with(".W") && p.shape.len() == 4)
        .collect();
    if conv_params.is_empty() {
        return Ok(dims);
    }
    ensure!(
        info.input_shape.len() == 4,
        "conv model '{}': input shape {:?} is not [batch, h, w, c]",
        info.name,
        info.input_shape
    );
    // conv stages must precede every dense stage (flatten happens once)
    if let Some(first_dense) = info
        .params
        .iter()
        .position(|p| p.name.ends_with(".W") && p.shape.len() == 2)
    {
        if let Some(&(last_conv, _)) = conv_params.last() {
            ensure!(
                last_conv < first_dense,
                "conv model '{}': conv weight {} appears after a dense layer",
                info.name,
                info.params[last_conv].name
            );
        }
    }
    let (mut h, mut w, mut c) =
        (info.input_shape[1], info.input_shape[2], info.input_shape[3]);
    for (idx, (pi, p)) in conv_params.iter().enumerate() {
        let (kh, kw, cin, cout) = (p.shape[0], p.shape[1], p.shape[2], p.shape[3]);
        ensure!(
            kh % 2 == 1 && kw % 2 == 1 && kh > 0 && kw > 0,
            "conv layer {}: kernel {}x{} must be odd for SAME padding",
            p.name,
            kh,
            kw
        );
        if cin != c {
            bail!(
                "conv layer {}: expects {} input channels, previous stage provides {}",
                p.name,
                cin,
                c
            );
        }
        let pool = idx % 2 == 1;
        if pool {
            ensure!(
                h % 2 == 0 && w % 2 == 0,
                "conv layer {}: MaxPool2x2 needs even spatial dims, got {}x{}",
                p.name,
                h,
                w
            );
        }
        let (h_next, w_next) = if pool { (h / 2, w / 2) } else { (h, w) };
        dims.push(ConvDims {
            name: p.name.clone(),
            param: *pi,
            kh,
            kw,
            cin,
            cout,
            h_in: h,
            w_in: w,
            pool,
            h_next,
            w_next,
        });
        h = h_next;
        w = w_next;
        c = cout;
    }
    Ok(dims)
}

/// Flattened activation dim leaving the conv stack (what the first
/// dense layer must consume). `None` for pure dense specs.
pub fn flatten_dim(dims: &[ConvDims]) -> Option<usize> {
    dims.last().map(ConvDims::out_dim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::reference::{cnn_info, mlp_info};

    #[test]
    fn c3_schedule_matches_the_paper_shape() {
        // 32x32 input, 6 convs, pool after conv1/conv3/conv5: spatial
        // runs 32,32,16,16,8,8 and flattens at 4*4*4*base.
        let info = cnn_info("cnn", 128, 1024, 50);
        let dims = spatial_dims(&info).unwrap();
        assert_eq!(dims.len(), 6);
        let spatial: Vec<usize> = dims.iter().map(|d| d.h_in).collect();
        assert_eq!(spatial, vec![32, 32, 16, 16, 8, 8]);
        let pools: Vec<bool> = dims.iter().map(|d| d.pool).collect();
        assert_eq!(pools, vec![false, true, false, true, false, true]);
        assert_eq!(flatten_dim(&dims), Some(4 * 4 * 512));
        assert_eq!(dims[0].cin, 3);
        assert_eq!(dims[5].cout, 512);
        assert_eq!(dims[2].patch_k(), 9 * 128);
        // the flatten dim must be exactly what the first fc expects
        let fc0 = info.params.iter().find(|p| p.name == "fc0.W").unwrap();
        assert_eq!(fc0.shape[0], flatten_dim(&dims).unwrap());
    }

    #[test]
    fn dense_specs_have_no_conv_dims() {
        let info = mlp_info("m", 784, 64, 2, 10, 16);
        assert_eq!(spatial_dims(&info).unwrap(), vec![]);
    }

    #[test]
    fn channel_mismatch_is_rejected() {
        let mut info = cnn_info("cnn", 8, 32, 4);
        // corrupt conv1's cin
        let p = info.params.iter_mut().find(|p| p.name == "conv1.W").unwrap();
        p.shape[2] += 1;
        let err = spatial_dims(&info).unwrap_err().to_string();
        assert!(err.contains("input channels"), "{err}");
    }
}
