//! Naive direct-convolution f32 oracle.
//!
//! Seven plain loops, no im2col, no packing, no SIMD — deliberately the
//! most transparent possible statement of SAME-padding conv and its
//! gradients. The property tests pin the lowered packed path against
//! these, and `train_step_baseline` runs conv layers through them so
//! the fast≡baseline agreement test covers conv end-to-end. The perf
//! ladder also benches this as the "naive" rung the im2col-packed path
//! must beat.
//!
//! Layouts match the subsystem convention: activations `(b, h, w, c)`
//! row-major HWC, weights `[kh, kw, cin, cout]` row-major.

/// `y[b,oy,ox,co] = sum_{ky,kx,ci} x[b,oy+ky-ph,ox+kx-pw,ci] * w[ky,kx,ci,co]`
/// with zeros outside the image. `y` is overwritten.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_forward(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    wt: &[f32],
    kh: usize,
    kw: usize,
    cout: usize,
    y: &mut [f32],
) {
    debug_assert_eq!(x.len(), b * h * w * cin);
    debug_assert_eq!(wt.len(), kh * kw * cin * cout);
    debug_assert_eq!(y.len(), b * h * w * cout);
    let (ph, pw) = (kh / 2, kw / 2);
    for bi in 0..b {
        for oy in 0..h {
            for ox in 0..w {
                let yo = ((bi * h + oy) * w + ox) * cout;
                y[yo..yo + cout].fill(0.0);
                for ky in 0..kh {
                    let iy = oy + ky;
                    if iy < ph || iy - ph >= h {
                        continue;
                    }
                    let iy = iy - ph;
                    for kx in 0..kw {
                        let ix = ox + kx;
                        if ix < pw || ix - pw >= w {
                            continue;
                        }
                        let ix = ix - pw;
                        let xo = ((bi * h + iy) * w + ix) * cin;
                        for ci in 0..cin {
                            let xv = x[xo + ci];
                            let wo = ((ky * kw + kx) * cin + ci) * cout;
                            for co in 0..cout {
                                y[yo + co] += xv * wt[wo + co];
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Input gradient: `dx = dy (*) flip(w)` — each input pixel gathers the
/// output positions whose window covered it. `dx` is overwritten.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_backward_dx(
    dy: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    wt: &[f32],
    kh: usize,
    kw: usize,
    cout: usize,
    dx: &mut [f32],
) {
    debug_assert_eq!(dy.len(), b * h * w * cout);
    debug_assert_eq!(wt.len(), kh * kw * cin * cout);
    debug_assert_eq!(dx.len(), b * h * w * cin);
    let (ph, pw) = (kh / 2, kw / 2);
    dx.fill(0.0);
    for bi in 0..b {
        for oy in 0..h {
            for ox in 0..w {
                let yo = ((bi * h + oy) * w + ox) * cout;
                for ky in 0..kh {
                    let iy = oy + ky;
                    if iy < ph || iy - ph >= h {
                        continue;
                    }
                    let iy = iy - ph;
                    for kx in 0..kw {
                        let ix = ox + kx;
                        if ix < pw || ix - pw >= w {
                            continue;
                        }
                        let ix = ix - pw;
                        let xo = ((bi * h + iy) * w + ix) * cin;
                        for ci in 0..cin {
                            let wo = ((ky * kw + kx) * cin + ci) * cout;
                            let mut acc = 0.0f32;
                            for co in 0..cout {
                                acc += dy[yo + co] * wt[wo + co];
                            }
                            dx[xo + ci] += acc;
                        }
                    }
                }
            }
        }
    }
}

/// Weight gradient: `dw[ky,kx,ci,co] = sum_{b,oy,ox} x[...] * dy[...]`.
/// `dw` is overwritten.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_backward_dw(
    x: &[f32],
    dy: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    kh: usize,
    kw: usize,
    cout: usize,
    dw: &mut [f32],
) {
    debug_assert_eq!(x.len(), b * h * w * cin);
    debug_assert_eq!(dy.len(), b * h * w * cout);
    debug_assert_eq!(dw.len(), kh * kw * cin * cout);
    let (ph, pw) = (kh / 2, kw / 2);
    dw.fill(0.0);
    for bi in 0..b {
        for oy in 0..h {
            for ox in 0..w {
                let yo = ((bi * h + oy) * w + ox) * cout;
                for ky in 0..kh {
                    let iy = oy + ky;
                    if iy < ph || iy - ph >= h {
                        continue;
                    }
                    let iy = iy - ph;
                    for kx in 0..kw {
                        let ix = ox + kx;
                        if ix < pw || ix - pw >= w {
                            continue;
                        }
                        let ix = ix - pw;
                        let xo = ((bi * h + iy) * w + ix) * cin;
                        for ci in 0..cin {
                            let xv = x[xo + ci];
                            let wo = ((ky * kw + kx) * cin + ci) * cout;
                            for co in 0..cout {
                                dw[wo + co] += xv * dy[yo + co];
                            }
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn numeric_grad(f: &mut dyn FnMut(&[f32]) -> f64, at: &mut Vec<f32>, i: usize) -> f64 {
        let eps = 1e-3f32;
        let keep = at[i];
        at[i] = keep + eps;
        let up = f(at);
        at[i] = keep - eps;
        let dn = f(at);
        at[i] = keep;
        (up - dn) / (2.0 * eps as f64)
    }

    #[test]
    fn identity_kernel_is_identity() {
        // 1x1 kernel, cin==cout, w = I: y == x
        let (b, h, w, c) = (2, 3, 4, 3);
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..b * h * w * c).map(|_| rng.normal()).collect();
        let mut wt = vec![0.0f32; c * c];
        for i in 0..c {
            wt[i * c + i] = 1.0;
        }
        let mut y = vec![0.0f32; x.len()];
        conv2d_forward(&x, b, h, w, c, &wt, 1, 1, c, &mut y);
        assert_eq!(y, x);
    }

    #[test]
    fn gradients_match_numeric_differentiation() {
        // loss = 0.5 * ||conv(x, w)||^2 on a ragged shape; dx and dw
        // must match central differences.
        let (b, h, w, cin, kh, kw, cout) = (2, 3, 5, 2, 3, 3, 3);
        let mut rng = Rng::new(0xD1FF);
        let mut x: Vec<f32> = (0..b * h * w * cin).map(|_| rng.normal() * 0.5).collect();
        let mut wt: Vec<f32> = (0..kh * kw * cin * cout).map(|_| rng.normal() * 0.5).collect();
        let mut y = vec![0.0f32; b * h * w * cout];
        conv2d_forward(&x, b, h, w, cin, &wt, kh, kw, cout, &mut y);
        // dL/dy = y
        let mut dx = vec![0.0f32; x.len()];
        conv2d_backward_dx(&y, b, h, w, cin, &wt, kh, kw, cout, &mut dx);
        let mut dw = vec![0.0f32; wt.len()];
        conv2d_backward_dw(&x, &y, b, h, w, cin, kh, kw, cout, &mut dw);

        let wt_c = wt.clone();
        let mut loss_of_x = |xs: &[f32]| -> f64 {
            let mut yy = vec![0.0f32; b * h * w * cout];
            conv2d_forward(xs, b, h, w, cin, &wt_c, kh, kw, cout, &mut yy);
            0.5 * yy.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>()
        };
        for &i in &[0usize, 7, x.len() / 2, x.len() - 1] {
            let g = numeric_grad(&mut loss_of_x, &mut x, i);
            assert!(
                (g - dx[i] as f64).abs() < 2e-2 * (1.0 + g.abs()),
                "dx[{i}]: analytic {} vs numeric {g}",
                dx[i]
            );
        }
        let x_c = x.clone();
        let mut loss_of_w = |ws: &[f32]| -> f64 {
            let mut yy = vec![0.0f32; b * h * w * cout];
            conv2d_forward(&x_c, b, h, w, cin, ws, kh, kw, cout, &mut yy);
            0.5 * yy.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>()
        };
        for &i in &[0usize, 5, wt.len() / 2, wt.len() - 1] {
            let g = numeric_grad(&mut loss_of_w, &mut wt, i);
            assert!(
                (g - dw[i] as f64).abs() < 2e-2 * (1.0 + g.abs()),
                "dw[{i}]: analytic {} vs numeric {g}",
                dw[i]
            );
        }
    }
}
