//! Patch gather/scatter for the im2col lowering.
//!
//! Layouts (all row-major):
//! * activations `x`: `(b, h, w, c)` — HWC per image, images stacked.
//! * patch matrix `out`: `(b*h*w) x (kh*kw*c)`; row `bi*h*w + oy*w + ox`
//!   holds the SAME-padded window centred on `(oy, ox)` of image `bi`,
//!   column `(ky*kw + kx)*c + ci`. This matches the flattening of a
//!   row-major `[kh, kw, cin, cout]` filter tensor into a
//!   `(kh*kw*cin) x cout` weight matrix, so conv forward is a plain
//!   GEMM over these rows.
//!
//! Both functions write only into caller-owned slices — no allocation —
//! and touch image `bi`'s data only from row block `bi`, which is what
//! makes the lowered GEMM batch-invariant per request.

/// Gather SAME-padded `kh x kw` patches of `x` into `out`.
/// `out.len()` must be exactly `b*h*w * kh*kw*c`; `kh`/`kw` odd.
pub fn im2col_into(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    kh: usize,
    kw: usize,
    out: &mut [f32],
) {
    let patch = kh * kw * c;
    debug_assert_eq!(x.len(), b * h * w * c);
    debug_assert_eq!(out.len(), b * h * w * patch);
    debug_assert!(kh % 2 == 1 && kw % 2 == 1);
    let (ph, pw) = (kh / 2, kw / 2);
    out.fill(0.0);
    for bi in 0..b {
        let img = &x[bi * h * w * c..(bi + 1) * h * w * c];
        let rows = &mut out[bi * h * w * patch..(bi + 1) * h * w * patch];
        for oy in 0..h {
            for ky in 0..kh {
                let iy = oy + ky;
                if iy < ph || iy - ph >= h {
                    continue; // zero padding row
                }
                let iy = iy - ph;
                for kx in 0..kw {
                    // valid ox range for this tap: 0 <= ox + kx - pw < w
                    let ox_lo = pw.saturating_sub(kx);
                    let ox_hi = (w + pw - kx).min(w);
                    let tap = (ky * kw + kx) * c;
                    for ox in ox_lo..ox_hi {
                        let ix = ox + kx - pw;
                        let src = (iy * w + ix) * c;
                        let dst = (oy * w + ox) * patch + tap;
                        rows[dst..dst + c].copy_from_slice(&img[src..src + c]);
                    }
                }
            }
        }
    }
}

/// Scatter-accumulate patch gradients back to the input image grid —
/// the exact adjoint of [`im2col_into`]. `dx` is overwritten (not
/// accumulated into); per-pixel accumulation runs in fixed tap order
/// `(ky, kx)` regardless of batch size, so gradients are
/// batch-placement invariant too.
pub fn col2im_into(
    dpatches: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    kh: usize,
    kw: usize,
    dx: &mut [f32],
) {
    let patch = kh * kw * c;
    debug_assert_eq!(dx.len(), b * h * w * c);
    debug_assert_eq!(dpatches.len(), b * h * w * patch);
    let (ph, pw) = (kh / 2, kw / 2);
    dx.fill(0.0);
    for bi in 0..b {
        let rows = &dpatches[bi * h * w * patch..(bi + 1) * h * w * patch];
        let dimg = &mut dx[bi * h * w * c..(bi + 1) * h * w * c];
        for oy in 0..h {
            for ky in 0..kh {
                let iy = oy + ky;
                if iy < ph || iy - ph >= h {
                    continue;
                }
                let iy = iy - ph;
                for kx in 0..kw {
                    let ox_lo = pw.saturating_sub(kx);
                    let ox_hi = (w + pw - kx).min(w);
                    let tap = (ky * kw + kx) * c;
                    for ox in ox_lo..ox_hi {
                        let ix = ox + kx - pw;
                        let src = (oy * w + ox) * patch + tap;
                        let dst = (iy * w + ix) * c;
                        for ci in 0..c {
                            dimg[dst + ci] += rows[src + ci];
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn patch_rows_match_hand_gather() {
        // 1 image, 3x3x2, 3x3 kernel: centre row sees the whole image,
        // corner rows see zeros outside.
        let (h, w, c) = (3, 3, 2);
        let x: Vec<f32> = (0..h * w * c).map(|i| i as f32 + 1.0).collect();
        let mut out = vec![-1.0; h * w * 9 * c];
        im2col_into(&x, 1, h, w, c, 3, 3, &mut out);
        let patch = 9 * c;
        // centre pixel (1,1): patch is the full image in scan order
        let centre = &out[(1 * w + 1) * patch..(1 * w + 1) * patch + patch];
        assert_eq!(centre, &x[..]);
        // top-left pixel (0,0): taps with ky==0 or kx==0 are padding
        let tl = &out[0..patch];
        for ky in 0..3 {
            for kx in 0..3 {
                let tap = &tl[(ky * 3 + kx) * c..(ky * 3 + kx) * c + c];
                if ky == 0 || kx == 0 {
                    assert_eq!(tap, &[0.0, 0.0], "tap ({ky},{kx}) not padded");
                } else {
                    let src = ((ky - 1) * w + (kx - 1)) * c;
                    assert_eq!(tap, &x[src..src + c]);
                }
            }
        }
    }

    #[test]
    fn col2im_is_the_adjoint_of_im2col() {
        // <im2col(x), d> == <x, col2im(d)> for random x, d — the
        // defining property of the transpose.
        for &(b, h, w, c, kh, kw) in
            &[(2usize, 4usize, 5usize, 3usize, 3usize, 3usize), (1, 3, 3, 1, 1, 1), (3, 6, 2, 2, 5, 3)]
        {
            let mut rng = Rng::new(0x00C2_117E);
            let x: Vec<f32> = (0..b * h * w * c).map(|_| rng.normal()).collect();
            let d: Vec<f32> = (0..b * h * w * kh * kw * c).map(|_| rng.normal()).collect();
            let mut px = vec![0.0; d.len()];
            im2col_into(&x, b, h, w, c, kh, kw, &mut px);
            let mut dx = vec![0.0; x.len()];
            col2im_into(&d, b, h, w, c, kh, kw, &mut dx);
            let lhs: f64 = px.iter().zip(&d).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
            let rhs: f64 = x.iter().zip(&dx).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
            assert!(
                (lhs - rhs).abs() <= 1e-6 * (1.0 + lhs.abs()),
                "adjoint mismatch ({b},{h},{w},{c},{kh},{kw}): {lhs} vs {rhs}"
            );
        }
    }

    #[test]
    fn batch_rows_are_independent_of_neighbours() {
        // the patch rows of image 1 in a batch of 3 equal the rows of
        // the same image gathered alone — the serving batch-invariance
        // precondition.
        let (h, w, c, kh, kw) = (4, 4, 3, 3, 3);
        let mut rng = Rng::new(7);
        let xs: Vec<f32> = (0..3 * h * w * c).map(|_| rng.normal()).collect();
        let mut all = vec![0.0; 3 * h * w * kh * kw * c];
        im2col_into(&xs, 3, h, w, c, kh, kw, &mut all);
        let one = &xs[h * w * c..2 * h * w * c];
        let mut solo = vec![0.0; h * w * kh * kw * c];
        im2col_into(one, 1, h, w, c, kh, kw, &mut solo);
        assert_eq!(&all[solo.len()..2 * solo.len()], &solo[..]);
    }
}
