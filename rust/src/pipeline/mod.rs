//! Minibatch pipeline: shuffled sampling, one-hot target encoding, and a
//! double-buffered prefetch thread with bounded-channel backpressure.
//!
//! The [`Executor`](crate::runtime::Executor) backends consume host
//! batches; batch assembly (gather +
//! one-hot encode) is cheap but not free, so a background thread builds the
//! next batches while the current step executes. A `sync_channel(depth)`
//! bounds memory and applies backpressure if the producer outruns the
//! trainer (std threads; tokio is not in the offline registry and adds
//! nothing to a synchronous training loop).

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;

use crate::data::Dataset;
use crate::util::pool::{par_rows, SendPtr};
use crate::util::Rng;

/// Below this many gathered f32s the pool dispatch costs more than the
/// copy; stay serial so small-batch gathers never contend with the
/// trainer's GEMMs for the pool.
const PAR_GATHER_MIN: usize = 1 << 18;

/// A fully-assembled minibatch in the wire layout the HLO expects.
pub struct Batch {
    /// batch * dim features (row-major).
    pub x: Vec<f32>,
    /// batch * n_classes targets in {-1, +1} (L2-SVM convention).
    pub y: Vec<f32>,
    /// number of real (non-padding) examples; == batch for training.
    pub n_valid: usize,
    /// epoch-relative batch index.
    pub index: usize,
}

/// Encode labels as +/-1 one-vs-rest rows (hinge-loss targets).
///
/// Panics with a diagnosable message on a label outside `0..n_classes`
/// (corrupt data used to surface as an opaque out-of-bounds `Vec` index
/// deep inside this loop).
pub fn encode_targets(labels: &[u8], n_classes: usize, out: &mut Vec<f32>) {
    out.clear();
    out.resize(labels.len() * n_classes, -1.0);
    for (i, &l) in labels.iter().enumerate() {
        assert!(
            (l as usize) < n_classes,
            "encode_targets: label {l} at index {i} out of range (n_classes = {n_classes}); \
             dataset is corrupt or mislabeled"
        );
        out[i * n_classes + l as usize] = 1.0;
    }
}

/// Assemble the batch whose example indices are `idx` (padding repeats the
/// last index; `n_valid` records how many are real). Large gathers copy
/// row blocks in parallel on the fork-join pool.
pub fn gather_batch(ds: &Dataset, idx: &[usize], batch: usize, index: usize) -> Batch {
    assert!(!idx.is_empty() && idx.len() <= batch);
    let dim = ds.dim;
    let last = *idx.last().unwrap();
    let src_of = |row: usize| -> usize {
        if row < idx.len() {
            idx[row]
        } else {
            last
        }
    };
    let mut x = vec![0f32; batch * dim];
    let fill = |lo: usize, out: &mut [f32]| {
        for (r, chunk) in out.chunks_exact_mut(dim).enumerate() {
            chunk.copy_from_slice(ds.row(src_of(lo + r)));
        }
    };
    if batch * dim >= PAR_GATHER_MIN {
        let xp = SendPtr(x.as_mut_ptr());
        par_rows(batch, 16, &|lo, hi| {
            // SAFETY: disjoint row ranges of x.
            let out = unsafe { xp.slice(lo * dim, (hi - lo) * dim) };
            fill(lo, out);
        });
    } else {
        fill(0, &mut x);
    }
    let labels: Vec<u8> = (0..batch).map(|r| ds.labels[src_of(r)]).collect();
    let mut y = Vec::new();
    encode_targets(&labels, ds.n_classes, &mut y);
    Batch { x, y, n_valid: idx.len(), index }
}

/// Plan of batches for one pass over a dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Plan {
    /// random order, drop the final partial batch (training).
    Shuffled { seed: u64 },
    /// in-order, pad the final partial batch (evaluation).
    Sequential,
}

/// Number of batches a plan will produce.
pub fn n_batches(n: usize, batch: usize, plan: Plan) -> usize {
    match plan {
        Plan::Shuffled { .. } => n / batch,
        Plan::Sequential => n.div_ceil(batch),
    }
}

/// Iterate batch index lists for one epoch (no data copying here).
pub fn batch_indices(n: usize, batch: usize, plan: Plan) -> Vec<Vec<usize>> {
    match plan {
        Plan::Shuffled { seed } => {
            let mut rng = Rng::new(seed);
            let perm = rng.permutation(n);
            perm.chunks_exact(batch)
                .map(|c| c.iter().map(|&i| i as usize).collect())
                .collect()
        }
        Plan::Sequential => (0..n)
            .collect::<Vec<_>>()
            .chunks(batch)
            .map(|c| c.to_vec())
            .collect(),
    }
}

/// Background prefetcher: builds batches on a worker thread.
pub struct Prefetcher {
    rx: Option<Receiver<Batch>>,
    handle: Option<JoinHandle<()>>,
    pub n_batches: usize,
}

impl Prefetcher {
    /// Spawn a producer for one epoch over `ds`. `depth` bounds the queue.
    pub fn spawn(ds: &Dataset, batch: usize, plan: Plan, depth: usize) -> Prefetcher {
        let plans = batch_indices(ds.len(), batch, plan);
        let n = plans.len();
        let ds = ds.clone(); // datasets are Arc-able later; clone is fine at this scale
        let (tx, rx) = sync_channel(depth.max(1));
        let handle = std::thread::spawn(move || {
            for (bi, idx) in plans.into_iter().enumerate() {
                let b = gather_batch(&ds, &idx, batch, bi);
                if tx.send(b).is_err() {
                    return; // consumer dropped early
                }
            }
        });
        Prefetcher { rx: Some(rx), handle: Some(handle), n_batches: n }
    }

    pub fn next(&mut self) -> Option<Batch> {
        self.rx.as_ref().and_then(|rx| rx.recv().ok())
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // Drop the receiver FIRST so a producer blocked on a full channel
        // sees a send error and exits; only then join.
        drop(self.rx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::synth_mnist;

    #[test]
    fn encode_targets_pm1() {
        let mut y = vec![];
        encode_targets(&[0, 2], 3, &mut y);
        assert_eq!(y, vec![1.0, -1.0, -1.0, -1.0, -1.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn encode_targets_rejects_corrupt_label() {
        let mut y = vec![];
        encode_targets(&[0, 3], 3, &mut y); // label 3 with n_classes 3
    }

    #[test]
    fn shuffled_plan_covers_dataset_once() {
        let plans = batch_indices(100, 10, Plan::Shuffled { seed: 3 });
        assert_eq!(plans.len(), 10);
        let mut all: Vec<usize> = plans.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn shuffled_drops_partial() {
        let plans = batch_indices(105, 10, Plan::Shuffled { seed: 3 });
        assert_eq!(plans.len(), 10);
        assert_eq!(n_batches(105, 10, Plan::Shuffled { seed: 3 }), 10);
    }

    #[test]
    fn sequential_pads_partial() {
        let plans = batch_indices(25, 10, Plan::Sequential);
        assert_eq!(plans.len(), 3);
        assert_eq!(plans[2], vec![20, 21, 22, 23, 24]);
    }

    #[test]
    fn different_seeds_different_order() {
        let a = batch_indices(50, 5, Plan::Shuffled { seed: 1 });
        let b = batch_indices(50, 5, Plan::Shuffled { seed: 2 });
        assert_ne!(a, b);
    }

    #[test]
    fn gather_batch_pads_and_counts() {
        let ds = synth_mnist(30, 1);
        let b = gather_batch(&ds, &[28, 29], 8, 0);
        assert_eq!(b.n_valid, 2);
        assert_eq!(b.x.len(), 8 * 784);
        assert_eq!(b.y.len(), 8 * 10);
        // padding repeats the last row
        assert_eq!(&b.x[784..2 * 784], &b.x[2 * 784..3 * 784]);
    }

    #[test]
    fn prefetcher_yields_all_batches() {
        let ds = synth_mnist(64, 2);
        let mut pf = Prefetcher::spawn(&ds, 16, Plan::Shuffled { seed: 9 }, 2);
        assert_eq!(pf.n_batches, 4);
        let mut count = 0;
        while let Some(b) = pf.next() {
            assert_eq!(b.n_valid, 16);
            count += 1;
        }
        assert_eq!(count, 4);
    }

    #[test]
    fn prefetcher_early_drop_does_not_hang() {
        let ds = synth_mnist(256, 3);
        let mut pf = Prefetcher::spawn(&ds, 8, Plan::Sequential, 1);
        let _ = pf.next();
        drop(pf); // must not deadlock on the blocked producer
    }
}
