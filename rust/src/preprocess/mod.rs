//! Input preprocessing: global contrast normalization + ZCA whitening
//! (the paper's CIFAR-10 / SVHN pipeline, Sec. 3.2) and per-feature
//! standardization (MNIST).
//!
//! ZCA fits on the training split only and is then applied to val/test with
//! the same statistics — fitting on test would leak. The whitening matrix
//! for D = 3072 costs one O(D^3) eigendecomposition (see `linalg`); fits
//! are cached to disk keyed by dataset name + size.

pub mod linalg;

use std::path::Path;

use crate::data::Dataset;
use linalg::sym_eig;

/// Global contrast normalization, in place, per image:
/// x <- s * (x - mean(x)) / max(eps, ||x - mean(x)||_2 / sqrt(dim)).
pub fn gcn(ds: &mut Dataset, scale: f32, eps: f32) {
    let dim = ds.dim;
    for row in ds.x.chunks_mut(dim) {
        let mean = row.iter().sum::<f32>() / dim as f32;
        let mut ss = 0.0f32;
        for v in row.iter_mut() {
            *v -= mean;
            ss += *v * *v;
        }
        let norm = (ss / dim as f32).sqrt().max(eps);
        for v in row.iter_mut() {
            *v = scale * *v / norm;
        }
    }
}

/// Per-feature standardization fit on a training set.
pub struct Standardizer {
    pub mean: Vec<f32>,
    pub std: Vec<f32>,
}

impl Standardizer {
    pub fn fit(ds: &Dataset) -> Self {
        let d = ds.dim;
        let n = ds.len().max(1);
        let mut mean = vec![0f64; d];
        for row in ds.x.chunks(d) {
            for (m, &v) in mean.iter_mut().zip(row) {
                *m += v as f64;
            }
        }
        for m in mean.iter_mut() {
            *m /= n as f64;
        }
        let mut var = vec![0f64; d];
        for row in ds.x.chunks(d) {
            for ((s, &v), m) in var.iter_mut().zip(row).zip(&mean) {
                let c = v as f64 - m;
                *s += c * c;
            }
        }
        let std = var
            .iter()
            .map(|&s| ((s / n as f64).sqrt().max(1e-6)) as f32)
            .collect();
        Self { mean: mean.iter().map(|&m| m as f32).collect(), std }
    }

    pub fn apply(&self, ds: &mut Dataset) {
        let d = ds.dim;
        for row in ds.x.chunks_mut(d) {
            for ((v, m), s) in row.iter_mut().zip(&self.mean).zip(&self.std) {
                *v = (*v - m) / s;
            }
        }
    }
}

/// ZCA whitening: W = U diag((lambda + eps)^-1/2) U^T, held in the
/// factored form  W = s0*I + U diag(D) U^T  with U the top-r sample
/// eigenvectors and s0 = 1/sqrt(eps).
///
/// When the fit uses n < d samples (always true at CIFAR scale here), the
/// sample covariance has rank <= n-1; eigenpairs come EXACTLY from the
/// n x n Gram matrix (O(n^3) instead of O(d^3) — the d = 3072
/// eigendecomposition would cost minutes, the n = 2000 Gram seconds), and
/// every null-space direction is whitened by the constant 1/sqrt(eps).
/// Application is two thin GEMVs per row (2*d*r) instead of a d^2 GEMV.
pub struct Zca {
    pub mean: Vec<f32>,
    /// d x r row-major eigenbasis.
    u: Vec<f32>,
    /// r entries: 1/sqrt(lambda_j + eps) - s0.
    diag: Vec<f32>,
    s0: f32,
    pub d: usize,
    pub r: usize,
}

impl Zca {
    /// Fit on (a subsample of) the training set. `max_samples` bounds the
    /// Gram-matrix cost; 0 = use all rows.
    pub fn fit(ds: &Dataset, eps: f64, max_samples: usize) -> Result<Self, String> {
        let d = ds.dim;
        let n_all = ds.len();
        let n = if max_samples > 0 { n_all.min(max_samples) } else { n_all };
        if n < 2 {
            return Err("zca: need at least 2 samples".into());
        }
        let threads = std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4);
        // mean
        let mut mean = vec![0f64; d];
        for i in 0..n {
            for (m, &v) in mean.iter_mut().zip(ds.row(i)) {
                *m += v as f64;
            }
        }
        for m in mean.iter_mut() {
            *m /= n as f64;
        }
        // centered data, f64, row-major n x d
        let mut xc = vec![0f64; n * d];
        for i in 0..n {
            for (j, &v) in ds.row(i).iter().enumerate() {
                xc[i * d + j] = v as f64 - mean[j];
            }
        }
        // Gram matrix G = Xc Xc^T / (n-1), threaded over row blocks
        let mut g = vec![0f64; n * n];
        let rows_per = n.div_ceil(threads).max(1);
        std::thread::scope(|s| {
            for (t, gchunk) in g.chunks_mut(rows_per * n).enumerate() {
                let lo = t * rows_per;
                let xc = &xc;
                s.spawn(move || {
                    for (ri, grow) in gchunk.chunks_mut(n).enumerate() {
                        let i = lo + ri;
                        let xi = &xc[i * d..(i + 1) * d];
                        for (j, gv) in grow.iter_mut().enumerate().skip(i) {
                            let xj = &xc[j * d..(j + 1) * d];
                            let mut acc = 0.0;
                            for (a, b) in xi.iter().zip(xj) {
                                acc += a * b;
                            }
                            *gv = acc / (n - 1) as f64;
                        }
                    }
                });
            }
        });
        for i in 0..n {
            for j in 0..i {
                g[i * n + j] = g[j * n + i];
            }
        }
        let eig = sym_eig(&g, n)?;
        // keep eigenvalues above a floor; they are ascending -> take tail
        let tol = 1e-10 * eig.values[n - 1].max(1e-30);
        let kept: Vec<usize> =
            (0..n).rev().filter(|&j| eig.values[j] > tol).collect();
        let r = kept.len();
        let s0 = (1.0 / eps.sqrt()) as f32;
        // U[:, j] = Xc^T v_j / sqrt((n-1) * lambda_j)  (exact unit vectors)
        let mut u = vec![0f32; d * r];
        let mut diag = vec![0f32; r];
        let cols_per = r.div_ceil(threads).max(1);
        std::thread::scope(|s| {
            for tb in 0..threads {
                let lo = tb * cols_per;
                let hi = ((tb + 1) * cols_per).min(r);
                if lo >= hi {
                    break;
                }
                let kept = &kept;
                let eigv = &eig;
                let xc = &xc;
                // each worker fills its own column range via raw pointer
                // arithmetic avoided: use interior chunks through unsafe-free
                // trick — write into a local then merge
                let handle = s.spawn(move || {
                    let mut local = vec![0f32; d * (hi - lo)];
                    for (cl, &jj) in kept[lo..hi].iter().enumerate() {
                        let lam = eigv.values[jj];
                        let scale = 1.0 / ((n - 1) as f64 * lam).sqrt();
                        for i in 0..n {
                            let vij = eigv.vectors[i * n + jj];
                            if vij == 0.0 {
                                continue;
                            }
                            let f = vij * scale;
                            let xrow = &xc[i * d..(i + 1) * d];
                            let lcol = &mut local[cl * d..(cl + 1) * d];
                            for (lv, &xv) in lcol.iter_mut().zip(xrow) {
                                *lv += (f * xv) as f32;
                            }
                        }
                    }
                    (lo, hi, local)
                });
                let (lo, hi, local) = handle.join().unwrap();
                for (cl, col) in (lo..hi).enumerate() {
                    for i in 0..d {
                        u[i * r + col] = local[cl * d + i];
                    }
                }
            }
        });
        for (out, &jj) in diag.iter_mut().zip(&kept) {
            *out = (1.0 / (eig.values[jj] + eps).sqrt()) as f32 - s0;
        }
        Ok(Self { mean: mean.iter().map(|&m| m as f32).collect(), u, diag, s0, d, r })
    }

    /// The whitening matrix row `i` (materialized on demand; tests only).
    pub fn w_row(&self, i: usize) -> Vec<f32> {
        let mut row = vec![0f32; self.d];
        row[i] = self.s0;
        for j in 0..self.r {
            let f = self.u[i * self.r + j] * self.diag[j];
            if f == 0.0 {
                continue;
            }
            for (o, chunk) in row.iter_mut().zip(0..self.d) {
                *o += f * self.u[chunk * self.r + j];
            }
        }
        row
    }

    /// Whiten a dataset in place: y = s0*(x-m) + U (D * (U^T (x-m))).
    ///
    /// Batched through the kernel layer's panel GEMMs in row chunks
    /// (T = Cen·U, column-scaled by D, then T·Uᵀ back to feature space),
    /// so the dataset-wide scratch stays bounded at ROWS·(d+r) floats and
    /// the GEMMs — not a hand-rolled per-row loop — carry the 2·d·r work.
    pub fn apply(&self, ds: &mut Dataset) {
        assert_eq!(ds.dim, self.d);
        let d = self.d;
        let r = self.r;
        const ROWS: usize = 256;
        let mut cen = vec![0f32; ROWS * d];
        let mut t = vec![0f32; ROWS * r];
        for chunk in ds.x.chunks_mut(ROWS * d) {
            let rows = chunk.len() / d;
            let cen = &mut cen[..rows * d];
            let t = &mut t[..rows * r];
            for (crow, xrow) in cen.chunks_exact_mut(d).zip(chunk.chunks_exact(d)) {
                for ((c, &v), &m) in crow.iter_mut().zip(xrow).zip(&self.mean) {
                    *c = v - m;
                }
            }
            // T[rows x r] = Cen · U
            crate::kernel::gemm(cen, &self.u, rows, d, r, t);
            for trow in t.chunks_exact_mut(r) {
                for (tv, &dv) in trow.iter_mut().zip(&self.diag) {
                    *tv *= dv;
                }
            }
            // chunk[rows x d] = T · Uᵀ  (r == 0 degenerates to fill(0.0))
            crate::kernel::gemm_a_bt(t, &self.u, rows, r, d, chunk);
            for (o, &cv) in chunk.iter_mut().zip(cen.iter()) {
                *o += self.s0 * cv;
            }
        }
    }

    /// Cache serialization:
    /// [d u64][r u64][s0 f32][mean d f32][diag r f32][u d*r f32], LE.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(&(self.d as u64).to_le_bytes())?;
        f.write_all(&(self.r as u64).to_le_bytes())?;
        f.write_all(&self.s0.to_le_bytes())?;
        for v in self.mean.iter().chain(self.diag.iter()).chain(self.u.iter()) {
            f.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> std::io::Result<Self> {
        use std::io::Read;
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut b8 = [0u8; 8];
        f.read_exact(&mut b8)?;
        let d = u64::from_le_bytes(b8) as usize;
        f.read_exact(&mut b8)?;
        let r = u64::from_le_bytes(b8) as usize;
        let mut b4 = [0u8; 4];
        f.read_exact(&mut b4)?;
        let s0 = f32::from_le_bytes(b4);
        let mut buf = vec![0u8; 4 * (d + r + d * r)];
        f.read_exact(&mut buf)?;
        let vals: Vec<f32> = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Self {
            mean: vals[..d].to_vec(),
            diag: vals[d..d + r].to_vec(),
            u: vals[d + r..].to_vec(),
            s0,
            d,
            r,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_ds(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut ds = Dataset::new("t", (1, d, 1), 2);
        // correlated features so whitening has something to do
        for i in 0..n {
            let base = rng.normal();
            let row: Vec<f32> = (0..d)
                .map(|j| base * (1.0 + j as f32 * 0.1) + 0.3 * rng.normal() + j as f32)
                .collect();
            ds.push(&row, (i % 2) as u8);
        }
        ds
    }

    #[test]
    fn gcn_zero_mean_unit_contrast() {
        let mut ds = random_ds(20, 16, 1);
        gcn(&mut ds, 1.0, 1e-8);
        for i in 0..ds.len() {
            let r = ds.row(i);
            let mean: f32 = r.iter().sum::<f32>() / 16.0;
            let rms: f32 = (r.iter().map(|v| v * v).sum::<f32>() / 16.0).sqrt();
            assert!(mean.abs() < 1e-4, "mean={mean}");
            assert!((rms - 1.0).abs() < 1e-3, "rms={rms}");
        }
    }

    #[test]
    fn gcn_constant_image_stays_finite() {
        let mut ds = Dataset::new("c", (1, 4, 1), 1);
        ds.push(&[0.5; 4], 0);
        gcn(&mut ds, 1.0, 1e-8);
        assert!(ds.x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn standardizer_zero_mean_unit_var() {
        let mut ds = random_ds(500, 8, 2);
        let st = Standardizer::fit(&ds);
        st.apply(&mut ds);
        let d = ds.dim;
        for j in 0..d {
            let col: Vec<f32> = (0..ds.len()).map(|i| ds.row(i)[j]).collect();
            let mean: f32 = col.iter().sum::<f32>() / col.len() as f32;
            let var: f32 =
                col.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / col.len() as f32;
            assert!(mean.abs() < 1e-3);
            assert!((var - 1.0).abs() < 0.02, "var={var}");
        }
    }

    #[test]
    fn zca_whitens_covariance() {
        let mut ds = random_ds(800, 6, 3);
        let zca = Zca::fit(&ds, 1e-6, 0).unwrap();
        zca.apply(&mut ds);
        let d = ds.dim;
        let n = ds.len();
        // empirical covariance ~ identity
        let mut mean = vec![0f64; d];
        for i in 0..n {
            for (m, &v) in mean.iter_mut().zip(ds.row(i)) {
                *m += v as f64 / n as f64;
            }
        }
        for a in 0..d {
            for b in 0..d {
                let mut c = 0.0;
                for i in 0..n {
                    let r = ds.row(i);
                    c += (r[a] as f64 - mean[a]) * (r[b] as f64 - mean[b]);
                }
                c /= (n - 1) as f64;
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((c - want).abs() < 0.05, "cov[{a}{b}]={c}");
            }
        }
    }

    #[test]
    fn zca_is_symmetric_transform() {
        let ds = random_ds(200, 5, 4);
        let zca = Zca::fit(&ds, 1e-5, 0).unwrap();
        let w: Vec<Vec<f32>> = (0..5).map(|i| zca.w_row(i)).collect();
        for i in 0..5 {
            for j in 0..5 {
                let diff = w[i][j] - w[j][i];
                assert!(diff.abs() < 1e-4);
            }
        }
    }

    #[test]
    fn zca_save_load_roundtrip() {
        let mut ds = random_ds(100, 4, 5);
        let zca = Zca::fit(&ds, 1e-5, 0).unwrap();
        let path = std::env::temp_dir().join(format!("zca_test_{}.bin", std::process::id()));
        zca.save(&path).unwrap();
        let loaded = Zca::load(&path).unwrap();
        assert_eq!(zca.d, loaded.d);
        assert_eq!(zca.r, loaded.r);
        assert_eq!(zca.mean, loaded.mean);
        let mut ds2 = ds.clone();
        zca.apply(&mut ds);
        loaded.apply(&mut ds2);
        assert_eq!(ds.x, ds2.x);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn zca_subsample_close_to_full() {
        let ds = random_ds(1000, 4, 6);
        let full = Zca::fit(&ds, 1e-4, 0).unwrap();
        let sub = Zca::fit(&ds, 1e-4, 500).unwrap();
        let mut a = ds.clone();
        let mut b = ds.clone();
        full.apply(&mut a);
        sub.apply(&mut b);
        let mad: f32 = a.x.iter().zip(&b.x).map(|(x, y)| (x - y).abs()).sum::<f32>()
            / a.x.len() as f32;
        assert!(mad < 0.3, "subsampled fit too far from full: {mad}");
    }

    #[test]
    fn zca_tall_data_uses_full_rank_and_whitens() {
        // n > d: rank = d, the identity+lowrank form must still whiten.
        let mut ds = random_ds(400, 3, 7);
        let zca = Zca::fit(&ds, 1e-6, 0).unwrap();
        assert_eq!(zca.r, 3);
        zca.apply(&mut ds);
        let n = ds.len();
        for a in 0..3 {
            for b in 0..3 {
                let mut c = 0.0f64;
                let ma: f64 = (0..n).map(|i| ds.row(i)[a] as f64).sum::<f64>() / n as f64;
                let mb: f64 = (0..n).map(|i| ds.row(i)[b] as f64).sum::<f64>() / n as f64;
                for i in 0..n {
                    c += (ds.row(i)[a] as f64 - ma) * (ds.row(i)[b] as f64 - mb);
                }
                c /= (n - 1) as f64;
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((c - want).abs() < 0.05, "cov[{a}{b}]={c}");
            }
        }
    }

    #[test]
    fn zca_wide_data_exact_on_span() {
        // n < d (the CIFAR-scale regime): components in the data span are
        // whitened to unit variance.
        let mut ds = random_ds(60, 100, 8);
        let zca = Zca::fit(&ds, 1e-8, 0).unwrap();
        assert!(zca.r < 60, "rank must be < n");
        zca.apply(&mut ds);
        // projections onto former principal directions have variance ~1:
        // total variance should be close to the rank (span whitened to 1,
        // null space contributes ~0 since data lives in the span)
        let n = ds.len();
        let d = ds.dim;
        let mut mean = vec![0f64; d];
        for i in 0..n {
            for (m, &v) in mean.iter_mut().zip(ds.row(i)) {
                *m += v as f64 / n as f64;
            }
        }
        let mut total = 0.0f64;
        for i in 0..n {
            for (j, &v) in ds.row(i).iter().enumerate() {
                let c = v as f64 - mean[j];
                total += c * c;
            }
        }
        total /= (n - 1) as f64;
        let r = zca.r as f64;
        assert!((total - r).abs() / r < 0.15, "total var {total} vs rank {r}");
    }
}
