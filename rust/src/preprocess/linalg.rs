//! Dense symmetric linear algebra substrate for ZCA whitening.
//!
//! No LAPACK is available offline, so we implement the classic EISPACK
//! pair: `tred2` (Householder reduction of a real symmetric matrix to
//! tridiagonal form, accumulating transformations) followed by `tql2`
//! (QL with implicit shifts on the tridiagonal), giving the full
//! eigendecomposition A = V diag(d) V^T. O(n^3), done once per dataset and
//! cached; n = 3072 for CIFAR-scale ZCA.
//!
//! This module is eigendecomposition only. The f32 GEMM trio lives in
//! [`crate::kernel`] (panel-packed + multithreaded) and the whitening
//! pipeline calls it directly; the allocating back-compat wrappers that
//! used to sit here are gone.

/// Column-major-agnostic square matrix as a flat row-major Vec<f64>.
#[derive(Clone)]
pub struct SymEig {
    /// eigenvalues, ascending.
    pub values: Vec<f64>,
    /// eigenvectors; column j (i.e. `vectors[i*n + j]` over i) pairs with
    /// `values[j]`.
    pub vectors: Vec<f64>,
    pub n: usize,
}

/// Householder reduction to tridiagonal (EISPACK tred2).
fn tred2(n: usize, a: &mut [f64], d: &mut [f64], e: &mut [f64]) {
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        let mut scale = 0.0;
        if l > 0 {
            for k in 0..=l {
                scale += a[i * n + k].abs();
            }
            if scale == 0.0 {
                e[i] = a[i * n + l];
            } else {
                for k in 0..=l {
                    a[i * n + k] /= scale;
                    h += a[i * n + k] * a[i * n + k];
                }
                let mut f = a[i * n + l];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                a[i * n + l] = f - g;
                f = 0.0;
                for j in 0..=l {
                    a[j * n + i] = a[i * n + j] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += a[j * n + k] * a[i * n + k];
                    }
                    for k in (j + 1)..=l {
                        g += a[k * n + j] * a[i * n + k];
                    }
                    e[j] = g / h;
                    f += e[j] * a[i * n + j];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = a[i * n + j];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        a[j * n + k] -= f * e[k] + g * a[i * n + k];
                    }
                }
            }
        } else {
            e[i] = a[i * n + l];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        let l = i;
        if d[i] != 0.0 {
            for j in 0..l {
                let mut g = 0.0;
                for k in 0..l {
                    g += a[i * n + k] * a[k * n + j];
                }
                for k in 0..l {
                    a[k * n + j] -= g * a[k * n + i];
                }
            }
        }
        d[i] = a[i * n + i];
        a[i * n + i] = 1.0;
        for j in 0..l {
            a[j * n + i] = 0.0;
            a[i * n + j] = 0.0;
        }
    }
}

/// QL with implicit shifts on a symmetric tridiagonal (EISPACK tql2).
fn tql2(n: usize, d: &mut [f64], e: &mut [f64], z: &mut [f64]) -> Result<(), String> {
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                return Err(format!("tql2: no convergence at row {l}"));
            }
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            let sign_r = if g >= 0.0 { r.abs() } else { -r.abs() };
            g = d[m] - d[l] + e[l] / (g + sign_r);
            let mut s = 1.0;
            let mut c = 1.0;
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                for k in 0..n {
                    f = z[k * n + i + 1];
                    z[k * n + i + 1] = s * z[k * n + i] + c * f;
                    z[k * n + i] = c * z[k * n + i] - s * f;
                }
            }
            if r == 0.0 && m > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    // sort ascending, carrying eigenvectors
    for i in 0..n {
        let mut k = i;
        let mut p = d[i];
        for j in (i + 1)..n {
            if d[j] < p {
                k = j;
                p = d[j];
            }
        }
        if k != i {
            d.swap(i, k);
            for r in 0..n {
                z.swap(r * n + i, r * n + k);
            }
        }
    }
    Ok(())
}

/// Full eigendecomposition of a symmetric matrix (row-major, n x n).
pub fn sym_eig(a: &[f64], n: usize) -> Result<SymEig, String> {
    assert_eq!(a.len(), n * n);
    let mut z = a.to_vec();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];
    tred2(n, &mut z, &mut d, &mut e);
    tql2(n, &mut d, &mut e, &mut z)?;
    Ok(SymEig { values: d, vectors: z, n })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_sym(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal() as f64;
                a[i * n + j] = v;
                a[j * n + i] = v;
            }
        }
        a
    }

    fn check_decomposition(a: &[f64], eig: &SymEig, tol: f64) {
        let n = eig.n;
        // A * v_j = lambda_j * v_j
        for j in 0..n {
            for i in 0..n {
                let mut av = 0.0;
                for k in 0..n {
                    av += a[i * n + k] * eig.vectors[k * n + j];
                }
                let lv = eig.values[j] * eig.vectors[i * n + j];
                assert!((av - lv).abs() < tol, "residual {} at ({i},{j})", av - lv);
            }
        }
    }

    #[test]
    fn eig_identity() {
        let n = 5;
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let e = sym_eig(&a, n).unwrap();
        for v in &e.values {
            assert!((v - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn eig_known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 1 and 3
        let a = vec![2.0, 1.0, 1.0, 2.0];
        let e = sym_eig(&a, 2).unwrap();
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn eig_random_matrices() {
        for n in [3, 8, 17, 40] {
            let a = random_sym(n, n as u64);
            let e = sym_eig(&a, n).unwrap();
            check_decomposition(&a, &e, 1e-8);
            // ascending order
            for w in e.values.windows(2) {
                assert!(w[0] <= w[1] + 1e-12);
            }
        }
    }

    #[test]
    fn eig_vectors_orthonormal() {
        let n = 12;
        let a = random_sym(n, 99);
        let e = sym_eig(&a, n).unwrap();
        for i in 0..n {
            for j in 0..n {
                let mut dot = 0.0;
                for k in 0..n {
                    dot += e.vectors[k * n + i] * e.vectors[k * n + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-9, "V^T V [{i}{j}] = {dot}");
            }
        }
    }

}
