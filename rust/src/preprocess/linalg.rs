//! Dense symmetric linear algebra substrate for ZCA whitening.
//!
//! No LAPACK is available offline, so we implement the classic EISPACK
//! pair: `tred2` (Householder reduction of a real symmetric matrix to
//! tridiagonal form, accumulating transformations) followed by `tql2`
//! (QL with implicit shifts on the tridiagonal), giving the full
//! eigendecomposition A = V diag(d) V^T. O(n^3), done once per dataset and
//! cached; n = 3072 for CIFAR-scale ZCA.
//!
//! The f32 GEMM trio that used to live here moved to [`crate::kernel`]
//! (blocked + multithreaded); `matmul_f32`/`matmul_at_b`/`matmul_a_bt`
//! remain as allocating back-compat wrappers, and the f64 `matmul` rides
//! the same thread pool.

/// Column-major-agnostic square matrix as a flat row-major Vec<f64>.
#[derive(Clone)]
pub struct SymEig {
    /// eigenvalues, ascending.
    pub values: Vec<f64>,
    /// eigenvectors; column j (i.e. `vectors[i*n + j]` over i) pairs with
    /// `values[j]`.
    pub vectors: Vec<f64>,
    pub n: usize,
}

/// Householder reduction to tridiagonal (EISPACK tred2).
fn tred2(n: usize, a: &mut [f64], d: &mut [f64], e: &mut [f64]) {
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        let mut scale = 0.0;
        if l > 0 {
            for k in 0..=l {
                scale += a[i * n + k].abs();
            }
            if scale == 0.0 {
                e[i] = a[i * n + l];
            } else {
                for k in 0..=l {
                    a[i * n + k] /= scale;
                    h += a[i * n + k] * a[i * n + k];
                }
                let mut f = a[i * n + l];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                a[i * n + l] = f - g;
                f = 0.0;
                for j in 0..=l {
                    a[j * n + i] = a[i * n + j] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += a[j * n + k] * a[i * n + k];
                    }
                    for k in (j + 1)..=l {
                        g += a[k * n + j] * a[i * n + k];
                    }
                    e[j] = g / h;
                    f += e[j] * a[i * n + j];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = a[i * n + j];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        a[j * n + k] -= f * e[k] + g * a[i * n + k];
                    }
                }
            }
        } else {
            e[i] = a[i * n + l];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        let l = i;
        if d[i] != 0.0 {
            for j in 0..l {
                let mut g = 0.0;
                for k in 0..l {
                    g += a[i * n + k] * a[k * n + j];
                }
                for k in 0..l {
                    a[k * n + j] -= g * a[k * n + i];
                }
            }
        }
        d[i] = a[i * n + i];
        a[i * n + i] = 1.0;
        for j in 0..l {
            a[j * n + i] = 0.0;
            a[i * n + j] = 0.0;
        }
    }
}

/// QL with implicit shifts on a symmetric tridiagonal (EISPACK tql2).
fn tql2(n: usize, d: &mut [f64], e: &mut [f64], z: &mut [f64]) -> Result<(), String> {
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                return Err(format!("tql2: no convergence at row {l}"));
            }
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            let sign_r = if g >= 0.0 { r.abs() } else { -r.abs() };
            g = d[m] - d[l] + e[l] / (g + sign_r);
            let mut s = 1.0;
            let mut c = 1.0;
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                for k in 0..n {
                    f = z[k * n + i + 1];
                    z[k * n + i + 1] = s * z[k * n + i] + c * f;
                    z[k * n + i] = c * z[k * n + i] - s * f;
                }
            }
            if r == 0.0 && m > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    // sort ascending, carrying eigenvectors
    for i in 0..n {
        let mut k = i;
        let mut p = d[i];
        for j in (i + 1)..n {
            if d[j] < p {
                k = j;
                p = d[j];
            }
        }
        if k != i {
            d.swap(i, k);
            for r in 0..n {
                z.swap(r * n + i, r * n + k);
            }
        }
    }
    Ok(())
}

/// Full eigendecomposition of a symmetric matrix (row-major, n x n).
pub fn sym_eig(a: &[f64], n: usize) -> Result<SymEig, String> {
    assert_eq!(a.len(), n * n);
    let mut z = a.to_vec();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];
    tred2(n, &mut z, &mut d, &mut e);
    tql2(n, &mut d, &mut e, &mut z)?;
    Ok(SymEig { values: d, vectors: z, n })
}

/// C[m x n] = A[m x k] @ B[k x n], row-major f32. Allocating wrapper over
/// the blocked, pool-parallel [`kernel::gemm`](crate::kernel::gemm) (the
/// GEMM trio's one home since the kernel-layer refactor).
pub fn matmul_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    crate::kernel::gemm(a, b, m, k, n, &mut c);
    c
}

/// C[k x n] = A^T @ B where A is (m x k) and B is (m x n) — the backward
/// pass's weight-gradient GEMM (dW = X^T dZ); wraps
/// [`kernel::gemm_at_b`](crate::kernel::gemm_at_b).
pub fn matmul_at_b(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0f32; k * n];
    crate::kernel::gemm_at_b(a, b, m, k, n, &mut c);
    c
}

/// C[m x k] = A @ B^T where A is (m x n) and B is (k x n) — the backward
/// pass's activation-gradient GEMM (dX = dZ W^T); wraps
/// [`kernel::gemm_a_bt`](crate::kernel::gemm_a_bt).
pub fn matmul_a_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * k];
    crate::kernel::gemm_a_bt(a, b, m, n, k, &mut c);
    c
}

/// C = A * B for row-major f64 (ZCA whitening); row blocks ride the
/// fork-join pool, each row keeping the seed's zero-skip ikj order.
pub fn matmul(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0.0; m * n];
    let cp = crate::util::pool::SendPtr(c.as_mut_ptr());
    crate::util::pool::par_rows(m, 8, &|lo, hi| {
        // SAFETY: par_rows hands out disjoint row ranges of C.
        let rows = unsafe { cp.slice(lo * n, (hi - lo) * n) };
        for (r, crow) in rows.chunks_exact_mut(n).enumerate() {
            let i = lo + r;
            for (p, &av) in a[i * k..(i + 1) * k].iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[p * n..(p + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_sym(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal() as f64;
                a[i * n + j] = v;
                a[j * n + i] = v;
            }
        }
        a
    }

    fn check_decomposition(a: &[f64], eig: &SymEig, tol: f64) {
        let n = eig.n;
        // A * v_j = lambda_j * v_j
        for j in 0..n {
            for i in 0..n {
                let mut av = 0.0;
                for k in 0..n {
                    av += a[i * n + k] * eig.vectors[k * n + j];
                }
                let lv = eig.values[j] * eig.vectors[i * n + j];
                assert!((av - lv).abs() < tol, "residual {} at ({i},{j})", av - lv);
            }
        }
    }

    #[test]
    fn eig_identity() {
        let n = 5;
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let e = sym_eig(&a, n).unwrap();
        for v in &e.values {
            assert!((v - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn eig_known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 1 and 3
        let a = vec![2.0, 1.0, 1.0, 2.0];
        let e = sym_eig(&a, 2).unwrap();
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn eig_random_matrices() {
        for n in [3, 8, 17, 40] {
            let a = random_sym(n, n as u64);
            let e = sym_eig(&a, n).unwrap();
            check_decomposition(&a, &e, 1e-8);
            // ascending order
            for w in e.values.windows(2) {
                assert!(w[0] <= w[1] + 1e-12);
            }
        }
    }

    #[test]
    fn eig_vectors_orthonormal() {
        let n = 12;
        let a = random_sym(n, 99);
        let e = sym_eig(&a, n).unwrap();
        for i in 0..n {
            for j in 0..n {
                let mut dot = 0.0;
                for k in 0..n {
                    dot += e.vectors[k * n + i] * e.vectors[k * n + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-9, "V^T V [{i}{j}] = {dot}");
            }
        }
    }

    #[test]
    fn matmul_small() {
        let a = vec![1.0, 2.0, 3.0, 4.0]; // 2x2
        let b = vec![5.0, 6.0, 7.0, 8.0];
        let c = matmul(&a, &b, 2, 2, 2);
        assert_eq!(c, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_f32_matches_f64() {
        let a = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let b = vec![7.0f32, 8.0, 9.0, 10.0, 11.0, 12.0]; // 3x2
        let c = matmul_f32(&a, &b, 2, 3, 2);
        assert_eq!(c, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn transposed_gemms_agree_with_explicit_transpose() {
        let mut rng = Rng::new(31);
        let (m, k, n) = (5, 7, 4);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();

        // A^T B via explicit transpose of A
        let mut at = vec![0f32; k * m];
        for t in 0..m {
            for i in 0..k {
                at[i * m + t] = a[t * k + i];
            }
        }
        let want = matmul_f32(&at, &b, k, m, n);
        let got = matmul_at_b(&a, &b, m, k, n);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }

        // B W^T via explicit transpose of W
        let mut wt = vec![0f32; n * k];
        for i in 0..k {
            for j in 0..n {
                wt[j * k + i] = w[i * n + j];
            }
        }
        let want = matmul_f32(&b, &wt, m, n, k);
        let got = matmul_a_bt(&b, &w, m, n, k);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }
}
