//! Metrics & visualization substrate: histograms (Figure 2), first-layer
//! feature tiles as PGM images (Figure 1), CSV curve files (Figure 3) and
//! mean/std aggregation (Table 2's "± " entries).

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// Fixed-range histogram.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f32,
    pub hi: f32,
    pub counts: Vec<u64>,
    pub n_under: u64,
    pub n_over: u64,
}

impl Histogram {
    pub fn build(values: &[f32], lo: f32, hi: f32, bins: usize) -> Histogram {
        assert!(bins > 0 && hi > lo);
        let mut h = Histogram { lo, hi, counts: vec![0; bins], n_under: 0, n_over: 0 };
        let scale = bins as f32 / (hi - lo);
        for &v in values {
            if v < lo {
                h.n_under += 1;
            } else if v >= hi {
                // count hi itself into the last bin, true overflow beyond
                if v == hi {
                    h.counts[bins - 1] += 1;
                } else {
                    h.n_over += 1;
                }
            } else {
                let b = ((v - lo) * scale) as usize;
                h.counts[b.min(bins - 1)] += 1;
            }
        }
        h
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.n_under + self.n_over
    }

    pub fn bin_center(&self, i: usize) -> f32 {
        let w = (self.hi - self.lo) / self.counts.len() as f32;
        self.lo + (i as f32 + 0.5) * w
    }

    /// Fraction of mass in bins whose center's |x| >= `thresh` — used to
    /// quantify Figure 2's "weights pile up near +/-1" observation.
    pub fn mass_beyond(&self, thresh: f32) -> f64 {
        let total = self.total().max(1) as f64;
        let mut m = self.n_under + self.n_over;
        for (i, &c) in self.counts.iter().enumerate() {
            if self.bin_center(i).abs() >= thresh {
                m += c;
            }
        }
        m as f64 / total
    }

    /// CSV: bin_center,count per line.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("bin_center,count\n");
        for (i, &c) in self.counts.iter().enumerate() {
            let _ = writeln!(s, "{:.6},{}", self.bin_center(i), c);
        }
        s
    }

    /// Console rendering (the paper's Figure 2 at terminal resolution).
    pub fn to_ascii(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let mut s = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let bar = (c as usize * width) / max as usize;
            let _ = writeln!(s, "{:>7.3} |{}{}", self.bin_center(i), "#".repeat(bar), "");
        }
        s
    }
}

/// mean and (population) std of a sample — Table 2 aggregates.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Write a PGM (P5) grayscale image.
pub fn write_pgm(path: &Path, pixels: &[u8], w: usize, h: usize) -> std::io::Result<()> {
    assert_eq!(pixels.len(), w * h);
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write!(f, "P5\n{w} {h}\n255\n")?;
    f.write_all(pixels)?;
    Ok(())
}

/// Tile the first `n_tiles` columns of a (in_dim x units) weight matrix as
/// (side x side) feature images in a grid — Figure 1's visualization.
/// Returns (pixels, width, height).
pub fn feature_tiles(
    w: &[f32],
    in_dim: usize,
    units: usize,
    side: usize,
    n_tiles: usize,
    cols: usize,
) -> (Vec<u8>, usize, usize) {
    assert_eq!(side * side, in_dim, "input is not square-image shaped");
    assert_eq!(w.len(), in_dim * units);
    let n = n_tiles.min(units);
    let rows = n.div_ceil(cols);
    let pad = 2;
    let width = cols * (side + pad) + pad;
    let height = rows * (side + pad) + pad;
    let mut img = vec![32u8; width * height]; // dark gray background
    for t in 0..n {
        // per-tile contrast normalization, like the paper's feature plots
        let col: Vec<f32> = (0..in_dim).map(|i| w[i * units + t]).collect();
        let maxabs = col.iter().fold(0f32, |a, &b| a.max(b.abs())).max(1e-12);
        let r0 = pad + (t / cols) * (side + pad);
        let c0 = pad + (t % cols) * (side + pad);
        for y in 0..side {
            for x in 0..side {
                let v = col[y * side + x] / maxabs; // [-1, 1]
                let px = ((v * 0.5 + 0.5) * 255.0) as u8;
                img[(r0 + y) * width + (c0 + x)] = px;
            }
        }
    }
    (img, width, height)
}

/// Minimal CSV writer for training curves and bench tables.
pub struct Csv {
    out: String,
    n_cols: usize,
}

impl Csv {
    pub fn new(header: &[&str]) -> Csv {
        Csv { out: format!("{}\n", header.join(",")), n_cols: header.len() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.n_cols, "csv row arity mismatch");
        self.out.push_str(&cells.join(","));
        self.out.push('\n');
    }

    pub fn rowf(&mut self, cells: &[f64]) {
        self.row(&cells.iter().map(|c| format!("{c:.6}")).collect::<Vec<_>>());
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, &self.out)
    }

    pub fn as_str(&self) -> &str {
        &self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_bins_and_total() {
        let h = Histogram::build(&[-1.0, -0.5, 0.0, 0.5, 0.999, 1.0, 2.0], -1.0, 1.0, 4);
        // bins: [-1,-.5) [-0.5,0) [0,.5) [.5,1]; 1.0 folds into the last
        assert_eq!(h.counts, vec![1, 1, 1, 3]);
        assert_eq!(h.n_over, 1); // 2.0
        assert_eq!(h.total(), 7);
    }

    #[test]
    fn histogram_mass_beyond() {
        let vals = vec![-0.95; 50].into_iter().chain(vec![0.0; 50]).collect::<Vec<_>>();
        let h = Histogram::build(&vals, -1.0, 1.0, 40);
        let frac = h.mass_beyond(0.9);
        assert!((frac - 0.5).abs() < 0.01, "{frac}");
    }

    #[test]
    fn histogram_csv_lines() {
        let h = Histogram::build(&[0.0, 0.1], -1.0, 1.0, 2);
        let csv = h.to_csv();
        assert!(csv.starts_with("bin_center,count\n"));
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn mean_std_known() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0, 4.0]);
        assert!((m - 2.5).abs() < 1e-12);
        assert!((s - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }

    #[test]
    fn feature_tiles_dimensions() {
        let in_dim = 16; // 4x4
        let units = 10;
        let w = vec![0.5f32; in_dim * units];
        let (img, wid, hei) = feature_tiles(&w, in_dim, units, 4, 6, 3);
        assert_eq!(img.len(), wid * hei);
        assert_eq!(wid, 3 * 6 + 2);
        assert_eq!(hei, 2 * 6 + 2);
    }

    #[test]
    fn csv_writer_rows() {
        let mut c = Csv::new(&["a", "b"]);
        c.rowf(&[1.0, 2.0]);
        c.row(&["x".into(), "y".into()]);
        let s = c.as_str();
        assert_eq!(s.lines().count(), 3);
        assert!(s.starts_with("a,b\n"));
    }

    #[test]
    #[should_panic]
    fn csv_arity_checked() {
        let mut c = Csv::new(&["a", "b"]);
        c.rowf(&[1.0]);
    }

    #[test]
    fn pgm_writes_header() {
        let p = std::env::temp_dir().join(format!("bc_pgm_{}.pgm", std::process::id()));
        write_pgm(&p, &[0, 128, 255, 64], 2, 2).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert!(bytes.starts_with(b"P5\n2 2\n255\n"));
        let _ = std::fs::remove_file(&p);
    }
}
