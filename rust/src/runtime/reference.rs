//! Pure-Rust reference backend: Algorithm 1 for the paper's MLP, with no
//! external runtime.
//!
//! Implements the exact semantics of the Python/HLO path
//! (python/compile/train.py + layers.py) in plain f32 loops:
//!
//! * **binarize** (Eqs. 1-3): deterministic sign to ±H or stochastic ±H
//!   with p = hard_sigmoid(w/H), H the layer's Glorot coefficient;
//! * **forward**: dense GEMM on the binarized weights, batch norm (train:
//!   batch statistics + running-stat update; eval: running statistics),
//!   ReLU, inverted dropout, L2-SVM squared-hinge output;
//! * **backward**: straight-through estimator — the gradient w.r.t. the
//!   binarized weights is applied to the real-valued weights — plus full
//!   batch-norm backward through the batch statistics;
//! * **update**: SGD / Nesterov momentum / ADAM with the Sec.-2.5 LR
//!   scaling (lr / H for ADAM, lr / H^2 for SGD and Nesterov) and the
//!   Sec.-2.4 clip of the real-valued weights to [-H, H].
//!
//! The GEMMs come from `preprocess::linalg` and the RNG from `util::rng`,
//! so the whole train/eval step is deterministic given `Hyper::seed`.
//!
//! A small builtin model registry replaces the artifact manifest for this
//! backend: CPU-scale MLP specs for each corpus, plus spec-only CNN
//! entries that feed the hardware cost model (`hw::step_cost`) but cannot
//! be executed without the `pjrt` feature.

use std::path::PathBuf;

use crate::preprocess::linalg::{matmul_a_bt, matmul_at_b, matmul_f32};
use crate::util::error::Result;
use crate::util::Rng;
use crate::{anyhow, bail};

use super::hyper::{Hyper, Mode, Opt};
use super::manifest::{ModelInfo, ParamInfo};
use super::{Executor, StepMetrics, TrainState};

/// Batch-norm epsilon — must match python/compile/layers.py.
pub const BN_EPS: f32 = 1e-4;

const INIT_SALT: u64 = 0xB1AC_0111_1217_0001;
const TRAIN_SALT: u64 = 0xB1AC_0111_1217_0002;
const EVAL_SALT: u64 = 0xB1AC_0111_1217_0003;

fn glorot_coeff(fan_in: usize, fan_out: usize) -> f64 {
    (6.0 / (fan_in + fan_out) as f64).sqrt()
}

fn bn_defs(name: &str, c: usize) -> Vec<ParamInfo> {
    let mk = |suffix: &str, kind: &str| ParamInfo {
        name: format!("{name}.{suffix}"),
        shape: vec![c],
        kind: kind.to_string(),
        glorot: 0.0,
    };
    vec![
        mk("gamma", "affine"),
        mk("beta", "affine"),
        mk("rmean", "bn_stat"),
        mk("rvar", "bn_stat"),
    ]
}

fn finish_info(
    name: &str,
    batch: usize,
    classes: usize,
    input_shape: Vec<usize>,
    params: Vec<ParamInfo>,
) -> ModelInfo {
    let n_scalars = params.iter().map(|p| p.numel()).sum();
    ModelInfo {
        name: name.to_string(),
        batch,
        classes,
        input_shape,
        params,
        n_scalars,
        use_pallas: false,
        init_path: PathBuf::new(),
        train_path: PathBuf::new(),
        eval_path: PathBuf::new(),
    }
}

/// Spec of a dense BinaryConnect MLP (mirror of MLPConfig.spec() in
/// python/compile/models.py): `depth` hidden ReLU+BN layers, L2-SVM out.
pub fn mlp_info(
    name: &str,
    in_dim: usize,
    hidden: usize,
    depth: usize,
    classes: usize,
    batch: usize,
) -> ModelInfo {
    let mut params = vec![];
    let mut d = in_dim;
    for i in 0..depth {
        params.push(ParamInfo {
            name: format!("l{i}.W"),
            shape: vec![d, hidden],
            kind: "weight".to_string(),
            glorot: glorot_coeff(d, hidden),
        });
        params.extend(bn_defs(&format!("l{i}.bn"), hidden));
        d = hidden;
    }
    params.push(ParamInfo {
        name: "out.W".to_string(),
        shape: vec![d, classes],
        kind: "weight".to_string(),
        glorot: glorot_coeff(d, classes),
    });
    params.push(ParamInfo {
        name: "out.b".to_string(),
        shape: vec![classes],
        kind: "affine".to_string(),
        glorot: 0.0,
    });
    finish_info(name, batch, classes, vec![batch, in_dim], params)
}

/// Spec of the paper's Eq.-5 CNN (mirror of CNNConfig.spec()).  Spec-only
/// on this backend: it feeds `hw::step_cost`, but executing it needs the
/// PJRT path.
pub fn cnn_info(name: &str, base: usize, fc: usize, batch: usize) -> ModelInfo {
    let mut params = vec![];
    let chans = [base, base, 2 * base, 2 * base, 4 * base, 4 * base];
    let mut cin = 3usize;
    for (i, &cout) in chans.iter().enumerate() {
        params.push(ParamInfo {
            name: format!("conv{i}.W"),
            shape: vec![3, 3, cin, cout],
            kind: "weight".to_string(),
            glorot: glorot_coeff(9 * cin, 9 * cout),
        });
        params.extend(bn_defs(&format!("conv{i}.bn"), cout));
        cin = cout;
    }
    let hw = 32 / 8;
    let mut d = hw * hw * chans[5];
    for i in 0..2 {
        params.push(ParamInfo {
            name: format!("fc{i}.W"),
            shape: vec![d, fc],
            kind: "weight".to_string(),
            glorot: glorot_coeff(d, fc),
        });
        params.extend(bn_defs(&format!("fc{i}.bn"), fc));
        d = fc;
    }
    params.push(ParamInfo {
        name: "out.W".to_string(),
        shape: vec![d, 10],
        kind: "weight".to_string(),
        glorot: glorot_coeff(d, 10),
    });
    params.push(ParamInfo {
        name: "out.b".to_string(),
        shape: vec![10],
        kind: "affine".to_string(),
        glorot: 0.0,
    });
    finish_info(name, batch, 10, vec![batch, 32, 32, 3], params)
}

/// Names served by [`builtin_info`]. The `cnn*` entries are spec-only.
pub fn builtin_names() -> &'static [&'static str] {
    &["mlp", "mlp_small", "cifar_mlp", "svhn_mlp", "cnn", "cnn_small"]
}

/// The builtin model registry (CPU-scale sizes; the paper's full-scale MLP
/// is 3 x 1024 hidden units — pass a custom [`mlp_info`] to go larger).
pub fn builtin_info(name: &str) -> Option<ModelInfo> {
    match name {
        "mlp" => Some(mlp_info("mlp", 784, 128, 3, 10, 100)),
        "mlp_small" => Some(mlp_info("mlp_small", 784, 64, 2, 10, 50)),
        "cifar_mlp" => Some(mlp_info("cifar_mlp", 3072, 256, 3, 10, 50)),
        "svhn_mlp" => Some(mlp_info("svhn_mlp", 3072, 128, 3, 10, 50)),
        "cnn" => Some(cnn_info("cnn", 128, 1024, 50)),
        "cnn_small" => Some(cnn_info("cnn_small", 64, 512, 50)),
        _ => None,
    }
}

/// One dense layer of the validated execution plan.
struct DenseLayer {
    /// param index of the (k x n) weight tensor.
    w: usize,
    k: usize,
    n: usize,
    /// Glorot coefficient: binarization scale and clip box half-width.
    h: f32,
    /// param index of BN gamma (beta/rmean/rvar follow); None on output.
    bn: Option<usize>,
    /// param index of the output bias; None on hidden layers.
    bias: Option<usize>,
}

fn plan(info: &ModelInfo) -> Result<Vec<DenseLayer>> {
    let params = &info.params;
    let n = params.len();
    let mut layers: Vec<DenseLayer> = vec![];
    let mut i = 0usize;
    while i < n {
        let p = &params[i];
        if !p.name.ends_with(".W") {
            bail!("reference backend: unexpected param {} at index {i} (wanted a .W)", p.name);
        }
        if p.shape.len() != 2 {
            bail!(
                "reference backend supports dense MLPs only; {} has shape {:?} \
                 (conv models need the pjrt feature)",
                p.name,
                p.shape
            );
        }
        let (k, units) = (p.shape[0], p.shape[1]);
        let is_output = i + 1 < n && params[i + 1].name.ends_with(".b");
        if is_output {
            if i + 2 != n {
                bail!("reference backend: the biased output layer must come last");
            }
            layers.push(DenseLayer {
                w: i,
                k,
                n: units,
                h: p.glorot as f32,
                bn: None,
                bias: Some(i + 1),
            });
            i += 2;
        } else {
            if i + 5 > n {
                bail!("reference backend: truncated BN block after {}", p.name);
            }
            for (off, suffix) in
                [(1usize, ".gamma"), (2, ".beta"), (3, ".rmean"), (4, ".rvar")]
            {
                if !params[i + off].name.ends_with(suffix) {
                    bail!(
                        "reference backend: expected {} after {}, found {}",
                        suffix,
                        p.name,
                        params[i + off].name
                    );
                }
            }
            layers.push(DenseLayer {
                w: i,
                k,
                n: units,
                h: p.glorot as f32,
                bn: Some(i + 1),
                bias: None,
            });
            i += 5;
        }
    }
    if layers.is_empty() || layers.last().unwrap().bias.is_none() {
        bail!("reference backend: model has no output layer");
    }
    for w in layers.windows(2) {
        if w[0].n != w[1].k {
            bail!("reference backend: layer dims do not chain ({} vs {})", w[0].n, w[1].k);
        }
    }
    if layers[0].k != info.input_dim() {
        bail!(
            "reference backend: first layer expects {} inputs, model input dim is {}",
            layers[0].k,
            info.input_dim()
        );
    }
    Ok(layers)
}

fn binarize(w: &[f32], h: f32, mode: Mode, rng: &mut Rng) -> Vec<f32> {
    match mode {
        Mode::None => w.to_vec(),
        Mode::Det => w.iter().map(|&v| if v >= 0.0 { h } else { -h }).collect(),
        Mode::Stoch => w
            .iter()
            .map(|&v| {
                // Eq. 2: p = hard_sigmoid(w / H)
                let p = ((v / h + 1.0) * 0.5).clamp(0.0, 1.0);
                if rng.uniform() < p {
                    h
                } else {
                    -h
                }
            })
            .collect(),
    }
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (j, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = j;
        }
    }
    best
}

/// Per-layer forward caches needed by the backward pass.
struct Cache {
    /// b x k input activations (post previous dropout).
    a_in: Vec<f32>,
    /// k x n binarized weights used in the forward GEMM.
    wb: Vec<f32>,
    /// b x n normalized pre-affine BN activations (hidden layers only).
    xhat: Vec<f32>,
    /// n per-unit 1/sqrt(var + eps) (hidden layers only).
    inv_std: Vec<f32>,
    /// b x n combined ReLU x dropout multiplier (hidden layers only).
    gate: Vec<f32>,
}

pub struct ReferenceExecutor {
    info: ModelInfo,
    layers: Vec<DenseLayer>,
}

impl ReferenceExecutor {
    /// Validate a dense-MLP spec into an executable plan.
    pub fn new(info: ModelInfo) -> Result<ReferenceExecutor> {
        let layers = plan(&info)?;
        Ok(ReferenceExecutor { info, layers })
    }

    /// Load a builtin model by name (see [`builtin_info`]).
    pub fn builtin(name: &str) -> Result<ReferenceExecutor> {
        let info = builtin_info(name).ok_or_else(|| {
            anyhow!("no builtin model '{name}' (have: {})", builtin_names().join(", "))
        })?;
        ReferenceExecutor::new(info)
    }

    fn check_batch(&self, x: &[f32], y: &[f32]) -> Result<()> {
        let want_x = self.info.batch * self.info.input_dim();
        if x.len() != want_x {
            bail!("x has {} elements, model expects {}", x.len(), want_x);
        }
        let want_y = self.info.batch * self.info.classes;
        if y.len() != want_y {
            bail!("y has {} elements, expected {}", y.len(), want_y);
        }
        Ok(())
    }

    /// Per-example squared-hinge loss + error indicator, and d(loss)/d(z)
    /// for loss = mean over the batch.
    fn metrics(
        &self,
        logits: &[f32],
        y: &[f32],
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let b = self.info.batch;
        let c = self.info.classes;
        let mut lossv = vec![0f32; b];
        let mut errv = vec![0f32; b];
        let mut dlogits = vec![0f32; b * c];
        let bf = b as f32;
        for t in 0..b {
            let zrow = &logits[t * c..(t + 1) * c];
            let yrow = &y[t * c..(t + 1) * c];
            let mut acc = 0f32;
            for j in 0..c {
                let margin = (1.0 - yrow[j] * zrow[j]).max(0.0);
                acc += margin * margin;
                dlogits[t * c + j] = -2.0 * margin * yrow[j] / bf;
            }
            lossv[t] = acc;
            errv[t] = if argmax(zrow) != argmax(yrow) { 1.0 } else { 0.0 };
        }
        (lossv, errv, dlogits)
    }
}

impl Executor for ReferenceExecutor {
    fn info(&self) -> &ModelInfo {
        &self.info
    }

    fn init_state(&self, hyper: &Hyper) -> Result<TrainState> {
        let mut rng = Rng::new(INIT_SALT ^ hyper.seed as u64);
        let mut params = Vec::with_capacity(self.info.params.len());
        for (i, p) in self.info.params.iter().enumerate() {
            let n = p.numel();
            let t: Vec<f32> = if p.kind == "weight" {
                // Glorot uniform in [-c, c)
                let c = p.glorot as f32;
                let mut r = rng.fork(i as u64);
                (0..n).map(|_| r.range(-c, c)).collect()
            } else if p.name.ends_with(".gamma") || p.name.ends_with(".rvar") {
                vec![1.0; n]
            } else {
                vec![0.0; n]
            };
            params.push(t);
        }
        let m: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
        let v = m.clone();
        Ok(TrainState { params, m, v })
    }

    fn train_step(
        &self,
        state: &mut TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<StepMetrics> {
        self.check_batch(x, y)?;
        let b = self.info.batch;
        let bf = b as f32;
        let mode = hyper.mode;
        let mut rng = Rng::new(TRAIN_SALT ^ hyper.seed as u64);
        let n_layers = self.layers.len();

        // ---- forward, caching what the backward pass needs ----
        let mut a: Vec<f32> = x.to_vec();
        if hyper.in_dropout > 0.0 {
            let p = hyper.in_dropout;
            let scale = 1.0 / (1.0 - p).max(1e-6);
            for v in a.iter_mut() {
                if rng.uniform() < p {
                    *v = 0.0;
                } else {
                    *v *= scale;
                }
            }
        }
        let mut caches: Vec<Cache> = Vec::with_capacity(n_layers);
        let mut bn_stat_updates: Vec<(usize, Vec<f32>)> = vec![];
        for (li, layer) in self.layers.iter().enumerate() {
            let wb = binarize(&state.params[layer.w], layer.h, mode, &mut rng);
            let n = layer.n;
            let mut z = matmul_f32(&a, &wb, b, layer.k, n);
            if li == n_layers - 1 {
                let bias = &state.params[layer.bias.unwrap()];
                for t in 0..b {
                    for (zv, &bv) in z[t * n..(t + 1) * n].iter_mut().zip(bias) {
                        *zv += bv;
                    }
                }
                let a_in = std::mem::replace(&mut a, z);
                caches.push(Cache {
                    a_in,
                    wb,
                    xhat: vec![],
                    inv_std: vec![],
                    gate: vec![],
                });
            } else {
                let gi = layer.bn.unwrap();
                // batch statistics (biased variance, like jnp.var)
                let mut mean = vec![0f32; n];
                for t in 0..b {
                    for (mj, &v) in mean.iter_mut().zip(&z[t * n..(t + 1) * n]) {
                        *mj += v;
                    }
                }
                for mj in mean.iter_mut() {
                    *mj /= bf;
                }
                let mut var = vec![0f32; n];
                for t in 0..b {
                    for j in 0..n {
                        let c = z[t * n + j] - mean[j];
                        var[j] += c * c;
                    }
                }
                for vj in var.iter_mut() {
                    *vj /= bf;
                }
                let inv_std: Vec<f32> =
                    var.iter().map(|&v| 1.0 / (v + BN_EPS).sqrt()).collect();
                let mut xhat = vec![0f32; b * n];
                for t in 0..b {
                    for j in 0..n {
                        xhat[t * n + j] = (z[t * n + j] - mean[j]) * inv_std[j];
                    }
                }
                // running-stat update (applied to state after backward)
                let mom = hyper.bn_momentum;
                let rmean = &state.params[gi + 2];
                let rvar = &state.params[gi + 3];
                bn_stat_updates.push((
                    gi + 2,
                    rmean
                        .iter()
                        .zip(&mean)
                        .map(|(&r, &m)| mom * r + (1.0 - mom) * m)
                        .collect(),
                ));
                bn_stat_updates.push((
                    gi + 3,
                    rvar.iter()
                        .zip(&var)
                        .map(|(&r, &v)| mom * r + (1.0 - mom) * v)
                        .collect(),
                ));
                // affine + ReLU + inverted dropout
                let gamma = &state.params[gi];
                let beta = &state.params[gi + 1];
                let p = hyper.dropout;
                let dscale = 1.0 / (1.0 - p).max(1e-6);
                let mut gate = vec![0f32; b * n];
                let mut next = vec![0f32; b * n];
                for t in 0..b {
                    for j in 0..n {
                        let idx = t * n + j;
                        let yv = gamma[j] * xhat[idx] + beta[j];
                        let s = if p > 0.0 {
                            if rng.uniform() < p {
                                0.0
                            } else {
                                dscale
                            }
                        } else {
                            1.0
                        };
                        if yv > 0.0 {
                            gate[idx] = s;
                            next[idx] = yv * s;
                        }
                    }
                }
                let a_in = std::mem::replace(&mut a, next);
                caches.push(Cache { a_in, wb, xhat, inv_std, gate });
            }
        }
        let logits = a;
        let (lossv, errv, dlogits) = self.metrics(&logits, y);
        let loss = lossv.iter().sum::<f32>() / bf;
        let n_err = errv.iter().sum::<f32>();

        // ---- backward (straight-through on the binarized weights) ----
        let mut grads: Vec<Option<Vec<f32>>> = vec![None; self.info.params.len()];
        let mut dcur = dlogits;
        for li in (0..n_layers).rev() {
            let layer = &self.layers[li];
            let cache = &caches[li];
            let n = layer.n;
            let dz: Vec<f32>;
            if li == n_layers - 1 {
                let mut db = vec![0f32; n];
                for t in 0..b {
                    for (dj, &d) in db.iter_mut().zip(&dcur[t * n..(t + 1) * n]) {
                        *dj += d;
                    }
                }
                grads[layer.bias.unwrap()] = Some(db);
                dz = dcur;
            } else {
                // through ReLU + dropout
                let mut dy = dcur;
                for (dv, &g) in dy.iter_mut().zip(&cache.gate) {
                    *dv *= g;
                }
                // batch-norm backward through the batch statistics
                let gi = layer.bn.unwrap();
                let gamma = &state.params[gi];
                let mut sum_dy = vec![0f32; n];
                let mut sum_dy_xhat = vec![0f32; n];
                for t in 0..b {
                    for j in 0..n {
                        let d = dy[t * n + j];
                        sum_dy[j] += d;
                        sum_dy_xhat[j] += d * cache.xhat[t * n + j];
                    }
                }
                let mut dzv = vec![0f32; b * n];
                for t in 0..b {
                    for j in 0..n {
                        let idx = t * n + j;
                        dzv[idx] = gamma[j] * cache.inv_std[j] / bf
                            * (bf * dy[idx] - sum_dy[j] - cache.xhat[idx] * sum_dy_xhat[j]);
                    }
                }
                grads[gi] = Some(sum_dy_xhat); // dgamma
                grads[gi + 1] = Some(sum_dy); // dbeta
                dz = dzv;
            }
            grads[layer.w] = Some(matmul_at_b(&cache.a_in, &dz, b, layer.k, n));
            dcur = if li > 0 {
                matmul_a_bt(&dz, &cache.wb, b, n, layer.k)
            } else {
                vec![]
            };
        }

        // ---- parameter update (Sec. 2.4 clip + Sec. 2.5 LR scaling) ----
        for (idx, stat) in bn_stat_updates {
            state.params[idx] = stat;
        }
        let lr = hyper.lr;
        for (i, p) in self.info.params.iter().enumerate() {
            let g = match grads[i].take() {
                Some(g) => g,
                None => continue,
            };
            let (lr_j, clip, h) = if p.kind == "weight" {
                let c = p.glorot as f32;
                let pow = match hyper.opt {
                    Opt::Adam => 1,
                    _ => 2,
                };
                let lr_j = if hyper.lr_scale { lr / c.powi(pow) } else { lr };
                (lr_j, mode != Mode::None, c)
            } else {
                (lr, false, 1.0f32)
            };
            let w = &mut state.params[i];
            let m = &mut state.m[i];
            let v = &mut state.v[i];
            match hyper.opt {
                Opt::Sgd => {
                    for (wv, &gv) in w.iter_mut().zip(&g) {
                        let mut wn = *wv - lr_j * gv;
                        if clip {
                            wn = wn.clamp(-h, h);
                        }
                        *wv = wn;
                    }
                }
                Opt::Nesterov => {
                    let mu = hyper.momentum;
                    for ((wv, mv), &gv) in w.iter_mut().zip(m.iter_mut()).zip(&g) {
                        let mn = mu * *mv - lr_j * gv;
                        let mut wn = *wv + mu * mn - lr_j * gv;
                        if clip {
                            wn = wn.clamp(-h, h);
                        }
                        *mv = mn;
                        *wv = wn;
                    }
                }
                Opt::Adam => {
                    let b1 = hyper.momentum;
                    let b2 = hyper.beta2;
                    let t = hyper.step as f32;
                    let corr1 = 1.0 - b1.powf(t);
                    let corr2 = 1.0 - b2.powf(t);
                    for (((wv, mv), vv), &gv) in
                        w.iter_mut().zip(m.iter_mut()).zip(v.iter_mut()).zip(&g)
                    {
                        let mn = b1 * *mv + (1.0 - b1) * gv;
                        let vn = b2 * *vv + (1.0 - b2) * gv * gv;
                        let m_hat = mn / corr1;
                        let v_hat = vn / corr2;
                        let mut wn = *wv - lr_j * m_hat / (v_hat.sqrt() + hyper.eps);
                        if clip {
                            wn = wn.clamp(-h, h);
                        }
                        *mv = mn;
                        *vv = vn;
                        *wv = wn;
                    }
                }
            }
        }
        Ok(StepMetrics { loss, n_err })
    }

    fn eval_batch(
        &self,
        state: &TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.check_batch(x, y)?;
        let b = self.info.batch;
        let mut rng = Rng::new(EVAL_SALT ^ hyper.seed as u64);
        let n_layers = self.layers.len();
        let mut a: Vec<f32> = x.to_vec();
        for (li, layer) in self.layers.iter().enumerate() {
            let wb = binarize(&state.params[layer.w], layer.h, hyper.mode, &mut rng);
            let n = layer.n;
            let mut z = matmul_f32(&a, &wb, b, layer.k, n);
            if li == n_layers - 1 {
                let bias = &state.params[layer.bias.unwrap()];
                for t in 0..b {
                    for (zv, &bv) in z[t * n..(t + 1) * n].iter_mut().zip(bias) {
                        *zv += bv;
                    }
                }
            } else {
                let gi = layer.bn.unwrap();
                let gamma = &state.params[gi];
                let beta = &state.params[gi + 1];
                let rmean = &state.params[gi + 2];
                let rvar = &state.params[gi + 3];
                let inv_std: Vec<f32> =
                    rvar.iter().map(|&v| 1.0 / (v + BN_EPS).sqrt()).collect();
                for t in 0..b {
                    for j in 0..n {
                        let idx = t * n + j;
                        let yv = (z[idx] - rmean[j]) * inv_std[j] * gamma[j] + beta[j];
                        z[idx] = yv.max(0.0);
                    }
                }
            }
            a = z;
        }
        let (lossv, errv, _) = self.metrics(&a, y);
        Ok((lossv, errv))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ReferenceExecutor {
        ReferenceExecutor::new(mlp_info("tiny", 6, 5, 1, 3, 4)).unwrap()
    }

    fn tiny_batch(exec: &ReferenceExecutor, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let info = exec.info();
        let x: Vec<f32> =
            (0..info.batch * info.input_dim()).map(|_| rng.normal()).collect();
        let mut y = vec![-1.0f32; info.batch * info.classes];
        for t in 0..info.batch {
            y[t * info.classes + rng.below(info.classes)] = 1.0;
        }
        (x, y)
    }

    #[test]
    fn builtin_registry_resolves() {
        for name in builtin_names() {
            assert!(builtin_info(name).is_some(), "{name} missing");
        }
        assert!(builtin_info("nope").is_none());
        let exec = ReferenceExecutor::builtin("mlp").unwrap();
        assert_eq!(exec.info().params.len(), 3 * 5 + 2);
        assert_eq!(exec.info().input_dim(), 784);
    }

    #[test]
    fn conv_specs_are_rejected_with_clear_error() {
        let err = ReferenceExecutor::builtin("cnn").unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }

    #[test]
    fn spec_matches_python_layout() {
        let info = mlp_info("m", 784, 1024, 3, 10, 200);
        // 3 hidden x (W + 4 bn) + out W + b = 17 tensors, like the manifest
        assert_eq!(info.params.len(), 17);
        assert_eq!(info.params[0].shape, vec![784, 1024]);
        assert_eq!(info.params[0].kind, "weight");
        assert!(info.params.iter().any(|p| p.kind == "bn_stat"));
        let c = info.params[0].glorot;
        assert!((c - (6.0f64 / 1808.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn init_is_seeded_and_bounded() {
        let exec = tiny();
        let a = exec.init_state(&Hyper { seed: 5, ..Default::default() }).unwrap();
        let b = exec.init_state(&Hyper { seed: 5, ..Default::default() }).unwrap();
        let c = exec.init_state(&Hyper { seed: 6, ..Default::default() }).unwrap();
        assert_eq!(a.params[0], b.params[0]);
        assert_ne!(a.params[0], c.params[0]);
        let lim = exec.info().params[0].glorot as f32;
        assert!(a.params[0].iter().all(|v| v.abs() <= lim));
        // gamma ones, beta zeros
        assert!(a.params[1].iter().all(|&v| v == 1.0));
        assert!(a.params[2].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn train_step_overfits_one_batch() {
        let exec = tiny();
        let mut state = exec.init_state(&Hyper::default()).unwrap();
        let (x, y) = tiny_batch(&exec, 3);
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for step in 1..=60 {
            let h = Hyper {
                lr: 0.01,
                mode: Mode::Det,
                opt: Opt::Adam,
                step,
                seed: step,
                ..Default::default()
            };
            let m = exec.train_step(&mut state, &x, &y, &h).unwrap();
            assert!(m.loss.is_finite());
            if step == 1 {
                first = m.loss;
            }
            last = m.loss;
        }
        assert!(last < first * 0.5, "loss {first} -> {last}");
    }

    #[test]
    fn numerical_gradient_check_mode_none() {
        // With Mode::None (no binarization, no clip) and no dropout, the
        // loss is differentiable; central differences must match the
        // analytic gradients the update consumed. Recover the gradient
        // from an SGD step with lr = 1 and lr_scale off.
        let exec = tiny();
        let base = exec.init_state(&Hyper { seed: 11, ..Default::default() }).unwrap();
        let (x, y) = tiny_batch(&exec, 4);
        let hyper = Hyper {
            lr: 0.0,
            mode: Mode::None,
            opt: Opt::Sgd,
            lr_scale: false,
            seed: 1,
            ..Default::default()
        };
        let loss_at = |state: &TrainState| -> f32 {
            let mut s = state.snapshot();
            exec.train_step(&mut s, &x, &y, &hyper).unwrap().loss
        };
        let grad_of = |state: &TrainState| -> TrainState {
            let mut s = state.snapshot();
            let h = Hyper { lr: 1.0, ..hyper.clone() };
            exec.train_step(&mut s, &x, &y, &h).unwrap();
            s
        };
        let stepped = grad_of(&base);
        // spot-check a few coordinates across tensor kinds:
        // l0.W, bn gamma, bn beta, out.W, out.b
        for (pi, ei) in [(0usize, 0usize), (0, 7), (1, 2), (2, 0), (5, 3), (6, 1)] {
            let analytic = base.params[pi][ei] - stepped.params[pi][ei];
            let eps = 3e-3f32;
            let mut plus = base.snapshot();
            plus.params[pi][ei] += eps;
            let mut minus = base.snapshot();
            minus.params[pi][ei] -= eps;
            let numeric = (loss_at(&plus) - loss_at(&minus)) / (2.0 * eps);
            assert!(
                (numeric - analytic).abs() < 0.05 * (1.0f32).max(analytic.abs()),
                "param {pi}[{ei}]: numeric {numeric} vs analytic {analytic}"
            );
        }
    }

    #[test]
    fn det_mode_clips_weights_to_glorot_box() {
        let exec = tiny();
        let mut state = exec.init_state(&Hyper::default()).unwrap();
        let (x, y) = tiny_batch(&exec, 5);
        for step in 1..=20 {
            let h = Hyper {
                lr: 0.1,
                mode: Mode::Det,
                opt: Opt::Sgd,
                step,
                seed: step,
                ..Default::default()
            };
            exec.train_step(&mut state, &x, &y, &h).unwrap();
        }
        for (t, p) in state.params.iter().zip(&exec.info().params) {
            if p.kind == "weight" {
                let lim = p.glorot as f32 + 1e-6;
                assert!(t.iter().all(|v| v.abs() <= lim), "{} escaped clip box", p.name);
            }
        }
    }

    #[test]
    fn bn_running_stats_move_during_training() {
        let exec = tiny();
        let mut state = exec.init_state(&Hyper::default()).unwrap();
        let (x, y) = tiny_batch(&exec, 6);
        let h = Hyper { lr: 0.01, step: 1, seed: 1, ..Default::default() };
        exec.train_step(&mut state, &x, &y, &h).unwrap();
        // rmean (param index 3) left its zero init
        assert!(state.params[3].iter().any(|&v| v != 0.0), "rmean never updated");
    }

    #[test]
    fn eval_ignores_seed_in_det_mode_but_not_stoch() {
        let exec = tiny();
        let state = exec.init_state(&Hyper::default()).unwrap();
        let (x, y) = tiny_batch(&exec, 7);
        let l1 = exec
            .eval_batch(&state, &x, &y, &Hyper { mode: Mode::Det, seed: 1, ..Default::default() })
            .unwrap()
            .0;
        let l2 = exec
            .eval_batch(&state, &x, &y, &Hyper { mode: Mode::Det, seed: 2, ..Default::default() })
            .unwrap()
            .0;
        assert_eq!(l1, l2);
        let s1 = exec
            .eval_batch(&state, &x, &y, &Hyper { mode: Mode::Stoch, seed: 1, ..Default::default() })
            .unwrap()
            .0;
        let s2 = exec
            .eval_batch(&state, &x, &y, &Hyper { mode: Mode::Stoch, seed: 2, ..Default::default() })
            .unwrap()
            .0;
        assert_ne!(s1, s2, "stochastic eval must sample from the seed");
    }
}
