//! Pure-Rust reference backend: Algorithm 1 for the paper's MLP, with no
//! external runtime.
//!
//! Implements the exact semantics of the Python/HLO path
//! (python/compile/train.py + layers.py) in plain f32 loops:
//!
//! * **binarize** (Eqs. 1-3): deterministic sign to ±H or stochastic ±H
//!   with p = hard_sigmoid(w/H), H the layer's Glorot coefficient;
//! * **forward**: GEMM on the binarized weights, batch norm (train:
//!   batch statistics + running-stat update; eval: running statistics),
//!   ReLU, inverted dropout, L2-SVM squared-hinge output;
//! * **backward**: straight-through estimator — the gradient w.r.t. the
//!   binarized weights is applied to the real-valued weights — plus full
//!   batch-norm backward through the batch statistics;
//! * **update**: SGD / Nesterov momentum / ADAM with the Sec.-2.5 LR
//!   scaling (lr / H for ADAM, lr / H^2 for SGD and Nesterov) and the
//!   Sec.-2.4 clip of the real-valued weights to [-H, H].
//!
//! ## The fast path (default)
//!
//! In `Mode::Det`/`Mode::Stoch` the binarized weights never materialize as
//! f32: each step packs their sign bits into a workspace-owned
//! [`BitMatrix`] and runs the forward `z = H·sign_gemm(a, Wb)` and the STE
//! backward `dX = dZ·Wb^T` as accumulation-only packed kernels — the
//! paper's "multiplications replaced by accumulations" claim realized
//! inside training. The weight gradient `dW = a^T·dZ` and the
//! `Mode::None` baseline use the blocked multithreaded f32 kernels in
//! [`crate::kernel`]. All intermediates live in a per-executor
//! [`Workspace`], so a warmed-up `train_step` performs **zero heap
//! allocations** (pinned by a counting-allocator test below). Kernels
//! parallelize over the `util::pool` fork-join pool; results are
//! identical for any `BCRUN_THREADS`. Beneath that, every inner loop
//! rides the runtime-dispatched SIMD microkernels
//! ([`crate::kernel::simd`], `BCRUN_SIMD` to pin a rung) with no
//! call-site changes here: the packed batched kernels are bit-exact
//! across rungs, and the FMA-reordered f32 GEMMs stay inside the same
//! 1e-4 envelope the fast-vs-baseline property tests already pin.
//!
//! `set_fast(false)` selects the seed-era dense path (f32 binarize copy +
//! naive single-threaded GEMMs + per-step allocations), kept as the
//! correctness oracle for the packed path (property-tested to agree
//! within 1e-4) and as the honest "current main" baseline `perf_gemm`
//! measures speedups against.
//!
//! The GEMMs come from `crate::kernel` and the RNG from `util::rng`, so
//! the whole train/eval step is deterministic given `Hyper::seed`.
//!
//! A small builtin model registry replaces the artifact manifest for this
//! backend: CPU-scale MLP specs for each corpus, plus spec-only CNN
//! entries that feed the hardware cost model (`hw::step_cost`) but cannot
//! be executed without the `pjrt` feature.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::binary::packed::BitMatrix;
use crate::kernel;
use crate::util::error::Result;
use crate::util::{FaultPlan, Rng};
use crate::{anyhow, bail};

use super::hyper::{Hyper, Mode, Opt};
use super::manifest::{ModelInfo, ParamInfo};
use super::{Executor, StepMetrics, TrainState};

/// Batch-norm epsilon — must match python/compile/layers.py.
pub const BN_EPS: f32 = 1e-4;

const INIT_SALT: u64 = 0xB1AC_0111_1217_0001;
const TRAIN_SALT: u64 = 0xB1AC_0111_1217_0002;
const EVAL_SALT: u64 = 0xB1AC_0111_1217_0003;

fn glorot_coeff(fan_in: usize, fan_out: usize) -> f64 {
    (6.0 / (fan_in + fan_out) as f64).sqrt()
}

fn bn_defs(name: &str, c: usize) -> Vec<ParamInfo> {
    let mk = |suffix: &str, kind: &str| ParamInfo {
        name: format!("{name}.{suffix}"),
        shape: vec![c],
        kind: kind.to_string(),
        glorot: 0.0,
    };
    vec![
        mk("gamma", "affine"),
        mk("beta", "affine"),
        mk("rmean", "bn_stat"),
        mk("rvar", "bn_stat"),
    ]
}

fn finish_info(
    name: &str,
    batch: usize,
    classes: usize,
    input_shape: Vec<usize>,
    params: Vec<ParamInfo>,
) -> ModelInfo {
    let n_scalars = params.iter().map(|p| p.numel()).sum();
    ModelInfo {
        name: name.to_string(),
        batch,
        classes,
        input_shape,
        params,
        n_scalars,
        use_pallas: false,
        init_path: PathBuf::new(),
        train_path: PathBuf::new(),
        eval_path: PathBuf::new(),
    }
}

/// Spec of a dense BinaryConnect MLP (mirror of MLPConfig.spec() in
/// python/compile/models.py): `depth` hidden ReLU+BN layers, L2-SVM out.
pub fn mlp_info(
    name: &str,
    in_dim: usize,
    hidden: usize,
    depth: usize,
    classes: usize,
    batch: usize,
) -> ModelInfo {
    let mut params = vec![];
    let mut d = in_dim;
    for i in 0..depth {
        params.push(ParamInfo {
            name: format!("l{i}.W"),
            shape: vec![d, hidden],
            kind: "weight".to_string(),
            glorot: glorot_coeff(d, hidden),
        });
        params.extend(bn_defs(&format!("l{i}.bn"), hidden));
        d = hidden;
    }
    params.push(ParamInfo {
        name: "out.W".to_string(),
        shape: vec![d, classes],
        kind: "weight".to_string(),
        glorot: glorot_coeff(d, classes),
    });
    params.push(ParamInfo {
        name: "out.b".to_string(),
        shape: vec![classes],
        kind: "affine".to_string(),
        glorot: 0.0,
    });
    finish_info(name, batch, classes, vec![batch, in_dim], params)
}

/// Spec of the paper's Eq.-5 CNN (mirror of CNNConfig.spec()).  Spec-only
/// on this backend: it feeds `hw::step_cost`, but executing it needs the
/// PJRT path.
pub fn cnn_info(name: &str, base: usize, fc: usize, batch: usize) -> ModelInfo {
    let mut params = vec![];
    let chans = [base, base, 2 * base, 2 * base, 4 * base, 4 * base];
    let mut cin = 3usize;
    for (i, &cout) in chans.iter().enumerate() {
        params.push(ParamInfo {
            name: format!("conv{i}.W"),
            shape: vec![3, 3, cin, cout],
            kind: "weight".to_string(),
            glorot: glorot_coeff(9 * cin, 9 * cout),
        });
        params.extend(bn_defs(&format!("conv{i}.bn"), cout));
        cin = cout;
    }
    let hw = 32 / 8;
    let mut d = hw * hw * chans[5];
    for i in 0..2 {
        params.push(ParamInfo {
            name: format!("fc{i}.W"),
            shape: vec![d, fc],
            kind: "weight".to_string(),
            glorot: glorot_coeff(d, fc),
        });
        params.extend(bn_defs(&format!("fc{i}.bn"), fc));
        d = fc;
    }
    params.push(ParamInfo {
        name: "out.W".to_string(),
        shape: vec![d, 10],
        kind: "weight".to_string(),
        glorot: glorot_coeff(d, 10),
    });
    params.push(ParamInfo {
        name: "out.b".to_string(),
        shape: vec![10],
        kind: "affine".to_string(),
        glorot: 0.0,
    });
    finish_info(name, batch, 10, vec![batch, 32, 32, 3], params)
}

/// Names served by [`builtin_info`]. The `cnn*` entries are spec-only.
pub fn builtin_names() -> &'static [&'static str] {
    &["mlp", "mlp_small", "cifar_mlp", "svhn_mlp", "cnn", "cnn_small"]
}

/// The builtin model registry (CPU-scale sizes; the paper's full-scale MLP
/// is 3 x 1024 hidden units — pass a custom [`mlp_info`] to go larger).
pub fn builtin_info(name: &str) -> Option<ModelInfo> {
    match name {
        "mlp" => Some(mlp_info("mlp", 784, 128, 3, 10, 100)),
        "mlp_small" => Some(mlp_info("mlp_small", 784, 64, 2, 10, 50)),
        "cifar_mlp" => Some(mlp_info("cifar_mlp", 3072, 256, 3, 10, 50)),
        "svhn_mlp" => Some(mlp_info("svhn_mlp", 3072, 128, 3, 10, 50)),
        "cnn" => Some(cnn_info("cnn", 128, 1024, 50)),
        "cnn_small" => Some(cnn_info("cnn_small", 64, 512, 50)),
        _ => None,
    }
}

/// One dense layer of the validated execution plan.
struct DenseLayer {
    /// param index of the (k x n) weight tensor.
    w: usize,
    k: usize,
    n: usize,
    /// Glorot coefficient: binarization scale and clip box half-width.
    h: f32,
    /// param index of BN gamma (beta/rmean/rvar follow); None on output.
    bn: Option<usize>,
    /// param index of the output bias; None on hidden layers.
    bias: Option<usize>,
}

fn plan(info: &ModelInfo) -> Result<Vec<DenseLayer>> {
    let params = &info.params;
    let n = params.len();
    let mut layers: Vec<DenseLayer> = vec![];
    let mut i = 0usize;
    while i < n {
        let p = &params[i];
        if !p.name.ends_with(".W") {
            bail!("reference backend: unexpected param {} at index {i} (wanted a .W)", p.name);
        }
        if p.shape.len() != 2 {
            bail!(
                "reference backend supports dense MLPs only; {} has shape {:?} \
                 (conv models need the pjrt feature)",
                p.name,
                p.shape
            );
        }
        let (k, units) = (p.shape[0], p.shape[1]);
        let is_output = i + 1 < n && params[i + 1].name.ends_with(".b");
        if is_output {
            if i + 2 != n {
                bail!("reference backend: the biased output layer must come last");
            }
            layers.push(DenseLayer {
                w: i,
                k,
                n: units,
                h: p.glorot as f32,
                bn: None,
                bias: Some(i + 1),
            });
            i += 2;
        } else {
            if i + 5 > n {
                bail!("reference backend: truncated BN block after {}", p.name);
            }
            for (off, suffix) in
                [(1usize, ".gamma"), (2, ".beta"), (3, ".rmean"), (4, ".rvar")]
            {
                if !params[i + off].name.ends_with(suffix) {
                    bail!(
                        "reference backend: expected {} after {}, found {}",
                        suffix,
                        p.name,
                        params[i + off].name
                    );
                }
            }
            layers.push(DenseLayer {
                w: i,
                k,
                n: units,
                h: p.glorot as f32,
                bn: Some(i + 1),
                bias: None,
            });
            i += 5;
        }
    }
    if layers.is_empty() || layers.last().unwrap().bias.is_none() {
        bail!("reference backend: model has no output layer");
    }
    for w in layers.windows(2) {
        if w[0].n != w[1].k {
            bail!("reference backend: layer dims do not chain ({} vs {})", w[0].n, w[1].k);
        }
    }
    if layers[0].k != info.input_dim() {
        bail!(
            "reference backend: first layer expects {} inputs, model input dim is {}",
            layers[0].k,
            info.input_dim()
        );
    }
    Ok(layers)
}

/// Materialize the binarized weights as f32 (the seed-era dense path;
/// the fast path packs bits instead — see [`BitMatrix::pack_det_into`]).
fn binarize(w: &[f32], h: f32, mode: Mode, rng: &mut Rng) -> Vec<f32> {
    match mode {
        Mode::None => w.to_vec(),
        Mode::Det => w.iter().map(|&v| if v >= 0.0 { h } else { -h }).collect(),
        Mode::Stoch => w
            .iter()
            .map(|&v| {
                // Eq. 2: p = hard_sigmoid(w / H)
                let p = ((v / h + 1.0) * 0.5).clamp(0.0, 1.0);
                if rng.uniform() < p {
                    h
                } else {
                    -h
                }
            })
            .collect(),
    }
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (j, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = j;
        }
    }
    best
}

/// Per-example squared-hinge loss + error indicator and d(loss)/d(z) for
/// loss = mean over the batch, written into caller buffers (row slices
/// hoisted — no per-element index arithmetic).
fn metrics_into(
    logits: &[f32],
    y: &[f32],
    c: usize,
    lossv: &mut [f32],
    errv: &mut [f32],
    dlogits: &mut [f32],
) {
    let bf = lossv.len() as f32;
    for (((zrow, yrow), (lv, ev)), drow) in logits
        .chunks_exact(c)
        .zip(y.chunks_exact(c))
        .zip(lossv.iter_mut().zip(errv.iter_mut()))
        .zip(dlogits.chunks_exact_mut(c))
    {
        let mut acc = 0f32;
        for ((dv, &zv), &yv) in drow.iter_mut().zip(zrow).zip(yrow) {
            let margin = (1.0 - yv * zv).max(0.0);
            acc += margin * margin;
            *dv = -2.0 * margin * yv / bf;
        }
        *lv = acc;
        *ev = if argmax(zrow) != argmax(yrow) { 1.0 } else { 0.0 };
    }
}

/// Divergence sentinel over the gradients a step actually produced:
/// true when any used gradient tensor holds a NaN/Inf.
fn grads_non_finite(grads: &[Vec<f32>], used: &[bool]) -> bool {
    grads
        .iter()
        .zip(used)
        .any(|(g, &u)| u && g.iter().any(|v| !v.is_finite()))
}

/// Preallocated per-step buffers. Built lazily on the first step and
/// reused for the executor's lifetime, so a steady-state `train_step`
/// allocates nothing (see `steady_state_train_step_is_allocation_free`).
struct Workspace {
    /// acts[li] = b x k input to layer li (acts[0] = dropped-out batch);
    /// acts[n_layers] = b x classes logits.
    acts: Vec<Vec<f32>>,
    /// b x n normalized pre-affine BN activations (hidden layers only).
    xhat: Vec<Vec<f32>>,
    /// n per-unit 1/sqrt(var + eps) (hidden layers only).
    inv_std: Vec<Vec<f32>>,
    /// b x n combined ReLU x dropout multiplier (hidden layers only).
    gate: Vec<Vec<f32>>,
    /// per-layer batch statistics (hidden layers only), kept until the
    /// end of the step so the running-stat write can happen *after* the
    /// divergence sentinel — a skipped step must leave rmean/rvar
    /// untouched too.
    bn_mean: Vec<Vec<f32>>,
    bn_var: Vec<Vec<f32>>,
    /// per-layer packed sign matrices, re-packed in place every step.
    bits: Vec<BitMatrix>,
    /// transpose scratch for the packed kernels (max_dim * b).
    xt: Vec<f32>,
    /// tmatmul selected-sum accumulator (max_k * b).
    acc: Vec<f32>,
    /// per-example row totals (b).
    totals: Vec<f32>,
    /// backward ping-pong buffers (b * max_dim each).
    d0: Vec<f32>,
    d1: Vec<f32>,
    /// per-param gradient buffers (+ which ones a step produced).
    grads: Vec<Vec<f32>>,
    grad_used: Vec<bool>,
    /// metrics buffers.
    lossv: Vec<f32>,
    errv: Vec<f32>,
    dlogits: Vec<f32>,
    /// panel-packing buffers for the f32 GEMM trio (presized for every
    /// layer orientation, so the warmed-up step never grows them).
    panels: kernel::PanelBuf,
}

impl Workspace {
    fn build(info: &ModelInfo, layers: &[DenseLayer]) -> Workspace {
        let b = info.batch;
        let nl = layers.len();
        let mut acts = Vec::with_capacity(nl + 1);
        acts.push(vec![0f32; b * layers[0].k]);
        for l in layers {
            acts.push(vec![0f32; b * l.n]);
        }
        let mut xhat = Vec::with_capacity(nl);
        let mut inv_std = Vec::with_capacity(nl);
        let mut gate = Vec::with_capacity(nl);
        let mut bn_mean = Vec::with_capacity(nl);
        let mut bn_var = Vec::with_capacity(nl);
        for l in layers {
            if l.bn.is_some() {
                xhat.push(vec![0f32; b * l.n]);
                inv_std.push(vec![0f32; l.n]);
                gate.push(vec![0f32; b * l.n]);
                bn_mean.push(vec![0f32; l.n]);
                bn_var.push(vec![0f32; l.n]);
            } else {
                xhat.push(Vec::new());
                inv_std.push(Vec::new());
                gate.push(Vec::new());
                bn_mean.push(Vec::new());
                bn_var.push(Vec::new());
            }
        }
        let max_dim = layers.iter().map(|l| l.k.max(l.n)).max().unwrap_or(1);
        let max_k = layers.iter().map(|l| l.k).max().unwrap_or(1);
        // presize the GEMM panel buffers for every product the step runs:
        // forward z = a @ W (b x k x n), grad dW = a^T @ dz (k x b x n),
        // and backward dX = dz @ W^T (b x n x k), per layer
        let mut panels = kernel::PanelBuf::new();
        for l in layers {
            panels.reserve_gemm(b, l.k, l.n);
            panels.reserve_gemm(l.k, b, l.n);
            panels.reserve_gemm(b, l.n, l.k);
        }
        Workspace {
            acts,
            xhat,
            inv_std,
            gate,
            bn_mean,
            bn_var,
            bits: layers.iter().map(|l| BitMatrix::zeroed(l.k, l.n)).collect(),
            xt: vec![0f32; max_dim * b],
            acc: vec![0f32; max_k * b],
            totals: vec![0f32; b],
            d0: vec![0f32; b * max_dim],
            d1: vec![0f32; b * max_dim],
            grads: info.params.iter().map(|p| vec![0f32; p.numel()]).collect(),
            grad_used: vec![false; info.params.len()],
            lossv: vec![0f32; b],
            errv: vec![0f32; b],
            dlogits: vec![0f32; b * info.classes],
            panels,
        }
    }
}

pub struct ReferenceExecutor {
    info: ModelInfo,
    layers: Vec<DenseLayer>,
    /// true (default): packed/blocked workspace path; false: the seed-era
    /// dense allocating path (benchmark baseline + correctness oracle).
    fast: bool,
    ws: Mutex<Option<Workspace>>,
    /// chaos harness: armed training-site fault plan (`nan_grad@P`).
    faults: Option<Arc<FaultPlan>>,
}

impl ReferenceExecutor {
    /// Validate a dense-MLP spec into an executable plan.
    pub fn new(info: ModelInfo) -> Result<ReferenceExecutor> {
        let layers = plan(&info)?;
        Ok(ReferenceExecutor { info, layers, fast: true, ws: Mutex::new(None), faults: None })
    }

    /// Load a builtin model by name (see [`builtin_info`]).
    pub fn builtin(name: &str) -> Result<ReferenceExecutor> {
        let info = builtin_info(name).ok_or_else(|| {
            anyhow!("no builtin model '{name}' (have: {})", builtin_names().join(", "))
        })?;
        ReferenceExecutor::new(info)
    }

    /// Select the kernel path: `true` = packed + blocked + workspace
    /// (default), `false` = the seed-era dense baseline. Train/eval
    /// results agree within f32 reorder noise (property-tested at 1e-4).
    pub fn set_fast(&mut self, fast: bool) {
        self.fast = fast;
    }

    /// Arm the executor-level fault sites (`nan_grad@P` poisons the first
    /// weight gradient of a step when the seeded decision fires, which
    /// the divergence sentinel must then catch and account for exactly).
    pub fn set_faults(&mut self, faults: Option<Arc<FaultPlan>>) {
        self.faults = faults;
    }

    fn check_batch(&self, x: &[f32], y: &[f32]) -> Result<()> {
        let want_x = self.info.batch * self.info.input_dim();
        if x.len() != want_x {
            bail!("x has {} elements, model expects {}", x.len(), want_x);
        }
        let want_y = self.info.batch * self.info.classes;
        if y.len() != want_y {
            bail!("y has {} elements, expected {}", y.len(), want_y);
        }
        Ok(())
    }

    /// Allocating metrics wrapper (baseline path + eval).
    fn metrics(&self, logits: &[f32], y: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let b = self.info.batch;
        let c = self.info.classes;
        let mut lossv = vec![0f32; b];
        let mut errv = vec![0f32; b];
        let mut dlogits = vec![0f32; b * c];
        metrics_into(logits, y, c, &mut lossv, &mut errv, &mut dlogits);
        (lossv, errv, dlogits)
    }

    /// Sec. 2.4 clip + Sec. 2.5 LR scaling + optimizer update, shared by
    /// the fast and baseline paths (in place; allocation-free).
    fn apply_updates(
        &self,
        state: &mut TrainState,
        hyper: &Hyper,
        grads: &[Vec<f32>],
        used: &[bool],
    ) {
        let lr = hyper.lr;
        let mode = hyper.mode;
        for (i, p) in self.info.params.iter().enumerate() {
            if !used[i] {
                continue;
            }
            let g = &grads[i];
            let (lr_j, clip, h) = if p.kind == "weight" {
                let c = p.glorot as f32;
                let pow = match hyper.opt {
                    Opt::Adam => 1,
                    _ => 2,
                };
                let lr_j = if hyper.lr_scale { lr / c.powi(pow) } else { lr };
                (lr_j, mode != Mode::None, c)
            } else {
                (lr, false, 1.0f32)
            };
            let w = &mut state.params[i];
            let m = &mut state.m[i];
            let v = &mut state.v[i];
            match hyper.opt {
                Opt::Sgd => {
                    for (wv, &gv) in w.iter_mut().zip(g) {
                        let mut wn = *wv - lr_j * gv;
                        if clip {
                            wn = wn.clamp(-h, h);
                        }
                        *wv = wn;
                    }
                }
                Opt::Nesterov => {
                    let mu = hyper.momentum;
                    for ((wv, mv), &gv) in w.iter_mut().zip(m.iter_mut()).zip(g) {
                        let mn = mu * *mv - lr_j * gv;
                        let mut wn = *wv + mu * mn - lr_j * gv;
                        if clip {
                            wn = wn.clamp(-h, h);
                        }
                        *mv = mn;
                        *wv = wn;
                    }
                }
                Opt::Adam => {
                    let b1 = hyper.momentum;
                    let b2 = hyper.beta2;
                    let t = hyper.step as f32;
                    let corr1 = 1.0 - b1.powf(t);
                    let corr2 = 1.0 - b2.powf(t);
                    for (((wv, mv), vv), &gv) in
                        w.iter_mut().zip(m.iter_mut()).zip(v.iter_mut()).zip(g)
                    {
                        let mn = b1 * *mv + (1.0 - b1) * gv;
                        let vn = b2 * *vv + (1.0 - b2) * gv * gv;
                        let m_hat = mn / corr1;
                        let v_hat = vn / corr2;
                        let mut wn = *wv - lr_j * m_hat / (v_hat.sqrt() + hyper.eps);
                        if clip {
                            wn = wn.clamp(-h, h);
                        }
                        *mv = mn;
                        *vv = vn;
                        *wv = wn;
                    }
                }
            }
        }
    }

    // -----------------------------------------------------------------
    // fast path: packed sign-GEMM + workspace, zero steady-state allocs
    // -----------------------------------------------------------------

    fn train_step_fast(
        &self,
        state: &mut TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<StepMetrics> {
        self.check_batch(x, y)?;
        let b = self.info.batch;
        let c = self.info.classes;
        let bf = b as f32;
        let mode = hyper.mode;
        let mut rng = Rng::new(TRAIN_SALT ^ hyper.seed as u64);
        let nl = self.layers.len();
        let mut guard = self.ws.lock().unwrap();
        let ws = guard.get_or_insert_with(|| Workspace::build(&self.info, &self.layers));

        // ---- forward ----
        {
            let a0 = &mut ws.acts[0];
            a0.copy_from_slice(x);
            if hyper.in_dropout > 0.0 {
                let p = hyper.in_dropout;
                let scale = 1.0 / (1.0 - p).max(1e-6);
                for v in a0.iter_mut() {
                    if rng.uniform() < p {
                        *v = 0.0;
                    } else {
                        *v *= scale;
                    }
                }
            }
        }
        for (li, layer) in self.layers.iter().enumerate() {
            let n = layer.n;
            let k = layer.k;
            // z = a_in @ Wb into acts[li + 1]
            let (alo, ahi) = ws.acts.split_at_mut(li + 1);
            let a_in: &[f32] = &alo[li];
            let z: &mut [f32] = &mut ahi[0];
            match mode {
                Mode::None => {
                    kernel::gemm_into(a_in, &state.params[layer.w], b, k, n, z, &mut ws.panels)
                }
                Mode::Det => {
                    let bits = &mut ws.bits[li];
                    bits.pack_det_into(&state.params[layer.w], k, n);
                    bits.matmul_scaled_into(a_in, b, layer.h, z, &mut ws.xt, &mut ws.totals);
                }
                Mode::Stoch => {
                    let bits = &mut ws.bits[li];
                    bits.pack_stoch_into(&state.params[layer.w], k, n, layer.h, &mut rng);
                    bits.matmul_scaled_into(a_in, b, layer.h, z, &mut ws.xt, &mut ws.totals);
                }
            }
            if li == nl - 1 {
                let bias = &state.params[layer.bias.unwrap()];
                for zrow in z.chunks_exact_mut(n) {
                    for (zv, &bv) in zrow.iter_mut().zip(bias) {
                        *zv += bv;
                    }
                }
            } else {
                let gi = layer.bn.unwrap();
                // batch statistics (biased variance, like jnp.var); kept
                // per layer so the rmean/rvar write can wait until the
                // divergence sentinel has cleared the step
                let mean = &mut ws.bn_mean[li][..];
                let var = &mut ws.bn_var[li][..];
                mean.fill(0.0);
                for zrow in z.chunks_exact(n) {
                    for (mj, &v) in mean.iter_mut().zip(zrow) {
                        *mj += v;
                    }
                }
                for mj in mean.iter_mut() {
                    *mj /= bf;
                }
                var.fill(0.0);
                for zrow in z.chunks_exact(n) {
                    for ((vj, &v), &mj) in var.iter_mut().zip(zrow).zip(&*mean) {
                        let cv = v - mj;
                        *vj += cv * cv;
                    }
                }
                for vj in var.iter_mut() {
                    *vj /= bf;
                }
                let inv_std = &mut ws.inv_std[li];
                for (o, &v) in inv_std.iter_mut().zip(&*var) {
                    *o = 1.0 / (v + BN_EPS).sqrt();
                }
                let xhat = &mut ws.xhat[li];
                for (xrow, zrow) in xhat.chunks_exact_mut(n).zip(z.chunks_exact(n)) {
                    for (((xv, &zv), &mj), &is) in
                        xrow.iter_mut().zip(zrow).zip(&*mean).zip(&*inv_std)
                    {
                        *xv = (zv - mj) * is;
                    }
                }
                // affine + ReLU + inverted dropout, z becomes acts[li + 1]
                let gamma = &state.params[gi];
                let beta = &state.params[gi + 1];
                let p = hyper.dropout;
                let dscale = 1.0 / (1.0 - p).max(1e-6);
                let gate = &mut ws.gate[li];
                for (zrow, (xrow, grow)) in z
                    .chunks_exact_mut(n)
                    .zip(ws.xhat[li].chunks_exact(n).zip(gate.chunks_exact_mut(n)))
                {
                    for (j, (zv, gv)) in zrow.iter_mut().zip(grow.iter_mut()).enumerate() {
                        let yv = gamma[j] * xrow[j] + beta[j];
                        let s = if p > 0.0 {
                            if rng.uniform() < p {
                                0.0
                            } else {
                                dscale
                            }
                        } else {
                            1.0
                        };
                        if yv > 0.0 {
                            *gv = s;
                            *zv = yv * s;
                        } else {
                            *gv = 0.0;
                            *zv = 0.0;
                        }
                    }
                }
            }
        }

        // ---- loss / metrics ----
        metrics_into(&ws.acts[nl], y, c, &mut ws.lossv, &mut ws.errv, &mut ws.dlogits);
        let loss = ws.lossv.iter().sum::<f32>() / bf;
        let n_err = ws.errv.iter().sum::<f32>();

        // ---- backward (straight-through on the binarized weights) ----
        for u in ws.grad_used.iter_mut() {
            *u = false;
        }
        ws.d0[..b * c].copy_from_slice(&ws.dlogits);
        let mut cur_in_d0 = true;
        for li in (0..nl).rev() {
            let layer = &self.layers[li];
            let n = layer.n;
            let k = layer.k;
            let (dcur, dnext) = if cur_in_d0 {
                (&mut ws.d0, &mut ws.d1)
            } else {
                (&mut ws.d1, &mut ws.d0)
            };
            let dz: &mut [f32] = &mut dcur[..b * n];
            if li == nl - 1 {
                let bidx = layer.bias.unwrap();
                let db = &mut ws.grads[bidx];
                db.fill(0.0);
                for drow in dz.chunks_exact(n) {
                    for (gv, &d) in db.iter_mut().zip(drow) {
                        *gv += d;
                    }
                }
                ws.grad_used[bidx] = true;
            } else {
                // through ReLU + dropout
                for (drow, grow) in dz.chunks_exact_mut(n).zip(ws.gate[li].chunks_exact(n)) {
                    for (dv, &g) in drow.iter_mut().zip(grow) {
                        *dv *= g;
                    }
                }
                // batch-norm backward through the batch statistics
                let gi = layer.bn.unwrap();
                let xhat: &[f32] = &ws.xhat[li];
                let inv_std: &[f32] = &ws.inv_std[li];
                let gamma: &[f32] = &state.params[gi];
                let (glo, ghi) = ws.grads.split_at_mut(gi + 1);
                let dgamma = &mut glo[gi]; // sum_dy_xhat
                let dbeta = &mut ghi[0]; // sum_dy
                dgamma.fill(0.0);
                dbeta.fill(0.0);
                for (drow, xrow) in dz.chunks_exact(n).zip(xhat.chunks_exact(n)) {
                    for (((sg, sb), &d), &xv) in
                        dgamma.iter_mut().zip(dbeta.iter_mut()).zip(drow).zip(xrow)
                    {
                        *sb += d;
                        *sg += d * xv;
                    }
                }
                for (drow, xrow) in dz.chunks_exact_mut(n).zip(xhat.chunks_exact(n)) {
                    for (j, dv) in drow.iter_mut().enumerate() {
                        *dv = gamma[j] * inv_std[j] / bf
                            * (bf * *dv - dbeta[j] - xrow[j] * dgamma[j]);
                    }
                }
                ws.grad_used[gi] = true;
                ws.grad_used[gi + 1] = true;
            }
            // dW = a_in^T · dZ (dense f32: dZ is real-valued either way)
            kernel::gemm_at_b_into(
                &ws.acts[li],
                dz,
                b,
                k,
                n,
                &mut ws.grads[layer.w],
                &mut ws.panels,
            );
            ws.grad_used[layer.w] = true;
            // dX = dZ · Wb^T for the next layer down
            if li > 0 {
                let dx: &mut [f32] = &mut dnext[..b * k];
                match mode {
                    Mode::None => kernel::gemm_a_bt_into(
                        dz,
                        &state.params[layer.w],
                        b,
                        n,
                        k,
                        dx,
                        &mut ws.panels,
                    ),
                    _ => ws.bits[li].tmatmul_scaled_into(
                        dz,
                        b,
                        layer.h,
                        dx,
                        &mut ws.xt,
                        &mut ws.acc,
                        &mut ws.totals,
                    ),
                }
                cur_in_d0 = !cur_in_d0;
            }
        }

        // ---- chaos harness: seeded gradient poisoning ----
        if self.faults.as_ref().is_some_and(|f| f.roll_nan_grad()) {
            ws.grads[self.layers[0].w][0] = f32::NAN;
        }

        // ---- divergence sentinel (loss + every produced gradient) ----
        let diverged = !loss.is_finite() || grads_non_finite(&ws.grads, &ws.grad_used);

        // ---- deferred state writes: BN running stats + parameter update,
        //      both skipped when a diverged step asked for skip-step
        //      recovery, so the state stays bit-exactly untouched ----
        if !(diverged && hyper.skip_nonfinite) {
            let mom = hyper.bn_momentum;
            for (li, layer) in self.layers.iter().enumerate() {
                if let Some(gi) = layer.bn {
                    for (r, &mj) in state.params[gi + 2].iter_mut().zip(&ws.bn_mean[li]) {
                        *r = mom * *r + (1.0 - mom) * mj;
                    }
                    for (r, &vj) in state.params[gi + 3].iter_mut().zip(&ws.bn_var[li]) {
                        *r = mom * *r + (1.0 - mom) * vj;
                    }
                }
            }
            self.apply_updates(state, hyper, &ws.grads, &ws.grad_used);
        }
        Ok(StepMetrics { loss, n_err, diverged })
    }

    fn eval_batch_fast(
        &self,
        state: &TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.check_batch(x, y)?;
        let b = self.info.batch;
        let c = self.info.classes;
        let mut rng = Rng::new(EVAL_SALT ^ hyper.seed as u64);
        let nl = self.layers.len();
        let mut guard = self.ws.lock().unwrap();
        let ws = guard.get_or_insert_with(|| Workspace::build(&self.info, &self.layers));

        ws.acts[0].copy_from_slice(x);
        for (li, layer) in self.layers.iter().enumerate() {
            let n = layer.n;
            let k = layer.k;
            let (alo, ahi) = ws.acts.split_at_mut(li + 1);
            let a_in: &[f32] = &alo[li];
            let z: &mut [f32] = &mut ahi[0];
            match hyper.mode {
                Mode::None => {
                    kernel::gemm_into(a_in, &state.params[layer.w], b, k, n, z, &mut ws.panels)
                }
                Mode::Det => {
                    let bits = &mut ws.bits[li];
                    bits.pack_det_into(&state.params[layer.w], k, n);
                    bits.matmul_scaled_into(a_in, b, layer.h, z, &mut ws.xt, &mut ws.totals);
                }
                Mode::Stoch => {
                    let bits = &mut ws.bits[li];
                    bits.pack_stoch_into(&state.params[layer.w], k, n, layer.h, &mut rng);
                    bits.matmul_scaled_into(a_in, b, layer.h, z, &mut ws.xt, &mut ws.totals);
                }
            }
            if li == nl - 1 {
                let bias = &state.params[layer.bias.unwrap()];
                for zrow in z.chunks_exact_mut(n) {
                    for (zv, &bv) in zrow.iter_mut().zip(bias) {
                        *zv += bv;
                    }
                }
            } else {
                let gi = layer.bn.unwrap();
                let gamma = &state.params[gi];
                let beta = &state.params[gi + 1];
                let rmean = &state.params[gi + 2];
                let rvar = &state.params[gi + 3];
                let inv_std = &mut ws.inv_std[li];
                for (o, &v) in inv_std.iter_mut().zip(rvar) {
                    *o = 1.0 / (v + BN_EPS).sqrt();
                }
                for zrow in z.chunks_exact_mut(n) {
                    for (j, zv) in zrow.iter_mut().enumerate() {
                        let yv = (*zv - rmean[j]) * inv_std[j] * gamma[j] + beta[j];
                        *zv = yv.max(0.0);
                    }
                }
            }
        }
        metrics_into(&ws.acts[nl], y, c, &mut ws.lossv, &mut ws.errv, &mut ws.dlogits);
        Ok((ws.lossv.clone(), ws.errv.clone()))
    }

    // -----------------------------------------------------------------
    // baseline path: the seed's dense allocating step (naive kernels)
    // -----------------------------------------------------------------

    fn train_step_baseline(
        &self,
        state: &mut TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<StepMetrics> {
        struct Cache {
            a_in: Vec<f32>,
            wb: Vec<f32>,
            xhat: Vec<f32>,
            inv_std: Vec<f32>,
            gate: Vec<f32>,
        }

        self.check_batch(x, y)?;
        let b = self.info.batch;
        let bf = b as f32;
        let mode = hyper.mode;
        let mut rng = Rng::new(TRAIN_SALT ^ hyper.seed as u64);
        let nl = self.layers.len();

        // ---- forward, caching what the backward pass needs ----
        let mut a: Vec<f32> = x.to_vec();
        if hyper.in_dropout > 0.0 {
            let p = hyper.in_dropout;
            let scale = 1.0 / (1.0 - p).max(1e-6);
            for v in a.iter_mut() {
                if rng.uniform() < p {
                    *v = 0.0;
                } else {
                    *v *= scale;
                }
            }
        }
        let mut caches: Vec<Cache> = Vec::with_capacity(nl);
        let mut bn_stat_updates: Vec<(usize, Vec<f32>)> = vec![];
        for (li, layer) in self.layers.iter().enumerate() {
            let wb = binarize(&state.params[layer.w], layer.h, mode, &mut rng);
            let n = layer.n;
            let mut z = vec![0f32; b * n];
            kernel::gemm_naive(&a, &wb, b, layer.k, n, &mut z);
            if li == nl - 1 {
                let bias = &state.params[layer.bias.unwrap()];
                for zrow in z.chunks_exact_mut(n) {
                    for (zv, &bv) in zrow.iter_mut().zip(bias) {
                        *zv += bv;
                    }
                }
                let a_in = std::mem::replace(&mut a, z);
                caches.push(Cache {
                    a_in,
                    wb,
                    xhat: vec![],
                    inv_std: vec![],
                    gate: vec![],
                });
            } else {
                let gi = layer.bn.unwrap();
                // batch statistics (biased variance, like jnp.var)
                let mut mean = vec![0f32; n];
                for zrow in z.chunks_exact(n) {
                    for (mj, &v) in mean.iter_mut().zip(zrow) {
                        *mj += v;
                    }
                }
                for mj in mean.iter_mut() {
                    *mj /= bf;
                }
                let mut var = vec![0f32; n];
                for zrow in z.chunks_exact(n) {
                    for ((vj, &v), &mj) in var.iter_mut().zip(zrow).zip(&mean) {
                        let cv = v - mj;
                        *vj += cv * cv;
                    }
                }
                for vj in var.iter_mut() {
                    *vj /= bf;
                }
                let inv_std: Vec<f32> =
                    var.iter().map(|&v| 1.0 / (v + BN_EPS).sqrt()).collect();
                let mut xhat = vec![0f32; b * n];
                for (xrow, zrow) in xhat.chunks_exact_mut(n).zip(z.chunks_exact(n)) {
                    for (((xv, &zv), &mj), &is) in
                        xrow.iter_mut().zip(zrow).zip(&mean).zip(&inv_std)
                    {
                        *xv = (zv - mj) * is;
                    }
                }
                // running-stat update (applied to state after backward)
                let mom = hyper.bn_momentum;
                let rmean = &state.params[gi + 2];
                let rvar = &state.params[gi + 3];
                bn_stat_updates.push((
                    gi + 2,
                    rmean
                        .iter()
                        .zip(&mean)
                        .map(|(&r, &m)| mom * r + (1.0 - mom) * m)
                        .collect(),
                ));
                bn_stat_updates.push((
                    gi + 3,
                    rvar.iter()
                        .zip(&var)
                        .map(|(&r, &v)| mom * r + (1.0 - mom) * v)
                        .collect(),
                ));
                // affine + ReLU + inverted dropout
                let gamma = &state.params[gi];
                let beta = &state.params[gi + 1];
                let p = hyper.dropout;
                let dscale = 1.0 / (1.0 - p).max(1e-6);
                let mut gate = vec![0f32; b * n];
                let mut next = vec![0f32; b * n];
                for ((nrow, xrow), grow) in next
                    .chunks_exact_mut(n)
                    .zip(xhat.chunks_exact(n))
                    .zip(gate.chunks_exact_mut(n))
                {
                    for (j, (nv, gv)) in nrow.iter_mut().zip(grow.iter_mut()).enumerate() {
                        let yv = gamma[j] * xrow[j] + beta[j];
                        let s = if p > 0.0 {
                            if rng.uniform() < p {
                                0.0
                            } else {
                                dscale
                            }
                        } else {
                            1.0
                        };
                        if yv > 0.0 {
                            *gv = s;
                            *nv = yv * s;
                        }
                    }
                }
                let a_in = std::mem::replace(&mut a, next);
                caches.push(Cache { a_in, wb, xhat, inv_std, gate });
            }
        }
        let logits = a;
        let (lossv, errv, dlogits) = self.metrics(&logits, y);
        let loss = lossv.iter().sum::<f32>() / bf;
        let n_err = errv.iter().sum::<f32>();

        // ---- backward (straight-through on the binarized weights) ----
        let mut grads: Vec<Vec<f32>> =
            self.info.params.iter().map(|_| Vec::new()).collect();
        let mut used = vec![false; self.info.params.len()];
        let mut dcur = dlogits;
        for li in (0..nl).rev() {
            let layer = &self.layers[li];
            let cache = &caches[li];
            let n = layer.n;
            let dz: Vec<f32>;
            if li == nl - 1 {
                let mut db = vec![0f32; n];
                for drow in dcur.chunks_exact(n) {
                    for (dj, &d) in db.iter_mut().zip(drow) {
                        *dj += d;
                    }
                }
                grads[layer.bias.unwrap()] = db;
                used[layer.bias.unwrap()] = true;
                dz = dcur;
            } else {
                // through ReLU + dropout
                let mut dy = dcur;
                for (dv, &g) in dy.iter_mut().zip(&cache.gate) {
                    *dv *= g;
                }
                // batch-norm backward through the batch statistics
                let gi = layer.bn.unwrap();
                let gamma = &state.params[gi];
                let mut sum_dy = vec![0f32; n];
                let mut sum_dy_xhat = vec![0f32; n];
                for (drow, xrow) in dy.chunks_exact(n).zip(cache.xhat.chunks_exact(n)) {
                    for (((sd, sx), &d), &xv) in
                        sum_dy.iter_mut().zip(sum_dy_xhat.iter_mut()).zip(drow).zip(xrow)
                    {
                        *sd += d;
                        *sx += d * xv;
                    }
                }
                let mut dzv = vec![0f32; b * n];
                for ((zrow, drow), xrow) in dzv
                    .chunks_exact_mut(n)
                    .zip(dy.chunks_exact(n))
                    .zip(cache.xhat.chunks_exact(n))
                {
                    for (j, zv) in zrow.iter_mut().enumerate() {
                        *zv = gamma[j] * cache.inv_std[j] / bf
                            * (bf * drow[j] - sum_dy[j] - xrow[j] * sum_dy_xhat[j]);
                    }
                }
                grads[gi] = sum_dy_xhat; // dgamma
                grads[gi + 1] = sum_dy; // dbeta
                used[gi] = true;
                used[gi + 1] = true;
                dz = dzv;
            }
            let mut dw = vec![0f32; layer.k * n];
            kernel::gemm_at_b_naive(&cache.a_in, &dz, b, layer.k, n, &mut dw);
            grads[layer.w] = dw;
            used[layer.w] = true;
            dcur = if li > 0 {
                let mut dx = vec![0f32; b * layer.k];
                kernel::gemm_a_bt_naive(&dz, &cache.wb, b, n, layer.k, &mut dx);
                dx
            } else {
                vec![]
            };
        }

        // ---- chaos harness: seeded gradient poisoning ----
        if self.faults.as_ref().is_some_and(|f| f.roll_nan_grad()) {
            grads[self.layers[0].w][0] = f32::NAN;
        }

        // ---- divergence sentinel (loss + every produced gradient) ----
        let diverged = !loss.is_finite() || grads_non_finite(&grads, &used);

        // ---- parameter update (Sec. 2.4 clip + Sec. 2.5 LR scaling),
        //      withheld entirely on a diverged step under skip-step
        //      recovery (running stats included) ----
        if !(diverged && hyper.skip_nonfinite) {
            for (idx, stat) in bn_stat_updates {
                state.params[idx] = stat;
            }
            self.apply_updates(state, hyper, &grads, &used);
        }
        Ok(StepMetrics { loss, n_err, diverged })
    }

    fn eval_batch_baseline(
        &self,
        state: &TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.check_batch(x, y)?;
        let b = self.info.batch;
        let mut rng = Rng::new(EVAL_SALT ^ hyper.seed as u64);
        let nl = self.layers.len();
        let mut a: Vec<f32> = x.to_vec();
        for (li, layer) in self.layers.iter().enumerate() {
            let wb = binarize(&state.params[layer.w], layer.h, hyper.mode, &mut rng);
            let n = layer.n;
            let mut z = vec![0f32; b * n];
            kernel::gemm_naive(&a, &wb, b, layer.k, n, &mut z);
            if li == nl - 1 {
                let bias = &state.params[layer.bias.unwrap()];
                for zrow in z.chunks_exact_mut(n) {
                    for (zv, &bv) in zrow.iter_mut().zip(bias) {
                        *zv += bv;
                    }
                }
            } else {
                let gi = layer.bn.unwrap();
                let gamma = &state.params[gi];
                let beta = &state.params[gi + 1];
                let rmean = &state.params[gi + 2];
                let rvar = &state.params[gi + 3];
                let inv_std: Vec<f32> =
                    rvar.iter().map(|&v| 1.0 / (v + BN_EPS).sqrt()).collect();
                for zrow in z.chunks_exact_mut(n) {
                    for (j, zv) in zrow.iter_mut().enumerate() {
                        let yv = (*zv - rmean[j]) * inv_std[j] * gamma[j] + beta[j];
                        *zv = yv.max(0.0);
                    }
                }
            }
            a = z;
        }
        let (lossv, errv, _) = self.metrics(&a, y);
        Ok((lossv, errv))
    }
}

impl Executor for ReferenceExecutor {
    fn info(&self) -> &ModelInfo {
        &self.info
    }

    fn init_state(&self, hyper: &Hyper) -> Result<TrainState> {
        let mut rng = Rng::new(INIT_SALT ^ hyper.seed as u64);
        let mut params = Vec::with_capacity(self.info.params.len());
        for (i, p) in self.info.params.iter().enumerate() {
            let n = p.numel();
            let t: Vec<f32> = if p.kind == "weight" {
                // Glorot uniform in [-c, c)
                let c = p.glorot as f32;
                let mut r = rng.fork(i as u64);
                (0..n).map(|_| r.range(-c, c)).collect()
            } else if p.name.ends_with(".gamma") || p.name.ends_with(".rvar") {
                vec![1.0; n]
            } else {
                vec![0.0; n]
            };
            params.push(t);
        }
        let m: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
        let v = m.clone();
        Ok(TrainState { params, m, v })
    }

    fn train_step(
        &self,
        state: &mut TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<StepMetrics> {
        if self.fast {
            self.train_step_fast(state, x, y, hyper)
        } else {
            self.train_step_baseline(state, x, y, hyper)
        }
    }

    fn eval_batch(
        &self,
        state: &TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        if self.fast {
            self.eval_batch_fast(state, x, y, hyper)
        } else {
            self.eval_batch_baseline(state, x, y, hyper)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ReferenceExecutor {
        ReferenceExecutor::new(mlp_info("tiny", 6, 5, 1, 3, 4)).unwrap()
    }

    fn tiny_batch(exec: &ReferenceExecutor, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let info = exec.info();
        let x: Vec<f32> =
            (0..info.batch * info.input_dim()).map(|_| rng.normal()).collect();
        let mut y = vec![-1.0f32; info.batch * info.classes];
        for t in 0..info.batch {
            y[t * info.classes + rng.below(info.classes)] = 1.0;
        }
        (x, y)
    }

    #[test]
    fn builtin_registry_resolves() {
        for name in builtin_names() {
            assert!(builtin_info(name).is_some(), "{name} missing");
        }
        assert!(builtin_info("nope").is_none());
        let exec = ReferenceExecutor::builtin("mlp").unwrap();
        assert_eq!(exec.info().params.len(), 3 * 5 + 2);
        assert_eq!(exec.info().input_dim(), 784);
    }

    #[test]
    fn conv_specs_are_rejected_with_clear_error() {
        let err = ReferenceExecutor::builtin("cnn").unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }

    #[test]
    fn spec_matches_python_layout() {
        let info = mlp_info("m", 784, 1024, 3, 10, 200);
        // 3 hidden x (W + 4 bn) + out W + b = 17 tensors, like the manifest
        assert_eq!(info.params.len(), 17);
        assert_eq!(info.params[0].shape, vec![784, 1024]);
        assert_eq!(info.params[0].kind, "weight");
        assert!(info.params.iter().any(|p| p.kind == "bn_stat"));
        let c = info.params[0].glorot;
        assert!((c - (6.0f64 / 1808.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn init_is_seeded_and_bounded() {
        let exec = tiny();
        let a = exec.init_state(&Hyper { seed: 5, ..Default::default() }).unwrap();
        let b = exec.init_state(&Hyper { seed: 5, ..Default::default() }).unwrap();
        let c = exec.init_state(&Hyper { seed: 6, ..Default::default() }).unwrap();
        assert_eq!(a.params[0], b.params[0]);
        assert_ne!(a.params[0], c.params[0]);
        let lim = exec.info().params[0].glorot as f32;
        assert!(a.params[0].iter().all(|v| v.abs() <= lim));
        // gamma ones, beta zeros
        assert!(a.params[1].iter().all(|&v| v == 1.0));
        assert!(a.params[2].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn train_step_overfits_one_batch() {
        let exec = tiny();
        let mut state = exec.init_state(&Hyper::default()).unwrap();
        let (x, y) = tiny_batch(&exec, 3);
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for step in 1..=60 {
            let h = Hyper {
                lr: 0.01,
                mode: Mode::Det,
                opt: Opt::Adam,
                step,
                seed: step,
                ..Default::default()
            };
            let m = exec.train_step(&mut state, &x, &y, &h).unwrap();
            assert!(m.loss.is_finite());
            if step == 1 {
                first = m.loss;
            }
            last = m.loss;
        }
        assert!(last < first * 0.5, "loss {first} -> {last}");
    }

    #[test]
    fn numerical_gradient_check_mode_none() {
        // With Mode::None (no binarization, no clip) and no dropout, the
        // loss is differentiable; central differences must match the
        // analytic gradients the update consumed. Recover the gradient
        // from an SGD step with lr = 1 and lr_scale off.
        let exec = tiny();
        let base = exec.init_state(&Hyper { seed: 11, ..Default::default() }).unwrap();
        let (x, y) = tiny_batch(&exec, 4);
        let hyper = Hyper {
            lr: 0.0,
            mode: Mode::None,
            opt: Opt::Sgd,
            lr_scale: false,
            seed: 1,
            ..Default::default()
        };
        let loss_at = |state: &TrainState| -> f32 {
            let mut s = state.snapshot();
            exec.train_step(&mut s, &x, &y, &hyper).unwrap().loss
        };
        let grad_of = |state: &TrainState| -> TrainState {
            let mut s = state.snapshot();
            let h = Hyper { lr: 1.0, ..hyper.clone() };
            exec.train_step(&mut s, &x, &y, &h).unwrap();
            s
        };
        let stepped = grad_of(&base);
        // spot-check a few coordinates across tensor kinds:
        // l0.W, bn gamma, bn beta, out.W, out.b
        for (pi, ei) in [(0usize, 0usize), (0, 7), (1, 2), (2, 0), (5, 3), (6, 1)] {
            let analytic = base.params[pi][ei] - stepped.params[pi][ei];
            let eps = 3e-3f32;
            let mut plus = base.snapshot();
            plus.params[pi][ei] += eps;
            let mut minus = base.snapshot();
            minus.params[pi][ei] -= eps;
            let numeric = (loss_at(&plus) - loss_at(&minus)) / (2.0 * eps);
            assert!(
                (numeric - analytic).abs() < 0.05 * (1.0f32).max(analytic.abs()),
                "param {pi}[{ei}]: numeric {numeric} vs analytic {analytic}"
            );
        }
    }

    #[test]
    fn det_mode_clips_weights_to_glorot_box() {
        let exec = tiny();
        let mut state = exec.init_state(&Hyper::default()).unwrap();
        let (x, y) = tiny_batch(&exec, 5);
        for step in 1..=20 {
            let h = Hyper {
                lr: 0.1,
                mode: Mode::Det,
                opt: Opt::Sgd,
                step,
                seed: step,
                ..Default::default()
            };
            exec.train_step(&mut state, &x, &y, &h).unwrap();
        }
        for (t, p) in state.params.iter().zip(&exec.info().params) {
            if p.kind == "weight" {
                let lim = p.glorot as f32 + 1e-6;
                assert!(t.iter().all(|v| v.abs() <= lim), "{} escaped clip box", p.name);
            }
        }
    }

    #[test]
    fn bn_running_stats_move_during_training() {
        let exec = tiny();
        let mut state = exec.init_state(&Hyper::default()).unwrap();
        let (x, y) = tiny_batch(&exec, 6);
        let h = Hyper { lr: 0.01, step: 1, seed: 1, ..Default::default() };
        exec.train_step(&mut state, &x, &y, &h).unwrap();
        // rmean (param index 3) left its zero init
        assert!(state.params[3].iter().any(|&v| v != 0.0), "rmean never updated");
    }

    #[test]
    fn eval_ignores_seed_in_det_mode_but_not_stoch() {
        let exec = tiny();
        let state = exec.init_state(&Hyper::default()).unwrap();
        let (x, y) = tiny_batch(&exec, 7);
        let l1 = exec
            .eval_batch(&state, &x, &y, &Hyper { mode: Mode::Det, seed: 1, ..Default::default() })
            .unwrap()
            .0;
        let l2 = exec
            .eval_batch(&state, &x, &y, &Hyper { mode: Mode::Det, seed: 2, ..Default::default() })
            .unwrap()
            .0;
        assert_eq!(l1, l2);
        let s1 = exec
            .eval_batch(&state, &x, &y, &Hyper { mode: Mode::Stoch, seed: 1, ..Default::default() })
            .unwrap()
            .0;
        let s2 = exec
            .eval_batch(&state, &x, &y, &Hyper { mode: Mode::Stoch, seed: 2, ..Default::default() })
            .unwrap()
            .0;
        assert_ne!(s1, s2, "stochastic eval must sample from the seed");
    }

    /// The packed/workspace fast path and the seed-era dense baseline are
    /// the same algorithm up to f32 summation order.
    #[test]
    fn fast_and_baseline_paths_agree() {
        for mode in [Mode::Det, Mode::Stoch, Mode::None] {
            let fast = ReferenceExecutor::new(mlp_info("fb", 70, 33, 2, 5, 8)).unwrap();
            let mut base = ReferenceExecutor::new(mlp_info("fb", 70, 33, 2, 5, 8)).unwrap();
            base.set_fast(false);
            let mut sf = fast.init_state(&Hyper { seed: 3, ..Default::default() }).unwrap();
            let mut sb = sf.snapshot();
            let (x, y) = tiny_batch(&fast, 9);
            for step in 1..=3 {
                let h = Hyper {
                    lr: 0.05,
                    mode,
                    opt: Opt::Nesterov,
                    step,
                    seed: 100 + step,
                    ..Default::default()
                };
                let mf = fast.train_step(&mut sf, &x, &y, &h).unwrap();
                let mb = base.train_step(&mut sb, &x, &y, &h).unwrap();
                assert!(
                    (mf.loss - mb.loss).abs() < 1e-4 * (1.0 + mb.loss.abs()),
                    "{mode:?} step {step}: loss {} vs {}",
                    mf.loss,
                    mb.loss
                );
                // n_err may differ only on an exact logit tie (fp reorder)
                assert!((mf.n_err - mb.n_err).abs() <= 1.0, "{mode:?} step {step}");
            }
            for (pi, (pf, pb)) in sf.params.iter().zip(&sb.params).enumerate() {
                for (j, (a, b)) in pf.iter().zip(pb).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-4 * (1.0 + b.abs()),
                        "{mode:?} param {pi}[{j}]: {a} vs {b}"
                    );
                }
            }
        }
    }

    /// Acceptance gate: after warmup, a train step allocates nothing on
    /// the stepping thread in any mode (workspace + packed kernels +
    /// pool dispatch are all allocation-free).
    #[test]
    fn steady_state_train_step_is_allocation_free() {
        // k = 70 (not a multiple of 64) exercises the ragged bit-word
        // paths; sizes big enough that the GEMMs take the pooled branch.
        let exec = ReferenceExecutor::new(mlp_info("za", 70, 96, 2, 10, 32)).unwrap();
        let mut state = exec.init_state(&Hyper::default()).unwrap();
        let (x, y) = tiny_batch(&exec, 13);
        let mut step = 0u32;
        for mode in [Mode::Det, Mode::Stoch, Mode::None] {
            let mut run = |steps: u32, step: &mut u32| {
                for _ in 0..steps {
                    *step += 1;
                    let h = Hyper {
                        lr: 0.01,
                        mode,
                        opt: Opt::Adam,
                        dropout: 0.1,
                        in_dropout: 0.1,
                        step: *step,
                        seed: *step,
                        ..Default::default()
                    };
                    exec.train_step(&mut state, &x, &y, &h).unwrap();
                }
            };
            run(3, &mut step); // warmup: workspace build + pool spawn
            let before = crate::test_alloc::thread_allocs();
            run(5, &mut step);
            let after = crate::test_alloc::thread_allocs();
            assert_eq!(
                after - before,
                0,
                "steady-state train_step allocated in mode {mode:?}"
            );
        }
    }

    /// The divergence sentinel + skip-step recovery: a poisoned gradient
    /// is detected on both kernel paths, and a skipped step leaves the
    /// whole state (params, m/v slots, BN running stats) bit-identical.
    #[test]
    fn nan_grad_with_skip_leaves_state_bit_identical() {
        for fast in [true, false] {
            let mut exec = tiny();
            exec.set_fast(fast);
            exec.set_faults(Some(Arc::new(FaultPlan::parse("nan_grad@1", 0).unwrap())));
            let mut state = exec.init_state(&Hyper { seed: 2, ..Default::default() }).unwrap();
            let before = state.snapshot();
            let (x, y) = tiny_batch(&exec, 8);
            let h = Hyper {
                lr: 0.05,
                opt: Opt::Adam,
                step: 1,
                seed: 1,
                skip_nonfinite: true,
                ..Default::default()
            };
            let m = exec.train_step(&mut state, &x, &y, &h).unwrap();
            assert!(m.diverged, "fast={fast}: poisoned gradient not detected");
            let bits = |t: &[Vec<f32>]| -> Vec<Vec<u32>> {
                t.iter().map(|v| v.iter().map(|x| x.to_bits()).collect()).collect()
            };
            assert_eq!(bits(&state.params), bits(&before.params), "fast={fast}");
            assert_eq!(bits(&state.m), bits(&before.m), "fast={fast}");
            assert_eq!(bits(&state.v), bits(&before.v), "fast={fast}");
        }
    }

    #[test]
    fn nan_grad_without_skip_poisons_the_update() {
        let mut exec = tiny();
        exec.set_faults(Some(Arc::new(FaultPlan::parse("nan_grad@1", 0).unwrap())));
        let mut state = exec.init_state(&Hyper { seed: 2, ..Default::default() }).unwrap();
        let (x, y) = tiny_batch(&exec, 8);
        let h = Hyper { lr: 0.05, step: 1, seed: 1, ..Default::default() };
        let m = exec.train_step(&mut state, &x, &y, &h).unwrap();
        assert!(m.diverged);
        // without skip-step recovery the NaN reaches the weights
        assert!(
            state.params[0].iter().any(|v| !v.is_finite()),
            "legacy (no-skip) path should have applied the poisoned update"
        );
    }

    #[test]
    fn finite_steps_report_not_diverged() {
        let exec = tiny();
        let mut state = exec.init_state(&Hyper::default()).unwrap();
        let (x, y) = tiny_batch(&exec, 3);
        let h = Hyper { lr: 0.01, step: 1, seed: 1, skip_nonfinite: true, ..Default::default() };
        let m = exec.train_step(&mut state, &x, &y, &h).unwrap();
        assert!(!m.diverged);
        // and the update actually happened
        assert!(state.params[3].iter().any(|&v| v != 0.0), "rmean never updated");
    }

    #[test]
    fn train_step_is_deterministic_for_any_thread_count() {
        // the pool splits rows, never reductions: two identical runs on
        // the same process (whatever BCRUN_THREADS resolved to) and the
        // serial kernels must agree exactly. Cross-thread-count equality
        // is enforced by kernel design (see kernel/gemm.rs tests).
        let exec = ReferenceExecutor::new(mlp_info("dt", 130, 64, 2, 10, 16)).unwrap();
        let mut s1 = exec.init_state(&Hyper { seed: 8, ..Default::default() }).unwrap();
        let mut s2 = s1.snapshot();
        let (x, y) = tiny_batch(&exec, 21);
        let h = Hyper { lr: 0.02, mode: Mode::Det, step: 1, seed: 5, ..Default::default() };
        let m1 = exec.train_step(&mut s1, &x, &y, &h).unwrap();
        let m2 = exec.train_step(&mut s2, &x, &y, &h).unwrap();
        assert_eq!(m1.loss, m2.loss);
        assert_eq!(s1.params[0], s2.params[0]);
    }
}
