//! Pure-Rust reference backend: Algorithm 1 for the paper's MLP, with no
//! external runtime.
//!
//! Implements the exact semantics of the Python/HLO path
//! (python/compile/train.py + layers.py) in plain f32 loops:
//!
//! * **binarize** (Eqs. 1-3): deterministic sign to ±H or stochastic ±H
//!   with p = hard_sigmoid(w/H), H the layer's Glorot coefficient;
//! * **forward**: GEMM on the binarized weights, batch norm (train:
//!   batch statistics + running-stat update; eval: running statistics),
//!   ReLU, inverted dropout, L2-SVM squared-hinge output;
//! * **backward**: straight-through estimator — the gradient w.r.t. the
//!   binarized weights is applied to the real-valued weights — plus full
//!   batch-norm backward through the batch statistics;
//! * **update**: SGD / Nesterov momentum / ADAM with the Sec.-2.5 LR
//!   scaling (lr / H for ADAM, lr / H^2 for SGD and Nesterov) and the
//!   Sec.-2.4 clip of the real-valued weights to [-H, H].
//!
//! ## The fast path (default)
//!
//! In `Mode::Det`/`Mode::Stoch` the binarized weights never materialize as
//! f32: each step packs their sign bits into a workspace-owned
//! [`BitMatrix`] and runs the forward `z = H·sign_gemm(a, Wb)` and the STE
//! backward `dX = dZ·Wb^T` as accumulation-only packed kernels — the
//! paper's "multiplications replaced by accumulations" claim realized
//! inside training. The weight gradient `dW = a^T·dZ` and the
//! `Mode::None` baseline use the blocked multithreaded f32 kernels in
//! [`crate::kernel`]. All intermediates live in a per-executor
//! [`Workspace`], so a warmed-up `train_step` performs **zero heap
//! allocations** (pinned by a counting-allocator test below). Kernels
//! parallelize over the `util::pool` fork-join pool; results are
//! identical for any `BCRUN_THREADS`. Beneath that, every inner loop
//! rides the runtime-dispatched SIMD microkernels
//! ([`crate::kernel::simd`], `BCRUN_SIMD` to pin a rung) with no
//! call-site changes here: the packed batched kernels are bit-exact
//! across rungs, and the FMA-reordered f32 GEMMs stay inside the same
//! 1e-4 envelope the fast-vs-baseline property tests already pin.
//!
//! `set_fast(false)` selects the seed-era dense path (f32 binarize copy +
//! naive single-threaded GEMMs + per-step allocations), kept as the
//! correctness oracle for the packed path (property-tested to agree
//! within 1e-4) and as the honest "current main" baseline `perf_gemm`
//! measures speedups against.
//!
//! The GEMMs come from `crate::kernel` and the RNG from `util::rng`, so
//! the whole train/eval step is deterministic given `Hyper::seed`.
//!
//! ## Binary convolution
//!
//! Conv specs (4-d `[kh, kw, cin, cout]` weight tensors ahead of the
//! dense stack) execute through the [`crate::conv`] subsystem: each conv
//! layer is lowered to `Z = im2col(X) @ Wb` on the same packed sign-GEMM
//! the dense layers use — the filter bank flattens row-major into a
//! `(kh*kw*cin) x cout` matrix, so the det/stoch bit-packers run on it
//! verbatim and the stochastic draw order matches the baseline's dense
//! `binarize` exactly. Per-channel BN runs over all `b*h*w` spatial
//! rows, MaxPool2x2 follows every second conv (the paper's C3 stacking,
//! see [`crate::conv::spatial_dims`]), and the STE backward is the
//! transpose pair: `dP = dZ·Wb^T` (packed) scattered by col2im, `dW =
//! P^T·dZ` (dense f32). The baseline path runs the same layers through
//! the naive direct-convolution oracle in [`crate::conv::oracle`], which
//! the fast path is property-tested against. All conv intermediates
//! (patches, pool indices, pre-pool activations) live in the same
//! grow-only [`Workspace`], preserving the zero-alloc warmed-step
//! contract.
//!
//! A small builtin model registry replaces the artifact manifest for this
//! backend: CPU-scale MLP and CNN specs for each corpus (all trainable
//! here), plus the paper-scale `cnn`/`cnn_small` entries that also feed
//! the hardware cost model (`hw::step_cost`).

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::binary::packed::BitMatrix;
use crate::conv::{im2col, oracle, pool};
use crate::kernel;
use crate::util::error::Result;
use crate::util::{FaultPlan, Rng};
use crate::{anyhow, bail};

use super::hyper::{Hyper, Mode, Opt};
use super::manifest::{ModelInfo, ParamInfo};
use super::{Executor, StepMetrics, TrainState};

/// Batch-norm epsilon — must match python/compile/layers.py.
pub const BN_EPS: f32 = 1e-4;

const INIT_SALT: u64 = 0xB1AC_0111_1217_0001;
const TRAIN_SALT: u64 = 0xB1AC_0111_1217_0002;
const EVAL_SALT: u64 = 0xB1AC_0111_1217_0003;

fn glorot_coeff(fan_in: usize, fan_out: usize) -> f64 {
    (6.0 / (fan_in + fan_out) as f64).sqrt()
}

fn bn_defs(name: &str, c: usize) -> Vec<ParamInfo> {
    let mk = |suffix: &str, kind: &str| ParamInfo {
        name: format!("{name}.{suffix}"),
        shape: vec![c],
        kind: kind.to_string(),
        glorot: 0.0,
    };
    vec![
        mk("gamma", "affine"),
        mk("beta", "affine"),
        mk("rmean", "bn_stat"),
        mk("rvar", "bn_stat"),
    ]
}

fn finish_info(
    name: &str,
    batch: usize,
    classes: usize,
    input_shape: Vec<usize>,
    params: Vec<ParamInfo>,
) -> ModelInfo {
    let n_scalars = params.iter().map(|p| p.numel()).sum();
    ModelInfo {
        name: name.to_string(),
        batch,
        classes,
        input_shape,
        params,
        n_scalars,
        use_pallas: false,
        init_path: PathBuf::new(),
        train_path: PathBuf::new(),
        eval_path: PathBuf::new(),
    }
}

/// Spec of a dense BinaryConnect MLP (mirror of MLPConfig.spec() in
/// python/compile/models.py): `depth` hidden ReLU+BN layers, L2-SVM out.
pub fn mlp_info(
    name: &str,
    in_dim: usize,
    hidden: usize,
    depth: usize,
    classes: usize,
    batch: usize,
) -> ModelInfo {
    let mut params = vec![];
    let mut d = in_dim;
    for i in 0..depth {
        params.push(ParamInfo {
            name: format!("l{i}.W"),
            shape: vec![d, hidden],
            kind: "weight".to_string(),
            glorot: glorot_coeff(d, hidden),
        });
        params.extend(bn_defs(&format!("l{i}.bn"), hidden));
        d = hidden;
    }
    params.push(ParamInfo {
        name: "out.W".to_string(),
        shape: vec![d, classes],
        kind: "weight".to_string(),
        glorot: glorot_coeff(d, classes),
    });
    params.push(ParamInfo {
        name: "out.b".to_string(),
        shape: vec![classes],
        kind: "affine".to_string(),
        glorot: 0.0,
    });
    finish_info(name, batch, classes, vec![batch, in_dim], params)
}

/// Spec of a C3-style conv net: one 3x3 SAME conv + BN layer per entry of
/// `chans` (MaxPool2x2 after every second conv — the paper's
/// `(2 x C3)-MP2` stacking), then one dense BN layer per entry of `fcs`
/// on the flattened features, then the biased L2-SVM output layer.
pub fn conv_net_info(
    name: &str,
    in_hw: usize,
    in_ch: usize,
    chans: &[usize],
    fcs: &[usize],
    classes: usize,
    batch: usize,
) -> ModelInfo {
    let mut params = vec![];
    let mut cin = in_ch;
    for (i, &cout) in chans.iter().enumerate() {
        params.push(ParamInfo {
            name: format!("conv{i}.W"),
            shape: vec![3, 3, cin, cout],
            kind: "weight".to_string(),
            glorot: glorot_coeff(9 * cin, 9 * cout),
        });
        params.extend(bn_defs(&format!("conv{i}.bn"), cout));
        cin = cout;
    }
    let pools = chans.len() / 2;
    assert!(in_hw % (1 << pools) == 0, "{in_hw}x{in_hw} input cannot survive {pools} pools");
    let hw = in_hw >> pools;
    let mut d = hw * hw * cin;
    for (i, &fc) in fcs.iter().enumerate() {
        params.push(ParamInfo {
            name: format!("fc{i}.W"),
            shape: vec![d, fc],
            kind: "weight".to_string(),
            glorot: glorot_coeff(d, fc),
        });
        params.extend(bn_defs(&format!("fc{i}.bn"), fc));
        d = fc;
    }
    params.push(ParamInfo {
        name: "out.W".to_string(),
        shape: vec![d, classes],
        kind: "weight".to_string(),
        glorot: glorot_coeff(d, classes),
    });
    params.push(ParamInfo {
        name: "out.b".to_string(),
        shape: vec![classes],
        kind: "affine".to_string(),
        glorot: 0.0,
    });
    finish_info(name, batch, classes, vec![batch, in_hw, in_hw, in_ch], params)
}

/// Spec of the paper's Eq.-5 CNN (mirror of CNNConfig.spec()): six 3x3
/// convs at `base/base/2b/2b/4b/4b` channels over a 32x32x3 input, two
/// `fc`-wide dense layers, 10-way L2-SVM output.
pub fn cnn_info(name: &str, base: usize, fc: usize, batch: usize) -> ModelInfo {
    conv_net_info(
        name,
        32,
        3,
        &[base, base, 2 * base, 2 * base, 4 * base, 4 * base],
        &[fc, fc],
        10,
        batch,
    )
}

/// Names served by [`builtin_info`]. All are trainable on this backend;
/// the paper-scale `cnn`/`cnn_small` are heavy on CPU — `cifar_cnn` and
/// `svhn_cnn` are the CPU-scale conv entries.
pub fn builtin_names() -> &'static [&'static str] {
    &[
        "mlp",
        "mlp_small",
        "cifar_mlp",
        "svhn_mlp",
        "cifar_cnn",
        "svhn_cnn",
        "cnn",
        "cnn_small",
    ]
}

/// The builtin model registry (CPU-scale sizes; the paper's full-scale MLP
/// is 3 x 1024 hidden units — pass a custom [`mlp_info`] to go larger).
pub fn builtin_info(name: &str) -> Option<ModelInfo> {
    match name {
        "mlp" => Some(mlp_info("mlp", 784, 128, 3, 10, 100)),
        "mlp_small" => Some(mlp_info("mlp_small", 784, 64, 2, 10, 50)),
        "cifar_mlp" => Some(mlp_info("cifar_mlp", 3072, 256, 3, 10, 50)),
        "svhn_mlp" => Some(mlp_info("svhn_mlp", 3072, 128, 3, 10, 50)),
        "cifar_cnn" => Some(cnn_info("cifar_cnn", 16, 128, 16)),
        "svhn_cnn" => Some(cnn_info("svhn_cnn", 8, 64, 16)),
        "cnn" => Some(cnn_info("cnn", 128, 1024, 50)),
        "cnn_small" => Some(cnn_info("cnn_small", 64, 512, 50)),
        _ => None,
    }
}

/// One dense layer of the validated execution plan.
struct DenseLayer {
    /// param index of the (k x n) weight tensor.
    w: usize,
    k: usize,
    n: usize,
    /// Glorot coefficient: binarization scale and clip box half-width.
    h: f32,
    /// param index of BN gamma (beta/rmean/rvar follow); None on output.
    bn: Option<usize>,
    /// param index of the output bias; None on hidden layers.
    bias: Option<usize>,
}

/// One conv stage of the validated execution plan (3x3-style SAME conv +
/// per-channel BN + ReLU, optionally followed by MaxPool2x2).
struct ConvLayer {
    /// param index of the [kh, kw, cin, cout] weight tensor.
    w: usize,
    kh: usize,
    kw: usize,
    cin: usize,
    cout: usize,
    /// Input spatial size (SAME padding: conv output is the same).
    h_in: usize,
    w_in: usize,
    /// MaxPool2x2 follows this conv (C3 schedule: every second conv).
    pool: bool,
    /// Glorot coefficient: binarization scale and clip box half-width.
    h: f32,
    /// param index of BN gamma (beta/rmean/rvar follow). Conv layers
    /// always carry BN in this plan.
    bn: usize,
}

impl ConvLayer {
    /// K dimension of the lowered GEMM (`kh*kw*cin`).
    fn patch_k(&self) -> usize {
        self.kh * self.kw * self.cin
    }

    /// Output positions per example (`h_in * w_in`, SAME padding).
    fn spatial(&self) -> usize {
        self.h_in * self.w_in
    }

    /// Flattened input dim (`h_in * w_in * cin`).
    fn in_dim(&self) -> usize {
        self.spatial() * self.cin
    }

    /// Flattened output dim leaving the stage (post-pool).
    fn out_dim(&self) -> usize {
        let s = if self.pool { self.spatial() / 4 } else { self.spatial() };
        s * self.cout
    }
}

enum Layer {
    Conv(ConvLayer),
    Dense(DenseLayer),
}

impl Layer {
    fn w(&self) -> usize {
        match self {
            Layer::Conv(c) => c.w,
            Layer::Dense(d) => d.w,
        }
    }

    fn bn(&self) -> Option<usize> {
        match self {
            Layer::Conv(c) => Some(c.bn),
            Layer::Dense(d) => d.bn,
        }
    }

    fn in_dim(&self) -> usize {
        match self {
            Layer::Conv(c) => c.in_dim(),
            Layer::Dense(d) => d.k,
        }
    }

    fn out_dim(&self) -> usize {
        match self {
            Layer::Conv(c) => c.out_dim(),
            Layer::Dense(d) => d.n,
        }
    }
}

/// Check the BN block (gamma/beta/rmean/rvar) directly follows param `i`.
fn expect_bn_block(params: &[ParamInfo], i: usize) -> Result<()> {
    let p = &params[i];
    if i + 5 > params.len() {
        bail!("reference backend: truncated BN block after {}", p.name);
    }
    for (off, suffix) in [(1usize, ".gamma"), (2, ".beta"), (3, ".rmean"), (4, ".rvar")] {
        if !params[i + off].name.ends_with(suffix) {
            bail!(
                "reference backend: expected {} after {}, found {}",
                suffix,
                p.name,
                params[i + off].name
            );
        }
    }
    Ok(())
}

fn plan(info: &ModelInfo) -> Result<Vec<Layer>> {
    let params = &info.params;
    let n = params.len();
    let mut layers: Vec<Layer> = vec![];
    let mut i = 0usize;
    // conv stages first: geometry from the shared shape inference
    // (SAME padding, pool-after-every-second-conv — conv::spatial_dims
    // is the single source of truth `bcrun hw` and the exporter share)
    for d in crate::conv::spatial_dims(info)? {
        let p = &params[d.param];
        if d.param != i {
            bail!(
                "reference backend: unexpected param {} at index {i} (wanted conv weight {})",
                params[i].name,
                p.name
            );
        }
        expect_bn_block(params, i)?;
        layers.push(Layer::Conv(ConvLayer {
            w: i,
            kh: d.kh,
            kw: d.kw,
            cin: d.cin,
            cout: d.cout,
            h_in: d.h_in,
            w_in: d.w_in,
            pool: d.pool,
            h: p.glorot as f32,
            bn: i + 1,
        }));
        i += 5;
    }
    while i < n {
        let p = &params[i];
        if !p.name.ends_with(".W") {
            bail!("reference backend: unexpected param {} at index {i} (wanted a .W)", p.name);
        }
        if p.shape.len() != 2 {
            bail!(
                "reference backend cannot execute {}: weight shape {:?} is neither dense \
                 [in, out] nor conv [kh, kw, cin, cout]; trainable builtin models: {}",
                p.name,
                p.shape,
                builtin_names().join(", ")
            );
        }
        let (k, units) = (p.shape[0], p.shape[1]);
        let is_output = i + 1 < n && params[i + 1].name.ends_with(".b");
        if is_output {
            if i + 2 != n {
                bail!("reference backend: the biased output layer must come last");
            }
            layers.push(Layer::Dense(DenseLayer {
                w: i,
                k,
                n: units,
                h: p.glorot as f32,
                bn: None,
                bias: Some(i + 1),
            }));
            i += 2;
        } else {
            expect_bn_block(params, i)?;
            layers.push(Layer::Dense(DenseLayer {
                w: i,
                k,
                n: units,
                h: p.glorot as f32,
                bn: Some(i + 1),
                bias: None,
            }));
            i += 5;
        }
    }
    match layers.last() {
        Some(Layer::Dense(d)) if d.bias.is_some() => {}
        _ => bail!("reference backend: model has no output layer"),
    }
    for w in layers.windows(2) {
        if w[0].out_dim() != w[1].in_dim() {
            bail!(
                "reference backend: layer dims do not chain ({} vs {})",
                w[0].out_dim(),
                w[1].in_dim()
            );
        }
    }
    if layers[0].in_dim() != info.input_dim() {
        bail!(
            "reference backend: first layer expects {} inputs, model input dim is {}",
            layers[0].in_dim(),
            info.input_dim()
        );
    }
    Ok(layers)
}

/// Materialize the binarized weights as f32 (the seed-era dense path;
/// the fast path packs bits instead — see [`BitMatrix::pack_det_into`]).
fn binarize(w: &[f32], h: f32, mode: Mode, rng: &mut Rng) -> Vec<f32> {
    match mode {
        Mode::None => w.to_vec(),
        Mode::Det => w.iter().map(|&v| if v >= 0.0 { h } else { -h }).collect(),
        Mode::Stoch => w
            .iter()
            .map(|&v| {
                // Eq. 2: p = hard_sigmoid(w / H)
                let p = ((v / h + 1.0) * 0.5).clamp(0.0, 1.0);
                if rng.uniform() < p {
                    h
                } else {
                    -h
                }
            })
            .collect(),
    }
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (j, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = j;
        }
    }
    best
}

/// Per-example squared-hinge loss + error indicator and d(loss)/d(z) for
/// loss = mean over the batch, written into caller buffers (row slices
/// hoisted — no per-element index arithmetic).
fn metrics_into(
    logits: &[f32],
    y: &[f32],
    c: usize,
    lossv: &mut [f32],
    errv: &mut [f32],
    dlogits: &mut [f32],
) {
    let bf = lossv.len() as f32;
    for (((zrow, yrow), (lv, ev)), drow) in logits
        .chunks_exact(c)
        .zip(y.chunks_exact(c))
        .zip(lossv.iter_mut().zip(errv.iter_mut()))
        .zip(dlogits.chunks_exact_mut(c))
    {
        let mut acc = 0f32;
        for ((dv, &zv), &yv) in drow.iter_mut().zip(zrow).zip(yrow) {
            let margin = (1.0 - yv * zv).max(0.0);
            acc += margin * margin;
            *dv = -2.0 * margin * yv / bf;
        }
        *lv = acc;
        *ev = if argmax(zrow) != argmax(yrow) { 1.0 } else { 0.0 };
    }
}

/// Divergence sentinel over the gradients a step actually produced:
/// true when any used gradient tensor holds a NaN/Inf.
fn grads_non_finite(grads: &[Vec<f32>], used: &[bool]) -> bool {
    grads
        .iter()
        .zip(used)
        .any(|(g, &u)| u && g.iter().any(|v| !v.is_finite()))
}

/// Training-mode BN (batch statistics) + affine + ReLU + inverted
/// dropout, in place on `z` (`rows x n` row-major), filling the caches
/// the backward needs. Shared by the dense and conv stages of both
/// kernel paths: for dense layers `rows` is the batch; for conv layers
/// it is `b*h*w` — per-channel BN over every spatial position, as in
/// the paper's conv stacks. Dropout draws (when `p > 0`) run row-major
/// over `z`, so the fast and baseline paths consume the RNG
/// identically.
#[allow(clippy::too_many_arguments)]
fn bn_forward_train_into(
    z: &mut [f32],
    n: usize,
    gamma: &[f32],
    beta: &[f32],
    p: f32,
    rng: &mut Rng,
    mean: &mut [f32],
    var: &mut [f32],
    inv_std: &mut [f32],
    xhat: &mut [f32],
    gate: &mut [f32],
) {
    let rows_f = (z.len() / n) as f32;
    // batch statistics (biased variance, like jnp.var); kept by the
    // caller so the rmean/rvar write can wait until the divergence
    // sentinel has cleared the step
    mean.fill(0.0);
    for zrow in z.chunks_exact(n) {
        for (mj, &v) in mean.iter_mut().zip(zrow) {
            *mj += v;
        }
    }
    for mj in mean.iter_mut() {
        *mj /= rows_f;
    }
    var.fill(0.0);
    for zrow in z.chunks_exact(n) {
        for ((vj, &v), &mj) in var.iter_mut().zip(zrow).zip(&*mean) {
            let cv = v - mj;
            *vj += cv * cv;
        }
    }
    for vj in var.iter_mut() {
        *vj /= rows_f;
    }
    for (o, &v) in inv_std.iter_mut().zip(&*var) {
        *o = 1.0 / (v + BN_EPS).sqrt();
    }
    for (xrow, zrow) in xhat.chunks_exact_mut(n).zip(z.chunks_exact(n)) {
        for (((xv, &zv), &mj), &is) in xrow.iter_mut().zip(zrow).zip(&*mean).zip(&*inv_std) {
            *xv = (zv - mj) * is;
        }
    }
    // affine + ReLU + inverted dropout; z becomes the layer output
    let dscale = 1.0 / (1.0 - p).max(1e-6);
    for (zrow, (xrow, grow)) in
        z.chunks_exact_mut(n).zip(xhat.chunks_exact(n).zip(gate.chunks_exact_mut(n)))
    {
        for (j, (zv, gv)) in zrow.iter_mut().zip(grow.iter_mut()).enumerate() {
            let yv = gamma[j] * xrow[j] + beta[j];
            let s = if p > 0.0 {
                if rng.uniform() < p {
                    0.0
                } else {
                    dscale
                }
            } else {
                1.0
            };
            if yv > 0.0 {
                *gv = s;
                *zv = yv * s;
            } else {
                *gv = 0.0;
                *zv = 0.0;
            }
        }
    }
}

/// Eval-mode BN (running statistics) + affine + ReLU, in place on `z`.
fn bn_forward_eval_into(
    z: &mut [f32],
    n: usize,
    gamma: &[f32],
    beta: &[f32],
    rmean: &[f32],
    rvar: &[f32],
    inv_std: &mut [f32],
) {
    for (o, &v) in inv_std.iter_mut().zip(rvar) {
        *o = 1.0 / (v + BN_EPS).sqrt();
    }
    for zrow in z.chunks_exact_mut(n) {
        for (j, zv) in zrow.iter_mut().enumerate() {
            let yv = (*zv - rmean[j]) * inv_std[j] * gamma[j] + beta[j];
            *zv = yv.max(0.0);
        }
    }
}

/// Batch-norm backward through the batch statistics, in place on `dz`
/// (which must already carry the ReLU/dropout gate). Writes
/// `dgamma = sum(dy * xhat)` and `dbeta = sum(dy)` as side products.
fn bn_backward_into(
    dz: &mut [f32],
    n: usize,
    gamma: &[f32],
    xhat: &[f32],
    inv_std: &[f32],
    dgamma: &mut [f32],
    dbeta: &mut [f32],
) {
    let rows_f = (dz.len() / n) as f32;
    dgamma.fill(0.0);
    dbeta.fill(0.0);
    for (drow, xrow) in dz.chunks_exact(n).zip(xhat.chunks_exact(n)) {
        for (((sg, sb), &d), &xv) in dgamma.iter_mut().zip(dbeta.iter_mut()).zip(drow).zip(xrow)
        {
            *sb += d;
            *sg += d * xv;
        }
    }
    for (drow, xrow) in dz.chunks_exact_mut(n).zip(xhat.chunks_exact(n)) {
        for (j, dv) in drow.iter_mut().enumerate() {
            *dv = gamma[j] * inv_std[j] / rows_f * (rows_f * *dv - dbeta[j] - xrow[j] * dgamma[j]);
        }
    }
}

/// Preallocated per-step buffers. Built lazily on the first step and
/// reused for the executor's lifetime, so a steady-state `train_step`
/// allocates nothing (see `steady_state_train_step_is_allocation_free`
/// and its conv twin).
struct Workspace {
    /// acts[li] = flattened input to layer li (acts[0] = dropped-out
    /// batch); acts[n_layers] = b x classes logits. Conv activations are
    /// `(b, h, w, c)` row-major, which flattens to exactly the dense
    /// layout the fc stack consumes.
    acts: Vec<Vec<f32>>,
    /// dacts[li] = gradient w.r.t. acts[li] (dacts[0] unused — the input
    /// gradient is never needed).
    dacts: Vec<Vec<f32>>,
    /// rows x n normalized pre-affine BN activations (BN layers only;
    /// rows = batch for dense, b*h*w for conv).
    xhat: Vec<Vec<f32>>,
    /// n per-unit 1/sqrt(var + eps) (BN layers only).
    inv_std: Vec<Vec<f32>>,
    /// rows x n combined ReLU x dropout multiplier (BN layers only).
    gate: Vec<Vec<f32>>,
    /// per-layer batch statistics (BN layers only), kept until the
    /// end of the step so the running-stat write can happen *after* the
    /// divergence sentinel — a skipped step must leave rmean/rvar
    /// untouched too.
    bn_mean: Vec<Vec<f32>>,
    bn_var: Vec<Vec<f32>>,
    /// per-layer packed sign matrices, re-packed in place every step
    /// (conv filter banks pack as (kh*kw*cin) x cout).
    bits: Vec<BitMatrix>,
    /// im2col patch matrices, (b*h*w) x (kh*kw*cin) (conv layers only).
    patches: Vec<Vec<f32>>,
    /// patch-gradient buffers, same shapes (conv layers only).
    dpatches: Vec<Vec<f32>>,
    /// pre-pool conv activations, (b*h*w) x cout (pooled conv layers
    /// only); doubles as the pool-backward scatter target.
    ybuf: Vec<Vec<f32>>,
    /// MaxPool2x2 argmax cache (pooled conv layers only).
    pool_idx: Vec<Vec<u32>>,
    /// transpose scratch for the packed kernels (max rows*max(k, n)).
    xt: Vec<f32>,
    /// tmatmul selected-sum accumulator (max rows*k).
    acc: Vec<f32>,
    /// per-GEMM-row totals (max rows).
    totals: Vec<f32>,
    /// per-param gradient buffers (+ which ones a step produced).
    grads: Vec<Vec<f32>>,
    grad_used: Vec<bool>,
    /// metrics buffers.
    lossv: Vec<f32>,
    errv: Vec<f32>,
    dlogits: Vec<f32>,
    /// panel-packing buffers for the f32 GEMM trio (presized for every
    /// layer orientation, so the warmed-up step never grows them).
    panels: kernel::PanelBuf,
}

impl Workspace {
    fn build(info: &ModelInfo, layers: &[Layer]) -> Workspace {
        let b = info.batch;
        let nl = layers.len();
        let mut acts = Vec::with_capacity(nl + 1);
        acts.push(vec![0f32; b * layers[0].in_dim()]);
        for l in layers {
            acts.push(vec![0f32; b * l.out_dim()]);
        }
        let mut dacts = Vec::with_capacity(nl + 1);
        dacts.push(Vec::new());
        for l in layers {
            dacts.push(vec![0f32; b * l.out_dim()]);
        }
        let mut xhat = Vec::with_capacity(nl);
        let mut inv_std = Vec::with_capacity(nl);
        let mut gate = Vec::with_capacity(nl);
        let mut bn_mean = Vec::with_capacity(nl);
        let mut bn_var = Vec::with_capacity(nl);
        let mut patches = Vec::with_capacity(nl);
        let mut dpatches = Vec::with_capacity(nl);
        let mut ybuf = Vec::with_capacity(nl);
        let mut pool_idx = Vec::with_capacity(nl);
        for l in layers {
            // (rows, units) of the layer's BN problem; rows = GEMM rows
            let (rows, units) = match l {
                Layer::Conv(c) => (b * c.spatial(), c.cout),
                Layer::Dense(d) => (b, d.n),
            };
            if l.bn().is_some() {
                xhat.push(vec![0f32; rows * units]);
                inv_std.push(vec![0f32; units]);
                gate.push(vec![0f32; rows * units]);
                bn_mean.push(vec![0f32; units]);
                bn_var.push(vec![0f32; units]);
            } else {
                xhat.push(Vec::new());
                inv_std.push(Vec::new());
                gate.push(Vec::new());
                bn_mean.push(Vec::new());
                bn_var.push(Vec::new());
            }
            match l {
                Layer::Conv(c) => {
                    patches.push(vec![0f32; rows * c.patch_k()]);
                    dpatches.push(vec![0f32; rows * c.patch_k()]);
                    if c.pool {
                        ybuf.push(vec![0f32; rows * c.cout]);
                        pool_idx.push(vec![0u32; rows * c.cout / 4]);
                    } else {
                        ybuf.push(Vec::new());
                        pool_idx.push(Vec::new());
                    }
                }
                Layer::Dense(_) => {
                    patches.push(Vec::new());
                    dpatches.push(Vec::new());
                    ybuf.push(Vec::new());
                    pool_idx.push(Vec::new());
                }
            }
        }
        // presize the GEMM panel buffers for every product the step runs
        // — forward z = a @ W (rows x k x n), grad dW = a^T @ dz
        // (k x rows x n), backward dX = dz @ W^T (rows x n x k) — and the
        // packed-kernel scratch for the largest layer in each role.
        let mut panels = kernel::PanelBuf::new();
        let mut bits = Vec::with_capacity(nl);
        let (mut xt_len, mut acc_len, mut tot_len) = (1usize, 1usize, 1usize);
        for l in layers {
            let (rows, k, units) = match l {
                Layer::Conv(c) => (b * c.spatial(), c.patch_k(), c.cout),
                Layer::Dense(d) => (b, d.k, d.n),
            };
            panels.reserve_gemm(rows, k, units);
            panels.reserve_gemm(k, rows, units);
            panels.reserve_gemm(rows, units, k);
            xt_len = xt_len.max(rows * k.max(units));
            acc_len = acc_len.max(rows * k);
            tot_len = tot_len.max(rows);
            bits.push(BitMatrix::zeroed(k, units));
        }
        Workspace {
            acts,
            dacts,
            xhat,
            inv_std,
            gate,
            bn_mean,
            bn_var,
            bits,
            patches,
            dpatches,
            ybuf,
            pool_idx,
            xt: vec![0f32; xt_len],
            acc: vec![0f32; acc_len],
            totals: vec![0f32; tot_len],
            grads: info.params.iter().map(|p| vec![0f32; p.numel()]).collect(),
            grad_used: vec![false; info.params.len()],
            lossv: vec![0f32; b],
            errv: vec![0f32; b],
            dlogits: vec![0f32; b * info.classes],
            panels,
        }
    }
}

pub struct ReferenceExecutor {
    info: ModelInfo,
    layers: Vec<Layer>,
    /// true (default): packed/blocked workspace path; false: the seed-era
    /// dense allocating path (benchmark baseline + correctness oracle).
    fast: bool,
    ws: Mutex<Option<Workspace>>,
    /// chaos harness: armed training-site fault plan (`nan_grad@P`).
    faults: Option<Arc<FaultPlan>>,
}

impl ReferenceExecutor {
    /// Validate a model spec (dense MLP, or C3-style conv net lowered
    /// onto the packed sign-GEMM) into an executable plan.
    pub fn new(info: ModelInfo) -> Result<ReferenceExecutor> {
        let layers = plan(&info)?;
        Ok(ReferenceExecutor { info, layers, fast: true, ws: Mutex::new(None), faults: None })
    }

    /// Load a builtin model by name (see [`builtin_info`]).
    pub fn builtin(name: &str) -> Result<ReferenceExecutor> {
        let info = builtin_info(name).ok_or_else(|| {
            anyhow!("no builtin model '{name}' (have: {})", builtin_names().join(", "))
        })?;
        ReferenceExecutor::new(info)
    }

    /// Select the kernel path: `true` = packed + blocked + workspace
    /// (default), `false` = the seed-era dense baseline. Train/eval
    /// results agree within f32 reorder noise (property-tested at 1e-4).
    pub fn set_fast(&mut self, fast: bool) {
        self.fast = fast;
    }

    /// Arm the executor-level fault sites (`nan_grad@P` poisons the first
    /// weight gradient of a step when the seeded decision fires, which
    /// the divergence sentinel must then catch and account for exactly).
    pub fn set_faults(&mut self, faults: Option<Arc<FaultPlan>>) {
        self.faults = faults;
    }

    fn check_batch(&self, x: &[f32], y: &[f32]) -> Result<()> {
        let want_x = self.info.batch * self.info.input_dim();
        if x.len() != want_x {
            bail!("x has {} elements, model expects {}", x.len(), want_x);
        }
        let want_y = self.info.batch * self.info.classes;
        if y.len() != want_y {
            bail!("y has {} elements, expected {}", y.len(), want_y);
        }
        Ok(())
    }

    /// Allocating metrics wrapper (baseline path + eval).
    fn metrics(&self, logits: &[f32], y: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let b = self.info.batch;
        let c = self.info.classes;
        let mut lossv = vec![0f32; b];
        let mut errv = vec![0f32; b];
        let mut dlogits = vec![0f32; b * c];
        metrics_into(logits, y, c, &mut lossv, &mut errv, &mut dlogits);
        (lossv, errv, dlogits)
    }

    /// Sec. 2.4 clip + Sec. 2.5 LR scaling + optimizer update, shared by
    /// the fast and baseline paths (in place; allocation-free).
    fn apply_updates(
        &self,
        state: &mut TrainState,
        hyper: &Hyper,
        grads: &[Vec<f32>],
        used: &[bool],
    ) {
        let lr = hyper.lr;
        let mode = hyper.mode;
        for (i, p) in self.info.params.iter().enumerate() {
            if !used[i] {
                continue;
            }
            let g = &grads[i];
            let (lr_j, clip, h) = if p.kind == "weight" {
                let c = p.glorot as f32;
                let pow = match hyper.opt {
                    Opt::Adam => 1,
                    _ => 2,
                };
                let lr_j = if hyper.lr_scale { lr / c.powi(pow) } else { lr };
                (lr_j, mode != Mode::None, c)
            } else {
                (lr, false, 1.0f32)
            };
            let w = &mut state.params[i];
            let m = &mut state.m[i];
            let v = &mut state.v[i];
            match hyper.opt {
                Opt::Sgd => {
                    for (wv, &gv) in w.iter_mut().zip(g) {
                        let mut wn = *wv - lr_j * gv;
                        if clip {
                            wn = wn.clamp(-h, h);
                        }
                        *wv = wn;
                    }
                }
                Opt::Nesterov => {
                    let mu = hyper.momentum;
                    for ((wv, mv), &gv) in w.iter_mut().zip(m.iter_mut()).zip(g) {
                        let mn = mu * *mv - lr_j * gv;
                        let mut wn = *wv + mu * mn - lr_j * gv;
                        if clip {
                            wn = wn.clamp(-h, h);
                        }
                        *mv = mn;
                        *wv = wn;
                    }
                }
                Opt::Adam => {
                    let b1 = hyper.momentum;
                    let b2 = hyper.beta2;
                    let t = hyper.step as f32;
                    let corr1 = 1.0 - b1.powf(t);
                    let corr2 = 1.0 - b2.powf(t);
                    for (((wv, mv), vv), &gv) in
                        w.iter_mut().zip(m.iter_mut()).zip(v.iter_mut()).zip(g)
                    {
                        let mn = b1 * *mv + (1.0 - b1) * gv;
                        let vn = b2 * *vv + (1.0 - b2) * gv * gv;
                        let m_hat = mn / corr1;
                        let v_hat = vn / corr2;
                        let mut wn = *wv - lr_j * m_hat / (v_hat.sqrt() + hyper.eps);
                        if clip {
                            wn = wn.clamp(-h, h);
                        }
                        *mv = mn;
                        *vv = vn;
                        *wv = wn;
                    }
                }
            }
        }
    }

    // -----------------------------------------------------------------
    // fast path: packed sign-GEMM + workspace, zero steady-state allocs
    // -----------------------------------------------------------------

    fn train_step_fast(
        &self,
        state: &mut TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<StepMetrics> {
        self.check_batch(x, y)?;
        let b = self.info.batch;
        let c = self.info.classes;
        let bf = b as f32;
        let mode = hyper.mode;
        let mut rng = Rng::new(TRAIN_SALT ^ hyper.seed as u64);
        let nl = self.layers.len();
        let mut guard = self.ws.lock().unwrap();
        let ws = guard.get_or_insert_with(|| Workspace::build(&self.info, &self.layers));

        // ---- forward ----
        {
            let a0 = &mut ws.acts[0];
            a0.copy_from_slice(x);
            if hyper.in_dropout > 0.0 {
                let p = hyper.in_dropout;
                let scale = 1.0 / (1.0 - p).max(1e-6);
                for v in a0.iter_mut() {
                    if rng.uniform() < p {
                        *v = 0.0;
                    } else {
                        *v *= scale;
                    }
                }
            }
        }
        for (li, layer) in self.layers.iter().enumerate() {
            let (alo, ahi) = ws.acts.split_at_mut(li + 1);
            let a_in: &[f32] = &alo[li];
            match layer {
                Layer::Dense(layer) => {
                    let n = layer.n;
                    let k = layer.k;
                    // z = a_in @ Wb into acts[li + 1]
                    let z: &mut [f32] = &mut ahi[0];
                    match mode {
                        Mode::None => kernel::gemm_into(
                            a_in,
                            &state.params[layer.w],
                            b,
                            k,
                            n,
                            z,
                            &mut ws.panels,
                        ),
                        Mode::Det => {
                            let bits = &mut ws.bits[li];
                            bits.pack_det_into(&state.params[layer.w], k, n);
                            bits.matmul_scaled_into(a_in, b, layer.h, z, &mut ws.xt, &mut ws.totals);
                        }
                        Mode::Stoch => {
                            let bits = &mut ws.bits[li];
                            bits.pack_stoch_into(&state.params[layer.w], k, n, layer.h, &mut rng);
                            bits.matmul_scaled_into(a_in, b, layer.h, z, &mut ws.xt, &mut ws.totals);
                        }
                    }
                    if let Some(bidx) = layer.bias {
                        let bias = &state.params[bidx];
                        for zrow in z.chunks_exact_mut(n) {
                            for (zv, &bv) in zrow.iter_mut().zip(bias) {
                                *zv += bv;
                            }
                        }
                    } else {
                        let gi = layer.bn.unwrap();
                        bn_forward_train_into(
                            z,
                            n,
                            &state.params[gi],
                            &state.params[gi + 1],
                            hyper.dropout,
                            &mut rng,
                            &mut ws.bn_mean[li],
                            &mut ws.bn_var[li],
                            &mut ws.inv_std[li],
                            &mut ws.xhat[li],
                            &mut ws.gate[li],
                        );
                    }
                }
                Layer::Conv(layer) => {
                    let rows = b * layer.spatial();
                    let pk = layer.patch_k();
                    // lower to a GEMM over gathered patches: the pre-pool
                    // conv output lands in ybuf when a pool follows,
                    // directly in acts[li + 1] otherwise
                    im2col::im2col_into(
                        a_in,
                        b,
                        layer.h_in,
                        layer.w_in,
                        layer.cin,
                        layer.kh,
                        layer.kw,
                        &mut ws.patches[li],
                    );
                    let z: &mut [f32] =
                        if layer.pool { &mut ws.ybuf[li][..] } else { &mut ahi[0][..] };
                    match mode {
                        Mode::None => kernel::gemm_into(
                            &ws.patches[li],
                            &state.params[layer.w],
                            rows,
                            pk,
                            layer.cout,
                            z,
                            &mut ws.panels,
                        ),
                        Mode::Det => {
                            let bits = &mut ws.bits[li];
                            bits.pack_det_into(&state.params[layer.w], pk, layer.cout);
                            bits.matmul_scaled_into(
                                &ws.patches[li],
                                rows,
                                layer.h,
                                z,
                                &mut ws.xt,
                                &mut ws.totals,
                            );
                        }
                        Mode::Stoch => {
                            let bits = &mut ws.bits[li];
                            bits.pack_stoch_into(
                                &state.params[layer.w],
                                pk,
                                layer.cout,
                                layer.h,
                                &mut rng,
                            );
                            bits.matmul_scaled_into(
                                &ws.patches[li],
                                rows,
                                layer.h,
                                z,
                                &mut ws.xt,
                                &mut ws.totals,
                            );
                        }
                    }
                    // per-channel BN over all b*h*w rows + ReLU + dropout
                    let gi = layer.bn;
                    bn_forward_train_into(
                        z,
                        layer.cout,
                        &state.params[gi],
                        &state.params[gi + 1],
                        hyper.dropout,
                        &mut rng,
                        &mut ws.bn_mean[li],
                        &mut ws.bn_var[li],
                        &mut ws.inv_std[li],
                        &mut ws.xhat[li],
                        &mut ws.gate[li],
                    );
                    if layer.pool {
                        pool::maxpool2x2_into(
                            &ws.ybuf[li],
                            b,
                            layer.h_in,
                            layer.w_in,
                            layer.cout,
                            &mut ahi[0],
                            &mut ws.pool_idx[li],
                        );
                    }
                }
            }
        }

        // ---- loss / metrics (dlogits land straight in dacts[nl]) ----
        metrics_into(&ws.acts[nl], y, c, &mut ws.lossv, &mut ws.errv, &mut ws.dacts[nl]);
        let loss = ws.lossv.iter().sum::<f32>() / bf;
        let n_err = ws.errv.iter().sum::<f32>();

        // ---- backward (straight-through on the binarized weights) ----
        for u in ws.grad_used.iter_mut() {
            *u = false;
        }
        for li in (0..nl).rev() {
            let layer = &self.layers[li];
            let (dlo, dhi) = ws.dacts.split_at_mut(li + 1);
            match layer {
                Layer::Dense(layer) => {
                    let n = layer.n;
                    let k = layer.k;
                    let dz: &mut [f32] = &mut dhi[0][..];
                    if let Some(bidx) = layer.bias {
                        let db = &mut ws.grads[bidx];
                        db.fill(0.0);
                        for drow in dz.chunks_exact(n) {
                            for (gv, &d) in db.iter_mut().zip(drow) {
                                *gv += d;
                            }
                        }
                        ws.grad_used[bidx] = true;
                    } else {
                        // through ReLU + dropout, then the batch statistics
                        for (drow, grow) in
                            dz.chunks_exact_mut(n).zip(ws.gate[li].chunks_exact(n))
                        {
                            for (dv, &g) in drow.iter_mut().zip(grow) {
                                *dv *= g;
                            }
                        }
                        let gi = layer.bn.unwrap();
                        let (glo, ghi) = ws.grads.split_at_mut(gi + 1);
                        bn_backward_into(
                            dz,
                            n,
                            &state.params[gi],
                            &ws.xhat[li],
                            &ws.inv_std[li],
                            &mut glo[gi],
                            &mut ghi[0],
                        );
                        ws.grad_used[gi] = true;
                        ws.grad_used[gi + 1] = true;
                    }
                    // dW = a_in^T · dZ (dense f32: dZ is real-valued either way)
                    kernel::gemm_at_b_into(
                        &ws.acts[li],
                        dz,
                        b,
                        k,
                        n,
                        &mut ws.grads[layer.w],
                        &mut ws.panels,
                    );
                    ws.grad_used[layer.w] = true;
                    // dX = dZ · Wb^T for the next layer down
                    if li > 0 {
                        let dx: &mut [f32] = &mut dlo[li][..];
                        match mode {
                            Mode::None => kernel::gemm_a_bt_into(
                                dz,
                                &state.params[layer.w],
                                b,
                                n,
                                k,
                                dx,
                                &mut ws.panels,
                            ),
                            _ => ws.bits[li].tmatmul_scaled_into(
                                dz,
                                b,
                                layer.h,
                                dx,
                                &mut ws.xt,
                                &mut ws.acc,
                                &mut ws.totals,
                            ),
                        }
                    }
                }
                Layer::Conv(layer) => {
                    let rows = b * layer.spatial();
                    let pk = layer.patch_k();
                    let n = layer.cout;
                    // un-pool first (scatter into ybuf), so dz has the
                    // pre-pool (rows x cout) shape either way
                    let dz: &mut [f32] = if layer.pool {
                        pool::maxpool2x2_backward_into(
                            &dhi[0],
                            &ws.pool_idx[li],
                            &mut ws.ybuf[li],
                        );
                        &mut ws.ybuf[li][..]
                    } else {
                        &mut dhi[0][..]
                    };
                    // through ReLU + dropout, then the batch statistics
                    for (drow, grow) in dz.chunks_exact_mut(n).zip(ws.gate[li].chunks_exact(n)) {
                        for (dv, &g) in drow.iter_mut().zip(grow) {
                            *dv *= g;
                        }
                    }
                    let gi = layer.bn;
                    let (glo, ghi) = ws.grads.split_at_mut(gi + 1);
                    bn_backward_into(
                        dz,
                        n,
                        &state.params[gi],
                        &ws.xhat[li],
                        &ws.inv_std[li],
                        &mut glo[gi],
                        &mut ghi[0],
                    );
                    ws.grad_used[gi] = true;
                    ws.grad_used[gi + 1] = true;
                    // dW = patches^T · dZ over all b*h*w patch rows
                    kernel::gemm_at_b_into(
                        &ws.patches[li],
                        dz,
                        rows,
                        pk,
                        n,
                        &mut ws.grads[layer.w],
                        &mut ws.panels,
                    );
                    ws.grad_used[layer.w] = true;
                    // dPatches = dZ · Wb^T, then scatter back to the image grid
                    if li > 0 {
                        match mode {
                            Mode::None => kernel::gemm_a_bt_into(
                                dz,
                                &state.params[layer.w],
                                rows,
                                n,
                                pk,
                                &mut ws.dpatches[li],
                                &mut ws.panels,
                            ),
                            _ => ws.bits[li].tmatmul_scaled_into(
                                dz,
                                rows,
                                layer.h,
                                &mut ws.dpatches[li],
                                &mut ws.xt,
                                &mut ws.acc,
                                &mut ws.totals,
                            ),
                        }
                        im2col::col2im_into(
                            &ws.dpatches[li],
                            b,
                            layer.h_in,
                            layer.w_in,
                            layer.cin,
                            layer.kh,
                            layer.kw,
                            &mut dlo[li],
                        );
                    }
                }
            }
        }

        // ---- chaos harness: seeded gradient poisoning ----
        if self.faults.as_ref().is_some_and(|f| f.roll_nan_grad()) {
            ws.grads[self.layers[0].w()][0] = f32::NAN;
        }

        // ---- divergence sentinel (loss + every produced gradient) ----
        let diverged = !loss.is_finite() || grads_non_finite(&ws.grads, &ws.grad_used);

        // ---- deferred state writes: BN running stats + parameter update,
        //      both skipped when a diverged step asked for skip-step
        //      recovery, so the state stays bit-exactly untouched ----
        if !(diverged && hyper.skip_nonfinite) {
            let mom = hyper.bn_momentum;
            for (li, layer) in self.layers.iter().enumerate() {
                if let Some(gi) = layer.bn() {
                    for (r, &mj) in state.params[gi + 2].iter_mut().zip(&ws.bn_mean[li]) {
                        *r = mom * *r + (1.0 - mom) * mj;
                    }
                    for (r, &vj) in state.params[gi + 3].iter_mut().zip(&ws.bn_var[li]) {
                        *r = mom * *r + (1.0 - mom) * vj;
                    }
                }
            }
            self.apply_updates(state, hyper, &ws.grads, &ws.grad_used);
        }
        Ok(StepMetrics { loss, n_err, diverged })
    }

    fn eval_batch_fast(
        &self,
        state: &TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.check_batch(x, y)?;
        let b = self.info.batch;
        let c = self.info.classes;
        let mut rng = Rng::new(EVAL_SALT ^ hyper.seed as u64);
        let nl = self.layers.len();
        let mut guard = self.ws.lock().unwrap();
        let ws = guard.get_or_insert_with(|| Workspace::build(&self.info, &self.layers));

        ws.acts[0].copy_from_slice(x);
        for (li, layer) in self.layers.iter().enumerate() {
            let (alo, ahi) = ws.acts.split_at_mut(li + 1);
            let a_in: &[f32] = &alo[li];
            match layer {
                Layer::Dense(layer) => {
                    let n = layer.n;
                    let k = layer.k;
                    let z: &mut [f32] = &mut ahi[0];
                    match hyper.mode {
                        Mode::None => kernel::gemm_into(
                            a_in,
                            &state.params[layer.w],
                            b,
                            k,
                            n,
                            z,
                            &mut ws.panels,
                        ),
                        Mode::Det => {
                            let bits = &mut ws.bits[li];
                            bits.pack_det_into(&state.params[layer.w], k, n);
                            bits.matmul_scaled_into(a_in, b, layer.h, z, &mut ws.xt, &mut ws.totals);
                        }
                        Mode::Stoch => {
                            let bits = &mut ws.bits[li];
                            bits.pack_stoch_into(&state.params[layer.w], k, n, layer.h, &mut rng);
                            bits.matmul_scaled_into(a_in, b, layer.h, z, &mut ws.xt, &mut ws.totals);
                        }
                    }
                    if let Some(bidx) = layer.bias {
                        let bias = &state.params[bidx];
                        for zrow in z.chunks_exact_mut(n) {
                            for (zv, &bv) in zrow.iter_mut().zip(bias) {
                                *zv += bv;
                            }
                        }
                    } else {
                        let gi = layer.bn.unwrap();
                        bn_forward_eval_into(
                            z,
                            n,
                            &state.params[gi],
                            &state.params[gi + 1],
                            &state.params[gi + 2],
                            &state.params[gi + 3],
                            &mut ws.inv_std[li],
                        );
                    }
                }
                Layer::Conv(layer) => {
                    let rows = b * layer.spatial();
                    let pk = layer.patch_k();
                    im2col::im2col_into(
                        a_in,
                        b,
                        layer.h_in,
                        layer.w_in,
                        layer.cin,
                        layer.kh,
                        layer.kw,
                        &mut ws.patches[li],
                    );
                    let z: &mut [f32] =
                        if layer.pool { &mut ws.ybuf[li][..] } else { &mut ahi[0][..] };
                    match hyper.mode {
                        Mode::None => kernel::gemm_into(
                            &ws.patches[li],
                            &state.params[layer.w],
                            rows,
                            pk,
                            layer.cout,
                            z,
                            &mut ws.panels,
                        ),
                        Mode::Det => {
                            let bits = &mut ws.bits[li];
                            bits.pack_det_into(&state.params[layer.w], pk, layer.cout);
                            bits.matmul_scaled_into(
                                &ws.patches[li],
                                rows,
                                layer.h,
                                z,
                                &mut ws.xt,
                                &mut ws.totals,
                            );
                        }
                        Mode::Stoch => {
                            let bits = &mut ws.bits[li];
                            bits.pack_stoch_into(
                                &state.params[layer.w],
                                pk,
                                layer.cout,
                                layer.h,
                                &mut rng,
                            );
                            bits.matmul_scaled_into(
                                &ws.patches[li],
                                rows,
                                layer.h,
                                z,
                                &mut ws.xt,
                                &mut ws.totals,
                            );
                        }
                    }
                    let gi = layer.bn;
                    bn_forward_eval_into(
                        z,
                        layer.cout,
                        &state.params[gi],
                        &state.params[gi + 1],
                        &state.params[gi + 2],
                        &state.params[gi + 3],
                        &mut ws.inv_std[li],
                    );
                    if layer.pool {
                        pool::maxpool2x2_into(
                            &ws.ybuf[li],
                            b,
                            layer.h_in,
                            layer.w_in,
                            layer.cout,
                            &mut ahi[0],
                            &mut ws.pool_idx[li],
                        );
                    }
                }
            }
        }
        metrics_into(&ws.acts[nl], y, c, &mut ws.lossv, &mut ws.errv, &mut ws.dlogits);
        Ok((ws.lossv.clone(), ws.errv.clone()))
    }

    // -----------------------------------------------------------------
    // baseline path: the seed's dense allocating step (naive kernels)
    // -----------------------------------------------------------------

    fn train_step_baseline(
        &self,
        state: &mut TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<StepMetrics> {
        struct Cache {
            a_in: Vec<f32>,
            wb: Vec<f32>,
            xhat: Vec<f32>,
            inv_std: Vec<f32>,
            gate: Vec<f32>,
            pool_idx: Vec<u32>,
        }

        self.check_batch(x, y)?;
        let b = self.info.batch;
        let bf = b as f32;
        let mode = hyper.mode;
        let mut rng = Rng::new(TRAIN_SALT ^ hyper.seed as u64);
        let nl = self.layers.len();

        // ---- forward, caching what the backward pass needs ----
        let mut a: Vec<f32> = x.to_vec();
        if hyper.in_dropout > 0.0 {
            let p = hyper.in_dropout;
            let scale = 1.0 / (1.0 - p).max(1e-6);
            for v in a.iter_mut() {
                if rng.uniform() < p {
                    *v = 0.0;
                } else {
                    *v *= scale;
                }
            }
        }
        let mut caches: Vec<Cache> = Vec::with_capacity(nl);
        let mut bn_stat_updates: Vec<(usize, Vec<f32>)> = vec![];
        let mom = hyper.bn_momentum;
        // queue the deferred running-stat write for one BN block
        let mut push_bn_stats =
            |out: &mut Vec<(usize, Vec<f32>)>, state: &TrainState, gi: usize, mean: &[f32], var: &[f32]| {
                out.push((
                    gi + 2,
                    state.params[gi + 2]
                        .iter()
                        .zip(mean)
                        .map(|(&r, &m)| mom * r + (1.0 - mom) * m)
                        .collect(),
                ));
                out.push((
                    gi + 3,
                    state.params[gi + 3]
                        .iter()
                        .zip(var)
                        .map(|(&r, &v)| mom * r + (1.0 - mom) * v)
                        .collect(),
                ));
            };
        for layer in self.layers.iter() {
            match layer {
                Layer::Dense(layer) => {
                    let wb = binarize(&state.params[layer.w], layer.h, mode, &mut rng);
                    let n = layer.n;
                    let mut z = vec![0f32; b * n];
                    kernel::gemm_naive(&a, &wb, b, layer.k, n, &mut z);
                    if let Some(bidx) = layer.bias {
                        let bias = &state.params[bidx];
                        for zrow in z.chunks_exact_mut(n) {
                            for (zv, &bv) in zrow.iter_mut().zip(bias) {
                                *zv += bv;
                            }
                        }
                        let a_in = std::mem::replace(&mut a, z);
                        caches.push(Cache {
                            a_in,
                            wb,
                            xhat: vec![],
                            inv_std: vec![],
                            gate: vec![],
                            pool_idx: vec![],
                        });
                    } else {
                        let gi = layer.bn.unwrap();
                        let mut mean = vec![0f32; n];
                        let mut var = vec![0f32; n];
                        let mut inv_std = vec![0f32; n];
                        let mut xhat = vec![0f32; b * n];
                        let mut gate = vec![0f32; b * n];
                        bn_forward_train_into(
                            &mut z,
                            n,
                            &state.params[gi],
                            &state.params[gi + 1],
                            hyper.dropout,
                            &mut rng,
                            &mut mean,
                            &mut var,
                            &mut inv_std,
                            &mut xhat,
                            &mut gate,
                        );
                        push_bn_stats(&mut bn_stat_updates, state, gi, &mean, &var);
                        let a_in = std::mem::replace(&mut a, z);
                        caches.push(Cache { a_in, wb, xhat, inv_std, gate, pool_idx: vec![] });
                    }
                }
                Layer::Conv(layer) => {
                    let wb = binarize(&state.params[layer.w], layer.h, mode, &mut rng);
                    let rows = b * layer.spatial();
                    let n = layer.cout;
                    let mut z = vec![0f32; rows * n];
                    oracle::conv2d_forward(
                        &a,
                        b,
                        layer.h_in,
                        layer.w_in,
                        layer.cin,
                        &wb,
                        layer.kh,
                        layer.kw,
                        n,
                        &mut z,
                    );
                    let gi = layer.bn;
                    let mut mean = vec![0f32; n];
                    let mut var = vec![0f32; n];
                    let mut inv_std = vec![0f32; n];
                    let mut xhat = vec![0f32; rows * n];
                    let mut gate = vec![0f32; rows * n];
                    bn_forward_train_into(
                        &mut z,
                        n,
                        &state.params[gi],
                        &state.params[gi + 1],
                        hyper.dropout,
                        &mut rng,
                        &mut mean,
                        &mut var,
                        &mut inv_std,
                        &mut xhat,
                        &mut gate,
                    );
                    push_bn_stats(&mut bn_stat_updates, state, gi, &mean, &var);
                    if layer.pool {
                        let mut pooled = vec![0f32; rows * n / 4];
                        let mut idx = vec![0u32; rows * n / 4];
                        pool::maxpool2x2_into(
                            &z,
                            b,
                            layer.h_in,
                            layer.w_in,
                            n,
                            &mut pooled,
                            &mut idx,
                        );
                        let a_in = std::mem::replace(&mut a, pooled);
                        caches.push(Cache { a_in, wb, xhat, inv_std, gate, pool_idx: idx });
                    } else {
                        let a_in = std::mem::replace(&mut a, z);
                        caches.push(Cache { a_in, wb, xhat, inv_std, gate, pool_idx: vec![] });
                    }
                }
            }
        }
        let logits = a;
        let (lossv, errv, dlogits) = self.metrics(&logits, y);
        let loss = lossv.iter().sum::<f32>() / bf;
        let n_err = errv.iter().sum::<f32>();

        // ---- backward (straight-through on the binarized weights) ----
        let mut grads: Vec<Vec<f32>> =
            self.info.params.iter().map(|_| Vec::new()).collect();
        let mut used = vec![false; self.info.params.len()];
        let mut dcur = dlogits;
        for li in (0..nl).rev() {
            let cache = &caches[li];
            match &self.layers[li] {
                Layer::Dense(layer) => {
                    let n = layer.n;
                    let dz: Vec<f32>;
                    if let Some(bidx) = layer.bias {
                        let mut db = vec![0f32; n];
                        for drow in dcur.chunks_exact(n) {
                            for (dj, &d) in db.iter_mut().zip(drow) {
                                *dj += d;
                            }
                        }
                        grads[bidx] = db;
                        used[bidx] = true;
                        dz = dcur;
                    } else {
                        // through ReLU + dropout, then the batch statistics
                        let mut dy = dcur;
                        for (dv, &g) in dy.iter_mut().zip(&cache.gate) {
                            *dv *= g;
                        }
                        let gi = layer.bn.unwrap();
                        let mut dgamma = vec![0f32; n];
                        let mut dbeta = vec![0f32; n];
                        bn_backward_into(
                            &mut dy,
                            n,
                            &state.params[gi],
                            &cache.xhat,
                            &cache.inv_std,
                            &mut dgamma,
                            &mut dbeta,
                        );
                        grads[gi] = dgamma;
                        grads[gi + 1] = dbeta;
                        used[gi] = true;
                        used[gi + 1] = true;
                        dz = dy;
                    }
                    let mut dw = vec![0f32; layer.k * n];
                    kernel::gemm_at_b_naive(&cache.a_in, &dz, b, layer.k, n, &mut dw);
                    grads[layer.w] = dw;
                    used[layer.w] = true;
                    dcur = if li > 0 {
                        let mut dx = vec![0f32; b * layer.k];
                        kernel::gemm_a_bt_naive(&dz, &cache.wb, b, n, layer.k, &mut dx);
                        dx
                    } else {
                        vec![]
                    };
                }
                Layer::Conv(layer) => {
                    let rows = b * layer.spatial();
                    let n = layer.cout;
                    // un-pool first so dy has the pre-pool (rows x cout) shape
                    let mut dy = if layer.pool {
                        let mut full = vec![0f32; rows * n];
                        pool::maxpool2x2_backward_into(&dcur, &cache.pool_idx, &mut full);
                        full
                    } else {
                        dcur
                    };
                    // through ReLU + dropout, then the batch statistics
                    for (dv, &g) in dy.iter_mut().zip(&cache.gate) {
                        *dv *= g;
                    }
                    let gi = layer.bn;
                    let mut dgamma = vec![0f32; n];
                    let mut dbeta = vec![0f32; n];
                    bn_backward_into(
                        &mut dy,
                        n,
                        &state.params[gi],
                        &cache.xhat,
                        &cache.inv_std,
                        &mut dgamma,
                        &mut dbeta,
                    );
                    grads[gi] = dgamma;
                    grads[gi + 1] = dbeta;
                    used[gi] = true;
                    used[gi + 1] = true;
                    let dz = dy;
                    let mut dw = vec![0f32; layer.kh * layer.kw * layer.cin * n];
                    oracle::conv2d_backward_dw(
                        &cache.a_in,
                        &dz,
                        b,
                        layer.h_in,
                        layer.w_in,
                        layer.cin,
                        layer.kh,
                        layer.kw,
                        n,
                        &mut dw,
                    );
                    grads[layer.w] = dw;
                    used[layer.w] = true;
                    dcur = if li > 0 {
                        let mut dx = vec![0f32; b * layer.h_in * layer.w_in * layer.cin];
                        oracle::conv2d_backward_dx(
                            &dz,
                            b,
                            layer.h_in,
                            layer.w_in,
                            layer.cin,
                            &cache.wb,
                            layer.kh,
                            layer.kw,
                            n,
                            &mut dx,
                        );
                        dx
                    } else {
                        vec![]
                    };
                }
            }
        }

        // ---- chaos harness: seeded gradient poisoning ----
        if self.faults.as_ref().is_some_and(|f| f.roll_nan_grad()) {
            grads[self.layers[0].w()][0] = f32::NAN;
        }

        // ---- divergence sentinel (loss + every produced gradient) ----
        let diverged = !loss.is_finite() || grads_non_finite(&grads, &used);

        // ---- parameter update (Sec. 2.4 clip + Sec. 2.5 LR scaling),
        //      withheld entirely on a diverged step under skip-step
        //      recovery (running stats included) ----
        if !(diverged && hyper.skip_nonfinite) {
            for (idx, stat) in bn_stat_updates {
                state.params[idx] = stat;
            }
            self.apply_updates(state, hyper, &grads, &used);
        }
        Ok(StepMetrics { loss, n_err, diverged })
    }

    fn eval_batch_baseline(
        &self,
        state: &TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.check_batch(x, y)?;
        let b = self.info.batch;
        let mut rng = Rng::new(EVAL_SALT ^ hyper.seed as u64);
        let mut a: Vec<f32> = x.to_vec();
        for layer in self.layers.iter() {
            match layer {
                Layer::Dense(layer) => {
                    let wb = binarize(&state.params[layer.w], layer.h, hyper.mode, &mut rng);
                    let n = layer.n;
                    let mut z = vec![0f32; b * n];
                    kernel::gemm_naive(&a, &wb, b, layer.k, n, &mut z);
                    if let Some(bidx) = layer.bias {
                        let bias = &state.params[bidx];
                        for zrow in z.chunks_exact_mut(n) {
                            for (zv, &bv) in zrow.iter_mut().zip(bias) {
                                *zv += bv;
                            }
                        }
                    } else {
                        let gi = layer.bn.unwrap();
                        let mut inv_std = vec![0f32; n];
                        bn_forward_eval_into(
                            &mut z,
                            n,
                            &state.params[gi],
                            &state.params[gi + 1],
                            &state.params[gi + 2],
                            &state.params[gi + 3],
                            &mut inv_std,
                        );
                    }
                    a = z;
                }
                Layer::Conv(layer) => {
                    let wb = binarize(&state.params[layer.w], layer.h, hyper.mode, &mut rng);
                    let rows = b * layer.spatial();
                    let n = layer.cout;
                    let mut z = vec![0f32; rows * n];
                    oracle::conv2d_forward(
                        &a,
                        b,
                        layer.h_in,
                        layer.w_in,
                        layer.cin,
                        &wb,
                        layer.kh,
                        layer.kw,
                        n,
                        &mut z,
                    );
                    let gi = layer.bn;
                    let mut inv_std = vec![0f32; n];
                    bn_forward_eval_into(
                        &mut z,
                        n,
                        &state.params[gi],
                        &state.params[gi + 1],
                        &state.params[gi + 2],
                        &state.params[gi + 3],
                        &mut inv_std,
                    );
                    if layer.pool {
                        let mut pooled = vec![0f32; rows * n / 4];
                        let mut idx = vec![0u32; rows * n / 4];
                        pool::maxpool2x2_into(
                            &z,
                            b,
                            layer.h_in,
                            layer.w_in,
                            n,
                            &mut pooled,
                            &mut idx,
                        );
                        a = pooled;
                    } else {
                        a = z;
                    }
                }
            }
        }
        let (lossv, errv, _) = self.metrics(&a, y);
        Ok((lossv, errv))
    }
}

impl Executor for ReferenceExecutor {
    fn info(&self) -> &ModelInfo {
        &self.info
    }

    fn init_state(&self, hyper: &Hyper) -> Result<TrainState> {
        let mut rng = Rng::new(INIT_SALT ^ hyper.seed as u64);
        let mut params = Vec::with_capacity(self.info.params.len());
        for (i, p) in self.info.params.iter().enumerate() {
            let n = p.numel();
            let t: Vec<f32> = if p.kind == "weight" {
                // Glorot uniform in [-c, c)
                let c = p.glorot as f32;
                let mut r = rng.fork(i as u64);
                (0..n).map(|_| r.range(-c, c)).collect()
            } else if p.name.ends_with(".gamma") || p.name.ends_with(".rvar") {
                vec![1.0; n]
            } else {
                vec![0.0; n]
            };
            params.push(t);
        }
        let m: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
        let v = m.clone();
        Ok(TrainState { params, m, v })
    }

    fn train_step(
        &self,
        state: &mut TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<StepMetrics> {
        if self.fast {
            self.train_step_fast(state, x, y, hyper)
        } else {
            self.train_step_baseline(state, x, y, hyper)
        }
    }

    fn eval_batch(
        &self,
        state: &TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        if self.fast {
            self.eval_batch_fast(state, x, y, hyper)
        } else {
            self.eval_batch_baseline(state, x, y, hyper)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ReferenceExecutor {
        ReferenceExecutor::new(mlp_info("tiny", 6, 5, 1, 3, 4)).unwrap()
    }

    fn tiny_batch(exec: &ReferenceExecutor, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let info = exec.info();
        let x: Vec<f32> =
            (0..info.batch * info.input_dim()).map(|_| rng.normal()).collect();
        let mut y = vec![-1.0f32; info.batch * info.classes];
        for t in 0..info.batch {
            y[t * info.classes + rng.below(info.classes)] = 1.0;
        }
        (x, y)
    }

    #[test]
    fn builtin_registry_resolves() {
        for name in builtin_names() {
            assert!(builtin_info(name).is_some(), "{name} missing");
        }
        assert!(builtin_info("nope").is_none());
        let exec = ReferenceExecutor::builtin("mlp").unwrap();
        assert_eq!(exec.info().params.len(), 3 * 5 + 2);
        assert_eq!(exec.info().input_dim(), 784);
    }

    #[test]
    fn unsupported_spec_error_enumerates_trainable_builtins() {
        // a weight that is neither [in, out] nor [kh, kw, cin, cout]
        let mut info = mlp_info("odd", 6, 5, 1, 3, 4);
        info.params[0].shape = vec![2, 3, 5];
        let err = ReferenceExecutor::new(info).unwrap_err().to_string();
        assert!(err.contains("neither dense"), "{err}");
        assert!(err.contains("cifar_cnn"), "error should list builtins: {err}");
        assert!(!err.contains("pjrt"), "stale pjrt hint resurfaced: {err}");
    }

    #[test]
    fn conv_builtins_resolve_and_plan() {
        for name in ["cifar_cnn", "svhn_cnn", "cnn", "cnn_small"] {
            let exec = ReferenceExecutor::builtin(name).unwrap();
            assert!(
                exec.layers.iter().any(|l| matches!(l, Layer::Conv(_))),
                "{name} planned no conv stages"
            );
        }
        let exec = ReferenceExecutor::builtin("cifar_cnn").unwrap();
        assert_eq!(exec.info().input_dim(), 32 * 32 * 3);
    }

    #[test]
    fn spec_matches_python_layout() {
        let info = mlp_info("m", 784, 1024, 3, 10, 200);
        // 3 hidden x (W + 4 bn) + out W + b = 17 tensors, like the manifest
        assert_eq!(info.params.len(), 17);
        assert_eq!(info.params[0].shape, vec![784, 1024]);
        assert_eq!(info.params[0].kind, "weight");
        assert!(info.params.iter().any(|p| p.kind == "bn_stat"));
        let c = info.params[0].glorot;
        assert!((c - (6.0f64 / 1808.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn init_is_seeded_and_bounded() {
        let exec = tiny();
        let a = exec.init_state(&Hyper { seed: 5, ..Default::default() }).unwrap();
        let b = exec.init_state(&Hyper { seed: 5, ..Default::default() }).unwrap();
        let c = exec.init_state(&Hyper { seed: 6, ..Default::default() }).unwrap();
        assert_eq!(a.params[0], b.params[0]);
        assert_ne!(a.params[0], c.params[0]);
        let lim = exec.info().params[0].glorot as f32;
        assert!(a.params[0].iter().all(|v| v.abs() <= lim));
        // gamma ones, beta zeros
        assert!(a.params[1].iter().all(|&v| v == 1.0));
        assert!(a.params[2].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn train_step_overfits_one_batch() {
        let exec = tiny();
        let mut state = exec.init_state(&Hyper::default()).unwrap();
        let (x, y) = tiny_batch(&exec, 3);
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for step in 1..=60 {
            let h = Hyper {
                lr: 0.01,
                mode: Mode::Det,
                opt: Opt::Adam,
                step,
                seed: step,
                ..Default::default()
            };
            let m = exec.train_step(&mut state, &x, &y, &h).unwrap();
            assert!(m.loss.is_finite());
            if step == 1 {
                first = m.loss;
            }
            last = m.loss;
        }
        assert!(last < first * 0.5, "loss {first} -> {last}");
    }

    #[test]
    fn numerical_gradient_check_mode_none() {
        // With Mode::None (no binarization, no clip) and no dropout, the
        // loss is differentiable; central differences must match the
        // analytic gradients the update consumed. Recover the gradient
        // from an SGD step with lr = 1 and lr_scale off.
        let exec = tiny();
        let base = exec.init_state(&Hyper { seed: 11, ..Default::default() }).unwrap();
        let (x, y) = tiny_batch(&exec, 4);
        let hyper = Hyper {
            lr: 0.0,
            mode: Mode::None,
            opt: Opt::Sgd,
            lr_scale: false,
            seed: 1,
            ..Default::default()
        };
        let loss_at = |state: &TrainState| -> f32 {
            let mut s = state.snapshot();
            exec.train_step(&mut s, &x, &y, &hyper).unwrap().loss
        };
        let grad_of = |state: &TrainState| -> TrainState {
            let mut s = state.snapshot();
            let h = Hyper { lr: 1.0, ..hyper.clone() };
            exec.train_step(&mut s, &x, &y, &h).unwrap();
            s
        };
        let stepped = grad_of(&base);
        // spot-check a few coordinates across tensor kinds:
        // l0.W, bn gamma, bn beta, out.W, out.b
        for (pi, ei) in [(0usize, 0usize), (0, 7), (1, 2), (2, 0), (5, 3), (6, 1)] {
            let analytic = base.params[pi][ei] - stepped.params[pi][ei];
            let eps = 3e-3f32;
            let mut plus = base.snapshot();
            plus.params[pi][ei] += eps;
            let mut minus = base.snapshot();
            minus.params[pi][ei] -= eps;
            let numeric = (loss_at(&plus) - loss_at(&minus)) / (2.0 * eps);
            assert!(
                (numeric - analytic).abs() < 0.05 * (1.0f32).max(analytic.abs()),
                "param {pi}[{ei}]: numeric {numeric} vs analytic {analytic}"
            );
        }
    }

    #[test]
    fn det_mode_clips_weights_to_glorot_box() {
        let exec = tiny();
        let mut state = exec.init_state(&Hyper::default()).unwrap();
        let (x, y) = tiny_batch(&exec, 5);
        for step in 1..=20 {
            let h = Hyper {
                lr: 0.1,
                mode: Mode::Det,
                opt: Opt::Sgd,
                step,
                seed: step,
                ..Default::default()
            };
            exec.train_step(&mut state, &x, &y, &h).unwrap();
        }
        for (t, p) in state.params.iter().zip(&exec.info().params) {
            if p.kind == "weight" {
                let lim = p.glorot as f32 + 1e-6;
                assert!(t.iter().all(|v| v.abs() <= lim), "{} escaped clip box", p.name);
            }
        }
    }

    #[test]
    fn bn_running_stats_move_during_training() {
        let exec = tiny();
        let mut state = exec.init_state(&Hyper::default()).unwrap();
        let (x, y) = tiny_batch(&exec, 6);
        let h = Hyper { lr: 0.01, step: 1, seed: 1, ..Default::default() };
        exec.train_step(&mut state, &x, &y, &h).unwrap();
        // rmean (param index 3) left its zero init
        assert!(state.params[3].iter().any(|&v| v != 0.0), "rmean never updated");
    }

    #[test]
    fn eval_ignores_seed_in_det_mode_but_not_stoch() {
        let exec = tiny();
        let state = exec.init_state(&Hyper::default()).unwrap();
        let (x, y) = tiny_batch(&exec, 7);
        let l1 = exec
            .eval_batch(&state, &x, &y, &Hyper { mode: Mode::Det, seed: 1, ..Default::default() })
            .unwrap()
            .0;
        let l2 = exec
            .eval_batch(&state, &x, &y, &Hyper { mode: Mode::Det, seed: 2, ..Default::default() })
            .unwrap()
            .0;
        assert_eq!(l1, l2);
        let s1 = exec
            .eval_batch(&state, &x, &y, &Hyper { mode: Mode::Stoch, seed: 1, ..Default::default() })
            .unwrap()
            .0;
        let s2 = exec
            .eval_batch(&state, &x, &y, &Hyper { mode: Mode::Stoch, seed: 2, ..Default::default() })
            .unwrap()
            .0;
        assert_ne!(s1, s2, "stochastic eval must sample from the seed");
    }

    /// The packed/workspace fast path and the seed-era dense baseline are
    /// the same algorithm up to f32 summation order.
    #[test]
    fn fast_and_baseline_paths_agree() {
        for mode in [Mode::Det, Mode::Stoch, Mode::None] {
            let fast = ReferenceExecutor::new(mlp_info("fb", 70, 33, 2, 5, 8)).unwrap();
            let mut base = ReferenceExecutor::new(mlp_info("fb", 70, 33, 2, 5, 8)).unwrap();
            base.set_fast(false);
            let mut sf = fast.init_state(&Hyper { seed: 3, ..Default::default() }).unwrap();
            let mut sb = sf.snapshot();
            let (x, y) = tiny_batch(&fast, 9);
            for step in 1..=3 {
                let h = Hyper {
                    lr: 0.05,
                    mode,
                    opt: Opt::Nesterov,
                    step,
                    seed: 100 + step,
                    ..Default::default()
                };
                let mf = fast.train_step(&mut sf, &x, &y, &h).unwrap();
                let mb = base.train_step(&mut sb, &x, &y, &h).unwrap();
                assert!(
                    (mf.loss - mb.loss).abs() < 1e-4 * (1.0 + mb.loss.abs()),
                    "{mode:?} step {step}: loss {} vs {}",
                    mf.loss,
                    mb.loss
                );
                // n_err may differ only on an exact logit tie (fp reorder)
                assert!((mf.n_err - mb.n_err).abs() <= 1.0, "{mode:?} step {step}");
            }
            for (pi, (pf, pb)) in sf.params.iter().zip(&sb.params).enumerate() {
                for (j, (a, b)) in pf.iter().zip(pb).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-4 * (1.0 + b.abs()),
                        "{mode:?} param {pi}[{j}]: {a} vs {b}"
                    );
                }
            }
        }
    }

    /// Acceptance gate: after warmup, a train step allocates nothing on
    /// the stepping thread in any mode (workspace + packed kernels +
    /// pool dispatch are all allocation-free).
    #[test]
    fn steady_state_train_step_is_allocation_free() {
        // k = 70 (not a multiple of 64) exercises the ragged bit-word
        // paths; sizes big enough that the GEMMs take the pooled branch.
        let exec = ReferenceExecutor::new(mlp_info("za", 70, 96, 2, 10, 32)).unwrap();
        let mut state = exec.init_state(&Hyper::default()).unwrap();
        let (x, y) = tiny_batch(&exec, 13);
        let mut step = 0u32;
        for mode in [Mode::Det, Mode::Stoch, Mode::None] {
            let mut run = |steps: u32, step: &mut u32| {
                for _ in 0..steps {
                    *step += 1;
                    let h = Hyper {
                        lr: 0.01,
                        mode,
                        opt: Opt::Adam,
                        dropout: 0.1,
                        in_dropout: 0.1,
                        step: *step,
                        seed: *step,
                        ..Default::default()
                    };
                    exec.train_step(&mut state, &x, &y, &h).unwrap();
                }
            };
            run(3, &mut step); // warmup: workspace build + pool spawn
            let before = crate::test_alloc::thread_allocs();
            run(5, &mut step);
            let after = crate::test_alloc::thread_allocs();
            assert_eq!(
                after - before,
                0,
                "steady-state train_step allocated in mode {mode:?}"
            );
        }
    }

    /// The divergence sentinel + skip-step recovery: a poisoned gradient
    /// is detected on both kernel paths, and a skipped step leaves the
    /// whole state (params, m/v slots, BN running stats) bit-identical.
    #[test]
    fn nan_grad_with_skip_leaves_state_bit_identical() {
        for fast in [true, false] {
            let mut exec = tiny();
            exec.set_fast(fast);
            exec.set_faults(Some(Arc::new(FaultPlan::parse("nan_grad@1", 0).unwrap())));
            let mut state = exec.init_state(&Hyper { seed: 2, ..Default::default() }).unwrap();
            let before = state.snapshot();
            let (x, y) = tiny_batch(&exec, 8);
            let h = Hyper {
                lr: 0.05,
                opt: Opt::Adam,
                step: 1,
                seed: 1,
                skip_nonfinite: true,
                ..Default::default()
            };
            let m = exec.train_step(&mut state, &x, &y, &h).unwrap();
            assert!(m.diverged, "fast={fast}: poisoned gradient not detected");
            let bits = |t: &[Vec<f32>]| -> Vec<Vec<u32>> {
                t.iter().map(|v| v.iter().map(|x| x.to_bits()).collect()).collect()
            };
            assert_eq!(bits(&state.params), bits(&before.params), "fast={fast}");
            assert_eq!(bits(&state.m), bits(&before.m), "fast={fast}");
            assert_eq!(bits(&state.v), bits(&before.v), "fast={fast}");
        }
    }

    #[test]
    fn nan_grad_without_skip_poisons_the_update() {
        let mut exec = tiny();
        exec.set_faults(Some(Arc::new(FaultPlan::parse("nan_grad@1", 0).unwrap())));
        let mut state = exec.init_state(&Hyper { seed: 2, ..Default::default() }).unwrap();
        let (x, y) = tiny_batch(&exec, 8);
        let h = Hyper { lr: 0.05, step: 1, seed: 1, ..Default::default() };
        let m = exec.train_step(&mut state, &x, &y, &h).unwrap();
        assert!(m.diverged);
        // without skip-step recovery the NaN reaches the weights
        assert!(
            state.params[0].iter().any(|v| !v.is_finite()),
            "legacy (no-skip) path should have applied the poisoned update"
        );
    }

    #[test]
    fn finite_steps_report_not_diverged() {
        let exec = tiny();
        let mut state = exec.init_state(&Hyper::default()).unwrap();
        let (x, y) = tiny_batch(&exec, 3);
        let h = Hyper { lr: 0.01, step: 1, seed: 1, skip_nonfinite: true, ..Default::default() };
        let m = exec.train_step(&mut state, &x, &y, &h).unwrap();
        assert!(!m.diverged);
        // and the update actually happened
        assert!(state.params[3].iter().any(|&v| v != 0.0), "rmean never updated");
    }

    #[test]
    fn train_step_is_deterministic_for_any_thread_count() {
        // the pool splits rows, never reductions: two identical runs on
        // the same process (whatever BCRUN_THREADS resolved to) and the
        // serial kernels must agree exactly. Cross-thread-count equality
        // is enforced by kernel design (see kernel/gemm.rs tests).
        let exec = ReferenceExecutor::new(mlp_info("dt", 130, 64, 2, 10, 16)).unwrap();
        let mut s1 = exec.init_state(&Hyper { seed: 8, ..Default::default() }).unwrap();
        let mut s2 = s1.snapshot();
        let (x, y) = tiny_batch(&exec, 21);
        let h = Hyper { lr: 0.02, mode: Mode::Det, step: 1, seed: 5, ..Default::default() };
        let m1 = exec.train_step(&mut s1, &x, &y, &h).unwrap();
        let m2 = exec.train_step(&mut s2, &x, &y, &h).unwrap();
        assert_eq!(m1.loss, m2.loss);
        assert_eq!(s1.params[0], s2.params[0]);
    }

    // ------------------------------------------------------------------
    // binary convolution (the im2col-lowered C3 path)
    // ------------------------------------------------------------------

    /// 6x6x2 input, two 3x3 convs (pool after the second), one fc, 3-way
    /// out. Param map: conv0.W=0 (+bn 1..4), conv1.W=5 (+bn 6..9),
    /// fc0.W=10 (+bn 11..14), out.W=15, out.b=16.
    fn tiny_cnn() -> ReferenceExecutor {
        ReferenceExecutor::new(conv_net_info("tc", 6, 2, &[3, 4], &[8], 3, 2)).unwrap()
    }

    #[test]
    fn conv_train_overfits_one_batch() {
        let exec = tiny_cnn();
        let mut state = exec.init_state(&Hyper::default()).unwrap();
        let (x, y) = tiny_batch(&exec, 31);
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for step in 1..=80 {
            let h = Hyper {
                lr: 0.01,
                mode: Mode::Det,
                opt: Opt::Adam,
                step,
                seed: step,
                ..Default::default()
            };
            let m = exec.train_step(&mut state, &x, &y, &h).unwrap();
            assert!(m.loss.is_finite(), "step {step} diverged");
            if step == 1 {
                first = m.loss;
            }
            last = m.loss;
        }
        assert!(last < first * 0.5, "conv loss {first} -> {last}");
    }

    /// The lowered packed conv path and the direct-convolution oracle are
    /// the same algorithm up to f32 summation order — every mode, batch 1
    /// and batch 4, patch_k 18/27 (not multiples of 64).
    #[test]
    fn conv_fast_and_baseline_paths_agree() {
        for batch in [1usize, 4] {
            for mode in [Mode::Det, Mode::Stoch, Mode::None] {
                let mk = || {
                    ReferenceExecutor::new(conv_net_info("fbc", 6, 2, &[3, 4], &[9], 3, batch))
                        .unwrap()
                };
                let fast = mk();
                let mut base = mk();
                base.set_fast(false);
                let mut sf = fast.init_state(&Hyper { seed: 3, ..Default::default() }).unwrap();
                let mut sb = sf.snapshot();
                let (x, y) = tiny_batch(&fast, 9);
                for step in 1..=3 {
                    let h = Hyper {
                        lr: 0.05,
                        mode,
                        opt: Opt::Nesterov,
                        dropout: 0.1,
                        in_dropout: 0.1,
                        step,
                        seed: 100 + step,
                        ..Default::default()
                    };
                    let mf = fast.train_step(&mut sf, &x, &y, &h).unwrap();
                    let mb = base.train_step(&mut sb, &x, &y, &h).unwrap();
                    assert!(
                        (mf.loss - mb.loss).abs() < 1e-4 * (1.0 + mb.loss.abs()),
                        "b={batch} {mode:?} step {step}: loss {} vs {}",
                        mf.loss,
                        mb.loss
                    );
                    assert!((mf.n_err - mb.n_err).abs() <= 1.0, "b={batch} {mode:?} step {step}");
                }
                for (pi, (pf, pb)) in sf.params.iter().zip(&sb.params).enumerate() {
                    for (j, (a, b)) in pf.iter().zip(pb).enumerate() {
                        assert!(
                            (a - b).abs() < 1e-4 * (1.0 + b.abs()),
                            "b={batch} {mode:?} param {pi}[{j}]: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    /// Batch-64 eval through the packed conv path matches the oracle, with
    /// signed zeros planted in the filter bank (−0.0 must binarize to +H
    /// on both paths).
    #[test]
    fn conv_forward_matches_oracle_at_batch_64() {
        let fast =
            ReferenceExecutor::new(conv_net_info("z64", 4, 2, &[3, 4], &[6], 3, 64)).unwrap();
        let mut base =
            ReferenceExecutor::new(conv_net_info("z64", 4, 2, &[3, 4], &[6], 3, 64)).unwrap();
        base.set_fast(false);
        let mut state = fast.init_state(&Hyper { seed: 17, ..Default::default() }).unwrap();
        state.params[0][0] = -0.0;
        state.params[0][1] = 0.0;
        let (x, y) = tiny_batch(&fast, 40);
        let h = Hyper { mode: Mode::Det, seed: 1, ..Default::default() };
        let (lf, ef) = fast.eval_batch(&state, &x, &y, &h).unwrap();
        let (lb, eb) = base.eval_batch(&state, &x, &y, &h).unwrap();
        for (i, (a, b)) in lf.iter().zip(&lb).enumerate() {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "loss[{i}]: {a} vs {b}");
        }
        let (nf, nb) = (ef.iter().sum::<f32>(), eb.iter().sum::<f32>());
        assert!((nf - nb).abs() <= 1.0, "err {nf} vs {nb}");
    }

    /// Central differences through the whole conv net (Mode::None, no
    /// dropout) — pins im2col/col2im, pool routing and conv BN backward.
    #[test]
    fn conv_numerical_gradient_check_mode_none() {
        let exec = tiny_cnn();
        let base = exec.init_state(&Hyper { seed: 11, ..Default::default() }).unwrap();
        let (x, y) = tiny_batch(&exec, 4);
        let hyper = Hyper {
            lr: 0.0,
            mode: Mode::None,
            opt: Opt::Sgd,
            lr_scale: false,
            seed: 1,
            ..Default::default()
        };
        let loss_at = |state: &TrainState| -> f32 {
            let mut s = state.snapshot();
            exec.train_step(&mut s, &x, &y, &hyper).unwrap().loss
        };
        let grad_of = |state: &TrainState| -> TrainState {
            let mut s = state.snapshot();
            let h = Hyper { lr: 1.0, ..hyper.clone() };
            exec.train_step(&mut s, &x, &y, &h).unwrap();
            s
        };
        let stepped = grad_of(&base);
        // conv0.W, conv0 gamma, conv0 beta, conv1.W, fc0.W, out.W, out.b
        for (pi, ei) in
            [(0usize, 0usize), (0, 13), (1, 2), (2, 0), (5, 3), (10, 1), (15, 0), (16, 1)]
        {
            let analytic = base.params[pi][ei] - stepped.params[pi][ei];
            let eps = 3e-3f32;
            let mut plus = base.snapshot();
            plus.params[pi][ei] += eps;
            let mut minus = base.snapshot();
            minus.params[pi][ei] -= eps;
            let numeric = (loss_at(&plus) - loss_at(&minus)) / (2.0 * eps);
            assert!(
                (numeric - analytic).abs() < 0.05 * (1.0f32).max(analytic.abs()),
                "param {pi}[{ei}]: numeric {numeric} vs analytic {analytic}"
            );
        }
    }

    /// Acceptance gate: the conv train step is allocation-free once the
    /// workspace is warm, in every mode, with both dropouts on.
    #[test]
    fn conv_steady_state_train_step_is_allocation_free() {
        let exec = ReferenceExecutor::new(conv_net_info("zc", 8, 3, &[4, 4], &[16], 5, 4)).unwrap();
        let mut state = exec.init_state(&Hyper::default()).unwrap();
        let (x, y) = tiny_batch(&exec, 13);
        let mut step = 0u32;
        for mode in [Mode::Det, Mode::Stoch, Mode::None] {
            let mut run = |steps: u32, step: &mut u32| {
                for _ in 0..steps {
                    *step += 1;
                    let h = Hyper {
                        lr: 0.01,
                        mode,
                        opt: Opt::Adam,
                        dropout: 0.1,
                        in_dropout: 0.1,
                        step: *step,
                        seed: *step,
                        ..Default::default()
                    };
                    exec.train_step(&mut state, &x, &y, &h).unwrap();
                }
            };
            run(3, &mut step);
            let before = crate::test_alloc::thread_allocs();
            run(5, &mut step);
            let after = crate::test_alloc::thread_allocs();
            assert_eq!(
                after - before,
                0,
                "steady-state conv train_step allocated in mode {mode:?}"
            );
        }
    }

    /// Skip-step recovery holds for conv nets on both kernel paths.
    #[test]
    fn conv_nan_grad_with_skip_leaves_state_bit_identical() {
        for fast in [true, false] {
            let mut exec = tiny_cnn();
            exec.set_fast(fast);
            exec.set_faults(Some(Arc::new(FaultPlan::parse("nan_grad@1", 0).unwrap())));
            let mut state = exec.init_state(&Hyper { seed: 2, ..Default::default() }).unwrap();
            let before = state.snapshot();
            let (x, y) = tiny_batch(&exec, 8);
            let h = Hyper {
                lr: 0.05,
                opt: Opt::Adam,
                step: 1,
                seed: 1,
                skip_nonfinite: true,
                ..Default::default()
            };
            let m = exec.train_step(&mut state, &x, &y, &h).unwrap();
            assert!(m.diverged, "fast={fast}: poisoned conv gradient not detected");
            let bits = |t: &[Vec<f32>]| -> Vec<Vec<u32>> {
                t.iter().map(|v| v.iter().map(|x| x.to_bits()).collect()).collect()
            };
            assert_eq!(bits(&state.params), bits(&before.params), "fast={fast}");
            assert_eq!(bits(&state.m), bits(&before.m), "fast={fast}");
            assert_eq!(bits(&state.v), bits(&before.v), "fast={fast}");
        }
    }

    #[test]
    fn conv_train_step_is_deterministic() {
        let exec = tiny_cnn();
        let mut s1 = exec.init_state(&Hyper { seed: 8, ..Default::default() }).unwrap();
        let mut s2 = s1.snapshot();
        let (x, y) = tiny_batch(&exec, 21);
        let h = Hyper { lr: 0.02, mode: Mode::Stoch, step: 1, seed: 5, ..Default::default() };
        let m1 = exec.train_step(&mut s1, &x, &y, &h).unwrap();
        let m2 = exec.train_step(&mut s2, &x, &y, &h).unwrap();
        assert_eq!(m1.loss, m2.loss);
        assert_eq!(s1.params[0], s2.params[0]);
    }
}
