//! PJRT session: load HLO-text artifacts, compile once, execute many.
//!
//! Gated behind the `pjrt` cargo feature: it needs the offline `xla` crate
//! (see DESIGN.md).  The Python side lowered `init` / `train_step` /
//! `eval_step` per model (python/compile/aot.py); this module owns the
//! PJRT client and adapts the artifacts to the backend-agnostic
//! [`Executor`] trait.  State crosses the trait boundary as flat
//! `Vec<f32>` tensors; CPU PJRT's "device" memory is host memory, so the
//! literal round-trip per step is a memcpy.

use std::path::Path;

use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::util::error::{Context, Result};
use crate::{anyhow, bail};

use super::hyper::Hyper;
use super::manifest::ModelInfo;
use super::{Executor, StepMetrics, TrainState};

/// Shared PJRT client (CPU).
pub struct Runtime {
    pub client: PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("create PJRT CPU client: {e}"))?;
        Ok(Runtime { client })
    }

    fn compile(&self, path: &Path) -> Result<PjRtLoadedExecutable> {
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow!("parse HLO text {}: {e}", path.display()))?;
        self.client
            .compile(&XlaComputation::from_proto(&proto))
            .map_err(|e| anyhow!("compile {}: {e}", path.display()))
    }

    /// Load and compile a model's three artifacts.
    pub fn load_model(&self, info: &ModelInfo) -> Result<Model> {
        Ok(Model {
            info: info.clone(),
            init: self.compile(&info.init_path)?,
            train: self.compile(&info.train_path)?,
            eval: self.compile(&info.eval_path)?,
        })
    }
}

/// A compiled model: init/train/eval executables + metadata.
pub struct Model {
    pub info: ModelInfo,
    init: PjRtLoadedExecutable,
    train: PjRtLoadedExecutable,
    eval: PjRtLoadedExecutable,
}

impl Model {
    fn n(&self) -> usize {
        self.info.params.len()
    }

    fn literal_x(&self, x: &[f32]) -> Result<Literal> {
        let dims: Vec<i64> = self.info.input_shape.iter().map(|&d| d as i64).collect();
        let want: usize = self.info.input_shape.iter().product();
        if x.len() != want {
            bail!("x has {} elements, model expects {}", x.len(), want);
        }
        Literal::vec1(x)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape x literal: {e}"))
    }

    fn literal_y(&self, y: &[f32]) -> Result<Literal> {
        let b = self.info.batch as i64;
        let c = self.info.classes as i64;
        if y.len() != (b * c) as usize {
            bail!("y has {} elements, expected {}", y.len(), b * c);
        }
        Literal::vec1(y)
            .reshape(&[b, c])
            .map_err(|e| anyhow!("reshape y literal: {e}"))
    }

    /// Flat tensor -> shaped literal for param index `i`.
    fn literal_param(&self, i: usize, data: &[f32]) -> Result<Literal> {
        let dims: Vec<i64> = self.info.params[i].shape.iter().map(|&d| d as i64).collect();
        Literal::vec1(data)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape param {}: {e}", self.info.params[i].name))
    }

    fn state_literals(&self, state: &TrainState) -> Result<Vec<Literal>> {
        let n = self.n();
        if state.params.len() != n || state.m.len() != n || state.v.len() != n {
            bail!("state has {} tensors, model expects {}", state.params.len(), n);
        }
        let mut out = Vec::with_capacity(3 * n);
        for group in [&state.params, &state.m, &state.v] {
            for (i, t) in group.iter().enumerate() {
                out.push(self.literal_param(i, t)?);
            }
        }
        Ok(out)
    }

    fn to_vecs(parts: Vec<Literal>) -> Result<Vec<Vec<f32>>> {
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("literal to host: {e}")))
            .collect()
    }
}

impl Executor for Model {
    fn info(&self) -> &ModelInfo {
        &self.info
    }

    /// Run the init artifact -> fresh TrainState.
    fn init_state(&self, hyper: &Hyper) -> Result<TrainState> {
        let hv = Literal::vec1(&hyper.to_vec());
        let out = self
            .init
            .execute::<Literal>(&[hv])
            .map_err(|e| anyhow!("init execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("init fetch: {e}"))?;
        let mut parts = out.to_tuple().map_err(|e| anyhow!("init untuple: {e}"))?;
        let n = self.n();
        if parts.len() != 3 * n {
            bail!("init returned {} tensors, expected {}", parts.len(), 3 * n);
        }
        let v = parts.split_off(2 * n);
        let m = parts.split_off(n);
        Ok(TrainState {
            params: Model::to_vecs(parts)?,
            m: Model::to_vecs(m)?,
            v: Model::to_vecs(v)?,
        })
    }

    /// One Algorithm-1 step: binarized fwd/bwd + clipped real-weight update.
    fn train_step(
        &self,
        state: &mut TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<StepMetrics> {
        let n = self.n();
        let xl = self.literal_x(x)?;
        let yl = self.literal_y(y)?;
        let hv = Literal::vec1(&hyper.to_vec());
        let lits = self.state_literals(state)?;
        let mut args: Vec<&Literal> = Vec::with_capacity(3 * n + 3);
        args.extend(lits.iter());
        args.push(&xl);
        args.push(&yl);
        args.push(&hv);
        let out = self
            .train
            .execute::<&Literal>(&args)
            .map_err(|e| anyhow!("train execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("train fetch: {e}"))?;
        let mut parts = out.to_tuple().map_err(|e| anyhow!("train untuple: {e}"))?;
        if parts.len() != 3 * n + 2 {
            bail!("train returned {} tensors, expected {}", parts.len(), 3 * n + 2);
        }
        let n_err = parts
            .pop()
            .unwrap()
            .to_vec::<f32>()
            .map_err(|e| anyhow!("n_err to host: {e}"))?[0];
        let loss = parts
            .pop()
            .unwrap()
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss to host: {e}"))?[0];
        let v = parts.split_off(2 * n);
        let m = parts.split_off(n);
        state.params = Model::to_vecs(parts)?;
        state.m = Model::to_vecs(m)?;
        state.v = Model::to_vecs(v)?;
        // the PJRT path has no gradient sentinel (the update already ran
        // on device, so `Hyper::skip_nonfinite` cannot be honored here);
        // the scalar loss is still checked so the trainer's divergence
        // accounting and rollback can react
        Ok(StepMetrics { loss, n_err, diverged: !loss.is_finite() })
    }

    /// Evaluate one (padded) batch -> per-example (loss, err) vectors.
    fn eval_batch(
        &self,
        state: &TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let xl = self.literal_x(x)?;
        let yl = self.literal_y(y)?;
        let hv = Literal::vec1(&hyper.to_vec());
        let mut lits = Vec::with_capacity(self.n());
        for (i, t) in state.params.iter().enumerate() {
            lits.push(self.literal_param(i, t)?);
        }
        let mut args: Vec<&Literal> = Vec::with_capacity(self.n() + 3);
        args.extend(lits.iter());
        args.push(&xl);
        args.push(&yl);
        args.push(&hv);
        let out = self
            .eval
            .execute::<&Literal>(&args)
            .map_err(|e| anyhow!("eval execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("eval fetch: {e}"))?;
        let (lossv, errv) = out.to_tuple2().map_err(|e| anyhow!("eval untuple: {e}"))?;
        Ok((
            lossv.to_vec::<f32>().map_err(|e| anyhow!("lossv to host: {e}"))?,
            errv.to_vec::<f32>().map_err(|e| anyhow!("errv to host: {e}"))?,
        ))
    }
}
