//! PJRT session: load HLO-text artifacts, compile once, execute many.
//!
//! The Python side lowered `init` / `train_step` / `eval_step` per model
//! (python/compile/aot.py); this module owns the PJRT client and the
//! training state, feeding params/slots back step after step. CPU PJRT's
//! "device" memory is host memory, so the literal round-trip per step is a
//! memcpy — measured in EXPERIMENTS.md par.Perf.

use std::path::Path;

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::hyper::Hyper;
use super::manifest::ModelInfo;

/// Shared PJRT client (CPU).
pub struct Runtime {
    pub client: PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: PjRtClient::cpu().context("create PJRT CPU client")? })
    }

    fn compile(&self, path: &Path) -> Result<PjRtLoadedExecutable> {
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        self.client
            .compile(&XlaComputation::from_proto(&proto))
            .with_context(|| format!("compile {}", path.display()))
    }

    /// Load and compile a model's three artifacts.
    pub fn load_model(&self, info: &ModelInfo) -> Result<Model> {
        Ok(Model {
            info: info.clone(),
            init: self.compile(&info.init_path)?,
            train: self.compile(&info.train_path)?,
            eval: self.compile(&info.eval_path)?,
        })
    }
}

/// A compiled model: init/train/eval executables + metadata.
pub struct Model {
    pub info: ModelInfo,
    init: PjRtLoadedExecutable,
    train: PjRtLoadedExecutable,
    eval: PjRtLoadedExecutable,
}

/// Training state: flat param and optimizer-slot literals in spec order.
pub struct TrainState {
    pub params: Vec<Literal>,
    pub m: Vec<Literal>,
    pub v: Vec<Literal>,
}

impl TrainState {
    /// Deep-copy (literal data is host memory under CPU PJRT).
    pub fn snapshot(&self) -> Result<TrainState> {
        let copy = |ls: &Vec<Literal>| -> Result<Vec<Literal>> {
            ls.iter()
                .map(|l| {
                    let v = l.to_vec::<f32>()?;
                    let shape = l.array_shape()?;
                    let dims: Vec<i64> = shape.dims().to_vec();
                    Ok(Literal::vec1(&v).reshape(&dims)?)
                })
                .collect()
        };
        Ok(TrainState { params: copy(&self.params)?, m: copy(&self.m)?, v: copy(&self.v)? })
    }

    /// Fetch one param tensor to host (histograms, feature dumps, packing).
    pub fn param_vec(&self, idx: usize) -> Result<Vec<f32>> {
        Ok(self.params[idx].to_vec::<f32>()?)
    }
}

/// Scalar metrics returned by one train step.
#[derive(Clone, Copy, Debug)]
pub struct StepMetrics {
    pub loss: f32,
    pub n_err: f32,
}

impl Model {
    fn n(&self) -> usize {
        self.info.params.len()
    }

    fn literal_x(&self, x: &[f32]) -> Result<Literal> {
        let dims: Vec<i64> = self.info.input_shape.iter().map(|&d| d as i64).collect();
        let want: usize = self.info.input_shape.iter().product();
        if x.len() != want {
            bail!("x has {} elements, model expects {}", x.len(), want);
        }
        Ok(Literal::vec1(x).reshape(&dims)?)
    }

    fn literal_y(&self, y: &[f32]) -> Result<Literal> {
        let b = self.info.batch as i64;
        let c = self.info.classes as i64;
        if y.len() != (b * c) as usize {
            bail!("y has {} elements, expected {}", y.len(), b * c);
        }
        Ok(Literal::vec1(y).reshape(&[b, c])?)
    }

    /// Run the init artifact -> fresh TrainState.
    pub fn init_state(&self, hyper: &Hyper) -> Result<TrainState> {
        let hv = Literal::vec1(&hyper.to_vec());
        let out = self.init.execute::<Literal>(&[hv])?[0][0].to_literal_sync()?;
        let mut parts = out.to_tuple()?;
        let n = self.n();
        if parts.len() != 3 * n {
            bail!("init returned {} tensors, expected {}", parts.len(), 3 * n);
        }
        let v = parts.split_off(2 * n);
        let m = parts.split_off(n);
        Ok(TrainState { params: parts, m, v })
    }

    /// One Algorithm-1 step: binarized fwd/bwd + clipped real-weight update.
    pub fn train_step(
        &self,
        state: &mut TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<StepMetrics> {
        let n = self.n();
        let xl = self.literal_x(x)?;
        let yl = self.literal_y(y)?;
        let hv = Literal::vec1(&hyper.to_vec());
        let mut args: Vec<&Literal> = Vec::with_capacity(3 * n + 3);
        args.extend(state.params.iter());
        args.extend(state.m.iter());
        args.extend(state.v.iter());
        args.push(&xl);
        args.push(&yl);
        args.push(&hv);
        let out = self.train.execute::<&Literal>(&args)?[0][0].to_literal_sync()?;
        let mut parts = out.to_tuple()?;
        if parts.len() != 3 * n + 2 {
            bail!("train returned {} tensors, expected {}", parts.len(), 3 * n + 2);
        }
        let n_err = parts.pop().unwrap().to_vec::<f32>()?[0];
        let loss = parts.pop().unwrap().to_vec::<f32>()?[0];
        let v = parts.split_off(2 * n);
        let m = parts.split_off(n);
        state.params = parts;
        state.m = m;
        state.v = v;
        Ok(StepMetrics { loss, n_err })
    }

    /// Evaluate one (padded) batch -> per-example (loss, err) vectors.
    pub fn eval_batch(
        &self,
        state: &TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let xl = self.literal_x(x)?;
        let yl = self.literal_y(y)?;
        let hv = Literal::vec1(&hyper.to_vec());
        let mut args: Vec<&Literal> = Vec::with_capacity(self.n() + 3);
        args.extend(state.params.iter());
        args.push(&xl);
        args.push(&yl);
        args.push(&hv);
        let out = self.eval.execute::<&Literal>(&args)?[0][0].to_literal_sync()?;
        let (lossv, errv) = out.to_tuple2()?;
        Ok((lossv.to_vec::<f32>()?, errv.to_vec::<f32>()?))
    }
}

#[cfg(test)]
mod tests {
    // Integration tests that need built artifacts live in
    // rust/tests/integration_runtime.rs; unit-testable pieces are covered
    // via manifest/hyper tests.
}
