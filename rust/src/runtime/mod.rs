//! Runtime layer: PJRT client wrapper, artifact manifest, hyper vector.
//!
//! Loads the HLO-text artifacts produced by `make artifacts`
//! (python/compile/aot.py) and executes them from the Rust hot path —
//! Python never runs at request time. Pattern adapted from
//! /opt/xla-example/load_hlo/.

pub mod hyper;
pub mod manifest;
pub mod session;

pub use hyper::{Hyper, Mode, Opt, HYPER_LEN};
pub use manifest::{Manifest, ModelInfo, ParamInfo};
pub use session::{Model, Runtime, StepMetrics, TrainState};
