//! Runtime layer: the backend-pluggable [`Executor`] abstraction, the
//! artifact manifest, the hyper vector, and the backends themselves.
//!
//! Two backends implement [`Executor`]:
//!
//! * [`reference::ReferenceExecutor`] — a pure-Rust f32 implementation of
//!   Algorithm 1 for the paper's MLP (binarize -> forward -> backward via
//!   the straight-through estimator -> clipped SGD/Nesterov/ADAM update).
//!   Always available; the default.
//! * `session::Model` — the PJRT path executing AOT-lowered HLO artifacts
//!   (python/compile/aot.py). Gated behind the `pjrt` cargo feature since
//!   it needs the offline `xla` crate (see DESIGN.md).
//!
//! Tensors cross the trait boundary as flat row-major `Vec<f32>` in spec
//! order — the same wire format the HLO artifacts use — so the trainer,
//! the packed-export path and the tests are backend-agnostic.

pub mod hyper;
pub mod manifest;
pub mod reference;
#[cfg(feature = "pjrt")]
pub mod session;

pub use hyper::{Hyper, Mode, Opt, HYPER_LEN};
pub use manifest::{Manifest, ModelInfo, ParamInfo};
pub use reference::ReferenceExecutor;
#[cfg(feature = "pjrt")]
pub use session::{Model, Runtime};

use crate::util::error::Result;
use crate::{anyhow, bail, ensure};

/// Training state: flat param and optimizer-slot tensors in spec order.
///
/// `m`/`v` are the optimizer slots (zeros where the optimizer does not use
/// them, so every optimizer shares one layout).
#[derive(Clone, Debug, Default)]
pub struct TrainState {
    pub params: Vec<Vec<f32>>,
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
}

impl TrainState {
    /// Deep copy (tensors are plain host vectors).
    pub fn snapshot(&self) -> TrainState {
        self.clone()
    }

    /// Fetch one param tensor (histograms, feature dumps, packing).
    pub fn param_vec(&self, idx: usize) -> Result<Vec<f32>> {
        self.params.get(idx).cloned().ok_or_else(|| {
            anyhow!("param index {idx} out of range ({} tensors)", self.params.len())
        })
    }

    /// Append the state to `buf` in the BCCKPT01 wire layout: `u32`
    /// tensor count, then per tensor `u32` numel followed by numel f32
    /// params, numel f32 `m`, numel f32 `v` — all little-endian raw bits,
    /// so NaN payloads and signed zeros survive and a save/load
    /// round-trip is bit-exact.
    pub fn serialize_into(&self, buf: &mut Vec<u8>) {
        buf.extend_from_slice(&(self.params.len() as u32).to_le_bytes());
        for i in 0..self.params.len() {
            buf.extend_from_slice(&(self.params[i].len() as u32).to_le_bytes());
            for t in [&self.params[i], &self.m[i], &self.v[i]] {
                for x in t.iter() {
                    buf.extend_from_slice(&x.to_bits().to_le_bytes());
                }
            }
        }
    }

    /// Parse a state written by [`TrainState::serialize_into`], consuming
    /// from the front of `r`. Sizes are sanity-capped *before* any
    /// allocation so a corrupt header cannot request gigabytes.
    pub fn deserialize(r: &mut &[u8]) -> Result<TrainState> {
        const MAX_TENSORS: usize = 4096;
        const MAX_NUMEL: usize = 1 << 27; // 512 MiB of f32 per tensor
        let n_tensors = read_u32(r, "tensor count")? as usize;
        ensure!(n_tensors <= MAX_TENSORS, "implausible tensor count {n_tensors}");
        let mut st = TrainState::default();
        for i in 0..n_tensors {
            let numel = read_u32(r, "tensor numel")? as usize;
            ensure!(numel <= MAX_NUMEL, "implausible numel {numel} for tensor {i}");
            ensure!(
                r.len() >= numel * 12,
                "truncated state: tensor {i} needs {} bytes, {} left",
                numel * 12,
                r.len()
            );
            for out in [&mut st.params, &mut st.m, &mut st.v] {
                let mut t = Vec::with_capacity(numel);
                for _ in 0..numel {
                    let mut b = [0u8; 4];
                    b.copy_from_slice(&r[..4]);
                    *r = &r[4..];
                    t.push(f32::from_bits(u32::from_le_bytes(b)));
                }
                out.push(t);
            }
        }
        Ok(st)
    }

    /// Shape/layer-chain validation against a model spec: the tensor
    /// count and every numel must match the spec order exactly, and the
    /// optimizer slots must mirror the params.
    pub fn validate_against(&self, info: &ModelInfo) -> Result<()> {
        ensure!(
            self.params.len() == info.params.len(),
            "state has {} tensors, model '{}' expects {}",
            self.params.len(),
            info.name,
            info.params.len()
        );
        ensure!(
            self.m.len() == self.params.len() && self.v.len() == self.params.len(),
            "optimizer slots do not mirror the params ({} params, {} m, {} v)",
            self.params.len(),
            self.m.len(),
            self.v.len()
        );
        for (i, p) in info.params.iter().enumerate() {
            let want: usize = p.shape.iter().product();
            for (which, t) in [("param", &self.params[i]), ("m", &self.m[i]), ("v", &self.v[i])] {
                ensure!(
                    t.len() == want,
                    "{which} tensor {i} ('{}') has {} elements, spec shape {:?} needs {want}",
                    p.name,
                    t.len(),
                    p.shape
                );
            }
        }
        Ok(())
    }
}

fn read_u32(r: &mut &[u8], what: &str) -> Result<u32> {
    if r.len() < 4 {
        bail!("truncated state: missing {what}");
    }
    let mut b = [0u8; 4];
    b.copy_from_slice(&r[..4]);
    *r = &r[4..];
    Ok(u32::from_le_bytes(b))
}

/// Scalar metrics returned by one train step.
#[derive(Clone, Copy, Debug)]
pub struct StepMetrics {
    /// mean squared-hinge loss over the batch.
    pub loss: f32,
    /// number of misclassified examples in the batch.
    pub n_err: f32,
    /// the divergence sentinel saw a non-finite loss or gradient this
    /// step; if `Hyper::skip_nonfinite` was set the update was skipped
    /// and the state is unchanged.
    pub diverged: bool,
}

/// A training/eval backend: load -> init -> train_step -> eval_step over
/// flat `Vec<f32>` tensors.
///
/// One `Executor` owns one compiled/validated model; the coordinator drives
/// it without knowing which engine is underneath.
pub trait Executor {
    /// The model's spec (param shapes/kinds, batch, classes, input shape).
    fn info(&self) -> &ModelInfo;

    /// Fresh state: initialized params, zeroed optimizer slots.
    fn init_state(&self, hyper: &Hyper) -> Result<TrainState>;

    /// One Algorithm-1 step: binarized fwd/bwd + clipped real-weight update.
    fn train_step(
        &self,
        state: &mut TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<StepMetrics>;

    /// Evaluate one (padded) batch -> per-example (loss, err) vectors.
    fn eval_batch(
        &self,
        state: &TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<(Vec<f32>, Vec<f32>)>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn train_state_param_vec_bounds() {
        let s = TrainState {
            params: vec![vec![1.0, 2.0]],
            m: vec![vec![0.0; 2]],
            v: vec![vec![0.0; 2]],
        };
        assert_eq!(s.param_vec(0).unwrap(), vec![1.0, 2.0]);
        assert!(s.param_vec(1).is_err());
    }

    #[test]
    fn snapshot_is_independent() {
        let mut s = TrainState {
            params: vec![vec![1.0]],
            m: vec![vec![0.0]],
            v: vec![vec![0.0]],
        };
        let snap = s.snapshot();
        s.params[0][0] = 9.0;
        assert_eq!(snap.params[0][0], 1.0);
    }

    #[test]
    fn state_serde_is_bit_exact_including_specials() {
        let s = TrainState {
            params: vec![vec![1.5, -0.0, f32::NAN], vec![f32::INFINITY]],
            m: vec![vec![0.25, 2.0, -3.5], vec![f32::NEG_INFINITY]],
            v: vec![vec![1e-30, -1e30, 0.0], vec![f32::MIN_POSITIVE]],
        };
        let mut buf = vec![];
        s.serialize_into(&mut buf);
        let mut r = &buf[..];
        let back = TrainState::deserialize(&mut r).unwrap();
        assert!(r.is_empty(), "nothing should be left over");
        let bits = |t: &[Vec<f32>]| -> Vec<Vec<u32>> {
            t.iter().map(|v| v.iter().map(|x| x.to_bits()).collect()).collect()
        };
        assert_eq!(bits(&s.params), bits(&back.params));
        assert_eq!(bits(&s.m), bits(&back.m));
        assert_eq!(bits(&s.v), bits(&back.v));
    }

    #[test]
    fn state_deserialize_rejects_truncation_and_implausible_sizes() {
        let s = TrainState {
            params: vec![vec![1.0, 2.0]],
            m: vec![vec![0.0; 2]],
            v: vec![vec![0.0; 2]],
        };
        let mut buf = vec![];
        s.serialize_into(&mut buf);
        for cut in 0..buf.len() {
            let mut r = &buf[..cut];
            assert!(TrainState::deserialize(&mut r).is_err(), "cut {cut} accepted");
        }
        // a header claiming 2^31 tensors must fail before allocating
        let mut r: &[u8] = &0x8000_0000u32.to_le_bytes()[..];
        assert!(TrainState::deserialize(&mut r).is_err());
    }
}
