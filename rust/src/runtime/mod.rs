//! Runtime layer: the backend-pluggable [`Executor`] abstraction, the
//! artifact manifest, the hyper vector, and the backends themselves.
//!
//! Two backends implement [`Executor`]:
//!
//! * [`reference::ReferenceExecutor`] — a pure-Rust f32 implementation of
//!   Algorithm 1 for the paper's MLP (binarize -> forward -> backward via
//!   the straight-through estimator -> clipped SGD/Nesterov/ADAM update).
//!   Always available; the default.
//! * `session::Model` — the PJRT path executing AOT-lowered HLO artifacts
//!   (python/compile/aot.py). Gated behind the `pjrt` cargo feature since
//!   it needs the offline `xla` crate (see DESIGN.md).
//!
//! Tensors cross the trait boundary as flat row-major `Vec<f32>` in spec
//! order — the same wire format the HLO artifacts use — so the trainer,
//! the packed-export path and the tests are backend-agnostic.

pub mod hyper;
pub mod manifest;
pub mod reference;
#[cfg(feature = "pjrt")]
pub mod session;

pub use hyper::{Hyper, Mode, Opt, HYPER_LEN};
pub use manifest::{Manifest, ModelInfo, ParamInfo};
pub use reference::ReferenceExecutor;
#[cfg(feature = "pjrt")]
pub use session::{Model, Runtime};

use crate::anyhow;
use crate::util::error::Result;

/// Training state: flat param and optimizer-slot tensors in spec order.
///
/// `m`/`v` are the optimizer slots (zeros where the optimizer does not use
/// them, so every optimizer shares one layout).
#[derive(Clone, Debug, Default)]
pub struct TrainState {
    pub params: Vec<Vec<f32>>,
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
}

impl TrainState {
    /// Deep copy (tensors are plain host vectors).
    pub fn snapshot(&self) -> TrainState {
        self.clone()
    }

    /// Fetch one param tensor (histograms, feature dumps, packing).
    pub fn param_vec(&self, idx: usize) -> Result<Vec<f32>> {
        self.params.get(idx).cloned().ok_or_else(|| {
            anyhow!("param index {idx} out of range ({} tensors)", self.params.len())
        })
    }
}

/// Scalar metrics returned by one train step.
#[derive(Clone, Copy, Debug)]
pub struct StepMetrics {
    /// mean squared-hinge loss over the batch.
    pub loss: f32,
    /// number of misclassified examples in the batch.
    pub n_err: f32,
}

/// A training/eval backend: load -> init -> train_step -> eval_step over
/// flat `Vec<f32>` tensors.
///
/// One `Executor` owns one compiled/validated model; the coordinator drives
/// it without knowing which engine is underneath.
pub trait Executor {
    /// The model's spec (param shapes/kinds, batch, classes, input shape).
    fn info(&self) -> &ModelInfo;

    /// Fresh state: initialized params, zeroed optimizer slots.
    fn init_state(&self, hyper: &Hyper) -> Result<TrainState>;

    /// One Algorithm-1 step: binarized fwd/bwd + clipped real-weight update.
    fn train_step(
        &self,
        state: &mut TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<StepMetrics>;

    /// Evaluate one (padded) batch -> per-example (loss, err) vectors.
    fn eval_batch(
        &self,
        state: &TrainState,
        x: &[f32],
        y: &[f32],
        hyper: &Hyper,
    ) -> Result<(Vec<f32>, Vec<f32>)>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn train_state_param_vec_bounds() {
        let s = TrainState {
            params: vec![vec![1.0, 2.0]],
            m: vec![vec![0.0; 2]],
            v: vec![vec![0.0; 2]],
        };
        assert_eq!(s.param_vec(0).unwrap(), vec![1.0, 2.0]);
        assert!(s.param_vec(1).is_err());
    }

    #[test]
    fn snapshot_is_independent() {
        let mut s = TrainState {
            params: vec![vec![1.0]],
            m: vec![vec![0.0]],
            v: vec![vec![0.0]],
        };
        let snap = s.snapshot();
        s.params[0][0] = 9.0;
        assert_eq!(snap.params[0][0], 1.0);
    }
}
