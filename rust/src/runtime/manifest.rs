//! Parse `artifacts/manifest.json` — the contract between the Python
//! compile path (python/compile/aot.py) and this runtime.

use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};
use crate::util::Json;
use crate::{anyhow, bail};

#[derive(Clone, Debug, PartialEq)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    /// "weight" | "affine" | "bn_stat" (see python/compile/models.py).
    pub kind: String,
    /// Glorot LR-scaling coefficient (0 for non-weights).
    pub glorot: f64,
}

impl ParamInfo {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub batch: usize,
    pub classes: usize,
    pub input_shape: Vec<usize>,
    pub params: Vec<ParamInfo>,
    pub n_scalars: usize,
    pub use_pallas: bool,
    pub init_path: PathBuf,
    pub train_path: PathBuf,
    pub eval_path: PathBuf,
}

impl ModelInfo {
    /// flattened feature dim of one example.
    pub fn input_dim(&self) -> usize {
        self.input_shape[1..].iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub scale: usize,
    pub hyper_len: usize,
    pub models: Vec<ModelInfo>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts` first)", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let hyper_len = j
            .get("hyper")
            .and_then(|h| h.get("len"))
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("manifest: missing hyper.len"))?;
        let scale = j.get("scale").and_then(|v| v.as_usize()).unwrap_or(1);
        let models_obj = j
            .get("models")
            .and_then(|m| m.as_obj())
            .ok_or_else(|| anyhow!("manifest: missing models"))?;
        let mut models = vec![];
        for (name, m) in models_obj {
            let get_usize = |k: &str| {
                m.get(k)
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| anyhow!("manifest: model {name} missing {k}"))
            };
            let params_json = m
                .get("params")
                .and_then(|p| p.as_arr())
                .ok_or_else(|| anyhow!("manifest: model {name} missing params"))?;
            let mut params = vec![];
            for p in params_json {
                params.push(ParamInfo {
                    name: p
                        .get("name")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow!("param missing name"))?
                        .to_string(),
                    shape: p
                        .get("shape")
                        .and_then(|v| v.as_arr())
                        .ok_or_else(|| anyhow!("param missing shape"))?
                        .iter()
                        .map(|x| x.as_usize().unwrap_or(0))
                        .collect(),
                    kind: p
                        .get("kind")
                        .and_then(|v| v.as_str())
                        .unwrap_or("weight")
                        .to_string(),
                    glorot: p.get("glorot").and_then(|v| v.as_f64()).unwrap_or(0.0),
                });
            }
            let arts = m
                .get("artifacts")
                .ok_or_else(|| anyhow!("manifest: model {name} missing artifacts"))?;
            let art = |k: &str| -> Result<PathBuf> {
                Ok(dir.join(
                    arts.get(k)
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow!("model {name} missing artifact {k}"))?,
                ))
            };
            let input_shape: Vec<usize> = m
                .get("input_shape")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("model {name} missing input_shape"))?
                .iter()
                .map(|x| x.as_usize().unwrap_or(0))
                .collect();
            let n_tensors = get_usize("n_param_tensors")?;
            if n_tensors != params.len() {
                bail!("model {name}: n_param_tensors {n_tensors} != params {}", params.len());
            }
            models.push(ModelInfo {
                name: name.clone(),
                batch: get_usize("batch")?,
                classes: get_usize("classes")?,
                input_shape,
                params,
                n_scalars: get_usize("n_scalars")?,
                use_pallas: m.get("use_pallas").and_then(|v| v.as_bool()).unwrap_or(true),
                init_path: art("init")?,
                train_path: art("train")?,
                eval_path: art("eval")?,
            });
        }
        Ok(Manifest { scale, hyper_len, models, dir: dir.to_path_buf() })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| {
                let names: Vec<&str> = self.models.iter().map(|m| m.name.as_str()).collect();
                anyhow!("model '{name}' not in manifest (have: {})", names.join(", "))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1, "scale": 1,
      "hyper": {"len": 16, "lr": 0},
      "models": {
        "m": {
          "batch": 4, "classes": 10, "input_shape": [4, 8],
          "n_param_tensors": 2, "n_scalars": 90, "use_pallas": true,
          "params": [
            {"name": "l0.W", "shape": [8, 10], "kind": "weight", "glorot": 0.5},
            {"name": "out.b", "shape": [10], "kind": "affine", "glorot": 0.0}
          ],
          "artifacts": {"init": "m_init.hlo.txt", "train": "m_train.hlo.txt",
                        "eval": "m_eval.hlo.txt"}
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.hyper_len, 16);
        let model = m.model("m").unwrap();
        assert_eq!(model.batch, 4);
        assert_eq!(model.params.len(), 2);
        assert_eq!(model.params[0].numel(), 80);
        assert_eq!(model.params[0].kind, "weight");
        assert_eq!(model.input_dim(), 8);
        assert!(model.train_path.ends_with("m_train.hlo.txt"));
    }

    #[test]
    fn unknown_model_lists_available() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let err = format!("{}", m.model("nope").unwrap_err());
        assert!(err.contains("have: m"), "{err}");
    }

    #[test]
    fn tensor_count_mismatch_rejected() {
        let bad = SAMPLE.replace("\"n_param_tensors\": 2", "\"n_param_tensors\": 3");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn parses_generated_manifest_if_present() {
        let dir = Path::new("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(m.model("mlp").is_ok());
            let mlp = m.model("mlp").unwrap();
            // 3 hidden x (W + 4 bn) + out W + b
            assert_eq!(mlp.params.len(), 17);
            assert!(mlp.params.iter().any(|p| p.kind == "bn_stat"));
        }
    }
}
