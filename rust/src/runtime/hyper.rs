//! The hyper vector: Rust mirror of python/compile/hyper.py.
//!
//! One f32[16] row carries every per-step scalar knob; the layouts MUST
//! stay in sync (an integration test cross-checks against the manifest).

/// Binarization mode during propagations (paper Sec. 2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// real-valued weights — the "No regularizer" baseline
    None = 0,
    /// Eq. 1 sign binarization
    Det = 1,
    /// Eq. 2 stochastic binarization
    Stoch = 2,
}

impl Mode {
    pub fn parse(s: &str) -> Option<Mode> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "real" | "noreg" => Some(Mode::None),
            "det" | "deterministic" => Some(Mode::Det),
            "stoch" | "stochastic" => Some(Mode::Stoch),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Mode::None => "none",
            Mode::Det => "det",
            Mode::Stoch => "stoch",
        }
    }
}

/// Optimizer selector (paper Sec. 2.5, Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Opt {
    Sgd = 0,
    Nesterov = 1,
    Adam = 2,
}

impl Opt {
    pub fn parse(s: &str) -> Option<Opt> {
        match s.to_ascii_lowercase().as_str() {
            "sgd" => Some(Opt::Sgd),
            "nesterov" | "momentum" => Some(Opt::Nesterov),
            "adam" => Some(Opt::Adam),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Opt::Sgd => "SGD",
            Opt::Nesterov => "Nesterov",
            Opt::Adam => "ADAM",
        }
    }
}

pub const HYPER_LEN: usize = 16;

/// Per-step hyperparameters; `to_vec` produces the HLO input row.
#[derive(Clone, Debug)]
pub struct Hyper {
    pub lr: f32,
    pub mode: Mode,
    pub opt: Opt,
    pub momentum: f32,
    pub beta2: f32,
    pub eps: f32,
    pub dropout: f32,
    pub bn_momentum: f32,
    pub lr_scale: bool,
    pub step: u32,
    pub seed: u32,
    pub in_dropout: f32,
    /// Host-side divergence policy, deliberately NOT part of the f32[16]
    /// row (the python layout stays untouched): when true, a step whose
    /// loss or gradients come out non-finite leaves the state bit-exactly
    /// unchanged and only reports `StepMetrics::diverged`.
    pub skip_nonfinite: bool,
}

impl Default for Hyper {
    fn default() -> Self {
        Self {
            lr: 0.01,
            mode: Mode::Det,
            opt: Opt::Sgd,
            momentum: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            dropout: 0.0,
            bn_momentum: 0.9,
            lr_scale: true,
            step: 1,
            seed: 0,
            in_dropout: 0.0,
            skip_nonfinite: false,
        }
    }
}

impl Hyper {
    pub fn to_vec(&self) -> Vec<f32> {
        let mut v = vec![0f32; HYPER_LEN];
        v[0] = self.lr;
        v[1] = self.mode as i32 as f32;
        v[2] = self.opt as i32 as f32;
        v[3] = self.momentum;
        v[4] = self.beta2;
        v[5] = self.eps;
        v[6] = self.dropout;
        v[7] = self.bn_momentum;
        v[8] = if self.lr_scale { 1.0 } else { 0.0 };
        v[9] = self.step as f32;
        v[10] = self.seed as f32;
        v[11] = self.in_dropout;
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_matches_python_indices() {
        let h = Hyper {
            lr: 0.5,
            mode: Mode::Stoch,
            opt: Opt::Adam,
            momentum: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            dropout: 0.25,
            bn_momentum: 0.95,
            lr_scale: true,
            step: 42,
            seed: 1234,
            in_dropout: 0.2,
            skip_nonfinite: false,
        };
        let v = h.to_vec();
        assert_eq!(v.len(), HYPER_LEN);
        assert_eq!(v[0], 0.5); // lr
        assert_eq!(v[1], 2.0); // mode
        assert_eq!(v[2], 2.0); // opt
        assert_eq!(v[8], 1.0); // lr_scale
        assert_eq!(v[9], 42.0); // step
        assert_eq!(v[10], 1234.0); // seed
        assert_eq!(v[11], 0.2); // in_dropout
    }

    #[test]
    fn parse_labels() {
        assert_eq!(Mode::parse("Det"), Some(Mode::Det));
        assert_eq!(Mode::parse("stochastic"), Some(Mode::Stoch));
        assert_eq!(Mode::parse("none"), Some(Mode::None));
        assert_eq!(Opt::parse("ADAM"), Some(Opt::Adam));
        assert_eq!(Opt::parse("bogus"), None);
    }

    #[test]
    fn skip_nonfinite_is_host_only() {
        // the HLO row must not change: python/compile/hyper.py knows
        // nothing about the divergence policy
        let on = Hyper { skip_nonfinite: true, ..Default::default() };
        assert_eq!(on.to_vec(), Hyper::default().to_vec());
    }

    #[test]
    fn seeds_survive_f32_roundtrip() {
        // f32 is exact through 2^24; the coordinator draws seeds below that.
        for seed in [0u32, 1, 1 << 20, (1 << 24) - 1] {
            let h = Hyper { seed, ..Default::default() };
            assert_eq!(h.to_vec()[10] as u32, seed);
        }
    }
}
