//! Benchmark harness substrate (criterion is not in the offline registry).
//!
//! Provides warmup + timed iterations with mean/p50/p99 reporting, a
//! paper-style table printer used by every `benches/*.rs` target to emit
//! the same rows the paper's tables/figures report, and a machine-readable
//! JSON sink ([`JsonReport`], the `--json <path>` flag) so perf
//! trajectories can be tracked across PRs (`perf_gemm` writes
//! `BENCH_perf.json` with it).

use std::collections::BTreeMap;
use std::path::Path;

use crate::kernel::simd;
use crate::util::{pool, Json, LatencyStats, Timer};

/// Result of timing one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean_s
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured ones.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut stats = LatencyStats::default();
    for _ in 0..iters {
        let t = Timer::start();
        f();
        stats.record(t.elapsed_s());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: stats.mean(),
        p50_s: stats.percentile(50.0),
        p99_s: stats.percentile(99.0),
        min_s: stats.min(),
    }
}

/// Fixed-width table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let sep: String = widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!(" {c:<width$} ", width = w))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Machine-readable bench results accumulator for `--json <path>`.
///
/// Each [`JsonReport::add`] records `{name, mean_s, p50_s, min_s, iters,
/// shape}`; [`JsonReport::metric`] records derived scalars (speedup
/// ratios). [`JsonReport::save`] writes one deterministic JSON object.
#[derive(Default)]
pub struct JsonReport {
    entries: Vec<Json>,
}

impl JsonReport {
    pub fn new() -> JsonReport {
        JsonReport::default()
    }

    /// Record one timed result and its problem shape (e.g. "1024x1024 b=100").
    pub fn add(&mut self, r: &BenchResult, shape: &str) {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(r.name.clone()));
        m.insert("mean_s".to_string(), Json::Num(r.mean_s));
        m.insert("p50_s".to_string(), Json::Num(r.p50_s));
        m.insert("min_s".to_string(), Json::Num(r.min_s));
        m.insert("iters".to_string(), Json::Num(r.iters as f64));
        m.insert("shape".to_string(), Json::Str(shape.to_string()));
        self.entries.push(Json::Obj(m));
    }

    /// Record a derived scalar (e.g. a speedup ratio).
    pub fn metric(&mut self, name: &str, value: f64) {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(name.to_string()));
        m.insert("value".to_string(), Json::Num(value));
        self.entries.push(Json::Obj(m));
    }

    /// Write `{"bench": <bench>, "generated": true, "machine": {...},
    /// "results": [...]}`. The machine block (core count, pool threads,
    /// detected and selected kernel ISA) is what makes `BENCH_perf.json`
    /// entries comparable across hosts.
    pub fn save(&self, bench: &str, path: &Path) -> std::io::Result<()> {
        let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        let mut machine = BTreeMap::new();
        machine.insert("cores".to_string(), Json::Num(cores as f64));
        machine.insert("pool_threads".to_string(), Json::Num(pool::global().n_threads as f64));
        machine.insert("isa_detected".to_string(), Json::Str(simd::detect().name().to_string()));
        machine.insert("isa_selected".to_string(), Json::Str(simd::active().name().to_string()));
        let mut top = BTreeMap::new();
        top.insert("bench".to_string(), Json::Str(bench.to_string()));
        top.insert("generated".to_string(), Json::Bool(true));
        top.insert("machine".to_string(), Json::Obj(machine));
        top.insert("results".to_string(), Json::Arr(self.entries.clone()));
        std::fs::write(path, Json::Obj(top).to_string())
    }
}

/// Format seconds human-readably.
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0;
        let r = bench("t", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(r.iters, 5);
        assert!(r.mean_s >= 0.0);
        assert!(r.p99_s >= r.p50_s || r.p99_s == 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("long-name"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    fn fmt_time_scales() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-6).ends_with("us"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with(" s"));
    }

    #[test]
    #[should_panic]
    fn table_checks_arity() {
        let mut t = Table::new(&["a"]);
        t.row(&["x".into(), "y".into()]);
    }

    #[test]
    fn json_report_roundtrips() {
        let mut rep = JsonReport::new();
        let r = bench("unit", 0, 3, || {
            std::hint::black_box(1 + 1);
        });
        rep.add(&r, "2x2");
        rep.metric("speedup", 4.25);
        let path = std::env::temp_dir()
            .join(format!("bc_bench_json_{}.json", std::process::id()));
        rep.save("perf_test", &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str(), Some("perf_test"));
        assert_eq!(j.get("generated").unwrap().as_bool(), Some(true));
        let machine = j.get("machine").unwrap();
        assert!(machine.get("cores").unwrap().as_usize().unwrap() >= 1);
        assert!(machine.get("pool_threads").unwrap().as_usize().unwrap() >= 1);
        let detected = machine.get("isa_detected").unwrap().as_str().unwrap();
        assert!(["scalar", "sse2", "avx2"].contains(&detected), "{detected}");
        let selected = machine.get("isa_selected").unwrap().as_str().unwrap();
        assert!(["scalar", "sse2", "avx2"].contains(&selected), "{selected}");
        let results = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].get("name").unwrap().as_str(), Some("unit"));
        assert_eq!(results[0].get("iters").unwrap().as_usize(), Some(3));
        assert_eq!(results[0].get("shape").unwrap().as_str(), Some("2x2"));
        assert_eq!(results[1].get("value").unwrap().as_f64(), Some(4.25));
    }
}
