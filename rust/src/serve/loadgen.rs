//! Closed-loop multi-threaded load generator for the serving layer, plus
//! the tiny HTTP/1.1 client it (and the integration tests) drive the
//! server with.
//!
//! Closed loop: each of `concurrency` workers keeps exactly one request
//! in flight on one persistent connection — offered load adapts to the
//! server instead of overrunning it, so the measured throughput is the
//! *sustainable* rate and latency percentiles are honest (no coordinated
//! omission from a blocked open-loop schedule).
//!
//! Retries: with `retries > 0` a ticket that comes back 500/503/504 (or
//! dies in transport — a supervised worker panic closes the connection)
//! is retried with capped exponential backoff plus full jitter, honoring
//! the server's `Retry-After` hint. This is what keeps the CI gate
//! meaningful once the server sheds load or runs under `BCRUN_FAULTS`:
//! shed-and-retry is the *designed* behavior, not a failure — but a row
//! that exhausts its retries still counts against `failed_status`.

use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use crate::util::error::{Context, Result};
use crate::util::{Json, LatencyStats, Rng, Timer};
use crate::{anyhow, bail, ensure};

/// A persistent keep-alive connection speaking just enough HTTP/1.1 for
/// the serving endpoints.
pub struct HttpClient {
    reader: BufReader<TcpStream>,
    line: Vec<u8>,
    /// `Retry-After` (seconds) from the most recent response, if any.
    retry_after: Option<u64>,
}

impl HttpClient {
    pub fn connect(host: &str) -> Result<HttpClient> {
        let stream = TcpStream::connect(host).with_context(|| format!("connect {host}"))?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        stream.set_write_timeout(Some(Duration::from_secs(30)))?;
        Ok(HttpClient {
            reader: BufReader::new(stream),
            line: Vec::with_capacity(256),
            retry_after: None,
        })
    }

    /// One request/response round trip. Returns (status, body).
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<(u16, String)> {
        self.request_with_headers(method, path, body, &[])
    }

    /// Like [`HttpClient::request`] with extra request headers (the
    /// integration tests use this to send `X-Deadline-Ms`).
    pub fn request_with_headers(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
        extra_headers: &[(&str, String)],
    ) -> Result<(u16, String)> {
        let body = body.unwrap_or("");
        let mut head = format!(
            "{method} {path} HTTP/1.1\r\nhost: bcrun\r\ncontent-length: {}\r\n",
            body.len()
        );
        for (name, value) in extra_headers {
            use std::fmt::Write as _;
            let _ = write!(head, "{name}: {value}\r\n");
        }
        head.push_str("\r\n");
        self.retry_after = None;
        let stream = self.reader.get_mut();
        stream.write_all(head.as_bytes())?;
        stream.write_all(body.as_bytes())?;
        stream.flush()?;

        let status_line = self.read_line().context("read status line")?;
        let mut parts = status_line.split_whitespace();
        let status: u16 = match (parts.next(), parts.next()) {
            (Some(v), Some(code)) if v.starts_with("HTTP/1.") => {
                code.parse().map_err(|_| anyhow!("bad status code in '{status_line}'"))?
            }
            _ => bail!("malformed status line '{status_line}'"),
        };
        let mut content_len = 0usize;
        loop {
            let header = self.read_line().context("read header")?;
            if header.is_empty() {
                break;
            }
            if let Some((name, value)) = header.split_once(':') {
                if name.trim().eq_ignore_ascii_case("content-length") {
                    content_len = value
                        .trim()
                        .parse()
                        .map_err(|_| anyhow!("bad content-length '{value}'"))?;
                } else if name.trim().eq_ignore_ascii_case("retry-after") {
                    self.retry_after = value.trim().parse().ok();
                }
            }
        }
        ensure!(content_len <= (64 << 20), "response body implausibly large");
        let mut buf = vec![0u8; content_len];
        self.read_exact_all(&mut buf)?;
        Ok((status, String::from_utf8_lossy(&buf).into_owned()))
    }

    /// `Retry-After` (seconds) from the most recent response, if any.
    pub fn last_retry_after(&self) -> Option<u64> {
        self.retry_after
    }

    fn read_line(&mut self) -> Result<String> {
        self.line.clear();
        loop {
            match self.reader.read_until(b'\n', &mut self.line) {
                Ok(0) => bail!("server closed the connection"),
                Ok(_) if self.line.last() == Some(&b'\n') => {
                    let s = String::from_utf8_lossy(&self.line);
                    return Ok(s.trim_end().to_string());
                }
                Ok(_) => {}
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => bail!("read error: {e}"),
            }
        }
    }

    fn read_exact_all(&mut self, buf: &mut [u8]) -> Result<()> {
        let mut off = 0;
        while off < buf.len() {
            match self.reader.read(&mut buf[off..]) {
                Ok(0) => bail!("server closed mid-body"),
                Ok(n) => off += n,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => bail!("read error: {e}"),
            }
        }
        Ok(())
    }
}

/// Strip the scheme from `http://host:port[/...]` (or accept a bare
/// `host:port`) — the connectable authority.
pub fn host_of(url: &str) -> Result<String> {
    let rest = if let Some(r) = url.strip_prefix("http://") {
        r
    } else if url.starts_with("https://") {
        bail!("https is not supported by the zero-dependency client");
    } else {
        url
    };
    let host = rest.split('/').next().unwrap_or("");
    ensure!(
        host.contains(':'),
        "'{url}': expected host:port (e.g. http://127.0.0.1:7878)"
    );
    Ok(host.to_string())
}

/// Serialize one `/predict` body into a reused buffer.
pub fn predict_body(out: &mut String, row: &[f32]) {
    use std::fmt::Write as _;
    out.clear();
    out.push_str("{\"x\":[");
    for (i, v) in row.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{v}");
    }
    out.push_str("]}");
}

#[derive(Clone, Debug)]
pub struct LoadgenOpts {
    /// `host:port` (see [`host_of`]).
    pub host: String,
    pub concurrency: usize,
    pub requests: usize,
    pub seed: u64,
    /// Retry budget per ticket for transient failures (500/503/504 and
    /// transport errors). 0 = every failure is final — the right setting
    /// for benchmarks, where retries would hide server misbehavior.
    pub retries: usize,
}

/// Aggregated closed-loop run result.
pub struct LoadReport {
    pub sent: usize,
    pub ok: usize,
    /// Responses with a non-2xx status *after* the retry budget.
    pub failed_status: usize,
    /// Transport-level failures (connect/read/write) after retries.
    pub errors: usize,
    /// Total retry attempts across all tickets (backoff waits included
    /// in `elapsed_s`, so retried runs honestly report lower rps).
    pub retries: usize,
    pub elapsed_s: f64,
    pub latency: LatencyStats,
    /// Sampled from the server's final `/stats` (0 when unavailable).
    pub server_mean_batch: f64,
}

impl LoadReport {
    pub fn throughput_rps(&self) -> f64 {
        if self.elapsed_s <= 0.0 {
            return 0.0;
        }
        self.ok as f64 / self.elapsed_s
    }
}

/// What the `/healthz` probe learned about the model's input: its flat
/// width, and whether it is an image (conv front present — payloads
/// should then be pixel-like values in [0, 1] rather than gaussians).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct InputShape {
    pub in_dim: usize,
    pub image: bool,
}

/// Parse a `/healthz` body into an [`InputShape`]. Conv-serving builds
/// report `input_shape: [h, w, c]`; older builds and dense models only
/// report `in_dim`, which stays the fallback. When both are present they
/// must agree — a mismatch means the server is confused, not us.
pub fn parse_input_shape(health: &Json) -> Result<InputShape> {
    let in_dim = health
        .get("in_dim")
        .and_then(Json::as_usize)
        .context("healthz body missing in_dim")?;
    let image = match health.get("input_shape") {
        None => false,
        Some(Json::Arr(dims)) => {
            ensure!(dims.len() == 3, "healthz input_shape must be [h, w, c]");
            let mut flat = 1usize;
            for d in dims {
                let d = d.as_usize().context("healthz input_shape entry not a size")?;
                flat = flat
                    .checked_mul(d)
                    .context("healthz input_shape overflows")?;
            }
            ensure!(
                flat == in_dim,
                "healthz input_shape ({flat}) disagrees with in_dim ({in_dim})"
            );
            true
        }
        Some(_) => bail!("healthz input_shape is not an array"),
    };
    Ok(InputShape { in_dim, image })
}

/// Run a closed-loop load test: probe `/healthz` for the input shape,
/// then hammer `/predict` from `concurrency` persistent connections
/// until `requests` responses have been collected.
pub fn run(opts: &LoadgenOpts) -> Result<LoadReport> {
    ensure!(opts.concurrency >= 1, "--concurrency must be >= 1");
    ensure!(opts.requests >= 1, "--requests must be >= 1");
    // probe: learn the model's input shape (and that the server is up);
    // the probe connection is dropped before the run so it does not
    // occupy one of the server's connection workers during measurement
    let shape = {
        let mut probe = HttpClient::connect(&opts.host)?;
        let (status, health) = probe.request("GET", "/healthz", None)?;
        ensure!(status == 200, "healthz returned {status}: {health}");
        let health = Json::parse(&health).map_err(|e| anyhow!("healthz body: {e}"))?;
        parse_input_shape(&health)?
    };

    let remaining = Arc::new(AtomicUsize::new(opts.requests));
    let barrier = Arc::new(Barrier::new(opts.concurrency));
    let mut joins = Vec::with_capacity(opts.concurrency);
    let t_all = Timer::start();
    for t in 0..opts.concurrency {
        let host = opts.host.clone();
        let remaining = Arc::clone(&remaining);
        let barrier = Arc::clone(&barrier);
        let tseed = opts.seed ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let retries = opts.retries;
        joins.push(std::thread::spawn(move || {
            worker(&host, shape, tseed, retries, &remaining, &barrier)
        }));
    }
    let mut report = LoadReport {
        sent: 0,
        ok: 0,
        failed_status: 0,
        errors: 0,
        retries: 0,
        elapsed_s: 0.0,
        latency: LatencyStats::default(),
        server_mean_batch: 0.0,
    };
    for j in joins {
        let w = j.join().map_err(|_| anyhow!("loadgen worker panicked"))?;
        report.sent += w.sent;
        report.ok += w.ok;
        report.failed_status += w.failed_status;
        report.errors += w.errors;
        report.retries += w.retries;
        report.latency.merge(&w.latency);
    }
    report.elapsed_s = t_all.elapsed_s();
    // fresh connection after the run: every worker connection is closed,
    // so this samples the server's final accounting
    if let Ok(mut probe) = HttpClient::connect(&opts.host) {
        if let Ok((200, stats)) = probe.request("GET", "/stats", None) {
            if let Ok(j) = Json::parse(&stats) {
                report.server_mean_batch =
                    j.get("mean_batch_rows").and_then(Json::as_f64).unwrap_or(0.0);
            }
        }
    }
    Ok(report)
}

struct WorkerReport {
    sent: usize,
    ok: usize,
    failed_status: usize,
    errors: usize,
    retries: usize,
    latency: LatencyStats,
}

/// Backoff before retry attempt `attempt` (1-based): capped exponential
/// with full jitter — `uniform(0, min(5ms·2^attempt, 500ms))` — so
/// concurrent workers that were shed together do not re-arrive together.
/// A server-provided `Retry-After` (whole seconds) raises the floor,
/// itself capped at 2s so a pessimistic hint cannot stall a chaos run.
fn backoff(attempt: usize, retry_after_s: Option<u64>, rng: &mut Rng) -> Duration {
    const BASE_MS: u64 = 5;
    const CAP_MS: u64 = 500;
    const RETRY_AFTER_CAP_MS: u64 = 2_000;
    let exp_ms = BASE_MS
        .saturating_mul(1u64 << attempt.min(10) as u32)
        .min(CAP_MS);
    let mut wait_ms = (rng.uniform_f64() * exp_ms as f64) as u64;
    if let Some(ra) = retry_after_s {
        wait_ms = wait_ms.max(ra.saturating_mul(1_000).min(RETRY_AFTER_CAP_MS));
    }
    Duration::from_millis(wait_ms)
}

fn worker(
    host: &str,
    shape: InputShape,
    seed: u64,
    retries: usize,
    remaining: &AtomicUsize,
    barrier: &Barrier,
) -> WorkerReport {
    let mut rep = WorkerReport {
        sent: 0,
        ok: 0,
        failed_status: 0,
        errors: 0,
        retries: 0,
        latency: LatencyStats::default(),
    };
    let in_dim = shape.in_dim;
    let mut rng = Rng::new(seed);
    // image models get pixel-like uniform [0,1) features (what a real
    // normalized HWC frame looks like); dense models keep gaussians
    let sample = move |rng: &mut Rng| {
        if shape.image {
            rng.uniform_f64() as f32
        } else {
            rng.normal()
        }
    };
    let mut row: Vec<f32> = (0..in_dim).map(|_| sample(&mut rng)).collect();
    let mut body = String::with_capacity(16 + in_dim * 10);
    let mut client = HttpClient::connect(host).ok();
    barrier.wait();
    let mut consecutive_errors = 0usize;
    'tickets: while remaining
        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
        .is_ok()
    {
        rep.sent += 1;
        // vary one feature per request — cheap, defeats trivial caching
        if in_dim > 0 {
            row[rep.sent % in_dim] = sample(&mut rng);
        }
        predict_body(&mut body, &row);
        // one ticket = one row, retried (same row) up to `retries` times
        // on transient outcomes; terminal outcomes advance to the next
        // ticket
        let mut attempt = 0usize;
        loop {
            let c = match &mut client {
                Some(c) => c,
                None => match HttpClient::connect(host) {
                    Ok(c2) => client.insert(c2),
                    Err(_) => {
                        consecutive_errors += 1;
                        if consecutive_errors > 10 {
                            break 'tickets; // server is gone; stop burning tickets
                        }
                        if attempt < retries {
                            attempt += 1;
                            rep.retries += 1;
                            std::thread::sleep(backoff(attempt, None, &mut rng));
                            continue;
                        }
                        rep.errors += 1;
                        break;
                    }
                },
            };
            let t = Timer::start();
            match c.request("POST", "/predict", Some(&body)) {
                Ok((200, _)) => {
                    rep.ok += 1;
                    rep.latency.record(t.elapsed_s());
                    consecutive_errors = 0;
                    break;
                }
                // transient: the server shed (503 admission / 504 queued
                // expiry) or aborted (500, supervised panic) this row —
                // the designed answer is "come back shortly"
                Ok((status, _)) if matches!(status, 500 | 503 | 504) && attempt < retries => {
                    let hint = c.last_retry_after();
                    consecutive_errors = 0;
                    attempt += 1;
                    rep.retries += 1;
                    std::thread::sleep(backoff(attempt, hint, &mut rng));
                }
                Ok((_, _)) => {
                    rep.failed_status += 1;
                    rep.latency.record(t.elapsed_s());
                    consecutive_errors = 0;
                    break;
                }
                Err(_) => {
                    client = None; // the connection is dead; reconnect
                    consecutive_errors += 1;
                    if consecutive_errors > 10 {
                        break 'tickets;
                    }
                    if attempt < retries {
                        attempt += 1;
                        rep.retries += 1;
                        std::thread::sleep(backoff(attempt, None, &mut rng));
                        continue;
                    }
                    rep.errors += 1;
                    break;
                }
            }
        }
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_of_parses_urls() {
        assert_eq!(host_of("http://127.0.0.1:7878").unwrap(), "127.0.0.1:7878");
        assert_eq!(host_of("http://10.0.0.2:80/predict").unwrap(), "10.0.0.2:80");
        assert_eq!(host_of("localhost:9000").unwrap(), "localhost:9000");
        assert!(host_of("https://secure:443").is_err());
        assert!(host_of("http://no-port").is_err());
    }

    #[test]
    fn backoff_is_capped_and_honors_retry_after() {
        let mut rng = Rng::new(42);
        // without a server hint: full jitter under the 500ms cap, even for
        // absurdly deep attempts (the shift is clamped)
        for attempt in 1..=64 {
            let d = backoff(attempt, None, &mut rng);
            assert!(d <= Duration::from_millis(500), "attempt {attempt}: {d:?}");
        }
        // Retry-After raises the floor: 1s hint → at least 1s
        let d = backoff(1, Some(1), &mut rng);
        assert!(d >= Duration::from_secs(1) && d <= Duration::from_secs(2), "{d:?}");
        // ...but a hostile/huge hint is capped at 2s
        let d = backoff(1, Some(600), &mut rng);
        assert_eq!(d, Duration::from_secs(2));
    }

    #[test]
    fn parse_input_shape_reads_conv_and_dense_healthz_bodies() {
        // dense / legacy: only in_dim — gaussian payloads
        let dense = Json::parse(r#"{"status":"ok","in_dim":784}"#).unwrap();
        assert_eq!(
            parse_input_shape(&dense).unwrap(),
            InputShape { in_dim: 784, image: false }
        );
        // conv: input_shape [h,w,c] consistent with in_dim — image payloads
        let conv =
            Json::parse(r#"{"status":"ok","in_dim":3072,"input_shape":[32,32,3]}"#).unwrap();
        assert_eq!(
            parse_input_shape(&conv).unwrap(),
            InputShape { in_dim: 3072, image: true }
        );
        // a server whose shape disagrees with its flat width is broken
        let bad = Json::parse(r#"{"in_dim":100,"input_shape":[32,32,3]}"#).unwrap();
        let err = parse_input_shape(&bad).unwrap_err().to_string();
        assert!(err.contains("disagrees"), "{err}");
        // wrong rank and wrong type are rejected, not guessed at
        let rank = Json::parse(r#"{"in_dim":9,"input_shape":[3,3]}"#).unwrap();
        assert!(parse_input_shape(&rank).is_err());
        let ty = Json::parse(r#"{"in_dim":9,"input_shape":"3x3x1"}"#).unwrap();
        assert!(parse_input_shape(&ty).is_err());
        // missing in_dim entirely: still an error (probe caught a non-bcrun)
        let none = Json::parse(r#"{"status":"ok"}"#).unwrap();
        assert!(parse_input_shape(&none).is_err());
    }

    #[test]
    fn predict_body_round_trips_through_json_exactly() {
        let row = vec![1.5f32, -0.25, 0.1, 3.0, f32::MIN_POSITIVE];
        let mut body = String::new();
        predict_body(&mut body, &row);
        let j = Json::parse(&body).unwrap();
        let xs = j.get("x").unwrap().as_arr().unwrap();
        assert_eq!(xs.len(), row.len());
        for (v, &want) in xs.iter().zip(&row) {
            // shortest-repr f32 display, parsed as f64, cast back: exact
            let got = v.as_f64().unwrap() as f32;
            assert_eq!(got.to_bits(), want.to_bits());
        }
    }
}
