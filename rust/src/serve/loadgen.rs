//! Closed-loop multi-threaded load generator for the serving layer, plus
//! the tiny HTTP/1.1 client it (and the integration tests) drive the
//! server with.
//!
//! Closed loop: each of `concurrency` workers keeps exactly one request
//! in flight on one persistent connection — offered load adapts to the
//! server instead of overrunning it, so the measured throughput is the
//! *sustainable* rate and latency percentiles are honest (no coordinated
//! omission from a blocked open-loop schedule).

use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use crate::util::error::{Context, Result};
use crate::util::{Json, LatencyStats, Rng, Timer};
use crate::{anyhow, bail, ensure};

/// A persistent keep-alive connection speaking just enough HTTP/1.1 for
/// the serving endpoints.
pub struct HttpClient {
    reader: BufReader<TcpStream>,
    line: Vec<u8>,
}

impl HttpClient {
    pub fn connect(host: &str) -> Result<HttpClient> {
        let stream = TcpStream::connect(host).with_context(|| format!("connect {host}"))?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        stream.set_write_timeout(Some(Duration::from_secs(30)))?;
        Ok(HttpClient { reader: BufReader::new(stream), line: Vec::with_capacity(256) })
    }

    /// One request/response round trip. Returns (status, body).
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<(u16, String)> {
        let body = body.unwrap_or("");
        let head = format!(
            "{method} {path} HTTP/1.1\r\nhost: bcrun\r\ncontent-length: {}\r\n\r\n",
            body.len()
        );
        let stream = self.reader.get_mut();
        stream.write_all(head.as_bytes())?;
        stream.write_all(body.as_bytes())?;
        stream.flush()?;

        let status_line = self.read_line().context("read status line")?;
        let mut parts = status_line.split_whitespace();
        let status: u16 = match (parts.next(), parts.next()) {
            (Some(v), Some(code)) if v.starts_with("HTTP/1.") => {
                code.parse().map_err(|_| anyhow!("bad status code in '{status_line}'"))?
            }
            _ => bail!("malformed status line '{status_line}'"),
        };
        let mut content_len = 0usize;
        loop {
            let header = self.read_line().context("read header")?;
            if header.is_empty() {
                break;
            }
            if let Some((name, value)) = header.split_once(':') {
                if name.trim().eq_ignore_ascii_case("content-length") {
                    content_len = value
                        .trim()
                        .parse()
                        .map_err(|_| anyhow!("bad content-length '{value}'"))?;
                }
            }
        }
        ensure!(content_len <= (64 << 20), "response body implausibly large");
        let mut buf = vec![0u8; content_len];
        self.read_exact_all(&mut buf)?;
        Ok((status, String::from_utf8_lossy(&buf).into_owned()))
    }

    fn read_line(&mut self) -> Result<String> {
        self.line.clear();
        loop {
            match self.reader.read_until(b'\n', &mut self.line) {
                Ok(0) => bail!("server closed the connection"),
                Ok(_) if self.line.last() == Some(&b'\n') => {
                    let s = String::from_utf8_lossy(&self.line);
                    return Ok(s.trim_end().to_string());
                }
                Ok(_) => {}
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => bail!("read error: {e}"),
            }
        }
    }

    fn read_exact_all(&mut self, buf: &mut [u8]) -> Result<()> {
        let mut off = 0;
        while off < buf.len() {
            match self.reader.read(&mut buf[off..]) {
                Ok(0) => bail!("server closed mid-body"),
                Ok(n) => off += n,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => bail!("read error: {e}"),
            }
        }
        Ok(())
    }
}

/// Strip the scheme from `http://host:port[/...]` (or accept a bare
/// `host:port`) — the connectable authority.
pub fn host_of(url: &str) -> Result<String> {
    let rest = if let Some(r) = url.strip_prefix("http://") {
        r
    } else if url.starts_with("https://") {
        bail!("https is not supported by the zero-dependency client");
    } else {
        url
    };
    let host = rest.split('/').next().unwrap_or("");
    ensure!(
        host.contains(':'),
        "'{url}': expected host:port (e.g. http://127.0.0.1:7878)"
    );
    Ok(host.to_string())
}

/// Serialize one `/predict` body into a reused buffer.
pub fn predict_body(out: &mut String, row: &[f32]) {
    use std::fmt::Write as _;
    out.clear();
    out.push_str("{\"x\":[");
    for (i, v) in row.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{v}");
    }
    out.push_str("]}");
}

#[derive(Clone, Debug)]
pub struct LoadgenOpts {
    /// `host:port` (see [`host_of`]).
    pub host: String,
    pub concurrency: usize,
    pub requests: usize,
    pub seed: u64,
}

/// Aggregated closed-loop run result.
pub struct LoadReport {
    pub sent: usize,
    pub ok: usize,
    /// Responses with a non-2xx status.
    pub failed_status: usize,
    /// Transport-level failures (connect/read/write).
    pub errors: usize,
    pub elapsed_s: f64,
    pub latency: LatencyStats,
    /// Sampled from the server's final `/stats` (0 when unavailable).
    pub server_mean_batch: f64,
}

impl LoadReport {
    pub fn throughput_rps(&self) -> f64 {
        if self.elapsed_s <= 0.0 {
            return 0.0;
        }
        self.ok as f64 / self.elapsed_s
    }
}

/// Run a closed-loop load test: probe `/healthz` for the input width,
/// then hammer `/predict` from `concurrency` persistent connections
/// until `requests` responses have been collected.
pub fn run(opts: &LoadgenOpts) -> Result<LoadReport> {
    ensure!(opts.concurrency >= 1, "--concurrency must be >= 1");
    ensure!(opts.requests >= 1, "--requests must be >= 1");
    // probe: learn the model's input width (and that the server is up);
    // the probe connection is dropped before the run so it does not
    // occupy one of the server's connection workers during measurement
    let in_dim = {
        let mut probe = HttpClient::connect(&opts.host)?;
        let (status, health) = probe.request("GET", "/healthz", None)?;
        ensure!(status == 200, "healthz returned {status}: {health}");
        let health = Json::parse(&health).map_err(|e| anyhow!("healthz body: {e}"))?;
        health
            .get("in_dim")
            .and_then(Json::as_usize)
            .context("healthz body missing in_dim")?
    };

    let remaining = Arc::new(AtomicUsize::new(opts.requests));
    let barrier = Arc::new(Barrier::new(opts.concurrency));
    let mut joins = Vec::with_capacity(opts.concurrency);
    let t_all = Timer::start();
    for t in 0..opts.concurrency {
        let host = opts.host.clone();
        let remaining = Arc::clone(&remaining);
        let barrier = Arc::clone(&barrier);
        let tseed = opts.seed ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        joins.push(std::thread::spawn(move || {
            worker(&host, in_dim, tseed, &remaining, &barrier)
        }));
    }
    let mut report = LoadReport {
        sent: 0,
        ok: 0,
        failed_status: 0,
        errors: 0,
        elapsed_s: 0.0,
        latency: LatencyStats::default(),
        server_mean_batch: 0.0,
    };
    for j in joins {
        let w = j.join().map_err(|_| anyhow!("loadgen worker panicked"))?;
        report.sent += w.sent;
        report.ok += w.ok;
        report.failed_status += w.failed_status;
        report.errors += w.errors;
        report.latency.merge(&w.latency);
    }
    report.elapsed_s = t_all.elapsed_s();
    // fresh connection after the run: every worker connection is closed,
    // so this samples the server's final accounting
    if let Ok(mut probe) = HttpClient::connect(&opts.host) {
        if let Ok((200, stats)) = probe.request("GET", "/stats", None) {
            if let Ok(j) = Json::parse(&stats) {
                report.server_mean_batch =
                    j.get("mean_batch_rows").and_then(Json::as_f64).unwrap_or(0.0);
            }
        }
    }
    Ok(report)
}

struct WorkerReport {
    sent: usize,
    ok: usize,
    failed_status: usize,
    errors: usize,
    latency: LatencyStats,
}

fn worker(
    host: &str,
    in_dim: usize,
    seed: u64,
    remaining: &AtomicUsize,
    barrier: &Barrier,
) -> WorkerReport {
    let mut rep = WorkerReport {
        sent: 0,
        ok: 0,
        failed_status: 0,
        errors: 0,
        latency: LatencyStats::default(),
    };
    let mut rng = Rng::new(seed);
    let mut row: Vec<f32> = (0..in_dim).map(|_| rng.normal()).collect();
    let mut body = String::with_capacity(16 + in_dim * 10);
    let mut client = HttpClient::connect(host).ok();
    barrier.wait();
    let mut consecutive_errors = 0usize;
    while remaining
        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
        .is_ok()
    {
        rep.sent += 1;
        // vary one feature per request — cheap, defeats trivial caching
        if in_dim > 0 {
            row[rep.sent % in_dim] = rng.normal();
        }
        predict_body(&mut body, &row);
        if client.is_none() {
            match HttpClient::connect(host) {
                Ok(c2) => client = Some(c2),
                Err(_) => {
                    rep.errors += 1;
                    consecutive_errors += 1;
                    if consecutive_errors > 10 {
                        return rep; // server is gone; stop burning tickets
                    }
                    continue;
                }
            }
        }
        let c = client.as_mut().unwrap();
        let t = Timer::start();
        match c.request("POST", "/predict", Some(&body)) {
            Ok((200, _)) => {
                rep.ok += 1;
                rep.latency.record(t.elapsed_s());
                consecutive_errors = 0;
            }
            Ok((_, _)) => {
                rep.failed_status += 1;
                rep.latency.record(t.elapsed_s());
                consecutive_errors = 0;
            }
            Err(_) => {
                rep.errors += 1;
                consecutive_errors += 1;
                client = None; // reconnect on the next ticket
                if consecutive_errors > 10 {
                    return rep;
                }
            }
        }
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_of_parses_urls() {
        assert_eq!(host_of("http://127.0.0.1:7878").unwrap(), "127.0.0.1:7878");
        assert_eq!(host_of("http://10.0.0.2:80/predict").unwrap(), "10.0.0.2:80");
        assert_eq!(host_of("localhost:9000").unwrap(), "localhost:9000");
        assert!(host_of("https://secure:443").is_err());
        assert!(host_of("http://no-port").is_err());
    }

    #[test]
    fn predict_body_round_trips_through_json_exactly() {
        let row = vec![1.5f32, -0.25, 0.1, 3.0, f32::MIN_POSITIVE];
        let mut body = String::new();
        predict_body(&mut body, &row);
        let j = Json::parse(&body).unwrap();
        let xs = j.get("x").unwrap().as_arr().unwrap();
        assert_eq!(xs.len(), row.len());
        for (v, &want) in xs.iter().zip(&row) {
            // shortest-repr f32 display, parsed as f64, cast back: exact
            let got = v.as_f64().unwrap() as f32;
            assert_eq!(got.to_bits(), want.to_bits());
        }
    }
}
