//! Dynamic micro-batching: coalesce concurrent single-row requests into
//! one lane-batched `PackedMlp` forward.
//!
//! Why: the packed sign-GEMM amortizes its bit-decode over batch columns
//! (SIMD lanes *are* batch columns — `kernel/simd`), so 16 rows in one
//! forward cost far less than 16 solo forwards. An online server sees
//! single rows; this queue turns concurrency into batch width.
//!
//! Contract:
//! * **Window.** The batcher sleeps until a first row arrives, then
//!   collects up to `max_batch` rows or until `max_wait` elapses,
//!   whichever is first. `max_wait == 0` disables coalescing-by-waiting
//!   (whatever is already queued still rides one forward).
//! * **Exactness.** Every forward goes through
//!   [`PackedMlp::forward_into`] — or, in [`ForwardMode::Bnn`],
//!   [`PackedMlp::forward_bnn_into`] — both of which guarantee that a
//!   row's logits are bit-identical whether it was served solo or inside
//!   any coalesced batch (tested here per mode and end-to-end over HTTP
//!   in `tests/integration_serve.rs`).
//! * **Backpressure.** The queue is bounded (`queue_cap` rows);
//!   [`BatchQueue::submit`] fails instead of blocking when full, and the
//!   HTTP layer maps that to 503 + Retry-After.
//! * **Drain.** [`Batcher::stop`] processes every queued row before the
//!   thread exits — a request that was accepted is always answered.
//! * **Allocation.** The slab, workspace and job vector are reused; the
//!   per-batch forward is allocation-free (`PackedWorkspace` contract).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::SyncSender;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::binary::packed::{argmax, PackedMlp, PackedWorkspace};
use crate::binary::{BnnWorkspace, ForwardMode};

use super::metrics::Metrics;

/// One queued row: the input and the channel its reply goes back on.
pub struct Job {
    /// One input row, `in_dim` long (validated by the submitter).
    pub x: Vec<f32>,
    pub reply: SyncSender<Reply>,
}

/// The per-row result of a batched forward.
#[derive(Clone, Debug)]
pub struct Reply {
    pub logits: Vec<f32>,
    pub pred: usize,
    /// How many rows shared the forward (1 = served solo).
    pub batch_rows: usize,
}

/// Batching knobs (`bcrun serve --max-batch --max-wait-us --queue-cap
/// --bnn`).
#[derive(Clone, Debug)]
pub struct BatchConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_cap: usize,
    /// Which forward engine the batcher thread owns a workspace for.
    pub mode: ForwardMode,
}

/// The batcher thread's workspace, matching its configured mode.
enum ModeWorkspace {
    F32(PackedWorkspace),
    Bnn(BnnWorkspace),
}

struct Shared {
    q: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: AtomicBool,
    cap: usize,
}

/// Cloneable submit handle onto the bounded row queue.
#[derive(Clone)]
pub struct BatchQueue {
    shared: Arc<Shared>,
}

impl BatchQueue {
    pub fn bounded(cap: usize) -> BatchQueue {
        BatchQueue {
            shared: Arc::new(Shared {
                q: Mutex::new(VecDeque::with_capacity(cap.min(4096))),
                cv: Condvar::new(),
                shutdown: AtomicBool::new(false),
                cap: cap.max(1),
            }),
        }
    }

    /// Enqueue one row. Fails (returning the job, no blocking) when the
    /// queue is at capacity or the batcher is shutting down — the
    /// caller's 503.
    pub fn submit(&self, job: Job) -> Result<(), Job> {
        if self.shared.shutdown.load(Ordering::Acquire) {
            return Err(job);
        }
        let mut q = self.shared.q.lock().unwrap();
        if q.len() >= self.shared.cap {
            return Err(job);
        }
        q.push_back(job);
        drop(q);
        self.shared.cv.notify_one();
        Ok(())
    }

    /// Rows currently queued (sampled; for `/stats`).
    pub fn depth(&self) -> usize {
        self.shared.q.lock().unwrap().len()
    }
}

/// The batching thread plus its queue handle.
pub struct Batcher {
    pub queue: BatchQueue,
    join: Option<JoinHandle<()>>,
}

impl Batcher {
    /// Spawn the batching thread over an existing queue (tests pre-seed
    /// the queue before spawning to pin coalescing deterministically).
    pub fn spawn(
        mlp: Arc<PackedMlp>,
        queue: BatchQueue,
        cfg: BatchConfig,
        metrics: Arc<Metrics>,
    ) -> Batcher {
        let shared = Arc::clone(&queue.shared);
        let join = std::thread::Builder::new()
            .name("bc-batcher".into())
            .spawn(move || run_loop(&mlp, &shared, &cfg, &metrics))
            .expect("spawn batcher thread");
        Batcher { queue, join: Some(join) }
    }

    /// Start with a fresh bounded queue.
    pub fn start(mlp: Arc<PackedMlp>, cfg: BatchConfig, metrics: Arc<Metrics>) -> Batcher {
        let queue = BatchQueue::bounded(cfg.queue_cap);
        Batcher::spawn(mlp, queue, cfg, metrics)
    }

    /// Graceful stop: refuse new rows, drain everything queued (each row
    /// still gets its reply), join the thread. Idempotent.
    pub fn stop(&mut self) {
        self.queue.shared.shutdown.store(true, Ordering::Release);
        self.queue.shared.cv.notify_all();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.stop();
    }
}

fn run_loop(mlp: &PackedMlp, shared: &Shared, cfg: &BatchConfig, metrics: &Metrics) {
    let max_batch = cfg.max_batch.max(1);
    let mut ws = match cfg.mode {
        ForwardMode::PackedF32 => ModeWorkspace::F32(mlp.workspace(max_batch)),
        ForwardMode::Bnn => ModeWorkspace::Bnn(mlp.bnn_workspace(max_batch)),
    };
    let mut slab = vec![0f32; max_batch * mlp.in_dim];
    let mut batch: Vec<Job> = Vec::with_capacity(max_batch);
    loop {
        {
            let mut q = shared.q.lock().unwrap();
            // sleep until the first row (or shutdown with an empty queue:
            // every accepted row has been answered — done)
            loop {
                if !q.is_empty() {
                    break;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                q = shared.cv.wait(q).unwrap();
            }
            // batching window: collect more rows up to max_batch or until
            // max_wait from *noticing* the first row; shutdown short-
            // circuits the wait so drain is prompt
            if q.len() < max_batch
                && !cfg.max_wait.is_zero()
                && !shared.shutdown.load(Ordering::Acquire)
            {
                let deadline = Instant::now() + cfg.max_wait;
                while q.len() < max_batch && !shared.shutdown.load(Ordering::Acquire) {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (guard, _) = shared.cv.wait_timeout(q, deadline - now).unwrap();
                    q = guard;
                }
            }
            let take = q.len().min(max_batch);
            batch.extend(q.drain(..take));
        }
        // defense in depth: the HTTP layer validates row shape, but a
        // malformed job must cost its own request a 500 (dropped reply
        // channel), never the batcher thread
        batch.retain(|job| job.x.len() == mlp.in_dim);
        let b = batch.len();
        if b == 0 {
            continue;
        }
        for (i, job) in batch.iter().enumerate() {
            slab[i * mlp.in_dim..(i + 1) * mlp.in_dim].copy_from_slice(&job.x);
        }
        let logits = match &mut ws {
            ModeWorkspace::F32(ws) => mlp.forward_into(&slab[..b * mlp.in_dim], b, ws),
            ModeWorkspace::Bnn(ws) => mlp.forward_bnn_into(&slab[..b * mlp.in_dim], b, ws),
        };
        metrics.record_batch(b);
        for (i, job) in batch.drain(..).enumerate() {
            let row = &logits[i * mlp.classes..(i + 1) * mlp.classes];
            let _ = job.reply.send(Reply {
                logits: row.to_vec(),
                pred: argmax(row),
                batch_rows: b,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::sync::mpsc::sync_channel;

    fn toy_mlp() -> Arc<PackedMlp> {
        let mut rng = Rng::new(7);
        let mut mat = |k: usize, n: usize| -> (Vec<f32>, usize, usize) {
            ((0..k * n).map(|_| rng.normal()).collect(), k, n)
        };
        let (w1, w2) = (mat(10, 66), mat(66, 5));
        Arc::new(PackedMlp::build(
            vec![w1, w2],
            vec![
                Some((vec![1.0; 66], vec![0.0; 66], vec![0.1; 66], vec![1.0; 66])),
                None,
            ],
            Some(vec![0.01, -0.01, 0.0, 0.02, 0.03]),
        ))
    }

    fn job(x: Vec<f32>) -> (Job, std::sync::mpsc::Receiver<Reply>) {
        let (tx, rx) = sync_channel(1);
        (Job { x, reply: tx }, rx)
    }

    fn rows(mlp: &PackedMlp, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..mlp.in_dim).map(|_| rng.normal()).collect())
            .collect()
    }

    #[test]
    fn preseeded_queue_coalesces_into_one_batch_bit_equal_to_solo() {
        let mlp = toy_mlp();
        let xs = rows(&mlp, 8, 21);
        // solo references through the same lane-batched path
        let mut ws = mlp.workspace(1);
        let solo: Vec<Vec<f32>> =
            xs.iter().map(|x| mlp.forward_into(x, 1, &mut ws).to_vec()).collect();
        // enqueue everything BEFORE the batcher thread exists: the first
        // drain deterministically takes all 8 rows as one batch
        let queue = BatchQueue::bounded(64);
        let rxs: Vec<_> = xs
            .iter()
            .map(|x| {
                let (j, rx) = job(x.clone());
                queue.submit(j).map_err(|_| ()).unwrap();
                rx
            })
            .collect();
        let metrics = Arc::new(Metrics::new());
        let cfg = BatchConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(50),
            queue_cap: 64,
            mode: ForwardMode::PackedF32,
        };
        let mut batcher = Batcher::spawn(Arc::clone(&mlp), queue, cfg, Arc::clone(&metrics));
        for (i, rx) in rxs.iter().enumerate() {
            let reply = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(reply.batch_rows, 8, "row {i} was not coalesced");
            assert_eq!(reply.logits, solo[i], "row {i}: coalesced != solo bits");
            assert_eq!(reply.pred, argmax(&solo[i]));
        }
        batcher.stop();
        assert_eq!(metrics.batches.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.rows.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn bnn_mode_coalesced_is_bit_equal_to_solo() {
        // the exactness contract must hold for the XNOR engine too: solo
        // bnn forwards through the same path the batcher takes
        let mlp = toy_mlp();
        let xs = rows(&mlp, 8, 24);
        let mut ws = mlp.bnn_workspace(1);
        let solo: Vec<Vec<f32>> =
            xs.iter().map(|x| mlp.forward_bnn_into(x, 1, &mut ws).to_vec()).collect();
        let queue = BatchQueue::bounded(64);
        let rxs: Vec<_> = xs
            .iter()
            .map(|x| {
                let (j, rx) = job(x.clone());
                queue.submit(j).map_err(|_| ()).unwrap();
                rx
            })
            .collect();
        let metrics = Arc::new(Metrics::new());
        let cfg = BatchConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(50),
            queue_cap: 64,
            mode: ForwardMode::Bnn,
        };
        let mut batcher = Batcher::spawn(Arc::clone(&mlp), queue, cfg, Arc::clone(&metrics));
        for (i, rx) in rxs.iter().enumerate() {
            let reply = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(reply.batch_rows, 8, "row {i} was not coalesced");
            assert_eq!(reply.logits, solo[i], "row {i}: bnn coalesced != solo bits");
            assert_eq!(reply.pred, argmax(&solo[i]));
        }
        batcher.stop();
    }

    #[test]
    fn max_batch_splits_a_large_backlog() {
        let mlp = toy_mlp();
        let xs = rows(&mlp, 10, 22);
        let queue = BatchQueue::bounded(64);
        let rxs: Vec<_> = xs
            .iter()
            .map(|x| {
                let (j, rx) = job(x.clone());
                queue.submit(j).map_err(|_| ()).unwrap();
                rx
            })
            .collect();
        let cfg = BatchConfig {
            max_batch: 4,
            max_wait: Duration::ZERO,
            queue_cap: 64,
            mode: ForwardMode::PackedF32,
        };
        let metrics = Arc::new(Metrics::new());
        let mut batcher = Batcher::spawn(Arc::clone(&mlp), queue, cfg, Arc::clone(&metrics));
        let sizes: Vec<usize> = rxs
            .iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(5)).unwrap().batch_rows)
            .collect();
        batcher.stop();
        assert_eq!(sizes, vec![4, 4, 4, 4, 4, 4, 4, 4, 2, 2], "drain order batches");
        assert_eq!(metrics.batches.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn bounded_queue_rejects_when_full() {
        let queue = BatchQueue::bounded(2);
        let (j1, _r1) = job(vec![0.0; 4]);
        let (j2, _r2) = job(vec![0.0; 4]);
        let (j3, _r3) = job(vec![0.0; 4]);
        assert!(queue.submit(j1).is_ok());
        assert!(queue.submit(j2).is_ok());
        assert!(queue.submit(j3).is_err(), "cap 2 must reject the third row");
        assert_eq!(queue.depth(), 2);
    }

    #[test]
    fn stop_drains_every_accepted_row() {
        let mlp = toy_mlp();
        let xs = rows(&mlp, 10, 23);
        let queue = BatchQueue::bounded(64);
        let rxs: Vec<_> = xs
            .iter()
            .map(|x| {
                let (j, rx) = job(x.clone());
                queue.submit(j).map_err(|_| ()).unwrap();
                rx
            })
            .collect();
        // a long window would stall the first batch for a second — stop()
        // must short-circuit it and still answer all 10 rows
        let cfg = BatchConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(1),
            queue_cap: 64,
            mode: ForwardMode::PackedF32,
        };
        let metrics = Arc::new(Metrics::new());
        let t0 = Instant::now();
        let mut batcher = Batcher::spawn(Arc::clone(&mlp), queue.clone(), cfg, metrics);
        batcher.stop();
        for rx in &rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        assert!(t0.elapsed() < Duration::from_secs(4), "drain did not short-circuit");
        // post-shutdown submissions are refused
        let (j, _rx) = job(xs[0].clone());
        assert!(queue.submit(j).is_err());
    }
}
