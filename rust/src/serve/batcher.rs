//! Dynamic micro-batching: coalesce concurrent single-row requests into
//! one lane-batched `PackedMlp` forward.
//!
//! Why: the packed sign-GEMM amortizes its bit-decode over batch columns
//! (SIMD lanes *are* batch columns — `kernel/simd`), so 16 rows in one
//! forward cost far less than 16 solo forwards. An online server sees
//! single rows; this queue turns concurrency into batch width.
//!
//! Contract:
//! * **Window.** The batcher sleeps until a first row arrives, then
//!   collects up to `max_batch` rows or until `max_wait` elapses,
//!   whichever is first. `max_wait == 0` disables coalescing-by-waiting
//!   (whatever is already queued still rides one forward).
//! * **Exactness.** Every forward goes through
//!   [`PackedMlp::forward_into`] — or, in [`ForwardMode::Bnn`],
//!   [`PackedMlp::forward_bnn_into`] — both of which guarantee that a
//!   row's logits are bit-identical whether it was served solo or inside
//!   any coalesced batch (tested here per mode and end-to-end over HTTP
//!   in `tests/integration_serve.rs`).
//! * **Backpressure.** The queue is bounded (`queue_cap` rows);
//!   [`BatchQueue::submit`] fails instead of blocking when full, and the
//!   HTTP layer maps that to 503 + Retry-After.
//! * **Deadlines.** A job may carry an answer-by [`Instant`]; rows whose
//!   deadline passed while queued are shed with [`Verdict::Expired`]
//!   (HTTP 504) *before* the forward — no compute is spent on answers
//!   nobody is waiting for.
//! * **Supervision.** The thread body runs `run_loop` under
//!   `catch_unwind`. A panic (a kernel bug, or `BCRUN_FAULTS` injection)
//!   fails the held rows with [`Verdict::Aborted`] (HTTP 500), bumps
//!   `batcher_restarts`, and re-enters `run_loop`, which rebuilds the
//!   mode workspace from scratch — a half-updated workspace never
//!   serves another row. Every accepted row gets *some* verdict.
//! * **Drain.** [`Batcher::stop`] processes every queued row before the
//!   thread exits — a request that was accepted is always answered.
//! * **Allocation.** The slab, workspace and job vector are reused; the
//!   per-batch forward is allocation-free (`PackedWorkspace` contract).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::SyncSender;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::binary::packed::{argmax, PackedMlp, PackedWorkspace};
use crate::binary::{BnnWorkspace, ForwardMode};
use crate::util::{lock_ok, FaultPlan, Timer};

use super::metrics::Metrics;

/// One queued row: the input, the channel its verdict goes back on, and
/// an optional answer-by deadline.
pub struct Job {
    /// One input row, `in_dim` long (validated by the submitter).
    pub x: Vec<f32>,
    pub reply: SyncSender<Verdict>,
    /// Shed with [`Verdict::Expired`] if still queued past this instant.
    pub deadline: Option<Instant>,
}

/// What became of one accepted row. The batcher promises exactly one
/// verdict per job — computed, shed, or failed, never silence.
#[derive(Clone, Debug)]
pub enum Verdict {
    /// Computed logits (HTTP 200).
    Reply(Reply),
    /// The row's deadline passed before its batch ran (HTTP 504).
    Expired,
    /// The batcher panicked while holding this row, or the row was
    /// malformed (HTTP 500). The forward never ran; retrying is safe.
    Aborted,
}

/// The per-row result of a batched forward.
#[derive(Clone, Debug)]
pub struct Reply {
    pub logits: Vec<f32>,
    pub pred: usize,
    /// How many rows shared the forward (1 = served solo).
    pub batch_rows: usize,
}

/// Batching knobs (`bcrun serve --max-batch --max-wait-us --queue-cap
/// --bnn`).
#[derive(Clone, Debug)]
pub struct BatchConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_cap: usize,
    /// Which forward engine the batcher thread owns a workspace for.
    pub mode: ForwardMode,
    /// Deterministic fault injection (`BCRUN_FAULTS`); `None` in
    /// production — the hot loop then pays one branch.
    pub faults: Option<Arc<FaultPlan>>,
}

/// The batcher thread's workspace, matching its configured mode.
enum ModeWorkspace {
    F32(PackedWorkspace),
    Bnn(BnnWorkspace),
}

struct Shared {
    q: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: AtomicBool,
    cap: usize,
}

/// Cloneable submit handle onto the bounded row queue.
#[derive(Clone)]
pub struct BatchQueue {
    shared: Arc<Shared>,
}

impl BatchQueue {
    pub fn bounded(cap: usize) -> BatchQueue {
        BatchQueue {
            shared: Arc::new(Shared {
                q: Mutex::new(VecDeque::with_capacity(cap.min(4096))),
                cv: Condvar::new(),
                shutdown: AtomicBool::new(false),
                cap: cap.max(1),
            }),
        }
    }

    /// Enqueue one row. Fails (returning the job, no blocking) when the
    /// queue is at capacity or the batcher is shutting down — the
    /// caller's 503.
    pub fn submit(&self, job: Job) -> Result<(), Job> {
        if self.shared.shutdown.load(Ordering::Acquire) {
            return Err(job);
        }
        let mut q = lock_ok(&self.shared.q);
        if q.len() >= self.shared.cap {
            return Err(job);
        }
        q.push_back(job);
        drop(q);
        self.shared.cv.notify_one();
        Ok(())
    }

    /// Rows currently queued (sampled; for `/stats`).
    pub fn depth(&self) -> usize {
        lock_ok(&self.shared.q).len()
    }
}

/// The batching thread plus its queue handle.
pub struct Batcher {
    pub queue: BatchQueue,
    join: Option<JoinHandle<()>>,
}

impl Batcher {
    /// Spawn the batching thread over an existing queue (tests pre-seed
    /// the queue before spawning to pin coalescing deterministically).
    ///
    /// The thread is a supervisor: `run_loop` runs under `catch_unwind`,
    /// and a panic fails the rows the loop held (`batch` lives out here,
    /// across unwinds, precisely so they can be answered), counts a
    /// restart, and re-enters the loop with a freshly built workspace.
    pub fn spawn(
        mlp: Arc<PackedMlp>,
        queue: BatchQueue,
        cfg: BatchConfig,
        metrics: Arc<Metrics>,
    ) -> Batcher {
        let shared = Arc::clone(&queue.shared);
        let join = std::thread::Builder::new()
            .name("bc-batcher".into())
            .spawn(move || {
                let mut batch: Vec<Job> = Vec::with_capacity(cfg.max_batch.max(1));
                loop {
                    let done = catch_unwind(AssertUnwindSafe(|| {
                        run_loop(&mlp, &shared, &cfg, &metrics, &mut batch)
                    }));
                    match done {
                        Ok(()) => return, // graceful shutdown
                        Err(_) => {
                            Metrics::bump(&metrics.batcher_restarts);
                            for job in batch.drain(..) {
                                let _ = job.reply.send(Verdict::Aborted);
                            }
                        }
                    }
                }
            })
            .expect("spawn batcher thread");
        Batcher { queue, join: Some(join) }
    }

    /// Start with a fresh bounded queue.
    pub fn start(mlp: Arc<PackedMlp>, cfg: BatchConfig, metrics: Arc<Metrics>) -> Batcher {
        let queue = BatchQueue::bounded(cfg.queue_cap);
        Batcher::spawn(mlp, queue, cfg, metrics)
    }

    /// Graceful stop: refuse new rows, drain everything queued (each row
    /// still gets its verdict), join the thread. Idempotent.
    pub fn stop(&mut self) {
        self.queue.shared.shutdown.store(true, Ordering::Release);
        self.queue.shared.cv.notify_all();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.stop();
    }
}

fn run_loop(
    mlp: &PackedMlp,
    shared: &Shared,
    cfg: &BatchConfig,
    metrics: &Metrics,
    batch: &mut Vec<Job>,
) {
    let max_batch = cfg.max_batch.max(1);
    // built fresh on every supervised (re)entry: a panic may have left
    // the previous workspace mid-update, and exactness cannot ride on
    // half-written scratch state
    let mut ws = match cfg.mode {
        ForwardMode::PackedF32 => ModeWorkspace::F32(mlp.workspace(max_batch)),
        ForwardMode::Bnn => ModeWorkspace::Bnn(mlp.bnn_workspace(max_batch)),
    };
    let mut slab = vec![0f32; max_batch * mlp.in_dim];
    loop {
        {
            let mut q = lock_ok(&shared.q);
            // sleep until the first row (or shutdown with an empty queue:
            // every accepted row has been answered — done)
            loop {
                if !q.is_empty() {
                    break;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                q = match shared.cv.wait(q) {
                    Ok(g) => g,
                    Err(poisoned) => poisoned.into_inner(),
                };
            }
            // batching window: collect more rows up to max_batch or until
            // max_wait from *noticing* the first row; shutdown short-
            // circuits the wait so drain is prompt
            if q.len() < max_batch
                && !cfg.max_wait.is_zero()
                && !shared.shutdown.load(Ordering::Acquire)
            {
                let deadline = Instant::now() + cfg.max_wait;
                while q.len() < max_batch && !shared.shutdown.load(Ordering::Acquire) {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (guard, _) = match shared.cv.wait_timeout(q, deadline - now) {
                        Ok(pair) => pair,
                        Err(poisoned) => poisoned.into_inner(),
                    };
                    q = guard;
                }
            }
            let take = q.len().min(max_batch);
            batch.extend(q.drain(..take));
        }
        // pre-forward sweep: shed rows whose deadline already passed (504
        // — computing them would be dead work the client stopped waiting
        // for) and abort malformed rows (defense in depth; the HTTP layer
        // validates shape). Either way the row is answered, never dropped.
        let now = Instant::now();
        let mut i = 0;
        while i < batch.len() {
            let malformed = batch[i].x.len() != mlp.in_dim;
            let expired = batch[i].deadline.is_some_and(|d| now >= d);
            if malformed || expired {
                let job = batch.swap_remove(i);
                let verdict = if malformed {
                    Verdict::Aborted
                } else {
                    Metrics::bump(&metrics.deadline_sheds);
                    Verdict::Expired
                };
                let _ = job.reply.send(verdict);
            } else {
                i += 1;
            }
        }
        let b = batch.len();
        if b == 0 {
            continue;
        }
        if let Some(faults) = &cfg.faults {
            // injection sits where a real kernel panic would: rows taken,
            // forward not yet run — the supervisor must answer them
            faults.maybe_panic_batcher();
            if let Some(d) = faults.slow_batch() {
                std::thread::sleep(d);
            }
        }
        for (i, job) in batch.iter().enumerate() {
            slab[i * mlp.in_dim..(i + 1) * mlp.in_dim].copy_from_slice(&job.x);
        }
        let t = Timer::start();
        let logits = match &mut ws {
            ModeWorkspace::F32(ws) => mlp.forward_into(&slab[..b * mlp.in_dim], b, ws),
            ModeWorkspace::Bnn(ws) => mlp.forward_bnn_into(&slab[..b * mlp.in_dim], b, ws),
        };
        metrics.record_forward(t.elapsed_s());
        metrics.record_batch(b);
        for (i, job) in batch.drain(..).enumerate() {
            let row = &logits[i * mlp.classes..(i + 1) * mlp.classes];
            let _ = job.reply.send(Verdict::Reply(Reply {
                logits: row.to_vec(),
                pred: argmax(row),
                batch_rows: b,
            }));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::sync::mpsc::{sync_channel, Receiver};

    fn toy_mlp() -> Arc<PackedMlp> {
        let mut rng = Rng::new(7);
        let mut mat = |k: usize, n: usize| -> (Vec<f32>, usize, usize) {
            ((0..k * n).map(|_| rng.normal()).collect(), k, n)
        };
        let (w1, w2) = (mat(10, 66), mat(66, 5));
        Arc::new(PackedMlp::build(
            vec![w1, w2],
            vec![
                Some((vec![1.0; 66], vec![0.0; 66], vec![0.1; 66], vec![1.0; 66])),
                None,
            ],
            Some(vec![0.01, -0.01, 0.0, 0.02, 0.03]),
        ))
    }

    fn job(x: Vec<f32>) -> (Job, Receiver<Verdict>) {
        let (tx, rx) = sync_channel(1);
        (Job { x, reply: tx, deadline: None }, rx)
    }

    fn job_with_deadline(x: Vec<f32>, deadline: Instant) -> (Job, Receiver<Verdict>) {
        let (tx, rx) = sync_channel(1);
        (Job { x, reply: tx, deadline: Some(deadline) }, rx)
    }

    fn recv_verdict(rx: &Receiver<Verdict>) -> Verdict {
        rx.recv_timeout(Duration::from_secs(5)).expect("job must be answered")
    }

    fn recv_reply(rx: &Receiver<Verdict>) -> Reply {
        match recv_verdict(rx) {
            Verdict::Reply(r) => r,
            other => panic!("expected a computed reply, got {other:?}"),
        }
    }

    fn cfg(max_batch: usize, max_wait: Duration, mode: ForwardMode) -> BatchConfig {
        BatchConfig { max_batch, max_wait, queue_cap: 64, mode, faults: None }
    }

    fn rows(mlp: &PackedMlp, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..mlp.in_dim).map(|_| rng.normal()).collect())
            .collect()
    }

    #[test]
    fn preseeded_queue_coalesces_into_one_batch_bit_equal_to_solo() {
        let mlp = toy_mlp();
        let xs = rows(&mlp, 8, 21);
        // solo references through the same lane-batched path
        let mut ws = mlp.workspace(1);
        let solo: Vec<Vec<f32>> =
            xs.iter().map(|x| mlp.forward_into(x, 1, &mut ws).to_vec()).collect();
        // enqueue everything BEFORE the batcher thread exists: the first
        // drain deterministically takes all 8 rows as one batch
        let queue = BatchQueue::bounded(64);
        let rxs: Vec<_> = xs
            .iter()
            .map(|x| {
                let (j, rx) = job(x.clone());
                queue.submit(j).map_err(|_| ()).unwrap();
                rx
            })
            .collect();
        let metrics = Arc::new(Metrics::new());
        let cfg = cfg(8, Duration::from_millis(50), ForwardMode::PackedF32);
        let mut batcher = Batcher::spawn(Arc::clone(&mlp), queue, cfg, Arc::clone(&metrics));
        for (i, rx) in rxs.iter().enumerate() {
            let reply = recv_reply(rx);
            assert_eq!(reply.batch_rows, 8, "row {i} was not coalesced");
            assert_eq!(reply.logits, solo[i], "row {i}: coalesced != solo bits");
            assert_eq!(reply.pred, argmax(&solo[i]));
        }
        batcher.stop();
        assert_eq!(metrics.batches.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.rows.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn bnn_mode_coalesced_is_bit_equal_to_solo() {
        // the exactness contract must hold for the XNOR engine too: solo
        // bnn forwards through the same path the batcher takes
        let mlp = toy_mlp();
        let xs = rows(&mlp, 8, 24);
        let mut ws = mlp.bnn_workspace(1);
        let solo: Vec<Vec<f32>> =
            xs.iter().map(|x| mlp.forward_bnn_into(x, 1, &mut ws).to_vec()).collect();
        let queue = BatchQueue::bounded(64);
        let rxs: Vec<_> = xs
            .iter()
            .map(|x| {
                let (j, rx) = job(x.clone());
                queue.submit(j).map_err(|_| ()).unwrap();
                rx
            })
            .collect();
        let metrics = Arc::new(Metrics::new());
        let cfg = cfg(8, Duration::from_millis(50), ForwardMode::Bnn);
        let mut batcher = Batcher::spawn(Arc::clone(&mlp), queue, cfg, Arc::clone(&metrics));
        for (i, rx) in rxs.iter().enumerate() {
            let reply = recv_reply(rx);
            assert_eq!(reply.batch_rows, 8, "row {i} was not coalesced");
            assert_eq!(reply.logits, solo[i], "row {i}: bnn coalesced != solo bits");
            assert_eq!(reply.pred, argmax(&solo[i]));
        }
        batcher.stop();
    }

    #[test]
    fn max_batch_splits_a_large_backlog() {
        let mlp = toy_mlp();
        let xs = rows(&mlp, 10, 22);
        let queue = BatchQueue::bounded(64);
        let rxs: Vec<_> = xs
            .iter()
            .map(|x| {
                let (j, rx) = job(x.clone());
                queue.submit(j).map_err(|_| ()).unwrap();
                rx
            })
            .collect();
        let cfg = cfg(4, Duration::ZERO, ForwardMode::PackedF32);
        let metrics = Arc::new(Metrics::new());
        let mut batcher = Batcher::spawn(Arc::clone(&mlp), queue, cfg, Arc::clone(&metrics));
        let sizes: Vec<usize> = rxs.iter().map(|rx| recv_reply(rx).batch_rows).collect();
        batcher.stop();
        assert_eq!(sizes, vec![4, 4, 4, 4, 4, 4, 4, 4, 2, 2], "drain order batches");
        assert_eq!(metrics.batches.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn bounded_queue_rejects_when_full() {
        let queue = BatchQueue::bounded(2);
        let (j1, _r1) = job(vec![0.0; 4]);
        let (j2, _r2) = job(vec![0.0; 4]);
        let (j3, _r3) = job(vec![0.0; 4]);
        assert!(queue.submit(j1).is_ok());
        assert!(queue.submit(j2).is_ok());
        assert!(queue.submit(j3).is_err(), "cap 2 must reject the third row");
        assert_eq!(queue.depth(), 2);
    }

    #[test]
    fn stop_drains_every_accepted_row() {
        let mlp = toy_mlp();
        let xs = rows(&mlp, 10, 23);
        let queue = BatchQueue::bounded(64);
        let rxs: Vec<_> = xs
            .iter()
            .map(|x| {
                let (j, rx) = job(x.clone());
                queue.submit(j).map_err(|_| ()).unwrap();
                rx
            })
            .collect();
        // a long window would stall the first batch for a second — stop()
        // must short-circuit it and still answer all 10 rows
        let cfg = cfg(4, Duration::from_secs(1), ForwardMode::PackedF32);
        let metrics = Arc::new(Metrics::new());
        let t0 = Instant::now();
        let mut batcher = Batcher::spawn(Arc::clone(&mlp), queue.clone(), cfg, metrics);
        batcher.stop();
        for rx in &rxs {
            recv_reply(rx);
        }
        assert!(t0.elapsed() < Duration::from_secs(4), "drain did not short-circuit");
        // post-shutdown submissions are refused
        let (j, _rx) = job(xs[0].clone());
        assert!(queue.submit(j).is_err());
    }

    #[test]
    fn expired_rows_are_shed_and_live_rows_still_served() {
        let mlp = toy_mlp();
        let xs = rows(&mlp, 6, 31);
        let queue = BatchQueue::bounded(64);
        let past = Instant::now() - Duration::from_millis(1);
        let future = Instant::now() + Duration::from_secs(30);
        // interleave expired and live rows in one pre-seeded batch
        let mut expired_rxs = Vec::new();
        let mut live_rxs = Vec::new();
        for (i, x) in xs.iter().enumerate() {
            let (j, rx) =
                job_with_deadline(x.clone(), if i % 2 == 0 { past } else { future });
            queue.submit(j).map_err(|_| ()).unwrap();
            if i % 2 == 0 {
                expired_rxs.push(rx);
            } else {
                live_rxs.push(rx);
            }
        }
        let metrics = Arc::new(Metrics::new());
        let cfg = cfg(6, Duration::from_millis(50), ForwardMode::PackedF32);
        let mut batcher = Batcher::spawn(Arc::clone(&mlp), queue, cfg, Arc::clone(&metrics));
        for rx in &expired_rxs {
            assert!(matches!(recv_verdict(rx), Verdict::Expired));
        }
        for rx in &live_rxs {
            // the 3 survivors ride one forward together
            assert_eq!(recv_reply(rx).batch_rows, 3);
        }
        batcher.stop();
        assert_eq!(metrics.deadline_sheds.load(Ordering::Relaxed), 3);
        assert_eq!(metrics.rows.load(Ordering::Relaxed), 3, "no compute spent on shed rows");
    }

    #[test]
    fn malformed_rows_are_aborted_not_dropped() {
        let mlp = toy_mlp();
        let queue = BatchQueue::bounded(64);
        let (bad, bad_rx) = job(vec![0.0; 3]); // wrong in_dim
        let (good, good_rx) = job(rows(&mlp, 1, 33).pop().unwrap());
        queue.submit(bad).map_err(|_| ()).unwrap();
        queue.submit(good).map_err(|_| ()).unwrap();
        let metrics = Arc::new(Metrics::new());
        let cfg = cfg(4, Duration::from_millis(50), ForwardMode::PackedF32);
        let mut batcher = Batcher::spawn(Arc::clone(&mlp), queue, cfg, Arc::clone(&metrics));
        assert!(matches!(recv_verdict(&bad_rx), Verdict::Aborted));
        assert_eq!(recv_reply(&good_rx).batch_rows, 1);
        batcher.stop();
    }

    #[test]
    fn batcher_panic_aborts_held_rows_then_respawns() {
        let mlp = toy_mlp();
        let xs = rows(&mlp, 3, 41);
        let queue = BatchQueue::bounded(64);
        let (j0, rx0) = job(xs[0].clone());
        let (j1, rx1) = job(xs[1].clone());
        queue.submit(j0).map_err(|_| ()).unwrap();
        queue.submit(j1).map_err(|_| ()).unwrap();
        let metrics = Arc::new(Metrics::new());
        let faults = Arc::new(FaultPlan::parse("panic_batcher@1", 0).unwrap());
        let cfg = BatchConfig {
            max_batch: 2,
            max_wait: Duration::from_millis(20),
            queue_cap: 64,
            mode: ForwardMode::PackedF32,
            faults: Some(Arc::clone(&faults)),
        };
        let mut batcher = Batcher::spawn(Arc::clone(&mlp), queue.clone(), cfg, Arc::clone(&metrics));
        // every batch panics: the held rows must come back Aborted, and
        // the loop must keep accepting work afterwards
        assert!(matches!(recv_verdict(&rx0), Verdict::Aborted));
        assert!(matches!(recv_verdict(&rx1), Verdict::Aborted));
        let (j2, rx2) = job(xs[2].clone());
        queue.submit(j2).map_err(|_| ()).unwrap();
        assert!(matches!(recv_verdict(&rx2), Verdict::Aborted));
        batcher.stop();
        let restarts = metrics.batcher_restarts.load(Ordering::Relaxed);
        assert_eq!(restarts, faults.injected_batcher_panics());
        assert!(restarts >= 2, "expected one restart per panicking batch, saw {restarts}");
    }

    #[test]
    fn every_job_is_answered_under_probabilistic_panics() {
        // seed-independent invariant: whatever the injected panic pattern,
        // each accepted row gets exactly one verdict and the restart
        // counter equals the fired-panic counter
        let mlp = toy_mlp();
        let xs = rows(&mlp, 30, 42);
        let queue = BatchQueue::bounded(64);
        let rxs: Vec<_> = xs
            .iter()
            .map(|x| {
                let (j, rx) = job(x.clone());
                queue.submit(j).map_err(|_| ()).unwrap();
                rx
            })
            .collect();
        let metrics = Arc::new(Metrics::new());
        let faults = Arc::new(FaultPlan::parse("panic_batcher@0.5", 3).unwrap());
        let cfg = BatchConfig {
            max_batch: 1, // one row per batch: 30 independent rolls
            max_wait: Duration::ZERO,
            queue_cap: 64,
            mode: ForwardMode::PackedF32,
            faults: Some(Arc::clone(&faults)),
        };
        let mut batcher = Batcher::spawn(Arc::clone(&mlp), queue, cfg, Arc::clone(&metrics));
        let mut replies = 0u64;
        let mut aborted = 0u64;
        for rx in &rxs {
            match recv_verdict(rx) {
                Verdict::Reply(_) => replies += 1,
                Verdict::Aborted => aborted += 1,
                Verdict::Expired => panic!("no deadlines were set"),
            }
        }
        batcher.stop();
        assert_eq!(replies + aborted, 30);
        assert_eq!(aborted, faults.injected_batcher_panics());
        assert_eq!(
            metrics.batcher_restarts.load(Ordering::Relaxed),
            faults.injected_batcher_panics()
        );
    }

    #[test]
    fn slow_batch_injection_delays_but_still_answers() {
        let mlp = toy_mlp();
        let queue = BatchQueue::bounded(8);
        let (j, rx) = job(rows(&mlp, 1, 43).pop().unwrap());
        queue.submit(j).map_err(|_| ()).unwrap();
        let metrics = Arc::new(Metrics::new());
        let faults = Arc::new(FaultPlan::parse("slow_batch=2ms@1", 0).unwrap());
        let cfg = BatchConfig {
            max_batch: 1,
            max_wait: Duration::ZERO,
            queue_cap: 8,
            mode: ForwardMode::PackedF32,
            faults: Some(Arc::clone(&faults)),
        };
        let mut batcher = Batcher::spawn(Arc::clone(&mlp), queue, cfg, metrics);
        assert_eq!(recv_reply(&rx).batch_rows, 1);
        batcher.stop();
        assert_eq!(faults.injected_slow_batches(), 1);
    }
}
