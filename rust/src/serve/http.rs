//! Minimal HTTP/1.1 on `std::net` — just enough protocol for the serving
//! layer: request parsing with hard caps (line length, header count, body
//! size, per-request deadline), keep-alive connections, and response
//! writing. No external crates; the JSON bodies go through `util::json`.
//!
//! The read path is built for the worker-thread model in `serve::mod`:
//! sockets carry a short read timeout, and a timeout that fires while *no*
//! request has started is reported as [`ReadOutcome::Idle`] so the worker
//! can poll its shutdown flag between requests — that poll is what makes
//! graceful drain possible without dropping an in-flight request.

use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Longest accepted request/header line (bytes, CRLF included).
pub const MAX_HEADER_LINE: usize = 8 * 1024;
/// Most headers accepted per request.
pub const MAX_HEADERS: usize = 64;

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path with any `?query` stripped.
    pub path: String,
    pub body: Vec<u8>,
    /// What the peer asked for (HTTP/1.1 default keep-alive, 1.0 close).
    pub keep_alive: bool,
    /// Per-request deadline from `X-Deadline-Ms` (milliseconds from
    /// arrival); overrides the server's `--default-deadline-ms`.
    pub deadline_ms: Option<u64>,
}

/// Outcome of trying to read one request off a kept-alive connection.
pub enum ReadOutcome {
    /// A complete request.
    Request(Request),
    /// Peer closed the connection between requests.
    Closed,
    /// The socket read timeout fired before any byte of a new request —
    /// the caller polls its shutdown flag and retries.
    Idle,
    /// Malformed, oversized or timed-out input; respond with `.1` (a JSON
    /// error body) at status `.0` and close the connection.
    Bad(u16, String),
}

enum LineEnd {
    Line,
    Eof,
    Timeout,
}

/// Append bytes up to and including `\n`. Returns `Timeout` on a socket
/// timeout once `deadline` (when given) has passed — or immediately when
/// no deadline is set, so the caller can decide whether the connection is
/// idle or a request stalled mid-line.
fn read_line(
    r: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
    deadline: Option<Instant>,
) -> Result<LineEnd, String> {
    loop {
        if buf.len() > MAX_HEADER_LINE {
            return Err("header line too long".into());
        }
        // fill_buf + bounded copy (not read_until, which would buffer a
        // delimiter-free flood without limit before any cap check ran)
        let (advance, done) = match r.fill_buf() {
            Ok([]) => return Ok(LineEnd::Eof),
            Ok(available) => {
                let limit = (MAX_HEADER_LINE + 1 - buf.len()).min(available.len());
                match available[..limit].iter().position(|&c| c == b'\n') {
                    Some(p) => {
                        buf.extend_from_slice(&available[..=p]);
                        (p + 1, true)
                    }
                    None => {
                        buf.extend_from_slice(&available[..limit]);
                        (limit, false)
                    }
                }
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                match deadline {
                    None => return Ok(LineEnd::Timeout),
                    Some(d) if Instant::now() >= d => return Ok(LineEnd::Timeout),
                    Some(_) => continue,
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(format!("read error: {e}")),
        };
        r.consume(advance);
        if done {
            return Ok(LineEnd::Line);
        }
    }
}

/// Fill `buf` completely or fail by `deadline`.
fn read_full(
    r: &mut BufReader<TcpStream>,
    buf: &mut [u8],
    deadline: Instant,
) -> Result<(), String> {
    let mut off = 0;
    while off < buf.len() {
        match r.read(&mut buf[off..]) {
            Ok(0) => return Err("connection closed mid-body".into()),
            Ok(n) => off += n,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if Instant::now() >= deadline {
                    return Err("body read timed out".into());
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(format!("read error: {e}")),
        }
    }
    Ok(())
}

fn bad(status: u16, msg: impl std::fmt::Display) -> ReadOutcome {
    ReadOutcome::Bad(status, error_body(&msg.to_string()))
}

/// Read one request. `budget` bounds the wall time from the first byte of
/// the request line to the last body byte; `max_body` bounds the declared
/// Content-Length (413 beyond it).
pub fn read_request(
    r: &mut BufReader<TcpStream>,
    max_body: usize,
    budget: Duration,
) -> ReadOutcome {
    // --- request line; a timeout before any byte means the connection
    //     is merely idle ---
    let mut line = Vec::with_capacity(256);
    let mut deadline: Option<Instant> = None;
    loop {
        match read_line(r, &mut line, deadline) {
            Ok(LineEnd::Line) => break,
            Ok(LineEnd::Eof) => {
                return if line.is_empty() {
                    ReadOutcome::Closed
                } else {
                    bad(400, "truncated request line")
                };
            }
            Ok(LineEnd::Timeout) => {
                if line.is_empty() {
                    return ReadOutcome::Idle;
                }
                match deadline {
                    // the request has started: give it the full budget
                    None => deadline = Some(Instant::now() + budget),
                    Some(_) => return bad(408, "request line timed out"),
                }
            }
            Err(e) => return bad(400, e),
        }
    }
    let deadline = deadline.unwrap_or_else(|| Instant::now() + budget);

    let first = match std::str::from_utf8(&line) {
        Ok(s) => s.trim_end(),
        Err(_) => return bad(400, "request line is not UTF-8"),
    };
    let mut parts = first.split_whitespace();
    let (method, target, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v), None) => (m.to_string(), t, v),
            _ => return bad(400, "malformed request line"),
        };
    if !version.starts_with("HTTP/1.") {
        return bad(400, "unsupported HTTP version");
    }
    let mut keep_alive = version == "HTTP/1.1";
    let path = target.split('?').next().unwrap_or("").to_string();

    // --- headers ---
    let mut content_len = 0usize;
    let mut deadline_ms: Option<u64> = None;
    let mut n_headers = 0usize;
    loop {
        line.clear();
        match read_line(r, &mut line, Some(deadline)) {
            Ok(LineEnd::Line) => {}
            Ok(LineEnd::Eof) => return bad(400, "truncated headers"),
            Ok(LineEnd::Timeout) => return bad(408, "header read timed out"),
            Err(e) => return bad(400, e),
        }
        let text = match std::str::from_utf8(&line) {
            Ok(s) => s.trim_end(),
            Err(_) => return bad(400, "header is not UTF-8"),
        };
        if text.is_empty() {
            break;
        }
        n_headers += 1;
        if n_headers > MAX_HEADERS {
            return bad(400, "too many headers");
        }
        let (name, value) = match text.split_once(':') {
            Some((n, v)) => (n.trim().to_ascii_lowercase(), v.trim()),
            None => return bad(400, "malformed header"),
        };
        match name.as_str() {
            "content-length" => match value.parse::<usize>() {
                Ok(n) if n <= max_body => content_len = n,
                Ok(n) => return bad(413, format!("body of {n} bytes exceeds cap {max_body}")),
                Err(_) => return bad(400, "bad content-length"),
            },
            "connection" => {
                if value.eq_ignore_ascii_case("close") {
                    keep_alive = false;
                } else if value.eq_ignore_ascii_case("keep-alive") {
                    keep_alive = true;
                }
            }
            "x-deadline-ms" => match value.parse::<u64>() {
                Ok(ms) => deadline_ms = Some(ms),
                Err(_) => return bad(400, "bad x-deadline-ms"),
            },
            _ => {}
        }
    }

    // --- body ---
    let mut body = vec![0u8; content_len];
    if content_len > 0 {
        if let Err(e) = read_full(r, &mut body, deadline) {
            return bad(408, e);
        }
    }
    ReadOutcome::Request(Request { method, path, body, keep_alive, deadline_ms })
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// `{"error": msg}` with the message JSON-escaped.
pub fn error_body(msg: &str) -> String {
    crate::util::Json::Obj(
        [("error".to_string(), crate::util::Json::Str(msg.to_string()))]
            .into_iter()
            .collect(),
    )
    .to_string()
}

/// Write one JSON response. `keep_alive` picks the `Connection` header;
/// 503 and 504 responses additionally carry `Retry-After: 1` (the
/// shedding contract: overload and deadline sheds are transient — retry
/// after the queue drains, ideally with a laxer deadline).
pub fn write_response(
    w: &mut TcpStream,
    status: u16,
    body: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\ncontent-type: application/json\r\n\
         content-length: {}\r\nconnection: {}\r\n",
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" }
    );
    if status == 503 || status == 504 {
        head.push_str("retry-after: 1\r\n");
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(body.as_bytes())?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Run the parser against raw bytes pushed through a real socket pair
    /// (the parser type is BufReader<TcpStream>, so tests use one too).
    fn parse_raw(raw: &[u8]) -> ReadOutcome {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_vec();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&raw).unwrap();
            // keep the socket open briefly so EOF is not racing the parse
            std::thread::sleep(Duration::from_millis(50));
        });
        let (stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(Duration::from_millis(20))).unwrap();
        let mut r = BufReader::new(stream);
        let out = read_request(&mut r, 1024, Duration::from_millis(200));
        writer.join().unwrap();
        out
    }

    #[test]
    fn parses_a_post_with_body() {
        let out = parse_raw(
            b"POST /predict HTTP/1.1\r\ncontent-length: 9\r\n\
              x-extra: 1\r\n\r\n{\"x\":[1]}",
        );
        match out {
            ReadOutcome::Request(req) => {
                assert_eq!(req.method, "POST");
                assert_eq!(req.path, "/predict");
                assert_eq!(req.body, b"{\"x\":[1]}");
                assert!(req.keep_alive);
                assert_eq!(req.deadline_ms, None);
            }
            _ => panic!("expected a request"),
        }
    }

    #[test]
    fn deadline_header_is_parsed_and_garbage_rejected() {
        let out = parse_raw(
            b"POST /predict HTTP/1.1\r\ncontent-length: 0\r\n\
              X-Deadline-Ms: 250\r\n\r\n",
        );
        match out {
            ReadOutcome::Request(req) => assert_eq!(req.deadline_ms, Some(250)),
            _ => panic!("expected a request"),
        }
        let out = parse_raw(
            b"POST /predict HTTP/1.1\r\ncontent-length: 0\r\n\
              x-deadline-ms: soon\r\n\r\n",
        );
        match out {
            ReadOutcome::Bad(400, body) => assert!(body.contains("x-deadline-ms"), "{body}"),
            _ => panic!("expected Bad(400)"),
        }
    }

    #[test]
    fn query_string_is_stripped_and_close_honored() {
        let out = parse_raw(b"GET /stats?pretty=1 HTTP/1.1\r\nConnection: close\r\n\r\n");
        match out {
            ReadOutcome::Request(req) => {
                assert_eq!(req.path, "/stats");
                assert!(!req.keep_alive);
            }
            _ => panic!("expected a request"),
        }
    }

    #[test]
    fn oversized_body_is_rejected_with_413() {
        let out = parse_raw(b"POST /predict HTTP/1.1\r\ncontent-length: 99999\r\n\r\n");
        match out {
            ReadOutcome::Bad(status, body) => {
                assert_eq!(status, 413);
                assert!(body.contains("exceeds"), "{body}");
            }
            _ => panic!("expected Bad"),
        }
    }

    #[test]
    fn garbage_request_line_is_a_400_not_a_panic() {
        for raw in [
            b"\x00\xff\xfe\r\n\r\n".as_slice(),
            b"GET\r\n\r\n",
            b"GET / HTTP/1.1 extra words\r\n\r\n",
            b"GET / SMTP/1.0\r\n\r\n",
            b"POST / HTTP/1.1\r\ncontent-length: minus-one\r\n\r\n",
            b"POST / HTTP/1.1\r\nno-colon-here\r\n\r\n",
        ] {
            match parse_raw(raw) {
                ReadOutcome::Bad(400, _) => {}
                ReadOutcome::Bad(s, b) => panic!("expected 400, got {s}: {b}"),
                _ => panic!("expected Bad for {:?}", String::from_utf8_lossy(raw)),
            }
        }
    }

    #[test]
    fn oversized_request_line_is_bounded_and_rejected() {
        // a delimiter-free flood must be refused after MAX_HEADER_LINE
        // buffered bytes, not accumulated without bound
        let mut raw = vec![b'A'; 3 * MAX_HEADER_LINE];
        raw.extend_from_slice(b"\r\n\r\n");
        match parse_raw(&raw) {
            ReadOutcome::Bad(400, body) => assert!(body.contains("too long"), "{body}"),
            _ => panic!("expected Bad(400)"),
        }
    }

    #[test]
    fn idle_connection_reports_idle_then_eof_reports_closed() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(Duration::from_millis(10))).unwrap();
        let mut r = BufReader::new(stream);
        assert!(matches!(
            read_request(&mut r, 1024, Duration::from_millis(100)),
            ReadOutcome::Idle
        ));
        drop(client);
        assert!(matches!(
            read_request(&mut r, 1024, Duration::from_millis(100)),
            ReadOutcome::Closed
        ));
    }

    #[test]
    fn truncated_body_times_out_cleanly() {
        // declares 50 bytes, sends 3, stalls: must be a 408, not a hang
        let out = parse_raw(b"POST / HTTP/1.1\r\ncontent-length: 50\r\n\r\nabc");
        match out {
            ReadOutcome::Bad(status, _) => assert_eq!(status, 408),
            _ => panic!("expected Bad(408)"),
        }
    }

    #[test]
    fn error_body_escapes() {
        let b = error_body("bad \"x\"\nvalue");
        assert!(crate::util::Json::parse(&b).is_ok(), "{b}");
    }
}
