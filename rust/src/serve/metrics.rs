//! Serving metrics: lock-free counters plus a bounded latency ring,
//! surfaced as the `/stats` endpoint's JSON snapshot.
//!
//! Latency percentiles ride the existing [`LatencyStats`] accumulator
//! (`util::timer`); the ring keeps the last [`RING_CAP`] samples so a
//! long-lived server reports *recent* p50/p95/p99 in O(1) memory instead
//! of growing a sample vector forever.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::{Json, LatencyStats};

/// Latency samples retained for percentile reporting.
pub const RING_CAP: usize = 4096;

struct Ring {
    buf: Vec<f64>,
    next: usize,
    filled: usize,
}

/// Shared serving counters. All counters are monotonic totals since
/// server start; `Relaxed` ordering is enough because readers only want
/// an eventually-consistent snapshot.
pub struct Metrics {
    /// Every parsed HTTP request, any route or status.
    pub requests: AtomicU64,
    /// 200s from `/predict`.
    pub predictions: AtomicU64,
    /// 400/408/413 responses.
    pub bad_requests: AtomicU64,
    /// 404 responses.
    pub not_found: AtomicU64,
    /// 503 responses (batch queue full or accept backlog full).
    pub overloads: AtomicU64,
    /// Batched forwards executed.
    pub batches: AtomicU64,
    /// Rows served across all batches.
    pub rows: AtomicU64,
    /// Largest batch coalesced so far.
    pub max_batch_rows: AtomicU64,
    lat: Mutex<Ring>,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            predictions: AtomicU64::new(0),
            bad_requests: AtomicU64::new(0),
            not_found: AtomicU64::new(0),
            overloads: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            max_batch_rows: AtomicU64::new(0),
            lat: Mutex::new(Ring { buf: vec![0.0; RING_CAP], next: 0, filled: 0 }),
        }
    }

    #[inline]
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one end-to-end `/predict` latency (seconds).
    pub fn record_latency(&self, seconds: f64) {
        let mut ring = self.lat.lock().unwrap();
        let at = ring.next;
        ring.buf[at] = seconds;
        ring.next = (at + 1) % RING_CAP;
        ring.filled = (ring.filled + 1).min(RING_CAP);
    }

    /// Record one executed batch of `rows` rows.
    pub fn record_batch(&self, rows: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(rows as u64, Ordering::Relaxed);
        self.max_batch_rows.fetch_max(rows as u64, Ordering::Relaxed);
    }

    /// The retained latency samples as a [`LatencyStats`] (copy; the ring
    /// keeps accumulating concurrently).
    pub fn latency(&self) -> LatencyStats {
        let mut stats = LatencyStats::default();
        let ring = self.lat.lock().unwrap();
        for &s in &ring.buf[..ring.filled] {
            stats.record(s);
        }
        stats
    }

    /// The `/stats` JSON object. `queue_depth` is sampled by the caller
    /// (the metrics struct does not own the batch queue).
    pub fn snapshot(&self, queue_depth: usize) -> Json {
        let lat = self.latency();
        let batches = self.batches.load(Ordering::Relaxed);
        let rows = self.rows.load(Ordering::Relaxed);
        let mut m = BTreeMap::new();
        let mut num = |k: &str, v: f64| {
            m.insert(k.to_string(), Json::Num(v));
        };
        num("requests", self.requests.load(Ordering::Relaxed) as f64);
        num("predictions", self.predictions.load(Ordering::Relaxed) as f64);
        num("bad_requests", self.bad_requests.load(Ordering::Relaxed) as f64);
        num("not_found", self.not_found.load(Ordering::Relaxed) as f64);
        num("overloads_503", self.overloads.load(Ordering::Relaxed) as f64);
        num("batches", batches as f64);
        num("rows", rows as f64);
        num("max_batch_rows", self.max_batch_rows.load(Ordering::Relaxed) as f64);
        num("mean_batch_rows", if batches == 0 { 0.0 } else { rows as f64 / batches as f64 });
        num("queue_depth", queue_depth as f64);
        num("latency_samples", lat.count() as f64);
        num("latency_mean_us", lat.mean() * 1e6);
        num("latency_p50_us", lat.percentile(50.0) * 1e6);
        num("latency_p95_us", lat.percentile(95.0) * 1e6);
        num("latency_p99_us", lat.percentile(99.0) * 1e6);
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_batches_accumulate() {
        let m = Metrics::new();
        Metrics::bump(&m.requests);
        Metrics::bump(&m.requests);
        m.record_batch(3);
        m.record_batch(5);
        m.record_batch(1);
        let snap = m.snapshot(7);
        assert_eq!(snap.get("requests").unwrap().as_usize(), Some(2));
        assert_eq!(snap.get("batches").unwrap().as_usize(), Some(3));
        assert_eq!(snap.get("rows").unwrap().as_usize(), Some(9));
        assert_eq!(snap.get("max_batch_rows").unwrap().as_usize(), Some(5));
        assert_eq!(snap.get("queue_depth").unwrap().as_usize(), Some(7));
        assert!((snap.get("mean_batch_rows").unwrap().as_f64().unwrap() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn latency_ring_keeps_the_most_recent_window() {
        let m = Metrics::new();
        // overfill the ring: the slow early samples must be evicted
        for _ in 0..RING_CAP {
            m.record_latency(1.0);
        }
        for _ in 0..RING_CAP {
            m.record_latency(0.001);
        }
        let lat = m.latency();
        assert_eq!(lat.count(), RING_CAP);
        assert!(lat.percentile(99.0) < 0.01, "old samples leaked into the window");
        // snapshot serializes without panicking and stays valid JSON
        let snap = m.snapshot(0).to_string();
        assert!(crate::util::Json::parse(&snap).is_ok(), "{snap}");
    }

    #[test]
    fn default_equals_new_and_always_records() {
        // no silent "ring-less" mode: Default and new are the same thing
        let m = Metrics::default();
        m.record_latency(0.5);
        assert_eq!(m.latency().count(), 1);
    }
}
