//! Serving metrics: lock-free counters plus a bounded latency ring,
//! surfaced as the `/stats` endpoint's JSON snapshot.
//!
//! Latency percentiles ride the existing [`LatencyStats`] accumulator
//! (`util::timer`); the ring keeps the last [`RING_CAP`] samples so a
//! long-lived server reports *recent* p50/p95/p99 in O(1) memory instead
//! of growing a sample vector forever.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::{lock_ok, Json, LatencyStats};

/// Latency samples retained for percentile reporting.
pub const RING_CAP: usize = 4096;

struct Ring {
    buf: Vec<f64>,
    next: usize,
    filled: usize,
}

/// Shared serving counters. All counters are monotonic totals since
/// server start; `Relaxed` ordering is enough because readers only want
/// an eventually-consistent snapshot.
pub struct Metrics {
    /// Every parsed HTTP request, any route or status.
    pub requests: AtomicU64,
    /// 200s from `/predict`.
    pub predictions: AtomicU64,
    /// 400/408/413 responses.
    pub bad_requests: AtomicU64,
    /// 404 responses.
    pub not_found: AtomicU64,
    /// 503 responses (batch queue full or accept backlog full).
    pub overloads: AtomicU64,
    /// Batched forwards executed.
    pub batches: AtomicU64,
    /// Rows served across all batches.
    pub rows: AtomicU64,
    /// Largest batch coalesced so far.
    pub max_batch_rows: AtomicU64,
    /// Worker panics caught and recovered by the supervisor (each one
    /// answered its in-flight connection with 500).
    pub worker_restarts: AtomicU64,
    /// Batcher panics caught; each respawn rebuilds the mode workspace
    /// and fails the held rows instead of dropping them.
    pub batcher_restarts: AtomicU64,
    /// Rows shed with 504 because their deadline passed while queued.
    pub deadline_sheds: AtomicU64,
    /// EWMA of batch forward time in microseconds; feeds the admission
    /// controller's queue-wait estimate.
    forward_ewma_us: AtomicU64,
    started: Instant,
    lat: Mutex<Ring>,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            predictions: AtomicU64::new(0),
            bad_requests: AtomicU64::new(0),
            not_found: AtomicU64::new(0),
            overloads: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            max_batch_rows: AtomicU64::new(0),
            worker_restarts: AtomicU64::new(0),
            batcher_restarts: AtomicU64::new(0),
            deadline_sheds: AtomicU64::new(0),
            forward_ewma_us: AtomicU64::new(0),
            started: Instant::now(),
            lat: Mutex::new(Ring { buf: vec![0.0; RING_CAP], next: 0, filled: 0 }),
        }
    }

    #[inline]
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Seconds since the metrics struct (i.e. the server) was created.
    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Fold one batch forward time (seconds) into the EWMA. The racy
    /// read-modify-write is deliberate: only the batcher writes, and a
    /// lost update merely delays the smoothing of an *estimate*.
    pub fn record_forward(&self, seconds: f64) {
        let sample = (seconds * 1e6) as u64;
        let prev = self.forward_ewma_us.load(Ordering::Relaxed);
        let next = if prev == 0 { sample } else { (prev * 7 + sample) / 8 };
        self.forward_ewma_us.store(next, Ordering::Relaxed);
    }

    /// Smoothed batch forward time in microseconds (0 until the first
    /// batch completes).
    pub fn forward_ewma_us(&self) -> u64 {
        self.forward_ewma_us.load(Ordering::Relaxed)
    }

    /// Record one end-to-end `/predict` latency (seconds).
    pub fn record_latency(&self, seconds: f64) {
        let mut ring = lock_ok(&self.lat);
        let at = ring.next;
        ring.buf[at] = seconds;
        ring.next = (at + 1) % RING_CAP;
        ring.filled = (ring.filled + 1).min(RING_CAP);
    }

    /// Record one executed batch of `rows` rows.
    pub fn record_batch(&self, rows: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(rows as u64, Ordering::Relaxed);
        self.max_batch_rows.fetch_max(rows as u64, Ordering::Relaxed);
    }

    /// The retained latency samples as a [`LatencyStats`] (copy; the ring
    /// keeps accumulating concurrently).
    pub fn latency(&self) -> LatencyStats {
        let mut stats = LatencyStats::default();
        let ring = lock_ok(&self.lat);
        for &s in &ring.buf[..ring.filled] {
            stats.record(s);
        }
        stats
    }

    /// The `/stats` JSON object. `queue_depth` is sampled by the caller
    /// (the metrics struct does not own the batch queue).
    pub fn snapshot(&self, queue_depth: usize) -> Json {
        let lat = self.latency();
        let batches = self.batches.load(Ordering::Relaxed);
        let rows = self.rows.load(Ordering::Relaxed);
        let mut m = BTreeMap::new();
        let mut num = |k: &str, v: f64| {
            m.insert(k.to_string(), Json::Num(v));
        };
        num("requests", self.requests.load(Ordering::Relaxed) as f64);
        num("predictions", self.predictions.load(Ordering::Relaxed) as f64);
        num("bad_requests", self.bad_requests.load(Ordering::Relaxed) as f64);
        num("not_found", self.not_found.load(Ordering::Relaxed) as f64);
        num("overloads_503", self.overloads.load(Ordering::Relaxed) as f64);
        num("batches", batches as f64);
        num("rows", rows as f64);
        num("max_batch_rows", self.max_batch_rows.load(Ordering::Relaxed) as f64);
        num("mean_batch_rows", if batches == 0 { 0.0 } else { rows as f64 / batches as f64 });
        num("queue_depth", queue_depth as f64);
        num("uptime_s", self.uptime_s());
        num("worker_restarts", self.worker_restarts.load(Ordering::Relaxed) as f64);
        num("batcher_restarts", self.batcher_restarts.load(Ordering::Relaxed) as f64);
        num("deadline_sheds_504", self.deadline_sheds.load(Ordering::Relaxed) as f64);
        num("forward_ewma_us", self.forward_ewma_us() as f64);
        num("latency_samples", lat.count() as f64);
        num("latency_mean_us", lat.mean() * 1e6);
        num("latency_p50_us", lat.percentile(50.0) * 1e6);
        num("latency_p95_us", lat.percentile(95.0) * 1e6);
        num("latency_p99_us", lat.percentile(99.0) * 1e6);
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_batches_accumulate() {
        let m = Metrics::new();
        Metrics::bump(&m.requests);
        Metrics::bump(&m.requests);
        m.record_batch(3);
        m.record_batch(5);
        m.record_batch(1);
        let snap = m.snapshot(7);
        assert_eq!(snap.get("requests").unwrap().as_usize(), Some(2));
        assert_eq!(snap.get("batches").unwrap().as_usize(), Some(3));
        assert_eq!(snap.get("rows").unwrap().as_usize(), Some(9));
        assert_eq!(snap.get("max_batch_rows").unwrap().as_usize(), Some(5));
        assert_eq!(snap.get("queue_depth").unwrap().as_usize(), Some(7));
        assert!((snap.get("mean_batch_rows").unwrap().as_f64().unwrap() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn supervision_counters_surface_in_the_snapshot() {
        let m = Metrics::new();
        let snap = m.snapshot(0);
        // fresh server: counters exist and read zero
        assert_eq!(snap.get("worker_restarts").unwrap().as_usize(), Some(0));
        assert_eq!(snap.get("batcher_restarts").unwrap().as_usize(), Some(0));
        assert_eq!(snap.get("deadline_sheds_504").unwrap().as_usize(), Some(0));
        assert!(snap.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
        Metrics::bump(&m.worker_restarts);
        Metrics::bump(&m.batcher_restarts);
        Metrics::bump(&m.batcher_restarts);
        Metrics::bump(&m.deadline_sheds);
        let snap = m.snapshot(0);
        assert_eq!(snap.get("worker_restarts").unwrap().as_usize(), Some(1));
        assert_eq!(snap.get("batcher_restarts").unwrap().as_usize(), Some(2));
        assert_eq!(snap.get("deadline_sheds_504").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn forward_ewma_smooths_toward_samples() {
        let m = Metrics::new();
        assert_eq!(m.forward_ewma_us(), 0);
        m.record_forward(0.001); // 1000 us: first sample adopted as-is
        assert_eq!(m.forward_ewma_us(), 1000);
        for _ in 0..64 {
            m.record_forward(0.002); // converges toward 2000 us
        }
        let ewma = m.forward_ewma_us();
        assert!((1900..=2000).contains(&ewma), "ewma {ewma}");
        let snap = m.snapshot(0);
        assert!(snap.get("forward_ewma_us").unwrap().as_f64().unwrap() >= 1900.0);
    }

    #[test]
    fn latency_ring_keeps_the_most_recent_window() {
        let m = Metrics::new();
        // overfill the ring: the slow early samples must be evicted
        for _ in 0..RING_CAP {
            m.record_latency(1.0);
        }
        for _ in 0..RING_CAP {
            m.record_latency(0.001);
        }
        let lat = m.latency();
        assert_eq!(lat.count(), RING_CAP);
        assert!(lat.percentile(99.0) < 0.01, "old samples leaked into the window");
        // snapshot serializes without panicking and stays valid JSON
        let snap = m.snapshot(0).to_string();
        assert!(crate::util::Json::parse(&snap).is_ok(), "{snap}");
    }

    #[test]
    fn default_equals_new_and_always_records() {
        // no silent "ring-less" mode: Default and new are the same thing
        let m = Metrics::default();
        m.record_latency(0.5);
        assert_eq!(m.latency().count(), 1);
    }
}
