//! `serve/` — online inference over the packed sign-GEMM engine.
//!
//! Turns a `.bcpack` model (the deterministic-BC test-time network,
//! paper Sec. 2.6) into an HTTP service using only `std`:
//!
//! * [`http`] — minimal HTTP/1.1 parsing/writing with hard input caps;
//! * [`batcher`] — the dynamic micro-batching queue that coalesces
//!   concurrent single-row requests into one lane-batched forward (the
//!   whole point: serve throughput rides the batched SIMD path, and a
//!   row's logits are bit-identical solo or coalesced);
//! * [`metrics`] — counters + bounded latency ring behind `/stats`;
//! * [`loadgen`] — the closed-loop load generator (`bcrun loadgen`).
//!
//! ## Threading model
//!
//! One nonblocking **acceptor** (the `Server` thread) hands connections
//! to a bounded channel; `workers` **connection threads** each run one
//! keep-alive connection at a time (read request → route → respond);
//! one **batcher** thread owns the model workspace and executes the
//! coalesced forwards. Backpressure exists at both hops: a full
//! connection backlog answers 503 at accept, a full row queue answers
//! 503 from `/predict`.
//!
//! ## Endpoints
//!
//! | route | semantics |
//! |---|---|
//! | `POST /predict` | `{"x":[...in_dim floats...]}` → `{"pred":c,"batch":b,"logits":[...]}` |
//! | `GET /healthz`  | model + config facts plus liveness counters, `{"ok":true,...}` |
//! | `GET /stats`    | counters and latency percentiles (see `metrics`) |
//! | `POST /shutdown`| begin graceful drain (also: SIGTERM / ctrl-c) |
//!
//! ## Failure model (DESIGN.md, "Failure model & supervision")
//!
//! Worker threads and the batcher run under `catch_unwind` supervision:
//! a panicking worker answers its in-flight connection with 500 and the
//! thread keeps serving; a panicking batcher fails its held rows (500)
//! and re-enters its loop with a freshly built workspace. Both paths
//! count restarts in `/stats`. Requests may carry a deadline
//! (`--default-deadline-ms` or `X-Deadline-Ms`): admission sheds
//! infeasible rows with 503, the batcher sheds expired queued rows with
//! 504. Every accepted request is answered — 200, 400, 500, 503 or 504,
//! never silence. `BCRUN_FAULTS` (util::faultinject) injects
//! deterministic panics/stalls to prove all of this under test.
//!
//! ## Shutdown
//!
//! `Server::stop` (triggered by signal, `/shutdown`, or drop) stops
//! accepting, lets every in-flight request finish, drains the batch
//! queue (accepted rows are always answered), then joins all threads.
//! A second signal during a wedged drain force-exits with code 143
//! (see [`signal`]).

pub mod batcher;
pub mod http;
pub mod loadgen;
pub mod metrics;

use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::binary::{ForwardMode, PackedMlp};
use crate::ensure;
use crate::kernel::simd;
use crate::util::error::{Context as _, Result};
use crate::util::{lock_ok, FaultPlan, Json, Timer};

use batcher::{BatchConfig, Batcher, Job, Verdict};
use http::{ReadOutcome, Request};
use metrics::Metrics;

/// Serving knobs (`bcrun serve` flags map 1:1).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind host (default loopback; expose deliberately).
    pub addr: String,
    /// TCP port; 0 binds an ephemeral port (see [`Server::addr`]).
    pub port: u16,
    /// Most rows coalesced into one forward.
    pub max_batch: usize,
    /// Batching window: how long the batcher waits for more rows after
    /// noticing the first one. Zero = no waiting.
    pub max_wait: Duration,
    /// Bound on queued rows; beyond it `/predict` answers 503.
    pub queue_cap: usize,
    /// Connection worker threads.
    pub workers: usize,
    /// Accept-to-worker handoff backlog; beyond it accept answers 503.
    pub conn_backlog: usize,
    /// Largest accepted request body (bytes).
    pub max_body: usize,
    /// Wall-time budget for reading one request.
    pub request_timeout: Duration,
    /// Close a keep-alive connection after this much request-free idle
    /// time. Each worker thread serves one connection at a time, so
    /// `workers` bounds the *concurrently-served* persistent
    /// connections — reaping idle sockets is what keeps silent clients
    /// from pinning workers forever.
    pub idle_timeout: Duration,
    /// Suppress the per-lifecycle eprintln lines.
    pub quiet: bool,
    /// Forward engine: classic packed-f32, or the XNOR–popcount BNN
    /// path (`--bnn`). Either way the solo ≡ coalesced bit-exactness
    /// contract holds; in BNN mode hidden activations are sign bits, so
    /// the served function differs from packed-f32 by design.
    pub mode: ForwardMode,
    /// Deadline applied to requests that do not send `X-Deadline-Ms`
    /// (`--default-deadline-ms`; `None` = no deadline). Admission
    /// answers 503 when the estimated queue wait already exceeds the
    /// deadline; rows that expire while queued are shed with 504.
    pub default_deadline: Option<Duration>,
    /// Deterministic fault-injection plan (`BCRUN_FAULTS`). `None` —
    /// the default — is production: no injection, no overhead.
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1".into(),
            port: 0,
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            queue_cap: 1024,
            workers: 8,
            conn_backlog: 128,
            max_body: 1 << 20,
            request_timeout: Duration::from_secs(10),
            idle_timeout: Duration::from_secs(30),
            quiet: true,
            mode: ForwardMode::PackedF32,
            default_deadline: None,
            faults: None,
        }
    }
}

/// Shared request-handling context.
struct Ctx {
    mlp: Arc<PackedMlp>,
    queue: batcher::BatchQueue,
    metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    max_body: usize,
    request_timeout: Duration,
    idle_timeout: Duration,
    /// Active forward engine, echoed by `/stats`.
    mode: ForwardMode,
    /// Workspace footprint for this mode at `max_batch` (static fact).
    activation_bytes: usize,
    /// Batching knobs, re-used by the admission-control wait estimate.
    max_batch: usize,
    max_wait: Duration,
    /// Deadline for requests without an `X-Deadline-Ms` header.
    default_deadline: Option<Duration>,
    /// Fault-injection plan shared with the batcher (`None` = inert).
    faults: Option<Arc<FaultPlan>>,
    /// Static part of the `/healthz` body; liveness counters (uptime,
    /// restarts, sheds) are merged in per request.
    health_base: Json,
}

/// A running server. Dropping it (or calling [`Server::stop`]) performs
/// the graceful drain described in the module docs.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    metrics: Arc<Metrics>,
    accept_join: Option<JoinHandle<()>>,
}

impl Server {
    /// The actually-bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// True once shutdown has been requested (signal, `/shutdown`, or
    /// [`Server::stop`]).
    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    /// Request + wait for the graceful drain. Idempotent.
    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Bind, spawn the batcher + worker + acceptor threads, return a handle.
pub fn start(mlp: PackedMlp, cfg: ServeConfig) -> Result<Server> {
    ensure!(cfg.max_batch >= 1, "max_batch must be >= 1");
    ensure!(cfg.workers >= 1, "workers must be >= 1");
    ensure!(cfg.queue_cap >= 1, "queue_cap must be >= 1");
    ensure!(!mlp.layers.is_empty(), "cannot serve an empty model");
    ensure!(
        cfg.mode != ForwardMode::Bnn || mlp.conv.is_empty(),
        "--bnn does not support conv models: the XNOR path has no conv front \
         (serve this model in packed-f32 mode)"
    );
    // note: queue_cap < max_batch is allowed — batches are then bounded
    // by the queue, which is exactly what the overload tests exploit
    let listener = TcpListener::bind((cfg.addr.as_str(), cfg.port))
        .with_context(|| format!("bind {}:{}", cfg.addr, cfg.port))?;
    let addr = listener.local_addr()?;
    listener
        .set_nonblocking(true)
        .context("set_nonblocking on the listener")?;

    let shutdown = Arc::new(AtomicBool::new(false));
    let metrics = Arc::new(Metrics::new());
    let mlp = Arc::new(mlp);
    let batch_cfg = BatchConfig {
        max_batch: cfg.max_batch,
        max_wait: cfg.max_wait,
        queue_cap: cfg.queue_cap,
        mode: cfg.mode,
        faults: cfg.faults.clone(),
    };
    let batcher = Batcher::start(Arc::clone(&mlp), batch_cfg, Arc::clone(&metrics));
    let health_base = health_json(&mlp, &cfg);
    let activation_bytes = mlp.activation_memory_bytes(cfg.max_batch, cfg.mode);
    let ctx = Arc::new(Ctx {
        mlp,
        queue: batcher.queue.clone(),
        metrics: Arc::clone(&metrics),
        shutdown: Arc::clone(&shutdown),
        max_body: cfg.max_body,
        request_timeout: cfg.request_timeout,
        idle_timeout: cfg.idle_timeout,
        mode: cfg.mode,
        activation_bytes,
        max_batch: cfg.max_batch,
        max_wait: cfg.max_wait,
        default_deadline: cfg.default_deadline,
        faults: cfg.faults.clone(),
        health_base,
    });

    let (conn_tx, conn_rx) = sync_channel::<TcpStream>(cfg.conn_backlog.max(1));
    let conn_rx = Arc::new(Mutex::new(conn_rx));
    let mut worker_joins = Vec::with_capacity(cfg.workers);
    for i in 0..cfg.workers {
        let rx = Arc::clone(&conn_rx);
        let ctx = Arc::clone(&ctx);
        let j = std::thread::Builder::new()
            .name(format!("bc-conn-{i}"))
            .spawn(move || conn_worker(&rx, &ctx))
            .context("spawn connection worker")?;
        worker_joins.push(j);
    }

    let accept_shutdown = Arc::clone(&shutdown);
    let accept_metrics = Arc::clone(&metrics);
    let quiet = cfg.quiet;
    let accept_join = std::thread::Builder::new()
        .name("bc-accept".into())
        .spawn(move || {
            acceptor(&listener, conn_tx, &accept_shutdown, &accept_metrics);
            // conn_tx is dropped by acceptor(): workers drain queued
            // connections, finish in-flight requests, then exit
            for j in worker_joins {
                let _ = j.join();
            }
            // only now is it safe to drain + stop the batcher: no worker
            // is left holding an unanswered row
            let mut batcher = batcher;
            batcher.stop();
            if !quiet {
                eprintln!("serve: drained and stopped");
            }
        })
        .context("spawn acceptor")?;

    Ok(Server { addr, shutdown, metrics, accept_join: Some(accept_join) })
}

fn health_json(mlp: &PackedMlp, cfg: &ServeConfig) -> Json {
    let mut m = BTreeMap::new();
    m.insert("ok".to_string(), Json::Bool(true));
    m.insert("in_dim".to_string(), Json::Num(mlp.in_dim as f64));
    if let Some(c0) = mlp.conv.first() {
        // conv models: the image geometry behind in_dim, so clients
        // (loadgen included) can shape payloads as (h, w, c) images
        m.insert(
            "input_shape".to_string(),
            Json::Arr(vec![
                Json::Num(c0.h_in as f64),
                Json::Num(c0.w_in as f64),
                Json::Num(c0.cin as f64),
            ]),
        );
    }
    m.insert("classes".to_string(), Json::Num(mlp.classes as f64));
    m.insert("layers".to_string(), Json::Num(mlp.layers.len() as f64));
    m.insert("conv_layers".to_string(), Json::Num(mlp.conv.len() as f64));
    m.insert(
        "weight_bytes".to_string(),
        Json::Num(mlp.weight_memory_bytes() as f64),
    );
    m.insert(
        "activation_bytes".to_string(),
        Json::Num(mlp.activation_memory_bytes(cfg.max_batch, cfg.mode) as f64),
    );
    m.insert("mode".to_string(), Json::Str(cfg.mode.label().to_string()));
    m.insert(
        "isa_selected".to_string(),
        Json::Str(simd::active().name().to_string()),
    );
    m.insert("max_batch".to_string(), Json::Num(cfg.max_batch as f64));
    m.insert(
        "max_wait_us".to_string(),
        Json::Num(cfg.max_wait.as_micros() as f64),
    );
    m.insert("queue_cap".to_string(), Json::Num(cfg.queue_cap as f64));
    m.insert("workers".to_string(), Json::Num(cfg.workers as f64));
    m.insert(
        "default_deadline_ms".to_string(),
        Json::Num(cfg.default_deadline.map_or(0.0, |d| d.as_millis() as f64)),
    );
    Json::Obj(m)
}

fn acceptor(
    listener: &TcpListener,
    conn_tx: std::sync::mpsc::SyncSender<TcpStream>,
    shutdown: &AtomicBool,
    metrics: &Metrics,
) {
    while !shutdown.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => match conn_tx.try_send(stream) {
                Ok(()) => {}
                Err(TrySendError::Full(stream)) => {
                    // every worker busy and the backlog full: shed load
                    // here instead of queueing unbounded connections
                    Metrics::bump(&metrics.overloads);
                    let mut s = stream;
                    let _ = s.set_nonblocking(false);
                    let _ = http::write_response(
                        &mut s,
                        503,
                        &http::error_body("overloaded: connection backlog full"),
                        false,
                    );
                }
                Err(TrySendError::Disconnected(_)) => break,
            },
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }
    // dropping conn_tx wakes the workers out of recv()
}

fn conn_worker(rx: &Mutex<Receiver<TcpStream>>, ctx: &Ctx) {
    loop {
        // holding the lock only while waiting for the *next* connection;
        // handling happens with the lock released (lock_ok: a panic in a
        // sibling worker must not poison this handoff for everyone)
        let stream = match lock_ok(rx).recv() {
            Ok(s) => s,
            Err(_) => return, // acceptor gone and backlog drained
        };
        // supervision: a panic while serving (a kernel bug, or an
        // injected fault) costs this connection a 500, never the thread.
        // The dup'd handle exists so the catch arm can still answer
        // after `stream` (inside the BufReader) unwound away.
        let spare = stream.try_clone().ok();
        let served = catch_unwind(AssertUnwindSafe(|| handle_connection(stream, ctx)));
        if served.is_err() {
            Metrics::bump(&ctx.metrics.worker_restarts);
            if let Some(mut s) = spare {
                let _ = http::write_response(
                    &mut s,
                    500,
                    &http::error_body("worker panicked; request aborted"),
                    false,
                );
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }
}

fn handle_connection(stream: TcpStream, ctx: &Ctx) {
    // accepted sockets may inherit the listener's nonblocking mode on
    // some platforms — normalize, then use a short read timeout so idle
    // keep-alive connections poll the shutdown flag
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    let mut reader = BufReader::new(stream);
    let mut last_request = std::time::Instant::now();
    loop {
        match http::read_request(&mut reader, ctx.max_body, ctx.request_timeout) {
            ReadOutcome::Idle => {
                if ctx.shutdown.load(Ordering::Acquire) {
                    return; // graceful: nothing in flight on this socket
                }
                // reap silent keep-alive sockets: each worker serves one
                // connection at a time, so a client that connects and
                // goes quiet would otherwise pin a worker forever and
                // starve the backlog
                if last_request.elapsed() >= ctx.idle_timeout {
                    return;
                }
            }
            ReadOutcome::Closed => return,
            ReadOutcome::Bad(status, body) => {
                Metrics::bump(&ctx.metrics.requests);
                Metrics::bump(&ctx.metrics.bad_requests);
                let _ = http::write_response(reader.get_mut(), status, &body, false);
                return;
            }
            ReadOutcome::Request(req) => {
                last_request = std::time::Instant::now();
                let keep = req.keep_alive && !ctx.shutdown.load(Ordering::Acquire);
                let (status, body) = route(ctx, &req);
                if http::write_response(reader.get_mut(), status, &body, keep).is_err() {
                    return;
                }
                if !keep {
                    return;
                }
            }
        }
    }
}

fn route(ctx: &Ctx, req: &Request) -> (u16, String) {
    Metrics::bump(&ctx.metrics.requests);
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/predict") => predict(ctx, req),
        ("GET", "/healthz") => {
            // static model/config facts plus the liveness counters a
            // fleet health-checker actually watches
            let mut j = ctx.health_base.clone();
            if let Json::Obj(m) = &mut j {
                let counter = |c: &std::sync::atomic::AtomicU64| {
                    Json::Num(c.load(Ordering::Relaxed) as f64)
                };
                m.insert("uptime_s".to_string(), Json::Num(ctx.metrics.uptime_s()));
                m.insert(
                    "worker_restarts".to_string(),
                    counter(&ctx.metrics.worker_restarts),
                );
                m.insert(
                    "batcher_restarts".to_string(),
                    counter(&ctx.metrics.batcher_restarts),
                );
                m.insert(
                    "deadline_sheds_504".to_string(),
                    counter(&ctx.metrics.deadline_sheds),
                );
            }
            (200, j.to_string())
        }
        ("GET", "/stats") => {
            // augment the counters with the engine facts here (rather
            // than widening Metrics::snapshot, which has many callers)
            let mut snap = ctx.metrics.snapshot(ctx.queue.depth());
            if let Json::Obj(m) = &mut snap {
                m.insert("mode".to_string(), Json::Str(ctx.mode.label().to_string()));
                m.insert(
                    "isa_selected".to_string(),
                    Json::Str(simd::active().name().to_string()),
                );
                m.insert(
                    "weight_bytes".to_string(),
                    Json::Num(ctx.mlp.weight_memory_bytes() as f64),
                );
                m.insert(
                    "activation_bytes".to_string(),
                    Json::Num(ctx.activation_bytes as f64),
                );
            }
            (200, snap.to_string())
        }
        ("POST", "/shutdown") => {
            ctx.shutdown.store(true, Ordering::Release);
            let mut m = BTreeMap::new();
            m.insert("ok".to_string(), Json::Bool(true));
            m.insert("draining".to_string(), Json::Bool(true));
            (200, Json::Obj(m).to_string())
        }
        _ => {
            Metrics::bump(&ctx.metrics.not_found);
            (
                404,
                http::error_body(&format!("no route {} {}", req.method, req.path)),
            )
        }
    }
}

fn predict(ctx: &Ctx, req: &Request) -> (u16, String) {
    let t = Timer::start();
    if let Some(faults) = &ctx.faults {
        // the worker injection point: a panic here unwinds into the
        // connection supervisor (conn_worker), which answers 500
        faults.maybe_panic_worker();
    }
    let parsed = match parse_predict(ctx, &req.body) {
        Ok(x) => x,
        Err(msg) => {
            Metrics::bump(&ctx.metrics.bad_requests);
            return (400, http::error_body(&msg));
        }
    };
    let arrival = Instant::now();
    let deadline = req
        .deadline_ms
        .map(Duration::from_millis)
        .or(ctx.default_deadline)
        .map(|d| arrival + d);
    if let Some(d) = deadline {
        // admission control: if the work already ahead of this row
        // implies missing its deadline, shed now (503 + Retry-After)
        // instead of queueing a row the batcher will only 504 later
        if arrival + estimated_queue_wait(ctx) > d {
            Metrics::bump(&ctx.metrics.overloads);
            return (
                503,
                http::error_body("deadline infeasible: estimated queue wait exceeds it"),
            );
        }
    }
    let (reply_tx, reply_rx) = sync_channel(1);
    if ctx.queue.submit(Job { x: parsed, reply: reply_tx, deadline }).is_err() {
        Metrics::bump(&ctx.metrics.overloads);
        return (503, http::error_body("overloaded: batch queue full"));
    }
    match reply_rx.recv_timeout(Duration::from_secs(30)) {
        Ok(Verdict::Reply(reply)) => {
            Metrics::bump(&ctx.metrics.predictions);
            ctx.metrics.record_latency(t.elapsed_s());
            let mut m = BTreeMap::new();
            m.insert("pred".to_string(), Json::Num(reply.pred as f64));
            m.insert("batch".to_string(), Json::Num(reply.batch_rows as f64));
            m.insert(
                "logits".to_string(),
                Json::Arr(reply.logits.iter().map(|&v| Json::Num(v as f64)).collect()),
            );
            (200, Json::Obj(m).to_string())
        }
        Ok(Verdict::Expired) => (
            504,
            http::error_body("deadline exceeded while queued; row shed before compute"),
        ),
        // an aborted row (batcher panicked while holding it) and a dead
        // reply channel look the same to the client: the forward never
        // ran, so retrying is safe
        Ok(Verdict::Aborted) | Err(_) => {
            (500, http::error_body("batcher aborted this request; retrying is safe"))
        }
    }
}

/// Estimate how long a newly-admitted row would wait for its logits:
/// the batches already ahead of it (queue depth / max_batch, plus its
/// own batch) each cost one batching window plus the smoothed forward
/// time. Deliberately cheap and conservative — it gates *admission*,
/// not correctness (an admitted row that still expires is shed by the
/// batcher with 504).
fn estimated_queue_wait(ctx: &Ctx) -> Duration {
    let batches_ahead = (ctx.queue.depth() / ctx.max_batch.max(1)) as u32 + 1;
    let per_batch = ctx.max_wait + Duration::from_micros(ctx.metrics.forward_ewma_us());
    per_batch.checked_mul(batches_ahead).unwrap_or(Duration::MAX)
}

/// Validate a `/predict` body into one input row. Every failure is a
/// client error (400) with an actionable message; the parser itself is
/// depth/size-capped (`Json::parse_untrusted`) because these bytes come
/// off the network.
fn parse_predict(ctx: &Ctx, body: &[u8]) -> Result<Vec<f32>, String> {
    let text =
        std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let json = Json::parse_untrusted(text, ctx.max_body)?;
    let xs = json
        .get("x")
        .and_then(Json::as_arr)
        .ok_or_else(|| "body must be {\"x\": [..numbers..]}".to_string())?;
    if xs.len() != ctx.mlp.in_dim {
        return Err(format!(
            "'x' must have {} features, got {}",
            ctx.mlp.in_dim,
            xs.len()
        ));
    }
    let mut x = Vec::with_capacity(xs.len());
    for (i, v) in xs.iter().enumerate() {
        match v.as_f64() {
            Some(f) if f.is_finite() => x.push(f as f32),
            _ => return Err(format!("'x'[{i}] is not a finite number")),
        }
    }
    Ok(x)
}

/// Process-wide shutdown signal latch for `bcrun serve` (SIGINT/SIGTERM
/// on unix; a no-op installer elsewhere — `/shutdown` still works).
///
/// State machine: the **first** signal latches "drain requested" — the
/// serve loop notices and begins the graceful drain. Any **further**
/// signal while the process is still alive (i.e. the drain is wedged on
/// a stuck connection or batch) force-exits immediately with the
/// distinct code [`FORCE_EXIT_CODE`], so an operator's second ctrl-c /
/// `kill -TERM` always works. The decision lives in the pure
/// [`action_for`] so the state machine is unit-testable without
/// delivering real signals.
pub mod signal {
    use std::sync::atomic::{AtomicU32, Ordering};

    static SIGNAL_COUNT: AtomicU32 = AtomicU32::new(0);

    /// Exit code of a forced (second-signal) shutdown: 128 + SIGTERM,
    /// the conventional "killed by signal 15" code — distinct from the
    /// graceful drain's 0.
    pub const FORCE_EXIT_CODE: i32 = 143;

    /// What a delivered signal should do, given it is the `nth` one
    /// (1-based) this process has received.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub enum Action {
        /// Latch the drain flag; the serve loop shuts down gracefully.
        BeginDrain,
        /// The drain is already running (and evidently not done):
        /// force-exit with [`FORCE_EXIT_CODE`].
        ForceExit,
    }

    /// The latch state machine, pure and reentrancy-free so it can be
    /// unit-tested and reasoned about: first signal drains, every later
    /// one force-exits.
    pub fn action_for(nth_signal: u32) -> Action {
        if nth_signal <= 1 {
            Action::BeginDrain
        } else {
            Action::ForceExit
        }
    }

    /// True once at least one shutdown signal (or [`trigger`]) arrived.
    pub fn triggered() -> bool {
        SIGNAL_COUNT.load(Ordering::Acquire) > 0
    }

    /// Test hook / manual trigger. Counts like a delivered signal for
    /// `triggered()`, but never force-exits (tests must not die).
    pub fn trigger() {
        SIGNAL_COUNT.fetch_add(1, Ordering::AcqRel);
    }

    /// Install handlers for SIGINT (2) and SIGTERM (15). Uses the C
    /// `signal` symbol already linked through std. The handler is
    /// async-signal-safe by construction: one atomic RMW, and on the
    /// force path a direct `_exit` — **not** `std::process::exit`,
    /// which runs atexit handlers and may allocate or take locks the
    /// interrupted thread already holds.
    #[cfg(unix)]
    pub fn install() {
        extern "C" fn handler(_sig: i32) {
            let nth = SIGNAL_COUNT.fetch_add(1, Ordering::AcqRel) + 1;
            if action_for(nth) == Action::ForceExit {
                extern "C" {
                    fn _exit(code: i32) -> !;
                }
                // SAFETY: _exit is async-signal-safe (POSIX) and does
                // not return; the wedged drain is abandoned by design.
                unsafe { _exit(FORCE_EXIT_CODE) }
            }
        }
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        // SAFETY: registering an async-signal-safe handler (see above).
        unsafe {
            signal(2, handler);
            signal(15, handler);
        }
    }

    #[cfg(not(unix))]
    pub fn install() {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn toy_mlp() -> PackedMlp {
        let mut rng = Rng::new(40);
        let w1: Vec<f32> = (0..6 * 70).map(|_| rng.normal()).collect();
        let w2: Vec<f32> = (0..70 * 3).map(|_| rng.normal()).collect();
        PackedMlp::build(
            vec![(w1, 6, 70), (w2, 70, 3)],
            vec![
                Some((vec![1.0; 70], vec![0.0; 70], vec![0.1; 70], vec![1.0; 70])),
                None,
            ],
            Some(vec![0.1, -0.1, 0.0]),
        )
    }

    fn test_ctx(cfg: &ServeConfig) -> Ctx {
        let mlp = Arc::new(toy_mlp());
        let health_base = health_json(&mlp, cfg);
        let activation_bytes = mlp.activation_memory_bytes(cfg.max_batch, cfg.mode);
        Ctx {
            mlp,
            queue: batcher::BatchQueue::bounded(cfg.queue_cap),
            metrics: Arc::new(Metrics::new()),
            shutdown: Arc::new(AtomicBool::new(false)),
            max_body: cfg.max_body,
            request_timeout: cfg.request_timeout,
            idle_timeout: cfg.idle_timeout,
            mode: cfg.mode,
            activation_bytes,
            max_batch: cfg.max_batch,
            max_wait: cfg.max_wait,
            default_deadline: cfg.default_deadline,
            faults: cfg.faults.clone(),
            health_base,
        }
    }

    #[test]
    fn parse_predict_validates_shape_and_values() {
        let cfg = ServeConfig::default();
        let ctx = test_ctx(&cfg);
        let ok = parse_predict(&ctx, br#"{"x":[1,2,3,4,5,6]}"#).unwrap();
        assert_eq!(ok.len(), 6);
        for bad in [
            &b"not json"[..],
            br#"{"y":[1]}"#,
            br#"{"x":[1,2,3]}"#,
            br#"{"x":[1,2,3,4,5,"s"]}"#,
            br#"{"x":[1,2,3,4,5,1e999]}"#,
            b"\xff\xfe",
        ] {
            assert!(parse_predict(&ctx, bad).is_err(), "{:?}", String::from_utf8_lossy(bad));
        }
    }

    #[test]
    fn health_json_reports_model_facts() {
        let cfg = ServeConfig { max_batch: 32, ..Default::default() };
        let ctx = test_ctx(&cfg);
        let j = Json::parse(&ctx.health_base.to_string()).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("in_dim").unwrap().as_usize(), Some(6));
        assert_eq!(j.get("classes").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("max_batch").unwrap().as_usize(), Some(32));
        assert_eq!(j.get("mode").unwrap().as_str(), Some("packed-f32"));
        assert_eq!(
            j.get("isa_selected").unwrap().as_str(),
            Some(simd::active().name())
        );
        let act = j.get("activation_bytes").unwrap().as_usize().unwrap();
        assert_eq!(act, ctx.mlp.activation_memory_bytes(32, ForwardMode::PackedF32));
    }

    #[test]
    fn health_json_reports_bnn_mode_facts() {
        let cfg = ServeConfig {
            max_batch: 16,
            mode: ForwardMode::Bnn,
            ..Default::default()
        };
        let ctx = test_ctx(&cfg);
        let j = Json::parse(&ctx.health_base.to_string()).unwrap();
        assert_eq!(j.get("mode").unwrap().as_str(), Some("bnn"));
        let act = j.get("activation_bytes").unwrap().as_usize().unwrap();
        assert_eq!(act, ctx.mlp.activation_memory_bytes(16, ForwardMode::Bnn));
        // bit activations are far smaller than the f32 ping-pong
        assert!(act < ctx.mlp.activation_memory_bytes(16, ForwardMode::PackedF32));
    }

    /// 4x4x2 image -> pooled 3x3 conv -> dense 12 -> 3.
    fn toy_conv_mlp() -> PackedMlp {
        use crate::binary::PackedConvLayer;
        use crate::binary::{BitMatrix, PackedLayer};
        let mut rng = Rng::new(41);
        let wc: Vec<f32> = (0..18 * 3).map(|_| rng.normal()).collect();
        let wd: Vec<f32> = (0..12 * 3).map(|_| rng.normal()).collect();
        PackedMlp {
            conv: vec![PackedConvLayer {
                bits: BitMatrix::pack(&wc, 18, 3),
                scale: vec![0.5; 3],
                shift: vec![0.0; 3],
                kh: 3,
                kw: 3,
                cin: 2,
                cout: 3,
                h_in: 4,
                w_in: 4,
                pool: true,
            }],
            layers: vec![PackedLayer {
                bits: BitMatrix::pack(&wd, 12, 3),
                scale: vec![1.0; 3],
                shift: vec![0.0; 3],
                relu: false,
            }],
            in_dim: 32,
            classes: 3,
        }
    }

    #[test]
    fn health_json_reports_conv_input_shape() {
        let cfg = ServeConfig::default();
        let mlp = toy_conv_mlp();
        let j = Json::parse(&health_json(&mlp, &cfg).to_string()).unwrap();
        assert_eq!(j.get("in_dim").unwrap().as_usize(), Some(32));
        let shape = j.get("input_shape").unwrap();
        assert_eq!(shape.idx(0).unwrap().as_usize(), Some(4));
        assert_eq!(shape.idx(1).unwrap().as_usize(), Some(4));
        assert_eq!(shape.idx(2).unwrap().as_usize(), Some(2));
        assert_eq!(j.get("conv_layers").unwrap().as_usize(), Some(1));
        // dense models keep the key absent (loadgen falls back to in_dim)
        let dense = Json::parse(&health_json(&toy_mlp(), &cfg).to_string()).unwrap();
        assert!(dense.get("input_shape").is_none());
        assert_eq!(dense.get("conv_layers").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn start_rejects_bad_configs() {
        assert!(start(toy_mlp(), ServeConfig { max_batch: 0, ..Default::default() }).is_err());
        assert!(start(toy_mlp(), ServeConfig { workers: 0, ..Default::default() }).is_err());
        assert!(start(toy_mlp(), ServeConfig { queue_cap: 0, ..Default::default() }).is_err());
        // the XNOR path has no conv front: refuse at startup, not at the
        // first forward
        let err = start(
            toy_conv_mlp(),
            ServeConfig { mode: ForwardMode::Bnn, ..Default::default() },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("--bnn does not support conv models"), "{err}");
        // packed-f32 serves the same model fine
        let mut srv = start(toy_conv_mlp(), ServeConfig::default()).unwrap();
        srv.stop();
    }

    #[test]
    fn estimated_wait_scales_with_queue_depth_and_forward_time() {
        let cfg = ServeConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        };
        let ctx = test_ctx(&cfg);
        // empty queue, no forward history: one window
        assert_eq!(estimated_queue_wait(&ctx), Duration::from_millis(1));
        ctx.metrics.record_forward(0.002); // 2ms smoothed forward
        let base = estimated_queue_wait(&ctx);
        assert_eq!(base, Duration::from_millis(3));
        // 8 queued rows at max_batch 4 = 2 batches ahead + own batch
        for _ in 0..8 {
            let (tx, _rx) = sync_channel(1);
            ctx.queue
                .submit(Job { x: vec![0.0; 6], reply: tx, deadline: None })
                .map_err(|_| ())
                .unwrap();
        }
        assert_eq!(estimated_queue_wait(&ctx), Duration::from_millis(9));
    }

    #[test]
    fn signal_latch_state_machine() {
        use signal::{action_for, Action, FORCE_EXIT_CODE};
        // first signal: graceful drain; every later one: force exit
        assert_eq!(action_for(1), Action::BeginDrain);
        assert_eq!(action_for(2), Action::ForceExit);
        assert_eq!(action_for(3), Action::ForceExit);
        assert_eq!(action_for(u32::MAX), Action::ForceExit);
        // the forced exit code is non-zero and distinct from sysexits
        assert_eq!(FORCE_EXIT_CODE, 143);
        // the manual trigger latches `triggered` (and, per its contract,
        // never force-exits — this test staying alive is the proof)
        assert!(!signal::triggered());
        signal::trigger();
        assert!(signal::triggered());
        signal::trigger();
        assert!(signal::triggered());
    }
}
