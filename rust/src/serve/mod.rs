//! `serve/` — online inference over the packed sign-GEMM engine.
//!
//! Turns a `.bcpack` model (the deterministic-BC test-time network,
//! paper Sec. 2.6) into an HTTP service using only `std`:
//!
//! * [`http`] — minimal HTTP/1.1 parsing/writing with hard input caps;
//! * [`batcher`] — the dynamic micro-batching queue that coalesces
//!   concurrent single-row requests into one lane-batched forward (the
//!   whole point: serve throughput rides the batched SIMD path, and a
//!   row's logits are bit-identical solo or coalesced);
//! * [`metrics`] — counters + bounded latency ring behind `/stats`;
//! * [`loadgen`] — the closed-loop load generator (`bcrun loadgen`).
//!
//! ## Threading model
//!
//! One nonblocking **acceptor** (the `Server` thread) hands connections
//! to a bounded channel; `workers` **connection threads** each run one
//! keep-alive connection at a time (read request → route → respond);
//! one **batcher** thread owns the model workspace and executes the
//! coalesced forwards. Backpressure exists at both hops: a full
//! connection backlog answers 503 at accept, a full row queue answers
//! 503 from `/predict`.
//!
//! ## Endpoints
//!
//! | route | semantics |
//! |---|---|
//! | `POST /predict` | `{"x":[...in_dim floats...]}` → `{"pred":c,"batch":b,"logits":[...]}` |
//! | `GET /healthz`  | model + config facts, `{"ok":true,...}` |
//! | `GET /stats`    | counters and latency percentiles (see `metrics`) |
//! | `POST /shutdown`| begin graceful drain (also: SIGTERM / ctrl-c) |
//!
//! ## Shutdown
//!
//! `Server::stop` (triggered by signal, `/shutdown`, or drop) stops
//! accepting, lets every in-flight request finish, drains the batch
//! queue (accepted rows are always answered), then joins all threads.

pub mod batcher;
pub mod http;
pub mod loadgen;
pub mod metrics;

use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::binary::{ForwardMode, PackedMlp};
use crate::ensure;
use crate::kernel::simd;
use crate::util::error::{Context as _, Result};
use crate::util::{Json, Timer};

use batcher::{BatchConfig, Batcher, Job};
use http::{ReadOutcome, Request};
use metrics::Metrics;

/// Serving knobs (`bcrun serve` flags map 1:1).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind host (default loopback; expose deliberately).
    pub addr: String,
    /// TCP port; 0 binds an ephemeral port (see [`Server::addr`]).
    pub port: u16,
    /// Most rows coalesced into one forward.
    pub max_batch: usize,
    /// Batching window: how long the batcher waits for more rows after
    /// noticing the first one. Zero = no waiting.
    pub max_wait: Duration,
    /// Bound on queued rows; beyond it `/predict` answers 503.
    pub queue_cap: usize,
    /// Connection worker threads.
    pub workers: usize,
    /// Accept-to-worker handoff backlog; beyond it accept answers 503.
    pub conn_backlog: usize,
    /// Largest accepted request body (bytes).
    pub max_body: usize,
    /// Wall-time budget for reading one request.
    pub request_timeout: Duration,
    /// Close a keep-alive connection after this much request-free idle
    /// time. Each worker thread serves one connection at a time, so
    /// `workers` bounds the *concurrently-served* persistent
    /// connections — reaping idle sockets is what keeps silent clients
    /// from pinning workers forever.
    pub idle_timeout: Duration,
    /// Suppress the per-lifecycle eprintln lines.
    pub quiet: bool,
    /// Forward engine: classic packed-f32, or the XNOR–popcount BNN
    /// path (`--bnn`). Either way the solo ≡ coalesced bit-exactness
    /// contract holds; in BNN mode hidden activations are sign bits, so
    /// the served function differs from packed-f32 by design.
    pub mode: ForwardMode,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1".into(),
            port: 0,
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            queue_cap: 1024,
            workers: 8,
            conn_backlog: 128,
            max_body: 1 << 20,
            request_timeout: Duration::from_secs(10),
            idle_timeout: Duration::from_secs(30),
            quiet: true,
            mode: ForwardMode::PackedF32,
        }
    }
}

/// Shared request-handling context.
struct Ctx {
    mlp: Arc<PackedMlp>,
    queue: batcher::BatchQueue,
    metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    max_body: usize,
    request_timeout: Duration,
    idle_timeout: Duration,
    /// Active forward engine, echoed by `/stats`.
    mode: ForwardMode,
    /// Workspace footprint for this mode at `max_batch` (static fact).
    activation_bytes: usize,
    /// Prebuilt `/healthz` body (model + config facts are static).
    health_body: String,
}

/// A running server. Dropping it (or calling [`Server::stop`]) performs
/// the graceful drain described in the module docs.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    metrics: Arc<Metrics>,
    accept_join: Option<JoinHandle<()>>,
}

impl Server {
    /// The actually-bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// True once shutdown has been requested (signal, `/shutdown`, or
    /// [`Server::stop`]).
    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    /// Request + wait for the graceful drain. Idempotent.
    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Bind, spawn the batcher + worker + acceptor threads, return a handle.
pub fn start(mlp: PackedMlp, cfg: ServeConfig) -> Result<Server> {
    ensure!(cfg.max_batch >= 1, "max_batch must be >= 1");
    ensure!(cfg.workers >= 1, "workers must be >= 1");
    ensure!(cfg.queue_cap >= 1, "queue_cap must be >= 1");
    ensure!(!mlp.layers.is_empty(), "cannot serve an empty model");
    // note: queue_cap < max_batch is allowed — batches are then bounded
    // by the queue, which is exactly what the overload tests exploit
    let listener = TcpListener::bind((cfg.addr.as_str(), cfg.port))
        .with_context(|| format!("bind {}:{}", cfg.addr, cfg.port))?;
    let addr = listener.local_addr()?;
    listener
        .set_nonblocking(true)
        .context("set_nonblocking on the listener")?;

    let shutdown = Arc::new(AtomicBool::new(false));
    let metrics = Arc::new(Metrics::new());
    let mlp = Arc::new(mlp);
    let batch_cfg = BatchConfig {
        max_batch: cfg.max_batch,
        max_wait: cfg.max_wait,
        queue_cap: cfg.queue_cap,
        mode: cfg.mode,
    };
    let batcher = Batcher::start(Arc::clone(&mlp), batch_cfg, Arc::clone(&metrics));
    let health_body = health_json(&mlp, &cfg).to_string();
    let activation_bytes = mlp.activation_memory_bytes(cfg.max_batch, cfg.mode);
    let ctx = Arc::new(Ctx {
        mlp,
        queue: batcher.queue.clone(),
        metrics: Arc::clone(&metrics),
        shutdown: Arc::clone(&shutdown),
        max_body: cfg.max_body,
        request_timeout: cfg.request_timeout,
        idle_timeout: cfg.idle_timeout,
        mode: cfg.mode,
        activation_bytes,
        health_body,
    });

    let (conn_tx, conn_rx) = sync_channel::<TcpStream>(cfg.conn_backlog.max(1));
    let conn_rx = Arc::new(Mutex::new(conn_rx));
    let mut worker_joins = Vec::with_capacity(cfg.workers);
    for i in 0..cfg.workers {
        let rx = Arc::clone(&conn_rx);
        let ctx = Arc::clone(&ctx);
        let j = std::thread::Builder::new()
            .name(format!("bc-conn-{i}"))
            .spawn(move || conn_worker(&rx, &ctx))
            .context("spawn connection worker")?;
        worker_joins.push(j);
    }

    let accept_shutdown = Arc::clone(&shutdown);
    let accept_metrics = Arc::clone(&metrics);
    let quiet = cfg.quiet;
    let accept_join = std::thread::Builder::new()
        .name("bc-accept".into())
        .spawn(move || {
            acceptor(&listener, conn_tx, &accept_shutdown, &accept_metrics);
            // conn_tx is dropped by acceptor(): workers drain queued
            // connections, finish in-flight requests, then exit
            for j in worker_joins {
                let _ = j.join();
            }
            // only now is it safe to drain + stop the batcher: no worker
            // is left holding an unanswered row
            let mut batcher = batcher;
            batcher.stop();
            if !quiet {
                eprintln!("serve: drained and stopped");
            }
        })
        .context("spawn acceptor")?;

    Ok(Server { addr, shutdown, metrics, accept_join: Some(accept_join) })
}

fn health_json(mlp: &PackedMlp, cfg: &ServeConfig) -> Json {
    let mut m = BTreeMap::new();
    m.insert("ok".to_string(), Json::Bool(true));
    m.insert("in_dim".to_string(), Json::Num(mlp.in_dim as f64));
    m.insert("classes".to_string(), Json::Num(mlp.classes as f64));
    m.insert("layers".to_string(), Json::Num(mlp.layers.len() as f64));
    m.insert(
        "weight_bytes".to_string(),
        Json::Num(mlp.weight_memory_bytes() as f64),
    );
    m.insert(
        "activation_bytes".to_string(),
        Json::Num(mlp.activation_memory_bytes(cfg.max_batch, cfg.mode) as f64),
    );
    m.insert("mode".to_string(), Json::Str(cfg.mode.label().to_string()));
    m.insert(
        "isa_selected".to_string(),
        Json::Str(simd::active().name().to_string()),
    );
    m.insert("max_batch".to_string(), Json::Num(cfg.max_batch as f64));
    m.insert(
        "max_wait_us".to_string(),
        Json::Num(cfg.max_wait.as_micros() as f64),
    );
    m.insert("queue_cap".to_string(), Json::Num(cfg.queue_cap as f64));
    m.insert("workers".to_string(), Json::Num(cfg.workers as f64));
    Json::Obj(m)
}

fn acceptor(
    listener: &TcpListener,
    conn_tx: std::sync::mpsc::SyncSender<TcpStream>,
    shutdown: &AtomicBool,
    metrics: &Metrics,
) {
    while !shutdown.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => match conn_tx.try_send(stream) {
                Ok(()) => {}
                Err(TrySendError::Full(stream)) => {
                    // every worker busy and the backlog full: shed load
                    // here instead of queueing unbounded connections
                    Metrics::bump(&metrics.overloads);
                    let mut s = stream;
                    let _ = s.set_nonblocking(false);
                    let _ = http::write_response(
                        &mut s,
                        503,
                        &http::error_body("overloaded: connection backlog full"),
                        false,
                    );
                }
                Err(TrySendError::Disconnected(_)) => break,
            },
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }
    // dropping conn_tx wakes the workers out of recv()
}

fn conn_worker(rx: &Mutex<Receiver<TcpStream>>, ctx: &Ctx) {
    loop {
        // holding the lock only while waiting for the *next* connection;
        // handling happens with the lock released
        let stream = match rx.lock().unwrap().recv() {
            Ok(s) => s,
            Err(_) => return, // acceptor gone and backlog drained
        };
        handle_connection(stream, ctx);
    }
}

fn handle_connection(stream: TcpStream, ctx: &Ctx) {
    // accepted sockets may inherit the listener's nonblocking mode on
    // some platforms — normalize, then use a short read timeout so idle
    // keep-alive connections poll the shutdown flag
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    let mut reader = BufReader::new(stream);
    let mut last_request = std::time::Instant::now();
    loop {
        match http::read_request(&mut reader, ctx.max_body, ctx.request_timeout) {
            ReadOutcome::Idle => {
                if ctx.shutdown.load(Ordering::Acquire) {
                    return; // graceful: nothing in flight on this socket
                }
                // reap silent keep-alive sockets: each worker serves one
                // connection at a time, so a client that connects and
                // goes quiet would otherwise pin a worker forever and
                // starve the backlog
                if last_request.elapsed() >= ctx.idle_timeout {
                    return;
                }
            }
            ReadOutcome::Closed => return,
            ReadOutcome::Bad(status, body) => {
                Metrics::bump(&ctx.metrics.requests);
                Metrics::bump(&ctx.metrics.bad_requests);
                let _ = http::write_response(reader.get_mut(), status, &body, false);
                return;
            }
            ReadOutcome::Request(req) => {
                last_request = std::time::Instant::now();
                let keep = req.keep_alive && !ctx.shutdown.load(Ordering::Acquire);
                let (status, body) = route(ctx, &req);
                if http::write_response(reader.get_mut(), status, &body, keep).is_err() {
                    return;
                }
                if !keep {
                    return;
                }
            }
        }
    }
}

fn route(ctx: &Ctx, req: &Request) -> (u16, String) {
    Metrics::bump(&ctx.metrics.requests);
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/predict") => predict(ctx, &req.body),
        ("GET", "/healthz") => (200, ctx.health_body.clone()),
        ("GET", "/stats") => {
            // augment the counters with the engine facts here (rather
            // than widening Metrics::snapshot, which has many callers)
            let mut snap = ctx.metrics.snapshot(ctx.queue.depth());
            if let Json::Obj(m) = &mut snap {
                m.insert("mode".to_string(), Json::Str(ctx.mode.label().to_string()));
                m.insert(
                    "isa_selected".to_string(),
                    Json::Str(simd::active().name().to_string()),
                );
                m.insert(
                    "weight_bytes".to_string(),
                    Json::Num(ctx.mlp.weight_memory_bytes() as f64),
                );
                m.insert(
                    "activation_bytes".to_string(),
                    Json::Num(ctx.activation_bytes as f64),
                );
            }
            (200, snap.to_string())
        }
        ("POST", "/shutdown") => {
            ctx.shutdown.store(true, Ordering::Release);
            let mut m = BTreeMap::new();
            m.insert("ok".to_string(), Json::Bool(true));
            m.insert("draining".to_string(), Json::Bool(true));
            (200, Json::Obj(m).to_string())
        }
        _ => {
            Metrics::bump(&ctx.metrics.not_found);
            (
                404,
                http::error_body(&format!("no route {} {}", req.method, req.path)),
            )
        }
    }
}

fn predict(ctx: &Ctx, body: &[u8]) -> (u16, String) {
    let t = Timer::start();
    let parsed = match parse_predict(ctx, body) {
        Ok(x) => x,
        Err(msg) => {
            Metrics::bump(&ctx.metrics.bad_requests);
            return (400, http::error_body(&msg));
        }
    };
    let (reply_tx, reply_rx) = sync_channel(1);
    if ctx.queue.submit(Job { x: parsed, reply: reply_tx }).is_err() {
        Metrics::bump(&ctx.metrics.overloads);
        return (503, http::error_body("overloaded: batch queue full"));
    }
    match reply_rx.recv_timeout(Duration::from_secs(30)) {
        Ok(reply) => {
            Metrics::bump(&ctx.metrics.predictions);
            ctx.metrics.record_latency(t.elapsed_s());
            let mut m = BTreeMap::new();
            m.insert("pred".to_string(), Json::Num(reply.pred as f64));
            m.insert("batch".to_string(), Json::Num(reply.batch_rows as f64));
            m.insert(
                "logits".to_string(),
                Json::Arr(reply.logits.iter().map(|&v| Json::Num(v as f64)).collect()),
            );
            (200, Json::Obj(m).to_string())
        }
        Err(_) => (500, http::error_body("batcher unavailable")),
    }
}

/// Validate a `/predict` body into one input row. Every failure is a
/// client error (400) with an actionable message; the parser itself is
/// depth/size-capped (`Json::parse_untrusted`) because these bytes come
/// off the network.
fn parse_predict(ctx: &Ctx, body: &[u8]) -> Result<Vec<f32>, String> {
    let text =
        std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let json = Json::parse_untrusted(text, ctx.max_body)?;
    let xs = json
        .get("x")
        .and_then(Json::as_arr)
        .ok_or_else(|| "body must be {\"x\": [..numbers..]}".to_string())?;
    if xs.len() != ctx.mlp.in_dim {
        return Err(format!(
            "'x' must have {} features, got {}",
            ctx.mlp.in_dim,
            xs.len()
        ));
    }
    let mut x = Vec::with_capacity(xs.len());
    for (i, v) in xs.iter().enumerate() {
        match v.as_f64() {
            Some(f) if f.is_finite() => x.push(f as f32),
            _ => return Err(format!("'x'[{i}] is not a finite number")),
        }
    }
    Ok(x)
}

/// Process-wide shutdown signal latch for `bcrun serve` (SIGINT/SIGTERM
/// on unix; a no-op installer elsewhere — `/shutdown` still works).
pub mod signal {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TRIGGERED: AtomicBool = AtomicBool::new(false);

    pub fn triggered() -> bool {
        TRIGGERED.load(Ordering::Acquire)
    }

    /// Test hook / manual trigger.
    pub fn trigger() {
        TRIGGERED.store(true, Ordering::Release);
    }

    /// Install handlers for SIGINT (2) and SIGTERM (15) that set the
    /// latch. Uses the C `signal` symbol already linked through std —
    /// the handler only stores to an atomic, which is async-signal-safe.
    #[cfg(unix)]
    pub fn install() {
        extern "C" fn handler(_sig: i32) {
            TRIGGERED.store(true, Ordering::Release);
        }
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        // SAFETY: registering an async-signal-safe handler (one relaxed
        // atomic store, no allocation, no locks).
        unsafe {
            signal(2, handler);
            signal(15, handler);
        }
    }

    #[cfg(not(unix))]
    pub fn install() {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn toy_mlp() -> PackedMlp {
        let mut rng = Rng::new(40);
        let w1: Vec<f32> = (0..6 * 70).map(|_| rng.normal()).collect();
        let w2: Vec<f32> = (0..70 * 3).map(|_| rng.normal()).collect();
        PackedMlp::build(
            vec![(w1, 6, 70), (w2, 70, 3)],
            vec![
                Some((vec![1.0; 70], vec![0.0; 70], vec![0.1; 70], vec![1.0; 70])),
                None,
            ],
            Some(vec![0.1, -0.1, 0.0]),
        )
    }

    fn test_ctx(cfg: &ServeConfig) -> Ctx {
        let mlp = Arc::new(toy_mlp());
        let health_body = health_json(&mlp, cfg).to_string();
        let activation_bytes = mlp.activation_memory_bytes(cfg.max_batch, cfg.mode);
        Ctx {
            mlp,
            queue: batcher::BatchQueue::bounded(cfg.queue_cap),
            metrics: Arc::new(Metrics::new()),
            shutdown: Arc::new(AtomicBool::new(false)),
            max_body: cfg.max_body,
            request_timeout: cfg.request_timeout,
            idle_timeout: cfg.idle_timeout,
            mode: cfg.mode,
            activation_bytes,
            health_body,
        }
    }

    #[test]
    fn parse_predict_validates_shape_and_values() {
        let cfg = ServeConfig::default();
        let ctx = test_ctx(&cfg);
        let ok = parse_predict(&ctx, br#"{"x":[1,2,3,4,5,6]}"#).unwrap();
        assert_eq!(ok.len(), 6);
        for bad in [
            &b"not json"[..],
            br#"{"y":[1]}"#,
            br#"{"x":[1,2,3]}"#,
            br#"{"x":[1,2,3,4,5,"s"]}"#,
            br#"{"x":[1,2,3,4,5,1e999]}"#,
            b"\xff\xfe",
        ] {
            assert!(parse_predict(&ctx, bad).is_err(), "{:?}", String::from_utf8_lossy(bad));
        }
    }

    #[test]
    fn health_json_reports_model_facts() {
        let cfg = ServeConfig { max_batch: 32, ..Default::default() };
        let ctx = test_ctx(&cfg);
        let j = Json::parse(&ctx.health_body).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("in_dim").unwrap().as_usize(), Some(6));
        assert_eq!(j.get("classes").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("max_batch").unwrap().as_usize(), Some(32));
        assert_eq!(j.get("mode").unwrap().as_str(), Some("packed-f32"));
        assert_eq!(
            j.get("isa_selected").unwrap().as_str(),
            Some(simd::active().name())
        );
        let act = j.get("activation_bytes").unwrap().as_usize().unwrap();
        assert_eq!(act, ctx.mlp.activation_memory_bytes(32, ForwardMode::PackedF32));
    }

    #[test]
    fn health_json_reports_bnn_mode_facts() {
        let cfg = ServeConfig {
            max_batch: 16,
            mode: ForwardMode::Bnn,
            ..Default::default()
        };
        let ctx = test_ctx(&cfg);
        let j = Json::parse(&ctx.health_body).unwrap();
        assert_eq!(j.get("mode").unwrap().as_str(), Some("bnn"));
        let act = j.get("activation_bytes").unwrap().as_usize().unwrap();
        assert_eq!(act, ctx.mlp.activation_memory_bytes(16, ForwardMode::Bnn));
        // bit activations are far smaller than the f32 ping-pong
        assert!(act < ctx.mlp.activation_memory_bytes(16, ForwardMode::PackedF32));
    }

    #[test]
    fn start_rejects_bad_configs() {
        assert!(start(toy_mlp(), ServeConfig { max_batch: 0, ..Default::default() }).is_err());
        assert!(start(toy_mlp(), ServeConfig { workers: 0, ..Default::default() }).is_err());
        assert!(start(toy_mlp(), ServeConfig { queue_cap: 0, ..Default::default() }).is_err());
    }
}
